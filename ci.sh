#!/usr/bin/env bash
# Local CI gate: formatting, lints, release build, tests, bench/doc rot
# checks. Mirrored by .github/workflows/ci.yml.
#
#   ./ci.sh          run everything
#   ./ci.sh quick    fast feedback: fmt + clippy + tests (skips the release
#                    build, bench compile-check and doc build)
#
# PJRT-dependent tests skip themselves when no PJRT runtime is present, so
# this script is expected to pass on machines without one.

set -euo pipefail

if ! command -v cargo >/dev/null 2>&1; then
    echo "ci.sh: error: \`cargo\` not found on PATH." >&2
    echo "ci.sh: install a Rust toolchain (https://rustup.rs) and retry." >&2
    exit 1
fi

cd "$(dirname "$0")/rust"

# Print the step header once, then run exactly that command.
step() {
    echo
    echo "=== $* ==="
    "$@"
}

step cargo fmt --check

step cargo clippy --all-targets -- -D warnings

if [[ "${1:-}" != "quick" ]]; then
    step cargo build --release

    # Benches and docs must not rot silently: compile-check every bench
    # target and build the docs with warnings denied.
    step cargo bench --no-run
    step env RUSTDOCFLAGS="-D warnings" cargo doc --no-deps
fi

step cargo test -q

echo
echo "ci.sh: all checks passed"
