#!/usr/bin/env bash
# Local CI gate: formatting, lints, release build, tests.
#
#   ./ci.sh          run everything
#   ./ci.sh quick    skip the release build (fmt + clippy + tests)
#
# PJRT-dependent tests skip themselves when no PJRT runtime is present, so
# this script is expected to pass on machines without one.

set -euo pipefail
cd "$(dirname "$0")/rust"

step() {
    echo
    echo "=== $* ==="
}

step cargo fmt --check
cargo fmt --check

step cargo clippy --all-targets -- -D warnings
cargo clippy --all-targets -- -D warnings

if [[ "${1:-}" != "quick" ]]; then
    step cargo build --release
    cargo build --release
fi

step cargo test -q
cargo test -q

echo
echo "ci.sh: all checks passed"
