#!/usr/bin/env bash
# Local CI gate: formatting, lints, release build, tests, bench/doc rot
# checks. Mirrored by .github/workflows/ci.yml.
#
#   ./ci.sh          run everything
#   ./ci.sh quick    fast feedback: fmt + clippy + tests (skips the release
#                    build, bench compile-check and doc build)
#
# PJRT-dependent tests skip themselves when no PJRT runtime is present, so
# this script is expected to pass on machines without one.

set -euo pipefail

if ! command -v cargo >/dev/null 2>&1; then
    echo "ci.sh: error: \`cargo\` not found on PATH." >&2
    echo "ci.sh: install a Rust toolchain (https://rustup.rs) and retry." >&2
    exit 1
fi

cd "$(dirname "$0")/rust"

# Print the step header once, then run exactly that command.
step() {
    echo
    echo "=== $* ==="
    "$@"
}

step cargo fmt --check

step cargo clippy --all-targets -- -D warnings

if [[ "${1:-}" != "quick" ]]; then
    step cargo build --release

    # Examples are part of the contract: compile-check all of them and
    # actually execute the quickstart (bind-once/run-many + concurrent
    # dispatch of one stencil handle, end to end).
    step cargo build --release --examples
    step cargo run --release --example quickstart

    # Benches and docs must not rot silently: compile-check every bench
    # target and build the docs with warnings denied.
    step cargo bench --no-run
    step env RUSTDOCFLAGS="-D warnings" cargo doc --no-deps

    # `repro run --json` must emit parseable JSON (the machine-readable
    # output feeding the perf-trajectory tooling).
    echo
    echo "=== repro run --json smoke ==="
    ./target/release/repro run --stencil laplacian --backend vector \
        --domain 8x8x4 --iters 2 --json > /tmp/gt4rs_run.json
    if command -v python3 >/dev/null 2>&1; then
        python3 -m json.tool /tmp/gt4rs_run.json >/dev/null
        echo "repro run --json: parseable JSON"
    else
        grep -q '"execute_ns"' /tmp/gt4rs_run.json
        echo "repro run --json: python3 missing, structural grep passed"
    fi
fi

step cargo test -q

echo
echo "ci.sh: all checks passed"
