#!/usr/bin/env bash
# Local CI gate: formatting, lints, release build, tests, bench/doc rot
# checks. Mirrored by .github/workflows/ci.yml (which additionally runs
# the test suites under a REPRO_THREADS matrix on multi-core runners).
#
#   ./ci.sh          run everything
#   ./ci.sh quick    fast feedback: fmt + clippy + bench compile-check +
#                    tests (skips the release build, examples, doc build
#                    and the JSON smoke runs)
#   ./ci.sh tsan     ThreadSanitizer pass over the concurrency unit tests
#                    (halo exchange, worker pool, storage views); needs a
#                    nightly toolchain with the rust-src component
#
# PJRT-dependent tests skip themselves when no PJRT runtime is present, so
# this script is expected to pass on machines without one.

set -euo pipefail

if ! command -v cargo >/dev/null 2>&1; then
    echo "ci.sh: error: \`cargo\` not found on PATH." >&2
    echo "ci.sh: install a Rust toolchain (https://rustup.rs) and retry." >&2
    exit 1
fi

cd "$(dirname "$0")/rust"

# Print the step header once, then run exactly that command.
step() {
    echo
    echo "=== $* ==="
    "$@"
}

# ThreadSanitizer mode: interpret the halo-exchange rendezvous, worker
# pool and storage-view tests under TSan (mirrors the hosted `tsan` job).
# `-Zsanitizer=thread` needs nightly, and std must be rebuilt instrumented
# (`-Zbuild-std`, which needs the rust-src component).
if [[ "${1:-}" == "tsan" ]]; then
    if ! cargo +nightly --version >/dev/null 2>&1; then
        echo "ci.sh tsan: nightly toolchain not installed; skipping." >&2
        echo "ci.sh tsan: rustup toolchain install nightly && rustup component add rust-src --toolchain nightly" >&2
        exit 0
    fi
    step env RUSTFLAGS="-Zsanitizer=thread" \
        cargo +nightly test -Zbuild-std --target x86_64-unknown-linux-gnu \
        --lib -- backend::shard:: storage::view::
    echo
    echo "ci.sh: tsan checks passed"
    exit 0
fi

step cargo fmt --check

step cargo clippy --all-targets -- -D warnings

# Benches rot silently when only the hosted full job compiles them:
# compile-check every bench target in quick mode too.
step cargo bench --no-run

if [[ "${1:-}" != "quick" ]]; then
    step cargo build --release

    # Examples are part of the contract: compile-check all of them and
    # actually execute the quickstart (bind-once/run-many + concurrent
    # dispatch + intra-call sharding of one stencil handle, end to end).
    step cargo build --release --examples
    step cargo run --release --example quickstart

    # Docs must not rot silently: build with warnings denied.
    step env RUSTDOCFLAGS="-D warnings" cargo doc --no-deps

    # `repro run --json` must emit parseable JSON (the machine-readable
    # output feeding the perf-trajectory tooling).
    echo
    echo "=== repro run --json smoke ==="
    ./target/release/repro run --stencil laplacian --backend vector \
        --domain 8x8x4 --iters 2 --threads 2 --json > /tmp/gt4rs_run.json
    if command -v python3 >/dev/null 2>&1; then
        python3 -m json.tool /tmp/gt4rs_run.json >/dev/null
        echo "repro run --json: parseable JSON"
    else
        grep -q '"execute_ns"' /tmp/gt4rs_run.json
        echo "repro run --json: python3 missing, structural grep passed"
    fi

    # The A6 scaling bench (tiny mode) runs its bitwise honesty gate and
    # the Auto-degrade assertion, and its JSON artifact must parse under
    # the same contract as `repro run --json`. The scaling-regression
    # gate (mirrored by the hosted bench-smoke job) then checks that the
    # `vadv_carry` sequential-carry kernel really runs sharded at
    # threads=4 — effective_threads == 1 there would mean the per-level
    # halo exchange regressed back to the serial fallback.
    step cargo bench --bench scaling -- --tiny --json /tmp/gt4rs_scaling.json
    echo
    echo "=== BENCH_scaling.json parse + scaling-regression gate ==="
    if command -v python3 >/dev/null 2>&1; then
        python3 -m json.tool /tmp/gt4rs_scaling.json >/dev/null
        python3 - /tmp/gt4rs_scaling.json <<'EOF'
import json, sys
rows = json.load(open(sys.argv[1]))
carry = [r for r in rows
         if r["stencil"] == "vadv_carry" and r["config"] == "threads=4"]
assert carry, "no vadv_carry threads=4 rows in scaling JSON"
bad = [r for r in carry if r["threads_used"] <= 1]
assert not bad, f"serial-fallback regression (threads_used <= 1): {bad}"
bad = [r for r in carry if r["serial_fallbacks"] > 0]
assert not bad, f"serial fallbacks reported for a sharded carry: {bad}"
print("scaling gate: vadv_carry sharded at threads=4 "
      f"(used={[r['threads_used'] for r in carry]}, "
      f"exchanges={[r['exchanges'] for r in carry]})")
EOF
        echo "scaling bench --json: parseable JSON, carry kernel sharded"
    else
        grep -q '"threads_used"' /tmp/gt4rs_scaling.json
        echo "scaling bench --json: python3 missing, structural grep passed"
    fi

    # The A7 kernels bench (tiny mode) runs its own honesty gates —
    # specialized bitwise-equal to interpreted, fast-math within
    # tolerance, the f32 column bitwise-equal to its own f32 interpreted
    # run and genuinely narrower than f64 — before timing anything; its
    # JSON artifact must parse under the same contract.
    step cargo bench --bench kernels -- --tiny --json /tmp/gt4rs_kernels.json
    echo
    echo "=== BENCH_kernels.json parse smoke ==="
    if command -v python3 >/dev/null 2>&1; then
        python3 -m json.tool /tmp/gt4rs_kernels.json >/dev/null
        echo "kernels bench --json: parseable JSON"
    else
        grep -q '"speedup_vs_interpreted"' /tmp/gt4rs_kernels.json
        echo "kernels bench --json: python3 missing, structural grep passed"
    fi

    # The A8 serve bench (tiny mode) gates on its wire-vs-in-process
    # bitwise check before timing anything; its JSON artifact must parse
    # under the same contract.
    step cargo bench --bench serve -- --tiny --json /tmp/gt4rs_serve.json
    echo
    echo "=== BENCH_serve.json parse smoke ==="
    if command -v python3 >/dev/null 2>&1; then
        python3 -m json.tool /tmp/gt4rs_serve.json >/dev/null
        echo "serve bench --json: parseable JSON"
    else
        grep -q '"requests_per_sec"' /tmp/gt4rs_serve.json
        echo "serve bench --json: python3 missing, structural grep passed"
    fi

    # The A9 warmstart bench (tiny mode) gates on warm-loaded artifacts
    # being bitwise-identical to cold compiles at O0-O3 x tier x sharding
    # before timing anything; its JSON artifact must parse under the same
    # contract.
    step cargo bench --bench warmstart -- --tiny --json /tmp/gt4rs_warmstart.json
    echo
    echo "=== BENCH_warmstart.json parse smoke ==="
    if command -v python3 >/dev/null 2>&1; then
        python3 -m json.tool /tmp/gt4rs_warmstart.json >/dev/null
        echo "warmstart bench --json: parseable JSON"
    else
        grep -q '"speedup_warm_vs_cold"' /tmp/gt4rs_warmstart.json
        echo "warmstart bench --json: python3 missing, structural grep passed"
    fi

    # Two-process warm-start smoke: `repro warm` populates a cache
    # directory, then a *fresh process* serves the same stencil with zero
    # pipeline runs (the pipeline_compiles honesty counter in the JSON
    # output proves it) and at least one persist hit.
    echo
    echo "=== repro warm two-process smoke ==="
    WARM_DIR=$(mktemp -d /tmp/gt4rs_warm.XXXXXX)
    ./target/release/repro warm --cache-dir "$WARM_DIR" --stencil hdiff --opt-level 3
    ./target/release/repro run --stencil hdiff --opt-level 3 --backend vector \
        --domain 8x8x4 --cache-dir "$WARM_DIR" --json > /tmp/gt4rs_warmrun.json
    grep -q '"pipeline_compiles":0' /tmp/gt4rs_warmrun.json
    grep -q '"persist_hits":[1-9]' /tmp/gt4rs_warmrun.json
    ./target/release/repro cache --cache-dir "$WARM_DIR" | grep -q 'ir'
    rm -rf "$WARM_DIR"
    echo "repro warm smoke: fresh process served hdiff with 0 pipeline compiles"

    # serve smoke: daemon on an ephemeral port, one bind/run/metrics/
    # shutdown round-trip through `repro client`, clean exit.
    echo
    echo "=== repro serve smoke ==="
    ./target/release/repro serve --addr 127.0.0.1:0 > /tmp/gt4rs_serve.log 2>&1 &
    SERVE_PID=$!
    for _ in $(seq 1 100); do
        grep -q '^listening on ' /tmp/gt4rs_serve.log 2>/dev/null && break
        sleep 0.05
    done
    ADDR=$(sed -n 's/^listening on //p' /tmp/gt4rs_serve.log | head -n1)
    test -n "$ADDR"
    BIND=$(./target/release/repro client --addr "$ADDR" \
        --request '{"op":"bind","stencil":"hdiff","domain":[16,16,8]}')
    echo "$BIND" | grep -q '"ok":true'
    LEASE=$(echo "$BIND" | sed -n 's/.*"lease":\([0-9]*\).*/\1/p')
    ./target/release/repro client --addr "$ADDR" \
        --request "{\"op\":\"run\",\"lease\":$LEASE}" | grep -q '"ok":true'
    ./target/release/repro client --addr "$ADDR" \
        --request '{"op":"metrics"}' | grep -q 'serve_requests_total'
    ./target/release/repro client --addr "$ADDR" \
        --request '{"op":"shutdown"}' | grep -q '"stopping":true'
    wait "$SERVE_PID"
    echo "repro serve smoke: bind/run/metrics/shutdown OK"
fi

step cargo test -q

# The UnsafeCell-based shared-slab storage views, the sharded writers
# built on their disjoint-write contract, and the per-level halo-exchange
# rendezvous (publish/wait on StorageView halo columns) are exactly the
# code Miri exists to check — the `storage::`/`backend::shard::` filters
# reach the halo_* and rendezvous unit tests too. Gated on the component
# being installed (the hosted `miri` job always runs it); quick mode
# skips it for latency.
if [[ "${1:-}" != "quick" ]]; then
    if cargo miri --version >/dev/null 2>&1; then
        step env MIRIFLAGS="-Zmiri-disable-isolation" \
            cargo miri test --lib -- storage:: backend::shard::
    else
        echo
        echo "=== cargo miri test (skipped: miri component not installed) ==="
    fi
fi

echo
echo "ci.sh: all checks passed"
