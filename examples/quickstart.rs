//! Quickstart: define a stencil in GTScript-RS, compile it through the
//! pipeline, inspect the IR the toolchain produced, and run it on two
//! backends — the 60-second tour of the framework.
//!
//!     cargo run --release --example quickstart

use anyhow::Result;
use gt4rs::coordinator::Coordinator;
use gt4rs::storage::Storage;

const SRC: &str = "
    # A smoothing stencil: out = (1-w)*phi + w/4 * neighbor-average
    stencil smooth(phi: Field<f64>, out: Field<f64>; w: f64) {
        with computation(PARALLEL), interval(...) {
            avg = (phi[-1,0,0] + phi[1,0,0] + phi[0,-1,0] + phi[0,1,0]) * 0.25;
            out = (1.0 - w) * phi + w * avg;
        }
    }";

fn main() -> Result<()> {
    let mut coord = Coordinator::new();

    // 1. Compile: parse -> inline -> resolve -> lower -> checks -> extents.
    let fp = coord.compile_source(SRC, "smooth", &Default::default())?;
    let ir = coord.ir(fp)?;
    println!("=== implementation IR ===\n{}", ir.dump());

    // 2. Allocate storages with exactly the halos the analysis derived
    //    (the paper's backend-aware `storage` containers).
    let domain = [16, 16, 4];
    let mut phi = coord.alloc_field(fp, "phi", domain)?;
    let mut out = coord.alloc_field(fp, "out", domain)?;
    for i in -1..17i64 {
        for j in -1..17i64 {
            for k in 0..4i64 {
                phi.set(i, j, k, (i as f64 * 0.3).sin() + (j as f64 * 0.2).cos());
            }
        }
    }

    // 3. Run on the interpreting backend...
    {
        let mut refs: Vec<(&str, &mut Storage)> =
            vec![("phi", &mut phi), ("out", &mut out)];
        let stats = coord.run(fp, "debug", &mut refs, &[("w", 0.5)], domain)?;
        println!("debug backend:  {:?} (checks {:?})", stats.execute, stats.checks);
    }
    let sum_debug = out.domain_sum();

    // 4. ...and on the XLA-codegen backend (JIT-compiled via PJRT); the
    //    second call hits the executable cache.
    for round in 0..2 {
        let mut refs: Vec<(&str, &mut Storage)> =
            vec![("phi", &mut phi), ("out", &mut out)];
        let stats = coord.run(fp, "xla", &mut refs, &[("w", 0.5)], domain)?;
        println!(
            "xla backend ({}): {:?}",
            if round == 0 { "compile+run" } else { "cached" },
            stats.execute
        );
    }
    let sum_xla = out.domain_sum();
    println!("checksums: debug {sum_debug:.12e}  xla {sum_xla:.12e}");
    assert!((sum_debug - sum_xla).abs() < 1e-9);
    println!("quickstart OK");
    Ok(())
}
