//! # gt4rs — GT4Py reproduced as a Rust + JAX/Pallas stencil framework
//!
//! A reproduction of *"GT4Py: High Performance Stencils for Weather and
//! Climate Applications using Python"* (Paredes et al., CSCS/ETH, 2023).
//! The compile flow has five layers — the paper's separation of the
//! mathematical definition from the implementation, with an explicit
//! optimizer in between (where the paper's "transformations to obtain the
//! performance of state-of-the-art C++ and CUDA implementations" live):
//!
//! ```text
//! dsl ──► analysis ──► opt ──► ir ──► backends
//! ```
//!
//! * **Frontend** ([`dsl`]) — GTScript-RS: a textual DSL plus a builder API
//!   producing the definition IR;
//! * **Analysis** ([`analysis`]) — inlining, name resolution, external
//!   folding, control-flow lowering, semantic checks, and halo/extent
//!   analysis, producing the *pre-optimization* implementation IR;
//! * **Optimizer** ([`opt`]) — a pass manager with named, ordered,
//!   individually-toggleable passes rewriting the IR before any backend
//!   sees it: constant folding + CSE (`fold-cse`), dead-stage/temporary
//!   elimination (`dce`), extent-checked stage fusion (`fuse`), and
//!   temporary demotion (`demote`) to one of three locality classes —
//!   `register` (pure SSA values), `plane` (group-scoped scratch for
//!   horizontally-offset reads) or `ring` (a k-cache of recent level
//!   planes for sweep carries with vertical offsets). The CLI's
//!   `--opt-level {0,1,2,3}` selects the configuration; every
//!   configuration produces bit-identical results on the interpreting
//!   backends. Level 3 runs the same passes as level 2 and additionally
//!   requests the *fused execution strategy* (`StencilIr::fused`);
//! * **Implementation IR** ([`ir`]) — the scheduled, lowered, optimized
//!   form all backends consume, with fusion groups and storage classes as
//!   first-class metadata included in the canonical form/fingerprint;
//! * **Backends** ([`backend`]) — `debug` (scalar reference interpreter,
//!   ignores optimization metadata by design), `vector` (plane-vectorized
//!   evaluator; demoted temporaries live in backend-local buffers instead
//!   of fields; at `--opt-level 3` it compiles each fusion group's stages
//!   into flat SSA tapes ([`backend::cexpr::CTape`], with cross-stage CSE
//!   via value numbering) and evaluates every output and demoted temporary
//!   of a group in one loop nest per interval ([`backend::fused`]) — no
//!   per-expression-node region buffers. Each tape is additionally
//!   lowered at compile time into a *kernel plan*
//!   ([`backend::kernels`]): per-op monomorphized kernels with
//!   pre-resolved strides and offsets in dense slot tables, per-op
//!   bounds intersected into a guard-free interior rectangle evaluated
//!   as cache-blocked j-tiles (guarded prologue/epilogue strips cover
//!   the fringes), dispatched by the default `specialized` executor
//!   tier ([`backend::kernels::ExecTier`]) — bitwise-identical to the
//!   interpreted tape walk by contract, with an opt-in, separately
//!   fingerprinted fast-math mode (FMA contraction) validated by
//!   tolerance norms), `xla` (XlaBuilder codegen
//!   JIT-compiled on PJRT; demoted temporaries emit no intermediate zero
//!   boxes), and `pjrt-aot` (prebuilt JAX/**Pallas** HLO artifacts). All
//!   backends execute through `&self` and are `Send + Sync`: program and
//!   executable caches live behind interior mutability, so one shared
//!   instance serves concurrent dispatch from many threads (the
//!   interpreting backends run fully in parallel; the PJRT-backed ones
//!   serialize on their client). The `vector` backend additionally
//!   shards a *single call* across cores ([`backend::shard`], the
//!   multi-core `gt:cpu_*` analog): a [`Sharding`] plan splits the
//!   domain into halo-correct i-slabs run on a persistent worker pool —
//!   slabs are the parallel units (demoted temporaries and ring k-caches
//!   stay slab-local, halo overlap is recomputed), tiers/stages are
//!   globally ordered barriers, sequential k-sweeps with cross-slab
//!   field carries exchange halos at per-level (or per-stage) rendezvous
//!   points instead of degrading to serial, and
//!   `Field3D` writes are clamped to each slab's owned columns. Every
//!   plan is bitwise-identical to serial execution, enforced by the
//!   property suites and the hosted CI thread-matrix;
//! * **Storage** ([`storage`]) — NumPy-like 3-D containers with
//!   backend-specific layout, alignment and halo padding;
//! * **Coordinator** ([`coordinator`]) — compiles definitions (memoized,
//!   opt-config-salted cache keys so opt levels never collide) and mints
//!   first-class [`Stencil`] handles, the `gtscript.stencil(backend=...)`
//!   analog: a cheap-to-clone, `Send + Sync` pairing of one cached
//!   `Arc<StencilIr>` with one backend instance. Handles dispatch through
//!   an invocation builder — [`Stencil::bind`] performs the layout/halo/
//!   dtype validation *once* and yields a reusable
//!   [`BoundInvocation`] whose repeat calls only re-check shapes
//!   (reproducing the paper's Fig. 3 dashed-line overhead elimination
//!   without disabling checks), and cloned handles run the same compiled
//!   stencil concurrently from many threads;
//! * **Cache** ([`cache`]) — fingerprint-based compilation caching,
//!   handing out shared `Arc<StencilIr>` artifacts (a hit is a refcount
//!   bump, never a deep copy);
//! * **Persist** ([`persist`]) — the on-disk half of caching (the
//!   `.gt_cache` analog): a versioned, integrity-checked artifact store
//!   keyed by the same opt-salted fingerprints, holding serialized
//!   canonical IR, the vector backend's compiled fused tapes, and
//!   `pjrt-aot` HLO text. Entries carry a schema version, toolchain tag
//!   and FNV-1a content digest — corruption or version skew is a miss,
//!   never an error — and writes are atomic (temp file + rename) so
//!   concurrent processes share one cache root. Off by default; enabled
//!   with `--cache-dir` / `REPRO_CACHE_DIR`, pre-populated with
//!   `repro warm`, inspected with `repro cache`. A warm process compiles
//!   zero stencils through the dsl→analysis→opt pipeline (the
//!   `pipeline_compiles` counter in `repro run --json` proves it);
//! * **Runtime** ([`runtime`]) — PJRT client / executable management plus
//!   the [`runtime::pjrt_available`] probe backing structured
//!   backend-unavailable errors;
//! * **Model** ([`model`]) — an "isentropic-like" advection–diffusion model
//!   (the paper's Tasmania analog) composed from framework stencils;
//! * **Serve** ([`serve`]) — `repro serve`, stencils as a long-running
//!   service: a std-net TCP daemon speaking newline-delimited JSON, with
//!   per-tenant stencil libraries (coordinator caches + lease tables of
//!   [`BoundInvocation`]s), admission under a global
//!   [`backend::shard::CoreBudget`] that composes outer request
//!   concurrency with each run's inner [`Sharding`] fan-out, structured
//!   429/408 load shedding, same-fingerprint small-domain run coalescing,
//!   and a Prometheus-style `/metrics` snapshot. Execution options travel
//!   the wire as the same [`ExecOptions`] surface the in-process API
//!   uses; results cross as bit-exact digests.
//!
//! Storage is dtype-generic (f64 and f32) end to end: the sealed
//! [`storage::element::Element`] trait monomorphizes every hot path per
//! dtype, `ExecOptions::with_dtype` retypes a whole program (salting its
//! fingerprint, so precisions never share cached artifacts), and each
//! dtype is bitwise-reproducible against its own debug interpreter.
//!
//! A prose tour of the layering, the [`ExecOptions`] knob taxonomy
//! (pure scheduling knobs vs fingerprint-salted artifact knobs), the
//! bitwise-equivalence invariants, and the persist/serve subsystems
//! lives in [`docs/ARCHITECTURE.md`](../../../docs/ARCHITECTURE.md) at
//! the repository root.

pub mod analysis;
pub mod backend;
pub mod baseline;
pub mod cache;
pub mod coordinator;
pub mod dsl;
pub mod ir;
pub mod jsonw;
pub mod model;
pub mod opt;
pub mod persist;
pub mod runtime;
pub mod serve;
pub mod stdlib;
pub mod storage;

pub use backend::kernels::ExecTier;
pub use backend::shard::Sharding;
pub use coordinator::{BoundInvocation, Coordinator, Stencil};
pub use dsl::span::{CResult, CompileError};
pub use ir::implir::StencilIr;
pub use opt::{ExecOptions, OptConfig, OptLevel, PassManager};
