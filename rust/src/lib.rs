//! # gt4rs — GT4Py reproduced as a Rust + JAX/Pallas stencil framework
//!
//! A reproduction of *"GT4Py: High Performance Stencils for Weather and
//! Climate Applications using Python"* (Paredes et al., CSCS/ETH, 2023) as
//! a three-layer Rust + JAX + Pallas system:
//!
//! * **Frontend** ([`dsl`]) — GTScript-RS: a textual DSL plus a builder API
//!   producing the definition IR;
//! * **Analysis** ([`analysis`]) — inlining, name resolution, external
//!   folding, control-flow lowering, semantic checks, and halo/extent
//!   analysis, producing the implementation IR ([`ir`]);
//! * **Backends** ([`backend`]) — `debug` (scalar interpreter), `vector`
//!   (plane-vectorized evaluator), `xla` (XlaBuilder codegen JIT-compiled on
//!   PJRT), and `pjrt-aot` (prebuilt JAX/Pallas HLO artifacts);
//! * **Storage** ([`storage`]) — NumPy-like 3-D containers with
//!   backend-specific layout, alignment and halo padding;
//! * **Coordinator** ([`coordinator`]) — stencil registry, run-time storage
//!   checks, dispatch, metrics;
//! * **Cache** ([`cache`]) — fingerprint-based compilation caching;
//! * **Runtime** ([`runtime`]) — PJRT client / executable management;
//! * **Model** ([`model`]) — an "isentropic-like" advection–diffusion model
//!   (the paper's Tasmania analog) composed from framework stencils.

pub mod analysis;
pub mod backend;
pub mod baseline;
pub mod cache;
pub mod coordinator;
pub mod dsl;
pub mod ir;
pub mod model;
pub mod runtime;
pub mod stdlib;
pub mod storage;

pub use dsl::span::{CResult, CompileError};
pub use ir::implir::StencilIr;
