//! The `pjrt-aot` backend: prebuilt JAX/Pallas HLO artifacts.
//!
//! The analog of GT4Py's `gtcuda` backend: the highest-performance tier is
//! generated outside the Rust process — here by the L2 JAX model and L1
//! Pallas kernels in `python/compile/`, lowered once by `make artifacts` to
//! HLO text under `artifacts/` — and only *loaded and executed* on the hot
//! path, Python-free.
//!
//! Calling convention (shared with `python/compile/aot.py`):
//! * one f64 input per field parameter, shaped to the field's *box*
//!   (compute domain + required extent, same geometry as the `xla`
//!   backend);
//! * one rank-0 f64 input per scalar parameter;
//! * output: a tuple with one (ni, nj, nk) array per written field, in
//!   declaration order.
//!
//! Artifacts are named `<stencil>[__<variant>]_<ni>x<nj>x<nk>.hlo.txt`.
//! Because XLA programs are shape-specialized, one artifact exists per
//! domain size used by the benchmarks/examples; the run-time cache below
//! mirrors GT4Py's compiled-stencil cache.

use super::{Backend, StencilArgs};
use crate::ir::implir::{Intent, StencilIr};
use crate::runtime::{Arg, Executable, Runtime};
use anyhow::{anyhow, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

/// Environment variable overriding the artifact directory.
pub const ARTIFACTS_ENV: &str = "GT4RS_ARTIFACTS";

fn default_artifacts_dir() -> PathBuf {
    if let Ok(dir) = std::env::var(ARTIFACTS_ENV) {
        return PathBuf::from(dir);
    }
    // Walk up from the current dir to find an `artifacts/` directory so
    // tests/examples work from any workspace subdirectory.
    let mut cur = std::env::current_dir().unwrap_or_else(|_| PathBuf::from("."));
    loop {
        let cand = cur.join("artifacts");
        if cand.is_dir() {
            return cand;
        }
        if !cur.pop() {
            return PathBuf::from("artifacts");
        }
    }
}

/// The artifact directory and variant are fixed at construction; all
/// mutable state (PJRT runtime, executable cache, staging buffers) lives
/// behind one `Mutex`, so calls through a shared instance serialize on
/// the client.
pub struct PjrtAotBackend {
    dir: PathBuf,
    /// Optional variant suffix (e.g. "pallas" vs "jnp" lowering).
    pub variant: Option<String>,
    inner: Mutex<AotInner>,
}

// SAFETY: the backend's own state (cache, staging) is serialized behind
// `self.inner.lock()`, and every PJRT FFI call additionally funnels
// through the process-wide `runtime::pjrt_lock`, so instances sharing
// one `Runtime` clone can never touch the client concurrently. See the
// full argument on `xlagen::XlaBackend`.
unsafe impl Send for PjrtAotBackend {}
unsafe impl Sync for PjrtAotBackend {}

struct AotInner {
    runtime: Runtime,
    /// `(artifact key, domain)` → executable.
    cache: HashMap<(String, [usize; 3]), Arc<Executable>>,
    /// Reused host staging buffers (see EXPERIMENTS.md §Perf).
    staging: Vec<Vec<f64>>,
    /// Optional persist store (see [`crate::persist`]): HLO text is
    /// load-or-compiled through it, so a warmed cache serves artifacts
    /// even when the `artifacts/` directory is absent.
    persist: Option<Arc<crate::persist::PersistStore>>,
}

impl PjrtAotBackend {
    pub fn new() -> Result<PjrtAotBackend> {
        Ok(PjrtAotBackend::with_runtime(Runtime::cpu()?))
    }

    pub fn with_runtime(runtime: Runtime) -> PjrtAotBackend {
        PjrtAotBackend {
            dir: default_artifacts_dir(),
            variant: None,
            inner: Mutex::new(AotInner {
                runtime,
                cache: HashMap::new(),
                staging: Vec::new(),
                persist: None,
            }),
        }
    }

    /// Select a lowering variant (artifact suffix), e.g. `pallas`.
    pub fn with_variant(mut self, variant: &str) -> Self {
        self.variant = Some(variant.to_string());
        self
    }

    pub fn artifacts_dir(&self) -> &PathBuf {
        &self.dir
    }

    /// Path of the artifact for a stencil + domain.
    pub fn artifact_path(&self, stencil: &str, domain: [usize; 3]) -> PathBuf {
        let stem = match &self.variant {
            Some(v) => format!("{stencil}__{v}"),
            None => stencil.to_string(),
        };
        self.dir
            .join(format!("{stem}_{}x{}x{}.hlo.txt", domain[0], domain[1], domain[2]))
    }

    /// Whether an artifact exists for this stencil + domain.
    pub fn available(&self, stencil: &str, domain: [usize; 3]) -> bool {
        self.artifact_path(stencil, domain).is_file()
    }
}

impl AotInner {
    // Executables are Arc'd for cheap cache hand-out; they never leave
    // the mutex (see the Send/Sync safety notes above).
    #[allow(clippy::arc_with_non_send_sync)]
    fn executable(
        &mut self,
        stencil: &str,
        domain: [usize; 3],
        path: &Path,
    ) -> Result<Arc<Executable>> {
        let key = (stencil.to_string(), domain);
        if let Some(e) = self.cache.get(&key) {
            return Ok(e.clone());
        }
        // Persist key: the artifact file stem (stencil, variant, domain —
        // everything that shape-specializes the program).
        let pkey = path
            .file_name()
            .and_then(|n| n.to_str())
            .map(|n| n.trim_end_matches(".hlo.txt").to_string());
        if let (Some(store), Some(pkey)) = (self.persist.clone(), &pkey) {
            if let Some(payload) = store.load("hlo", pkey) {
                // `load_hlo_text` wants a file: stage the payload next to
                // the store (same filesystem, private name) and clean up.
                let tmp = store.root().join(format!(
                    ".stage_{pkey}.{}.hlo.txt",
                    std::process::id()
                ));
                let loaded = std::fs::write(&tmp, &payload)
                    .ok()
                    .and_then(|()| self.runtime.load_hlo_text(&tmp).ok());
                let _ = std::fs::remove_file(&tmp);
                match loaded {
                    Some(exe) => {
                        let exe = Arc::new(exe);
                        self.cache.insert(key, exe.clone());
                        return Ok(exe);
                    }
                    // Digest-valid but not loadable HLO: demote the hit.
                    None => store.reject_loaded(),
                }
            }
        }
        let exe = Arc::new(self.runtime.load_hlo_text(path).with_context(|| {
            format!(
                "no AOT artifact for stencil `{stencil}` at domain {domain:?} — run `make artifacts` (looked at {})",
                path.display()
            )
        })?);
        if let (Some(store), Some(pkey)) = (&self.persist, &pkey) {
            if let Ok(text) = std::fs::read_to_string(path) {
                let _ = store.store("hlo", pkey, &text);
            }
        }
        self.cache.insert(key, exe.clone());
        Ok(exe)
    }

    fn run(&mut self, ir: &StencilIr, args: &mut StencilArgs, path: &Path) -> Result<()> {
        // The AOT calling convention is f64-only (f64 staging buffers,
        // `run_f64` transfers); a non-f64 program is a structured error,
        // never a silent widening.
        if ir.dtype() != crate::dsl::ast::DType::F64 {
            anyhow::bail!(
                "backend `pjrt-aot` supports f64 programs only; `{}` is {} \
                 (use the debug/vector backends for f32)",
                ir.name,
                ir.dtype()
            );
        }
        let domain = args.domain;
        let exe = self.executable(&ir.name, domain, path)?;

        // Stage inputs with exactly the xla-backend geometry; staging
        // buffers are reused across calls.
        self.staging.resize_with(ir.fields.len(), Vec::new);
        let mut dims_list: Vec<Vec<usize>> = Vec::with_capacity(ir.fields.len());
        for (buf, f) in self.staging.iter_mut().zip(&ir.fields) {
            let e = f.extent;
            let lo = [e.i.0 as i64, e.j.0 as i64, e.k.0 as i64];
            let dims = [
                (domain[0] as i64 + (e.i.1 - e.i.0) as i64) as usize,
                (domain[1] as i64 + (e.j.1 - e.j.0) as i64) as usize,
                (domain[2] as i64 + (e.k.1 - e.k.0) as i64) as usize,
            ];
            let (_, storage) = args
                .fields
                .iter()
                .find(|(n, _)| *n == f.name)
                .ok_or_else(|| anyhow!("missing field argument `{}`", f.name))?;
            storage.box_write_c_order(lo, dims, buf);
            dims_list.push(dims.to_vec());
        }
        let mut xargs: Vec<Arg> = self
            .staging
            .iter()
            .zip(&dims_list)
            .map(|(d, dims)| Arg::F64(d, dims.clone()))
            .collect();
        for s in &ir.scalars {
            let v = args
                .scalars
                .iter()
                .find(|(n, _)| *n == s.name)
                .map(|(_, v)| *v)
                .ok_or_else(|| anyhow!("missing scalar argument `{}`", s.name))?;
            xargs.push(Arg::Scalar(v));
        }

        let outputs = exe.run_f64(&xargs)?;
        let expected: usize =
            ir.fields.iter().filter(|f| f.intent != Intent::In).count();
        if outputs.len() != expected {
            anyhow::bail!(
                "artifact for `{}` returned {} outputs, stencil writes {} fields",
                ir.name,
                outputs.len(),
                expected
            );
        }
        let mut oi = 0;
        for f in &ir.fields {
            if f.intent == Intent::In {
                continue;
            }
            let (_, storage) = args
                .fields
                .iter_mut()
                .find(|(n, _)| *n == f.name)
                .ok_or_else(|| anyhow!("missing field argument `{}`", f.name))?;
            storage.domain_from_c_order(&outputs[oi]);
            oi += 1;
        }
        Ok(())
    }
}

impl Backend for PjrtAotBackend {
    fn name(&self) -> &'static str {
        "pjrt-aot"
    }

    fn set_persist(&self, store: &Arc<crate::persist::PersistStore>) {
        self.inner.lock().unwrap().persist = Some(store.clone());
    }

    fn run(&self, ir: &StencilIr, args: &mut StencilArgs) -> Result<()> {
        let path = self.artifact_path(&ir.name, args.domain);
        self.inner.lock().unwrap().run(ir, args, &path)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn artifact_paths() {
        if crate::runtime::skip_test_without_pjrt("artifact_paths") {
            return;
        }
        let be = PjrtAotBackend::new().unwrap();
        let p = be.artifact_path("hdiff", [64, 64, 16]);
        assert!(p.to_string_lossy().ends_with("hdiff_64x64x16.hlo.txt"));
        let bev = PjrtAotBackend::new().unwrap().with_variant("pallas");
        let pv = bev.artifact_path("hdiff", [8, 8, 4]);
        assert!(pv.to_string_lossy().ends_with("hdiff__pallas_8x8x4.hlo.txt"));
    }

    #[test]
    fn missing_artifact_reports_make_hint() {
        if crate::runtime::skip_test_without_pjrt("missing_artifact_reports_make_hint") {
            return;
        }
        let ir = crate::analysis::compile_source(
            "stencil ghost_stencil(a: Field<f64>, b: Field<f64>) {\n\
               with computation(PARALLEL), interval(...) { b = a; }\n\
             }",
            "ghost_stencil",
            &std::collections::BTreeMap::new(),
        )
        .unwrap();
        let be = PjrtAotBackend::new().unwrap();
        let mut a = crate::storage::Storage::with_halo([2, 2, 2], 0);
        let mut b = crate::storage::Storage::with_halo([2, 2, 2], 0);
        let mut refs: Vec<(&str, &mut crate::storage::Storage)> =
            vec![("a", &mut a), ("b", &mut b)];
        let err = be
            .run(&ir, &mut StencilArgs { fields: &mut refs, scalars: &[], domain: [2, 2, 2] })
            .unwrap_err();
        assert!(format!("{err:#}").contains("make artifacts"));
    }
}
