//! Compiled expressions: the backend-internal form of stage right-hand
//! sides. Field names are pre-resolved to dense slot indices and scalars to
//! positions so the interpreting backends pay no hashing on the hot path.
//! Booleans are represented as 1.0 / 0.0 (selects compare against 0.5).

use crate::dsl::ast::{BinOp, Builtin, Expr, Offset, UnOp};
use crate::ir::implir::{Extent, StorageClass};
use crate::storage::Element;
use anyhow::{bail, Result};
use std::collections::{HashMap, HashSet};

/// A compiled point-wise expression.
#[derive(Debug, Clone)]
pub enum CExpr {
    Const(f64),
    Scalar(usize),
    Field { slot: usize, off: Offset },
    Neg(Box<CExpr>),
    Not(Box<CExpr>),
    Bin(BinOp, Box<CExpr>, Box<CExpr>),
    Select(Box<CExpr>, Box<CExpr>, Box<CExpr>),
    Call1(Builtin, Box<CExpr>),
    Call2(Builtin, Box<CExpr>, Box<CExpr>),
}

impl CExpr {
    /// Compile a resolved AST expression against slot/scalar tables.
    pub fn compile(
        e: &Expr,
        slots: &HashMap<String, usize>,
        scalars: &HashMap<String, usize>,
    ) -> Result<CExpr> {
        Ok(match e {
            Expr::Float(v) => CExpr::Const(*v),
            Expr::Bool(b) => CExpr::Const(if *b { 1.0 } else { 0.0 }),
            Expr::Field { name, offset, .. } => {
                let slot = *slots
                    .get(name)
                    .ok_or_else(|| anyhow::anyhow!("unbound field `{name}`"))?;
                CExpr::Field { slot, off: *offset }
            }
            Expr::Scalar(name) => {
                let idx = *scalars
                    .get(name)
                    .ok_or_else(|| anyhow::anyhow!("unbound scalar `{name}`"))?;
                CExpr::Scalar(idx)
            }
            Expr::Unary { op, operand } => {
                let c = Box::new(CExpr::compile(operand, slots, scalars)?);
                match op {
                    UnOp::Neg => CExpr::Neg(c),
                    UnOp::Not => CExpr::Not(c),
                }
            }
            Expr::Binary { op, lhs, rhs } => CExpr::Bin(
                *op,
                Box::new(CExpr::compile(lhs, slots, scalars)?),
                Box::new(CExpr::compile(rhs, slots, scalars)?),
            ),
            Expr::Ternary { cond, then_e, else_e } => CExpr::Select(
                Box::new(CExpr::compile(cond, slots, scalars)?),
                Box::new(CExpr::compile(then_e, slots, scalars)?),
                Box::new(CExpr::compile(else_e, slots, scalars)?),
            ),
            Expr::Builtin { func, args } => {
                if args.len() == 1 {
                    CExpr::Call1(*func, Box::new(CExpr::compile(&args[0], slots, scalars)?))
                } else {
                    CExpr::Call2(
                        *func,
                        Box::new(CExpr::compile(&args[0], slots, scalars)?),
                        Box::new(CExpr::compile(&args[1], slots, scalars)?),
                    )
                }
            }
            Expr::Name(n, _) | Expr::External(n, _) => {
                bail!("unresolved symbol `{n}` reached a backend (analysis bug)")
            }
            Expr::Call { name, .. } => {
                bail!("unresolved call `{name}` reached a backend (analysis bug)")
            }
        })
    }
}

impl CExpr {
    /// Visit every field access `(slot, offset)` in this expression.
    pub fn visit_reads(&self, f: &mut impl FnMut(usize, Offset)) {
        match self {
            CExpr::Const(_) | CExpr::Scalar(_) => {}
            CExpr::Field { slot, off } => f(*slot, *off),
            CExpr::Neg(a) | CExpr::Not(a) | CExpr::Call1(_, a) => a.visit_reads(f),
            CExpr::Bin(_, a, b) | CExpr::Call2(_, a, b) => {
                a.visit_reads(f);
                b.visit_reads(f);
            }
            CExpr::Select(c, t, e) => {
                c.visit_reads(f);
                t.visit_reads(f);
                e.visit_reads(f);
            }
        }
    }
}

// ---------------------------------------------------------------------------
// CTape: the flat SSA form of one fused stage-group tier.
//
// A tape is a topologically ordered list of instructions over *value slots*
// (an instruction's id is its index; operands always refer to earlier
// instructions). The fused evaluator (`crate::backend::fused`) materializes
// one short strip of values per instruction and sweeps the tape point by
// point — no per-expression-node region buffers. Building the tape
// value-numbers every instruction, so identical subtrees are computed once
// even when they originate in *different stages* of the group: the
// within-stage CSE of `crate::opt::foldcse` extends across stages here.
// ---------------------------------------------------------------------------

/// One tape instruction with its evaluation region.
///
/// `region` is the union of the compute extents of every stage that
/// (transitively) consumes the value: the instruction only runs where some
/// consumer needs it, and — because regions are widened bottom-up — every
/// operand's region contains it, so memory accesses stay inside the halos
/// the extent analysis guaranteed.
///
/// Under intra-call domain sharding the same containment argument holds
/// per i-slab: compute ops resolve to the slab's extent-*expanded* range
/// `[a + i.0, b + i.1)` (recomputing the halo overlap into slab-local
/// buffers), while [`TapeOp::StoreField`] resolves to the slab's *owned*
/// partition — see `shard::owned_store_range` and
/// `fused::resolve_bounds`. The region also feeds the fused halo-plan
/// analysis: a `Load` of a field stored in the same multistage is
/// sync-free only when column-local (zero i-offset *and* zero region
/// i-extent); wider reads pick the cheapest sufficient rendezvous
/// schedule (`fused::ms_halo_plan_fused`).
#[derive(Debug, Clone)]
pub struct TapeInst {
    pub op: TapeOp,
    pub region: Extent,
}

/// Tape operations. `u32` operands are instruction indices.
#[derive(Debug, Clone)]
pub enum TapeOp {
    Const(f64),
    Scalar(usize),
    /// Read an undemoted storage slot at a relative offset.
    Load { slot: usize, off: Offset },
    /// Read a demoted local: register/plane locals from the group scratch
    /// buffer, ring locals from the level-plane ring.
    LoadLocal { slot: usize, off: Offset },
    Neg(u32),
    Not(u32),
    Bin(BinOp, u32, u32),
    Select(u32, u32, u32),
    Call1(Builtin, u32),
    Call2(Builtin, u32, u32),
    /// Write value `v` into an undemoted storage slot (stage extent
    /// region serially; clamped to the slab's owned i-columns under
    /// sharding so two slabs never store the same element).
    StoreField { slot: usize, v: u32 },
    /// Write value `v` into a demoted local's scratch buffer or ring
    /// plane (always slab-local under sharding — never clamped, the halo
    /// overlap is recomputed instead).
    StoreLocal { slot: usize, v: u32 },
}

impl TapeOp {
    /// Value operands of this op (region widening, invariant checks).
    pub(crate) fn operands(&self) -> [Option<u32>; 3] {
        match self {
            TapeOp::Const(_)
            | TapeOp::Scalar(_)
            | TapeOp::Load { .. }
            | TapeOp::LoadLocal { .. } => [None, None, None],
            TapeOp::Neg(a) | TapeOp::Not(a) | TapeOp::Call1(_, a) => {
                [Some(*a), None, None]
            }
            TapeOp::Bin(_, a, b) | TapeOp::Call2(_, a, b) => [Some(*a), Some(*b), None],
            TapeOp::Select(c, t, f) => [Some(*c), Some(*t), Some(*f)],
            TapeOp::StoreField { v, .. } | TapeOp::StoreLocal { v, .. } => {
                [Some(*v), None, None]
            }
        }
    }
}

/// A compiled tier: the fused evaluator runs the whole instruction list at
/// every point of the tier's loop nest.
#[derive(Debug, Clone)]
pub struct CTape {
    pub ops: Vec<TapeInst>,
}

/// Value-numbering key: float identity by bits, loads versioned by the
/// number of preceding stores to the same slot (a store invalidates sharing
/// across it).
#[derive(Hash, PartialEq, Eq)]
enum OpKey {
    Const(u64),
    Scalar(usize),
    Load(usize, [i32; 3], u32),
    LoadLocal(usize, [i32; 3], u32),
    Neg(u32),
    Not(u32),
    Bin(u8, u32, u32),
    Select(u32, u32, u32),
    Call1(u8, u32),
    Call2(u8, u32, u32),
}

/// Immutable context for tape construction.
pub struct TapeCtx<'a> {
    /// Per-slot storage class (`program.slots[i].storage`).
    pub classes: &'a [StorageClass],
    /// Register/plane locals backed by a group scratch buffer (offset reads
    /// or cross-tier flow); everything else demoted lives in SSA values.
    pub scratch: &'a [bool],
    /// Demoted locals already stored by an earlier tier of this group
    /// (zero-offset reads of them must hit the scratch buffer, not fold to
    /// the unwritten-reads-as-zero constant).
    pub written: &'a HashSet<usize>,
}

/// Builds one tier's tape, one stage at a time, with cross-stage value
/// numbering.
#[derive(Default)]
pub struct TapeBuilder {
    ops: Vec<TapeInst>,
    cse: HashMap<OpKey, u32>,
    /// Demoted local -> SSA value of its latest in-tier definition.
    local_def: HashMap<usize, u32>,
    /// Store count per slot, versioning load keys.
    version: HashMap<usize, u32>,
}

impl TapeBuilder {
    pub fn new() -> TapeBuilder {
        TapeBuilder::default()
    }

    /// Append one stage: value-number its expression, then its store.
    pub fn push_stage(&mut self, expr: &CExpr, extent: Extent, target: usize, ctx: &TapeCtx) {
        let v = self.emit_expr(expr, extent, ctx);
        if ctx.classes[target] == StorageClass::Field3D {
            self.ops.push(TapeInst { op: TapeOp::StoreField { slot: target, v }, region: extent });
        } else {
            self.local_def.insert(target, v);
            if ctx.classes[target] == StorageClass::Ring || ctx.scratch[target] {
                self.ops
                    .push(TapeInst { op: TapeOp::StoreLocal { slot: target, v }, region: extent });
            }
        }
        *self.version.entry(target).or_insert(0) += 1;
    }

    pub fn finish(self) -> CTape {
        CTape { ops: self.ops }
    }

    fn emit_expr(&mut self, e: &CExpr, ext: Extent, ctx: &TapeCtx) -> u32 {
        match e {
            CExpr::Const(v) => self.emit(OpKey::Const(v.to_bits()), TapeOp::Const(*v), ext),
            CExpr::Scalar(ix) => self.emit(OpKey::Scalar(*ix), TapeOp::Scalar(*ix), ext),
            CExpr::Field { slot, off } => self.emit_read(*slot, *off, ext, ctx),
            CExpr::Neg(a) => {
                let ra = self.emit_expr(a, ext, ctx);
                self.emit(OpKey::Neg(ra), TapeOp::Neg(ra), ext)
            }
            CExpr::Not(a) => {
                let ra = self.emit_expr(a, ext, ctx);
                self.emit(OpKey::Not(ra), TapeOp::Not(ra), ext)
            }
            CExpr::Bin(op, a, b) => {
                let ra = self.emit_expr(a, ext, ctx);
                let rb = self.emit_expr(b, ext, ctx);
                self.emit(OpKey::Bin(*op as u8, ra, rb), TapeOp::Bin(*op, ra, rb), ext)
            }
            CExpr::Select(c, t, f) => {
                let rc = self.emit_expr(c, ext, ctx);
                let rt = self.emit_expr(t, ext, ctx);
                let rf = self.emit_expr(f, ext, ctx);
                self.emit(OpKey::Select(rc, rt, rf), TapeOp::Select(rc, rt, rf), ext)
            }
            CExpr::Call1(f, a) => {
                let ra = self.emit_expr(a, ext, ctx);
                self.emit(OpKey::Call1(*f as u8, ra), TapeOp::Call1(*f, ra), ext)
            }
            CExpr::Call2(f, a, b) => {
                let ra = self.emit_expr(a, ext, ctx);
                let rb = self.emit_expr(b, ext, ctx);
                self.emit(OpKey::Call2(*f as u8, ra, rb), TapeOp::Call2(*f, ra, rb), ext)
            }
        }
    }

    fn emit_read(&mut self, slot: usize, off: Offset, ext: Extent, ctx: &TapeCtx) -> u32 {
        let ver = self.version.get(&slot).copied().unwrap_or(0);
        if ctx.classes[slot] == StorageClass::Field3D {
            // Undemoted: always a real memory load. Zero-offset loads after
            // an in-tier store read the just-written value at the same
            // point, which is exactly the reference semantics.
            return self.emit(
                OpKey::Load(slot, off, ver),
                TapeOp::Load { slot, off },
                ext,
            );
        }
        if off == [0, 0, 0] {
            if let Some(&v) = self.local_def.get(&slot) {
                // Same-tier SSA reuse; fusion guaranteed containment.
                self.widen(v, ext);
                return v;
            }
            if ctx.classes[slot] != StorageClass::Ring && !ctx.written.contains(&slot) {
                // Demoted local read before any write in the group: zeros,
                // like the zero-initialized field it replaces. (Ring locals
                // may carry state from earlier groups of the multistage, so
                // they always go through the ring lookup.)
                return self.emit(OpKey::Const(0f64.to_bits()), TapeOp::Const(0.0), ext);
            }
        }
        self.emit(
            OpKey::LoadLocal(slot, off, ver),
            TapeOp::LoadLocal { slot, off },
            ext,
        )
    }

    fn emit(&mut self, key: OpKey, op: TapeOp, ext: Extent) -> u32 {
        if let Some(&v) = self.cse.get(&key) {
            self.widen(v, ext);
            return v;
        }
        let id = self.ops.len() as u32;
        self.ops.push(TapeInst { op, region: ext });
        self.cse.insert(key, id);
        id
    }

    /// Grow an instruction's region to cover a new consumer, propagating to
    /// its operands so inputs are always computed wherever outputs are.
    fn widen(&mut self, v: u32, ext: Extent) {
        let cur = self.ops[v as usize].region;
        if ext.within(&cur) {
            return;
        }
        let merged = cur.union(ext);
        self.ops[v as usize].region = merged;
        for opnd in self.ops[v as usize].op.operands().into_iter().flatten() {
            self.widen(opnd, merged);
        }
    }
}

/// Apply a binary operator to scalar values (booleans as `ONE`/`ZERO`).
/// Generic over the element dtype — monomorphized per backend, all
/// arithmetic at `T`'s native precision.
#[inline(always)]
pub fn apply_bin<T: Element>(op: BinOp, a: T, b: T) -> T {
    match op {
        BinOp::Add => a + b,
        BinOp::Sub => a - b,
        BinOp::Mul => a * b,
        BinOp::Div => a / b,
        // Truncated remainder, matching XLA's `rem` so all backends agree.
        BinOp::Mod => a % b,
        BinOp::Lt => T::from_bool(a < b),
        BinOp::Le => T::from_bool(a <= b),
        BinOp::Gt => T::from_bool(a > b),
        BinOp::Ge => T::from_bool(a >= b),
        BinOp::Eq => T::from_bool(a == b),
        BinOp::Ne => T::from_bool(a != b),
        BinOp::And => T::from_bool(a.truthy() && b.truthy()),
        BinOp::Or => T::from_bool(a.truthy() || b.truthy()),
    }
}

/// Apply a unary builtin at `T`'s native precision.
#[inline(always)]
pub fn apply_builtin1<T: Element>(f: Builtin, a: T) -> T {
    match f {
        Builtin::Abs => a.abs(),
        Builtin::Sqrt => a.sqrt(),
        Builtin::Exp => a.exp(),
        Builtin::Log => a.ln(),
        Builtin::Floor => a.floor(),
        Builtin::Ceil => a.ceil(),
        Builtin::Sin => a.sin(),
        Builtin::Cos => a.cos(),
        Builtin::Tanh => a.tanh(),
        _ => unreachable!("binary builtin used as unary"),
    }
}

/// Apply a binary builtin at `T`'s native precision.
#[inline(always)]
pub fn apply_builtin2<T: Element>(f: Builtin, a: T, b: T) -> T {
    match f {
        Builtin::Min => a.min(b),
        Builtin::Max => a.max(b),
        Builtin::Pow => a.powf(b),
        _ => unreachable!("unary builtin used as binary"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsl::parser::parse_expr;

    #[test]
    fn compiles_resolved_expression() {
        // Build a resolved expr by hand: a[1,0,0] * s + 2.0
        let e = Expr::binary(
            BinOp::Add,
            Expr::binary(
                BinOp::Mul,
                Expr::field("a", [1, 0, 0]),
                Expr::Scalar("s".into()),
            ),
            Expr::Float(2.0),
        );
        let mut slots = HashMap::new();
        slots.insert("a".to_string(), 0);
        let mut scalars = HashMap::new();
        scalars.insert("s".to_string(), 0);
        let c = CExpr::compile(&e, &slots, &scalars).unwrap();
        assert!(matches!(c, CExpr::Bin(BinOp::Add, _, _)));
    }

    #[test]
    fn unresolved_name_rejected() {
        let e = parse_expr("ghost + 1.0").unwrap();
        let r = CExpr::compile(&e, &HashMap::new(), &HashMap::new());
        assert!(r.is_err());
    }

    #[test]
    fn apply_bin_semantics() {
        assert_eq!(apply_bin(BinOp::Add, 2.0, 3.0), 5.0);
        assert_eq!(apply_bin(BinOp::Lt, 1.0, 2.0), 1.0);
        assert_eq!(apply_bin(BinOp::Lt, 2.0, 1.0), 0.0);
        assert_eq!(apply_bin(BinOp::And, 1.0, 0.0), 0.0);
        assert_eq!(apply_bin(BinOp::Or, 1.0, 0.0), 1.0);
        assert_eq!(apply_bin(BinOp::Mod, 7.0, 3.0), 1.0);
    }

    #[test]
    fn builtins_semantics() {
        assert_eq!(apply_builtin1(Builtin::Abs, -2.0), 2.0);
        assert_eq!(apply_builtin1(Builtin::Sqrt, 9.0), 3.0);
        assert_eq!(apply_builtin2(Builtin::Min, 1.0, 2.0), 1.0);
        assert_eq!(apply_builtin2(Builtin::Max, 1.0, 2.0), 2.0);
        assert_eq!(apply_builtin2(Builtin::Pow, 2.0, 10.0), 1024.0);
    }
}
