//! Compiled expressions: the backend-internal form of stage right-hand
//! sides. Field names are pre-resolved to dense slot indices and scalars to
//! positions so the interpreting backends pay no hashing on the hot path.
//! Booleans are represented as 1.0 / 0.0 (selects compare against 0.5).

use crate::dsl::ast::{BinOp, Builtin, Expr, Offset, UnOp};
use anyhow::{bail, Result};
use std::collections::HashMap;

/// A compiled point-wise expression.
#[derive(Debug, Clone)]
pub enum CExpr {
    Const(f64),
    Scalar(usize),
    Field { slot: usize, off: Offset },
    Neg(Box<CExpr>),
    Not(Box<CExpr>),
    Bin(BinOp, Box<CExpr>, Box<CExpr>),
    Select(Box<CExpr>, Box<CExpr>, Box<CExpr>),
    Call1(Builtin, Box<CExpr>),
    Call2(Builtin, Box<CExpr>, Box<CExpr>),
}

impl CExpr {
    /// Compile a resolved AST expression against slot/scalar tables.
    pub fn compile(
        e: &Expr,
        slots: &HashMap<String, usize>,
        scalars: &HashMap<String, usize>,
    ) -> Result<CExpr> {
        Ok(match e {
            Expr::Float(v) => CExpr::Const(*v),
            Expr::Bool(b) => CExpr::Const(if *b { 1.0 } else { 0.0 }),
            Expr::Field { name, offset, .. } => {
                let slot = *slots
                    .get(name)
                    .ok_or_else(|| anyhow::anyhow!("unbound field `{name}`"))?;
                CExpr::Field { slot, off: *offset }
            }
            Expr::Scalar(name) => {
                let idx = *scalars
                    .get(name)
                    .ok_or_else(|| anyhow::anyhow!("unbound scalar `{name}`"))?;
                CExpr::Scalar(idx)
            }
            Expr::Unary { op, operand } => {
                let c = Box::new(CExpr::compile(operand, slots, scalars)?);
                match op {
                    UnOp::Neg => CExpr::Neg(c),
                    UnOp::Not => CExpr::Not(c),
                }
            }
            Expr::Binary { op, lhs, rhs } => CExpr::Bin(
                *op,
                Box::new(CExpr::compile(lhs, slots, scalars)?),
                Box::new(CExpr::compile(rhs, slots, scalars)?),
            ),
            Expr::Ternary { cond, then_e, else_e } => CExpr::Select(
                Box::new(CExpr::compile(cond, slots, scalars)?),
                Box::new(CExpr::compile(then_e, slots, scalars)?),
                Box::new(CExpr::compile(else_e, slots, scalars)?),
            ),
            Expr::Builtin { func, args } => {
                if args.len() == 1 {
                    CExpr::Call1(*func, Box::new(CExpr::compile(&args[0], slots, scalars)?))
                } else {
                    CExpr::Call2(
                        *func,
                        Box::new(CExpr::compile(&args[0], slots, scalars)?),
                        Box::new(CExpr::compile(&args[1], slots, scalars)?),
                    )
                }
            }
            Expr::Name(n, _) | Expr::External(n, _) => {
                bail!("unresolved symbol `{n}` reached a backend (analysis bug)")
            }
            Expr::Call { name, .. } => {
                bail!("unresolved call `{name}` reached a backend (analysis bug)")
            }
        })
    }
}

/// Apply a binary operator to scalar values (booleans as 0.0/1.0).
#[inline(always)]
pub fn apply_bin(op: BinOp, a: f64, b: f64) -> f64 {
    match op {
        BinOp::Add => a + b,
        BinOp::Sub => a - b,
        BinOp::Mul => a * b,
        BinOp::Div => a / b,
        // Truncated remainder, matching XLA's `rem` so all backends agree.
        BinOp::Mod => a % b,
        BinOp::Lt => ((a < b) as u8) as f64,
        BinOp::Le => ((a <= b) as u8) as f64,
        BinOp::Gt => ((a > b) as u8) as f64,
        BinOp::Ge => ((a >= b) as u8) as f64,
        BinOp::Eq => ((a == b) as u8) as f64,
        BinOp::Ne => ((a != b) as u8) as f64,
        BinOp::And => (((a != 0.0) && (b != 0.0)) as u8) as f64,
        BinOp::Or => (((a != 0.0) || (b != 0.0)) as u8) as f64,
    }
}

/// Apply a unary builtin.
#[inline(always)]
pub fn apply_builtin1(f: Builtin, a: f64) -> f64 {
    match f {
        Builtin::Abs => a.abs(),
        Builtin::Sqrt => a.sqrt(),
        Builtin::Exp => a.exp(),
        Builtin::Log => a.ln(),
        Builtin::Floor => a.floor(),
        Builtin::Ceil => a.ceil(),
        Builtin::Sin => a.sin(),
        Builtin::Cos => a.cos(),
        Builtin::Tanh => a.tanh(),
        _ => unreachable!("binary builtin used as unary"),
    }
}

/// Apply a binary builtin.
#[inline(always)]
pub fn apply_builtin2(f: Builtin, a: f64, b: f64) -> f64 {
    match f {
        Builtin::Min => a.min(b),
        Builtin::Max => a.max(b),
        Builtin::Pow => a.powf(b),
        _ => unreachable!("unary builtin used as binary"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsl::parser::parse_expr;

    #[test]
    fn compiles_resolved_expression() {
        // Build a resolved expr by hand: a[1,0,0] * s + 2.0
        let e = Expr::binary(
            BinOp::Add,
            Expr::binary(
                BinOp::Mul,
                Expr::field("a", [1, 0, 0]),
                Expr::Scalar("s".into()),
            ),
            Expr::Float(2.0),
        );
        let mut slots = HashMap::new();
        slots.insert("a".to_string(), 0);
        let mut scalars = HashMap::new();
        scalars.insert("s".to_string(), 0);
        let c = CExpr::compile(&e, &slots, &scalars).unwrap();
        assert!(matches!(c, CExpr::Bin(BinOp::Add, _, _)));
    }

    #[test]
    fn unresolved_name_rejected() {
        let e = parse_expr("ghost + 1.0").unwrap();
        let r = CExpr::compile(&e, &HashMap::new(), &HashMap::new());
        assert!(r.is_err());
    }

    #[test]
    fn apply_bin_semantics() {
        assert_eq!(apply_bin(BinOp::Add, 2.0, 3.0), 5.0);
        assert_eq!(apply_bin(BinOp::Lt, 1.0, 2.0), 1.0);
        assert_eq!(apply_bin(BinOp::Lt, 2.0, 1.0), 0.0);
        assert_eq!(apply_bin(BinOp::And, 1.0, 0.0), 0.0);
        assert_eq!(apply_bin(BinOp::Or, 1.0, 0.0), 1.0);
        assert_eq!(apply_bin(BinOp::Mod, 7.0, 3.0), 1.0);
    }

    #[test]
    fn builtins_semantics() {
        assert_eq!(apply_builtin1(Builtin::Abs, -2.0), 2.0);
        assert_eq!(apply_builtin1(Builtin::Sqrt, 9.0), 3.0);
        assert_eq!(apply_builtin2(Builtin::Min, 1.0, 2.0), 1.0);
        assert_eq!(apply_builtin2(Builtin::Max, 1.0, 2.0), 2.0);
        assert_eq!(apply_builtin2(Builtin::Pow, 2.0, 10.0), 1024.0);
    }
}
