//! Intra-call horizontal domain sharding: the schedule half of the
//! paper's multi-core CPU backends (`gt:cpu_kfirst`/`gt:cpu_ifirst`,
//! Fig. 3), kept strictly separate from the algorithm as in Devito and
//! Halide — a [`Sharding`] plan says *how* one invocation's compute
//! domain is split across threads, and nothing about *what* is computed.
//!
//! ## Execution model
//!
//! The compute domain `[0, ni)` is partitioned into contiguous,
//! halo-correct **i-slabs**, one per thread:
//!
//! * **Slabs are the parallel units.** Each slab evaluates demoted
//!   temporaries (register/plane scratch, ring k-caches) over its own
//!   extent-expanded i-range, recomputing the halo overlap instead of
//!   communicating — temporaries never cross a slab boundary.
//! * **Writes to real storages are owned.** `Field3D` stores are clamped
//!   to the slab's owned partition (edge slabs absorb the write halo), so
//!   two slabs never write the same element.
//! * **Tiers (and materializing stages) are globally ordered barriers.**
//!   Inside a `PARALLEL` multistage, every slab finishes loop-nest pass
//!   *t* before any slab starts pass *t+1*, which gives cross-slab
//!   readers of just-written fields a happens-before edge.
//! * **Vertical sweeps exchange halos per level.** A sequential
//!   (FORWARD/BACKWARD) multistage runs each slab's k-sweep with ring
//!   k-caches and demoted scratch kept slab-local. The [`HaloPlan`]
//!   analysis in the vector backend classifies the multistage's cross-slab
//!   field flow: column-local sweeps run with zero synchronization
//!   ([`HaloPlan::Local`]); sweeps whose horizontal field carries only
//!   cross k-levels rendezvous once per level ([`HaloPlan::PerLevel`]) —
//!   every slab's writes to level *k* are published before any slab reads
//!   neighbor columns at the next level; same-level cross-slab flow
//!   between stages/tiers adds a rendezvous after every executed stage
//!   ([`HaloPlan::PerStage`]). Only an irreducible in-pass wavefront (a
//!   stage both storing a field and reading it at a horizontal offset on
//!   the *same* level) still runs serially ([`HaloPlan::Serial`]).
//!
//! Every plan is bitwise-identical to [`Sharding::Off`]: values are
//! computed by the same floating-point expressions over the same inputs,
//! only the loop partitioning changes. `tests/property_equivalence.rs`
//! sweeps random programs across thread counts to enforce this, and the
//! hosted CI thread-matrix re-runs those suites on real multi-core
//! runners with `REPRO_THREADS` exported.

use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// How one stencil invocation's compute domain is split across threads.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Sharding {
    /// Single-threaded execution (the default): the bitwise reference.
    #[default]
    Off,
    /// Exactly `n` i-slabs on `n` threads, clamped to the domain's
    /// i-extent (a 3-column domain can host at most 3 one-column slabs).
    Threads(usize),
    /// One slab per available core, degraded toward `Off` whenever the
    /// domain is too narrow to give every slab at least
    /// [`MIN_AUTO_SLAB_WIDTH`] columns (tiny domains, CI smoke sizes).
    Auto,
}

/// Narrowest i-slab `Auto` considers profitable: below this the per-call
/// fork/join and halo-recompute overhead swamps the parallel win.
pub const MIN_AUTO_SLAB_WIDTH: usize = 16;

impl Sharding {
    /// Parse a CLI/env spelling: `off`, `auto`, or a thread count.
    pub fn parse(s: &str) -> Option<Sharding> {
        match s.trim().to_ascii_lowercase().as_str() {
            "off" | "0" => Some(Sharding::Off),
            "auto" => Some(Sharding::Auto),
            n => n.parse::<usize>().ok().map(Sharding::Threads),
        }
    }

    /// The plan named by the `REPRO_THREADS` environment variable (how
    /// the CI thread-matrix reaches the test suites); unset or
    /// unparsable means `Off`.
    pub fn from_env() -> Sharding {
        std::env::var("REPRO_THREADS")
            .ok()
            .and_then(|s| Sharding::parse(&s))
            .unwrap_or(Sharding::Off)
    }

    /// Effective thread count for a domain with i-extent `ni` (1 means
    /// serial execution). `Auto` degrades to serial when slabs would be
    /// narrower than [`MIN_AUTO_SLAB_WIDTH`]; explicit `Threads(n)` only
    /// clamps to the number of nonempty slabs.
    pub fn resolve(&self, ni: usize) -> usize {
        let want = match self {
            Sharding::Off => 1,
            Sharding::Threads(n) => (*n).max(1),
            Sharding::Auto => {
                let avail = std::thread::available_parallelism()
                    .map(|n| n.get())
                    .unwrap_or(1);
                avail.min(ni / MIN_AUTO_SLAB_WIDTH)
            }
        };
        want.min(ni.max(1)).max(1)
    }
}

impl std::fmt::Display for Sharding {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Sharding::Off => write!(f, "off"),
            Sharding::Threads(n) => write!(f, "{n}"),
            Sharding::Auto => write!(f, "auto"),
        }
    }
}

/// The synchronization schedule one sequential multistage needs to run
/// sharded, computed at compile time from stage read/write extents (the
/// vector backend's `ms_halo_plan` / the fused evaluator's
/// `ms_halo_plan_fused`). Variants are ordered by strictness, so an
/// analysis folds per-read requirements with [`HaloPlan::merge`].
///
/// Soundness argument (level/stage lockstep): between two consecutive
/// rendezvous every slab executes the same level (and, under `PerStage`,
/// the same stage/tier). Writes in that window touch only the current
/// level's owned columns, so a cross-slab read is safe iff it targets a
/// *different* level (`PerLevel`) or a slot written by an *earlier*,
/// already-published stage (`PerStage`). A stage reading its own
/// same-level store at a horizontal offset has no such window — that is
/// the irreducible `Serial` wavefront. j-offsets never cross i-slabs and
/// k-ranges are slab-independent, so rendezvous schedules are identical
/// on every slab (the [`WorkerPool::run_slabs`] barrier caveat).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum HaloPlan {
    /// No cross-slab field flow: slabs sweep with zero synchronization
    /// (`PARALLEL` multistages also report `Local`; their per-stage/tier
    /// barriers are part of the parallel execution model, not of this
    /// plan).
    Local,
    /// Horizontal field carries cross k-levels only (`off.k != 0`): one
    /// halo rendezvous after every k-level of the sweep.
    PerLevel,
    /// Some stage reads another stage's same-level store at a horizontal
    /// offset: rendezvous after every executed stage of every level (in
    /// the fused evaluator, after every tier), plus the per-level one.
    PerStage,
    /// A stage both stores a field and reads it at a horizontal offset on
    /// the same level (gather/scatter or strip-order wavefront): no
    /// level- or stage-granular schedule is sound — run serially.
    Serial,
}

impl HaloPlan {
    /// Fold two per-read requirements: the stricter plan wins.
    #[must_use]
    pub fn merge(self, other: HaloPlan) -> HaloPlan {
        self.max(other)
    }

    /// Whether the multistage can run sharded at all under this plan.
    pub fn sharded(self) -> bool {
        self != HaloPlan::Serial
    }

    /// Stable lowercase spelling (tape dumps, persisted tapes).
    pub fn as_str(self) -> &'static str {
        match self {
            HaloPlan::Local => "local",
            HaloPlan::PerLevel => "per-level",
            HaloPlan::PerStage => "per-stage",
            HaloPlan::Serial => "serial",
        }
    }
}

impl std::fmt::Display for HaloPlan {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

/// A reusable rendezvous for per-level halo exchange: `n` slab
/// participants meeting on the same mutex/condvar generation pattern as
/// the worker pool's job epochs. Semantically a `std::sync::Barrier`,
/// plus a crossing counter the reports surface — each full rendezvous is
/// one "halo exchange" in [`ShardReport::exchanges`] and the
/// `pool_halo_exchanges_total` metric.
pub struct HaloRendezvous {
    state: Mutex<GateState>,
    all: Condvar,
    n: usize,
    crossings: std::sync::atomic::AtomicU64,
}

struct GateState {
    arrived: usize,
    generation: u64,
}

impl HaloRendezvous {
    pub fn new(n: usize) -> HaloRendezvous {
        HaloRendezvous {
            state: Mutex::new(GateState { arrived: 0, generation: 0 }),
            all: Condvar::new(),
            n: n.max(1),
            crossings: std::sync::atomic::AtomicU64::new(0),
        }
    }

    /// Number of participants each rendezvous waits for.
    pub fn participants(&self) -> usize {
        self.n
    }

    /// Block until all `n` participants arrive. The last arriver opens
    /// the gate for everyone and bumps the crossing count; the gate then
    /// resets for the next level (generations make it safely reusable
    /// back-to-back, exactly like an epoch bump in [`WorkerPool`]).
    pub fn wait(&self) {
        let mut st = self.state.lock().unwrap();
        st.arrived += 1;
        if st.arrived == self.n {
            st.arrived = 0;
            st.generation = st.generation.wrapping_add(1);
            self.crossings
                .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            drop(st);
            self.all.notify_all();
        } else {
            let gen = st.generation;
            while st.generation == gen {
                st = self.all.wait(st).unwrap();
            }
        }
    }

    /// Completed rendezvous so far (the run's halo-exchange count).
    pub fn crossings(&self) -> u64 {
        self.crossings.load(std::sync::atomic::Ordering::Relaxed)
    }
}

/// What a sharded run actually did — surfaced through
/// [`crate::coordinator::RunStats`] so `--json` consumers see the
/// *effective* thread count, never the requested plan.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ShardReport {
    /// Largest thread count any parallel region of the run fanned out to
    /// (1 = the whole call ran serially, whatever the plan asked for).
    pub threads: u32,
    /// Number of i-slabs the domain was split into.
    pub slabs: u32,
    /// Shortest per-slab wall time inside parallel regions, summed over
    /// the run's regions. Note: this is *occupancy*, not pure compute —
    /// a slab stalled in a tier/stage barrier keeps accruing, so inside
    /// barriered `PARALLEL` groups the per-slab spread understates load
    /// imbalance (between-region skew still shows).
    pub busy_min: Duration,
    /// Longest per-slab wall time (the critical path of the fan-out);
    /// same occupancy caveat as [`ShardReport::busy_min`].
    pub busy_max: Duration,
    /// Total per-slab wall time across all slabs; same occupancy caveat
    /// as [`ShardReport::busy_min`].
    pub busy_total: Duration,
    /// Cross-slab halo rendezvous the run crossed (0 on the zero-sync
    /// paths). A nonzero count on a sequential-carry kernel is the proof
    /// the serial fallback did not run — `benches/scaling.rs` and the CI
    /// scaling-regression gate key off it.
    pub exchanges: u64,
}

impl ShardReport {
    /// The report of an unsharded run with no timing attached (trait
    /// defaults, backends that never shard).
    pub fn serial() -> ShardReport {
        ShardReport::serial_with(Duration::ZERO)
    }

    /// The report of an unsharded run that took `busy` on the calling
    /// thread — serial execution still reports honest busy time, so the
    /// scaling bench's occupancy columns mean the same thing whether or
    /// not a plan degraded.
    pub fn serial_with(busy: Duration) -> ShardReport {
        ShardReport {
            threads: 1,
            slabs: 1,
            busy_min: busy,
            busy_max: busy,
            busy_total: busy,
            exchanges: 0,
        }
    }
}

impl Default for ShardReport {
    fn default() -> Self {
        ShardReport::serial()
    }
}

/// Contiguous i-slabs partitioning `[0, ni)` as evenly as possible
/// (widths differ by at most one column); empty slabs never occur because
/// the count is clamped to `ni`.
pub fn split_slabs(ni: usize, threads: usize) -> Vec<(i64, i64)> {
    let t = threads.min(ni).max(1);
    (0..t)
        .map(|s| (((ni * s) / t) as i64, ((ni * (s + 1)) / t) as i64))
        .collect()
}

/// The i-range of `Field3D` *stores* owned by slab `(a, b)` for a write
/// whose serial range is `[e0, ni + e1)` (stage/op i-extent `(e0, e1)`,
/// `e0 <= 0 <= e1`): interior boundaries partition exactly at the slab
/// edges, and the edge slabs absorb the write halo. The full slab
/// `(0, ni)` reproduces the serial range. Shared by the materializing
/// path's `stage_region` and the fused path's `resolve_bounds` so the
/// ownership rule can never diverge between the two evaluators.
pub(crate) fn owned_store_range(
    slab: (i64, i64),
    ni: i64,
    e0: i64,
    e1: i64,
) -> (i64, i64) {
    let (a, b) = slab;
    (
        if a == 0 { e0 } else { a },
        if b == ni { ni + e1 } else { b },
    )
}

// Slab jobs access the run's storages through the typed
// `storage::StorageView`s of a shared `program::EnvView` — element-granular
// `UnsafeCell` interior mutability under the disjoint-write contract
// documented in `storage/view.rs`. (The old `&mut`-aliasing `SyncCell`
// lived here; it is gone, which is what makes this module Miri-clean.)

/// One queued fan-out: a borrowed slab closure, lifetime-erased. The
/// pointer is only dereferenced while [`WorkerPool::run_slabs`] blocks
/// its caller, which keeps the referent alive.
#[derive(Clone, Copy)]
struct Job {
    f: *const (dyn Fn(usize) + Sync),
    nslabs: usize,
}

// Safety: see `Job` — the raw pointer never outlives the blocked caller.
unsafe impl Send for Job {}

struct PoolState {
    /// Bumped once per job; workers wake when it moves past what they saw.
    epoch: u64,
    job: Option<Job>,
    /// Workers that have not finished (or skipped) the current job yet.
    remaining: usize,
    /// A slab of the current job panicked (re-raised on the caller).
    panicked: bool,
    shutdown: bool,
}

struct PoolShared {
    state: Mutex<PoolState>,
    work: Condvar,
    done: Condvar,
}

/// A persistent pool of parked worker threads executing *scoped* slab
/// jobs: plain `std` threads, spawned once and reused across stencil
/// calls (the paper's OpenMP-thread-team analog, without the runtime
/// dependency).
///
/// Slab `s` of a job is always executed by participant `s` — the caller
/// runs slab 0, worker `w` runs slab `w` — so a job over `n` slabs is
/// guaranteed `n` distinct concurrent threads and may synchronize them
/// with a `std::sync::Barrier` of `n` participants (the fused evaluator's
/// tier barriers rely on this).
pub struct WorkerPool {
    shared: Arc<PoolShared>,
    handles: Vec<std::thread::JoinHandle<()>>,
}

impl Default for WorkerPool {
    fn default() -> Self {
        WorkerPool::new()
    }
}

impl WorkerPool {
    /// An empty pool; workers are spawned on demand by
    /// [`WorkerPool::ensure_workers`].
    pub fn new() -> WorkerPool {
        WorkerPool {
            shared: Arc::new(PoolShared {
                state: Mutex::new(PoolState {
                    epoch: 0,
                    job: None,
                    remaining: 0,
                    panicked: false,
                    shutdown: false,
                }),
                work: Condvar::new(),
                done: Condvar::new(),
            }),
            handles: Vec::new(),
        }
    }

    pub fn workers(&self) -> usize {
        self.handles.len()
    }

    /// Grow the pool until at least `n` workers exist (never shrinks —
    /// the pool is meant to persist across calls).
    pub fn ensure_workers(&mut self, n: usize) {
        while self.handles.len() < n {
            let idx = self.handles.len() + 1; // participant/slab index
            let shared = self.shared.clone();
            let handle = std::thread::Builder::new()
                .name(format!("gt4rs-shard-{idx}"))
                .spawn(move || worker_loop(&shared, idx))
                .expect("spawn shard worker");
            self.handles.push(handle);
        }
    }

    /// Execute `f(slab)` for every slab in `0..nslabs` concurrently, one
    /// slab per participant (caller = slab 0), and block until all slabs
    /// complete — even when a slab panics (the caller must not unwind
    /// while workers still hold the borrowed closure; a worker-side panic
    /// is re-raised here after the join). Requires
    /// `nslabs - 1 <= self.workers()`.
    ///
    /// Caveat: the panic-safe join cannot rescue a job whose *other*
    /// slabs are blocked in a `std::sync::Barrier` the panicking slab
    /// never reached (std barriers have no poisoning) — such a bug hangs
    /// the run instead of panicking it. Slab jobs must therefore keep
    /// their barrier schedules slab-independent, as the evaluators do.
    pub fn run_slabs(&self, nslabs: usize, f: &(dyn Fn(usize) + Sync)) {
        assert!(
            nslabs >= 1 && nslabs <= self.handles.len() + 1,
            "run_slabs: {nslabs} slabs exceed pool of {} workers + caller",
            self.handles.len()
        );
        if nslabs == 1 {
            f(0);
            return;
        }
        {
            let mut st = self.shared.state.lock().unwrap();
            debug_assert!(st.job.is_none(), "overlapping run_slabs on one pool");
            // Safety: lifetime erasure of the borrowed closure (a fat
            // reference reinterpreted as a fat raw pointer). We block
            // below until `remaining` reaches zero, so the referent
            // outlives every dereference.
            let erased: *const (dyn Fn(usize) + Sync) =
                unsafe { std::mem::transmute(f) };
            st.epoch += 1;
            st.job = Some(Job { f: erased, nslabs });
            st.remaining = self.handles.len();
            st.panicked = false;
            self.shared.work.notify_all();
        }
        let caller = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(0)));
        let panicked = {
            let mut st = self.shared.state.lock().unwrap();
            while st.remaining > 0 {
                st = self.shared.done.wait(st).unwrap();
            }
            st.job = None;
            st.panicked
        };
        if let Err(payload) = caller {
            std::panic::resume_unwind(payload);
        }
        if panicked {
            panic!("a sharded slab job panicked on a worker thread");
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.shutdown = true;
        }
        self.shared.work.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(shared: &PoolShared, idx: usize) {
    let mut seen = 0u64;
    loop {
        let job = {
            let mut st = shared.state.lock().unwrap();
            loop {
                if st.shutdown {
                    return;
                }
                if st.epoch != seen {
                    break;
                }
                st = shared.work.wait(st).unwrap();
            }
            seen = st.epoch;
            st.job.expect("job present at a new epoch")
        };
        let mut failed = false;
        if idx < job.nslabs {
            // Safety: `run_slabs` blocks its caller until every worker has
            // decremented `remaining`, keeping the closure alive here. A
            // panicking slab is caught so the countdown (and with it the
            // caller's join) always completes; the caller re-raises.
            let f = unsafe { &*job.f };
            failed = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(idx)))
                .is_err();
        }
        let mut st = shared.state.lock().unwrap();
        if failed {
            st.panicked = true;
        }
        st.remaining -= 1;
        if st.remaining == 0 {
            shared.done.notify_all();
        }
    }
}

// ---------------------------------------------------------------------------
// Global core budget — admission control over the worker-pool slots
// ---------------------------------------------------------------------------

/// Outcome of a [`CoreBudget::acquire`] attempt.
pub enum Admission {
    /// Cores granted; release is the permit's `Drop`.
    Granted(CorePermit),
    /// The budget is saturated and the request was not allowed to wait
    /// (no deadline to wait under, or the wait queue is full). The
    /// serve layer maps this to a structured 429-style response.
    Overloaded {
        /// Cores in use at the rejection.
        in_use: usize,
        /// Requests already queued at the rejection.
        waiters: usize,
    },
    /// The request waited but its deadline expired before cores freed up.
    DeadlineExceeded,
}

struct BudgetState {
    in_use: usize,
    waiters: usize,
}

/// A counting semaphore over CPU cores: the composition point between
/// *outer* concurrency (many concurrent stencil requests) and *inner*
/// concurrency (each request's [`Sharding`] fan-out). Every request
/// acquires as many slots as its resolved shard plan will occupy, so the
/// server never oversubscribes the machine however clients combine the
/// two levels; saturation is surfaced as explicit admission outcomes
/// (shed or timed out), never as an unbounded queue.
pub struct CoreBudget {
    state: Mutex<BudgetState>,
    freed: Condvar,
    cores: usize,
    /// Max requests allowed to wait for cores at once; everything past
    /// this is shed immediately ([`Admission::Overloaded`]).
    max_waiters: usize,
}

impl CoreBudget {
    pub fn new(cores: usize, max_waiters: usize) -> Arc<CoreBudget> {
        Arc::new(CoreBudget {
            state: Mutex::new(BudgetState { in_use: 0, waiters: 0 }),
            freed: Condvar::new(),
            cores: cores.max(1),
            max_waiters,
        })
    }

    pub fn cores(&self) -> usize {
        self.cores
    }

    /// Cores currently granted (a metrics peek).
    pub fn in_use(&self) -> usize {
        self.state.lock().unwrap().in_use
    }

    /// Requests currently waiting for cores (a metrics peek).
    pub fn waiters(&self) -> usize {
        self.state.lock().unwrap().waiters
    }

    /// Try to take `want` cores (clamped to the budget size, min 1).
    /// Grants immediately when they fit; otherwise waits until `deadline`
    /// if one is given and the wait queue has room, else sheds. Fairness
    /// is condvar wake order — good enough for load shedding, not a FIFO
    /// guarantee.
    pub fn acquire(self: &Arc<Self>, want: usize, deadline: Option<Instant>) -> Admission {
        let want = want.clamp(1, self.cores);
        let mut st = self.state.lock().unwrap();
        if st.in_use + want <= self.cores {
            st.in_use += want;
            return Admission::Granted(CorePermit { budget: self.clone(), n: want });
        }
        let Some(deadline) = deadline else {
            return Admission::Overloaded { in_use: st.in_use, waiters: st.waiters };
        };
        if st.waiters >= self.max_waiters {
            return Admission::Overloaded { in_use: st.in_use, waiters: st.waiters };
        }
        st.waiters += 1;
        loop {
            let now = Instant::now();
            if now >= deadline {
                st.waiters -= 1;
                return Admission::DeadlineExceeded;
            }
            let (guard, timeout) =
                self.freed.wait_timeout(st, deadline - now).unwrap();
            st = guard;
            if st.in_use + want <= self.cores {
                st.waiters -= 1;
                st.in_use += want;
                return Admission::Granted(CorePermit { budget: self.clone(), n: want });
            }
            if timeout.timed_out() && Instant::now() >= deadline {
                st.waiters -= 1;
                return Admission::DeadlineExceeded;
            }
        }
    }
}

/// RAII grant of `n` cores from a [`CoreBudget`]; dropping it returns
/// them and wakes the waiters.
pub struct CorePermit {
    budget: Arc<CoreBudget>,
    n: usize,
}

impl CorePermit {
    /// How many cores this permit holds.
    pub fn cores(&self) -> usize {
        self.n
    }
}

impl Drop for CorePermit {
    fn drop(&mut self) {
        let mut st = self.budget.state.lock().unwrap();
        st.in_use -= self.n;
        drop(st);
        self.budget.freed.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Barrier;

    #[test]
    fn parse_and_display_roundtrip() {
        assert_eq!(Sharding::parse("off"), Some(Sharding::Off));
        assert_eq!(Sharding::parse("0"), Some(Sharding::Off));
        assert_eq!(Sharding::parse("auto"), Some(Sharding::Auto));
        assert_eq!(Sharding::parse("4"), Some(Sharding::Threads(4)));
        assert_eq!(Sharding::parse("AUTO"), Some(Sharding::Auto));
        assert_eq!(Sharding::parse("banana"), None);
        assert_eq!(Sharding::Off.to_string(), "off");
        assert_eq!(Sharding::Threads(8).to_string(), "8");
        assert_eq!(Sharding::Auto.to_string(), "auto");
    }

    #[test]
    fn resolve_clamps_to_domain_and_degrades_auto() {
        // Explicit thread counts clamp to the number of nonempty slabs.
        assert_eq!(Sharding::Threads(8).resolve(3), 3);
        assert_eq!(Sharding::Threads(2).resolve(64), 2);
        assert_eq!(Sharding::Threads(1).resolve(64), 1);
        assert_eq!(Sharding::Off.resolve(1024), 1);
        // Auto never shards a domain narrower than one profitable slab
        // per extra thread (the CI bench-smoke / tiny-domain guarantee).
        assert_eq!(Sharding::Auto.resolve(MIN_AUTO_SLAB_WIDTH - 1), 1);
        assert_eq!(Sharding::Auto.resolve(8), 1);
        // Auto on a wide domain uses at most one thread per core.
        let avail = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        assert!(Sharding::Auto.resolve(1 << 20) <= avail);
    }

    #[test]
    fn split_slabs_partitions_exactly() {
        for ni in [1usize, 2, 3, 7, 16, 33, 128] {
            for t in [1usize, 2, 3, 4, 8, 200] {
                let slabs = split_slabs(ni, t);
                assert_eq!(slabs.len(), t.min(ni));
                assert_eq!(slabs[0].0, 0);
                assert_eq!(slabs.last().unwrap().1, ni as i64);
                for w in slabs.windows(2) {
                    assert_eq!(w[0].1, w[1].0, "slabs must tile contiguously");
                }
                for (a, b) in &slabs {
                    assert!(b > a, "empty slab in {slabs:?}");
                }
            }
        }
    }

    #[test]
    fn owned_store_ranges_tile_the_serial_write_range() {
        // For any slab partition and write extent, the owned store
        // ranges must tile [e0, ni + e1) exactly — no overlap, no gap —
        // and the full slab must reproduce the serial range.
        let ni = 13usize;
        for (e0, e1) in [(0i64, 0i64), (-2, 1), (-1, 3)] {
            assert_eq!(
                owned_store_range((0, ni as i64), ni as i64, e0, e1),
                (e0, ni as i64 + e1)
            );
            for t in [1usize, 2, 3, 5] {
                let slabs = split_slabs(ni, t);
                let ranges: Vec<(i64, i64)> = slabs
                    .iter()
                    .map(|&s| owned_store_range(s, ni as i64, e0, e1))
                    .collect();
                assert_eq!(ranges[0].0, e0);
                assert_eq!(ranges.last().unwrap().1, ni as i64 + e1);
                for w in ranges.windows(2) {
                    assert_eq!(w[0].1, w[1].0, "store ranges must tile: {ranges:?}");
                }
            }
        }
    }

    #[test]
    fn halo_plan_merge_orders_by_strictness() {
        use HaloPlan::*;
        assert_eq!(Local.merge(PerLevel), PerLevel);
        assert_eq!(PerLevel.merge(Local), PerLevel);
        assert_eq!(PerLevel.merge(PerStage), PerStage);
        assert_eq!(PerStage.merge(PerLevel), PerStage);
        assert_eq!(Serial.merge(Local), Serial);
        assert_eq!(PerStage.merge(Serial), Serial);
        assert!(Local.sharded() && PerLevel.sharded() && PerStage.sharded());
        assert!(!Serial.sharded());
        assert_eq!(PerLevel.to_string(), "per-level");
        assert_eq!(Serial.as_str(), "serial");
    }

    #[test]
    fn halo_rendezvous_is_reusable_and_counts_crossings() {
        // One participant never blocks (narrow domains degrade cleanly).
        let solo = HaloRendezvous::new(1);
        solo.wait();
        solo.wait();
        assert_eq!(solo.crossings(), 2);
        // Four slabs on the worker pool, five back-to-back levels: after
        // each rendezvous every slab must observe all contributions of
        // the level (the happens-before edge the halo exchange needs).
        let mut pool = WorkerPool::new();
        pool.ensure_workers(3);
        let gate = HaloRendezvous::new(4);
        assert_eq!(gate.participants(), 4);
        let sum = AtomicUsize::new(0);
        let levels = 5usize;
        pool.run_slabs(4, &|s| {
            for lvl in 0..levels {
                sum.fetch_add(s + 1, Ordering::SeqCst);
                gate.wait();
                assert_eq!(sum.load(Ordering::SeqCst), 10 * (lvl + 1));
                // Second gate: nobody starts the next level's adds until
                // every slab has checked this one.
                gate.wait();
            }
        });
        assert_eq!(gate.crossings(), 2 * levels as u64);
    }

    #[test]
    fn halo_exchange_publishes_neighbor_columns_per_level() {
        // The exact per-level exchange shape the sequential evaluators
        // run, reduced to its synchronization skeleton: each slab writes
        // its owned columns of level k through a shared StorageView,
        // meets the rendezvous, and only then reads neighbor-owned
        // columns of level k to produce level k+1. Run under Miri and
        // TSan, this is the regression test for the halo-exchange
        // aliasing and happens-before obligations.
        use crate::storage::Storage;
        use crate::storage::view::StorageView;
        let (ni, nj, nk) = (8i64, 2i64, 5i64);
        let mut s = Storage::with_halo([ni as usize, nj as usize, nk as usize], 1);
        for j in 0..nj {
            for k in 0..nk {
                s.set(-1, j, k, 0.25);
                s.set(ni, j, k, 0.75);
            }
            for i in 0..ni {
                s.set(i, j, 0, (i + 1) as f64);
            }
        }
        // Serial reference for the carry x[i,k] = x[i-1,k-1] + x[i+1,k-1].
        let mut want = vec![0.0f64; (ni * nj * nk) as usize];
        let at = |i: i64, j: i64, k: i64, w: &[f64]| -> f64 {
            if i < 0 {
                0.25
            } else if i >= ni {
                0.75
            } else {
                w[((i * nj + j) * nk + k) as usize]
            }
        };
        for j in 0..nj {
            for i in 0..ni {
                want[((i * nj + j) * nk) as usize] = (i + 1) as f64;
            }
        }
        for k in 1..nk {
            for j in 0..nj {
                for i in 0..ni {
                    let v = at(i - 1, j, k - 1, &want) + at(i + 1, j, k - 1, &want);
                    want[((i * nj + j) * nk + k) as usize] = v;
                }
            }
        }
        let slabs = split_slabs(ni as usize, 2);
        let gate = HaloRendezvous::new(slabs.len());
        let mut pool = WorkerPool::new();
        pool.ensure_workers(slabs.len() - 1);
        let view: StorageView<'_, f64> = s.view();
        pool.run_slabs(slabs.len(), &|sx| {
            let (a, b) = slabs[sx];
            for k in 1..nk {
                for j in 0..nj {
                    for i in a..b {
                        // SAFETY: reads touch only level k-1 (published by
                        // the previous rendezvous or the pre-fan-out fill);
                        // writes touch only this slab's owned columns of
                        // level k — the disjoint-write contract.
                        unsafe {
                            let v = view.get(i - 1, j, k - 1) + view.get(i + 1, j, k - 1);
                            view.set(i, j, k, v);
                        }
                    }
                }
                gate.wait();
            }
        });
        assert_eq!(gate.crossings(), (nk - 1) as u64);
        for i in 0..ni {
            for j in 0..nj {
                for k in 0..nk {
                    assert_eq!(
                        s.get(i, j, k),
                        want[((i * nj + j) * nk + k) as usize],
                        "halo exchange diverged at ({i},{j},{k})"
                    );
                }
            }
        }
    }

    #[test]
    fn serial_report_carries_busy_time() {
        let r = ShardReport::serial_with(Duration::from_millis(7));
        assert_eq!(r.threads, 1);
        assert_eq!(r.slabs, 1);
        assert_eq!(r.exchanges, 0);
        assert_eq!(r.busy_total, Duration::from_millis(7));
        assert_eq!(r.busy_min, r.busy_max);
        assert_eq!(ShardReport::default(), ShardReport::serial());
    }

    #[test]
    fn worker_pool_runs_every_slab_exactly_once() {
        let mut pool = WorkerPool::new();
        pool.ensure_workers(3);
        assert_eq!(pool.workers(), 3);
        let hits: Vec<AtomicUsize> = (0..4).map(|_| AtomicUsize::new(0)).collect();
        // Reuse across jobs, including narrower fan-outs than the pool.
        for _ in 0..50 {
            pool.run_slabs(4, &|s| {
                hits[s].fetch_add(1, Ordering::Relaxed);
            });
            pool.run_slabs(2, &|s| {
                hits[s].fetch_add(1, Ordering::Relaxed);
            });
        }
        assert_eq!(hits[0].load(Ordering::Relaxed), 100);
        assert_eq!(hits[1].load(Ordering::Relaxed), 100);
        assert_eq!(hits[2].load(Ordering::Relaxed), 50);
        assert_eq!(hits[3].load(Ordering::Relaxed), 50);
    }

    #[test]
    fn worker_pool_guarantees_concurrent_slabs_for_barriers() {
        // Every slab gets its own thread, so an n-participant barrier
        // inside the job must not deadlock — the property the fused
        // evaluator's tier barriers depend on.
        let mut pool = WorkerPool::new();
        pool.ensure_workers(3);
        let barrier = Barrier::new(4);
        let phase = AtomicUsize::new(0);
        pool.run_slabs(4, &|_s| {
            phase.fetch_add(1, Ordering::SeqCst);
            barrier.wait();
            assert_eq!(phase.load(Ordering::SeqCst), 4);
            barrier.wait();
            phase.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(phase.load(Ordering::SeqCst), 8);
    }

    #[test]
    fn worker_pool_grows_on_demand() {
        let mut pool = WorkerPool::new();
        pool.run_slabs(1, &|s| assert_eq!(s, 0));
        pool.ensure_workers(1);
        pool.ensure_workers(1); // idempotent
        assert_eq!(pool.workers(), 1);
        let sum = AtomicUsize::new(0);
        pool.run_slabs(2, &|s| {
            sum.fetch_add(s + 1, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 3);
    }

    #[test]
    fn core_budget_grants_and_releases() {
        let budget = CoreBudget::new(4, 8);
        assert_eq!(budget.cores(), 4);
        let a = match budget.acquire(3, None) {
            Admission::Granted(p) => p,
            _ => panic!("3 of 4 cores must grant immediately"),
        };
        assert_eq!(a.cores(), 3);
        assert_eq!(budget.in_use(), 3);
        // One more core still fits; a second full request does not.
        let b = match budget.acquire(1, None) {
            Admission::Granted(p) => p,
            _ => panic!("the last core must grant"),
        };
        assert!(matches!(
            budget.acquire(1, None),
            Admission::Overloaded { in_use: 4, .. }
        ));
        drop(b);
        drop(a);
        assert_eq!(budget.in_use(), 0);
        // Requests wider than the budget clamp instead of deadlocking.
        let wide = match budget.acquire(64, None) {
            Admission::Granted(p) => p,
            _ => panic!("oversized requests clamp to the budget"),
        };
        assert_eq!(wide.cores(), 4);
    }

    #[test]
    fn core_budget_sheds_when_wait_queue_is_full() {
        let budget = CoreBudget::new(1, 0);
        let held = match budget.acquire(1, None) {
            Admission::Granted(p) => p,
            _ => panic!(),
        };
        // max_waiters = 0: even a deadline-carrying request is shed.
        let deadline = Some(Instant::now() + Duration::from_secs(5));
        assert!(matches!(
            budget.acquire(1, deadline),
            Admission::Overloaded { in_use: 1, waiters: 0 }
        ));
        drop(held);
    }

    #[test]
    fn core_budget_times_out_waiters_at_their_deadline() {
        let budget = CoreBudget::new(1, 4);
        let held = budget.acquire(1, None);
        assert!(matches!(held, Admission::Granted(_)));
        let t0 = Instant::now();
        let adm = budget.acquire(1, Some(Instant::now() + Duration::from_millis(30)));
        assert!(matches!(adm, Admission::DeadlineExceeded));
        assert!(t0.elapsed() >= Duration::from_millis(25));
        assert_eq!(budget.waiters(), 0, "timed-out waiters must deregister");
    }

    #[test]
    fn core_budget_hands_freed_cores_to_waiters() {
        let budget = CoreBudget::new(2, 4);
        let held = match budget.acquire(2, None) {
            Admission::Granted(p) => p,
            _ => panic!(),
        };
        let waiter = {
            let budget = budget.clone();
            std::thread::spawn(move || {
                matches!(
                    budget.acquire(2, Some(Instant::now() + Duration::from_secs(10))),
                    Admission::Granted(_)
                )
            })
        };
        // Give the waiter time to enqueue, then free the cores.
        while budget.waiters() == 0 {
            std::thread::yield_now();
        }
        drop(held);
        assert!(waiter.join().unwrap(), "freed cores must reach the waiter");
        assert_eq!(budget.in_use(), 0);
    }
}
