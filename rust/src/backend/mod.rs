//! Execution backends (paper §2.3).
//!
//! | Paper backend  | gt4rs backend | Strategy                                   |
//! |----------------|---------------|--------------------------------------------|
//! | `debug`        | [`debug`]     | scalar tree-walking interpreter            |
//! | `numpy`        | [`vector`]    | plane-vectorized, materialized temporaries |
//! | `gtx86`/`gtmc` | [`xlagen`]    | XlaBuilder codegen, JIT-compiled on PJRT   |
//! | `gtcuda`       | [`pjrt_aot`]  | prebuilt JAX/**Pallas** HLO artifacts      |
//!
//! All backends consume the same implementation IR and are interchangeable
//! behind the [`Backend`] trait; equivalence across backends is asserted in
//! the test suites.

pub mod cexpr;
pub mod debug;
pub mod pjrt_aot;
pub mod program;
pub mod vector;
pub mod xlagen;

use crate::ir::implir::StencilIr;
use crate::storage::Storage;
use anyhow::Result;

/// Arguments for one stencil invocation.
pub struct StencilArgs<'a, 'b> {
    /// `(name, storage)` for every field parameter, any order.
    pub fields: &'a mut [(&'b str, &'b mut Storage)],
    /// `(name, value)` for every scalar parameter.
    pub scalars: &'a [(&'b str, f64)],
    /// Compute-domain shape (ni, nj, nk).
    pub domain: [usize; 3],
}

/// A stencil execution backend.
pub trait Backend {
    fn name(&self) -> &'static str;

    /// One-time compilation/codegen for a stencil (cached by the
    /// coordinator); optional — `run` must self-prepare when skipped.
    fn prepare(&mut self, _ir: &StencilIr) -> Result<()> {
        Ok(())
    }

    /// Execute the stencil over `args.domain`.
    fn run(&mut self, ir: &StencilIr, args: &mut StencilArgs) -> Result<()>;
}

/// Names of all built-in backends, in the tier order of Fig. 3.
pub const BACKEND_NAMES: [&str; 4] = ["debug", "vector", "xla", "pjrt-aot"];

/// Instantiate a backend by name.
pub fn create(name: &str) -> Result<Box<dyn Backend>> {
    Ok(match name {
        "debug" => Box::new(debug::DebugBackend::new()),
        "vector" => Box::new(vector::VectorBackend::new()),
        "xla" => Box::new(xlagen::XlaBackend::new()?),
        "pjrt-aot" => Box::new(pjrt_aot::PjrtAotBackend::new()?),
        other => anyhow::bail!(
            "unknown backend `{other}` (available: {})",
            BACKEND_NAMES.join(", ")
        ),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn create_interpreting_backends() {
        assert_eq!(create("debug").unwrap().name(), "debug");
        assert_eq!(create("vector").unwrap().name(), "vector");
        assert!(create("nope").is_err());
    }
}
