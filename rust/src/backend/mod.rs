//! Execution backends (paper §2.3).
//!
//! | Paper backend  | gt4rs backend | Strategy                                   |
//! |----------------|---------------|--------------------------------------------|
//! | `debug`        | [`debug`]     | scalar tree-walking interpreter            |
//! | `numpy`        | [`vector`]    | plane-vectorized, materialized temporaries |
//! | `gtx86`/`gtmc` | [`xlagen`]    | XlaBuilder codegen, JIT-compiled on PJRT   |
//! | `gtcuda`       | [`pjrt_aot`]  | prebuilt JAX/**Pallas** HLO artifacts      |
//!
//! All backends consume the same implementation IR and are interchangeable
//! behind the [`Backend`] trait; equivalence across backends is asserted in
//! the test suites.

pub mod cexpr;
pub mod debug;
pub mod fused;
pub mod kernels;
pub mod pjrt_aot;
pub mod program;
pub mod shard;
pub mod vector;
pub mod xlagen;

use crate::ir::implir::StencilIr;
use crate::storage::Storage;
use anyhow::Result;
use kernels::ExecTier;
use shard::{ShardReport, Sharding};

/// Arguments for one stencil invocation.
pub struct StencilArgs<'a, 'b> {
    /// `(name, storage)` for every field parameter, any order.
    pub fields: &'a mut [(&'b str, &'b mut Storage)],
    /// `(name, value)` for every scalar parameter.
    pub scalars: &'a [(&'b str, f64)],
    /// Compute-domain shape (ni, nj, nk).
    pub domain: [usize; 3],
}

/// Per-call execution parameters that are *not* part of the compiled
/// artifact: they change how a run is scheduled, never what it computes,
/// so they stay out of IR fingerprints and cache keys (contrast
/// [`crate::opt::OptConfig`]'s pass toggles).
#[derive(Debug, Clone, Copy, Default)]
pub struct RunConfig {
    /// Intra-call domain sharding plan (see [`shard::Sharding`]).
    pub sharding: Sharding,
    /// Which executor the fused (`--opt-level 3`) path uses (see
    /// [`kernels::ExecTier`]); bitwise-identical by contract, so a pure
    /// scheduling choice like `sharding`.
    pub tier: ExecTier,
}

/// A stencil execution backend.
///
/// Backends execute through `&self` and are `Send + Sync`: one instance is
/// shared by every [`crate::coordinator::Stencil`] handle bound to it, and
/// handles dispatch concurrently from many threads. Mutable state — the
/// per-fingerprint program/executable caches, buffer pools, worker pools,
/// staging buffers — lives behind interior mutability (`RwLock`/`Mutex`)
/// inside each backend. The interpreting backends (`debug`, `vector`) run
/// fully in parallel; the PJRT-backed backends (`xla`, `pjrt-aot`)
/// serialize calls on an internal lock around their client.
pub trait Backend: Send + Sync {
    fn name(&self) -> &'static str;

    /// One-time compilation/codegen for a stencil (memoized per
    /// fingerprint inside the backend); optional — `run` must self-prepare
    /// when skipped.
    fn prepare(&self, _ir: &StencilIr) -> Result<()> {
        Ok(())
    }

    /// Execute the stencil over `args.domain`.
    fn run(&self, ir: &StencilIr, args: &mut StencilArgs) -> Result<()>;

    /// Execute with per-call scheduling parameters, reporting what the
    /// schedule actually did. Backends without an intra-call parallel
    /// path (everything except `vector` today) ignore the plan and run
    /// serially — results are identical by the sharding contract, so
    /// degrading is always safe.
    fn run_sharded(
        &self,
        ir: &StencilIr,
        args: &mut StencilArgs,
        cfg: &RunConfig,
    ) -> Result<ShardReport> {
        let _ = cfg;
        self.run(ir, args)?;
        Ok(ShardReport::serial())
    }

    /// A snapshot of this backend's buffer-pool/executor counters, if it
    /// keeps any (`None` for backends without pools). A *peek*: unlike
    /// the resetting takers some backends expose, this never clears the
    /// counters — metrics endpoints may call it repeatedly.
    fn pool_stats(&self) -> Option<vector::PoolStats> {
        None
    }

    /// Attach a persistent artifact store (see [`crate::persist`]). The
    /// coordinator forwards its store to every backend it creates;
    /// backends with process-surviving artifacts (`vector`'s compiled
    /// fused tapes, `pjrt-aot`'s HLO text) load-or-compile through it.
    /// Default: no-op — interpreting and JIT-only backends have nothing
    /// worth persisting beyond the IR the coordinator already stores.
    fn set_persist(&self, _store: &std::sync::Arc<crate::persist::PersistStore>) {}
}

/// Names of all built-in backends, in the tier order of Fig. 3.
pub const BACKEND_NAMES: [&str; 4] = ["debug", "vector", "xla", "pjrt-aot"];

/// Structured backend-instantiation failure: lets callers (coordinator,
/// CLI, tests) distinguish *misconfiguration* (a name that doesn't exist)
/// from *missing hardware/runtime* (a real backend this process cannot
/// host, e.g. no PJRT plugin).
#[derive(Debug)]
pub enum CreateError {
    /// No backend goes by this name.
    UnknownBackend(String),
    /// The backend exists but cannot run in this environment.
    Unavailable {
        backend: &'static str,
        reason: String,
    },
}

impl std::fmt::Display for CreateError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CreateError::UnknownBackend(name) => write!(
                f,
                "unknown backend `{name}` (available: {})",
                BACKEND_NAMES.join(", ")
            ),
            CreateError::Unavailable { backend, reason } => {
                write!(f, "backend `{backend}` unavailable: {reason}")
            }
        }
    }
}

impl std::error::Error for CreateError {}

/// Whether an error chain bottoms out in [`CreateError::Unavailable`] —
/// used to degrade gracefully (skip a backend) instead of failing hard.
pub fn is_unavailable(err: &anyhow::Error) -> bool {
    err.chain().any(|e| {
        matches!(
            e.downcast_ref::<CreateError>(),
            Some(CreateError::Unavailable { .. })
        )
    })
}

/// Instantiate a backend by name.
pub fn create(name: &str) -> Result<Box<dyn Backend>, CreateError> {
    // The compiled backends need a PJRT client; probe once so the failure
    // is a structured `Unavailable`, not an opaque constructor error.
    let pjrt = |backend: &'static str| -> Result<(), CreateError> {
        if crate::runtime::pjrt_available() {
            Ok(())
        } else {
            Err(CreateError::Unavailable {
                backend,
                reason: "no PJRT CPU client can be created in this process".to_string(),
            })
        }
    };
    Ok(match name {
        "debug" => Box::new(debug::DebugBackend::new()),
        "vector" => Box::new(vector::VectorBackend::new()),
        "xla" => {
            pjrt("xla")?;
            Box::new(xlagen::XlaBackend::new().map_err(|e| CreateError::Unavailable {
                backend: "xla",
                reason: format!("{e:#}"),
            })?)
        }
        "pjrt-aot" => {
            pjrt("pjrt-aot")?;
            Box::new(pjrt_aot::PjrtAotBackend::new().map_err(|e| {
                CreateError::Unavailable { backend: "pjrt-aot", reason: format!("{e:#}") }
            })?)
        }
        other => return Err(CreateError::UnknownBackend(other.to_string())),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn create_interpreting_backends() {
        assert_eq!(create("debug").unwrap().name(), "debug");
        assert_eq!(create("vector").unwrap().name(), "vector");
    }

    #[test]
    fn unknown_and_unavailable_are_distinct() {
        match create("nope") {
            Err(CreateError::UnknownBackend(n)) => assert_eq!(n, "nope"),
            other => panic!("expected UnknownBackend, got {other:?}"),
        }
        // The compiled backends either come up or report Unavailable —
        // never UnknownBackend.
        for be in ["xla", "pjrt-aot"] {
            match create(be) {
                Ok(b) => assert_eq!(b.name(), be),
                Err(CreateError::Unavailable { backend, .. }) => assert_eq!(backend, be),
                Err(e @ CreateError::UnknownBackend(_)) => {
                    panic!("`{be}` misreported as {e}")
                }
            }
        }
    }

    #[test]
    fn unavailable_detection_through_anyhow() {
        let err = anyhow::Error::new(CreateError::Unavailable {
            backend: "xla",
            reason: "probe".into(),
        })
        .context("creating backend");
        assert!(is_unavailable(&err));
        let other = anyhow::anyhow!("something else");
        assert!(!is_unavailable(&other));
        let unknown = anyhow::Error::new(CreateError::UnknownBackend("warp".into()));
        assert!(!is_unavailable(&unknown));
    }
}
