//! The `debug` backend: a scalar tree-walking interpreter.
//!
//! The analog of GT4Py's pure-Python `debug` backend (§2.3): every point of
//! the iteration space is evaluated by walking the expression tree with
//! dynamic dispatch. Deliberately unoptimized — it exists to define the
//! reference semantics, to be steppable, and to be the slow baseline of the
//! Fig. 3 reproduction. Do not optimize this backend.
//!
//! That rule extends to the optimizer's IR metadata: fusion groups are
//! ignored (stage-outermost order *is* the IR's semantics) and demoted
//! temporaries are still materialized as full zero-initialized fields
//! ([`Env::build`] with `materialize_demoted = true`). Because every
//! optimizer pass is semantics-preserving under this execution model, the
//! debug backend produces bit-identical results at every opt level — which
//! is exactly what makes it the arbiter in the equivalence suites.

use super::cexpr::{apply_bin, apply_builtin1, apply_builtin2, CExpr};
use super::program::{Env, EnvView, Program};
use super::{Backend, StencilArgs};
use crate::dsl::ast::{DType, IterationPolicy};
use crate::ir::implir::StencilIr;
use crate::storage::Element;
use anyhow::Result;
use std::sync::{Arc, RwLock};

#[derive(Default)]
pub struct DebugBackend {
    /// Slot-resolved programs keyed by stencil fingerprint (one backend
    /// instance is shared across stencils and across threads; the lock is
    /// only held for cache lookup/insert, never during execution).
    programs: RwLock<std::collections::HashMap<u64, Arc<Program>>>,
}

impl DebugBackend {
    pub fn new() -> Self {
        Self::default()
    }

    fn program(&self, ir: &StencilIr) -> Result<Arc<Program>> {
        if let Some(p) = self.programs.read().unwrap().get(&ir.fingerprint) {
            return Ok(p.clone());
        }
        let compiled = Arc::new(Program::compile(ir)?);
        let mut programs = self.programs.write().unwrap();
        Ok(programs.entry(ir.fingerprint).or_insert(compiled).clone())
    }
}

/// Recursive tree-walk at the stencil's native precision `T` (constants
/// converted round-to-nearest once per visit — deterministic).
///
/// SAFETY of the view accesses: the debug backend runs single-threaded over
/// an exclusively owned [`Env`], so the disjoint-write contract holds
/// trivially; coordinates stay inside the allocated box by the extent
/// analysis (debug-asserted in the views).
fn eval<T: Element>(env: &EnvView<'_, T>, e: &CExpr, i: i64, j: i64, k: i64) -> T {
    match e {
        CExpr::Const(v) => T::from_f64(*v),
        CExpr::Scalar(ix) => env.scalars[*ix],
        CExpr::Field { slot, off } => unsafe {
            env.storages[*slot].get(
                i + off[0] as i64,
                j + off[1] as i64,
                k + off[2] as i64,
            )
        },
        CExpr::Neg(a) => -eval(env, a, i, j, k),
        CExpr::Not(a) => T::from_bool(!eval(env, a, i, j, k).truthy()),
        CExpr::Bin(op, a, b) => {
            apply_bin(*op, eval(env, a, i, j, k), eval(env, b, i, j, k))
        }
        // Short-circuit select: only the taken branch is evaluated, the
        // natural semantics for a per-point interpreter.
        CExpr::Select(c, t, f) => {
            if eval(env, c, i, j, k).truthy() {
                eval(env, t, i, j, k)
            } else {
                eval(env, f, i, j, k)
            }
        }
        CExpr::Call1(f, a) => apply_builtin1(*f, eval(env, a, i, j, k)),
        CExpr::Call2(f, a, b) => {
            apply_builtin2(*f, eval(env, a, i, j, k), eval(env, b, i, j, k))
        }
    }
}

fn run_program<T: Element>(program: &Program, env: &EnvView<'_, T>) {
    let [ni, nj, _] = env.domain;
    for ms in &program.multistages {
        match ms.policy {
            IterationPolicy::Parallel => {
                // Stage-outermost: each assignment is applied over its full
                // 3-D region before the next statement starts.
                for st in &ms.stages {
                    let (k0, k1) = env.krange(&st.interval);
                    let e = st.extent;
                    for k in k0..k1 {
                        for i in e.i.0 as i64..ni as i64 + e.i.1 as i64 {
                            for j in e.j.0 as i64..nj as i64 + e.j.1 as i64 {
                                let v = eval(env, &st.expr, i, j, k);
                                // SAFETY: single-threaded exclusive Env.
                                unsafe { env.storages[st.target].set(i, j, k, v) };
                            }
                        }
                    }
                }
            }
            IterationPolicy::Forward | IterationPolicy::Backward => {
                // k-outermost: on each level, in-interval stages run in
                // program order over the horizontal plane.
                let ranges: Vec<(i64, i64)> =
                    ms.stages.iter().map(|s| env.krange(&s.interval)).collect();
                let kmin = ranges.iter().map(|r| r.0).min().unwrap_or(0);
                let kmax = ranges.iter().map(|r| r.1).max().unwrap_or(0);
                let ks: Vec<i64> = if ms.policy == IterationPolicy::Forward {
                    (kmin..kmax).collect()
                } else {
                    (kmin..kmax).rev().collect()
                };
                for k in ks {
                    for (st, (k0, k1)) in ms.stages.iter().zip(&ranges) {
                        if k < *k0 || k >= *k1 {
                            continue;
                        }
                        let e = st.extent;
                        for i in e.i.0 as i64..ni as i64 + e.i.1 as i64 {
                            for j in e.j.0 as i64..nj as i64 + e.j.1 as i64 {
                                let v = eval(env, &st.expr, i, j, k);
                                // SAFETY: single-threaded exclusive Env.
                                unsafe { env.storages[st.target].set(i, j, k, v) };
                            }
                        }
                    }
                }
            }
        }
    }
}

impl Backend for DebugBackend {
    fn name(&self) -> &'static str {
        "debug"
    }

    fn prepare(&self, ir: &StencilIr) -> Result<()> {
        self.program(ir)?;
        Ok(())
    }

    fn run(&self, ir: &StencilIr, args: &mut StencilArgs) -> Result<()> {
        let program = self.program(ir)?;
        let mut env = Env::build(&program, args.fields, args.scalars, args.domain)?;
        // One dtype dispatch per run; the evaluator is monomorphized.
        match program.dtype {
            DType::F64 => run_program(&program, &env.view::<f64>()),
            DType::F32 => run_program(&program, &env.view::<f32>()),
        }
        env.restore(&program, args.fields);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::compile_source;
    use crate::storage::Storage;
    use std::collections::BTreeMap;

    fn run_stencil<'b>(
        src: &str,
        name: &str,
        fields: &mut [(&'b str, &'b mut Storage)],
        scalars: &[(&'b str, f64)],
        domain: [usize; 3],
    ) {
        let ir = compile_source(src, name, &BTreeMap::new()).unwrap();
        let be = DebugBackend::new();
        let mut args = StencilArgs { fields, scalars, domain };
        be.run(&ir, &mut args).unwrap();
    }

    #[test]
    fn copy_stencil() {
        let mut a = Storage::from_fn([3, 3, 2], 0, |i, j, k| (i + 10 * j + 100 * k) as f64);
        let mut b = Storage::with_halo([3, 3, 2], 0);
        run_stencil(
            "stencil c(a: Field<f64>, b: Field<f64>) {\n\
               with computation(PARALLEL), interval(...) { b = a; }\n\
             }",
            "c",
            &mut [("a", &mut a), ("b", &mut b)],
            &[],
            [3, 3, 2],
        );
        assert_eq!(b.get(2, 1, 1), 112.0);
        assert_eq!(a.max_abs_diff(&b), 0.0);
    }

    #[test]
    fn laplacian_values() {
        let mut a = Storage::from_fn_extended([3, 3, 1], 1, |i, j, _| (i * i + j * j) as f64);
        let mut out = Storage::with_horizontal_halo([3, 3, 1], 0);
        run_stencil(
            "function lap(p) {\n\
               return -4.0*p[0,0,0] + p[-1,0,0] + p[1,0,0] + p[0,-1,0] + p[0,1,0];\n\
             }\n\
             stencil s(a: Field<f64>, out: Field<f64>) {\n\
               with computation(PARALLEL), interval(...) { out = lap(a); }\n\
             }",
            "s",
            &mut [("a", &mut a), ("out", &mut out)],
            &[],
            [3, 3, 1],
        );
        // Δ(i²+j²) = 4 exactly on the 5-point stencil.
        for i in 0..3 {
            for j in 0..3 {
                assert_eq!(out.get(i, j, 0), 4.0, "at ({i},{j})");
            }
        }
    }

    #[test]
    fn temporary_with_halo_used() {
        // t needs ±1 extent: halo of `a` = 2.
        let mut a = Storage::from_fn_extended([4, 4, 1], 2, |i, j, _| (i + j) as f64);
        let mut out = Storage::with_horizontal_halo([4, 4, 1], 0);
        run_stencil(
            "stencil s(a: Field<f64>, out: Field<f64>) {\n\
               with computation(PARALLEL), interval(...) {\n\
                 t = a[-1,0,0] + a[1,0,0];\n\
                 out = t[0,-1,0] + t[0,1,0];\n\
               }\n\
             }",
            "s",
            &mut [("a", &mut a), ("out", &mut out)],
            &[],
            [4, 4, 1],
        );
        // t(i,j) = 2(i+j); out = t(i,j-1)+t(i,j+1) = 4(i+j).
        for i in 0..4 {
            for j in 0..4 {
                assert_eq!(out.get(i, j, 0), 4.0 * (i + j) as f64);
            }
        }
    }

    #[test]
    fn forward_cumulative_sum() {
        let mut a = Storage::from_fn([2, 2, 5], 0, |_, _, _| 1.0);
        let mut b = Storage::with_halo([2, 2, 5], 0);
        run_stencil(
            "stencil cum(a: Field<f64>, b: Field<f64>) {\n\
               with computation(FORWARD) {\n\
                 interval(0, 1) { b = a; }\n\
                 interval(1, None) { b = b[0,0,-1] + a; }\n\
               }\n\
             }",
            "cum",
            &mut [("a", &mut a), ("b", &mut b)],
            &[],
            [2, 2, 5],
        );
        for k in 0..5 {
            assert_eq!(b.get(0, 0, k), (k + 1) as f64);
            assert_eq!(b.get(1, 1, k), (k + 1) as f64);
        }
    }

    #[test]
    fn backward_cumulative_sum() {
        let mut a = Storage::from_fn([2, 2, 5], 0, |_, _, _| 1.0);
        let mut b = Storage::with_halo([2, 2, 5], 0);
        run_stencil(
            "stencil cum(a: Field<f64>, b: Field<f64>) {\n\
               with computation(BACKWARD) {\n\
                 interval(-1, None) { b = a; }\n\
                 interval(0, -1) { b = b[0,0,1] + a; }\n\
               }\n\
             }",
            "cum",
            &mut [("a", &mut a), ("b", &mut b)],
            &[],
            [2, 2, 5],
        );
        for k in 0..5 {
            assert_eq!(b.get(0, 0, k), (5 - k) as f64);
        }
    }

    #[test]
    fn ternary_flux_limiter() {
        let mut a = Storage::from_fn([4, 1, 1], 0, |i, _, _| i as f64 - 1.5);
        let mut b = Storage::with_halo([4, 1, 1], 0);
        run_stencil(
            "stencil s(a: Field<f64>, b: Field<f64>; lim: f64) {\n\
               with computation(PARALLEL), interval(...) { b = a > lim ? a : lim; }\n\
             }",
            "s",
            &mut [("a", &mut a), ("b", &mut b)],
            &[("lim", 0.0)],
            [4, 1, 1],
        );
        assert_eq!(b.get(0, 0, 0), 0.0);
        assert_eq!(b.get(1, 0, 0), 0.0);
        assert_eq!(b.get(2, 0, 0), 0.5);
        assert_eq!(b.get(3, 0, 0), 1.5);
    }

    #[test]
    fn if_else_semantics() {
        let mut a = Storage::from_fn([4, 1, 1], 0, |i, _, _| i as f64 - 1.5);
        let mut b = Storage::with_halo([4, 1, 1], 0);
        run_stencil(
            "stencil s(a: Field<f64>, b: Field<f64>) {\n\
               with computation(PARALLEL), interval(...) {\n\
                 if a > 0.0 { b = 1.0; } else { b = -1.0; }\n\
               }\n\
             }",
            "s",
            &mut [("a", &mut a), ("b", &mut b)],
            &[],
            [4, 1, 1],
        );
        assert_eq!(b.get(0, 0, 0), -1.0);
        assert_eq!(b.get(3, 0, 0), 1.0);
    }

    #[test]
    fn interval_split_specializes_levels() {
        let mut a = Storage::from_fn([1, 1, 4], 0, |_, _, _| 1.0);
        let mut b = Storage::with_halo([1, 1, 4], 0);
        run_stencil(
            "stencil s(a: Field<f64>, b: Field<f64>) {\n\
               with computation(PARALLEL) {\n\
                 interval(0, 1) { b = a * 10.0; }\n\
                 interval(1, -1) { b = a * 20.0; }\n\
                 interval(-1, None) { b = a * 30.0; }\n\
               }\n\
             }",
            "s",
            &mut [("a", &mut a), ("b", &mut b)],
            &[],
            [1, 1, 4],
        );
        assert_eq!(b.get(0, 0, 0), 10.0);
        assert_eq!(b.get(0, 0, 1), 20.0);
        assert_eq!(b.get(0, 0, 2), 20.0);
        assert_eq!(b.get(0, 0, 3), 30.0);
    }

    #[test]
    fn optimized_ir_is_reference_equal() {
        // The debug backend must execute a fully optimized IR (fused
        // groups, demoted temporaries) with unchanged reference semantics.
        let src = "stencil s(a: Field<f64>, out: Field<f64>) {\n\
                     with computation(PARALLEL), interval(...) {\n\
                       t = a[-1,0,0] + a[1,0,0];\n\
                       out = t[0,-1,0] + t[0,1,0];\n\
                     }\n\
                   }";
        let ir0 = compile_source(src, "s", &BTreeMap::new()).unwrap();
        let ir2 = crate::analysis::compile_source_opt(
            src,
            "s",
            &BTreeMap::new(),
            &crate::opt::OptConfig::default(),
        )
        .unwrap();
        let mk = || Storage::from_fn_extended([4, 4, 2], 2, |i, j, k| {
            (i * 7 + j * 3 + k) as f64 * 0.25
        });
        let run_one = |ir: &crate::ir::implir::StencilIr| {
            let mut a = mk();
            let mut out = Storage::with_horizontal_halo([4, 4, 2], 0);
            let mut refs: Vec<(&str, &mut Storage)> =
                vec![("a", &mut a), ("out", &mut out)];
            DebugBackend::new()
                .run(ir, &mut StencilArgs { fields: &mut refs, scalars: &[], domain: [4, 4, 2] })
                .unwrap();
            out
        };
        let o0 = run_one(&ir0);
        let o2 = run_one(&ir2);
        assert_eq!(o0.max_abs_diff(&o2), 0.0);
    }

    #[test]
    fn parallel_statement_order_domain_wide() {
        // Second statement reads the temp at an offset — requires the first
        // statement to have completed over the whole (extended) domain.
        let mut a = Storage::from_fn_extended([4, 1, 1], 1, |i, _, _| i as f64);
        let mut out = Storage::with_horizontal_halo([4, 1, 1], 0);
        run_stencil(
            "stencil s(a: Field<f64>, out: Field<f64>) {\n\
               with computation(PARALLEL), interval(...) {\n\
                 t = a * 2.0;\n\
                 out = t[1,0,0] - t[-1,0,0];\n\
               }\n\
             }",
            "s",
            &mut [("a", &mut a), ("out", &mut out)],
            &[],
            [4, 1, 1],
        );
        for i in 0..4 {
            assert_eq!(out.get(i, 0, 0), 4.0);
        }
    }
}
