//! Slot-resolved stencil programs and the execution environment shared by
//! the interpreting backends (`debug`, `vector`).

use super::cexpr::CExpr;
use crate::dsl::ast::{DType, Interval, IterationPolicy};
use crate::ir::implir::{Extent, StencilIr, StorageClass};
use crate::storage::{Element, Storage, StorageInfo, StorageView};
use anyhow::{bail, Result};
use std::collections::HashMap;

/// Per-slot metadata. Parameters occupy the first `num_params` slots in
/// declaration order; temporaries follow.
#[derive(Debug, Clone)]
pub struct SlotInfo {
    pub name: String,
    pub is_temp: bool,
    /// Run-time storage class: parameters and undemoted temporaries are
    /// [`StorageClass::Field3D`]; demoted temporaries are served from
    /// backend-local buffers (register/plane/ring) instead of storages.
    /// The `debug` reference interpreter materializes everything.
    pub storage: StorageClass,
    /// Allocation extent for temporaries; halo requirement for params.
    pub extent: Extent,
    /// For [`StorageClass::Ring`] slots: how many past level planes the
    /// ring must retain (max absolute vertical read offset, at least 1).
    pub ring_depth: i32,
}

impl SlotInfo {
    /// Whether optimizing backends may serve this slot from local buffers.
    #[inline]
    pub fn demoted(&self) -> bool {
        self.storage != StorageClass::Field3D
    }
}

/// A stage with its expression compiled to slots.
#[derive(Debug, Clone)]
pub struct CStage {
    pub target: usize,
    pub expr: CExpr,
    pub interval: Interval,
    pub extent: Extent,
    /// Fusion-group id from the optimizer (scopes demoted-buffer lifetime).
    pub fusion_group: usize,
}

#[derive(Debug, Clone)]
pub struct CMultistage {
    pub policy: IterationPolicy,
    pub stages: Vec<CStage>,
}

/// A fully slot-resolved program, independent of any particular domain.
#[derive(Debug, Clone)]
pub struct Program {
    pub slots: Vec<SlotInfo>,
    pub num_params: usize,
    pub scalar_names: Vec<String>,
    pub multistages: Vec<CMultistage>,
    /// Uniform element dtype of every field, temporary and scalar
    /// (`analysis::check_dtypes` rejects mixed declarations). Backends
    /// dispatch on this once per run to pick the `f64` or `f32`
    /// monomorphization of their evaluator.
    pub dtype: DType,
}

impl Program {
    pub fn compile(ir: &StencilIr) -> Result<Program> {
        let mut slots = Vec::new();
        let mut slot_index = HashMap::new();
        for f in &ir.fields {
            slot_index.insert(f.name.clone(), slots.len());
            slots.push(SlotInfo {
                name: f.name.clone(),
                is_temp: false,
                storage: StorageClass::Field3D,
                extent: f.extent,
                ring_depth: 0,
            });
        }
        let num_params = slots.len();
        for t in &ir.temporaries {
            slot_index.insert(t.name.clone(), slots.len());
            slots.push(SlotInfo {
                name: t.name.clone(),
                is_temp: true,
                storage: t.storage,
                extent: t.extent,
                ring_depth: t.ring_depth,
            });
        }
        let scalar_names: Vec<String> = ir.scalars.iter().map(|s| s.name.clone()).collect();
        let scalar_index: HashMap<String, usize> = scalar_names
            .iter()
            .enumerate()
            .map(|(i, n)| (n.clone(), i))
            .collect();

        let mut multistages = Vec::new();
        for ms in &ir.multistages {
            let mut stages = Vec::new();
            for st in &ms.stages {
                let target = *slot_index
                    .get(&st.stmt.target)
                    .ok_or_else(|| anyhow::anyhow!("unbound target `{}`", st.stmt.target))?;
                let expr = CExpr::compile(&st.stmt.value, &slot_index, &scalar_index)?;
                stages.push(CStage {
                    target,
                    expr,
                    interval: st.interval,
                    extent: st.extent,
                    fusion_group: st.fusion_group,
                });
            }
            multistages.push(CMultistage { policy: ms.policy, stages });
        }
        Ok(Program { slots, num_params, scalar_names, multistages, dtype: ir.dtype() })
    }
}

/// Execution environment: owns every field slot for the duration of a run.
/// Parameter storages are moved in (swapped) so evaluation can read any
/// slot through `&self` while writes go through `&mut self`.
pub struct Env {
    pub storages: Vec<Storage>,
    pub scalars: Vec<f64>,
    pub domain: [usize; 3],
}

impl Env {
    /// Build an environment: takes the caller's parameter storages (swapped
    /// out of the slice) and allocates temporaries sized for `domain`.
    /// Demoted temporaries are materialized too — the reference-semantics
    /// path used by the `debug` backend.
    pub fn build(
        program: &Program,
        fields: &mut [(&str, &mut Storage)],
        scalars: &[(&str, f64)],
        domain: [usize; 3],
    ) -> Result<Env> {
        Env::build_with(program, fields, scalars, domain, true)
    }

    /// Like [`Env::build`], but with `materialize_demoted = false` demoted
    /// temporaries (any non-[`StorageClass::Field3D`] class) get a
    /// zero-size placeholder storage: the backend promises to serve every
    /// access to them from its own local buffers.
    pub fn build_with(
        program: &Program,
        fields: &mut [(&str, &mut Storage)],
        scalars: &[(&str, f64)],
        domain: [usize; 3],
        materialize_demoted: bool,
    ) -> Result<Env> {
        let mut storages = Vec::with_capacity(program.slots.len());
        for (idx, slot) in program.slots.iter().enumerate() {
            if idx < program.num_params {
                let pos = fields
                    .iter()
                    .position(|(n, _)| *n == slot.name)
                    .ok_or_else(|| anyhow::anyhow!("missing field argument `{}`", slot.name))?;
                let taken = std::mem::replace(
                    fields[pos].1,
                    Storage::zeros(StorageInfo::new([0, 0, 0], [(0, 0); 3])),
                );
                storages.push(taken);
            } else if slot.demoted() && !materialize_demoted {
                storages.push(Storage::zeros(StorageInfo::new([0, 0, 0], [(0, 0); 3])));
            } else {
                // Temporary: allocate with its analysis extent as halo, at
                // the program's element dtype.
                let e = slot.extent;
                let info = StorageInfo::new(
                    domain,
                    [
                        ((-e.i.0) as usize, e.i.1 as usize),
                        ((-e.j.0) as usize, e.j.1 as usize),
                        ((-e.k.0) as usize, e.k.1 as usize),
                    ],
                )
                .with_dtype(program.dtype);
                storages.push(Storage::zeros(info));
            }
        }
        let mut scalar_vals = Vec::with_capacity(program.scalar_names.len());
        for name in &program.scalar_names {
            let v = scalars
                .iter()
                .find(|(n, _)| n == name)
                .map(|(_, v)| *v)
                .ok_or_else(|| anyhow::anyhow!("missing scalar argument `{name}`"))?;
            scalar_vals.push(v);
        }
        Ok(Env { storages, scalars: scalar_vals, domain })
    }

    /// Return parameter storages to the caller (inverse of `build`).
    pub fn restore(mut self, program: &Program, fields: &mut [(&str, &mut Storage)]) {
        for idx in (0..program.num_params).rev() {
            let name = &program.slots[idx].name;
            let pos = fields
                .iter()
                .position(|(n, _)| n == name)
                .expect("field disappeared during run");
            let storage = std::mem::replace(
                &mut self.storages[idx],
                Storage::zeros(StorageInfo::new([0, 0, 0], [(0, 0); 3])),
            );
            *fields[pos].1 = storage;
        }
    }

    /// Resolve a stage's vertical range against the domain, clamped.
    pub fn krange(&self, interval: &Interval) -> (i64, i64) {
        let (lo, hi) = interval.resolve(self.domain[2]);
        (lo.max(0), hi.min(self.domain[2] as i64))
    }

    /// A typed window over the whole environment: one [`StorageView`] per
    /// slot plus the scalar parameters converted once (round-to-nearest)
    /// to `T`. This is the structure every evaluator executes against —
    /// serial paths and sharded slabs alike — so there is exactly one
    /// generic evaluator per backend. Zero-size placeholder slots
    /// (non-materialized demoted temporaries) become inert empty views.
    pub fn view<T: Element>(&mut self) -> EnvView<'_, T> {
        EnvView {
            storages: self.storages.iter_mut().map(|s| s.view::<T>()).collect(),
            scalars: self.scalars.iter().map(|&v| T::from_f64(v)).collect(),
            domain: self.domain,
        }
    }
}

/// Typed, shareable execution window over an [`Env`] (see [`Env::view`]).
/// Cheap to clone per worker slab; access soundness follows the
/// [`StorageView`] disjoint-write contract.
pub struct EnvView<'a, T: Element> {
    pub storages: Vec<StorageView<'a, T>>,
    /// Scalar parameters at native precision (converted once from `f64`).
    pub scalars: Vec<T>,
    pub domain: [usize; 3],
}

impl<T: Element> Clone for EnvView<'_, T> {
    fn clone(&self) -> Self {
        EnvView {
            storages: self.storages.clone(),
            scalars: self.scalars.clone(),
            domain: self.domain,
        }
    }
}

impl<T: Element> EnvView<'_, T> {
    /// Resolve a stage's vertical range against the domain, clamped.
    pub fn krange(&self, interval: &Interval) -> (i64, i64) {
        let (lo, hi) = interval.resolve(self.domain[2]);
        (lo.max(0), hi.min(self.domain[2] as i64))
    }
}

/// Validate one parameter storage's *geometry* (shape covers the domain,
/// halo covers the required extent, dtype matches) against its declaration.
/// Works from a [`StorageInfo`] alone so the bind-time validation of
/// [`crate::coordinator::BoundInvocation`] shares this exact code path.
pub fn validate_field(
    f: &crate::ir::implir::FieldInfo,
    info: &StorageInfo,
    domain: [usize; 3],
) -> Result<()> {
    let shape = info.shape;
    for ax in 0..3 {
        if shape[ax] < domain[ax] {
            bail!(
                "field `{}` shape {:?} smaller than domain {:?}",
                f.name,
                shape,
                domain
            );
        }
    }
    let halo = info.halo;
    let need = f.extent;
    let have = [
        (halo[0].0 as i32, halo[0].1 as i32),
        (halo[1].0 as i32, halo[1].1 as i32),
        (halo[2].0 as i32, halo[2].1 as i32),
    ];
    let needs = [
        ((-need.i.0), need.i.1),
        ((-need.j.0), need.j.1),
        ((-need.k.0), need.k.1),
    ];
    for ax in 0..3 {
        if have[ax].0 < needs[ax].0 || have[ax].1 < needs[ax].1 {
            bail!(
                "field `{}` halo {:?} insufficient for required extent {} (axis {})",
                f.name,
                halo,
                need,
                ax
            );
        }
    }
    if info.dtype != f.dtype {
        bail!(
            "field `{}` dtype {} does not match declared {}",
            f.name,
            info.dtype,
            f.dtype
        );
    }
    Ok(())
}

/// Validate that each parameter storage provides the halo the IR requires
/// and covers the domain — the run-time checks responsible for the paper's
/// Fig. 3 constant per-call overhead (solid vs dashed lines).
pub fn validate_args(
    ir: &StencilIr,
    fields: &[(&str, &mut Storage)],
    scalars: &[(&str, f64)],
    domain: [usize; 3],
) -> Result<()> {
    for f in &ir.fields {
        let (_, storage) = fields
            .iter()
            .find(|(n, _)| *n == f.name)
            .ok_or_else(|| anyhow::anyhow!("missing field argument `{}`", f.name))?;
        validate_field(f, &storage.info, domain)?;
    }
    for s in &ir.scalars {
        if !scalars.iter().any(|(n, _)| *n == s.name) {
            bail!("missing scalar argument `{}`", s.name);
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::compile_source;
    use std::collections::BTreeMap;

    const SRC: &str = "
        stencil sm(a: Field<f64>, b: Field<f64>; w: f64) {
            with computation(PARALLEL), interval(...) {
                t = (a[-1,0,0] + a[1,0,0]) * 0.5;
                b = t * w;
            }
        }";

    fn ir() -> StencilIr {
        compile_source(SRC, "sm", &BTreeMap::new()).unwrap()
    }

    #[test]
    fn program_compiles_slots() {
        let p = Program::compile(&ir()).unwrap();
        assert_eq!(p.num_params, 2);
        assert_eq!(p.slots.len(), 3);
        assert!(p.slots[2].is_temp);
        assert_eq!(p.scalar_names, vec!["w".to_string()]);
        assert_eq!(p.multistages.len(), 1);
        assert_eq!(p.multistages[0].stages.len(), 2);
    }

    #[test]
    fn env_build_restore_roundtrip() {
        let ir = ir();
        let p = Program::compile(&ir).unwrap();
        let mut a = Storage::with_horizontal_halo([4, 4, 2], 1);
        a.set(0, 0, 0, 3.0);
        let mut b = Storage::with_horizontal_halo([4, 4, 2], 1);
        let mut fields: Vec<(&str, &mut Storage)> =
            vec![("a", &mut a), ("b", &mut b)];
        let env = Env::build(&p, &mut fields, &[("w", 2.0)], [4, 4, 2]).unwrap();
        assert_eq!(env.storages.len(), 3);
        assert_eq!(env.scalars, vec![2.0]);
        env.restore(&p, &mut fields);
        assert_eq!(a.get(0, 0, 0), 3.0); // storage returned intact
    }

    #[test]
    fn validate_rejects_insufficient_halo() {
        let ir = ir();
        let mut a = Storage::with_horizontal_halo([4, 4, 2], 0); // needs 1
        let mut b = Storage::with_horizontal_halo([4, 4, 2], 0);
        let fields: Vec<(&str, &mut Storage)> = vec![("a", &mut a), ("b", &mut b)];
        let r = validate_args(&ir, &fields, &[("w", 1.0)], [4, 4, 2]);
        assert!(r.is_err());
    }

    #[test]
    fn validate_rejects_missing_scalar() {
        let ir = ir();
        let mut a = Storage::with_horizontal_halo([4, 4, 2], 1);
        let mut b = Storage::with_horizontal_halo([4, 4, 2], 1);
        let fields: Vec<(&str, &mut Storage)> = vec![("a", &mut a), ("b", &mut b)];
        assert!(validate_args(&ir, &fields, &[], [4, 4, 2]).is_err());
    }

    #[test]
    fn validate_rejects_small_storage() {
        let ir = ir();
        let mut a = Storage::with_horizontal_halo([2, 4, 2], 1);
        let mut b = Storage::with_horizontal_halo([4, 4, 2], 1);
        let fields: Vec<(&str, &mut Storage)> = vec![("a", &mut a), ("b", &mut b)];
        assert!(validate_args(&ir, &fields, &[("w", 1.0)], [4, 4, 2]).is_err());
    }

    #[test]
    fn krange_clamps() {
        let env = Env { storages: vec![], scalars: vec![], domain: [4, 4, 8] };
        let (lo, hi) = env.krange(&Interval::full());
        assert_eq!((lo, hi), (0, 8));
    }
}
