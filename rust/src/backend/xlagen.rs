//! The `xla` backend: native code generation from the implementation IR.
//!
//! The analog of GT4Py's `gtx86`/`gtmc` backends (§2.3), which generate C++
//! from the implementation IR and JIT-compile it. Here the backend emits an
//! XLA computation with `XlaBuilder` — every stage becomes fused tensor
//! arithmetic over exactly the sub-box the extent analysis derived — and
//! JIT-compiles it on the PJRT CPU client. Executables are cached per
//! `(stencil fingerprint, domain)`, reproducing the paper's JIT-with-
//! caching workflow (§2.3).
//!
//! Representation: each field lives as a value tensor covering its *box*
//! (compute domain + analysis extent). PARALLEL stages evaluate 3-D regions
//! and splice them into the box; FORWARD/BACKWARD multistages unroll the
//! vertical loop, carrying one plane value per level so the sequential
//! dependence chain is explicit in the graph.

use super::{Backend, StencilArgs};
use crate::dsl::ast::{BinOp, Builtin, Expr, IterationPolicy, UnOp};
use crate::ir::implir::{Extent, Intent, StencilIr, StorageClass};
use crate::runtime::{Arg, Executable, Runtime};
use anyhow::{anyhow, bail, Result};
use std::collections::{HashMap, HashSet};
use std::sync::{Arc, Mutex};

/// Geometry of a field's value tensor: `lo` is the signed offset of the
/// tensor's first element in domain coordinates, `dims` its shape.
#[derive(Debug, Clone, Copy)]
struct BoxGeom {
    lo: [i64; 3],
    dims: [usize; 3],
}

impl BoxGeom {
    fn for_extent(e: Extent, domain: [usize; 3]) -> BoxGeom {
        BoxGeom {
            lo: [e.i.0 as i64, e.j.0 as i64, e.k.0 as i64],
            dims: [
                (domain[0] as i64 + (e.i.1 - e.i.0) as i64) as usize,
                (domain[1] as i64 + (e.j.1 - e.j.0) as i64) as usize,
                (domain[2] as i64 + (e.k.1 - e.k.0) as i64) as usize,
            ],
        }
    }

    fn idims(&self) -> [i64; 3] {
        [self.dims[0] as i64, self.dims[1] as i64, self.dims[2] as i64]
    }
}

/// A region of the iteration space a stage computes over.
#[derive(Debug, Clone, Copy)]
struct Region {
    lo: [i64; 3],
    dims: [usize; 3],
}

/// Per-field graph state during codegen.
enum FieldVal {
    /// 3-D tensor over the field's box.
    Whole(xla::XlaOp),
    /// One plane op per box level (inside a sequential multistage).
    Planes(Vec<xla::XlaOp>),
}

struct GraphCtx<'a> {
    builder: &'a xla::XlaBuilder,
    geoms: HashMap<String, BoxGeom>,
    values: HashMap<String, FieldVal>,
    scalar_ops: HashMap<String, xla::XlaOp>,
    /// Demoted temporaries: no zero-initialized box is materialized for
    /// them — the graph carries fewer intermediate buffers, and reads
    /// before the first write lower to a zero broadcast.
    demoted: HashSet<String>,
}

impl GraphCtx<'_> {
    /// Evaluate an IR expression over `region`, returning an op of shape
    /// `region.dims` (f64) or a predicate of the same shape.
    fn eval(&self, e: &Expr, region: Region) -> Result<xla::XlaOp> {
        match e {
            Expr::Float(v) => Ok(self.builder.c0(*v).map_err(xerr)?),
            Expr::Bool(b) => {
                let one = self.builder.c0(if *b { 1.0f64 } else { 0.0 }).map_err(xerr)?;
                let half = self.builder.c0(0.5f64).map_err(xerr)?;
                Ok(one.gt(&half).map_err(xerr)?)
            }
            Expr::Scalar(name) => Ok(self
                .scalar_ops
                .get(name)
                .ok_or_else(|| anyhow!("unbound scalar `{name}`"))?
                .clone()),
            Expr::Field { name, offset, .. } => self.field_slice(name, *offset, region),
            Expr::Unary { op, operand } => {
                let v = self.eval(operand, region)?;
                Ok(match op {
                    UnOp::Neg => v.neg().map_err(xerr)?,
                    UnOp::Not => v.not().map_err(xerr)?,
                })
            }
            Expr::Binary { op, lhs, rhs } => {
                let a = self.eval(lhs, region)?;
                let b = self.eval(rhs, region)?;
                Ok(match op {
                    BinOp::Add => a.add_(&b).map_err(xerr)?,
                    BinOp::Sub => a.sub_(&b).map_err(xerr)?,
                    BinOp::Mul => a.mul_(&b).map_err(xerr)?,
                    BinOp::Div => a.div_(&b).map_err(xerr)?,
                    BinOp::Mod => a.rem_(&b).map_err(xerr)?,
                    BinOp::Lt => a.lt(&b).map_err(xerr)?,
                    BinOp::Le => a.le(&b).map_err(xerr)?,
                    BinOp::Gt => a.gt(&b).map_err(xerr)?,
                    BinOp::Ge => a.ge(&b).map_err(xerr)?,
                    BinOp::Eq => a.eq(&b).map_err(xerr)?,
                    BinOp::Ne => a.ne(&b).map_err(xerr)?,
                    BinOp::And => a.and(&b).map_err(xerr)?,
                    BinOp::Or => a.or(&b).map_err(xerr)?,
                })
            }
            Expr::Ternary { cond, then_e, else_e } => {
                let c = self.eval(cond, region)?;
                let t = self.eval(then_e, region)?;
                let f = self.eval(else_e, region)?;
                // Scalar branches must be broadcast for `select`.
                let t = self.broadcast_like(&t, &c, region)?;
                let f = self.broadcast_like(&f, &c, region)?;
                Ok(c.select(&t, &f).map_err(xerr)?)
            }
            Expr::Builtin { func, args } => {
                let a = self.eval(&args[0], region)?;
                Ok(match func {
                    Builtin::Abs => a.abs().map_err(xerr)?,
                    Builtin::Sqrt => a.sqrt().map_err(xerr)?,
                    Builtin::Exp => a.exp().map_err(xerr)?,
                    Builtin::Log => a.log().map_err(xerr)?,
                    Builtin::Floor => a.floor().map_err(xerr)?,
                    Builtin::Ceil => a.ceil().map_err(xerr)?,
                    Builtin::Sin => a.sin().map_err(xerr)?,
                    Builtin::Cos => a.cos().map_err(xerr)?,
                    Builtin::Tanh => a.tanh().map_err(xerr)?,
                    Builtin::Min => {
                        let b = self.eval(&args[1], region)?;
                        a.min(&b).map_err(xerr)?
                    }
                    Builtin::Max => {
                        let b = self.eval(&args[1], region)?;
                        a.max(&b).map_err(xerr)?
                    }
                    Builtin::Pow => {
                        let b = self.eval(&args[1], region)?;
                        a.pow(&b).map_err(xerr)?
                    }
                })
            }
            Expr::Name(n, _) | Expr::External(n, _) => {
                bail!("unresolved symbol `{n}` reached xla codegen")
            }
            Expr::Call { name, .. } => bail!("unresolved call `{name}` reached xla codegen"),
        }
    }

    /// If `v` is rank-0 while `like` is rank-3, broadcast it.
    fn broadcast_like(
        &self,
        v: &xla::XlaOp,
        like: &xla::XlaOp,
        _region: Region,
    ) -> Result<xla::XlaOp> {
        let vr = v.rank().map_err(xerr)?;
        let lr = like.rank().map_err(xerr)?;
        if vr == 0 && lr > 0 {
            let dims = like.dims().map_err(xerr)?;
            let dims: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
            Ok(v.broadcast(&dims).map_err(xerr)?)
        } else {
            Ok(v.clone())
        }
    }

    /// Slice the value of `name` at `offset` aligned to `region`.
    fn field_slice(&self, name: &str, offset: [i32; 3], region: Region) -> Result<xla::XlaOp> {
        let geom = self
            .geoms
            .get(name)
            .ok_or_else(|| anyhow!("unbound field `{name}`"))?;
        let start = |d: usize, off: i32| region.lo[d] + off as i64 - geom.lo[d];
        match self.values.get(name) {
            Some(FieldVal::Whole(op)) => {
                let mut v = op.clone();
                for d in 0..3 {
                    let s = start(d, offset[d as usize]);
                    let e = s + region.dims[d] as i64;
                    if s < 0 || e > geom.dims[d] as i64 {
                        bail!(
                            "extent analysis violated: field `{name}` sliced [{s},{e}) on axis {d} of box {:?}",
                            geom.dims
                        );
                    }
                    if s != 0 || e != geom.dims[d] as i64 {
                        v = v.slice_in_dim(s, e, 1, d as i64).map_err(xerr)?;
                    }
                }
                Ok(v)
            }
            Some(FieldVal::Planes(planes)) => {
                if region.dims[2] != 1 {
                    bail!("plane access to `{name}` with non-plane region");
                }
                let kidx = start(2, offset[2]);
                if kidx < 0 || kidx as usize >= planes.len() {
                    bail!("plane index {kidx} out of range for `{name}`");
                }
                let mut v = planes[kidx as usize].clone();
                for d in 0..2 {
                    let s = start(d, offset[d]);
                    let e = s + region.dims[d] as i64;
                    if s != 0 || e != geom.dims[d] as i64 {
                        v = v.slice_in_dim(s, e, 1, d as i64).map_err(xerr)?;
                    }
                }
                Ok(v)
            }
            None => {
                if self.demoted.contains(name) {
                    // Unwritten demoted temporary: zeros, like the
                    // zero-initialized field it replaces.
                    let zero = self.builder.c0(0.0f64).map_err(xerr)?;
                    let dims = [
                        region.dims[0] as i64,
                        region.dims[1] as i64,
                        region.dims[2] as i64,
                    ];
                    Ok(zero.broadcast(&dims).map_err(xerr)?)
                } else {
                    bail!("field `{name}` has no value yet")
                }
            }
        }
    }

    /// Broadcast a rank-0 stage value (e.g. `out = s1 * 2.0`) to the
    /// region shape so it can be spliced into the target box.
    fn broadcast_to_region(&self, v: xla::XlaOp, region: Region) -> Result<xla::XlaOp> {
        if v.rank().map_err(xerr)? == 0 {
            let dims = [
                region.dims[0] as i64,
                region.dims[1] as i64,
                region.dims[2] as i64,
            ];
            Ok(v.broadcast(&dims).map_err(xerr)?)
        } else {
            Ok(v)
        }
    }

    /// Splice `value` (shape `region.dims`) into `target`'s box tensor.
    fn update_whole(&mut self, target: &str, value: xla::XlaOp, region: Region) -> Result<()> {
        let geom = self.geoms[target];
        let value = self.as_f64(value, region)?;
        let value = self.broadcast_to_region(value, region)?;
        let current = match self.values.get(target) {
            Some(FieldVal::Whole(op)) => Some(op.clone()),
            Some(FieldVal::Planes(_)) => bail!("whole-update on plane value `{target}`"),
            None => None,
        };
        let start = [
            region.lo[0] - geom.lo[0],
            region.lo[1] - geom.lo[1],
            region.lo[2] - geom.lo[2],
        ];
        let covers_box = (0..3).all(|d| start[d] == 0 && region.dims[d] == geom.dims[d]);
        let new_val = if covers_box {
            value
        } else {
            let cur = match current {
                Some(op) => op,
                // Partial first write to a demoted temporary: splice into
                // a zero box created on demand (parameters and undemoted
                // temporaries always have a value by construction).
                None if self.demoted.contains(target) => {
                    let zero = self.builder.c0(0.0f64).map_err(xerr)?;
                    zero.broadcast(&geom.idims()).map_err(xerr)?
                }
                None => bail!("partial write to uninitialized `{target}`"),
            };
            insert_box(&cur, &value, start, region.dims, geom.dims)?
        };
        self.values.insert(target.to_string(), FieldVal::Whole(new_val));
        Ok(())
    }

    /// Splice a plane value into `target`'s plane list at box level `kidx`.
    fn update_plane(
        &mut self,
        target: &str,
        value: xla::XlaOp,
        region: Region,
        kidx: usize,
    ) -> Result<()> {
        let geom = self.geoms[target];
        let value = self.as_f64(value, region)?;
        let value = self.broadcast_to_region(value, region)?;
        let start = [region.lo[0] - geom.lo[0], region.lo[1] - geom.lo[1], 0];
        let covers = (0..2).all(|d| start[d] == 0 && region.dims[d] == geom.dims[d]);
        let planes = match self.values.get_mut(target) {
            Some(FieldVal::Planes(p)) => p,
            _ => bail!("plane-update on non-plane value `{target}`"),
        };
        let new_plane = if covers {
            value
        } else {
            insert_box(
                &planes[kidx],
                &value,
                start,
                [region.dims[0], region.dims[1], 1],
                [geom.dims[0], geom.dims[1], 1],
            )?
        };
        planes[kidx] = new_plane;
        Ok(())
    }

    /// Predicates assigned to fields become 1.0/0.0 (mask materialization).
    fn as_f64(&self, v: xla::XlaOp, region: Region) -> Result<xla::XlaOp> {
        let ty = v.ty().map_err(xerr)?;
        if ty == xla::PrimitiveType::Pred {
            let one = self.builder.c0(1.0f64).map_err(xerr)?;
            let zero = self.builder.c0(0.0f64).map_err(xerr)?;
            let one = self.broadcast_like(&one, &v, region)?;
            let zero = self.broadcast_like(&zero, &v, region)?;
            Ok(v.select(&one, &zero).map_err(xerr)?)
        } else {
            Ok(v)
        }
    }
}

fn xerr(e: xla::Error) -> anyhow::Error {
    anyhow!("xla: {e:?}")
}

/// Splice `value` into `cur` at `start` via per-axis slice + concat
/// (XLA has no static update-slice in this crate's API surface).
fn insert_box(
    cur: &xla::XlaOp,
    value: &xla::XlaOp,
    start: [i64; 3],
    vdims: [usize; 3],
    bdims: [usize; 3],
) -> Result<xla::XlaOp> {
    fn rec(
        cur: &xla::XlaOp,
        value: &xla::XlaOp,
        start: [i64; 3],
        vdims: [usize; 3],
        bdims: [usize; 3],
        axis: usize,
    ) -> Result<xla::XlaOp> {
        if axis == 3 {
            return Ok(value.clone());
        }
        let s = start[axis];
        let e = s + vdims[axis] as i64;
        let b = bdims[axis] as i64;
        // Middle slab, restricted along `axis`, recursively spliced.
        let mid_cur = if s == 0 && e == b {
            cur.clone()
        } else {
            cur.slice_in_dim(s, e, 1, axis as i64).map_err(xerr)?
        };
        let mut nbdims = bdims;
        nbdims[axis] = vdims[axis];
        let mid = rec(&mid_cur, value, start, vdims, nbdims, axis + 1)?;
        if s == 0 && e == b {
            return Ok(mid);
        }
        let mut parts: Vec<xla::XlaOp> = Vec::new();
        if s > 0 {
            parts.push(cur.slice_in_dim(0, s, 1, axis as i64).map_err(xerr)?);
        }
        parts.push(mid);
        if e < b {
            parts.push(cur.slice_in_dim(e, b, 1, axis as i64).map_err(xerr)?);
        }
        Ok(parts[0].concat_in_dim(&parts[1..], axis as i64).map_err(xerr)?)
    }
    rec(cur, value, start, vdims, bdims, 0)
}

/// Build the XLA computation for `ir` over a concrete `domain`.
///
/// The binding's staging path is f64-only (`ElementType::F64` parameters,
/// `run_f64` transfers), so a non-f64 program is a structured error here —
/// silently widening it would break the per-dtype bitwise-honesty contract.
pub fn build_computation(ir: &StencilIr, domain: [usize; 3]) -> Result<xla::XlaComputation> {
    if ir.dtype() != crate::dsl::ast::DType::F64 {
        bail!(
            "backend `xla` supports f64 programs only; `{}` is {} \
             (use the debug/vector backends for f32)",
            ir.name,
            ir.dtype()
        );
    }
    let builder = xla::XlaBuilder::new(&format!("{}_{:016x}", ir.name, ir.fingerprint));
    let mut ctx = GraphCtx {
        builder: &builder,
        geoms: HashMap::new(),
        values: HashMap::new(),
        scalar_ops: HashMap::new(),
        demoted: ir
            .temporaries
            .iter()
            .filter(|t| t.storage != StorageClass::Field3D)
            .map(|t| t.name.clone())
            .collect(),
    };

    // Parameters: fields first (box-shaped), then scalars (rank 0).
    let mut pnum = 0i64;
    for f in &ir.fields {
        let geom = BoxGeom::for_extent(f.extent, domain);
        let op = builder
            .parameter(pnum, xla::ElementType::F64, &geom.idims(), &f.name)
            .map_err(xerr)?;
        pnum += 1;
        ctx.geoms.insert(f.name.clone(), geom);
        ctx.values.insert(f.name.clone(), FieldVal::Whole(op));
    }
    for s in &ir.scalars {
        let op = builder
            .parameter(pnum, xla::ElementType::F64, &[], &s.name)
            .map_err(xerr)?;
        pnum += 1;
        ctx.scalar_ops.insert(s.name.clone(), op);
    }
    // Temporaries: zero-initialized boxes — except demoted ones, whose
    // first write provides their value (fewer intermediate buffers in the
    // emitted graph).
    for t in &ir.temporaries {
        let geom = BoxGeom::for_extent(t.extent, domain);
        ctx.geoms.insert(t.name.clone(), geom);
        if t.storage != StorageClass::Field3D {
            continue;
        }
        let zero = builder.c0(0.0f64).map_err(xerr)?;
        let op = zero.broadcast(&geom.idims()).map_err(xerr)?;
        ctx.values.insert(t.name.clone(), FieldVal::Whole(op));
    }

    for ms in &ir.multistages {
        match ms.policy {
            IterationPolicy::Parallel => {
                for st in &ms.stages {
                    let (k0, k1) = st.interval.resolve(domain[2]);
                    let (k0, k1) = (k0.max(0), k1.min(domain[2] as i64));
                    if k0 >= k1 {
                        continue;
                    }
                    let e = st.extent;
                    let region = Region {
                        lo: [e.i.0 as i64, e.j.0 as i64, k0],
                        dims: [
                            (domain[0] as i64 + (e.i.1 - e.i.0) as i64) as usize,
                            (domain[1] as i64 + (e.j.1 - e.j.0) as i64) as usize,
                            (k1 - k0) as usize,
                        ],
                    };
                    let v = ctx.eval(&st.stmt.value, region)?;
                    ctx.update_whole(&st.stmt.target, v, region)?;
                }
            }
            IterationPolicy::Forward | IterationPolicy::Backward => {
                // Split every field written in this multistage into planes.
                let written: Vec<String> = ms
                    .stages
                    .iter()
                    .map(|s| s.stmt.target.clone())
                    .collect::<std::collections::BTreeSet<_>>()
                    .into_iter()
                    .collect();
                for w in &written {
                    let geom = ctx.geoms[w.as_str()];
                    if let Some(FieldVal::Whole(op)) = ctx.values.get(w.as_str()) {
                        let mut planes = Vec::with_capacity(geom.dims[2]);
                        for kk in 0..geom.dims[2] as i64 {
                            planes.push(op.slice_in_dim(kk, kk + 1, 1, 2).map_err(xerr)?);
                        }
                        ctx.values.insert(w.clone(), FieldVal::Planes(planes));
                    } else if !ctx.values.contains_key(w.as_str()) {
                        // Demoted temporary first written inside this
                        // sequential multistage: start from zero planes
                        // (unwritten levels read as zeros; XLA dead-code-
                        // eliminates the ones every level overwrites).
                        let zero = ctx.builder.c0(0.0f64).map_err(xerr)?;
                        let plane = zero
                            .broadcast(&[geom.dims[0] as i64, geom.dims[1] as i64, 1])
                            .map_err(xerr)?;
                        let planes = vec![plane; geom.dims[2]];
                        ctx.values.insert(w.clone(), FieldVal::Planes(planes));
                    }
                }
                let ranges: Vec<(i64, i64)> = ms
                    .stages
                    .iter()
                    .map(|s| {
                        let (a, b) = s.interval.resolve(domain[2]);
                        (a.max(0), b.min(domain[2] as i64))
                    })
                    .collect();
                let kmin = ranges.iter().map(|r| r.0).min().unwrap_or(0);
                let kmax = ranges.iter().map(|r| r.1).max().unwrap_or(0);
                let ks: Vec<i64> = if ms.policy == IterationPolicy::Forward {
                    (kmin..kmax).collect()
                } else {
                    (kmin..kmax).rev().collect()
                };
                for k in ks {
                    for (st, (a, b)) in ms.stages.iter().zip(&ranges) {
                        if k < *a || k >= *b {
                            continue;
                        }
                        let e = st.extent;
                        let region = Region {
                            lo: [e.i.0 as i64, e.j.0 as i64, k],
                            dims: [
                                (domain[0] as i64 + (e.i.1 - e.i.0) as i64) as usize,
                                (domain[1] as i64 + (e.j.1 - e.j.0) as i64) as usize,
                                1,
                            ],
                        };
                        let v = ctx.eval(&st.stmt.value, region)?;
                        let geom = ctx.geoms[st.stmt.target.as_str()];
                        let kidx = (k - geom.lo[2]) as usize;
                        ctx.update_plane(&st.stmt.target, v, region, kidx)?;
                    }
                }
                // Re-assemble plane lists into whole boxes.
                for w in &written {
                    if let Some(FieldVal::Planes(planes)) = ctx.values.remove(w.as_str()) {
                        let whole = if planes.len() == 1 {
                            planes[0].clone()
                        } else {
                            planes[0].concat_in_dim(&planes[1..], 2).map_err(xerr)?
                        };
                        ctx.values.insert(w.clone(), FieldVal::Whole(whole));
                    }
                }
            }
        }
    }

    // Outputs: domain slice of every written API field, in declaration order.
    let mut outs = Vec::new();
    for f in &ir.fields {
        if f.intent == Intent::In {
            continue;
        }
        let geom = ctx.geoms[f.name.as_str()];
        let op = match &ctx.values[f.name.as_str()] {
            FieldVal::Whole(op) => op.clone(),
            FieldVal::Planes(_) => bail!("unexpected plane value at output"),
        };
        let mut v = op;
        for d in 0..3 {
            let s = -geom.lo[d];
            let e = s + domain[d] as i64;
            if s != 0 || e != geom.dims[d] as i64 {
                v = v.slice_in_dim(s, e, 1, d as i64).map_err(xerr)?;
            }
        }
        outs.push(v);
    }
    let tuple = builder.tuple(&outs).map_err(xerr)?;
    Ok(tuple.build().map_err(xerr)?)
}

/// The backend: JIT codegen + per-(fingerprint, domain) executable cache.
///
/// All mutable state — the PJRT runtime, the executable cache and the
/// reused staging buffers — lives behind one `Mutex`, so calls through a
/// shared instance serialize on the client (the paper's JIT backends are
/// single-queue too; concurrent *dispatch* scalability is the interpreting
/// backends' job).
pub struct XlaBackend {
    inner: Mutex<XlaInner>,
}

// SAFETY: the backend's own state (cache, staging) is serialized behind
// `self.inner.lock()`, and every PJRT FFI call — client creation,
// compilation, execution — additionally funnels through the
// *process-wide* lock in `runtime::pjrt_lock`, so even two backend
// instances sharing one `Runtime` clone (e.g. via `with_runtime`) can
// never touch the client concurrently. The client handle is an `Arc`
// (atomic refcounts), no reference to the inner state escapes the
// locks, and the Rust `xla` bindings are only conservatively
// `!Send`/`!Sync` at the FFI boundary.
unsafe impl Send for XlaBackend {}
unsafe impl Sync for XlaBackend {}

struct XlaInner {
    runtime: Runtime,
    cache: HashMap<(u64, [usize; 3]), Arc<Executable>>,
    /// Reused host staging buffers (perf: avoids ~MBs of fresh allocation
    /// per call at large domains — EXPERIMENTS.md §Perf).
    staging: Vec<Vec<f64>>,
    /// Count of compilations actually performed (cache instrumentation).
    compilations: usize,
}

impl XlaBackend {
    pub fn new() -> Result<XlaBackend> {
        Ok(XlaBackend::with_runtime(Runtime::cpu()?))
    }

    /// Create sharing an existing PJRT runtime.
    pub fn with_runtime(runtime: Runtime) -> XlaBackend {
        XlaBackend {
            inner: Mutex::new(XlaInner {
                runtime,
                cache: HashMap::new(),
                staging: Vec::new(),
                compilations: 0,
            }),
        }
    }

    /// Count of compilations actually performed (cache instrumentation).
    pub fn compilations(&self) -> usize {
        self.inner.lock().unwrap().compilations
    }
}

impl XlaInner {
    // Executables are Arc'd for cheap cache hand-out; they never leave
    // the mutex (see the Send/Sync safety notes above).
    #[allow(clippy::arc_with_non_send_sync)]
    fn executable(&mut self, ir: &StencilIr, domain: [usize; 3]) -> Result<Arc<Executable>> {
        let key = (ir.fingerprint, domain);
        if let Some(e) = self.cache.get(&key) {
            return Ok(e.clone());
        }
        let comp = build_computation(ir, domain)?;
        let exe = Arc::new(self.runtime.compile(&comp)?);
        self.compilations += 1;
        self.cache.insert(key, exe.clone());
        Ok(exe)
    }

    fn run(&mut self, ir: &StencilIr, args: &mut StencilArgs) -> Result<()> {
        let domain = args.domain;
        let exe = self.executable(ir, domain)?;

        // Stage inputs: per-field required box, then scalars. Staging
        // buffers are reused across calls.
        self.staging.resize_with(ir.fields.len(), Vec::new);
        let mut dims_list: Vec<Vec<usize>> = Vec::with_capacity(ir.fields.len());
        for (buf, f) in self.staging.iter_mut().zip(&ir.fields) {
            let geom = BoxGeom::for_extent(f.extent, domain);
            let (_, storage) = args
                .fields
                .iter()
                .find(|(n, _)| *n == f.name)
                .ok_or_else(|| anyhow!("missing field argument `{}`", f.name))?;
            storage.box_write_c_order(geom.lo, geom.dims, buf);
            dims_list.push(geom.dims.to_vec());
        }
        let mut xargs: Vec<Arg> = self
            .staging
            .iter()
            .zip(&dims_list)
            .map(|(d, dims)| Arg::F64(d, dims.clone()))
            .collect();
        for s in &ir.scalars {
            let v = args
                .scalars
                .iter()
                .find(|(n, _)| *n == s.name)
                .map(|(_, v)| *v)
                .ok_or_else(|| anyhow!("missing scalar argument `{}`", s.name))?;
            xargs.push(Arg::Scalar(v));
        }

        let outputs = exe.run_f64(&xargs)?;
        let mut oi = 0;
        for f in &ir.fields {
            if f.intent == Intent::In {
                continue;
            }
            let (_, storage) = args
                .fields
                .iter_mut()
                .find(|(n, _)| *n == f.name)
                .ok_or_else(|| anyhow!("missing field argument `{}`", f.name))?;
            storage.domain_from_c_order(&outputs[oi]);
            oi += 1;
        }
        Ok(())
    }
}

impl Backend for XlaBackend {
    fn name(&self) -> &'static str {
        "xla"
    }

    fn run(&self, ir: &StencilIr, args: &mut StencilArgs) -> Result<()> {
        self.inner.lock().unwrap().run(ir, args)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::compile_source;
    use crate::backend::debug::DebugBackend;
    use crate::storage::Storage;
    use std::collections::BTreeMap;

    /// debug vs xla equivalence on pseudo-random inputs.
    fn assert_xla_matches_debug(src: &str, name: &str, domain: [usize; 3], tol: f64) {
        assert_xla_matches_debug_ir(src, name, domain, tol, None);
    }

    /// Like [`assert_xla_matches_debug`], optionally running the xla
    /// backend on a different (e.g. optimized) IR of the same stencil.
    fn assert_xla_matches_debug_ir(
        src: &str,
        name: &str,
        domain: [usize; 3],
        tol: f64,
        xla_ir: Option<&crate::ir::implir::StencilIr>,
    ) {
        let ir = compile_source(src, name, &BTreeMap::new()).unwrap();
        let halo = 3usize;
        let mut seed = 7u64;
        let mut rand = move || {
            seed = seed.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            ((seed >> 33) as f64) / (u32::MAX as f64) - 0.5
        };
        let names: Vec<String> = ir.fields.iter().map(|f| f.name.clone()).collect();
        let base: Vec<Storage> = names
            .iter()
            .map(|_| Storage::from_fn_extended(domain, halo, |_, _, _| rand()))
            .collect();
        let scalars: Vec<(&str, f64)> =
            ir.scalars.iter().map(|s| (s.name.as_str(), 0.23)).collect();

        let mut d_fields = base.clone();
        {
            let mut refs: Vec<(&str, &mut Storage)> = names
                .iter()
                .map(|n| n.as_str())
                .zip(d_fields.iter_mut())
                .collect();
            DebugBackend::new()
                .run(&ir, &mut StencilArgs { fields: &mut refs, scalars: &scalars, domain })
                .unwrap();
        }
        let mut x_fields = base.clone();
        {
            let mut refs: Vec<(&str, &mut Storage)> = names
                .iter()
                .map(|n| n.as_str())
                .zip(x_fields.iter_mut())
                .collect();
            XlaBackend::new()
                .unwrap()
                .run(
                    xla_ir.unwrap_or(&ir),
                    &mut StencilArgs { fields: &mut refs, scalars: &scalars, domain },
                )
                .unwrap();
        }
        for (n, (d, x)) in names.iter().zip(d_fields.iter().zip(&x_fields)) {
            let diff = d.max_abs_diff(x);
            assert!(diff <= tol, "field `{n}` differs by {diff}");
        }
    }

    #[test]
    fn xla_matches_debug_parallel() {
        if crate::runtime::skip_test_without_pjrt("xla_matches_debug_parallel") {
            return;
        }
        assert_xla_matches_debug(
            "function lap(p) {\n\
               return -4.0*p[0,0,0] + p[-1,0,0] + p[1,0,0] + p[0,-1,0] + p[0,1,0];\n\
             }\n\
             stencil s(a: Field<f64>, out: Field<f64>; w: f64) {\n\
               with computation(PARALLEL), interval(...) {\n\
                 t = lap(a);\n\
                 out = a + w * lap(t);\n\
               }\n\
             }",
            "s",
            [6, 5, 3],
            1e-13,
        );
    }

    #[test]
    fn xla_matches_debug_sequential() {
        if crate::runtime::skip_test_without_pjrt("xla_matches_debug_sequential") {
            return;
        }
        assert_xla_matches_debug(
            "stencil cum(a: Field<f64>, b: Field<f64>) {\n\
               with computation(FORWARD) {\n\
                 interval(0, 1) { b = a; }\n\
                 interval(1, None) { b = b[0,0,-1] * 0.5 + a; }\n\
               }\n\
               with computation(BACKWARD) {\n\
                 interval(-1, None) { a = b; }\n\
                 interval(0, -1) { a = a[0,0,1] * 0.25 + b; }\n\
               }\n\
             }",
            "cum",
            [4, 3, 6],
            1e-13,
        );
    }

    #[test]
    fn xla_matches_debug_conditionals() {
        if crate::runtime::skip_test_without_pjrt("xla_matches_debug_conditionals") {
            return;
        }
        assert_xla_matches_debug(
            "stencil s(a: Field<f64>, out: Field<f64>; lim: f64) {\n\
               with computation(PARALLEL), interval(...) {\n\
                 g = a[1,0,0] - a[-1,0,0];\n\
                 out = g * a > lim ? g : lim;\n\
                 if out > 0.0 { out = out * 2.0; } else { out = a; }\n\
               }\n\
             }",
            "s",
            [5, 5, 2],
            1e-13,
        );
    }

    #[test]
    fn xla_matches_debug_interval_split() {
        if crate::runtime::skip_test_without_pjrt("xla_matches_debug_interval_split") {
            return;
        }
        assert_xla_matches_debug(
            "stencil s(a: Field<f64>, b: Field<f64>) {\n\
               with computation(PARALLEL) {\n\
                 interval(0, 1) { b = a * 10.0; }\n\
                 interval(1, -1) { b = a * 20.0; }\n\
                 interval(-1, None) { b = a * 30.0; }\n\
               }\n\
             }",
            "s",
            [3, 3, 5],
            1e-13,
        );
    }

    #[test]
    fn xla_optimized_ir_matches_debug() {
        if crate::runtime::skip_test_without_pjrt("xla_optimized_ir_matches_debug") {
            return;
        }
        // Run xla on the fully optimized hdiff IR (fused groups, demoted
        // temporaries — no zero boxes emitted) against the pre-opt debug
        // reference.
        let ir_opt = crate::analysis::compile_source_opt(
            crate::stdlib::HDIFF_SRC,
            "hdiff",
            &BTreeMap::new(),
            &crate::opt::OptConfig::default(),
        )
        .unwrap();
        assert!(ir_opt
            .temporaries
            .iter()
            .all(|t| t.storage != StorageClass::Field3D));
        assert_xla_matches_debug_ir(
            crate::stdlib::HDIFF_SRC,
            "hdiff",
            [9, 8, 3],
            1e-13,
            Some(&ir_opt),
        );
    }

    #[test]
    fn executable_cache_hits() {
        if crate::runtime::skip_test_without_pjrt("executable_cache_hits") {
            return;
        }
        let ir = compile_source(
            "stencil c(a: Field<f64>, b: Field<f64>) {\n\
               with computation(PARALLEL), interval(...) { b = a; }\n\
             }",
            "c",
            &BTreeMap::new(),
        )
        .unwrap();
        let be = XlaBackend::new().unwrap();
        let domain = [4, 4, 2];
        for _ in 0..3 {
            let mut a = Storage::with_halo(domain, 0);
            let mut b = Storage::with_halo(domain, 0);
            let mut refs: Vec<(&str, &mut Storage)> = vec![("a", &mut a), ("b", &mut b)];
            be.run(&ir, &mut StencilArgs { fields: &mut refs, scalars: &[], domain })
                .unwrap();
        }
        assert_eq!(be.compilations(), 1);
        // new domain -> one more compilation
        let domain2 = [5, 4, 2];
        let mut a = Storage::with_halo(domain2, 0);
        let mut b = Storage::with_halo(domain2, 0);
        let mut refs: Vec<(&str, &mut Storage)> = vec![("a", &mut a), ("b", &mut b)];
        be.run(&ir, &mut StencilArgs { fields: &mut refs, scalars: &[], domain: domain2 })
            .unwrap();
        assert_eq!(be.compilations(), 2);
    }
}
