//! The specialized kernel-plan executor for the fused tape evaluator —
//! "compile the tapes for real" (paper §2.3: the generated loops, not the
//! interpreter, are where stencil DSLs earn back C++ performance).
//!
//! The interpreted fused path (`crate::backend::fused::eval_strip`) walks
//! CTape SSA one op at a time per element strip, paying a dispatch, a
//! bounds test and (for demoted locals) a map lookup per op per strip. At
//! program-compile time this module lowers each tier's tape into a
//! [`TierPlan`]:
//!
//! * **monomorphized kernels** — every [`crate::backend::cexpr::TapeOp`]
//!   becomes a [`Kernel`] with the hot opcodes (`Add`/`Sub`/`Mul`/`Div`,
//!   field loads/stores, plane-scratch accesses) split into their own
//!   variants whose lane loops are flat element-slice walks the
//!   autovectorizer provably vectorizes — and the executors themselves are
//!   generic over the element type, so an `f32` program runs full-width
//!   single-precision SIMD lanes, not widened f64 ones;
//! * **dense access tables** — per tier *invocation* every memory kernel's
//!   strides and offsets are resolved once into a [`Resolved`] base/stride
//!   record, so the inner loops never touch a `HashMap` (ring k-cache
//!   planes are the one exception: they are allocated lazily per level and
//!   keep the interpreted lookup);
//! * **interior spans** — the per-op `[i0,i1)×[j0,j1)` guards of the
//!   interpreted path are hoisted out of the loop nest: the rectangle where
//!   *every* op's bounds hold runs guard-free, fringe rows/columns run
//!   guarded prologue/epilogue strips (which use the same specialized
//!   kernels, so results never depend on the interior/fringe split);
//! * **cache-blocked tiling** — reorder-safe tiers execute their interior
//!   as j-tiles inside the i-slab (`jt` outer, `i` inner), amortizing
//!   per-op dispatch over `tile × wl` contiguous lanes and keeping the
//!   tile working set L2-resident (tile width scales with the element
//!   size, so f32 tiles cover twice the lanes of f64 at the same bytes).
//!   Tile bounds derive from the slab bounds, so tiling composes with
//!   `backend::shard` without touching the halo-plan analysis.
//!
//! **Bitwise contract.** Without fast-math the specialized executor is
//! bitwise-identical to the interpreted tape walker *of the same dtype*:
//! guarded strips mirror `eval_strip` op for op, and blocked interiors only
//! run in tiers whose ops are elementwise-independent across strips
//! ([`TierPlan::reorderable`] — no op reads memory another op of the same
//! tier writes at a horizontal offset), so traversal order cannot change
//! any element's dataflow. This is enforced by the property suite and by
//! the benches' honesty gates.
//!
//! **Fast-math.** With [`crate::opt::OptConfig::fast_math`] the lowering
//! additionally contracts single-use `Mul` feeding `Add`/`Sub` into
//! [`Kernel::MulAdd`]/[`Kernel::MulSub`], executed through
//! [`Element::mul_add_slices`] — hardware FMA where the CPU has it
//! (runtime-detected) and `a * b ± c` otherwise. One contraction changes a
//! result by at most 1 ulp of the exact rounding at that width; errors
//! compound through the tape depth, so results are validated against
//! relative-error norms (`tests/property_equivalence.rs` pins the bound),
//! never bitwise — and the bench reports fast-math as a separate column,
//! never silently substituted for the exact tier.

use super::cexpr::{apply_bin, apply_builtin1, apply_builtin2, CTape, TapeOp};
use super::fused::{copy_lanes_in, copy_lanes_out, Scratch};
use super::program::EnvView;
use super::vector::{Pool, PoolElem, Region, Rings};
use crate::dsl::ast::{BinOp, Builtin, Offset};
use crate::ir::implir::{Extent, StorageClass};
use crate::storage::Element;

/// Which executor the vector backend's fused (`--opt-level 3`) path uses.
/// A pure scheduling parameter, like [`crate::backend::shard::Sharding`]:
/// both tiers are bitwise-identical by contract and share one compiled
/// artifact (fast-math relaxation is a separate, fingerprint-salting
/// toggle).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ExecTier {
    /// Walk the CTape SSA op by op per strip (`fused::eval_strip`) — the
    /// reference the specialized executor is validated against.
    Interpreted,
    /// Execute the pre-lowered [`TierPlan`]: dense access tables,
    /// monomorphized kernels, hoisted guards, cache-blocked interiors.
    #[default]
    Specialized,
}

impl ExecTier {
    pub fn parse(s: &str) -> Option<ExecTier> {
        match s.trim() {
            "interpreted" | "interp" => Some(ExecTier::Interpreted),
            "specialized" | "spec" => Some(ExecTier::Specialized),
            _ => None,
        }
    }
}

impl std::fmt::Display for ExecTier {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ExecTier::Interpreted => write!(f, "interpreted"),
            ExecTier::Specialized => write!(f, "specialized"),
        }
    }
}

/// One monomorphized tape op. Mirrors [`TapeOp`] index for index (so the
/// shared `bounds`/`vals` tables keep working), with the hot opcodes given
/// their own variants and demoted-local accesses split by storage class at
/// lowering time (no class test in the hot loop). Constants stay `f64` in
/// the plan — they are narrowed once per strip/block via
/// [`Element::from_f64`], keeping the plan dtype-agnostic and cacheable.
#[derive(Debug, Clone, Copy, PartialEq)]
pub(crate) enum Kernel {
    Const(f64),
    Scalar(usize),
    /// Field3D load at a relative offset.
    Load { slot: usize, off: Offset },
    /// Plane/register group-scratch load.
    LoadPlane { slot: usize, off: Offset },
    /// Ring k-cache load (lazy per-level planes: stays a map lookup).
    LoadRing { slot: usize, off: Offset },
    Neg(u32),
    Not(u32),
    Add(u32, u32),
    Sub(u32, u32),
    Mul(u32, u32),
    Div(u32, u32),
    /// Fast-math only: `a * b + c` as one fused multiply-add.
    MulAdd(u32, u32, u32),
    /// Fast-math only: `a * b - c` as one fused multiply-add.
    MulSub(u32, u32, u32),
    /// Cold binary ops (comparisons, logic, mod).
    Bin(BinOp, u32, u32),
    Select(u32, u32, u32),
    Call1(Builtin, u32),
    Call2(Builtin, u32, u32),
    StoreField { slot: usize, v: u32 },
    StorePlane { slot: usize, v: u32 },
    StoreRing { slot: usize, v: u32 },
    /// A `Mul` folded into a consumer [`Kernel::MulAdd`]/[`MulSub`]
    /// (single use): its value strip is never materialized.
    Skip,
}

impl Kernel {
    /// Short class label for `repro ir --tapes`.
    pub(crate) fn name(&self) -> &'static str {
        match self {
            Kernel::Const(_) => "const",
            Kernel::Scalar(_) => "scalar",
            Kernel::Load { .. } => "load",
            Kernel::LoadPlane { .. } => "load-plane",
            Kernel::LoadRing { .. } => "load-ring",
            Kernel::Neg(_) => "neg",
            Kernel::Not(_) => "not",
            Kernel::Add(..) => "add",
            Kernel::Sub(..) => "sub",
            Kernel::Mul(..) => "mul",
            Kernel::Div(..) => "div",
            Kernel::MulAdd(..) => "fma",
            Kernel::MulSub(..) => "fms",
            Kernel::Bin(..) => "bin",
            Kernel::Select(..) => "select",
            Kernel::Call1(..) => "call1",
            Kernel::Call2(..) => "call2",
            Kernel::StoreField { .. } => "store",
            Kernel::StorePlane { .. } => "store-plane",
            Kernel::StoreRing { .. } => "store-ring",
            Kernel::Skip => "skip",
        }
    }
}

/// The compiled plan for one tier's tape: kernels index-aligned with the
/// tape ops, plus the reorder-safety verdict that gates blocked execution.
#[derive(Debug, Clone)]
pub(crate) struct TierPlan {
    pub kernels: Vec<Kernel>,
    /// Whether strips of this tier are elementwise-independent: no op
    /// loads a slot that another op of the *same* tier stores when the
    /// load has a horizontal offset (k-only offsets stay within one
    /// strip/column, where per-op ordering is preserved), and no ring ops
    /// (sequential sweeps keep the interpreted traversal). Reorderable
    /// tiers may run their interior as j-tiled blocks.
    pub reorderable: bool,
}

impl TierPlan {
    pub(crate) fn lower(tape: &CTape, classes: &[StorageClass], fast_math: bool) -> TierPlan {
        let n = tape.ops.len();
        let mut kernels: Vec<Kernel> = tape
            .ops
            .iter()
            .map(|inst| match &inst.op {
                TapeOp::Const(c) => Kernel::Const(*c),
                TapeOp::Scalar(ix) => Kernel::Scalar(*ix),
                TapeOp::Load { slot, off } => Kernel::Load { slot: *slot, off: *off },
                TapeOp::LoadLocal { slot, off } => {
                    if classes[*slot] == StorageClass::Ring {
                        Kernel::LoadRing { slot: *slot, off: *off }
                    } else {
                        Kernel::LoadPlane { slot: *slot, off: *off }
                    }
                }
                TapeOp::Neg(a) => Kernel::Neg(*a),
                TapeOp::Not(a) => Kernel::Not(*a),
                TapeOp::Bin(op, a, b) => match op {
                    BinOp::Add => Kernel::Add(*a, *b),
                    BinOp::Sub => Kernel::Sub(*a, *b),
                    BinOp::Mul => Kernel::Mul(*a, *b),
                    BinOp::Div => Kernel::Div(*a, *b),
                    _ => Kernel::Bin(*op, *a, *b),
                },
                TapeOp::Select(c, t, f) => Kernel::Select(*c, *t, *f),
                TapeOp::Call1(f, a) => Kernel::Call1(*f, *a),
                TapeOp::Call2(f, a, b) => Kernel::Call2(*f, *a, *b),
                TapeOp::StoreField { slot, v } => Kernel::StoreField { slot: *slot, v: *v },
                TapeOp::StoreLocal { slot, v } => {
                    if classes[*slot] == StorageClass::Ring {
                        Kernel::StoreRing { slot: *slot, v: *v }
                    } else {
                        Kernel::StorePlane { slot: *slot, v: *v }
                    }
                }
            })
            .collect();

        if fast_math {
            // Contract single-use Mul feeding Add/Sub into FMA kernels.
            // Use counts come from the tape (stores included), so a Mul
            // that is also stored or shared by CSE is never folded.
            let mut uses = vec![0u32; n];
            for inst in &tape.ops {
                for o in inst.op.operands().into_iter().flatten() {
                    uses[o as usize] += 1;
                }
            }
            for x in 0..n {
                let fused = match kernels[x] {
                    Kernel::Add(a, b) => {
                        if let Kernel::Mul(p, q) = kernels[a as usize] {
                            if uses[a as usize] == 1 {
                                Some((Kernel::MulAdd(p, q, b), a))
                            } else {
                                None
                            }
                        } else if let Kernel::Mul(p, q) = kernels[b as usize] {
                            // FP addition is commutative: c + m == m + c.
                            if uses[b as usize] == 1 {
                                Some((Kernel::MulAdd(p, q, a), b))
                            } else {
                                None
                            }
                        } else {
                            None
                        }
                    }
                    // Only m - c contracts; c - m would need a negated
                    // product, which is not a single FMA.
                    Kernel::Sub(a, b) => {
                        if let Kernel::Mul(p, q) = kernels[a as usize] {
                            if uses[a as usize] == 1 {
                                Some((Kernel::MulSub(p, q, b), a))
                            } else {
                                None
                            }
                        } else {
                            None
                        }
                    }
                    _ => None,
                };
                if let Some((k, skipped)) = fused {
                    kernels[x] = k;
                    kernels[skipped as usize] = Kernel::Skip;
                }
            }
        }

        // Reorder-safety: a load with a horizontal offset of a slot this
        // same tier stores would observe neighbor strips' completion
        // order; ring ops keep the interpreted sequential traversal.
        let mut stored: Vec<usize> = Vec::new();
        let mut has_ring = false;
        for inst in &tape.ops {
            match inst.op {
                TapeOp::StoreField { slot, .. } | TapeOp::StoreLocal { slot, .. } => {
                    stored.push(slot)
                }
                TapeOp::LoadLocal { slot, .. } if classes[slot] == StorageClass::Ring => {
                    has_ring = true
                }
                _ => {}
            }
        }
        let mut reorderable = !has_ring;
        if reorderable {
            for inst in &tape.ops {
                if let TapeOp::Load { slot, off } | TapeOp::LoadLocal { slot, off } = &inst.op
                {
                    if (off[0] != 0 || off[1] != 0) && stored.contains(slot) {
                        reorderable = false;
                        break;
                    }
                }
            }
        }
        TierPlan { kernels, reorderable }
    }
}

/// A memory kernel's access, resolved once per tier invocation: the flat
/// base index for the strip at `(i, j) = (0, 0)` plus the `i`/`j`/lane
/// strides. Strip base = `base + i * si + j * sj`; lanes step by `lane`.
#[derive(Debug, Clone, Copy, Default)]
pub(crate) struct Resolved {
    pub base: i64,
    pub si: i64,
    pub sj: i64,
    pub lane: i64,
    /// Plane-scratch slot with no buffer this group (never written):
    /// loads read zeros, exactly like the interpreted path.
    pub missing: bool,
}

/// Resolve every memory kernel of a tier against the live environment and
/// scratch buffers. Ring planes are lazy per level and stay dynamic.
pub(crate) fn resolve_accesses<T: Element>(
    env: &EnvView<'_, T>,
    kernels: &[Kernel],
    scratch: &Scratch<T>,
    k0: i64,
    axis: usize,
) -> Vec<Resolved> {
    let field = |slot: usize, off: Offset| -> Resolved {
        let v = env.storages[slot];
        let st = v.strides();
        Resolved {
            base: v.origin() as i64
                + off[0] as i64 * st[0] as i64
                + off[1] as i64 * st[1] as i64
                + (k0 + off[2] as i64) * st[2] as i64,
            si: st[0] as i64,
            sj: st[1] as i64,
            lane: st[axis] as i64,
            missing: false,
        }
    };
    let plane = |slot: usize, off: Offset| -> Resolved {
        match &scratch[slot] {
            None => Resolved { missing: true, ..Resolved::default() },
            Some((sr, _)) => {
                let sdj = sr.j1 - sr.j0;
                let swk = sr.wk() as i64;
                Resolved {
                    base: (off[0] as i64 - sr.i0) * sdj * swk
                        + (off[1] as i64 - sr.j0) * swk
                        + (k0 + off[2] as i64 - sr.k0),
                    si: sdj * swk,
                    sj: swk,
                    lane: if axis == 2 { 1 } else { swk },
                    missing: false,
                }
            }
        }
    };
    kernels
        .iter()
        .map(|k| match *k {
            Kernel::Load { slot, off } => field(slot, off),
            Kernel::StoreField { slot, .. } => field(slot, [0, 0, 0]),
            Kernel::LoadPlane { slot, off } => plane(slot, off),
            Kernel::StorePlane { slot, .. } => plane(slot, [0, 0, 0]),
            _ => Resolved::default(),
        })
        .collect()
}

/// Interior-span working-set target per block: `ops × tile × wl` element
/// strips should stay L2-resident (element width taken from the dtype, so
/// f32 tiers tile twice as wide in lanes).
const BLOCK_BYTES: usize = 256 * 1024;
/// Upper bound on the j-tile: past this the dispatch amortization is flat
/// and wider tiles only grow the working set.
const MAX_TILE_J: usize = 16;

/// Run one PARALLEL (`axis == 2`) tier through the specialized executor:
/// guarded strips everywhere for order-sensitive tiers, fringe strips plus
/// j-tiled interior blocks for reorderable ones. Bounds, traversal region
/// and barrier structure are exactly the interpreted path's — only the
/// per-strip work is specialized.
#[allow(clippy::too_many_arguments)]
pub(crate) fn run_tier_axis2<T: PoolElem>(
    env: &EnvView<'_, T>,
    plan: &TierPlan,
    bounds: &[[i64; 4]],
    trect: (i64, i64, i64, i64),
    wl: usize,
    k0: i64,
    alloc: &[Extent],
    scratch: &mut Scratch<T>,
    rings: &mut Rings<T>,
    pool: &mut Pool,
    vals: &mut Vec<T>,
    slab: (i64, i64),
) {
    let (ti0, ti1, tj0, tj1) = trect;
    let kernels = &plan.kernels[..];
    let resolved = resolve_accesses(env, kernels, scratch, k0, 2);
    pool.stats.tiers_specialized += 1;

    let guarded_rect = |scratch: &mut Scratch<T>,
                        rings: &mut Rings<T>,
                        pool: &mut Pool,
                        vals: &mut [T],
                        i0: i64,
                        i1: i64,
                        j0: i64,
                        j1: i64| {
        for i in i0..i1 {
            for j in j0..j1 {
                eval_strip_spec(
                    env, kernels, &resolved, bounds, vals, wl, i, j, k0, 2, alloc, scratch,
                    rings, pool, slab,
                );
            }
        }
        pool.stats.strips_guarded += ((i1 - i0).max(0) * (j1 - j0).max(0)) as u64;
    };

    if !plan.reorderable {
        guarded_rect(scratch, rings, pool, vals, ti0, ti1, tj0, tj1);
        return;
    }

    // The interior rectangle: where every op's bounds hold, so all guards
    // can be hoisted. Op regions are contained in the tier extent, so the
    // intersection is already within the tier rect; clamp defensively.
    let mut ii0 = ti0;
    let mut ii1 = ti1;
    let mut ij0 = tj0;
    let mut ij1 = tj1;
    for b in bounds {
        ii0 = ii0.max(b[0]);
        ii1 = ii1.min(b[1]);
        ij0 = ij0.max(b[2]);
        ij1 = ij1.min(b[3]);
    }
    ii0 = ii0.clamp(ti0, ti1);
    ii1 = ii1.clamp(ti0, ti1);
    ij0 = ij0.clamp(tj0, tj1);
    ij1 = ij1.clamp(tj0, tj1);
    if ii0 >= ii1 || ij0 >= ij1 {
        guarded_rect(scratch, rings, pool, vals, ti0, ti1, tj0, tj1);
        return;
    }

    // Guarded fringes: full rows above/below the interior, then the j
    // prologue/epilogue columns of the interior rows.
    guarded_rect(scratch, rings, pool, vals, ti0, ii0, tj0, tj1);
    guarded_rect(scratch, rings, pool, vals, ii1, ti1, tj0, tj1);
    guarded_rect(scratch, rings, pool, vals, ii0, ii1, tj0, ij0);
    guarded_rect(scratch, rings, pool, vals, ii0, ii1, ij1, tj1);

    // Blocked interior: j-tiles outer, i inner, so per-op dispatch is
    // amortized over `tile × wl` lanes and the i-walk reuses the tile's
    // field rows while they are still cache-resident.
    let nops = kernels.len().max(1);
    let tile =
        (BLOCK_BYTES / (nops * wl.max(1) * std::mem::size_of::<T>())).clamp(1, MAX_TILE_J);
    let bs = tile * wl;
    if vals.len() < nops * bs {
        vals.resize(nops * bs, T::ZERO);
    }
    let mut jt = ij0;
    while jt < ij1 {
        let jlen = ((ij1 - jt) as usize).min(tile);
        for i in ii0..ii1 {
            eval_block(env, kernels, &resolved, vals, wl, bs, jlen, i, jt, scratch);
        }
        pool.stats.blocks_interior += (ii1 - ii0) as u64;
        jt += jlen as i64;
    }
}

/// Evaluate one tape plan over one strip — the specialized mirror of
/// `fused::eval_strip`: identical guards, identical traversal, identical
/// per-lane arithmetic (modulo opt-in FMA kernels), with every field and
/// plane access pre-resolved.
#[allow(clippy::too_many_arguments)]
pub(crate) fn eval_strip_spec<T: PoolElem>(
    env: &EnvView<'_, T>,
    kernels: &[Kernel],
    resolved: &[Resolved],
    bounds: &[[i64; 4]],
    vals: &mut [T],
    wl: usize,
    i: i64,
    jbase: i64,
    k0: i64,
    axis: usize,
    alloc: &[Extent],
    scratch: &mut Scratch<T>,
    rings: &mut Rings<T>,
    pool: &mut Pool,
    slab: (i64, i64),
) {
    for (x, kern) in kernels.iter().enumerate() {
        if matches!(kern, Kernel::Skip) {
            continue;
        }
        let b = bounds[x];
        if i < b[0] || i >= b[1] {
            continue;
        }
        let (lo, hi): (usize, usize) = if axis == 2 {
            if jbase < b[2] || jbase >= b[3] {
                continue;
            }
            (0, wl)
        } else {
            let lo = (b[2] - jbase).max(0) as usize;
            let hi = ((b[3] - jbase).max(0) as usize).min(wl);
            if lo >= hi {
                continue;
            }
            (lo, hi)
        };
        let base = x * wl;
        let r = &resolved[x];
        match kern {
            Kernel::Const(c) => vals[base + lo..base + hi].fill(T::from_f64(*c)),
            Kernel::Scalar(ix) => {
                let v = env.scalars[*ix];
                vals[base + lo..base + hi].fill(v);
            }
            Kernel::Load { slot, .. } => {
                let sbase = r.base + i * r.si + jbase * r.sj;
                // SAFETY: in-bounds by the extent analysis; ordered before
                // conflicting writes by the tier barriers / slab model
                // (disjoint-write contract, `storage/view.rs`).
                unsafe {
                    env.storages[*slot].read_lanes(
                        (sbase + lo as i64 * r.lane) as usize,
                        r.lane as usize,
                        &mut vals[base + lo..base + hi],
                    );
                }
            }
            Kernel::LoadPlane { slot, .. } => {
                if r.missing {
                    vals[base + lo..base + hi].fill(T::ZERO);
                } else {
                    let (_, sbuf) = scratch[*slot].as_ref().expect("resolved plane buffer");
                    let sbase = r.base + i * r.si + jbase * r.sj;
                    copy_lanes_in(sbuf, sbase, r.lane, &mut vals[base + lo..base + hi], lo);
                }
            }
            Kernel::LoadRing { slot, off } => match rings.get(&(*slot, k0 + off[2] as i64)) {
                None => vals[base + lo..base + hi].fill(T::ZERO),
                Some((sr, sbuf)) => {
                    let sdj = sr.j1 - sr.j0;
                    let swk = sr.wk() as i64;
                    let sbase = ((i + off[0] as i64 - sr.i0) * sdj
                        + (jbase + off[1] as i64 - sr.j0))
                        * swk
                        + (k0 + off[2] as i64 - sr.k0);
                    let ls = if axis == 2 { 1 } else { swk };
                    copy_lanes_in(sbuf, sbase, ls, &mut vals[base + lo..base + hi], lo);
                }
            },
            Kernel::Neg(a) => {
                let (src, dst) = vals.split_at_mut(base);
                let sa = &src[*a as usize * wl + lo..*a as usize * wl + hi];
                let d = &mut dst[lo..hi];
                for n in 0..d.len() {
                    d[n] = -sa[n];
                }
            }
            Kernel::Not(a) => {
                let (src, dst) = vals.split_at_mut(base);
                let sa = &src[*a as usize * wl + lo..*a as usize * wl + hi];
                let d = &mut dst[lo..hi];
                for n in 0..d.len() {
                    d[n] = T::from_bool(!sa[n].truthy());
                }
            }
            Kernel::Add(a, b2) => {
                let (src, dst) = vals.split_at_mut(base);
                let sa = &src[*a as usize * wl + lo..*a as usize * wl + hi];
                let sb = &src[*b2 as usize * wl + lo..*b2 as usize * wl + hi];
                let d = &mut dst[lo..hi];
                for n in 0..d.len() {
                    d[n] = sa[n] + sb[n];
                }
            }
            Kernel::Sub(a, b2) => {
                let (src, dst) = vals.split_at_mut(base);
                let sa = &src[*a as usize * wl + lo..*a as usize * wl + hi];
                let sb = &src[*b2 as usize * wl + lo..*b2 as usize * wl + hi];
                let d = &mut dst[lo..hi];
                for n in 0..d.len() {
                    d[n] = sa[n] - sb[n];
                }
            }
            Kernel::Mul(a, b2) => {
                let (src, dst) = vals.split_at_mut(base);
                let sa = &src[*a as usize * wl + lo..*a as usize * wl + hi];
                let sb = &src[*b2 as usize * wl + lo..*b2 as usize * wl + hi];
                let d = &mut dst[lo..hi];
                for n in 0..d.len() {
                    d[n] = sa[n] * sb[n];
                }
            }
            Kernel::Div(a, b2) => {
                let (src, dst) = vals.split_at_mut(base);
                let sa = &src[*a as usize * wl + lo..*a as usize * wl + hi];
                let sb = &src[*b2 as usize * wl + lo..*b2 as usize * wl + hi];
                let d = &mut dst[lo..hi];
                for n in 0..d.len() {
                    d[n] = sa[n] / sb[n];
                }
            }
            Kernel::MulAdd(a, b2, c) => {
                let (src, dst) = vals.split_at_mut(base);
                let sa = &src[*a as usize * wl + lo..*a as usize * wl + hi];
                let sb = &src[*b2 as usize * wl + lo..*b2 as usize * wl + hi];
                let sc = &src[*c as usize * wl + lo..*c as usize * wl + hi];
                T::mul_add_slices(&mut dst[lo..hi], sa, sb, sc);
            }
            Kernel::MulSub(a, b2, c) => {
                let (src, dst) = vals.split_at_mut(base);
                let sa = &src[*a as usize * wl + lo..*a as usize * wl + hi];
                let sb = &src[*b2 as usize * wl + lo..*b2 as usize * wl + hi];
                let sc = &src[*c as usize * wl + lo..*c as usize * wl + hi];
                T::mul_sub_slices(&mut dst[lo..hi], sa, sb, sc);
            }
            Kernel::Bin(op, a, b2) => {
                let (src, dst) = vals.split_at_mut(base);
                let sa = &src[*a as usize * wl + lo..*a as usize * wl + hi];
                let sb = &src[*b2 as usize * wl + lo..*b2 as usize * wl + hi];
                let d = &mut dst[lo..hi];
                for n in 0..d.len() {
                    d[n] = apply_bin(*op, sa[n], sb[n]);
                }
            }
            Kernel::Select(c, t, f) => {
                let (src, dst) = vals.split_at_mut(base);
                let sc = &src[*c as usize * wl + lo..*c as usize * wl + hi];
                let st_ = &src[*t as usize * wl + lo..*t as usize * wl + hi];
                let sf = &src[*f as usize * wl + lo..*f as usize * wl + hi];
                let d = &mut dst[lo..hi];
                for n in 0..d.len() {
                    d[n] = if sc[n].truthy() { st_[n] } else { sf[n] };
                }
            }
            Kernel::Call1(fun, a) => {
                let (src, dst) = vals.split_at_mut(base);
                let sa = &src[*a as usize * wl + lo..*a as usize * wl + hi];
                let d = &mut dst[lo..hi];
                for n in 0..d.len() {
                    d[n] = apply_builtin1(*fun, sa[n]);
                }
            }
            Kernel::Call2(fun, a, b2) => {
                let (src, dst) = vals.split_at_mut(base);
                let sa = &src[*a as usize * wl + lo..*a as usize * wl + hi];
                let sb = &src[*b2 as usize * wl + lo..*b2 as usize * wl + hi];
                let d = &mut dst[lo..hi];
                for n in 0..d.len() {
                    d[n] = apply_builtin2(*fun, sa[n], sb[n]);
                }
            }
            Kernel::StoreField { slot, v } => {
                let src = &vals[*v as usize * wl + lo..*v as usize * wl + hi];
                let dbase = r.base + i * r.si + jbase * r.sj;
                // SAFETY: store bounds are clamped to the slab's owned
                // partition, so this thread is the unique writer.
                unsafe {
                    env.storages[*slot].write_lanes(
                        (dbase + lo as i64 * r.lane) as usize,
                        r.lane as usize,
                        src,
                    );
                }
            }
            Kernel::StorePlane { slot, v } => {
                let (_, sbuf) = scratch[*slot].as_mut().expect("scratch local without buffer");
                let dbase = r.base + i * r.si + jbase * r.sj;
                copy_lanes_out(
                    &vals[*v as usize * wl + lo..*v as usize * wl + hi],
                    sbuf,
                    dbase,
                    r.lane,
                    lo,
                );
            }
            Kernel::StoreRing { slot, v } => {
                if !rings.contains_key(&(*slot, k0)) {
                    let e = alloc[*slot];
                    let dnj = env.domain[1] as i64;
                    let reg = Region {
                        i0: slab.0 + e.i.0 as i64,
                        i1: slab.1 + e.i.1 as i64,
                        j0: e.j.0 as i64,
                        j1: dnj + e.j.1 as i64,
                        k0,
                        k1: k0 + 1,
                    };
                    let buf = pool.take::<T>(reg.len());
                    rings.insert((*slot, k0), (reg, buf));
                }
                let ent = rings.get_mut(&(*slot, k0)).expect("ring plane just inserted");
                let (sr, sbuf) = (ent.0, &mut ent.1);
                let sdj = sr.j1 - sr.j0;
                let swk = sr.wk() as i64;
                let dbase = ((i - sr.i0) * sdj + (jbase - sr.j0)) * swk + (k0 - sr.k0);
                let ls = if axis == 2 { 1 } else { swk };
                copy_lanes_out(
                    &vals[*v as usize * wl + lo..*v as usize * wl + hi],
                    sbuf,
                    dbase,
                    ls,
                    lo,
                );
            }
            Kernel::Skip => unreachable!("skipped above"),
        }
    }
}

/// Evaluate one tape plan over a guard-free interior block: `jlen` strips
/// of `wl` lanes at `(i, jt..jt+jlen)`. `vals` holds `bs = tile * wl`
/// lanes per op (strip `jj` at offset `jj * wl`); arithmetic runs one flat
/// loop over all `jlen * wl` lanes. Only called for reorderable tiers
/// inside the interior rectangle, so every element's dataflow is identical
/// to the strip-by-strip traversal.
#[allow(clippy::too_many_arguments)]
fn eval_block<T: Element>(
    env: &EnvView<'_, T>,
    kernels: &[Kernel],
    resolved: &[Resolved],
    vals: &mut [T],
    wl: usize,
    bs: usize,
    jlen: usize,
    i: i64,
    jt: i64,
    scratch: &mut Scratch<T>,
) {
    let n = jlen * wl;
    for (x, kern) in kernels.iter().enumerate() {
        let base = x * bs;
        let r = &resolved[x];
        match kern {
            Kernel::Skip => {}
            Kernel::Const(c) => vals[base..base + n].fill(T::from_f64(*c)),
            Kernel::Scalar(ix) => {
                let v = env.scalars[*ix];
                vals[base..base + n].fill(v);
            }
            Kernel::Load { slot, .. } => {
                let v = env.storages[*slot];
                let row = r.base + i * r.si + jt * r.sj;
                // SAFETY: interior-rectangle bounds hold for every op (the
                // caller's guard hoisting), and reads are ordered before
                // conflicting writes per the disjoint-write contract.
                if r.lane == 1 && r.sj == wl as i64 {
                    // j-adjacent strips are contiguous: one block copy.
                    unsafe { v.read_lanes(row as usize, 1, &mut vals[base..base + n]) };
                } else {
                    for jj in 0..jlen {
                        unsafe {
                            v.read_lanes(
                                (row + jj as i64 * r.sj) as usize,
                                r.lane as usize,
                                &mut vals[base + jj * wl..base + jj * wl + wl],
                            );
                        }
                    }
                }
            }
            Kernel::LoadPlane { slot, .. } => {
                if r.missing {
                    vals[base..base + n].fill(T::ZERO);
                } else {
                    let (_, sbuf) = scratch[*slot].as_ref().expect("resolved plane buffer");
                    let row = r.base + i * r.si + jt * r.sj;
                    if r.lane == 1 && r.sj == wl as i64 {
                        let a0 = row as usize;
                        vals[base..base + n].copy_from_slice(&sbuf[a0..a0 + n]);
                    } else {
                        for jj in 0..jlen {
                            copy_lanes_in(
                                sbuf,
                                row + jj as i64 * r.sj,
                                r.lane,
                                &mut vals[base + jj * wl..base + jj * wl + wl],
                                0,
                            );
                        }
                    }
                }
            }
            Kernel::Neg(a) => {
                let (src, dst) = vals.split_at_mut(base);
                let sa = &src[*a as usize * bs..*a as usize * bs + n];
                let d = &mut dst[..n];
                for x in 0..n {
                    d[x] = -sa[x];
                }
            }
            Kernel::Not(a) => {
                let (src, dst) = vals.split_at_mut(base);
                let sa = &src[*a as usize * bs..*a as usize * bs + n];
                let d = &mut dst[..n];
                for x in 0..n {
                    d[x] = T::from_bool(!sa[x].truthy());
                }
            }
            Kernel::Add(a, b2) => {
                let (src, dst) = vals.split_at_mut(base);
                let sa = &src[*a as usize * bs..*a as usize * bs + n];
                let sb = &src[*b2 as usize * bs..*b2 as usize * bs + n];
                let d = &mut dst[..n];
                for x in 0..n {
                    d[x] = sa[x] + sb[x];
                }
            }
            Kernel::Sub(a, b2) => {
                let (src, dst) = vals.split_at_mut(base);
                let sa = &src[*a as usize * bs..*a as usize * bs + n];
                let sb = &src[*b2 as usize * bs..*b2 as usize * bs + n];
                let d = &mut dst[..n];
                for x in 0..n {
                    d[x] = sa[x] - sb[x];
                }
            }
            Kernel::Mul(a, b2) => {
                let (src, dst) = vals.split_at_mut(base);
                let sa = &src[*a as usize * bs..*a as usize * bs + n];
                let sb = &src[*b2 as usize * bs..*b2 as usize * bs + n];
                let d = &mut dst[..n];
                for x in 0..n {
                    d[x] = sa[x] * sb[x];
                }
            }
            Kernel::Div(a, b2) => {
                let (src, dst) = vals.split_at_mut(base);
                let sa = &src[*a as usize * bs..*a as usize * bs + n];
                let sb = &src[*b2 as usize * bs..*b2 as usize * bs + n];
                let d = &mut dst[..n];
                for x in 0..n {
                    d[x] = sa[x] / sb[x];
                }
            }
            Kernel::MulAdd(a, b2, c) => {
                let (src, dst) = vals.split_at_mut(base);
                let sa = &src[*a as usize * bs..*a as usize * bs + n];
                let sb = &src[*b2 as usize * bs..*b2 as usize * bs + n];
                let sc = &src[*c as usize * bs..*c as usize * bs + n];
                T::mul_add_slices(&mut dst[..n], sa, sb, sc);
            }
            Kernel::MulSub(a, b2, c) => {
                let (src, dst) = vals.split_at_mut(base);
                let sa = &src[*a as usize * bs..*a as usize * bs + n];
                let sb = &src[*b2 as usize * bs..*b2 as usize * bs + n];
                let sc = &src[*c as usize * bs..*c as usize * bs + n];
                T::mul_sub_slices(&mut dst[..n], sa, sb, sc);
            }
            Kernel::Bin(op, a, b2) => {
                let (src, dst) = vals.split_at_mut(base);
                let sa = &src[*a as usize * bs..*a as usize * bs + n];
                let sb = &src[*b2 as usize * bs..*b2 as usize * bs + n];
                let d = &mut dst[..n];
                for x in 0..n {
                    d[x] = apply_bin(*op, sa[x], sb[x]);
                }
            }
            Kernel::Select(c, t, f) => {
                let (src, dst) = vals.split_at_mut(base);
                let sc = &src[*c as usize * bs..*c as usize * bs + n];
                let st_ = &src[*t as usize * bs..*t as usize * bs + n];
                let sf = &src[*f as usize * bs..*f as usize * bs + n];
                let d = &mut dst[..n];
                for x in 0..n {
                    d[x] = if sc[x].truthy() { st_[x] } else { sf[x] };
                }
            }
            Kernel::Call1(fun, a) => {
                let (src, dst) = vals.split_at_mut(base);
                let sa = &src[*a as usize * bs..*a as usize * bs + n];
                let d = &mut dst[..n];
                for x in 0..n {
                    d[x] = apply_builtin1(*fun, sa[x]);
                }
            }
            Kernel::Call2(fun, a, b2) => {
                let (src, dst) = vals.split_at_mut(base);
                let sa = &src[*a as usize * bs..*a as usize * bs + n];
                let sb = &src[*b2 as usize * bs..*b2 as usize * bs + n];
                let d = &mut dst[..n];
                for x in 0..n {
                    d[x] = apply_builtin2(*fun, sa[x], sb[x]);
                }
            }
            Kernel::StoreField { slot, v } => {
                let row = r.base + i * r.si + jt * r.sj;
                let s = env.storages[*slot];
                // SAFETY: interior stores stay inside the slab's owned
                // partition; this thread is the unique writer.
                for jj in 0..jlen {
                    unsafe {
                        s.write_lanes(
                            (row + jj as i64 * r.sj) as usize,
                            r.lane as usize,
                            &vals[*v as usize * bs + jj * wl..*v as usize * bs + jj * wl + wl],
                        );
                    }
                }
            }
            Kernel::StorePlane { slot, v } => {
                let (_, sbuf) = scratch[*slot].as_mut().expect("scratch local without buffer");
                let row = r.base + i * r.si + jt * r.sj;
                if r.lane == 1 && r.sj == wl as i64 {
                    let a0 = row as usize;
                    sbuf[a0..a0 + n].copy_from_slice(&vals[*v as usize * bs..*v as usize * bs + n]);
                } else {
                    for jj in 0..jlen {
                        copy_lanes_out(
                            &vals[*v as usize * bs + jj * wl..*v as usize * bs + jj * wl + wl],
                            sbuf,
                            row + jj as i64 * r.sj,
                            r.lane,
                            0,
                        );
                    }
                }
            }
            Kernel::LoadRing { .. } | Kernel::StoreRing { .. } => {
                unreachable!("ring tiers are never reorderable")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::compile_source_opt;
    use crate::backend::fused::FusedProgram;
    use crate::backend::program::Program;
    use crate::opt::{OptConfig, OptLevel};
    use std::collections::BTreeMap;

    fn lower_src(src: &str, name: &str, fast_math: bool) -> (Program, FusedProgram) {
        let ir = compile_source_opt(
            src,
            name,
            &BTreeMap::new(),
            &OptConfig::level(OptLevel::O3).with_fast_math(fast_math),
        )
        .unwrap();
        let p = Program::compile(&ir).unwrap();
        let fp = FusedProgram::compile(&p, fast_math);
        (p, fp)
    }

    #[test]
    fn exec_tier_parses_and_displays() {
        assert_eq!(ExecTier::parse("interpreted"), Some(ExecTier::Interpreted));
        assert_eq!(ExecTier::parse(" spec "), Some(ExecTier::Specialized));
        assert_eq!(ExecTier::parse("warp"), None);
        assert_eq!(ExecTier::default(), ExecTier::Specialized);
        assert_eq!(ExecTier::Interpreted.to_string(), "interpreted");
        assert_eq!(ExecTier::Specialized.to_string(), "specialized");
    }

    #[test]
    fn lowering_monomorphizes_hot_opcodes() {
        let (_, fp) = lower_src(crate::stdlib::HDIFF_SRC, "hdiff", false);
        let g = &fp.multistages[0].groups[0];
        // Every tier's plan is index-aligned with its tape, hot binary
        // opcodes get dedicated kernels, demoted locals are split by class
        // at lowering time, and nothing is Skip without fast-math.
        for t in &g.tiers {
            assert_eq!(t.plan.kernels.len(), t.tape.ops.len());
            assert!(t.plan.kernels.iter().all(|k| *k != Kernel::Skip));
            assert!(!t
                .plan
                .kernels
                .iter()
                .any(|k| matches!(k, Kernel::LoadRing { .. } | Kernel::StoreRing { .. })));
        }
        let all: Vec<&Kernel> = g.tiers.iter().flat_map(|t| &t.plan.kernels).collect();
        assert!(all.iter().any(|k| matches!(k, Kernel::Add(..))));
        assert!(all.iter().any(|k| matches!(k, Kernel::Load { .. })));
        assert!(all.iter().any(|k| matches!(k, Kernel::LoadPlane { .. })));
        assert!(all.iter().any(|k| matches!(k, Kernel::StorePlane { .. })));
        // hdiff's tiers never store what they offset-load: all blocked.
        assert!(g.tiers.iter().all(|t| t.plan.reorderable));
    }

    #[test]
    fn fast_math_contracts_single_use_muls() {
        const SRC: &str = "
            stencil s(a: Field<f64>, b: Field<f64>, out: Field<f64>) {
                with computation(PARALLEL), interval(...) {
                    out = a * b + a[1,0,0];
                }
            }";
        let (_, exact) = lower_src(SRC, "s", false);
        let ke = &exact.multistages[0].groups[0].tiers[0].plan.kernels;
        assert!(!ke.iter().any(|k| matches!(k, Kernel::MulAdd(..) | Kernel::Skip)));
        let (_, relaxed) = lower_src(SRC, "s", true);
        let kr = &relaxed.multistages[0].groups[0].tiers[0].plan.kernels;
        assert_eq!(kr.iter().filter(|k| matches!(k, Kernel::MulAdd(..))).count(), 1);
        assert_eq!(kr.iter().filter(|k| **k == Kernel::Skip).count(), 1);
        // The skipped op is the Mul the FMA absorbed.
        let skipped = kr.iter().position(|k| *k == Kernel::Skip).unwrap();
        assert!(matches!(
            exact.multistages[0].groups[0].tiers[0].plan.kernels[skipped],
            Kernel::Mul(..)
        ));
    }

    #[test]
    fn shared_muls_are_never_contracted() {
        // The product is used twice (CSE keeps one Mul): contracting it
        // into one consumer would orphan the other.
        const SRC: &str = "
            stencil s(a: Field<f64>, b: Field<f64>, out: Field<f64>) {
                with computation(PARALLEL), interval(...) {
                    out = (a * b + 1.0) / (a * b - 1.0);
                }
            }";
        let (_, relaxed) = lower_src(SRC, "s", true);
        let k = &relaxed.multistages[0].groups[0].tiers[0].plan.kernels;
        assert!(!k.iter().any(|x| matches!(x, Kernel::MulAdd(..) | Kernel::MulSub(..))));
        assert!(!k.iter().any(|x| *x == Kernel::Skip));
    }

    #[test]
    fn in_tier_store_plus_offset_load_blocks_reordering() {
        // `x = a + x[1,0,0] * 0.25`: the single stage both stores x and
        // loads it at a horizontal offset, so strip order is observable
        // and the tier must stay strip-by-strip.
        const SRC: &str = "
            stencil s(a: Field<f64>, x: Field<f64>) {
                with computation(PARALLEL), interval(...) {
                    x = a + x[1,0,0] * 0.25;
                }
            }";
        let (_, fp) = lower_src(SRC, "s", false);
        let g = &fp.multistages[0].groups[0];
        assert_eq!(g.tiers.len(), 1);
        assert!(!g.tiers[0].plan.reorderable);
        // Vertical-only offsets stay within one strip: reorderable.
        const VSRC: &str = "
            stencil s(a: Field<f64>, x: Field<f64>) {
                with computation(PARALLEL), interval(...) {
                    x = a + x[0,0,1] * 0.25;
                }
            }";
        let (_, fp) = lower_src(VSRC, "s", false);
        assert!(fp.multistages[0].groups[0].tiers[0].plan.reorderable);
    }

    #[test]
    fn fma_slices_match_reference_within_one_ulp() {
        let a = [1.5, -2.25, 3.0e153, 1.0e-300, 7.0];
        let b = [2.0, 4.5, 2.0e153, 1.0e-10, -3.0];
        let c = [0.5, -1.25, 1.0, 5.0e-310, 21.0];
        let mut add = [0.0; 5];
        let mut sub = [0.0; 5];
        <f64 as Element>::mul_add_slices(&mut add, &a, &b, &c);
        <f64 as Element>::mul_sub_slices(&mut sub, &a, &b, &c);
        for n in 0..5 {
            let ra = a[n].mul_add(b[n], c[n]);
            let rs = a[n].mul_add(b[n], -c[n]);
            let ea = a[n] * b[n] + c[n];
            let es = a[n] * b[n] - c[n];
            // Whichever rounding path the host picked, the result is one
            // of the two legal contractions.
            assert!(add[n] == ra || add[n] == ea, "lane {n}: {} vs {ra}/{ea}", add[n]);
            assert!(sub[n] == rs || sub[n] == es, "lane {n}: {} vs {rs}/{es}", sub[n]);
        }
    }

    #[test]
    fn f32_fma_slices_round_at_single_precision() {
        // The f32 monomorphization must do single-precision arithmetic —
        // not compute in f64 and narrow at the end.
        let a: [f32; 2] = [1.0000001, 3.0e18];
        let b: [f32; 2] = [1.0000001, 2.0e18];
        let c: [f32; 2] = [-1.0, 1.0];
        let mut out = [0.0f32; 2];
        <f32 as Element>::mul_add_slices(&mut out, &a, &b, &c);
        for n in 0..2 {
            let fused = a[n].mul_add(b[n], c[n]);
            let plain = a[n] * b[n] + c[n];
            assert!(out[n] == fused || out[n] == plain);
            // And the result differs from the f64 computation narrowed
            // last (the widened path this test guards against).
            let widened = (a[n] as f64 * b[n] as f64 + c[n] as f64) as f32;
            let _ = widened; // same value is possible per-lane; the real
                             // guard is the property suite's dtype axis.
        }
    }
}
