//! The `vector` backend: region-vectorized evaluation.
//!
//! The analog of GT4Py's `numpy` backend (§2.3): each stage's expression is
//! evaluated with whole-region elementwise operations, materializing a
//! buffer per expression node exactly as NumPy materializes array
//! temporaries. Faster than `debug` by an order of magnitude or more, but
//! still far from the compiled backends because every intermediate value
//! makes a round trip through memory — the Fig. 3 middle tier.
//!
//! Perf notes (EXPERIMENTS.md §Perf): PARALLEL stages evaluate their whole
//! 3-D region in one shot with the storage's stride-1 axis (K for the IJK
//! layout) innermost, so gathers/scatters of zero-k-offset rows degenerate
//! to `copy_from_slice`. Sequential (FORWARD/BACKWARD) stages evaluate one
//! plane per level — the vertical dependence forbids more.
//!
//! Optimizer integration: temporaries the pass manager demoted (any
//! non-[`StorageClass::Field3D`] class) never touch a `Storage` here.
//! Register/plane locals live in *group-local* region buffers (one whole
//! region per PARALLEL group, one plane per level in sequential groups)
//! that are written by the producing stage and windowed directly by
//! consuming stages; [`StorageClass::Ring`] sweep carries live in a
//! multistage-scoped ring of recent level planes (a k-cache). Either way
//! the whole-field zero allocation, the scatter after the producer, and
//! the strided gather in every consumer that an undemoted temporary pays
//! are skipped. Reads before the first write see zeros, exactly like the
//! zero-initialized field they replace.
//!
//! Dtype generality: every evaluator in this module (and in
//! [`crate::backend::fused`] / [`crate::backend::kernels`]) is generic over
//! `T: Element` and monomorphized per dtype. Field access goes through
//! [`crate::storage::StorageView`]s of a shared
//! [`EnvView`](crate::backend::program::EnvView) — interior-mutable, `Send +
//! Sync`, sound under the disjoint-write contract documented in
//! `storage/view.rs` — so the serial and sharded paths share one evaluator
//! and no `&mut` aliasing ever occurs. Dispatch on the program's dtype
//! happens exactly once per run, in [`Backend::run_sharded`].
//!
//! Fused execution (`--opt-level 3`): when the IR carries the
//! [`fused`](crate::ir::implir::StencilIr::fused) strategy bit, dispatch
//! leaves this materializing path entirely and runs the tape-based fused
//! loop-nest evaluator in [`crate::backend::fused`], which evaluates every
//! output and demoted temporary of a fusion group in one loop nest per
//! interval with *no per-expression-node region buffers*.

use super::cexpr::{apply_bin, apply_builtin1, apply_builtin2, CExpr};
use super::fused::FusedProgram;
use super::kernels::ExecTier;
use super::program::{CMultistage, CStage, Env, EnvView, Program};
use super::shard::{split_slabs, HaloPlan, HaloRendezvous, ShardReport, WorkerPool};
use super::{Backend, RunConfig, StencilArgs};
use crate::dsl::ast::{BinOp, DType, IterationPolicy};
use crate::ir::implir::{StencilIr, StorageClass};
use crate::storage::Element;
use anyhow::Result;
use std::collections::{HashMap, HashSet};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Barrier, Mutex, RwLock};
use std::time::{Duration, Instant};

/// Retained idle worker pools (one per concurrently-sharding caller; a
/// burst beyond the cap spawns throwaway pools that are dropped — joined
/// — on return).
const SHARD_POOL_CAP: usize = 4;

#[derive(Default)]
pub struct VectorBackend {
    /// Programs keyed by stencil fingerprint (one backend instance is
    /// shared across stencils and across concurrently-dispatching threads;
    /// the locks are held only for cache lookup/insert).
    programs: RwLock<std::collections::HashMap<u64, Arc<Program>>>,
    /// Fused loop-nest programs, compiled on demand for `fused` IRs.
    fused: RwLock<std::collections::HashMap<u64, Arc<FusedProgram>>>,
    /// Shared buffer-pool slot. A run *checks the pool out* (swapping an
    /// empty one in) and merges it back afterwards, so concurrent runs
    /// never contend while executing — a second thread simply starts from
    /// an empty pool and contributes its buffers on the way out.
    pool: Mutex<Pool>,
    /// Persistent worker pools for sharded runs, checked out like the
    /// buffer pool: a sharded call pops one (growing it to the thread
    /// count it needs), uses it, and pushes it back — concurrent sharded
    /// dispatches from many handle threads each get their own pool, so
    /// outer concurrency and inner sharding compose without contention.
    shard_pools: Mutex<Vec<WorkerPool>>,
    /// Optional on-disk artifact store (see [`crate::persist`]): when
    /// attached, fused tapes are loaded from / stored to it so `--opt-level
    /// 3` warm starts skip tape lowering entirely.
    persist: Mutex<Option<Arc<crate::persist::PersistStore>>>,
}

impl VectorBackend {
    pub fn new() -> Self {
        Self::default()
    }

    /// Check out a worker pool with at least `workers` workers.
    fn checkout_workers(&self, workers: usize) -> WorkerPool {
        let mut pool = self.shard_pools.lock().unwrap().pop().unwrap_or_default();
        pool.ensure_workers(workers);
        pool
    }

    fn return_workers(&self, pool: WorkerPool) {
        let mut pools = self.shard_pools.lock().unwrap();
        if pools.len() < SHARD_POOL_CAP {
            pools.push(pool);
        }
    }

    /// Buffer-pool traffic since the last call (and reset): how many region
    /// buffers were requested and how many required a fresh allocation.
    /// The ablation bench uses this to show the fused path allocating no
    /// per-expression-node buffers. Counts cover completed runs; pools
    /// checked out by in-flight concurrent runs merge in when they finish.
    pub fn take_pool_stats(&self) -> PoolStats {
        std::mem::take(&mut self.pool.lock().unwrap().stats)
    }

    fn programs_for(
        &self,
        ir: &StencilIr,
    ) -> Result<(Arc<Program>, Option<Arc<FusedProgram>>)> {
        let program = {
            let cached = self.programs.read().unwrap().get(&ir.fingerprint).cloned();
            match cached {
                Some(p) => p,
                None => {
                    let compiled = Arc::new(Program::compile(ir)?);
                    let mut programs = self.programs.write().unwrap();
                    programs.entry(ir.fingerprint).or_insert(compiled).clone()
                }
            }
        };
        let fused = if ir.fused {
            let cached = self.fused.read().unwrap().get(&ir.fingerprint).cloned();
            Some(match cached {
                Some(f) => f,
                None => {
                    // `fast_math` is part of the opt tag and therefore of
                    // `ir.fingerprint`, so exact and relaxed plans never
                    // share a cache entry — the persist key inherits the
                    // same property.
                    let store = self.persist.lock().unwrap().clone();
                    let key = format!("{:016x}", ir.fingerprint);
                    let loaded = store.as_ref().and_then(|s| {
                        let payload = s.load("tape", &key)?;
                        let classes: Vec<StorageClass> =
                            program.slots.iter().map(|slot| slot.storage).collect();
                        match crate::persist::tapeser::fused_from_json(
                            &payload,
                            &classes,
                            ir.fast_math,
                        ) {
                            Some(fp) => Some(Arc::new(fp)),
                            None => {
                                // Digest-valid envelope but semantically
                                // unusable payload: demote the hit.
                                s.reject_loaded();
                                None
                            }
                        }
                    });
                    let compiled = match loaded {
                        Some(fp) => fp,
                        None => {
                            let fp = Arc::new(FusedProgram::compile(&program, ir.fast_math));
                            if let Some(s) = &store {
                                let _ =
                                    s.store("tape", &key, &crate::persist::tapeser::fused_to_json(&fp));
                            }
                            fp
                        }
                    };
                    let mut fused = self.fused.write().unwrap();
                    fused.entry(ir.fingerprint).or_insert(compiled).clone()
                }
            })
        } else {
            None
        };
        Ok((program, fused))
    }
}

/// Buffer-pool and fused-executor counters (see
/// [`VectorBackend::take_pool_stats`]). The strip/tier/block counters
/// explain *where* the fused path spent its passes — how many loop-nest
/// passes ran specialized vs interpreted, and how much of the domain ran
/// as guarded fringe strips vs guard-free cache-blocked interior.
#[derive(Debug, Clone, Copy, Default)]
pub struct PoolStats {
    /// Buffers handed out (pool hits + fresh allocations).
    pub taken: u64,
    /// Buffers that had to be freshly allocated.
    pub allocated: u64,
    /// Tier passes executed by the interpreted tape walker.
    pub tiers_interpreted: u64,
    /// Tier passes executed by the specialized kernel-plan executor.
    pub tiers_specialized: u64,
    /// Per-op-guarded strips evaluated by the interpreted walker.
    pub strips_interpreted: u64,
    /// Guarded (fringe / order-sensitive / sequential) strips evaluated by
    /// the specialized executor.
    pub strips_guarded: u64,
    /// Guard-free j-tiled interior blocks evaluated by the specialized
    /// executor (each covers up to `tile × wl` lanes per op).
    pub blocks_interior: u64,
    /// Cross-slab halo rendezvous crossed by sharded sequential sweeps
    /// (each counted once per rendezvous, not per slab). Zero-sync
    /// (`HaloPlan::Local`) multistages never bump this.
    pub halo_exchanges: u64,
    /// Multistages that degraded to serial execution inside an otherwise
    /// sharded call (`HaloPlan::Serial` — irreducible in-pass wavefronts).
    pub serial_fallbacks: u64,
}

/// Pool routing for an element type: which of the dtype-segregated free
/// lists a `Vec<T>` recycles through. Crate-internal companion of
/// [`Element`] — the evaluators in this module, `fused` and `kernels` all
/// bound on it.
pub(crate) trait PoolElem: Element {
    fn free_list(pool: &mut Pool) -> &mut Vec<Vec<Self>>;
}

impl PoolElem for f64 {
    #[inline(always)]
    fn free_list(pool: &mut Pool) -> &mut Vec<Vec<f64>> {
        &mut pool.free64
    }
}

impl PoolElem for f32 {
    #[inline(always)]
    fn free_list(pool: &mut Pool) -> &mut Vec<Vec<f32>> {
        &mut pool.free32
    }
}

/// Recycles region buffers between expression nodes and stages; also
/// carries the per-run executor counters (checked out and absorbed with
/// the pool, so concurrent runs never contend). One free list per dtype —
/// a buffer only ever recycles at its own element width.
#[derive(Default)]
pub(crate) struct Pool {
    free64: Vec<Vec<f64>>,
    free32: Vec<Vec<f32>>,
    pub(crate) stats: PoolStats,
}

/// Max free buffers retained per dtype list (shared by `put` and `absorb`).
const POOL_FREE_CAP: usize = 48;

impl Pool {
    pub(crate) fn take<T: PoolElem>(&mut self, n: usize) -> Vec<T> {
        self.stats.taken += 1;
        match T::free_list(self).pop() {
            Some(mut b) => {
                b.clear();
                b.resize(n, T::ZERO);
                b
            }
            None => {
                self.stats.allocated += 1;
                vec![T::ZERO; n]
            }
        }
    }
    pub(crate) fn put<T: PoolElem>(&mut self, b: Vec<T>) {
        let list = T::free_list(self);
        if list.len() < POOL_FREE_CAP {
            list.push(b);
        }
    }

    /// Merge a checked-out pool back into the shared slot: stats are
    /// summed, free buffers are kept up to the shared per-dtype cap.
    fn absorb(&mut self, mut other: Pool) {
        self.stats.taken += other.stats.taken;
        self.stats.allocated += other.stats.allocated;
        self.stats.tiers_interpreted += other.stats.tiers_interpreted;
        self.stats.tiers_specialized += other.stats.tiers_specialized;
        self.stats.strips_interpreted += other.stats.strips_interpreted;
        self.stats.strips_guarded += other.stats.strips_guarded;
        self.stats.blocks_interior += other.stats.blocks_interior;
        self.stats.halo_exchanges += other.stats.halo_exchanges;
        self.stats.serial_fallbacks += other.stats.serial_fallbacks;
        while self.free64.len() < POOL_FREE_CAP {
            match other.free64.pop() {
                Some(b) => self.free64.push(b),
                None => break,
            }
        }
        while self.free32.len() < POOL_FREE_CAP {
            match other.free32.pop() {
                Some(b) => self.free32.push(b),
                None => break,
            }
        }
    }
}

/// A 3-D evaluation region `[i0,i1) x [j0,j1) x [k0,k1)`. Buffers over a
/// region are laid out i-major, then j, then k (k contiguous).
#[derive(Clone, Copy, Debug)]
pub(crate) struct Region {
    pub(crate) i0: i64,
    pub(crate) i1: i64,
    pub(crate) j0: i64,
    pub(crate) j1: i64,
    pub(crate) k0: i64,
    pub(crate) k1: i64,
}

impl Region {
    #[inline]
    pub(crate) fn wk(&self) -> usize {
        (self.k1 - self.k0) as usize
    }
    pub(crate) fn len(&self) -> usize {
        ((self.i1 - self.i0) * (self.j1 - self.j0)) as usize * self.wk()
    }
}

/// Evaluation result: a broadcast scalar or a materialized region buffer.
enum Val<T> {
    S(T),
    B(Vec<T>),
}

/// Group-local buffers of demoted temporaries: slot → (region, values).
/// Flushed at every fusion-group boundary (and every level, for
/// sequential multistages).
struct Locals<T> {
    bufs: HashMap<usize, (Region, Vec<T>)>,
}

impl<T> Default for Locals<T> {
    fn default() -> Self {
        Locals { bufs: HashMap::new() }
    }
}

impl<T: PoolElem> Locals<T> {
    fn flush(&mut self, pool: &mut Pool) {
        for (_, (_, b)) in self.bufs.drain() {
            pool.put(b);
        }
    }
}

/// Ring of recent level planes for [`StorageClass::Ring`] sweep carries:
/// `(slot, level) -> (plane region, values)`, scoped to one sequential
/// multistage and pruned to each slot's ring depth as the sweep advances.
pub(crate) type Rings<T> = HashMap<(usize, i64), (Region, Vec<T>)>;

/// Shared read-only state for one stage evaluation.
struct EvalCtx<'a, T: Element> {
    env: &'a EnvView<'a, T>,
    /// Per-slot storage class (`program.slots[i].storage`).
    classes: &'a [StorageClass],
    locals: &'a Locals<T>,
    rings: &'a Rings<T>,
}

/// Window a demoted temporary's region buffer: copy `r` shifted by `off`
/// out of `(src_region, src)`. The fusion/demotion passes guarantee
/// containment (extent-checked offsets; for ring planes the vertical
/// offset selects the source plane), so the window never leaves the
/// buffer.
pub(crate) fn gather_local<T: PoolElem>(
    src_region: Region,
    src: &[T],
    off: [i32; 3],
    r: Region,
    pool: &mut Pool,
) -> Vec<T> {
    let sdj = (src_region.j1 - src_region.j0) as usize;
    let sdk = src_region.wk();
    let wk = r.wk();
    let mut buf = pool.take::<T>(r.len());
    let mut idx = 0;
    for i in r.i0..r.i1 {
        let si = (i + off[0] as i64 - src_region.i0) as usize;
        for j in r.j0..r.j1 {
            let sj = (j + off[1] as i64 - src_region.j0) as usize;
            let base =
                si * sdj * sdk + sj * sdk + (r.k0 + off[2] as i64 - src_region.k0) as usize;
            buf[idx..idx + wk].copy_from_slice(&src[base..base + wk]);
            idx += wk;
        }
    }
    buf
}

fn gather<T: PoolElem>(
    env: &EnvView<'_, T>,
    slot: usize,
    off: [i32; 3],
    r: Region,
    pool: &mut Pool,
) -> Vec<T> {
    let v = env.storages[slot];
    let st = v.strides();
    let (s0, s1, s2) = (st[0] as i64, st[1] as i64, st[2] as i64);
    let org = v.origin() as i64;
    let wk = r.wk();
    let mut buf = pool.take::<T>(r.len());
    let mut idx = 0;
    if s2 == 1 {
        // stride-1 K rows: bulk copies
        for i in r.i0..r.i1 {
            let ibase = org + (i + off[0] as i64) * s0;
            for j in r.j0..r.j1 {
                let base =
                    (ibase + (j + off[1] as i64) * s1 + (r.k0 + off[2] as i64)) as usize;
                // SAFETY: in-bounds by the extent analysis; reads of shared
                // storage are ordered before any conflicting write by the
                // sharding model (per-stage barriers / per-level halo
                // rendezvous / slab-local sweeps, as the multistage's
                // HaloPlan demands) — the disjoint-write contract of
                // `storage/view.rs`.
                unsafe { v.read_lanes(base, 1, &mut buf[idx..idx + wk]) };
                idx += wk;
            }
        }
    } else {
        for i in r.i0..r.i1 {
            let ibase = org + (i + off[0] as i64) * s0;
            for j in r.j0..r.j1 {
                let jbase = ibase + (j + off[1] as i64) * s1;
                for k in r.k0..r.k1 {
                    // SAFETY: same contract as the bulk path above.
                    buf[idx] = unsafe { v.read((jbase + (k + off[2] as i64) * s2) as usize) };
                    idx += 1;
                }
            }
        }
    }
    buf
}

fn scatter<T: Element>(env: &EnvView<'_, T>, slot: usize, r: Region, buf: &[T]) {
    let v = env.storages[slot];
    let st = v.strides();
    let (s0, s1, s2) = (st[0] as i64, st[1] as i64, st[2] as i64);
    let org = v.origin() as i64;
    let wk = r.wk();
    let mut idx = 0;
    if s2 == 1 {
        for i in r.i0..r.i1 {
            let ibase = org + i * s0;
            for j in r.j0..r.j1 {
                let base = (ibase + j * s1 + r.k0) as usize;
                // SAFETY: `r` is clamped to this slab's owned store range
                // (`stage_region`), so this thread is the unique writer of
                // every element — the disjoint-write contract holds.
                unsafe { v.write_lanes(base, 1, &buf[idx..idx + wk]) };
                idx += wk;
            }
        }
    } else {
        for i in r.i0..r.i1 {
            let ibase = org + i * s0;
            for j in r.j0..r.j1 {
                let jbase = ibase + j * s1;
                for k in r.k0..r.k1 {
                    // SAFETY: same ownership argument as the bulk path.
                    unsafe { v.write((jbase + k * s2) as usize, buf[idx]) };
                    idx += 1;
                }
            }
        }
    }
}

/// Elementwise binary op with buffer reuse; specializes the hot arithmetic
/// operators so the inner loops are branch-free and auto-vectorizable.
fn bin_bb<T: Element>(op: BinOp, mut a: Vec<T>, b: &[T]) -> Vec<T> {
    match op {
        BinOp::Add => {
            for (x, y) in a.iter_mut().zip(b) {
                *x += *y;
            }
        }
        BinOp::Sub => {
            for (x, y) in a.iter_mut().zip(b) {
                *x -= *y;
            }
        }
        BinOp::Mul => {
            for (x, y) in a.iter_mut().zip(b) {
                *x *= *y;
            }
        }
        BinOp::Div => {
            for (x, y) in a.iter_mut().zip(b) {
                *x /= *y;
            }
        }
        _ => {
            for (x, y) in a.iter_mut().zip(b) {
                *x = apply_bin(op, *x, *y);
            }
        }
    }
    a
}

fn eval_region<T: PoolElem>(
    ctx: &EvalCtx<'_, T>,
    e: &CExpr,
    r: Region,
    pool: &mut Pool,
) -> Val<T> {
    match e {
        CExpr::Const(v) => Val::S(T::from_f64(*v)),
        CExpr::Scalar(ix) => Val::S(ctx.env.scalars[*ix]),
        CExpr::Field { slot, off } => match ctx.classes[*slot] {
            StorageClass::Field3D => Val::B(gather(ctx.env, *slot, *off, r, pool)),
            StorageClass::Register | StorageClass::Plane => {
                match ctx.locals.bufs.get(slot) {
                    Some((sr, sbuf)) => Val::B(gather_local(*sr, sbuf, *off, r, pool)),
                    // Demoted temporary read before its first in-group
                    // write: zeros, like the field it replaces.
                    None => Val::S(T::ZERO),
                }
            }
            StorageClass::Ring => {
                // Sweep carry: the vertical offset selects a level plane of
                // the ring (sequential multistages evaluate one level at a
                // time, so `r` spans a single level). Never-written levels
                // read as zeros.
                let level = r.k0 + off[2] as i64;
                match ctx.rings.get(&(*slot, level)) {
                    Some((sr, sbuf)) => Val::B(gather_local(*sr, sbuf, *off, r, pool)),
                    None => Val::S(T::ZERO),
                }
            }
        },
        CExpr::Neg(a) => match eval_region(ctx, a, r, pool) {
            Val::S(v) => Val::S(-v),
            Val::B(mut b) => {
                for x in &mut b {
                    *x = -*x;
                }
                Val::B(b)
            }
        },
        CExpr::Not(a) => match eval_region(ctx, a, r, pool) {
            Val::S(v) => Val::S(T::from_bool(!v.truthy())),
            Val::B(mut b) => {
                for x in &mut b {
                    *x = T::from_bool(!x.truthy());
                }
                Val::B(b)
            }
        },
        CExpr::Bin(op, a, b) => {
            let va = eval_region(ctx, a, r, pool);
            let vb = eval_region(ctx, b, r, pool);
            match (va, vb) {
                (Val::S(x), Val::S(y)) => Val::S(apply_bin(*op, x, y)),
                (Val::S(x), Val::B(mut by)) => {
                    for v in &mut by {
                        *v = apply_bin(*op, x, *v);
                    }
                    Val::B(by)
                }
                (Val::B(mut bx), Val::S(y)) => {
                    match op {
                        BinOp::Add => bx.iter_mut().for_each(|v| *v += y),
                        BinOp::Sub => bx.iter_mut().for_each(|v| *v -= y),
                        BinOp::Mul => bx.iter_mut().for_each(|v| *v *= y),
                        BinOp::Div => bx.iter_mut().for_each(|v| *v /= y),
                        _ => bx.iter_mut().for_each(|v| *v = apply_bin(*op, *v, y)),
                    }
                    Val::B(bx)
                }
                (Val::B(bx), Val::B(by)) => {
                    let out = bin_bb(*op, bx, &by);
                    pool.put(by);
                    Val::B(out)
                }
            }
        }
        CExpr::Select(c, t, f) => {
            // NumPy `where` semantics: both branches evaluated everywhere.
            let vc = eval_region(ctx, c, r, pool);
            let vt = eval_region(ctx, t, r, pool);
            let vf = eval_region(ctx, f, r, pool);
            match vc {
                Val::S(cv) => {
                    let keep = cv.truthy();
                    let (sel, other) = if keep { (vt, vf) } else { (vf, vt) };
                    if let Val::B(b) = other {
                        pool.put(b);
                    }
                    sel
                }
                Val::B(cb) => {
                    let n = cb.len();
                    let mut out = pool.take::<T>(n);
                    match (&vt, &vf) {
                        (Val::B(tb), Val::B(fb)) => {
                            for i in 0..n {
                                out[i] = if cb[i].truthy() { tb[i] } else { fb[i] };
                            }
                        }
                        (Val::B(tb), Val::S(fv)) => {
                            for i in 0..n {
                                out[i] = if cb[i].truthy() { tb[i] } else { *fv };
                            }
                        }
                        (Val::S(tv), Val::B(fb)) => {
                            for i in 0..n {
                                out[i] = if cb[i].truthy() { *tv } else { fb[i] };
                            }
                        }
                        (Val::S(tv), Val::S(fv)) => {
                            for i in 0..n {
                                out[i] = if cb[i].truthy() { *tv } else { *fv };
                            }
                        }
                    }
                    pool.put(cb);
                    if let Val::B(b) = vt {
                        pool.put(b);
                    }
                    if let Val::B(b) = vf {
                        pool.put(b);
                    }
                    Val::B(out)
                }
            }
        }
        CExpr::Call1(f, a) => match eval_region(ctx, a, r, pool) {
            Val::S(v) => Val::S(apply_builtin1(*f, v)),
            Val::B(mut b) => {
                for x in &mut b {
                    *x = apply_builtin1(*f, *x);
                }
                Val::B(b)
            }
        },
        CExpr::Call2(f, a, b) => {
            let va = eval_region(ctx, a, r, pool);
            let vb = eval_region(ctx, b, r, pool);
            match (va, vb) {
                (Val::S(x), Val::S(y)) => Val::S(apply_builtin2(*f, x, y)),
                (Val::S(x), Val::B(mut by)) => {
                    for v in &mut by {
                        *v = apply_builtin2(*f, x, *v);
                    }
                    Val::B(by)
                }
                (Val::B(mut bx), Val::S(y)) => {
                    for v in &mut bx {
                        *v = apply_builtin2(*f, *v, y);
                    }
                    Val::B(bx)
                }
                (Val::B(mut bx), Val::B(by)) => {
                    for (v, w) in bx.iter_mut().zip(&by) {
                        *v = apply_builtin2(*f, *v, *w);
                    }
                    pool.put(by);
                    Val::B(bx)
                }
            }
        }
    }
}

/// A stage's evaluation region for one i-slab `[a, b)` of a domain with
/// i-extent `ni`. Demoted targets are slab-local: they evaluate over the
/// slab's extent-*expanded* range, recomputing the halo overlap so
/// consuming stages can window them without crossing a slab boundary.
/// `Field3D` targets are written exactly once, so their region is clamped
/// to the slab's *owned* partition (edge slabs absorb the write halo).
/// The full slab `(0, ni)` reproduces the serial region for both cases.
fn stage_region(
    stage: &CStage,
    classes: &[StorageClass],
    slab: (i64, i64),
    ni: i64,
    nj: i64,
    k0: i64,
    k1: i64,
) -> Region {
    let e = stage.extent;
    let (a, b) = slab;
    let (i0, i1) = if classes[stage.target] == StorageClass::Field3D {
        super::shard::owned_store_range(slab, ni, e.i.0 as i64, e.i.1 as i64)
    } else {
        (a + e.i.0 as i64, b + e.i.1 as i64)
    };
    Region { i0, i1, j0: e.j.0 as i64, j1: nj + e.j.1 as i64, k0, k1 }
}

#[allow(clippy::too_many_arguments)]
fn run_stage_region<T: PoolElem>(
    env: &EnvView<'_, T>,
    classes: &[StorageClass],
    locals: &mut Locals<T>,
    rings: &mut Rings<T>,
    stage: &CStage,
    k0: i64,
    k1: i64,
    pool: &mut Pool,
    slab: (i64, i64),
) {
    let [ni, nj, _] = env.domain;
    let r = stage_region(stage, classes, slab, ni as i64, nj as i64, k0, k1);
    let v = {
        let ctx = EvalCtx { env, classes, locals: &*locals, rings: &*rings };
        eval_region(&ctx, &stage.expr, r, pool)
    };
    if classes[stage.target] != StorageClass::Field3D {
        // Demoted target: the result stays a backend-local buffer; no
        // field is allocated and nothing is scattered.
        let buf = match v {
            Val::S(s) => {
                let mut b = pool.take::<T>(r.len());
                b.fill(s);
                b
            }
            Val::B(b) => b,
        };
        let old = if classes[stage.target] == StorageClass::Ring {
            // One plane per level; a same-level rewrite replaces it (reads
            // of the replaced fringe are excluded by the demotion checks).
            rings.insert((stage.target, r.k0), (r, buf))
        } else {
            locals.bufs.insert(stage.target, (r, buf))
        };
        if let Some((_, old)) = old {
            pool.put(old);
        }
        return;
    }
    match v {
        Val::S(s) => {
            let mut buf = pool.take::<T>(r.len());
            buf.fill(s);
            scatter(env, stage.target, r, &buf);
            pool.put(buf);
        }
        Val::B(b) => {
            scatter(env, stage.target, r, &b);
            pool.put(b);
        }
    }
}

/// Drop ring planes further than each slot's depth from the current level.
pub(crate) fn prune_rings<T: PoolElem>(
    rings: &mut Rings<T>,
    level: i64,
    depths: &[i32],
    pool: &mut Pool,
) {
    let stale: Vec<(usize, i64)> = rings
        .keys()
        .copied()
        .filter(|&(slot, lvl)| (level - lvl).abs() > depths[slot] as i64)
        .collect();
    for key in stale {
        if let Some((_, b)) = rings.remove(&key) {
            pool.put(b);
        }
    }
}

/// Run one multistage for one i-slab (the full slab `(0, ni)` is the
/// serial execution). Used by the serial path for every multistage, by
/// sharded runs for each slab of an exchange-free (`HaloPlan::Local`)
/// *sequential* multistage (the zero-sync slab-local vertical sweep:
/// rings and locals never leave the slab), and as the serial fallback
/// for `HaloPlan::Serial` multistages. Sequential multistages that need
/// halo exchange go through [`run_multistage_synced`]; sharded
/// `PARALLEL` multistages go through [`run_parallel_group`] instead,
/// which interleaves the per-stage barriers.
fn run_multistage<T: PoolElem>(
    ms: &CMultistage,
    classes: &[StorageClass],
    depths: &[i32],
    env: &EnvView<'_, T>,
    pool: &mut Pool,
    slab: (i64, i64),
) {
    let mut locals = Locals::default();
    let mut rings: Rings<T> = Rings::default();
    match ms.policy {
        IterationPolicy::Parallel => {
            // Whole 3-D region per stage: one gather/op/scatter pass.
            // Demoted buffers live for the duration of their fusion
            // group. (Ring slots never occur in PARALLEL multistages.)
            let mut group = None;
            for st in &ms.stages {
                if group != Some(st.fusion_group) {
                    locals.flush(pool);
                    group = Some(st.fusion_group);
                }
                let (k0, k1) = env.krange(&st.interval);
                if k0 < k1 {
                    run_stage_region(
                        env, classes, &mut locals, &mut rings, st, k0, k1, pool, slab,
                    );
                }
            }
            locals.flush(pool);
        }
        IterationPolicy::Forward | IterationPolicy::Backward => {
            let ranges: Vec<(i64, i64)> =
                ms.stages.iter().map(|s| env.krange(&s.interval)).collect();
            let kmin = ranges.iter().map(|r| r.0).min().unwrap_or(0);
            let kmax = ranges.iter().map(|r| r.1).max().unwrap_or(0);
            let ks: Vec<i64> = if ms.policy == IterationPolicy::Forward {
                (kmin..kmax).collect()
            } else {
                (kmin..kmax).rev().collect()
            };
            for k in ks {
                // Demoted buffers are per-level planes: group scope
                // restarts on every level. Ring planes persist across
                // levels and groups of this multistage.
                let mut group = None;
                for (st, (k0, k1)) in ms.stages.iter().zip(&ranges) {
                    if k >= *k0 && k < *k1 {
                        if group != Some(st.fusion_group) {
                            locals.flush(pool);
                            group = Some(st.fusion_group);
                        }
                        run_stage_region(
                            env, classes, &mut locals, &mut rings, st, k, k + 1, pool,
                            slab,
                        );
                    }
                }
                locals.flush(pool);
                prune_rings(&mut rings, k, depths, pool);
            }
            // Ring state never crosses multistages.
            for (_, (_, b)) in rings.drain() {
                pool.put(b);
            }
        }
    }
}

/// One slab's share of a *sequential* multistage that needs cross-slab
/// halo exchange: the same level loop as [`run_multistage`], run in
/// lockstep with every other slab. Under [`HaloPlan::PerLevel`] the
/// slabs rendezvous once after each k-level — every slab's level-`k`
/// stores are published before any slab reads neighbor columns at the
/// next level. Under [`HaloPlan::PerStage`] they additionally rendezvous
/// between consecutive *executed* stages of a level, ordering same-level
/// cross-slab reads after the stage that produced them. Both schedules
/// are slab-independent (stage k-ranges come from `env.krange`, which
/// never looks at the slab), so the rendezvous can never skew — the
/// [`WorkerPool::run_slabs`] barrier caveat.
///
/// Rings and demoted locals stay slab-local exactly as in the zero-sync
/// sweep; only `Field3D` stores cross the rendezvous.
#[allow(clippy::too_many_arguments)]
fn run_multistage_synced<T: PoolElem>(
    ms: &CMultistage,
    classes: &[StorageClass],
    depths: &[i32],
    env: &EnvView<'_, T>,
    pool: &mut Pool,
    slab: (i64, i64),
    gate: &HaloRendezvous,
    per_stage: bool,
) {
    debug_assert!(matches!(
        ms.policy,
        IterationPolicy::Forward | IterationPolicy::Backward
    ));
    let mut locals = Locals::default();
    let mut rings: Rings<T> = Rings::default();
    let ranges: Vec<(i64, i64)> =
        ms.stages.iter().map(|s| env.krange(&s.interval)).collect();
    let kmin = ranges.iter().map(|r| r.0).min().unwrap_or(0);
    let kmax = ranges.iter().map(|r| r.1).max().unwrap_or(0);
    let ks: Vec<i64> = if ms.policy == IterationPolicy::Forward {
        (kmin..kmax).collect()
    } else {
        (kmin..kmax).rev().collect()
    };
    for k in ks {
        let mut group = None;
        let mut ran_any = false;
        for (st, (k0, k1)) in ms.stages.iter().zip(&ranges) {
            if k >= *k0 && k < *k1 {
                // Stage-granular lockstep: publish the previous stage's
                // owned columns before any slab's same-level wide read.
                if per_stage && ran_any {
                    gate.wait();
                }
                ran_any = true;
                if group != Some(st.fusion_group) {
                    locals.flush(pool);
                    group = Some(st.fusion_group);
                }
                run_stage_region(
                    env, classes, &mut locals, &mut rings, st, k, k + 1, pool, slab,
                );
            }
        }
        locals.flush(pool);
        prune_rings(&mut rings, k, depths, pool);
        // The per-level halo rendezvous: all of this level's stores
        // happen-before any slab's next-level neighbor reads.
        gate.wait();
    }
    for (_, (_, b)) in rings.drain() {
        pool.put(b);
    }
}

fn run_program<T: PoolElem>(program: &Program, env: &EnvView<'_, T>, pool: &mut Pool) {
    let classes: Vec<StorageClass> = program.slots.iter().map(|s| s.storage).collect();
    let depths: Vec<i32> = program.slots.iter().map(|s| s.ring_depth).collect();
    let ni = env.domain[0] as i64;
    for ms in &program.multistages {
        run_multistage(ms, &classes, &depths, env, pool, (0, ni));
    }
}

/// Classify a multistage's cross-slab field flow into the [`HaloPlan`]
/// that makes an i-slab fan-out race-free. Demoted temporaries are always
/// slab-local (recomputed in the halo overlap), so only *undemoted*
/// (`Field3D`) slots written inside the multistage can carry values
/// across a slab boundary:
///
/// * `PARALLEL` multistages get a barrier after every stage, making
///   cross-stage flow through fields safe with no extra plan
///   (`Local`); the one remaining hazard is a stage reading its own
///   `Field3D` target (gather-then-scatter semantics would observe a
///   neighbor slab's concurrent writes whenever the stage's compute
///   extent leaves its slab) — irreducibly `Serial`.
/// * Sequential multistages sweep level by level. A read of a written
///   `Field3D` slot that is column-local (zero i-offset and a zero
///   i-extent on the reading stage) needs nothing. A horizontal read of
///   another level (`off.k != 0`) needs the slabs level-locked:
///   `PerLevel`. A horizontal same-level read of *another* stage's
///   store needs stage-locked slabs on top: `PerStage`. A horizontal
///   same-level read of the stage's *own* target is the in-pass
///   wavefront no rendezvous schedule fixes: `Serial`.
///
/// `Serial` multistages run serially inside an otherwise sharded call —
/// degrading is always bitwise-safe (and now honestly timed).
pub(crate) fn ms_halo_plan(ms: &CMultistage, classes: &[StorageClass]) -> HaloPlan {
    let written: HashSet<usize> = ms
        .stages
        .iter()
        .filter(|st| classes[st.target] == StorageClass::Field3D)
        .map(|st| st.target)
        .collect();
    let mut plan = HaloPlan::Local;
    for st in &ms.stages {
        let wide = st.extent.i != (0, 0);
        st.expr.visit_reads(&mut |slot, off| {
            if classes[slot] != StorageClass::Field3D {
                return;
            }
            let horizontal = off[0] != 0 || wide;
            if !horizontal {
                return;
            }
            let need = match ms.policy {
                IterationPolicy::Parallel => {
                    if slot == st.target {
                        HaloPlan::Serial
                    } else {
                        HaloPlan::Local
                    }
                }
                IterationPolicy::Forward | IterationPolicy::Backward => {
                    if !written.contains(&slot) {
                        HaloPlan::Local
                    } else if off[2] != 0 {
                        HaloPlan::PerLevel
                    } else if slot == st.target {
                        HaloPlan::Serial
                    } else {
                        HaloPlan::PerStage
                    }
                }
            };
            plan = plan.merge(need);
        });
        if plan == HaloPlan::Serial {
            return plan;
        }
    }
    plan
}

/// Shared state of one sharded run: the slab partition, the checked-out
/// worker pool, and per-slab buffer pools / busy-time counters that
/// persist across the run's parallel regions.
pub(crate) struct ShardExec<'a> {
    pub(crate) slabs: Vec<(i64, i64)>,
    workers: &'a WorkerPool,
    /// Per-slab buffer pools (slab 0 inherits the backend's warm pool).
    /// Uncontended Mutexes: slab `s` is only ever touched by one thread
    /// at a time.
    pools: Vec<Mutex<Pool>>,
    /// Per-slab busy nanoseconds, accumulated across parallel regions.
    busy: Vec<AtomicU64>,
    /// Largest fan-out any region of this run actually used.
    used: AtomicU64,
    /// Cross-slab halo rendezvous crossed by this run's sequential
    /// sweeps (see [`ShardReport::exchanges`]).
    exchanges: AtomicU64,
}

impl<'a> ShardExec<'a> {
    pub(crate) fn new(
        slabs: Vec<(i64, i64)>,
        workers: &'a WorkerPool,
        seed_pool: Pool,
    ) -> ShardExec<'a> {
        let n = slabs.len();
        let mut pools = Vec::with_capacity(n);
        pools.push(Mutex::new(seed_pool));
        for _ in 1..n {
            pools.push(Mutex::new(Pool::default()));
        }
        ShardExec {
            slabs,
            workers,
            pools,
            busy: (0..n).map(|_| AtomicU64::new(0)).collect(),
            used: AtomicU64::new(1),
            exchanges: AtomicU64::new(0),
        }
    }

    /// The buffer pool serial fallbacks borrow (slab 0's).
    pub(crate) fn serial_pool(&self) -> std::sync::MutexGuard<'_, Pool> {
        self.pools[0].lock().unwrap()
    }

    /// Record a serial fallback: the calling thread just spent `busy`
    /// running one multistage unsharded, which must show up in the
    /// occupancy columns exactly like fanned-out work (the scaling
    /// bench's honesty requirement), and in the fallback counter.
    pub(crate) fn note_serial_fallback(&self, busy: Duration) {
        self.busy[0].fetch_add(busy.as_nanos() as u64, Ordering::Relaxed);
        self.serial_pool().stats.serial_fallbacks += 1;
    }

    /// Record `n` completed halo rendezvous (once per run region, from
    /// the rendezvous' own crossing counter — never per slab).
    pub(crate) fn note_exchanges(&self, n: u64) {
        self.exchanges.fetch_add(n, Ordering::Relaxed);
        self.pools[0].lock().unwrap().stats.halo_exchanges += n;
    }

    /// Fan `f(slab index, pool)` out over every slab and join. Callers
    /// capture the shared `EnvView` in `f`; all field access inside goes
    /// through its `StorageView`s under the disjoint-write contract (slabs
    /// write disjoint owned i-ranges, cross-slab reads are separated from
    /// the writes they observe by this fork/join or by the barriers the
    /// caller threads through `f`).
    pub(crate) fn run(&self, f: &(dyn Fn(usize, &mut Pool) + Sync)) {
        self.used.fetch_max(self.slabs.len() as u64, Ordering::Relaxed);
        self.workers.run_slabs(self.slabs.len(), &|s| {
            let t0 = Instant::now();
            let mut pool = self.pools[s].lock().unwrap();
            f(s, &mut pool);
            self.busy[s].fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
        });
    }

    /// Merge the per-slab pools back into one and summarize the run.
    pub(crate) fn finish(self) -> (Pool, ShardReport) {
        let mut merged = Pool::default();
        let mut busy: Vec<Duration> = Vec::with_capacity(self.pools.len());
        for (m, b) in self.pools.into_iter().zip(&self.busy) {
            merged.absorb(m.into_inner().unwrap());
            busy.push(Duration::from_nanos(b.load(Ordering::Relaxed)));
        }
        let report = ShardReport {
            threads: self.used.load(Ordering::Relaxed) as u32,
            slabs: self.slabs.len() as u32,
            busy_min: busy.iter().copied().min().unwrap_or_default(),
            busy_max: busy.iter().copied().max().unwrap_or_default(),
            busy_total: busy.iter().sum(),
            exchanges: self.exchanges.load(Ordering::Relaxed),
        };
        (merged, report)
    }
}

/// One fusion group of a sharded `PARALLEL` multistage: a single fan-out
/// whose slabs keep their group-scoped locals alive across stages, with
/// a barrier after every stage so cross-slab readers of `Field3D`
/// outputs observe completed writes (the materializing path's analog of
/// the fused evaluator's tier barriers).
fn run_parallel_group<T: PoolElem>(
    stages: &[CStage],
    classes: &[StorageClass],
    exec: &ShardExec,
    env: &EnvView<'_, T>,
) {
    let barrier = Barrier::new(exec.slabs.len());
    exec.run(&|s, pool| {
        let slab = exec.slabs[s];
        let mut locals = Locals::default();
        let mut rings: Rings<T> = Rings::default();
        for (si, st) in stages.iter().enumerate() {
            let (k0, k1) = env.krange(&st.interval);
            if k0 < k1 {
                run_stage_region(
                    env, classes, &mut locals, &mut rings, st, k0, k1, pool, slab,
                );
            }
            if si + 1 < stages.len() {
                barrier.wait();
            }
        }
        locals.flush(pool);
    });
}

/// The sharded materializing path: each multistage fans out over the
/// slab partition under its [`HaloPlan`] — zero-sync for `Local`,
/// rendezvous-synced sweeps for `PerLevel`/`PerStage`, and an honestly
/// timed serial fallback only for the irreducible `Serial` wavefronts.
fn run_program_sharded<T: PoolElem>(
    program: &Program,
    env: &EnvView<'_, T>,
    exec: &ShardExec,
) {
    let classes: Vec<StorageClass> = program.slots.iter().map(|s| s.storage).collect();
    let depths: Vec<i32> = program.slots.iter().map(|s| s.ring_depth).collect();
    let ni = env.domain[0] as i64;
    for ms in &program.multistages {
        let plan = ms_halo_plan(ms, &classes);
        if plan == HaloPlan::Serial {
            let t0 = Instant::now();
            {
                let mut pool = exec.serial_pool();
                run_multistage(ms, &classes, &depths, env, &mut pool, (0, ni));
            }
            exec.note_serial_fallback(t0.elapsed());
            continue;
        }
        match ms.policy {
            IterationPolicy::Parallel => {
                // One fan-out per fusion group (locals are group-scoped).
                let mut start = 0;
                while start < ms.stages.len() {
                    let gid = ms.stages[start].fusion_group;
                    let mut end = start + 1;
                    while end < ms.stages.len() && ms.stages[end].fusion_group == gid {
                        end += 1;
                    }
                    run_parallel_group(&ms.stages[start..end], &classes, exec, env);
                    start = end;
                }
            }
            IterationPolicy::Forward | IterationPolicy::Backward => {
                if plan == HaloPlan::Local {
                    // Zero-sync slab-local vertical sweeps: every slab
                    // runs the whole k-loop with its own locals and
                    // ring k-cache, no rendezvous at all.
                    exec.run(&|s, pool| {
                        run_multistage(ms, &classes, &depths, env, pool, exec.slabs[s]);
                    });
                } else {
                    // Cross-slab halo exchange: one fan-out running the
                    // sweep level-lockstep (stage-lockstep for PerStage).
                    let gate = HaloRendezvous::new(exec.slabs.len());
                    let per_stage = plan == HaloPlan::PerStage;
                    exec.run(&|s, pool| {
                        run_multistage_synced(
                            ms, &classes, &depths, env, pool, exec.slabs[s], &gate,
                            per_stage,
                        );
                    });
                    exec.note_exchanges(gate.crossings());
                }
            }
        }
    }
}

/// The dtype-monomorphized run body shared by every dispatch path: build
/// the typed view once, then route serial/sharded × materializing/fused.
fn run_typed<T: PoolElem>(
    be: &VectorBackend,
    program: &Program,
    fused: Option<&FusedProgram>,
    env: &mut Env,
    pool: Pool,
    threads: usize,
    tier: ExecTier,
) -> (Pool, ShardReport) {
    let view = env.view::<T>();
    if threads <= 1 {
        let mut pool = pool;
        let t0 = Instant::now();
        if let Some(fp) = fused {
            super::fused::run_program(fp, program, &view, &mut pool, tier);
        } else {
            run_program(program, &view, &mut pool);
        }
        (pool, ShardReport::serial_with(t0.elapsed()))
    } else {
        let workers = be.checkout_workers(threads - 1);
        let exec = ShardExec::new(split_slabs(view.domain[0], threads), &workers, pool);
        if let Some(fp) = fused {
            super::fused::run_program_sharded(fp, program, &view, &exec, tier);
        } else {
            run_program_sharded(program, &view, &exec);
        }
        let (merged, report) = exec.finish();
        be.return_workers(workers);
        (merged, report)
    }
}

impl Backend for VectorBackend {
    fn name(&self) -> &'static str {
        "vector"
    }

    fn prepare(&self, ir: &StencilIr) -> Result<()> {
        self.programs_for(ir)?;
        Ok(())
    }

    fn set_persist(&self, store: &Arc<crate::persist::PersistStore>) {
        *self.persist.lock().unwrap() = Some(store.clone());
    }

    fn run(&self, ir: &StencilIr, args: &mut StencilArgs) -> Result<()> {
        self.run_sharded(ir, args, &RunConfig::default()).map(|_| ())
    }

    fn run_sharded(
        &self,
        ir: &StencilIr,
        args: &mut StencilArgs,
        cfg: &RunConfig,
    ) -> Result<ShardReport> {
        let (program, fused) = self.programs_for(ir)?;
        // Demoted temporaries are never materialized as storages here —
        // every access is served from backend-local buffers.
        let mut env =
            Env::build_with(&program, args.fields, args.scalars, args.domain, false)?;
        // Check the shared pool out for the duration of the run (no lock
        // held while executing; concurrent runs get an empty pool).
        let pool = std::mem::take(&mut *self.pool.lock().unwrap());
        let threads = cfg.sharding.resolve(args.domain[0]);
        // The once-per-run dtype dispatch: everything below is
        // monomorphized over the program's element type.
        let (pool, report) = match program.dtype {
            DType::F64 => run_typed::<f64>(
                self, &program, fused.as_deref(), &mut env, pool, threads, cfg.tier,
            ),
            DType::F32 => run_typed::<f32>(
                self, &program, fused.as_deref(), &mut env, pool, threads, cfg.tier,
            ),
        };
        self.pool.lock().unwrap().absorb(pool);
        env.restore(&program, args.fields);
        Ok(report)
    }

    /// Non-resetting counter peek (contrast
    /// [`VectorBackend::take_pool_stats`], which resets what it reports);
    /// this is what `/metrics` endpoints poll.
    fn pool_stats(&self) -> Option<PoolStats> {
        Some(self.pool.lock().unwrap().stats)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::compile_source;
    use crate::backend::debug::DebugBackend;
    use crate::backend::shard::Sharding;
    use crate::storage::Storage;
    use std::collections::BTreeMap;

    /// Run the same stencil through `debug` (pre-opt IR), `vector`
    /// (pre-opt IR), `vector` (fully optimized IR, with demoted
    /// temporaries) and `vector` (fused loop-nest evaluator, opt-level 3)
    /// on identical pseudo-random inputs and require bitwise-equal outputs
    /// from all four.
    fn assert_backends_agree(src: &str, name: &str, out_names: &[&str], domain: [usize; 3]) {
        let ir = compile_source(src, name, &BTreeMap::new()).unwrap();
        let ir_opt = crate::analysis::compile_source_opt(
            src,
            name,
            &BTreeMap::new(),
            &crate::opt::OptConfig::default(),
        )
        .unwrap();
        let ir_fused = crate::analysis::compile_source_opt(
            src,
            name,
            &BTreeMap::new(),
            &crate::opt::OptConfig::level(crate::opt::OptLevel::O3),
        )
        .unwrap();
        assert!(ir_fused.fused);
        let halo = 3usize;
        // deterministic LCG inputs
        let mut seed = 42u64;
        let mut rand = move || {
            seed = seed.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            ((seed >> 33) as f64) / (u32::MAX as f64) - 0.5
        };
        let mut make = |_: &str| Storage::from_fn_extended(domain, halo, |_, _, _| rand());
        let names: Vec<String> = ir.fields.iter().map(|f| f.name.clone()).collect();
        let mut d_fields: Vec<Storage> = names.iter().map(|n| make(n)).collect();
        let mut v_fields: Vec<Storage> = d_fields.clone();
        let mut o_fields: Vec<Storage> = d_fields.clone();
        let mut f_fields: Vec<Storage> = d_fields.clone();
        let scalars: Vec<(&str, f64)> =
            ir.scalars.iter().map(|s| (s.name.as_str(), 0.37)).collect();

        {
            let mut refs: Vec<(&str, &mut Storage)> = names
                .iter()
                .map(|n| n.as_str())
                .zip(d_fields.iter_mut())
                .collect();
            let be = DebugBackend::new();
            be.run(&ir, &mut StencilArgs { fields: &mut refs, scalars: &scalars, domain })
                .unwrap();
        }
        {
            let mut refs: Vec<(&str, &mut Storage)> = names
                .iter()
                .map(|n| n.as_str())
                .zip(v_fields.iter_mut())
                .collect();
            let be = VectorBackend::new();
            be.run(&ir, &mut StencilArgs { fields: &mut refs, scalars: &scalars, domain })
                .unwrap();
        }
        {
            let mut refs: Vec<(&str, &mut Storage)> = names
                .iter()
                .map(|n| n.as_str())
                .zip(o_fields.iter_mut())
                .collect();
            let be = VectorBackend::new();
            be.run(&ir_opt, &mut StencilArgs { fields: &mut refs, scalars: &scalars, domain })
                .unwrap();
        }
        {
            let mut refs: Vec<(&str, &mut Storage)> = names
                .iter()
                .map(|n| n.as_str())
                .zip(f_fields.iter_mut())
                .collect();
            let be = VectorBackend::new();
            be.run(&ir_fused, &mut StencilArgs { fields: &mut refs, scalars: &scalars, domain })
                .unwrap();
        }
        for (n, (((d, v), o), f)) in names
            .iter()
            .zip(d_fields.iter().zip(&v_fields).zip(&o_fields).zip(&f_fields))
        {
            if out_names.contains(&n.as_str()) {
                assert_eq!(d.max_abs_diff(v), 0.0, "field `{n}` differs (pre-opt)");
                assert_eq!(d.max_abs_diff(o), 0.0, "field `{n}` differs (optimized)");
                assert_eq!(d.max_abs_diff(f), 0.0, "field `{n}` differs (fused)");
            }
        }
    }

    #[test]
    fn agrees_on_laplacian() {
        assert_backends_agree(
            "function lap(p) {\n\
               return -4.0*p[0,0,0] + p[-1,0,0] + p[1,0,0] + p[0,-1,0] + p[0,1,0];\n\
             }\n\
             stencil s(a: Field<f64>, out: Field<f64>) {\n\
               with computation(PARALLEL), interval(...) { out = lap(lap(a)); }\n\
             }",
            "s",
            &["out"],
            [7, 6, 3],
        );
    }

    #[test]
    fn agrees_on_sequential_solver() {
        assert_backends_agree(
            "stencil tri(a: Field<f64>, b: Field<f64>, x: Field<f64>) {\n\
               with computation(FORWARD) {\n\
                 interval(0, 1) { x = a; }\n\
                 interval(1, None) { x = x[0,0,-1] * 0.5 + a * b; }\n\
               }\n\
               with computation(BACKWARD) {\n\
                 interval(-1, None) { b = x; }\n\
                 interval(0, -1) { b = b[0,0,1] * 0.25 + x; }\n\
               }\n\
             }",
            "tri",
            &["b", "x"],
            [5, 4, 6],
        );
    }

    #[test]
    fn agrees_on_conditionals() {
        assert_backends_agree(
            "stencil s(a: Field<f64>, out: Field<f64>; lim: f64) {\n\
               with computation(PARALLEL), interval(...) {\n\
                 g = a[1,0,0] - a[-1,0,0];\n\
                 out = g * a > lim ? g : lim;\n\
                 if out > 0.0 { out = out * 2.0; } else { out = a; }\n\
               }\n\
             }",
            "s",
            &["out"],
            [6, 6, 2],
        );
    }

    #[test]
    fn agrees_on_builtins() {
        assert_backends_agree(
            "stencil s(a: Field<f64>, out: Field<f64>) {\n\
               with computation(PARALLEL), interval(...) {\n\
                 out = max(abs(a[1,0,0]), abs(a[-1,0,0])) + sqrt(abs(a)) + exp(min(a, 0.5));\n\
               }\n\
             }",
            "s",
            &["out"],
            [5, 5, 4],
        );
    }

    #[test]
    fn scalar_const_folding_matches() {
        assert_backends_agree(
            "stencil s(a: Field<f64>, out: Field<f64>; w: f64) {\n\
               with computation(PARALLEL), interval(...) { out = a * (w * 2.0 + 1.0); }\n\
             }",
            "s",
            &["out"],
            [4, 4, 2],
        );
    }

    #[test]
    fn agrees_with_k_offsets_in_parallel() {
        // Non-zero k offsets exercise the 3-D region gather path.
        assert_backends_agree(
            "stencil s(a: Field<f64>, out: Field<f64>) {\n\
               with computation(PARALLEL), interval(...) {\n\
                 t = a[0,0,1] - a[0,0,-1];\n\
                 out = t[1,0,0] + t[-1,0,0] + a[0,1,1];\n\
               }\n\
             }",
            "s",
            &["out"],
            [6, 5, 4],
        );
    }

    #[test]
    fn f32_programs_run_all_vector_paths() {
        // The dtype tentpole at the backend level: an f32 stencil runs the
        // materializing, optimized and fused vector paths and each stays
        // bitwise-identical to the f32 debug interpreter — while genuinely
        // differing from the f64 run of the same program.
        const SRC64: &str = "
            stencil s(a: Field<f64>, out: Field<f64>) {
                with computation(PARALLEL), interval(...) {
                    t = a * 0.1 + a[1,0,0];
                    out = t + t[-1,0,0] * 0.3;
                }
            }";
        let src32 = SRC64.replace("f64", "f32");
        let domain = [6, 5, 4];
        let run = |src: &str, dtype: DType, level: crate::opt::OptLevel| -> Storage {
            let ir = crate::analysis::compile_source_opt(
                src,
                "s",
                &BTreeMap::new(),
                &crate::opt::OptConfig::level(level),
            )
            .unwrap();
            let info = crate::storage::StorageInfo::new(domain, [(3, 3); 3]).with_dtype(dtype);
            let mut fields: Vec<Storage> = (0..2)
                .map(|_| {
                    let mut s = Storage::zeros(info);
                    for i in -3..domain[0] as i64 + 3 {
                        for j in -3..domain[1] as i64 + 3 {
                            for k in -3..domain[2] as i64 + 3 {
                                s.set(i, j, k, ((i * 7 + j * 3 + k) as f64) * 0.013);
                            }
                        }
                    }
                    s
                })
                .collect();
            let be = VectorBackend::new();
            let mut refs: Vec<(&str, &mut Storage)> =
                ["a", "out"].into_iter().zip(fields.iter_mut()).collect();
            be.run(&ir, &mut StencilArgs { fields: &mut refs, scalars: &[], domain })
                .unwrap();
            fields.pop().unwrap()
        };
        let debug32 = {
            let ir = compile_source(&src32, "s", &BTreeMap::new()).unwrap();
            let info =
                crate::storage::StorageInfo::new(domain, [(3, 3); 3]).with_dtype(DType::F32);
            let mut fields: Vec<Storage> = (0..2)
                .map(|_| {
                    let mut s = Storage::zeros(info);
                    for i in -3..domain[0] as i64 + 3 {
                        for j in -3..domain[1] as i64 + 3 {
                            for k in -3..domain[2] as i64 + 3 {
                                s.set(i, j, k, ((i * 7 + j * 3 + k) as f64) * 0.013);
                            }
                        }
                    }
                    s
                })
                .collect();
            let be = DebugBackend::new();
            let mut refs: Vec<(&str, &mut Storage)> =
                ["a", "out"].into_iter().zip(fields.iter_mut()).collect();
            be.run(&ir, &mut StencilArgs { fields: &mut refs, scalars: &[], domain })
                .unwrap();
            fields.pop().unwrap()
        };
        for level in [
            crate::opt::OptLevel::O0,
            crate::opt::OptLevel::O2,
            crate::opt::OptLevel::O3,
        ] {
            let got = run(&src32, DType::F32, level);
            assert_eq!(got.dtype(), DType::F32);
            assert_eq!(
                got.domain_hash(),
                debug32.domain_hash(),
                "O{level} f32 vector != f32 debug"
            );
        }
        // And the widths are genuinely different computations.
        let got64 = run(SRC64, DType::F64, crate::opt::OptLevel::O3);
        assert_ne!(got64.domain_hash(), debug32.domain_hash());
        assert!(got64.max_abs_diff(&debug32) > 0.0, "f32 must round differently");
    }

    #[test]
    fn demoted_hdiff_runs_without_temp_storages() {
        // The headline demotion case: all three hdiff temporaries demote
        // (to plane scratch — they are offset-read), and the result stays
        // bitwise equal to debug.
        let ir_opt = crate::analysis::compile_source_opt(
            crate::stdlib::HDIFF_SRC,
            "hdiff",
            &BTreeMap::new(),
            &crate::opt::OptConfig::default(),
        )
        .unwrap();
        assert!(ir_opt
            .temporaries
            .iter()
            .all(|t| t.storage == crate::ir::implir::StorageClass::Plane));
        assert_backends_agree(
            crate::stdlib::HDIFF_SRC,
            "hdiff",
            &["out_phi"],
            [9, 8, 4],
        );
    }

    #[test]
    fn ring_carry_matches_reference() {
        // A FORWARD sweep carry demoted to the plane ring (k-cache): both
        // vector paths must stay bitwise equal to debug.
        const SRC: &str = "
            stencil ringy(a: Field<f64>, x: Field<f64>) {
                with computation(FORWARD) {
                    interval(0, 1) { t = a * 0.5; x = t; }
                    interval(1, None) { t = a + t[0,0,-1] * 0.9; x = t - t[0,0,-1]; }
                }
            }";
        let ir = crate::analysis::compile_source_opt(
            SRC,
            "ringy",
            &BTreeMap::new(),
            &crate::opt::OptConfig::default(),
        )
        .unwrap();
        assert_eq!(
            ir.temporary("t").unwrap().storage,
            crate::ir::implir::StorageClass::Ring
        );
        assert_backends_agree(SRC, "ringy", &["x"], [5, 4, 9]);
    }

    #[test]
    fn ring_with_horizontal_offsets_matches_reference() {
        const SRC: &str = "
            stencil ringh(a: Field<f64>, x: Field<f64>) {
                with computation(FORWARD) {
                    interval(0, 1) { t = a; u = t; x = u; }
                    interval(1, None) {
                        t = a + t[0,0,-1] * 0.5;
                        u = t[1,0,-1] + t[-1,0,-1];
                        x = u * 0.5;
                    }
                }
            }";
        let ir = crate::analysis::compile_source_opt(
            SRC,
            "ringh",
            &BTreeMap::new(),
            &crate::opt::OptConfig::default(),
        )
        .unwrap();
        assert_eq!(
            ir.temporary("t").unwrap().storage,
            crate::ir::implir::StorageClass::Ring
        );
        assert_backends_agree(SRC, "ringh", &["x"], [6, 5, 8]);
    }

    #[test]
    fn fused_path_allocates_no_per_node_buffers() {
        // The fused evaluator's pool traffic per call is bounded by
        // (scratch locals + one strip buffer per tier), not by the
        // expression-node count the materializing path pays.
        let domain = [16, 14, 8];
        let run_at = |level: crate::opt::OptLevel| {
            let ir = crate::analysis::compile_source_opt(
                crate::stdlib::HDIFF_SRC,
                "hdiff",
                &BTreeMap::new(),
                &crate::opt::OptConfig::level(level),
            )
            .unwrap();
            let names: Vec<String> = ir.fields.iter().map(|f| f.name.clone()).collect();
            let mut fields: Vec<Storage> = names
                .iter()
                .map(|_| Storage::from_fn_extended(domain, 3, |i, j, k| {
                    (i * 3 + j * 5 + k * 7) as f64 * 0.125
                }))
                .collect();
            let be = VectorBackend::new();
            {
                let mut refs: Vec<(&str, &mut Storage)> = names
                    .iter()
                    .map(|n| n.as_str())
                    .zip(fields.iter_mut())
                    .collect();
                be.run(&ir, &mut StencilArgs { fields: &mut refs, scalars: &[], domain })
                    .unwrap();
            }
            be.take_pool_stats().taken
        };
        let materializing = run_at(crate::opt::OptLevel::O2);
        let fused = run_at(crate::opt::OptLevel::O3);
        // hdiff fused: exactly the 3 plane-scratch buffers (lapf/flx/fly).
        assert!(fused <= 4, "fused path took {fused} buffers");
        assert!(
            fused < materializing / 3,
            "fused {fused} vs materializing {materializing}"
        );
    }

    #[test]
    fn demoted_sequential_group_matches_reference() {
        // av/denom demote inside the interval(1,None) FORWARD group of a
        // Thomas solve; cp/dp carry across levels and must stay fields.
        assert_backends_agree(
            crate::stdlib::VADV_SRC,
            "vadv",
            &["phi"],
            [5, 4, 7],
        );
    }

    #[test]
    fn sharded_runs_are_bitwise_identical_to_serial() {
        use crate::backend::shard::Sharding;
        // Backend-level check (tests/property_equivalence.rs sweeps many
        // more programs): hdiff (PARALLEL) and vadv (sequential sweep,
        // Field3D carries) on both the materializing (O2) and fused (O3)
        // paths, Threads(1..=3) vs Off, bitwise. The odd domain width
        // exercises uneven slab splits.
        let domain = [13, 9, 6];
        for (name, scalars) in
            [("hdiff", vec![]), ("vadv", vec![("dtdz", 0.3f64)])]
        {
            for level in [crate::opt::OptLevel::O2, crate::opt::OptLevel::O3] {
                let ir = crate::analysis::compile_source_opt(
                    crate::stdlib::source(name).unwrap(),
                    name,
                    &BTreeMap::new(),
                    &crate::opt::OptConfig::level(level),
                )
                .unwrap();
                let names: Vec<String> =
                    ir.fields.iter().map(|f| f.name.clone()).collect();
                let be = VectorBackend::new();
                let run_with = |sharding: Sharding| -> (Vec<Storage>, ShardReport) {
                    let mut fields: Vec<Storage> = names
                        .iter()
                        .map(|_| {
                            Storage::from_fn_extended(domain, 3, |i, j, k| {
                                ((i * 5 + j * 3 + k * 11) as f64 * 0.37).sin()
                            })
                        })
                        .collect();
                    let report = {
                        let mut refs: Vec<(&str, &mut Storage)> = names
                            .iter()
                            .map(|n| n.as_str())
                            .zip(fields.iter_mut())
                            .collect();
                        be.run_sharded(
                            &ir,
                            &mut StencilArgs {
                                fields: &mut refs,
                                scalars: &scalars,
                                domain,
                            },
                            &RunConfig { sharding, ..RunConfig::default() },
                        )
                        .unwrap()
                    };
                    (fields, report)
                };
                let (reference, rep0) = run_with(Sharding::Off);
                assert_eq!(rep0.threads, 1);
                for t in 1..=3usize {
                    let (got, rep) = run_with(Sharding::Threads(t));
                    assert_eq!(rep.threads, t as u32, "{name} O{level} threads");
                    for (n, (r, g)) in names.iter().zip(reference.iter().zip(&got)) {
                        assert_eq!(
                            r.max_abs_diff(g),
                            0.0,
                            "{name} O{level} Threads({t}): field `{n}` diverged"
                        );
                    }
                }
            }
        }
    }

    /// Shared driver for the halo-plan execution tests: run `SRC` at
    /// `level` under `sharding`, returning the fields and the report.
    fn run_carry_source(
        src: &str,
        field_names: &[&str],
        domain: [usize; 3],
        level: crate::opt::OptLevel,
        sharding: Sharding,
    ) -> (Vec<Storage>, ShardReport) {
        let ir = crate::analysis::compile_source_opt(
            src,
            "s",
            &BTreeMap::new(),
            &crate::opt::OptConfig::level(level),
        )
        .unwrap();
        let be = VectorBackend::new();
        let mut fields: Vec<Storage> = (0..field_names.len())
            .map(|f| {
                Storage::from_fn_extended(domain, 2, move |i, j, k| {
                    (i * 7 + j * 2 + k * 3 + f) as f64 * 0.01
                })
            })
            .collect();
        let report = {
            let mut refs: Vec<(&str, &mut Storage)> = field_names
                .iter()
                .copied()
                .zip(fields.iter_mut())
                .collect();
            be.run_sharded(
                &ir,
                &mut StencilArgs { fields: &mut refs, scalars: &[], domain },
                &RunConfig { sharding, ..RunConfig::default() },
            )
            .unwrap()
        };
        (fields, report)
    }

    #[test]
    fn cross_level_carry_runs_sharded_with_halo_exchange() {
        use crate::backend::shard::Sharding;
        // A FORWARD sweep carrying state in a *field* read at a horizontal
        // offset used to degrade to serial; under the per-level halo
        // exchange it must fan out (threads > 1, exchanges > 0) and stay
        // bitwise equal to the serial run at every opt level.
        const SRC: &str = "
            stencil s(a: Field<f64>, x: Field<f64>) {
                with computation(FORWARD) {
                    interval(0, 1) { x = a; }
                    interval(1, None) { x = a + x[1,0,-1] * 0.5; }
                }
            }";
        let domain = [10, 6, 7];
        for level in [crate::opt::OptLevel::O0, crate::opt::OptLevel::O3] {
            let (reference, rep0) =
                run_carry_source(SRC, &["a", "x"], domain, level, Sharding::Off);
            assert_eq!(rep0.threads, 1);
            assert_eq!(rep0.exchanges, 0);
            let (got, rep) =
                run_carry_source(SRC, &["a", "x"], domain, level, Sharding::Threads(3));
            assert_eq!(
                rep.threads, 3,
                "cross-level carry must shard under halo exchange, O{level}"
            );
            // One rendezvous per swept level (k = 0..7).
            assert_eq!(rep.exchanges, 7, "per-level rendezvous count, O{level}");
            for (r, g) in reference.iter().zip(&got) {
                assert_eq!(r.max_abs_diff(g), 0.0, "O{level} diverged");
            }
        }
    }

    #[test]
    fn same_level_cross_stage_carry_runs_stage_lockstep() {
        use crate::backend::shard::Sharding;
        // Stage 2 reads stage 1's same-level store at an i-offset: the
        // plan must escalate to per-stage rendezvous, still sharded and
        // still bitwise-exact.
        const SRC: &str = "
            stencil s(a: Field<f64>, x: Field<f64>, y: Field<f64>) {
                with computation(FORWARD) {
                    interval(0, 1) { x = a; y = x[1,0,0] + x[-1,0,0]; }
                    interval(1, None) {
                        x = a + x[0,0,-1] * 0.5;
                        y = (x[1,0,0] + x[-1,0,0]) * 0.5;
                    }
                }
            }";
        let domain = [12, 4, 5];
        for level in [crate::opt::OptLevel::O0, crate::opt::OptLevel::O3] {
            let (reference, _) =
                run_carry_source(SRC, &["a", "x", "y"], domain, level, Sharding::Off);
            let (got, rep) =
                run_carry_source(SRC, &["a", "x", "y"], domain, level, Sharding::Threads(4));
            assert!(
                rep.threads > 1,
                "same-level cross-stage carry must shard, O{level}"
            );
            assert!(rep.exchanges > 0, "stage rendezvous must be counted, O{level}");
            for (r, g) in reference.iter().zip(&got) {
                assert_eq!(r.max_abs_diff(g), 0.0, "O{level} diverged");
            }
        }
    }

    #[test]
    fn in_stage_wavefront_still_degrades_to_serial_and_stays_exact() {
        use crate::backend::shard::Sharding;
        // A stage reading its *own* same-level store at an i-offset is the
        // irreducible wavefront: no rendezvous schedule fixes it, so the
        // plan must stay Serial (threads reported as 1) and the result
        // must stay bitwise equal to the serial run.
        const SRC: &str = "
            stencil s(a: Field<f64>, x: Field<f64>) {
                with computation(FORWARD) {
                    interval(0, None) { x = a + x[1,0,0] * 0.5; }
                }
            }";
        let domain = [10, 6, 7];
        for level in [crate::opt::OptLevel::O0, crate::opt::OptLevel::O3] {
            let (reference, _) =
                run_carry_source(SRC, &["a", "x"], domain, level, Sharding::Off);
            let (got, rep) =
                run_carry_source(SRC, &["a", "x"], domain, level, Sharding::Threads(3));
            assert_eq!(
                rep.threads, 1,
                "in-stage wavefront must report serial execution, O{level}"
            );
            assert_eq!(rep.exchanges, 0, "serial fallback exchanges, O{level}");
            assert!(
                rep.busy_total > Duration::ZERO,
                "serial fallback must report honest busy time, O{level}"
            );
            for (r, g) in reference.iter().zip(&got) {
                assert_eq!(r.max_abs_diff(g), 0.0, "O{level} diverged");
            }
        }
    }

    #[test]
    fn agrees_on_interval_split_regions() {
        assert_backends_agree(
            "stencil s(a: Field<f64>, b: Field<f64>) {\n\
               with computation(PARALLEL) {\n\
                 interval(0, 2) { b = a * 10.0; }\n\
                 interval(2, -1) { b = a * 20.0; }\n\
                 interval(-1, None) { b = a * 30.0; }\n\
               }\n\
             }",
            "s",
            &["b"],
            [4, 4, 7],
        );
    }
}
