//! The fused loop-nest evaluator — loop fusion proper for the `vector`
//! backend (`--opt-level 3`).
//!
//! The materializing vector path (the NumPy analog) pays one whole-region
//! memory round trip per expression node. This module instead compiles each
//! fusion group's stages into flat SSA tapes ([`CTape`]) and evaluates
//! every output and demoted temporary of the group in one loop nest per
//! interval: intermediate values live in a short strip buffer (one strip
//! per tape value, along the storage's stride-1 axis) that stays cache
//! resident, and demoted temporaries live in registers (pure SSA values), a
//! group-scoped plane/region scratch, or a ring of recent level planes (a
//! k-cache) — depending on their [`StorageClass`] and vertical offsets.
//! *No per-expression-node region buffer is ever allocated.*
//!
//! Tape construction value-numbers across all stages of a tier, extending
//! the within-stage CSE of `opt/foldcse` across stages of one group.
//!
//! ## Tiers
//!
//! A group's stages are split into *tiers*, full passes over the loop nest
//! in stage order. A new tier starts exactly where per-point evaluation
//! would observe a neighbor value that the same pass has not produced yet
//! (a read at a horizontal offset of something defined earlier in the
//! group), or would overwrite values a neighbor read still needs (a write
//! to something the current tier read at a horizontal offset). Everything
//! else — zero-offset flow, vertical offsets along the strip, ring reads of
//! finalized levels — fuses into a single pass. hdiff, for example, runs as
//! three passes (lapf; the fluxes; the output) instead of six materializing
//! stages with ~30 region-buffer round trips.
//!
//! ## Loop structure
//!
//! PARALLEL groups iterate `i`/`j` with the tape evaluated over the whole
//! `k` interval per point (contiguous strips for the IJK layout), so
//! gathers degenerate to `copy_from_slice` and the arithmetic loops
//! auto-vectorize. Sequential (FORWARD/BACKWARD) multistages iterate
//! level-outermost as their semantics demand, evaluating the tape over
//! `j`-strips per (`i`, level).
//!
//! ## Execution tiers
//!
//! Each compiled tier carries, besides its interpretable tape, a lowered
//! [`TierPlan`] of monomorphized kernels (see [`crate::backend::kernels`]).
//! [`ExecTier`] selects the executor at run time: `Interpreted` walks the
//! tape through [`eval_strip`], `Specialized` (the default) runs the plan
//! with pre-resolved accesses, hoisted guards and cache-blocked interior
//! spans. Both are bitwise-identical by contract; the opt-in `fast-math`
//! relaxation is a *compile*-time property of the plan (it salts the
//! fingerprint) and only ever engages in the specialized executor.
//!
//! ## Dtype generality
//!
//! The compiled artifacts (tapes, kernel plans, bounds) are dtype-agnostic
//! — constants stay `f64` in the tape and are narrowed once per strip via
//! [`Element::from_f64`] (round-to-nearest, deterministic). The evaluators
//! are generic over `T: Element` and field access goes through the shared
//! [`EnvView`]'s `StorageView`s under the disjoint-write contract of
//! `storage/view.rs`, so serial and sharded execution share one evaluator
//! per dtype with no `&mut` aliasing.
//!
//! Bitwise equivalence to the `debug` reference interpreter at every opt
//! level is enforced by `tests/property_equivalence.rs`.

use super::cexpr::{
    apply_bin, apply_builtin1, apply_builtin2, CTape, TapeBuilder, TapeCtx, TapeInst, TapeOp,
};
use super::kernels::{self, ExecTier, TierPlan};
use super::program::{CStage, EnvView, Program};
use super::shard::{HaloPlan, HaloRendezvous};
use super::vector::{prune_rings, Pool, PoolElem, Region, Rings, ShardExec};
use crate::dsl::ast::{BinOp, Interval, IterationPolicy, Offset};
use crate::ir::implir::{Extent, StorageClass};
use crate::storage::Element;
use std::collections::{HashMap, HashSet};
use std::time::Instant;

/// Group-scoped scratch buffers for plane/register locals, dense by slot:
/// `scratch[slot] = Some((region, values))` for the group's scratch-backed
/// locals, `None` elsewhere — no hashing on the strip path.
pub(crate) type Scratch<T> = Vec<Option<(Region, Vec<T>)>>;

/// A fused group: consecutive stages of one multistage sharing a fusion
/// group id (and therefore a vertical interval).
#[derive(Debug, Clone)]
pub struct FusedGroup {
    pub interval: Interval,
    /// Register/plane locals that need a group-scoped scratch buffer
    /// (offset reads or cross-tier flow), with their allocation extents.
    pub scratch: Vec<(usize, Extent)>,
    pub tiers: Vec<Tier>,
}

/// One full pass over the group's loop nest.
#[derive(Debug, Clone)]
pub struct Tier {
    /// Loop bounds: union of the member stages' compute extents.
    pub extent: Extent,
    pub tape: CTape,
    /// The specialized executor's lowering of `tape` (monomorphized
    /// kernels + reorder-safety verdict), built once at program compile.
    pub(crate) plan: TierPlan,
}

#[derive(Debug, Clone)]
pub struct FusedMultistage {
    pub policy: IterationPolicy,
    pub groups: Vec<FusedGroup>,
    /// The synchronization schedule an i-slab fan-out needs (see
    /// [`ms_halo_plan_fused`]); [`HaloPlan::Serial`] entries run serially
    /// inside an otherwise sharded call.
    pub halo: HaloPlan,
}

/// The fused form of a whole stencil program.
#[derive(Debug, Clone)]
pub struct FusedProgram {
    pub multistages: Vec<FusedMultistage>,
    /// Allocation extent per slot, dense by slot index (for demoted slots:
    /// the analysis extent unioned with every writer's compute extent) —
    /// sizes scratch buffers and ring planes with no hashing at run time.
    pub(crate) alloc: Vec<Extent>,
}

impl FusedProgram {
    /// Compile the fused form. `fast_math` must match the IR's
    /// (fingerprint-salted) flag: it selects whether tier plans contract
    /// FMAs, and the caller caches fused programs by IR fingerprint.
    pub fn compile(program: &Program, fast_math: bool) -> FusedProgram {
        let classes: Vec<StorageClass> =
            program.slots.iter().map(|s| s.storage).collect();
        let mut alloc: Vec<Extent> =
            program.slots.iter().map(|s| s.extent).collect();
        for ms in &program.multistages {
            for st in &ms.stages {
                if classes[st.target] != StorageClass::Field3D {
                    alloc[st.target] = alloc[st.target].union(st.extent);
                }
            }
        }
        let mut multistages = Vec::new();
        for ms in &program.multistages {
            let mut groups = Vec::new();
            let mut start = 0;
            while start < ms.stages.len() {
                let gid = ms.stages[start].fusion_group;
                let mut end = start + 1;
                while end < ms.stages.len() && ms.stages[end].fusion_group == gid {
                    end += 1;
                }
                groups.push(compile_group(
                    &ms.stages[start..end],
                    &classes,
                    &alloc,
                    fast_math,
                ));
                start = end;
            }
            let halo = ms_halo_plan_fused(&groups, ms.policy);
            multistages.push(FusedMultistage { policy: ms.policy, groups, halo });
        }
        FusedProgram { multistages, alloc }
    }

    /// Total tier count — the number of loop-nest passes per call (the
    /// fused analog of "number of materialized stages").
    pub fn num_tiers(&self) -> usize {
        self.multistages
            .iter()
            .flat_map(|m| &m.groups)
            .map(|g| g.tiers.len())
            .sum()
    }

    /// Render the compiled tapes and their kernel plans (`repro ir
    /// --tapes`): per tier the extent, reorder verdict and guard-free
    /// interior rectangle for the full-domain slab, then every op with
    /// its kernel class, region and resolved loop bounds.
    pub fn dump_tapes(&self, program: &Program, domain: [usize; 3]) -> String {
        use std::fmt::Write as _;
        let slot_name = |slot: usize| program.slots[slot].name.as_str();
        let ni = domain[0] as i64;
        let mut out = String::new();
        for (mi, ms) in self.multistages.iter().enumerate() {
            let _ = writeln!(
                out,
                "multistage {mi}: {:?} halo={}",
                ms.policy, ms.halo
            );
            for (gi, g) in ms.groups.iter().enumerate() {
                let scratch: Vec<&str> =
                    g.scratch.iter().map(|(s, _)| slot_name(*s)).collect();
                let _ = writeln!(
                    out,
                    "  group {gi}: tiers={} scratch=[{}]",
                    g.tiers.len(),
                    scratch.join(", ")
                );
                let gbounds = resolve_bounds(g, domain, (0, ni));
                for (ti, (t, bounds)) in g.tiers.iter().zip(&gbounds).enumerate() {
                    let (mut ii0, mut ii1) = (i64::MIN, i64::MAX);
                    let (mut ij0, mut ij1) = (i64::MIN, i64::MAX);
                    for b in bounds {
                        ii0 = ii0.max(b[0]);
                        ii1 = ii1.min(b[1]);
                        ij0 = ij0.max(b[2]);
                        ij1 = ij1.min(b[3]);
                    }
                    let _ = writeln!(
                        out,
                        "    tier {ti}: extent {} {} interior i[{ii0},{ii1}) j[{ij0},{ij1})",
                        t.extent,
                        if t.plan.reorderable {
                            "reorderable"
                        } else {
                            "strip-ordered"
                        },
                    );
                    for (x, (inst, b)) in t.tape.ops.iter().zip(bounds).enumerate() {
                        let _ = writeln!(
                            out,
                            "      %{x:<3} {:<11} {:<24} region {} bounds i[{},{}) j[{},{})",
                            t.plan.kernels[x].name(),
                            fmt_tape_op(&inst.op, program),
                            inst.region,
                            b[0],
                            b[1],
                            b[2],
                            b[3],
                        );
                    }
                }
            }
        }
        out
    }
}

/// Compact one-line rendering of a tape op for `dump_tapes`.
fn fmt_tape_op(op: &TapeOp, program: &Program) -> String {
    let name = |slot: &usize| program.slots[*slot].name.clone();
    let off = |o: &Offset| format!("[{},{},{}]", o[0], o[1], o[2]);
    match op {
        TapeOp::Const(c) => format!("const {c}"),
        TapeOp::Scalar(ix) => format!("scalar {}", program.scalar_names[*ix]),
        TapeOp::Load { slot, off: o } => format!("load {}{}", name(slot), off(o)),
        TapeOp::LoadLocal { slot, off: o } => {
            format!("load.local {}{}", name(slot), off(o))
        }
        TapeOp::Neg(a) => format!("neg %{a}"),
        TapeOp::Not(a) => format!("not %{a}"),
        TapeOp::Bin(op, a, b) => format!("{op:?} %{a} %{b}").to_lowercase(),
        TapeOp::Select(c, t, f) => format!("select %{c} %{t} %{f}"),
        TapeOp::Call1(f, a) => format!("{f:?} %{a}").to_lowercase(),
        TapeOp::Call2(f, a, b) => format!("{f:?} %{a} %{b}").to_lowercase(),
        TapeOp::StoreField { slot, v } => format!("store {} %{v}", name(slot)),
        TapeOp::StoreLocal { slot, v } => format!("store.local {} %{v}", name(slot)),
    }
}

fn compile_group(
    stages: &[CStage],
    classes: &[StorageClass],
    alloc: &[Extent],
    fast_math: bool,
) -> FusedGroup {
    let reads: Vec<Vec<(usize, Offset)>> = stages
        .iter()
        .map(|st| {
            let mut v = Vec::new();
            st.expr.visit_reads(&mut |slot, off| v.push((slot, off)));
            v
        })
        .collect();

    // Tier assignment. A horizontal-offset read observes *neighbor* points
    // of the current pass, so it must run a full pass after the producer;
    // a write into something this pass offset-read would corrupt neighbor
    // reads at already-visited points. Zero-offset and pure-vertical flow
    // is per-point/per-column and fuses freely.
    let mut tier = vec![0usize; stages.len()];
    let mut cur = 0usize;
    let mut tier_of_def: HashMap<usize, usize> = HashMap::new();
    let mut offset_read: HashSet<usize> = HashSet::new();
    for (si, st) in stages.iter().enumerate() {
        let mut req = cur;
        for (slot, off) in &reads[si] {
            if off[0] != 0 || off[1] != 0 {
                if let Some(&t) = tier_of_def.get(slot) {
                    req = req.max(t + 1);
                }
            }
        }
        if req == cur && offset_read.contains(&st.target) {
            req = cur + 1;
        }
        if req > cur {
            cur = req;
            offset_read.clear();
        }
        tier[si] = cur;
        for (slot, off) in &reads[si] {
            if off[0] != 0 || off[1] != 0 {
                offset_read.insert(*slot);
            }
        }
        tier_of_def.insert(st.target, cur);
    }

    // Which register/plane locals need a scratch buffer: any horizontal-
    // offset read, or zero-offset flow that crosses a tier boundary
    // (same-tier zero-offset flow rides the SSA value instead).
    let mut scratch_flags = vec![false; classes.len()];
    {
        let mut last_write_tier: HashMap<usize, usize> = HashMap::new();
        for (si, st) in stages.iter().enumerate() {
            for (slot, off) in &reads[si] {
                if matches!(classes[*slot], StorageClass::Register | StorageClass::Plane) {
                    if off[0] != 0 || off[1] != 0 {
                        scratch_flags[*slot] = true;
                    } else if let Some(&t) = last_write_tier.get(slot) {
                        if t != tier[si] {
                            scratch_flags[*slot] = true;
                        }
                    }
                }
            }
            if matches!(classes[st.target], StorageClass::Register | StorageClass::Plane) {
                last_write_tier.insert(st.target, tier[si]);
            }
        }
    }

    // Build one tape per tier, value-numbering across its stages.
    let ntiers = tier.iter().copied().max().unwrap_or(0) + 1;
    let mut tiers = Vec::with_capacity(ntiers);
    let mut written: HashSet<usize> = HashSet::new();
    for t in 0..ntiers {
        let mut b = TapeBuilder::new();
        let mut text: Option<Extent> = None;
        {
            let ctx =
                TapeCtx { classes, scratch: &scratch_flags, written: &written };
            for (si, st) in stages.iter().enumerate() {
                if tier[si] != t {
                    continue;
                }
                b.push_stage(&st.expr, st.extent, st.target, &ctx);
                text = Some(match text {
                    None => st.extent,
                    Some(e) => e.union(st.extent),
                });
            }
        }
        for (si, st) in stages.iter().enumerate() {
            if tier[si] == t && classes[st.target] != StorageClass::Field3D {
                written.insert(st.target);
            }
        }
        let tape = b.finish();
        let plan = TierPlan::lower(&tape, classes, fast_math);
        tiers.push(Tier { extent: text.unwrap_or_else(Extent::zero), tape, plan });
    }

    let scratch: Vec<(usize, Extent)> = scratch_flags
        .iter()
        .enumerate()
        .filter(|(_, &need)| need)
        .map(|(slot, _)| (slot, alloc[slot]))
        .collect();

    FusedGroup { interval: stages[0].interval, scratch, tiers }
}

/// The fused analog of `vector::ms_halo_plan`, computed from the tapes.
/// Demoted locals (scratch, rings) are slab-local under sharding, so only
/// `Field3D` flow can cross a slab boundary:
///
/// * In `PARALLEL` multistages, tiers are barriers — cross-*tier* field
///   flow is safe at any offset with no extra plan (`Local`). The one
///   hazard is a tier that both stores a field slot and loads it with a
///   non-column-local access (nonzero i-offset — which tier splitting
///   already rules out for earlier-stage defs — or a load region whose
///   i-extent leaves the slab): per-point store/load ordering would then
///   observe a neighbor slab's concurrent writes — `Serial`.
/// * In sequential multistages the slabs sweep levels in lockstep under
///   the rendezvous schedule. A non-column-local load of a stored field
///   at another level (`off.k != 0`) needs `PerLevel`; a same-level one
///   of *another* tier's store needs `PerStage` (tier-granular lockstep);
///   a same-level one of the *same* tier's store is the irreducible
///   in-pass wavefront — `Serial`.
pub(crate) fn ms_halo_plan_fused(groups: &[FusedGroup], policy: IterationPolicy) -> HaloPlan {
    let mut written: HashSet<usize> = HashSet::new();
    for g in groups {
        for t in &g.tiers {
            for inst in &t.tape.ops {
                if let TapeOp::StoreField { slot, .. } = inst.op {
                    written.insert(slot);
                }
            }
        }
    }
    let mut plan = HaloPlan::Local;
    for g in groups {
        for t in &g.tiers {
            let tier_stores: HashSet<usize> = t
                .tape
                .ops
                .iter()
                .filter_map(|inst| match inst.op {
                    TapeOp::StoreField { slot, .. } => Some(slot),
                    _ => None,
                })
                .collect();
            for inst in &t.tape.ops {
                if let TapeOp::Load { slot, off } = &inst.op {
                    let wide = off[0] != 0 || inst.region.i != (0, 0);
                    if !wide {
                        continue;
                    }
                    let need = match policy {
                        IterationPolicy::Parallel => {
                            if tier_stores.contains(slot) {
                                HaloPlan::Serial
                            } else {
                                HaloPlan::Local
                            }
                        }
                        IterationPolicy::Forward | IterationPolicy::Backward => {
                            if !written.contains(slot) {
                                HaloPlan::Local
                            } else if off[2] != 0 {
                                HaloPlan::PerLevel
                            } else if tier_stores.contains(slot) {
                                HaloPlan::Serial
                            } else {
                                HaloPlan::PerStage
                            }
                        }
                    };
                    plan = plan.merge(need);
                    if plan == HaloPlan::Serial {
                        return plan;
                    }
                }
            }
        }
    }
    plan
}

/// Execute a fused program serially (called from the vector backend's
/// dispatch; the full slab `(0, ni)` makes every region identical to the
/// pre-sharding evaluator).
pub(crate) fn run_program<T: PoolElem>(
    fp: &FusedProgram,
    program: &Program,
    env: &EnvView<'_, T>,
    pool: &mut Pool,
    exec: ExecTier,
) {
    let classes: Vec<StorageClass> = program.slots.iter().map(|s| s.storage).collect();
    let depths: Vec<i32> = program.slots.iter().map(|s| s.ring_depth).collect();
    let ni = env.domain[0] as i64;
    // One strip buffer for the whole run, grown to the largest tier.
    let mut vals: Vec<T> = Vec::new();
    for ms in &fp.multistages {
        run_multistage(ms, fp, &classes, &depths, env, pool, &mut vals, (0, ni), exec);
    }
}

/// Run one fused multistage for one i-slab (the serial path passes the
/// full slab; sharded exchange-free sequential multistages pass each
/// slab — the zero-sync slab-local vertical sweep with its slab-local
/// ring k-cache). Sequential multistages whose [`HaloPlan`] demands
/// exchange go through [`run_multistage_synced`]; sharded `PARALLEL`
/// multistages need per-tier barriers and go through
/// [`run_program_sharded`]'s group fan-out instead.
#[allow(clippy::too_many_arguments)]
fn run_multistage<T: PoolElem>(
    ms: &FusedMultistage,
    fp: &FusedProgram,
    classes: &[StorageClass],
    depths: &[i32],
    env: &EnvView<'_, T>,
    pool: &mut Pool,
    vals: &mut Vec<T>,
    slab: (i64, i64),
    exec: ExecTier,
) {
    // Per-op loop bounds depend only on (tier, domain, slab): resolve
    // them once per multistage, not once per sweep level.
    let bounds: Vec<Vec<Vec<[i64; 4]>>> =
        ms.groups.iter().map(|g| resolve_bounds(g, env.domain, slab)).collect();
    let mut rings: Rings<T> = Rings::default();
    match ms.policy {
        IterationPolicy::Parallel => {
            for (g, gb) in ms.groups.iter().zip(&bounds) {
                let (k0, k1) = env.krange(&g.interval);
                if k0 < k1 {
                    run_group(
                        env, g, gb, classes, &fp.alloc, k0, k1, 2, &mut rings, pool,
                        vals, slab, None, exec,
                    );
                }
            }
        }
        IterationPolicy::Forward | IterationPolicy::Backward => {
            let ranges: Vec<(i64, i64)> =
                ms.groups.iter().map(|g| env.krange(&g.interval)).collect();
            let kmin = ranges.iter().map(|r| r.0).min().unwrap_or(0);
            let kmax = ranges.iter().map(|r| r.1).max().unwrap_or(0);
            let ks: Vec<i64> = if ms.policy == IterationPolicy::Forward {
                (kmin..kmax).collect()
            } else {
                (kmin..kmax).rev().collect()
            };
            for k in ks {
                for ((g, gb), (gk0, gk1)) in ms.groups.iter().zip(&bounds).zip(&ranges)
                {
                    if k >= *gk0 && k < *gk1 {
                        run_group(
                            env, g, gb, classes, &fp.alloc, k, k + 1, 1, &mut rings,
                            pool, vals, slab, None, exec,
                        );
                    }
                }
                prune_rings(&mut rings, k, depths, pool);
            }
            for (_, (_, b)) in rings.drain() {
                pool.put(b);
            }
        }
    }
}

/// One slab's share of a *sequential* fused multistage that needs
/// cross-slab halo exchange: the same level loop as [`run_multistage`],
/// run in lockstep with every other slab. Under [`HaloPlan::PerLevel`]
/// the slabs rendezvous once after each k-level; under
/// [`HaloPlan::PerStage`] they additionally rendezvous between
/// consecutive tiers and groups of a level (the rendezvous is threaded
/// into [`run_group`] as its inter-tier barrier), ordering same-level
/// cross-slab reads after the tier that produced them. All wait counts
/// derive from `env.krange` and static tier counts — slab-independent,
/// per the worker pool's barrier caveat.
#[allow(clippy::too_many_arguments)]
fn run_multistage_synced<T: PoolElem>(
    ms: &FusedMultistage,
    fp: &FusedProgram,
    classes: &[StorageClass],
    depths: &[i32],
    env: &EnvView<'_, T>,
    pool: &mut Pool,
    vals: &mut Vec<T>,
    slab: (i64, i64),
    gate: &HaloRendezvous,
    per_tier: bool,
    exec: ExecTier,
) {
    debug_assert!(matches!(
        ms.policy,
        IterationPolicy::Forward | IterationPolicy::Backward
    ));
    let bounds: Vec<Vec<Vec<[i64; 4]>>> =
        ms.groups.iter().map(|g| resolve_bounds(g, env.domain, slab)).collect();
    let mut rings: Rings<T> = Rings::default();
    let ranges: Vec<(i64, i64)> =
        ms.groups.iter().map(|g| env.krange(&g.interval)).collect();
    let kmin = ranges.iter().map(|r| r.0).min().unwrap_or(0);
    let kmax = ranges.iter().map(|r| r.1).max().unwrap_or(0);
    let ks: Vec<i64> = if ms.policy == IterationPolicy::Forward {
        (kmin..kmax).collect()
    } else {
        (kmin..kmax).rev().collect()
    };
    for k in ks {
        let mut ran_any = false;
        for ((g, gb), (gk0, gk1)) in ms.groups.iter().zip(&bounds).zip(&ranges) {
            if k >= *gk0 && k < *gk1 {
                // Tier-granular lockstep across group boundaries: publish
                // the previous group's last tier before any slab's
                // same-level wide read in this group.
                if per_tier && ran_any {
                    gate.wait();
                }
                ran_any = true;
                run_group(
                    env,
                    g,
                    gb,
                    classes,
                    &fp.alloc,
                    k,
                    k + 1,
                    1,
                    &mut rings,
                    pool,
                    vals,
                    slab,
                    if per_tier { Some(gate) } else { None },
                    exec,
                );
            }
        }
        prune_rings(&mut rings, k, depths, pool);
        // The per-level halo rendezvous: all of this level's stores
        // happen-before any slab's next-level neighbor reads.
        gate.wait();
    }
    for (_, (_, b)) in rings.drain() {
        pool.put(b);
    }
}

/// The sharded fused path: `PARALLEL` multistages fan every fusion group
/// out over the slab partition with a rendezvous between tiers;
/// sequential multistages run under their [`HaloPlan`] — zero-sync
/// slab-local sweeps for `Local`, level/tier-lockstep synced sweeps for
/// `PerLevel`/`PerStage`, and an honestly timed serial fallback only for
/// the irreducible `Serial` wavefronts. Every worker captures the same
/// `EnvView`; all field access inside goes through its views under the
/// disjoint-write contract (stores clamped to owned slab ranges,
/// cross-slab reads ordered by the tier barriers, the halo rendezvous,
/// or the fork/join between multistages).
pub(crate) fn run_program_sharded<T: PoolElem>(
    fp: &FusedProgram,
    program: &Program,
    env: &EnvView<'_, T>,
    exec: &ShardExec,
    tier: ExecTier,
) {
    let classes: Vec<StorageClass> = program.slots.iter().map(|s| s.storage).collect();
    let depths: Vec<i32> = program.slots.iter().map(|s| s.ring_depth).collect();
    let ni = env.domain[0] as i64;
    for ms in &fp.multistages {
        if ms.halo == HaloPlan::Serial {
            let t0 = Instant::now();
            {
                let mut pool = exec.serial_pool();
                let mut vals: Vec<T> = Vec::new();
                run_multistage(
                    ms, fp, &classes, &depths, env, &mut pool, &mut vals, (0, ni), tier,
                );
            }
            exec.note_serial_fallback(t0.elapsed());
            continue;
        }
        match ms.policy {
            IterationPolicy::Parallel => {
                for g in &ms.groups {
                    let gate = HaloRendezvous::new(exec.slabs.len());
                    exec.run(&|s, pool| {
                        let slab = exec.slabs[s];
                        let (k0, k1) = env.krange(&g.interval);
                        // k-bounds are slab-independent: either every slab
                        // runs the group's tiers (waiting on the same
                        // rendezvous) or none does.
                        if k0 < k1 {
                            let gb = resolve_bounds(g, env.domain, slab);
                            let mut rings: Rings<T> = Rings::default();
                            let mut vals: Vec<T> = Vec::new();
                            run_group(
                                env, g, &gb, &classes, &fp.alloc, k0, k1, 2,
                                &mut rings, pool, &mut vals, slab, Some(&gate),
                                tier,
                            );
                        }
                    });
                }
            }
            IterationPolicy::Forward | IterationPolicy::Backward => {
                if ms.halo == HaloPlan::Local {
                    // Zero-sync slab-local sweeps.
                    exec.run(&|s, pool| {
                        let mut vals: Vec<T> = Vec::new();
                        run_multistage(
                            ms, fp, &classes, &depths, env, pool, &mut vals,
                            exec.slabs[s], tier,
                        );
                    });
                } else {
                    // Cross-slab halo exchange: level-lockstep sweeps
                    // (tier-lockstep for PerStage).
                    let gate = HaloRendezvous::new(exec.slabs.len());
                    let per_tier = ms.halo == HaloPlan::PerStage;
                    exec.run(&|s, pool| {
                        let mut vals: Vec<T> = Vec::new();
                        run_multistage_synced(
                            ms, fp, &classes, &depths, env, pool, &mut vals,
                            exec.slabs[s], &gate, per_tier, tier,
                        );
                    });
                    exec.note_exchanges(gate.crossings());
                }
            }
        }
    }
}

/// Resolve every op's `[i0,i1,j0,j1]` loop bounds against the domain for
/// one i-slab, per tier of one group. Compute ops run over the slab's
/// extent-expanded range (recomputing the halo overlap into slab-local
/// buffers); `StoreField` ops are clamped to the slab's owned partition
/// so field writes never overlap between slabs. The full slab `(0, ni)`
/// yields the serial bounds for both kinds.
fn resolve_bounds(
    g: &FusedGroup,
    domain: [usize; 3],
    slab: (i64, i64),
) -> Vec<Vec<[i64; 4]>> {
    let (ni, nj) = (domain[0] as i64, domain[1] as i64);
    let (a, b) = slab;
    g.tiers
        .iter()
        .map(|t| {
            t.tape
                .ops
                .iter()
                .map(|inst| {
                    let (ri0, ri1) =
                        (inst.region.i.0 as i64, inst.region.i.1 as i64);
                    let (i0, i1) = if matches!(inst.op, TapeOp::StoreField { .. }) {
                        super::shard::owned_store_range(slab, ni, ri0, ri1)
                    } else {
                        (a + ri0, b + ri1)
                    };
                    [
                        i0,
                        i1,
                        inst.region.j.0 as i64,
                        nj + inst.region.j.1 as i64,
                    ]
                })
                .collect()
        })
        .collect()
}

/// Run one group over `[k0,k1)` for one i-slab: `axis` selects the strip
/// direction (2 = contiguous k strips for PARALLEL, 1 = j strips per
/// level for sequential multistages). Scratch buffers cover the slab's
/// extent-expanded range, so offset reads of demoted locals never leave
/// the slab. When `barrier` is set (sharded PARALLEL groups, and
/// sequential `HaloPlan::PerStage` sweeps via [`run_multistage_synced`]),
/// every slab rendezvouses before each tier after the first — tiers are
/// globally ordered barriers, which is what makes cross-slab reads of
/// fields written by an earlier tier race-free.
#[allow(clippy::too_many_arguments)]
fn run_group<T: PoolElem>(
    env: &EnvView<'_, T>,
    g: &FusedGroup,
    gbounds: &[Vec<[i64; 4]>],
    classes: &[StorageClass],
    alloc: &[Extent],
    k0: i64,
    k1: i64,
    axis: usize,
    rings: &mut Rings<T>,
    pool: &mut Pool,
    vals: &mut Vec<T>,
    slab: (i64, i64),
    barrier: Option<&HaloRendezvous>,
    exec: ExecTier,
) {
    let nj = env.domain[1] as i64;
    let (a, b) = slab;
    // Group-scoped scratch, zero-initialized (reads before the first write
    // see zeros, like the zero-initialized field a demoted temp replaces).
    let mut scratch: Scratch<T> = vec![None; classes.len()];
    for (slot, e) in &g.scratch {
        let r = Region {
            i0: a + e.i.0 as i64,
            i1: b + e.i.1 as i64,
            j0: e.j.0 as i64,
            j1: nj + e.j.1 as i64,
            k0,
            k1,
        };
        let buf = pool.take::<T>(r.len());
        scratch[*slot] = Some((r, buf));
    }
    for (tix, (t, bounds)) in g.tiers.iter().zip(gbounds).enumerate() {
        if tix > 0 {
            // Before the skip checks: every slab of the fan-out must make
            // the same number of `wait` calls (the checks below are
            // slab-independent, but this keeps the invariant local).
            if let Some(bar) = barrier {
                bar.wait();
            }
        }
        let (ti0, ti1) = (a + t.extent.i.0 as i64, b + t.extent.i.1 as i64);
        let (tj0, tj1) = (t.extent.j.0 as i64, nj + t.extent.j.1 as i64);
        if ti0 >= ti1 || tj0 >= tj1 || t.tape.ops.is_empty() {
            continue;
        }
        let wl = if axis == 2 { (k1 - k0) as usize } else { (tj1 - tj0) as usize };
        if wl == 0 {
            continue;
        }
        let need = t.tape.ops.len() * wl;
        if vals.len() < need {
            vals.resize(need, T::ZERO);
        }
        if axis == 2 {
            if exec == ExecTier::Specialized {
                kernels::run_tier_axis2(
                    env,
                    &t.plan,
                    bounds,
                    (ti0, ti1, tj0, tj1),
                    wl,
                    k0,
                    alloc,
                    &mut scratch,
                    rings,
                    pool,
                    vals,
                    slab,
                );
            } else {
                pool.stats.tiers_interpreted += 1;
                pool.stats.strips_interpreted += ((ti1 - ti0) * (tj1 - tj0)) as u64;
                for i in ti0..ti1 {
                    for j in tj0..tj1 {
                        eval_strip(
                            env, &t.tape.ops, bounds, vals, wl, i, j, k0, 2, classes,
                            alloc, &mut scratch, rings, pool, slab,
                        );
                    }
                }
            }
        } else if exec == ExecTier::Specialized {
            // Sequential sweeps: specialized guarded j-strips per (i,
            // level) — pre-resolved accesses and monomorphized dispatch,
            // no lane splitting (a level is one pass, tiling buys nothing).
            let resolved =
                kernels::resolve_accesses(env, &t.plan.kernels, &scratch, k0, 1);
            pool.stats.tiers_specialized += 1;
            pool.stats.strips_guarded += (ti1 - ti0) as u64;
            for i in ti0..ti1 {
                kernels::eval_strip_spec(
                    env,
                    &t.plan.kernels,
                    &resolved,
                    bounds,
                    vals,
                    wl,
                    i,
                    tj0,
                    k0,
                    1,
                    alloc,
                    &mut scratch,
                    rings,
                    pool,
                    slab,
                );
            }
        } else {
            pool.stats.tiers_interpreted += 1;
            pool.stats.strips_interpreted += (ti1 - ti0) as u64;
            for i in ti0..ti1 {
                eval_strip(
                    env, &t.tape.ops, bounds, vals, wl, i, tj0, k0, 1, classes,
                    alloc, &mut scratch, rings, pool, slab,
                );
            }
        }
    }
    for entry in scratch.iter_mut() {
        if let Some((_, b)) = entry.take() {
            pool.put(b);
        }
    }
}

/// Copy `dst.len()` lanes out of `src`, starting at flat index
/// `base + lane0 * stride` (scratch/ring plane gathers; field gathers go
/// through `StorageView::read_lanes`).
#[inline]
pub(crate) fn copy_lanes_in<T: Element>(
    src: &[T],
    base: i64,
    stride: i64,
    dst: &mut [T],
    lane0: usize,
) {
    if stride == 1 {
        let a0 = (base + lane0 as i64) as usize;
        dst.copy_from_slice(&src[a0..a0 + dst.len()]);
    } else {
        let mut idx = base + lane0 as i64 * stride;
        for d in dst.iter_mut() {
            *d = src[idx as usize];
            idx += stride;
        }
    }
}

/// Copy `src.len()` lanes into `dst`, starting at flat index
/// `base + lane0 * stride` (scratch/ring plane scatters; field scatters go
/// through `StorageView::write_lanes`).
#[inline]
pub(crate) fn copy_lanes_out<T: Element>(
    src: &[T],
    dst: &mut [T],
    base: i64,
    stride: i64,
    lane0: usize,
) {
    if stride == 1 {
        let a0 = (base + lane0 as i64) as usize;
        dst[a0..a0 + src.len()].copy_from_slice(src);
    } else {
        let mut idx = base + lane0 as i64 * stride;
        for s in src {
            dst[idx as usize] = *s;
            idx += stride;
        }
    }
}

/// Evaluate one tape over one strip: the point `(i, jbase, k0)` extended
/// along `axis` by `wl` lanes. `vals` holds one strip per tape value;
/// stores write straight into storages / scratch / ring planes. `slab`
/// sizes lazily-allocated ring planes (slab-local under sharding; the
/// full slab for serial runs).
#[allow(clippy::too_many_arguments)]
fn eval_strip<T: PoolElem>(
    env: &EnvView<'_, T>,
    ops: &[TapeInst],
    bounds: &[[i64; 4]],
    vals: &mut [T],
    wl: usize,
    i: i64,
    jbase: i64,
    k0: i64,
    axis: usize,
    classes: &[StorageClass],
    alloc: &[Extent],
    scratch: &mut Scratch<T>,
    rings: &mut Rings<T>,
    pool: &mut Pool,
    slab: (i64, i64),
) {
    for (x, inst) in ops.iter().enumerate() {
        let b = bounds[x];
        if i < b[0] || i >= b[1] {
            continue;
        }
        // Active lane range of this op.
        let (lo, hi): (usize, usize) = if axis == 2 {
            if jbase < b[2] || jbase >= b[3] {
                continue;
            }
            (0, wl)
        } else {
            let lo = (b[2] - jbase).max(0) as usize;
            let hi = ((b[3] - jbase).max(0) as usize).min(wl);
            if lo >= hi {
                continue;
            }
            (lo, hi)
        };
        let base = x * wl;
        match &inst.op {
            TapeOp::Const(c) => vals[base + lo..base + hi].fill(T::from_f64(*c)),
            TapeOp::Scalar(ix) => {
                let v = env.scalars[*ix];
                vals[base + lo..base + hi].fill(v);
            }
            TapeOp::Load { slot, off } => {
                let v = env.storages[*slot];
                let st = v.strides();
                let sbase = v.origin() as i64
                    + (i + off[0] as i64) * st[0] as i64
                    + (jbase + off[1] as i64) * st[1] as i64
                    + (k0 + off[2] as i64) * st[2] as i64;
                let stride = st[axis];
                // SAFETY: in-bounds by the extent analysis; ordered before
                // conflicting writes by the tier barriers / slab model
                // (disjoint-write contract, `storage/view.rs`).
                unsafe {
                    v.read_lanes(
                        (sbase + lo as i64 * stride as i64) as usize,
                        stride,
                        &mut vals[base + lo..base + hi],
                    );
                }
            }
            TapeOp::LoadLocal { slot, off } => {
                let entry = if classes[*slot] == StorageClass::Ring {
                    rings.get(&(*slot, k0 + off[2] as i64))
                } else {
                    scratch[*slot].as_ref()
                };
                match entry {
                    // Never written (this group / that level): zeros.
                    None => vals[base + lo..base + hi].fill(T::ZERO),
                    Some((sr, sbuf)) => {
                        let sdj = sr.j1 - sr.j0;
                        let swk = sr.wk() as i64;
                        let sbase = ((i + off[0] as i64 - sr.i0) * sdj
                            + (jbase + off[1] as i64 - sr.j0))
                            * swk
                            + (k0 + off[2] as i64 - sr.k0);
                        let ls = if axis == 2 { 1 } else { swk };
                        copy_lanes_in(sbuf, sbase, ls, &mut vals[base + lo..base + hi], lo);
                    }
                }
            }
            TapeOp::Neg(a) => {
                let (src, dst) = vals.split_at_mut(base);
                let sa = &src[*a as usize * wl + lo..*a as usize * wl + hi];
                let d = &mut dst[lo..hi];
                for n in 0..d.len() {
                    d[n] = -sa[n];
                }
            }
            TapeOp::Not(a) => {
                let (src, dst) = vals.split_at_mut(base);
                let sa = &src[*a as usize * wl + lo..*a as usize * wl + hi];
                let d = &mut dst[lo..hi];
                for n in 0..d.len() {
                    d[n] = T::from_bool(!sa[n].truthy());
                }
            }
            TapeOp::Bin(op, a, b2) => {
                let (src, dst) = vals.split_at_mut(base);
                let sa = &src[*a as usize * wl + lo..*a as usize * wl + hi];
                let sb = &src[*b2 as usize * wl + lo..*b2 as usize * wl + hi];
                let d = &mut dst[lo..hi];
                match op {
                    BinOp::Add => {
                        for n in 0..d.len() {
                            d[n] = sa[n] + sb[n];
                        }
                    }
                    BinOp::Sub => {
                        for n in 0..d.len() {
                            d[n] = sa[n] - sb[n];
                        }
                    }
                    BinOp::Mul => {
                        for n in 0..d.len() {
                            d[n] = sa[n] * sb[n];
                        }
                    }
                    BinOp::Div => {
                        for n in 0..d.len() {
                            d[n] = sa[n] / sb[n];
                        }
                    }
                    _ => {
                        for n in 0..d.len() {
                            d[n] = apply_bin(*op, sa[n], sb[n]);
                        }
                    }
                }
            }
            TapeOp::Select(c, t, f) => {
                let (src, dst) = vals.split_at_mut(base);
                let sc = &src[*c as usize * wl + lo..*c as usize * wl + hi];
                let st_ = &src[*t as usize * wl + lo..*t as usize * wl + hi];
                let sf = &src[*f as usize * wl + lo..*f as usize * wl + hi];
                let d = &mut dst[lo..hi];
                for n in 0..d.len() {
                    d[n] = if sc[n].truthy() { st_[n] } else { sf[n] };
                }
            }
            TapeOp::Call1(fun, a) => {
                let (src, dst) = vals.split_at_mut(base);
                let sa = &src[*a as usize * wl + lo..*a as usize * wl + hi];
                let d = &mut dst[lo..hi];
                for n in 0..d.len() {
                    d[n] = apply_builtin1(*fun, sa[n]);
                }
            }
            TapeOp::Call2(fun, a, b2) => {
                let (src, dst) = vals.split_at_mut(base);
                let sa = &src[*a as usize * wl + lo..*a as usize * wl + hi];
                let sb = &src[*b2 as usize * wl + lo..*b2 as usize * wl + hi];
                let d = &mut dst[lo..hi];
                for n in 0..d.len() {
                    d[n] = apply_builtin2(*fun, sa[n], sb[n]);
                }
            }
            TapeOp::StoreField { slot, v } => {
                let src = &vals[*v as usize * wl + lo..*v as usize * wl + hi];
                let s = env.storages[*slot];
                let st = s.strides();
                let dbase = s.origin() as i64
                    + i * st[0] as i64
                    + jbase * st[1] as i64
                    + k0 * st[2] as i64;
                let stride = st[axis];
                // SAFETY: store bounds are clamped to the slab's owned
                // partition (`resolve_bounds`), so this thread is the
                // unique writer of every stored element.
                unsafe {
                    s.write_lanes(
                        (dbase + lo as i64 * stride as i64) as usize,
                        stride,
                        src,
                    );
                }
            }
            TapeOp::StoreLocal { slot, v } => {
                if classes[*slot] == StorageClass::Ring && !rings.contains_key(&(*slot, k0))
                {
                    // First write to this level's plane: allocate it zeroed
                    // over the slot's allocation extent (slab-local in i).
                    let e = alloc[*slot];
                    let dnj = env.domain[1] as i64;
                    let r = Region {
                        i0: slab.0 + e.i.0 as i64,
                        i1: slab.1 + e.i.1 as i64,
                        j0: e.j.0 as i64,
                        j1: dnj + e.j.1 as i64,
                        k0,
                        k1: k0 + 1,
                    };
                    let buf = pool.take::<T>(r.len());
                    rings.insert((*slot, k0), (r, buf));
                }
                let (sr, sbuf) = if classes[*slot] == StorageClass::Ring {
                    let ent = rings.get_mut(&(*slot, k0)).expect("ring plane just inserted");
                    (ent.0, &mut ent.1)
                } else {
                    let ent =
                        scratch[*slot].as_mut().expect("scratch local without buffer");
                    (ent.0, &mut ent.1)
                };
                let sdj = sr.j1 - sr.j0;
                let swk = sr.wk() as i64;
                let dbase =
                    ((i - sr.i0) * sdj + (jbase - sr.j0)) * swk + (k0 - sr.k0);
                let ls = if axis == 2 { 1 } else { swk };
                copy_lanes_out(
                    &vals[*v as usize * wl + lo..*v as usize * wl + hi],
                    sbuf,
                    dbase,
                    ls,
                    lo,
                );
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::compile_source_opt;
    use crate::opt::{OptConfig, OptLevel};
    use std::collections::BTreeMap;

    fn fused_program(src: &str, name: &str) -> (Program, FusedProgram) {
        let ir = compile_source_opt(
            src,
            name,
            &BTreeMap::new(),
            &OptConfig::level(OptLevel::O3),
        )
        .unwrap();
        assert!(ir.fused);
        let p = Program::compile(&ir).unwrap();
        let fp = FusedProgram::compile(&p, false);
        (p, fp)
    }

    #[test]
    fn dump_tapes_renders_plans_and_bounds() {
        let (p, fp) = fused_program(crate::stdlib::HDIFF_SRC, "hdiff");
        let dump = fp.dump_tapes(&p, [16, 16, 8]);
        assert!(dump.contains("multistage 0"));
        assert!(dump.contains("halo=local"));
        assert!(dump.contains("reorderable"));
        // Kernel classes, op rendering and resolved bounds all surface.
        assert!(dump.contains("store-plane"));
        assert!(dump.contains("load.local"));
        assert!(dump.contains("bounds i["));
        assert!(dump.contains("interior i["));
    }

    #[test]
    fn hdiff_compiles_to_three_tiers() {
        let (_, fp) = fused_program(crate::stdlib::HDIFF_SRC, "hdiff");
        assert_eq!(fp.multistages.len(), 1);
        assert_eq!(fp.multistages[0].groups.len(), 1);
        // lapf | flx+fly (with their limiter rewrites) | out_phi.
        assert_eq!(fp.num_tiers(), 3);
        assert_eq!(fp.multistages[0].groups[0].tiers.len(), 3);
        // All three temporaries are offset-read: all scratch-backed.
        assert_eq!(fp.multistages[0].groups[0].scratch.len(), 3);
    }

    #[test]
    fn cross_stage_cse_shares_subtrees() {
        // Both fluxes read lapf at [0,0,0]: in the materializing path that
        // is two gathers; in the shared tier tape it must be ONE LoadLocal.
        let (_, fp) = fused_program(crate::stdlib::HDIFF_SRC, "hdiff");
        let flux_tier = &fp.multistages[0].groups[0].tiers[1];
        let zero_loads = flux_tier
            .tape
            .ops
            .iter()
            .filter(|inst| {
                matches!(inst.op, TapeOp::LoadLocal { off: [0, 0, 0], .. })
            })
            .count();
        assert_eq!(zero_loads, 1, "lapf[0,0,0] must be value-numbered once");
    }

    #[test]
    fn register_locals_have_no_stores() {
        // A temp only read at [0,0,0] in its own tier is pure SSA: the tape
        // must contain no StoreLocal for it and the group no scratch.
        const SRC: &str = "
            stencil s(a: Field<f64>, out: Field<f64>) {
                with computation(PARALLEL), interval(...) {
                    t = a * 2.0 + 1.5;
                    out = t * t + a;
                }
            }";
        let (_, fp) = fused_program(SRC, "s");
        let g = &fp.multistages[0].groups[0];
        assert!(g.scratch.is_empty(), "register local must not get scratch");
        assert_eq!(g.tiers.len(), 1);
        assert!(g.tiers[0]
            .tape
            .ops
            .iter()
            .all(|inst| !matches!(inst.op, TapeOp::StoreLocal { .. })));
    }

    #[test]
    fn halo_plans_match_execution_model() {
        // hdiff (PARALLEL, all temporaries demoted to slab-local scratch)
        // and vadv (sequential, but every in-sweep field read is
        // column-local) both run with zero cross-slab synchronization.
        let (_, fp) = fused_program(crate::stdlib::HDIFF_SRC, "hdiff");
        assert!(
            fp.multistages.iter().all(|ms| ms.halo == HaloPlan::Local),
            "hdiff must shard sync-free"
        );
        let (_, fp) = fused_program(crate::stdlib::VADV_SRC, "vadv");
        assert!(
            fp.multistages.iter().all(|ms| ms.halo == HaloPlan::Local),
            "vadv must shard sync-free"
        );
        // A sweep whose carry lives in a *field* read at a horizontal
        // offset into the previous level sheds the old serial fallback:
        // it now runs sharded with a per-level halo rendezvous.
        const CARRY: &str = "
            stencil s(a: Field<f64>, x: Field<f64>) {
                with computation(FORWARD) {
                    interval(0, 1) { x = a; }
                    interval(1, None) { x = a + x[1,0,-1] * 0.5; }
                }
            }";
        let (_, fp) = fused_program(CARRY, "s");
        assert!(
            fp.multistages.iter().any(|ms| ms.halo == HaloPlan::PerLevel),
            "cross-level field carry must get a per-level halo plan"
        );
        // A same-level self-read of the sweep's own target is the
        // irreducible in-pass wavefront: still serial.
        const WAVEFRONT: &str = "
            stencil s(a: Field<f64>, x: Field<f64>) {
                with computation(FORWARD), interval(...) {
                    x = a + x[1,0,0] * 0.5;
                }
            }";
        let (_, fp) = fused_program(WAVEFRONT, "s");
        assert!(
            fp.multistages.iter().any(|ms| ms.halo == HaloPlan::Serial),
            "in-level wavefront must stay on the serial fallback"
        );
    }

    #[test]
    fn tape_regions_cover_consumers() {
        // Every operand's region must contain its consumer's region.
        let (_, fp) = fused_program(crate::stdlib::HDIFF_SRC, "hdiff");
        for ms in &fp.multistages {
            for g in &ms.groups {
                for t in &g.tiers {
                    for inst in &t.tape.ops {
                        for opnd in inst.op.operands().into_iter().flatten() {
                            assert!(
                                inst.region.within(&t.tape.ops[opnd as usize].region),
                                "operand region must cover consumer"
                            );
                        }
                    }
                }
            }
        }
    }
}
