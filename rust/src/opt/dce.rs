//! Dead-stage and dead-temporary elimination.
//!
//! A stage is *live* when it writes an API field, or when it writes a
//! temporary that some live stage reads. Everything else — including whole
//! chains of temporaries feeding only each other — is removed, along with
//! temporaries left without any remaining access and multistages left
//! without stages. Liveness is a simple grow-only fixpoint seeded at the
//! API writes, so a guarded self-read (`t = mask ? v : t`) does not keep
//! its own stage alive.
//!
//! Field/scalar parameter lists are never touched: they are the stencil's
//! call signature, and the run-time argument checks must keep validating
//! the full declared interface.

use crate::ir::implir::StencilIr;
use std::collections::HashSet;

pub fn run(ir: &mut StencilIr) {
    let temps: HashSet<String> =
        ir.temporaries.iter().map(|t| t.name.clone()).collect();

    // Flatten stage order for the fixpoint.
    let flat: Vec<(usize, usize)> = ir
        .multistages
        .iter()
        .enumerate()
        .flat_map(|(mi, ms)| (0..ms.stages.len()).map(move |si| (mi, si)))
        .collect();
    let mut live: Vec<bool> = flat
        .iter()
        .map(|&(mi, si)| !temps.contains(&ir.multistages[mi].stages[si].stmt.target))
        .collect();

    loop {
        // Temporaries read by any currently-live stage.
        let mut read_by_live: HashSet<&str> = HashSet::new();
        for (idx, &(mi, si)) in flat.iter().enumerate() {
            if live[idx] {
                for (f, _) in &ir.multistages[mi].stages[si].reads {
                    read_by_live.insert(f.as_str());
                }
            }
        }
        let mut changed = false;
        for (idx, &(mi, si)) in flat.iter().enumerate() {
            if !live[idx]
                && read_by_live.contains(ir.multistages[mi].stages[si].stmt.target.as_str())
            {
                live[idx] = true;
                changed = true;
            }
        }
        if !changed {
            break;
        }
    }

    // Drop dead stages (walk flat order alongside the nested structure).
    let mut idx = 0;
    for ms in &mut ir.multistages {
        ms.stages.retain(|_| {
            let keep = live[idx];
            idx += 1;
            keep
        });
    }
    ir.multistages.retain(|ms| !ms.stages.is_empty());

    // Drop temporaries with no remaining access.
    let mut used: HashSet<&str> = HashSet::new();
    for ms in &ir.multistages {
        for st in &ms.stages {
            used.insert(st.stmt.target.as_str());
            for (f, _) in &st.reads {
                used.insert(f.as_str());
            }
        }
    }
    let used: HashSet<String> = used.iter().map(|s| s.to_string()).collect();
    ir.temporaries.retain(|t| used.contains(&t.name));
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::compile_source;
    use std::collections::BTreeMap;

    #[test]
    fn removes_dead_temporary_chain() {
        const SRC: &str = "
            stencil s(a: Field<f64>, out: Field<f64>) {
                with computation(PARALLEL), interval(...) {
                    t1 = a * 2.0;
                    t2 = t1 + 1.0;
                    out = a;
                }
            }";
        let mut ir = compile_source(SRC, "s", &BTreeMap::new()).unwrap();
        assert_eq!(ir.num_stages(), 3);
        run(&mut ir);
        assert_eq!(ir.num_stages(), 1);
        assert!(ir.temporaries.is_empty());
        assert_eq!(ir.multistages[0].stages[0].stmt.target, "out");
    }

    #[test]
    fn keeps_live_chain_through_temporaries() {
        const SRC: &str = "
            stencil s(a: Field<f64>, out: Field<f64>) {
                with computation(PARALLEL), interval(...) {
                    t1 = a * 2.0;
                    t2 = t1 + 1.0;
                    out = t2;
                }
            }";
        let mut ir = compile_source(SRC, "s", &BTreeMap::new()).unwrap();
        run(&mut ir);
        assert_eq!(ir.num_stages(), 3);
        assert_eq!(ir.temporaries.len(), 2);
    }

    #[test]
    fn self_sustaining_dead_cycle_removed() {
        // `if a > 0 { t = t_prev }` style: t's guarded rewrite reads t
        // itself, but nothing live reads t — the whole thing must go.
        const SRC: &str = "
            stencil s(a: Field<f64>, out: Field<f64>) {
                with computation(PARALLEL), interval(...) {
                    t = a;
                    if a > 0.0 { t = a * 3.0; }
                    out = a + 1.0;
                }
            }";
        let mut ir = compile_source(SRC, "s", &BTreeMap::new()).unwrap();
        run(&mut ir);
        assert_eq!(ir.num_stages(), 1);
        assert!(ir.temporaries.is_empty());
    }

    #[test]
    fn drops_empty_multistages() {
        const SRC: &str = "
            stencil s(a: Field<f64>, out: Field<f64>) {
                with computation(FORWARD) {
                    interval(0, 1) { t = a; }
                    interval(1, None) { t = t[0,0,-1] + a; }
                }
                with computation(PARALLEL), interval(...) {
                    out = a * 0.5;
                }
            }";
        let mut ir = compile_source(SRC, "s", &BTreeMap::new()).unwrap();
        assert_eq!(ir.multistages.len(), 2);
        run(&mut ir);
        assert_eq!(ir.multistages.len(), 1);
        assert_eq!(ir.num_stages(), 1);
    }
}
