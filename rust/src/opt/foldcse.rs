//! Constant folding + common-subexpression elimination over stage
//! expressions.
//!
//! Folding only performs rewrites that are *bit-exact* on every backend:
//! constant-constant arithmetic uses the same `apply_bin`/`apply_builtin`
//! semantics the interpreting backends use at run time, comparisons fold to
//! boolean literals (preserving the predicate type the XLA backend needs
//! for `select`), and the only algebraic identities applied are the IEEE-
//! exact `x * 1.0`, `1.0 * x` and `x / 1.0`. Transcendental builtins
//! (`exp`, `log`, `sin`, ...) are deliberately *not* folded: libm and XLA
//! may differ in the last ulp, and folding would perturb the cross-backend
//! equivalence the test suite asserts.
//!
//! CSE hoists repeated value-typed subtrees of a stage expression into a
//! fresh `__cse_N` temporary stage inserted immediately before it (same
//! interval, same extent). Consumers read the new temporary at offset
//! `[0,0,0]`, so the hoisted stage fuses into the same group and — at
//! opt-level 2 — demotes to a register buffer. Hoisting out of a ternary
//! branch is value-safe: f64 arithmetic is total (no traps), and the value
//! is only *read* where the original expression would have evaluated it.

use crate::backend::cexpr::{apply_bin, apply_builtin1, apply_builtin2};
use crate::dsl::ast::{BinOp, Builtin, Expr, UnOp};
use crate::ir::canon;
use crate::ir::implir::{Assign, Stage, StencilIr, StorageClass, TempField};
use std::collections::BTreeMap;

/// Minimum node count for a subtree to be worth hoisting.
const CSE_MIN_SIZE: usize = 4;
/// Upper bound on hoists per stage (defensive; real stages hit fixpoint
/// long before).
const CSE_MAX_ROUNDS: usize = 8;

/// Run folding, then CSE, over every stage.
pub fn run(ir: &mut StencilIr) {
    for ms in &mut ir.multistages {
        for st in &mut ms.stages {
            st.stmt.value = fold_expr(&st.stmt.value);
            st.reads = Stage::collect_reads(&st.stmt);
        }
    }
    cse(ir);
    // Re-establish the pre-fusion invariant: one distinct group per stage
    // (CSE inserts stages; group merging happens later, in `fusion`).
    let mut next = 0usize;
    for ms in &mut ir.multistages {
        for st in &mut ms.stages {
            st.fusion_group = next;
            next += 1;
        }
    }
}

/// Bottom-up constant folding. Value-typed results fold to `Expr::Float`,
/// boolean-typed results to `Expr::Bool`.
pub fn fold_expr(e: &Expr) -> Expr {
    match e {
        Expr::Unary { op, operand } => {
            let o = fold_expr(operand);
            match (op, &o) {
                (UnOp::Neg, Expr::Float(v)) => Expr::Float(-*v),
                (UnOp::Not, Expr::Bool(b)) => Expr::Bool(!*b),
                _ => Expr::Unary { op: *op, operand: Box::new(o) },
            }
        }
        Expr::Binary { op, lhs, rhs } => {
            let l = fold_expr(lhs);
            let r = fold_expr(rhs);
            match (&l, &r) {
                (Expr::Float(a), Expr::Float(b)) => {
                    if op.is_comparison() {
                        return Expr::Bool(apply_bin(*op, *a, *b) != 0.0);
                    }
                    if !op.is_logical() {
                        return Expr::Float(apply_bin(*op, *a, *b));
                    }
                }
                (Expr::Bool(a), Expr::Bool(b)) if op.is_logical() => {
                    return Expr::Bool(match op {
                        BinOp::And => *a && *b,
                        BinOp::Or => *a || *b,
                        _ => unreachable!(),
                    });
                }
                _ => {}
            }
            // IEEE-exact identities only (preserve NaN, signed zero).
            match op {
                BinOp::Mul => {
                    if matches!(r, Expr::Float(v) if v.to_bits() == 1.0f64.to_bits()) {
                        return l;
                    }
                    if matches!(l, Expr::Float(v) if v.to_bits() == 1.0f64.to_bits()) {
                        return r;
                    }
                }
                BinOp::Div => {
                    if matches!(r, Expr::Float(v) if v.to_bits() == 1.0f64.to_bits()) {
                        return l;
                    }
                }
                _ => {}
            }
            Expr::Binary { op: *op, lhs: Box::new(l), rhs: Box::new(r) }
        }
        Expr::Ternary { cond, then_e, else_e } => {
            let c = fold_expr(cond);
            if let Expr::Bool(b) = &c {
                return if *b { fold_expr(then_e) } else { fold_expr(else_e) };
            }
            Expr::Ternary {
                cond: Box::new(c),
                then_e: Box::new(fold_expr(then_e)),
                else_e: Box::new(fold_expr(else_e)),
            }
        }
        Expr::Builtin { func, args } => {
            let folded: Vec<Expr> = args.iter().map(fold_expr).collect();
            let all_const = folded.iter().all(|a| matches!(a, Expr::Float(_)));
            if all_const && foldable_builtin(*func) {
                let vals: Vec<f64> = folded
                    .iter()
                    .map(|a| match a {
                        Expr::Float(v) => *v,
                        _ => unreachable!(),
                    })
                    .collect();
                return Expr::Float(if vals.len() == 1 {
                    apply_builtin1(*func, vals[0])
                } else {
                    apply_builtin2(*func, vals[0], vals[1])
                });
            }
            Expr::Builtin { func: *func, args: folded }
        }
        other => other.clone(),
    }
}

/// Builtins whose host-side evaluation is bit-identical to every backend
/// (IEEE-exact operations only).
fn foldable_builtin(f: Builtin) -> bool {
    matches!(
        f,
        Builtin::Abs | Builtin::Sqrt | Builtin::Floor | Builtin::Ceil | Builtin::Min | Builtin::Max
    )
}

/// Whether a subtree produces a boolean (predicate-typed) value — such
/// trees cannot be stored in an f64 temporary without changing the type
/// the XLA backend sees at its use sites.
fn is_boolean(e: &Expr) -> bool {
    match e {
        Expr::Bool(_) => true,
        Expr::Unary { op: UnOp::Not, .. } => true,
        Expr::Binary { op, .. } => op.is_comparison() || op.is_logical(),
        _ => false,
    }
}

fn canon_of(e: &Expr) -> String {
    let mut s = String::new();
    canon::canon_expr(e, &mut s);
    s
}

/// Hoist repeated subtrees stage-by-stage.
fn cse(ir: &mut StencilIr) {
    let temp_dtype = ir
        .fields
        .first()
        .map(|f| f.dtype)
        .unwrap_or(crate::dsl::ast::DType::F64);
    let mut counter = 0usize;
    let mut new_temps: Vec<TempField> = Vec::new();

    for ms in &mut ir.multistages {
        let mut si = 0;
        while si < ms.stages.len() {
            for _ in 0..CSE_MAX_ROUNDS {
                let Some((key, subtree)) = best_candidate(&ms.stages[si].stmt.value) else {
                    break;
                };
                // Fresh, collision-free name (user code cannot produce
                // `__cse_*`: the lexer has no leading-underscore keywords
                // but be defensive anyway).
                let mut name = format!("__cse_{counter}");
                counter += 1;
                while ir.fields.iter().any(|f| f.name == name)
                    || ir.temporaries.iter().any(|t| t.name == name)
                    || new_temps.iter().any(|t| t.name == name)
                {
                    name = format!("__cse_{counter}");
                    counter += 1;
                }
                let host = &mut ms.stages[si];
                host.stmt.value =
                    replace_subtree(&host.stmt.value, &key, &name);
                host.reads = Stage::collect_reads(&host.stmt);
                let (interval, extent) = (host.interval, host.extent);
                let stmt = Assign { target: name.clone(), value: subtree };
                let reads = Stage::collect_reads(&stmt);
                ms.stages.insert(
                    si,
                    Stage { stmt, interval, extent, reads, fusion_group: 0 },
                );
                si += 1; // host moved one slot down
                new_temps.push(TempField {
                    name,
                    dtype: temp_dtype,
                    extent,
                    storage: StorageClass::Field3D,
                    ring_depth: 0,
                });
            }
            si += 1;
        }
    }
    ir.temporaries.extend(new_temps);
}

/// The most beneficial repeated value-typed subtree of `e`, as
/// `(canonical key, subtree clone)`; `None` when nothing qualifies.
fn best_candidate(e: &Expr) -> Option<(String, Expr)> {
    // BTreeMap keeps candidate selection deterministic.
    let mut counts: BTreeMap<String, (usize, usize, Expr)> = BTreeMap::new();
    collect_subtrees(e, &mut counts);
    let mut best: Option<(usize, String, Expr)> = None;
    for (key, (count, size, tree)) in counts {
        if count < 2 {
            continue;
        }
        let score = size * (count - 1);
        match &best {
            Some((bscore, _, _)) if *bscore >= score => {}
            _ => best = Some((score, key, tree)),
        }
    }
    best.map(|(_, key, tree)| (key, tree))
}

fn collect_subtrees(e: &Expr, counts: &mut BTreeMap<String, (usize, usize, Expr)>) {
    let size = e.size();
    if size >= CSE_MIN_SIZE && !is_boolean(e) {
        let key = canon_of(e);
        counts
            .entry(key)
            .and_modify(|(c, _, _)| *c += 1)
            .or_insert_with(|| (1, size, e.clone()));
    }
    match e {
        Expr::Unary { operand, .. } => collect_subtrees(operand, counts),
        Expr::Binary { lhs, rhs, .. } => {
            collect_subtrees(lhs, counts);
            collect_subtrees(rhs, counts);
        }
        Expr::Ternary { cond, then_e, else_e } => {
            collect_subtrees(cond, counts);
            collect_subtrees(then_e, counts);
            collect_subtrees(else_e, counts);
        }
        Expr::Call { args, .. } | Expr::Builtin { args, .. } => {
            for a in args {
                collect_subtrees(a, counts);
            }
        }
        _ => {}
    }
}

/// Replace every occurrence of the subtree with canonical form `key` by a
/// zero-offset read of `temp`. Identical trees cannot overlap partially,
/// so top-down replacement is complete and unambiguous.
fn replace_subtree(e: &Expr, key: &str, temp: &str) -> Expr {
    if !is_boolean(e) && e.size() >= CSE_MIN_SIZE && canon_of(e) == key {
        return Expr::field(temp, [0, 0, 0]);
    }
    match e {
        Expr::Unary { op, operand } => Expr::Unary {
            op: *op,
            operand: Box::new(replace_subtree(operand, key, temp)),
        },
        Expr::Binary { op, lhs, rhs } => Expr::Binary {
            op: *op,
            lhs: Box::new(replace_subtree(lhs, key, temp)),
            rhs: Box::new(replace_subtree(rhs, key, temp)),
        },
        Expr::Ternary { cond, then_e, else_e } => Expr::Ternary {
            cond: Box::new(replace_subtree(cond, key, temp)),
            then_e: Box::new(replace_subtree(then_e, key, temp)),
            else_e: Box::new(replace_subtree(else_e, key, temp)),
        },
        Expr::Call { name, args, span } => Expr::Call {
            name: name.clone(),
            args: args.iter().map(|a| replace_subtree(a, key, temp)).collect(),
            span: *span,
        },
        Expr::Builtin { func, args } => Expr::Builtin {
            func: *func,
            args: args.iter().map(|a| replace_subtree(a, key, temp)).collect(),
        },
        other => other.clone(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::compile_source;
    use crate::dsl::parser::parse_expr;
    use std::collections::BTreeMap as Map;

    fn fold_src(src: &str) -> Expr {
        fold_expr(&parse_expr(src).unwrap())
    }

    #[test]
    fn folds_constant_arithmetic_exactly() {
        assert_eq!(fold_src("1.5 + 2.25"), Expr::Float(3.75));
        assert_eq!(fold_src("2.0 * 3.0 - 1.0"), Expr::Float(5.0));
        assert_eq!(fold_src("7.0 % 3.0"), Expr::Float(1.0));
        assert_eq!(fold_src("-(2.0)"), Expr::Float(-2.0));
    }

    #[test]
    fn comparisons_fold_to_bools_and_select_branches() {
        assert_eq!(fold_src("2.0 > 1.0"), Expr::Bool(true));
        assert_eq!(fold_src("2.0 > 1.0 ? 5.0 : 7.0"), Expr::Float(5.0));
        assert_eq!(fold_src("1.0 >= 2.0 ? 5.0 : 7.0"), Expr::Float(7.0));
    }

    #[test]
    fn exact_identities_only() {
        // x * 1.0 and x / 1.0 are exact; x + 0.0 is NOT (signed zero).
        let x = fold_src("ghost * 1.0");
        assert!(matches!(x, Expr::Name(..)));
        let y = fold_src("ghost / 1.0");
        assert!(matches!(y, Expr::Name(..)));
        let z = fold_src("ghost + 0.0");
        assert!(matches!(z, Expr::Binary { .. }));
    }

    #[test]
    fn exact_builtins_fold_transcendentals_do_not() {
        assert_eq!(fold_src("sqrt(9.0)"), Expr::Float(3.0));
        assert_eq!(fold_src("min(3.0, max(1.0, 2.0))"), Expr::Float(2.0));
        assert_eq!(fold_src("abs(-4.5)"), Expr::Float(4.5));
        assert!(matches!(fold_src("exp(1.0)"), Expr::Builtin { .. }));
        assert!(matches!(fold_src("sin(0.5)"), Expr::Builtin { .. }));
    }

    #[test]
    fn cse_hoists_repeated_laplacian() {
        const SRC: &str = "
            function lap(p) {
                return 4.0 * p[0,0,0] - (p[-1,0,0] + p[1,0,0] + p[0,-1,0] + p[0,1,0]);
            }
            stencil s(a: Field<f64>, out: Field<f64>) {
                with computation(PARALLEL), interval(...) {
                    out = lap(a) * lap(a) + sqrt(abs(lap(a)));
                }
            }";
        let mut ir = compile_source(SRC, "s", &Map::new()).unwrap();
        let before = ir.num_stages();
        run(&mut ir);
        assert_eq!(before, 1);
        assert_eq!(ir.num_stages(), 2, "{}", ir.dump());
        assert!(ir.temporaries.iter().any(|t| t.name.starts_with("__cse_")));
        // The hoisted stage precedes the consumer and shares its extent.
        let stages = &ir.multistages[0].stages;
        assert!(stages[0].stmt.target.starts_with("__cse_"));
        assert_eq!(stages[0].extent, stages[1].extent);
        // Consumer reads the new temp at zero offset.
        assert!(stages[1]
            .reads
            .iter()
            .any(|(n, off)| n.starts_with("__cse_") && *off == [0, 0, 0]));
    }

    #[test]
    fn cse_skips_boolean_subtrees() {
        const SRC: &str = "
            stencil s(a: Field<f64>, out: Field<f64>) {
                with computation(PARALLEL), interval(...) {
                    out = (a[1,0,0] + a[-1,0,0] > 1.0 ? a : 0.5)
                        + (a[1,0,0] + a[-1,0,0] > 1.0 ? 0.25 : a);
                }
            }";
        let mut ir = compile_source(SRC, "s", &Map::new()).unwrap();
        run(&mut ir);
        // The repeated subtree is the *comparison* (boolean) — but its
        // value-typed operand `a[1,0,0] + a[-1,0,0]` is too small (size 3)
        // to hoist, so nothing happens.
        assert_eq!(ir.num_stages(), 1, "{}", ir.dump());
    }

    #[test]
    fn folding_is_applied_inside_stages() {
        const SRC: &str = "
            stencil s(a: Field<f64>, out: Field<f64>) {
                with computation(PARALLEL), interval(...) {
                    out = a * (2.0 * 0.5) + (3.0 - 3.0);
                }
            }";
        let mut ir = compile_source(SRC, "s", &Map::new()).unwrap();
        run(&mut ir);
        let mut s = String::new();
        canon::canon_expr(&ir.multistages[0].stages[0].stmt.value, &mut s);
        // a * 1.0 folds to a; + 0.0 must remain (signed-zero exactness).
        assert_eq!(s, format!("o+(F(a,0,0,0),f{:016x})", 0.0f64.to_bits()));
    }
}
