//! The optimizing pass manager — the layer between analysis and backends
//! (paper §2.3: the toolchain applies "transformations to obtain the
//! performance of state-of-the-art C++ and CUDA implementations"; Devito
//! and Pace locate most of that speedup in an explicit pass-based optimizer
//! over the stencil IR, not in per-kernel codegen).
//!
//! The pipeline ([`crate::analysis`]) emits *pre-optimization* IR: one
//! stage per lowered assignment, every temporary a full 3-D field. The
//! [`PassManager`] rewrites that IR in place with named, ordered,
//! individually-toggleable passes:
//!
//! | order | pass       | effect                                              |
//! |-------|------------|-----------------------------------------------------|
//! | 1     | `fold-cse` | constant folding + common-subexpression elimination |
//! | 2     | `dce`      | dead-stage / dead-temporary elimination             |
//! | 3     | `fuse`     | stage fusion (extent-checked fusion groups)         |
//! | 4     | `demote`   | temporary demotion to register/plane buffers        |
//!
//! Every pass is semantics-preserving under the IR's stage-outermost
//! execution model, so all backends remain interchangeable at every opt
//! level; the `debug` reference interpreter ignores the metadata entirely
//! and still produces bit-identical results. The optimized IR's fingerprint
//! incorporates the pass configuration ([`OptConfig::canon`]) so cached
//! artifacts from different opt levels never collide.

pub mod dce;
pub mod demote;
pub mod foldcse;
pub mod fusion;

use crate::ir::implir::{Stage, StencilIr};

/// Coarse optimization levels, the CLI's `--opt-level {0,1,2}`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OptLevel {
    /// No optimization: the pipeline's pre-opt IR verbatim.
    O0,
    /// Structure-preserving cleanups: fold-cse, dce, fuse.
    O1,
    /// Everything, including temporary demotion.
    O2,
}

impl OptLevel {
    pub fn parse(s: &str) -> Option<OptLevel> {
        match s.trim() {
            "0" => Some(OptLevel::O0),
            "1" => Some(OptLevel::O1),
            "2" => Some(OptLevel::O2),
            _ => None,
        }
    }
}

impl std::fmt::Display for OptLevel {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            OptLevel::O0 => write!(f, "0"),
            OptLevel::O1 => write!(f, "1"),
            OptLevel::O2 => write!(f, "2"),
        }
    }
}

/// Per-pass toggles. `Default` is the full [`OptLevel::O2`] configuration.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct OptConfig {
    pub fold_cse: bool,
    pub dce: bool,
    pub fuse: bool,
    pub demote: bool,
}

impl Default for OptConfig {
    fn default() -> Self {
        OptConfig::level(OptLevel::O2)
    }
}

impl OptConfig {
    /// All passes disabled (opt-level 0).
    pub fn none() -> OptConfig {
        OptConfig { fold_cse: false, dce: false, fuse: false, demote: false }
    }

    pub fn level(level: OptLevel) -> OptConfig {
        match level {
            OptLevel::O0 => OptConfig::none(),
            OptLevel::O1 => {
                OptConfig { fold_cse: true, dce: true, fuse: true, demote: false }
            }
            OptLevel::O2 => {
                OptConfig { fold_cse: true, dce: true, fuse: true, demote: true }
            }
        }
    }

    /// Canonical string of the enabled passes, mixed into IR fingerprints.
    /// Empty exactly when no pass is enabled, so opt-level 0 keeps the
    /// pipeline's pre-opt fingerprint unchanged.
    pub fn canon(&self) -> String {
        let mut names = Vec::new();
        if self.fold_cse {
            names.push("fold-cse");
        }
        if self.dce {
            names.push("dce");
        }
        if self.fuse {
            names.push("fuse");
        }
        if self.demote {
            names.push("demote");
        }
        names.join(",")
    }

    /// Stable hash of the configuration, for salting cache keys computed
    /// *before* analysis (the coordinator's definition-fingerprint memo).
    pub fn salt(&self) -> u64 {
        crate::ir::canon::fnv1a64(self.canon().as_bytes())
    }
}

/// A named IR-to-IR rewrite.
pub struct Pass {
    pub name: &'static str,
    pub enabled: bool,
    run: fn(&mut StencilIr),
}

/// Ordered pass list for one configuration.
pub struct PassManager {
    passes: Vec<Pass>,
    config: OptConfig,
}

impl PassManager {
    pub fn new(config: &OptConfig) -> PassManager {
        let passes = vec![
            Pass { name: "fold-cse", enabled: config.fold_cse, run: foldcse::run },
            Pass { name: "dce", enabled: config.dce, run: dce::run },
            Pass { name: "fuse", enabled: config.fuse, run: fusion::run },
            Pass { name: "demote", enabled: config.demote, run: demote::run },
        ];
        PassManager { passes, config: *config }
    }

    pub fn passes(&self) -> &[Pass] {
        &self.passes
    }

    /// Apply every enabled pass in order, then refresh derived metadata and
    /// restamp the fingerprint with the pass configuration.
    pub fn run(&self, ir: &mut StencilIr) {
        for p in self.passes.iter().filter(|p| p.enabled) {
            (p.run)(ir);
        }
        self.finish(ir);
    }

    /// Like [`PassManager::run`], but returns `(pass name, enabled,
    /// IR dump after the pass)` for each pass — the `repro ir` subcommand.
    pub fn run_traced(&self, ir: &mut StencilIr) -> Vec<(&'static str, bool, String)> {
        let mut trace = Vec::with_capacity(self.passes.len());
        for p in &self.passes {
            if p.enabled {
                (p.run)(ir);
                self.finish(ir);
            }
            trace.push((p.name, p.enabled, ir.dump()));
        }
        trace
    }

    fn finish(&self, ir: &mut StencilIr) {
        refresh_reads(ir);
        ir.fingerprint = crate::analysis::fingerprint_ir_with(ir, &self.config.canon());
    }
}

/// Recompute every stage's read list from its (possibly rewritten)
/// expression.
fn refresh_reads(ir: &mut StencilIr) {
    for ms in &mut ir.multistages {
        for st in &mut ms.stages {
            st.reads = Stage::collect_reads(&st.stmt);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::compile_source;
    use std::collections::BTreeMap;

    const SRC: &str = "
        function lap(p) {
            return 4.0 * p[0,0,0] - (p[-1,0,0] + p[1,0,0] + p[0,-1,0] + p[0,1,0]);
        }
        stencil s(a: Field<f64>, out: Field<f64>) {
            with computation(PARALLEL), interval(...) {
                t = lap(a);
                dead = t * 2.0;
                out = t[1,0,0] + t[-1,0,0] + (1.0 * a);
            }
        }";

    fn ir_at(config: OptConfig) -> crate::ir::implir::StencilIr {
        let mut ir = compile_source(SRC, "s", &BTreeMap::new()).unwrap();
        PassManager::new(&config).run(&mut ir);
        ir
    }

    #[test]
    fn opt_levels_toggle_passes() {
        let o0 = OptConfig::level(OptLevel::O0);
        assert_eq!(o0.canon(), "");
        let o2 = OptConfig::level(OptLevel::O2);
        assert_eq!(o2.canon(), "fold-cse,dce,fuse,demote");
        assert_ne!(o0.salt(), o2.salt());
    }

    #[test]
    fn fingerprints_distinct_across_levels() {
        let f0 = ir_at(OptConfig::level(OptLevel::O0)).fingerprint;
        let f1 = ir_at(OptConfig::level(OptLevel::O1)).fingerprint;
        let f2 = ir_at(OptConfig::level(OptLevel::O2)).fingerprint;
        assert_ne!(f0, f1);
        assert_ne!(f1, f2);
        assert_ne!(f0, f2);
        // O0 through the pass manager equals the raw pipeline fingerprint.
        let raw = compile_source(SRC, "s", &BTreeMap::new()).unwrap();
        assert_eq!(f0, raw.fingerprint);
    }

    #[test]
    fn full_pipeline_removes_dead_and_demotes() {
        let ir = ir_at(OptConfig::level(OptLevel::O2));
        // `dead` eliminated, `t` survives.
        assert!(ir.temporary("dead").is_none());
        let t = ir.temporary("t").unwrap();
        assert_eq!(t.storage, crate::ir::implir::StorageClass::Register);
        assert_eq!(ir.num_stages(), 2);
        // `1.0 * a` folded away.
        let out_stage = &ir.multistages[0].stages[1];
        let mut s = String::new();
        crate::ir::canon::canon_expr(&out_stage.stmt.value, &mut s);
        assert!(!s.contains("f3ff0000000000000"), "identity not folded: {s}");
    }

    #[test]
    fn run_traced_reports_every_pass() {
        let mut ir = compile_source(SRC, "s", &BTreeMap::new()).unwrap();
        let pm = PassManager::new(&OptConfig::default());
        let trace = pm.run_traced(&mut ir);
        assert_eq!(trace.len(), 4);
        let names: Vec<&str> = trace.iter().map(|(n, _, _)| *n).collect();
        assert_eq!(names, vec!["fold-cse", "dce", "fuse", "demote"]);
        assert!(trace.iter().all(|(_, enabled, _)| *enabled));
    }
}
