//! The optimizing pass manager — the layer between analysis and backends
//! (paper §2.3: the toolchain applies "transformations to obtain the
//! performance of state-of-the-art C++ and CUDA implementations"; Devito
//! and Pace locate most of that speedup in an explicit pass-based optimizer
//! over the stencil IR, not in per-kernel codegen).
//!
//! The pipeline ([`crate::analysis`]) emits *pre-optimization* IR: one
//! stage per lowered assignment, every temporary a full 3-D field. The
//! [`PassManager`] rewrites that IR in place with named, ordered,
//! individually-toggleable passes:
//!
//! | order | pass       | effect                                              |
//! |-------|------------|-----------------------------------------------------|
//! | 1     | `fold-cse` | constant folding + common-subexpression elimination |
//! | 2     | `dce`      | dead-stage / dead-temporary elimination             |
//! | 3     | `fuse`     | stage fusion (extent-checked fusion groups)         |
//! | 4     | `demote`   | temporary demotion to register/plane buffers        |
//!
//! Every pass is semantics-preserving under the IR's stage-outermost
//! execution model, so all backends remain interchangeable at every opt
//! level; the `debug` reference interpreter ignores the metadata entirely
//! and still produces bit-identical results. The optimized IR's fingerprint
//! incorporates the pass configuration ([`OptConfig::canon`]) so cached
//! artifacts from different opt levels never collide.
//!
//! `--opt-level 3` runs the same pass list as level 2 and additionally
//! requests the *fused execution strategy* ([`StencilIr::fused`]): backends
//! with a fused path (currently `vector`) compile each fusion group to a
//! flat SSA tape ([`crate::backend::cexpr::CTape`]) and evaluate the whole
//! group in one loop nest per interval (`crate::backend::fused`). This is
//! an execution-strategy bit, not an IR rewrite — results stay bitwise
//! identical to every other level.

pub mod dce;
pub mod demote;
pub mod foldcse;
pub mod fusion;

use crate::backend::kernels::ExecTier;
use crate::backend::shard::Sharding;
use crate::dsl::ast::DType;
use crate::ir::implir::{Stage, StencilIr};

/// Coarse optimization levels, the CLI's `--opt-level {0,1,2,3}`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OptLevel {
    /// No optimization: the pipeline's pre-opt IR verbatim.
    O0,
    /// Structure-preserving cleanups: fold-cse, dce, fuse.
    O1,
    /// Everything, including temporary demotion.
    O2,
    /// O2 plus the fused loop-nest execution strategy: backends that
    /// support it (currently `vector`) compile each fusion group to a flat
    /// SSA tape and evaluate every output and demoted temporary of the
    /// group in one loop nest per interval — no per-expression-node region
    /// buffers.
    O3,
}

impl OptLevel {
    pub fn parse(s: &str) -> Option<OptLevel> {
        match s.trim() {
            "0" => Some(OptLevel::O0),
            "1" => Some(OptLevel::O1),
            "2" => Some(OptLevel::O2),
            "3" => Some(OptLevel::O3),
            _ => None,
        }
    }
}

impl std::fmt::Display for OptLevel {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            OptLevel::O0 => write!(f, "0"),
            OptLevel::O1 => write!(f, "1"),
            OptLevel::O2 => write!(f, "2"),
            OptLevel::O3 => write!(f, "3"),
        }
    }
}

/// The one unified execution-options surface: every layer that accepts
/// knobs — [`crate::coordinator::Coordinator`], [`crate::coordinator::Stencil`]
/// handles, invocation builders, the model driver's config, CLI flag
/// parsing, and the serve wire protocol — accepts this struct, so there is
/// exactly one place that spells out which options salt compilation
/// fingerprints and which are pure scheduling:
///
/// * **Fingerprint-salting half** (`opt_level`, `fast_math`, `dtype`):
///   these select *what artifact* is compiled. Different values must never
///   share a cache slot ([`OptConfig::salt`]).
/// * **Scheduling half** (`sharding`, `tier`): these select *how a run is
///   scheduled*. Every value is bitwise-identical by contract, so they
///   stay out of every fingerprint and can be changed per invocation
///   without recompiling.
///
/// The thin per-knob setters (`set_opt_level`, `set_sharding`,
/// `set_exec_tier`, `set_fast_math`) survive as delegating conveniences.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ExecOptions {
    /// Pass-manager level (fingerprint-salting).
    pub opt_level: OptLevel,
    /// Opt-in numeric relaxation for the specialized executor
    /// (fingerprint-salting — exact and relaxed artifacts never collide).
    pub fast_math: bool,
    /// Storage-precision override (fingerprint-salting): `Some(dtype)`
    /// recompiles the stencil with every field, scalar and temporary at
    /// that element type; `None` honors the source declarations. An f32
    /// artifact computes genuinely different bits than the f64 one, so
    /// the two never share a cache slot.
    pub dtype: Option<DType>,
    /// Intra-call domain-sharding plan (pure scheduling).
    pub sharding: Sharding,
    /// Fused-path executor tier (pure scheduling).
    pub tier: ExecTier,
}

impl Default for ExecOptions {
    /// `--opt-level 2`, exact numerics, serial, specialized executor —
    /// the defaults every layer starts from.
    fn default() -> Self {
        ExecOptions {
            opt_level: OptLevel::O2,
            fast_math: false,
            dtype: None,
            sharding: Sharding::Off,
            tier: ExecTier::default(),
        }
    }
}

impl ExecOptions {
    pub fn new() -> ExecOptions {
        ExecOptions::default()
    }

    pub fn with_opt_level(mut self, level: OptLevel) -> ExecOptions {
        self.opt_level = level;
        self
    }

    pub fn with_fast_math(mut self, fast_math: bool) -> ExecOptions {
        self.fast_math = fast_math;
        self
    }

    pub fn with_sharding(mut self, sharding: Sharding) -> ExecOptions {
        self.sharding = sharding;
        self
    }

    pub fn with_tier(mut self, tier: ExecTier) -> ExecOptions {
        self.tier = tier;
        self
    }

    pub fn with_dtype(mut self, dtype: Option<DType>) -> ExecOptions {
        self.dtype = dtype;
        self
    }

    /// The pass-manager configuration these options name — the single
    /// mapping point from the user-facing surface to [`OptConfig`].
    pub fn opt_config(&self) -> OptConfig {
        OptConfig::level(self.opt_level)
            .with_sharding(self.sharding)
            .with_tier(self.tier)
            .with_fast_math(self.fast_math)
            .with_dtype(self.dtype)
    }
}

/// Per-pass toggles. `Default` is the full [`OptLevel::O2`] configuration.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct OptConfig {
    pub fold_cse: bool,
    pub dce: bool,
    pub fuse: bool,
    pub demote: bool,
    /// Not a pass: requests the fused loop-nest execution strategy from
    /// backends that support it (stamped on the IR as [`StencilIr::fused`]).
    pub fused: bool,
    /// Not a pass either, but — like `fused` — part of the canonical
    /// form: opt-in numeric relaxation (FMA contraction + limited
    /// reassociation) for the specialized tape executor, stamped on the IR
    /// as [`StencilIr::fast_math`]. It changes results (within a
    /// tolerance bound, see `backend::kernels`), so exact and fast-math
    /// artifacts must never share a cache slot.
    pub fast_math: bool,
    /// Not a pass either, and — unlike `fused` — **not part of the
    /// canonical form or any fingerprint**: the intra-call domain-sharding
    /// plan is a pure scheduling parameter (every plan is bitwise-equal to
    /// `Off` by contract), so `Threads(2)` and `Threads(8)` must share one
    /// cached artifact. It rides on `OptConfig` so the coordinator stamps
    /// it into every [`crate::coordinator::Stencil`] handle it mints; the
    /// per-call override lives on the invocation builder.
    pub sharding: Sharding,
    /// Also a pure scheduling parameter outside every fingerprint: which
    /// executor the vector backend's fused path uses — the interpreted
    /// tape walker or the specialized kernel-plan executor
    /// ([`crate::backend::kernels::ExecTier`]). Every tier is
    /// bitwise-identical by contract (fast-math relaxation is the
    /// `fast_math` toggle above, *not* this one), so both tiers share one
    /// cached artifact, exactly like sharding plans.
    pub tier: ExecTier,
    /// Storage-precision override, applied by [`PassManager::finish`]: the
    /// IR's fields, scalars and temporaries are rewritten to this dtype
    /// before the fingerprint restamp. The canonical IR form spells out
    /// every field's dtype, so the rewritten IR fingerprints differently
    /// from the declared-dtype one without any `canon()` involvement —
    /// but [`OptConfig::salt`] (used for cache keys computed *before*
    /// analysis) must still mix it in explicitly.
    pub dtype: Option<DType>,
}

impl Default for OptConfig {
    fn default() -> Self {
        OptConfig::level(OptLevel::O2)
    }
}

impl OptConfig {
    /// All passes disabled (opt-level 0).
    pub fn none() -> OptConfig {
        OptConfig {
            fold_cse: false,
            dce: false,
            fuse: false,
            demote: false,
            fused: false,
            fast_math: false,
            sharding: Sharding::Off,
            tier: ExecTier::default(),
            dtype: None,
        }
    }

    pub fn level(level: OptLevel) -> OptConfig {
        match level {
            OptLevel::O0 => OptConfig::none(),
            OptLevel::O1 => OptConfig {
                fold_cse: true,
                dce: true,
                fuse: true,
                ..OptConfig::none()
            },
            OptLevel::O2 => OptConfig {
                fold_cse: true,
                dce: true,
                fuse: true,
                demote: true,
                ..OptConfig::none()
            },
            OptLevel::O3 => OptConfig {
                fold_cse: true,
                dce: true,
                fuse: true,
                demote: true,
                fused: true,
                ..OptConfig::none()
            },
        }
    }

    /// The same pass configuration with a different sharding plan (which
    /// never changes fingerprints — see [`OptConfig::sharding`]).
    pub fn with_sharding(mut self, sharding: Sharding) -> OptConfig {
        self.sharding = sharding;
        self
    }

    /// The same pass configuration with a different fused-path executor
    /// (never part of fingerprints — see [`OptConfig::tier`]).
    pub fn with_tier(mut self, tier: ExecTier) -> OptConfig {
        self.tier = tier;
        self
    }

    /// The same pass configuration with fast-math toggled (which *does*
    /// change fingerprints — see [`OptConfig::fast_math`]).
    pub fn with_fast_math(mut self, fast_math: bool) -> OptConfig {
        self.fast_math = fast_math;
        self
    }

    /// The same pass configuration with a storage-precision override
    /// (which *does* change fingerprints — see [`OptConfig::dtype`]).
    pub fn with_dtype(mut self, dtype: Option<DType>) -> OptConfig {
        self.dtype = dtype;
        self
    }

    /// Canonical string of the enabled passes, mixed into IR fingerprints.
    /// Empty exactly when no pass is enabled, so opt-level 0 keeps the
    /// pipeline's pre-opt fingerprint unchanged. The `fused` execution
    /// strategy participates too: O2 and O3 artifacts never share a cache
    /// slot even though they run the same pass list.
    pub fn canon(&self) -> String {
        let mut names = Vec::new();
        if self.fold_cse {
            names.push("fold-cse");
        }
        if self.dce {
            names.push("dce");
        }
        if self.fuse {
            names.push("fuse");
        }
        if self.demote {
            names.push("demote");
        }
        if self.fused {
            names.push("fused");
        }
        if self.fast_math {
            names.push("fast-math");
        }
        names.join(",")
    }

    /// Stable hash of the configuration, for salting cache keys computed
    /// *before* analysis (the coordinator's definition-fingerprint memo).
    /// The precision override is mixed in here (unlike [`OptConfig::canon`],
    /// which names only passes): an f32 request must never hit a memoized
    /// f64 handle, even though post-analysis the rewritten field dtypes
    /// already separate the IR fingerprints.
    pub fn salt(&self) -> u64 {
        let mut tag = self.canon();
        if let Some(dt) = self.dtype {
            tag.push_str(";dtype=");
            tag.push_str(&dt.to_string());
        }
        crate::ir::canon::fnv1a64(tag.as_bytes())
    }
}

/// A named IR-to-IR rewrite.
pub struct Pass {
    pub name: &'static str,
    pub enabled: bool,
    run: fn(&mut StencilIr),
}

/// Ordered pass list for one configuration.
pub struct PassManager {
    passes: Vec<Pass>,
    config: OptConfig,
}

impl PassManager {
    pub fn new(config: &OptConfig) -> PassManager {
        let passes = vec![
            Pass { name: "fold-cse", enabled: config.fold_cse, run: foldcse::run },
            Pass { name: "dce", enabled: config.dce, run: dce::run },
            Pass { name: "fuse", enabled: config.fuse, run: fusion::run },
            Pass { name: "demote", enabled: config.demote, run: demote::run },
        ];
        PassManager { passes, config: *config }
    }

    pub fn passes(&self) -> &[Pass] {
        &self.passes
    }

    /// Apply every enabled pass in order, then refresh derived metadata and
    /// restamp the fingerprint with the pass configuration.
    pub fn run(&self, ir: &mut StencilIr) {
        for p in self.passes.iter().filter(|p| p.enabled) {
            (p.run)(ir);
        }
        self.finish(ir);
    }

    /// Like [`PassManager::run`], but returns `(pass name, enabled,
    /// IR dump after the pass)` for each pass — the `repro ir` subcommand.
    pub fn run_traced(&self, ir: &mut StencilIr) -> Vec<(&'static str, bool, String)> {
        let mut trace = Vec::with_capacity(self.passes.len());
        for p in &self.passes {
            if p.enabled {
                (p.run)(ir);
                self.finish(ir);
            }
            trace.push((p.name, p.enabled, ir.dump()));
        }
        trace
    }

    fn finish(&self, ir: &mut StencilIr) {
        refresh_reads(ir);
        // Apply the storage-precision override before restamping: the
        // canonical IR form spells out every field's dtype, so the
        // rewritten IR fingerprints differently from the declared one.
        if let Some(dt) = self.config.dtype {
            for f in &mut ir.fields {
                f.dtype = dt;
            }
            for sc in &mut ir.scalars {
                sc.dtype = dt;
            }
            for t in &mut ir.temporaries {
                t.dtype = dt;
            }
        }
        ir.fused = self.config.fused;
        ir.fast_math = self.config.fast_math;
        ir.fingerprint = crate::analysis::fingerprint_ir_with(ir, &self.config.canon());
    }
}

/// Recompute every stage's read list from its (possibly rewritten)
/// expression.
fn refresh_reads(ir: &mut StencilIr) {
    for ms in &mut ir.multistages {
        for st in &mut ms.stages {
            st.reads = Stage::collect_reads(&st.stmt);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::compile_source;
    use std::collections::BTreeMap;

    const SRC: &str = "
        function lap(p) {
            return 4.0 * p[0,0,0] - (p[-1,0,0] + p[1,0,0] + p[0,-1,0] + p[0,1,0]);
        }
        stencil s(a: Field<f64>, out: Field<f64>) {
            with computation(PARALLEL), interval(...) {
                t = lap(a);
                dead = t * 2.0;
                out = t[1,0,0] + t[-1,0,0] + (1.0 * a);
            }
        }";

    fn ir_at(config: OptConfig) -> crate::ir::implir::StencilIr {
        let mut ir = compile_source(SRC, "s", &BTreeMap::new()).unwrap();
        PassManager::new(&config).run(&mut ir);
        ir
    }

    #[test]
    fn opt_levels_toggle_passes() {
        let o0 = OptConfig::level(OptLevel::O0);
        assert_eq!(o0.canon(), "");
        let o2 = OptConfig::level(OptLevel::O2);
        assert_eq!(o2.canon(), "fold-cse,dce,fuse,demote");
        let o3 = OptConfig::level(OptLevel::O3);
        assert_eq!(o3.canon(), "fold-cse,dce,fuse,demote,fused");
        assert_ne!(o0.salt(), o2.salt());
        assert_ne!(o2.salt(), o3.salt());
    }

    #[test]
    fn exec_options_map_onto_opt_configs() {
        use crate::backend::kernels::ExecTier;
        use crate::backend::shard::Sharding;
        // The defaults agree with OptConfig's defaults.
        assert_eq!(ExecOptions::default().opt_config(), OptConfig::default());
        // Builders set exactly their field; the mapping point is
        // `opt_config`, so the fingerprint discipline is inherited: the
        // scheduling half never changes the salt, the compile half does.
        let base = ExecOptions::new().with_opt_level(OptLevel::O3);
        assert_eq!(base.opt_config().canon(), "fold-cse,dce,fuse,demote,fused");
        let sched = base
            .with_sharding(Sharding::Threads(4))
            .with_tier(ExecTier::Interpreted);
        assert_eq!(sched.opt_config().salt(), base.opt_config().salt());
        assert_eq!(sched.opt_config().sharding, Sharding::Threads(4));
        assert_eq!(sched.opt_config().tier, ExecTier::Interpreted);
        let fm = base.with_fast_math(true);
        assert_ne!(fm.opt_config().salt(), base.opt_config().salt());
    }

    #[test]
    fn sharding_never_reaches_fingerprints() {
        use crate::backend::shard::Sharding;
        // The sharding plan is a scheduling parameter: Threads(2) and
        // Threads(8) must share one cached artifact, so neither the
        // canonical pass string nor the cache salt may see it.
        let base = OptConfig::level(OptLevel::O3);
        let sharded = base.with_sharding(Sharding::Threads(8));
        assert_eq!(base.canon(), sharded.canon());
        assert_eq!(base.salt(), sharded.salt());
        let auto = base.with_sharding(Sharding::Auto);
        assert_eq!(base.salt(), auto.salt());
        let mut ir_a = compile_source(SRC, "s", &BTreeMap::new()).unwrap();
        PassManager::new(&base).run(&mut ir_a);
        let mut ir_b = compile_source(SRC, "s", &BTreeMap::new()).unwrap();
        PassManager::new(&sharded).run(&mut ir_b);
        assert_eq!(ir_a.fingerprint, ir_b.fingerprint);
    }

    #[test]
    fn exec_tier_never_reaches_fingerprints_but_fast_math_does() {
        use crate::backend::kernels::ExecTier;
        let base = OptConfig::level(OptLevel::O3);
        // The executor choice is a scheduling parameter, like sharding.
        let interp = base.with_tier(ExecTier::Interpreted);
        assert_eq!(base.canon(), interp.canon());
        assert_eq!(base.salt(), interp.salt());
        // fast-math changes numerics: distinct canon, salt, fingerprint.
        let fm = base.with_fast_math(true);
        assert_eq!(fm.canon(), "fold-cse,dce,fuse,demote,fused,fast-math");
        assert_ne!(base.salt(), fm.salt());
        let exact = ir_at(base);
        let relaxed = ir_at(fm);
        assert!(!exact.fast_math);
        assert!(relaxed.fast_math);
        assert_ne!(exact.fingerprint, relaxed.fingerprint);
    }

    #[test]
    fn dtype_override_rewrites_ir_and_salts_fingerprints() {
        use crate::dsl::ast::DType;
        let base = OptConfig::level(OptLevel::O2);
        let f32c = base.with_dtype(Some(DType::F32));
        // Pre-analysis memo keys must separate too.
        assert_ne!(base.salt(), f32c.salt());
        // canon() names passes only; the dtype rides on salt + IR rewrite.
        assert_eq!(base.canon(), f32c.canon());
        let i64_ = ir_at(base);
        let i32_ = ir_at(f32c);
        assert_eq!(i64_.dtype(), DType::F64);
        assert_eq!(i32_.dtype(), DType::F32);
        assert!(i32_.fields.iter().all(|f| f.dtype == DType::F32));
        assert!(i32_.temporaries.iter().all(|t| t.dtype == DType::F32));
        assert_ne!(i64_.fingerprint, i32_.fingerprint);
        // An explicit f64 override on f64 sources is a no-op for the IR
        // fingerprint (the rewrite changes nothing) but still salts the
        // pre-analysis memo key.
        let f64c = base.with_dtype(Some(DType::F64));
        assert_eq!(ir_at(f64c).fingerprint, i64_.fingerprint);
        assert_ne!(f64c.salt(), base.salt());
    }

    #[test]
    fn o3_marks_ir_fused_with_distinct_fingerprint() {
        let i2 = ir_at(OptConfig::level(OptLevel::O2));
        let i3 = ir_at(OptConfig::level(OptLevel::O3));
        assert!(!i2.fused);
        assert!(i3.fused);
        assert_ne!(i2.fingerprint, i3.fingerprint);
        // The pass list is identical: only the execution strategy differs.
        assert_eq!(i2.num_stages(), i3.num_stages());
        assert_eq!(i2.temporaries, i3.temporaries);
    }

    #[test]
    fn fingerprints_distinct_across_levels() {
        let f0 = ir_at(OptConfig::level(OptLevel::O0)).fingerprint;
        let f1 = ir_at(OptConfig::level(OptLevel::O1)).fingerprint;
        let f2 = ir_at(OptConfig::level(OptLevel::O2)).fingerprint;
        assert_ne!(f0, f1);
        assert_ne!(f1, f2);
        assert_ne!(f0, f2);
        // O0 through the pass manager equals the raw pipeline fingerprint.
        let raw = compile_source(SRC, "s", &BTreeMap::new()).unwrap();
        assert_eq!(f0, raw.fingerprint);
    }

    #[test]
    fn full_pipeline_removes_dead_and_demotes() {
        let ir = ir_at(OptConfig::level(OptLevel::O2));
        // `dead` eliminated, `t` survives.
        assert!(ir.temporary("dead").is_none());
        let t = ir.temporary("t").unwrap();
        // `t` is read at horizontal offsets: demoted to a plane scratch.
        assert_eq!(t.storage, crate::ir::implir::StorageClass::Plane);
        assert_eq!(ir.num_stages(), 2);
        // `1.0 * a` folded away.
        let out_stage = &ir.multistages[0].stages[1];
        let mut s = String::new();
        crate::ir::canon::canon_expr(&out_stage.stmt.value, &mut s);
        assert!(!s.contains("f3ff0000000000000"), "identity not folded: {s}");
    }

    #[test]
    fn run_traced_reports_every_pass() {
        let mut ir = compile_source(SRC, "s", &BTreeMap::new()).unwrap();
        let pm = PassManager::new(&OptConfig::default());
        let trace = pm.run_traced(&mut ir);
        assert_eq!(trace.len(), 4);
        let names: Vec<&str> = trace.iter().map(|(n, _, _)| *n).collect();
        assert_eq!(names, vec!["fold-cse", "dce", "fuse", "demote"]);
        assert!(trace.iter().all(|(_, enabled, _)| *enabled));
    }
}
