//! Stage fusion: partition each multistage's stages into *fusion groups* —
//! maximal runs of consecutive stages that execute as one unit.
//!
//! Grouping never reorders execution (the IR keeps stage-outermost
//! semantics), so its purpose is to scope data flow: a temporary whose
//! every access lives inside one group can be demoted to a transient
//! register/plane buffer (`crate::opt::demote`), and backends may stream a
//! group's stages without materializing intermediates between them.
//!
//! A stage joins the current group when (using the halo data the extent
//! analysis stamped on the IR):
//!
//! * it shares the group's vertical interval (sequential multistages apply
//!   a group's stages level-by-level; a mismatched interval would
//!   interleave differently), and
//! * every read of a *temporary* written earlier in the group stays inside
//!   the producer's computed extent — `reader.extent.translate(offset) ⊆
//!   writer.extent` — with a zero vertical offset (a register buffer holds
//!   only the group's current k-slab), and
//! * every read of an *API field* written earlier in the group is at
//!   offset `[0,0,0]` (point-local flow; anything wider must observe the
//!   caller-visible storage).

use crate::ir::implir::{Extent, StencilIr};
use std::collections::{HashMap, HashSet};

pub fn run(ir: &mut StencilIr) {
    let temps: HashSet<String> =
        ir.temporaries.iter().map(|t| t.name.clone()).collect();

    let mut next_group = 0usize;
    for ms in &mut ir.multistages {
        // Writer extents of fields written by the current group.
        let mut group_written: HashMap<String, Extent> = HashMap::new();
        let mut group_start: Option<usize> = None;
        for idx in 0..ms.stages.len() {
            let joins = match group_start {
                None => true,
                Some(start) => {
                    let st = &ms.stages[idx];
                    st.interval == ms.stages[start].interval
                        && st.reads.iter().all(|(f, off)| match group_written.get(f) {
                            None => true,
                            Some(wext) => {
                                if temps.contains(f) {
                                    off[2] == 0
                                        && st.extent.translate(*off).within(wext)
                                } else {
                                    *off == [0, 0, 0]
                                }
                            }
                        })
                }
            };
            if !joins {
                next_group += 1;
                group_written.clear();
            }
            if group_start.is_none() || !joins {
                group_start = Some(idx);
            }
            let st = &mut ms.stages[idx];
            st.fusion_group = next_group;
            group_written.insert(st.stmt.target.clone(), st.extent);
        }
        // Groups never span multistages.
        next_group += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::compile_source;
    use std::collections::BTreeMap;

    fn groups(ir: &StencilIr) -> Vec<Vec<usize>> {
        ir.multistages
            .iter()
            .map(|ms| ms.stages.iter().map(|s| s.fusion_group).collect())
            .collect()
    }

    #[test]
    fn hdiff_fuses_into_one_group() {
        let mut ir =
            compile_source(crate::stdlib::HDIFF_SRC, "hdiff", &BTreeMap::new()).unwrap();
        run(&mut ir);
        let g = groups(&ir);
        assert_eq!(g.len(), 1);
        assert!(
            g[0].iter().all(|&gid| gid == g[0][0]),
            "hdiff stages must share one fusion group: {g:?}"
        );
    }

    #[test]
    fn interval_mismatch_splits_groups() {
        let mut ir =
            compile_source(crate::stdlib::VADV_SRC, "vadv", &BTreeMap::new()).unwrap();
        run(&mut ir);
        let g = groups(&ir);
        assert_eq!(g.len(), 2);
        // FORWARD: interval(0,1) stages vs interval(1,None) stages.
        assert_eq!(g[0][0], g[0][1]);
        assert_ne!(g[0][1], g[0][2]);
        assert!(g[0][2..].iter().all(|&x| x == g[0][2]));
        // Groups never span multistages.
        assert!(g[1].iter().all(|&x| !g[0].contains(&x)));
    }

    #[test]
    fn vertical_offset_read_of_temp_splits_group() {
        const SRC: &str = "
            stencil s(a: Field<f64>, out: Field<f64>) {
                with computation(PARALLEL), interval(...) {
                    t = a[0,0,1] - a[0,0,-1];
                    out = t[0,0,1] + a;
                }
            }";
        let mut ir = compile_source(SRC, "s", &BTreeMap::new()).unwrap();
        run(&mut ir);
        let g = groups(&ir);
        assert_ne!(g[0][0], g[0][1], "k-offset temp read must not fuse");
    }

    #[test]
    fn horizontal_offset_within_extent_fuses() {
        const SRC: &str = "
            stencil s(a: Field<f64>, out: Field<f64>) {
                with computation(PARALLEL), interval(...) {
                    t = a * 2.0;
                    out = t[1,0,0] - t[-1,0,0];
                }
            }";
        let mut ir = compile_source(SRC, "s", &BTreeMap::new()).unwrap();
        run(&mut ir);
        let g = groups(&ir);
        assert_eq!(g[0][0], g[0][1], "extent-covered reads must fuse");
    }

    #[test]
    fn api_field_offset_read_splits_group() {
        const SRC: &str = "
            stencil s(a: Field<f64>, mid: Field<f64>, out: Field<f64>) {
                with computation(PARALLEL), interval(...) {
                    mid = a * 2.0;
                    out = mid[1,0,0];
                }
            }";
        let mut ir = compile_source(SRC, "s", &BTreeMap::new()).unwrap();
        run(&mut ir);
        let g = groups(&ir);
        assert_ne!(
            g[0][0], g[0][1],
            "offset read of a group-written API field must not fuse"
        );
    }
}
