//! Temporary demotion: temporaries whose data flow is provably local get a
//! cheaper [`StorageClass`] than the default full 3-D field, so backends
//! can keep their values in transient buffers (or nothing at all) instead
//! of allocating, scattering into and gathering from a whole field.
//!
//! Three demoted classes, from cheapest to widest:
//!
//! * [`StorageClass::Register`] — every write *and* read happens in one
//!   fusion group (one multistage, consecutive stages, one interval) and
//!   every read is at offset `[0,0,0]`. In the fused evaluator the value is
//!   a pure SSA register; interpreting backends may use a group-local
//!   buffer.
//! * [`StorageClass::Plane`] — same single-group locality, reads have zero
//!   vertical offset but nonzero horizontal offsets: the group keeps a
//!   scratch buffer (one plane per level in sequential multistages, the
//!   group region in PARALLEL ones).
//! * [`StorageClass::Ring`] — sweep state: every access lives in a single
//!   FORWARD/BACKWARD multistage (groups may differ — a carry crosses the
//!   `interval(0,1)` / `interval(1,None)` split), and every read's window
//!   is contained in every writer's computed extent. `analysis::checks`
//!   guarantees vertical offsets only ever look at already-computed levels
//!   and that current-level reads are exact, so a ring of the most recent
//!   level planes (depth = max vertical offset) serves every access.
//!
//! Reads *before* the first write (a guarded `t = m ? v : t` rewrite, or a
//! carry read at a never-written level) are fine for every class: demoted
//! buffers read as zeros until written, exactly like the zero-initialized
//! field they replace.

use crate::dsl::ast::IterationPolicy;
use crate::ir::implir::{Extent, StencilIr, StorageClass};
use std::collections::HashMap;

/// Per-temporary access summary.
#[derive(Default)]
struct Access {
    written: bool,
    /// Fusion groups of every write and read.
    groups: Vec<usize>,
    /// Multistage index of every write and read.
    multistages: Vec<usize>,
    /// `(offset, reader stage extent)` for every read.
    reads: Vec<([i32; 3], Extent)>,
    /// Compute extent of every writing stage.
    writer_extents: Vec<Extent>,
}

pub fn run(ir: &mut StencilIr) {
    let mut access: HashMap<String, Access> = ir
        .temporaries
        .iter()
        .map(|t| (t.name.clone(), Access::default()))
        .collect();

    for (mi, ms) in ir.multistages.iter().enumerate() {
        for st in &ms.stages {
            if let Some(a) = access.get_mut(st.stmt.target.as_str()) {
                a.written = true;
                a.groups.push(st.fusion_group);
                a.multistages.push(mi);
                a.writer_extents.push(st.extent);
            }
            for (f, off) in &st.reads {
                if let Some(a) = access.get_mut(f.as_str()) {
                    a.groups.push(st.fusion_group);
                    a.multistages.push(mi);
                    a.reads.push((*off, st.extent));
                }
            }
        }
    }

    let sequential: Vec<bool> = ir
        .multistages
        .iter()
        .map(|m| m.policy != IterationPolicy::Parallel)
        .collect();

    for t in &mut ir.temporaries {
        let a = &access[&t.name];
        t.storage = classify(a, &sequential);
        t.ring_depth = if t.storage == StorageClass::Ring {
            a.reads
                .iter()
                .map(|(off, _)| off[2].abs())
                .max()
                .unwrap_or(0)
                .max(1)
        } else {
            0
        };
    }
}

fn classify(a: &Access, sequential: &[bool]) -> StorageClass {
    if !a.written {
        return StorageClass::Field3D;
    }
    let single_group = a.groups.iter().all(|&g| g == a.groups[0]);
    if single_group && a.reads.iter().all(|(off, _)| off[2] == 0) {
        // The fusion pass already verified containment for every in-group
        // read, so the split is purely on offset shape.
        return if a.reads.iter().all(|(off, _)| *off == [0, 0, 0]) {
            StorageClass::Register
        } else {
            StorageClass::Plane
        };
    }
    // Ring (k-cache) candidate: all accesses inside one sequential
    // multistage, every read window contained in every writer's extent (a
    // plane only holds what its writer computed; windows outside it would
    // observe the zero fringe a real field provides).
    let single_ms = a.multistages.iter().all(|&m| m == a.multistages[0]);
    if single_ms && sequential[a.multistages[0]] {
        let contained = a.reads.iter().all(|(off, rext)| {
            let window = rext.translate([off[0], off[1], 0]);
            a.writer_extents.iter().all(|wext| window.within(wext))
        });
        if contained {
            return StorageClass::Ring;
        }
    }
    StorageClass::Field3D
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::compile_source;
    use crate::opt::fusion;
    use std::collections::BTreeMap;

    fn opt(src: &str, name: &str) -> StencilIr {
        let mut ir = compile_source(src, name, &BTreeMap::new()).unwrap();
        fusion::run(&mut ir);
        run(&mut ir);
        ir
    }

    fn class(ir: &StencilIr, name: &str) -> StorageClass {
        ir.temporary(name).unwrap().storage
    }

    #[test]
    fn hdiff_temporaries_all_demote_to_planes() {
        // lapf/flx/fly are all read at horizontal offsets inside the one
        // fused group: plane scratch, not pure registers.
        let ir = opt(crate::stdlib::HDIFF_SRC, "hdiff");
        for t in ["lapf", "flx", "fly"] {
            assert_eq!(class(&ir, t), StorageClass::Plane, "temp `{t}`");
        }
    }

    #[test]
    fn vadv_sweep_carries_stay_fields() {
        let ir = opt(crate::stdlib::VADV_SRC, "vadv");
        // cp/dp are read again by the BACKWARD multistage: no class fits.
        assert_eq!(class(&ir, "cp"), StorageClass::Field3D);
        assert_eq!(class(&ir, "dp"), StorageClass::Field3D);
        // av/denom live entirely inside the interval(1,None) group and are
        // only read at [0,0,0]: pure registers.
        assert_eq!(class(&ir, "av"), StorageClass::Register);
        assert_eq!(class(&ir, "denom"), StorageClass::Register);
    }

    #[test]
    fn cross_multistage_temp_stays_field() {
        const SRC: &str = "
            stencil s(a: Field<f64>, out: Field<f64>) {
                with computation(PARALLEL), interval(...) {
                    t = a * 2.0;
                }
                with computation(PARALLEL), interval(...) {
                    out = t;
                }
            }";
        let ir = opt(SRC, "s");
        assert_eq!(class(&ir, "t"), StorageClass::Field3D);
    }

    #[test]
    fn parallel_k_offset_read_stays_field() {
        const SRC: &str = "
            stencil s(a: Field<f64>, out: Field<f64>) {
                with computation(PARALLEL), interval(...) {
                    t = a * 2.0;
                    out = t[0,0,1] + a;
                }
            }";
        let ir = opt(SRC, "s");
        assert_eq!(class(&ir, "t"), StorageClass::Field3D);
    }

    #[test]
    fn guarded_rewrite_demotes_to_plane() {
        // Lowering turns the `if` into `t = cond ? v : t` (a zero-offset
        // self-read) — all accesses stay inside one group; the consumer's
        // horizontal offsets make it a plane, not a register.
        const SRC: &str = "
            stencil s(a: Field<f64>, out: Field<f64>) {
                with computation(PARALLEL), interval(...) {
                    t = a;
                    if a > 0.0 { t = a * 3.0; }
                    out = t[1,0,0] + t[-1,0,0];
                }
            }";
        let ir = opt(SRC, "s");
        assert_eq!(class(&ir, "t"), StorageClass::Plane);
    }

    #[test]
    fn zero_offset_only_temp_is_register() {
        const SRC: &str = "
            stencil s(a: Field<f64>, out: Field<f64>) {
                with computation(PARALLEL), interval(...) {
                    t = a * 2.0;
                    out = t + a;
                }
            }";
        let ir = opt(SRC, "s");
        assert_eq!(class(&ir, "t"), StorageClass::Register);
    }

    #[test]
    fn forward_carry_demotes_to_ring() {
        // The column-sum shape: a carry written in both interval groups of
        // one FORWARD multistage, read at k-1 — a classic k-cache.
        const SRC: &str = "
            stencil s(a: Field<f64>, x: Field<f64>) {
                with computation(FORWARD) {
                    interval(0, 1) { t = a * 0.5; x = t; }
                    interval(1, None) { t = a + t[0,0,-1] * 0.9; x = t - t[0,0,-1]; }
                }
            }";
        let ir = opt(SRC, "s");
        assert_eq!(class(&ir, "t"), StorageClass::Ring);
        assert_eq!(ir.temporary("t").unwrap().ring_depth, 1);
    }

    #[test]
    fn ring_requires_read_windows_inside_writer_extents() {
        // x reads the previous level's t at a horizontal offset, but the
        // interval(1,None) writer (textually after the read, so nothing
        // widens its extent) only computes t over the unextended domain:
        // the plane a ring would serve never holds the window x needs, so
        // t must stay a field (whose zero halo provides the fringe).
        const SRC: &str = "
            stencil s(a: Field<f64>, x: Field<f64>) {
                with computation(FORWARD) {
                    interval(0, 1) { t = a; x = t; }
                    interval(1, None) { x = t[1,0,-1]; t = a; }
                }
            }";
        let ir = opt(SRC, "s");
        assert_eq!(class(&ir, "t"), StorageClass::Field3D);
    }

    #[test]
    fn ring_allows_horizontal_offsets_covered_by_writers() {
        // Here the temp chain widens t's compute extent to ±1, so the
        // ring planes do hold u's windows.
        const SRC: &str = "
            stencil s(a: Field<f64>, x: Field<f64>) {
                with computation(FORWARD) {
                    interval(0, 1) { t = a; u = t; x = u; }
                    interval(1, None) {
                        t = a + t[0,0,-1];
                        u = t[1,0,-1] + t[-1,0,-1];
                        x = u * 0.5;
                    }
                }
            }";
        let ir = opt(SRC, "s");
        assert_eq!(class(&ir, "t"), StorageClass::Ring);
        // u is written in two groups of the multistage but only read at
        // [0,0,0]: the ring class covers it too (depth 1).
        assert_eq!(class(&ir, "u"), StorageClass::Ring);
    }

    #[test]
    fn backward_carry_demotes_to_ring() {
        const SRC: &str = "
            stencil s(a: Field<f64>, x: Field<f64>) {
                with computation(BACKWARD) {
                    interval(-1, None) { t = a; x = t; }
                    interval(0, -1) { t = a + t[0,0,1] * 0.5; x = t; }
                }
            }";
        let ir = opt(SRC, "s");
        assert_eq!(class(&ir, "t"), StorageClass::Ring);
    }
}
