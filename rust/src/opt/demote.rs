//! Temporary demotion: temporaries produced and consumed inside a single
//! fusion group become [`StorageClass::Register`] values — backends may
//! hold them in transient region/plane buffers for the lifetime of the
//! group instead of allocating, scattering into and gathering from a full
//! 3-D field.
//!
//! Legality (on top of what `fusion` already guarantees for in-group
//! reads):
//!
//! * every write *and* every read of the temporary happens in one fusion
//!   group (one multistage, consecutive stages, one interval);
//! * every read has a zero vertical offset — a register buffer holds only
//!   the group's current k-slab (one plane per level in sequential
//!   multistages), so a `t[0,0,-1]`-style sweep carry must stay a field.
//!
//! Reads *before* the first in-group write (a guarded `t = m ? v : t`
//! rewrite) are fine: register buffers read as zeros until written,
//! exactly like the zero-initialized field the temporary would otherwise
//! be.

use crate::ir::implir::{StencilIr, StorageClass};
use std::collections::HashMap;

/// Per-temporary access summary.
struct Access {
    groups: Vec<usize>,
    written: bool,
    reads_k_zero: bool,
}

pub fn run(ir: &mut StencilIr) {
    let mut access: HashMap<String, Access> = ir
        .temporaries
        .iter()
        .map(|t| {
            (t.name.clone(), Access { groups: Vec::new(), written: false, reads_k_zero: true })
        })
        .collect();

    for ms in &ir.multistages {
        for st in &ms.stages {
            if let Some(a) = access.get_mut(st.stmt.target.as_str()) {
                a.groups.push(st.fusion_group);
                a.written = true;
            }
            for (f, off) in &st.reads {
                if let Some(a) = access.get_mut(f.as_str()) {
                    a.groups.push(st.fusion_group);
                    if off[2] != 0 {
                        a.reads_k_zero = false;
                    }
                }
            }
        }
    }

    for t in &mut ir.temporaries {
        let a = &access[&t.name];
        let single_group = !a.groups.is_empty() && a.groups.iter().all(|&g| g == a.groups[0]);
        t.storage = if a.written && single_group && a.reads_k_zero {
            StorageClass::Register
        } else {
            StorageClass::Field3D
        };
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::compile_source;
    use crate::opt::fusion;
    use std::collections::BTreeMap;

    fn opt(src: &str, name: &str) -> StencilIr {
        let mut ir = compile_source(src, name, &BTreeMap::new()).unwrap();
        fusion::run(&mut ir);
        run(&mut ir);
        ir
    }

    fn class(ir: &StencilIr, name: &str) -> StorageClass {
        ir.temporary(name).unwrap().storage
    }

    #[test]
    fn hdiff_temporaries_all_demote() {
        let ir = opt(crate::stdlib::HDIFF_SRC, "hdiff");
        for t in ["lapf", "flx", "fly"] {
            assert_eq!(class(&ir, t), StorageClass::Register, "temp `{t}`");
        }
    }

    #[test]
    fn vadv_sweep_carries_stay_fields() {
        let ir = opt(crate::stdlib::VADV_SRC, "vadv");
        // cp/dp cross groups (and cp is read at k-1): must stay fields.
        assert_eq!(class(&ir, "cp"), StorageClass::Field3D);
        assert_eq!(class(&ir, "dp"), StorageClass::Field3D);
        // av/denom live entirely inside the interval(1,None) group.
        assert_eq!(class(&ir, "av"), StorageClass::Register);
        assert_eq!(class(&ir, "denom"), StorageClass::Register);
    }

    #[test]
    fn cross_multistage_temp_stays_field() {
        const SRC: &str = "
            stencil s(a: Field<f64>, out: Field<f64>) {
                with computation(PARALLEL), interval(...) {
                    t = a * 2.0;
                }
                with computation(PARALLEL), interval(...) {
                    out = t;
                }
            }";
        let ir = opt(SRC, "s");
        assert_eq!(class(&ir, "t"), StorageClass::Field3D);
    }

    #[test]
    fn parallel_k_offset_read_stays_field() {
        const SRC: &str = "
            stencil s(a: Field<f64>, out: Field<f64>) {
                with computation(PARALLEL), interval(...) {
                    t = a * 2.0;
                    out = t[0,0,1] + a;
                }
            }";
        let ir = opt(SRC, "s");
        assert_eq!(class(&ir, "t"), StorageClass::Field3D);
    }

    #[test]
    fn guarded_rewrite_still_demotes() {
        // Lowering turns the `if` into `t = cond ? v : t` (a zero-offset
        // self-read) — all accesses stay inside one group.
        const SRC: &str = "
            stencil s(a: Field<f64>, out: Field<f64>) {
                with computation(PARALLEL), interval(...) {
                    t = a;
                    if a > 0.0 { t = a * 3.0; }
                    out = t[1,0,0] + t[-1,0,0];
                }
            }";
        let ir = opt(SRC, "s");
        assert_eq!(class(&ir, "t"), StorageClass::Register);
    }
}
