//! First-class stencil handles — the `StencilObject` analog of GT4Py's
//! `gtscript.stencil(backend=...)` return value.
//!
//! A [`Stencil`] is a cheap-to-clone, `Send + Sync` handle pairing one
//! compiled implementation IR (`Arc<StencilIr>`, shared with the
//! coordinator's cache — no per-call deep copies) with one backend
//! instance (`Arc<dyn Backend>`, whose executable caches stay warm across
//! every handle bound to it). Clone a handle into as many threads as you
//! like: the same compiled artifact dispatches concurrently.
//!
//! Calling goes through an invocation builder:
//!
//! ```no_run
//! # use gt4rs::coordinator::Coordinator;
//! # fn main() -> anyhow::Result<()> {
//! let mut coord = Coordinator::new();
//! let stencil = coord.stencil_library("diffuse", "vector")?;
//! let domain = [64, 64, 32];
//! let mut phi = stencil.alloc_field("phi", domain)?;
//! let mut out = stencil.alloc_field("out", domain)?;
//!
//! // Bind once: the full layout/halo/dtype validation — the paper's
//! // Fig. 3 constant per-call overhead — happens here, exactly once.
//! let mut step = stencil
//!     .bind()
//!     .field("phi", &phi)
//!     .field("out", &out)
//!     .scalar("alpha", 0.1)
//!     .domain(domain)
//!     .finish()?;
//!
//! // Run many: repeat calls only re-check that the storages still have
//! // the validated geometry (a handful of integer compares) —
//! // reproducing the dashed-line overhead elimination without globally
//! // disabling checks.
//! for _ in 0..100 {
//!     step.run(&mut [&mut phi, &mut out])?;
//! }
//! # Ok(()) }
//! ```
//!
//! Storages are **not** borrowed between calls: `run` takes them fresh
//! each time, in the stencil's field declaration order. If a storage was
//! reallocated with a different geometry since binding, the shape
//! re-check rejects the call with a "re-bind" error instead of computing
//! on a stale layout.

use crate::backend::kernels::ExecTier;
use crate::backend::program::validate_field;
use crate::backend::shard::Sharding;
use crate::backend::{Backend, RunConfig, StencilArgs};
use crate::coordinator::metrics::SharedMetrics;
use crate::coordinator::RunStats;
use crate::ir::implir::StencilIr;
use crate::opt::ExecOptions;
use crate::storage::{Storage, StorageInfo};
use anyhow::{anyhow, bail, Result};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// A compiled stencil bound to a backend — see the module docs.
#[derive(Clone)]
pub struct Stencil {
    ir: Arc<StencilIr>,
    backend: Arc<dyn Backend>,
    checks_enabled: bool,
    /// The full execution-options surface this handle was minted with.
    /// The compile half (`opt_level`, `fast_math`) records what the
    /// artifact behind `ir` was built with; the scheduling half
    /// (`sharding`, `tier`) is the default for invocations bound from
    /// this handle (overridable per invocation via
    /// [`InvocationBuilder::sharding`] / [`InvocationBuilder::exec_tier`]).
    exec: ExecOptions,
    metrics: SharedMetrics,
}

impl Stencil {
    pub(super) fn new(
        ir: Arc<StencilIr>,
        backend: Arc<dyn Backend>,
        checks_enabled: bool,
        exec: ExecOptions,
        metrics: SharedMetrics,
    ) -> Stencil {
        Stencil { ir, backend, checks_enabled, exec, metrics }
    }

    /// The analyzed implementation IR (shared, never copied).
    pub fn ir(&self) -> &StencilIr {
        &self.ir
    }

    pub fn name(&self) -> &str {
        &self.ir.name
    }

    pub fn fingerprint(&self) -> u64 {
        self.ir.fingerprint
    }

    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    pub fn checks_enabled(&self) -> bool {
        self.checks_enabled
    }

    /// Toggle the run-time storage checks for this handle (and invocations
    /// bound from it afterwards) — the Fig. 3 solid/dashed switch, scoped
    /// to one handle instead of a whole engine.
    pub fn set_checks_enabled(&mut self, enabled: bool) {
        self.checks_enabled = enabled;
    }

    /// The full execution-options surface of this handle. The compile
    /// half (`opt_level`, `fast_math`) reports what the artifact was
    /// built with; the scheduling half is the current invocation default.
    pub fn exec_options(&self) -> ExecOptions {
        self.exec
    }

    /// Apply the *scheduling half* of `exec` (sharding, tier) to this
    /// handle. The fingerprint-salting half (`opt_level`, `fast_math`)
    /// records what this handle's artifact was compiled with and is not
    /// changed by this setter — recompile through the coordinator to get
    /// a differently optimized artifact.
    pub fn set_exec_options(&mut self, exec: ExecOptions) {
        self.exec.sharding = exec.sharding;
        self.exec.tier = exec.tier;
    }

    /// This handle's default intra-call sharding plan.
    pub fn sharding(&self) -> Sharding {
        self.exec.sharding
    }

    /// Thin delegate: set the intra-call sharding plan for invocations
    /// bound from this handle afterwards. Purely a scheduling knob: every
    /// plan is bitwise identical to [`Sharding::Off`], and backends
    /// without a sharded path ignore it.
    pub fn set_sharding(&mut self, sharding: Sharding) {
        self.exec.sharding = sharding;
    }

    /// This handle's default fused-path executor tier.
    pub fn exec_tier(&self) -> ExecTier {
        self.exec.tier
    }

    /// Thin delegate: set the fused-path executor tier for invocations
    /// bound from this handle afterwards. Purely a scheduling knob —
    /// every tier is bitwise-identical by contract (numeric relaxation is
    /// the coordinator's fast-math opt-in, not this switch), and backends
    /// without a fused path ignore it.
    pub fn set_exec_tier(&mut self, tier: ExecTier) {
        self.exec.tier = tier;
    }

    /// Allocate a zeroed storage with exactly the halo this stencil's
    /// field requires for `domain` (the `gt4py.storage.zeros(backend=...)`
    /// analog).
    pub fn alloc_field(&self, field: &str, domain: [usize; 3]) -> Result<Storage> {
        alloc_field_for(&self.ir, field, domain)
    }

    /// Start binding an invocation. Field/scalar order does not matter;
    /// the finished [`BoundInvocation`] expects storages in declaration
    /// order.
    pub fn bind(&self) -> InvocationBuilder<'_> {
        InvocationBuilder {
            stencil: self,
            fields: Vec::with_capacity(self.ir.fields.len()),
            scalars: Vec::with_capacity(self.ir.scalars.len()),
            domain: None,
            sharding: None,
            tier: None,
        }
    }

}

/// Builder collecting the arguments of one invocation; created by
/// [`Stencil::bind`], consumed by [`InvocationBuilder::finish`].
pub struct InvocationBuilder<'s> {
    stencil: &'s Stencil,
    /// `(name, geometry snapshot)` per bound field, in bind order.
    fields: Vec<(String, StorageInfo)>,
    scalars: Vec<(String, f64)>,
    domain: Option<[usize; 3]>,
    /// Per-invocation sharding override (`None` = the handle's plan).
    sharding: Option<Sharding>,
    /// Per-invocation executor-tier override (`None` = the handle's tier).
    tier: Option<ExecTier>,
}

impl InvocationBuilder<'_> {
    /// Bind a field argument. Only the storage's geometry is captured —
    /// the storage itself is handed to every [`BoundInvocation::run`]
    /// call, so it stays free between calls.
    pub fn field(mut self, name: &str, storage: &Storage) -> Self {
        self.fields.push((name.to_string(), storage.info));
        self
    }

    /// Bind a scalar argument.
    pub fn scalar(mut self, name: &str, value: f64) -> Self {
        self.scalars.push((name.to_string(), value));
        self
    }

    /// Bind every `(name, storage)` pair — convenience over repeated
    /// [`InvocationBuilder::field`] for callers holding a collection.
    pub fn fields<N: AsRef<str>>(mut self, pairs: &[(N, Storage)]) -> Self {
        for (n, s) in pairs {
            self = self.field(n.as_ref(), s);
        }
        self
    }

    /// Bind every `(name, value)` scalar pair.
    pub fn scalars<N: AsRef<str>>(mut self, pairs: &[(N, f64)]) -> Self {
        for (n, v) in pairs {
            self = self.scalar(n.as_ref(), *v);
        }
        self
    }

    /// Set the compute-domain shape (required).
    pub fn domain(mut self, domain: [usize; 3]) -> Self {
        self.domain = Some(domain);
        self
    }

    /// Override the intra-call sharding plan for this invocation (the
    /// handle's plan applies otherwise). Scheduling only — results are
    /// bitwise identical whatever the plan.
    pub fn sharding(mut self, sharding: Sharding) -> Self {
        self.sharding = Some(sharding);
        self
    }

    /// Override the fused-path executor tier for this invocation (the
    /// handle's tier applies otherwise). Scheduling only — every tier is
    /// bitwise identical by contract.
    pub fn exec_tier(mut self, tier: ExecTier) -> Self {
        self.tier = Some(tier);
        self
    }

    /// Apply the scheduling half of an [`ExecOptions`] as this
    /// invocation's overrides — equivalent to calling
    /// [`InvocationBuilder::sharding`] and [`InvocationBuilder::exec_tier`]
    /// with its fields. The compile half (`opt_level`, `fast_math`) is
    /// fixed by the handle's artifact and ignored here.
    pub fn exec_options(mut self, exec: ExecOptions) -> Self {
        self.sharding = Some(exec.sharding);
        self.tier = Some(exec.tier);
        self
    }

    /// Resolve and fully validate the invocation *once*. The layout /
    /// halo / dtype checks run here (when the handle's checks are
    /// enabled); the returned [`BoundInvocation`] only re-checks shapes
    /// on each call.
    pub fn finish(self) -> Result<BoundInvocation> {
        let stencil = self.stencil;
        let ir = &*stencil.ir;
        let domain = self
            .domain
            .ok_or_else(|| anyhow!("bind: no domain set (call .domain([ni, nj, nk]))"))?;
        let t0 = Instant::now();

        // Resolve bound fields against the declaration, in declaration
        // order — the order `run` expects its storages in.
        let mut field_names = Vec::with_capacity(ir.fields.len());
        let mut expected = Vec::with_capacity(ir.fields.len());
        for f in &ir.fields {
            let mut found = None;
            for (n, info) in &self.fields {
                if n == &f.name {
                    if found.is_some() {
                        bail!("bind: field `{}` bound twice", f.name);
                    }
                    found = Some(*info);
                }
            }
            let info =
                found.ok_or_else(|| anyhow!("bind: missing field argument `{}`", f.name))?;
            if stencil.checks_enabled {
                validate_field(f, &info, domain)?;
            }
            field_names.push(f.name.clone());
            expected.push(info);
        }
        for (n, _) in &self.fields {
            if ir.field(n).is_none() {
                bail!("bind: stencil `{}` has no field `{n}`", ir.name);
            }
        }

        // Resolve scalars, declaration order. Like fields, binding one
        // twice is an error (use `BoundInvocation::set_scalar` to change
        // a value between calls).
        for (i, (n, _)) in self.scalars.iter().enumerate() {
            if self.scalars[..i].iter().any(|(m, _)| m == n) {
                bail!("bind: scalar `{n}` bound twice");
            }
        }
        let mut scalars = Vec::with_capacity(ir.scalars.len());
        for s in &ir.scalars {
            let v = self
                .scalars
                .iter()
                .find(|(n, _)| n == &s.name)
                .map(|(_, v)| *v)
                .ok_or_else(|| anyhow!("bind: missing scalar argument `{}`", s.name))?;
            scalars.push((s.name.clone(), v));
        }
        for (n, _) in &self.scalars {
            if !ir.scalars.iter().any(|s| &s.name == n) {
                bail!("bind: stencil `{}` has no scalar `{n}`", ir.name);
            }
        }

        let bind_checks = if stencil.checks_enabled { t0.elapsed() } else { Duration::ZERO };
        Ok(BoundInvocation {
            stencil: stencil.clone(),
            domain,
            field_names,
            expected,
            scalars,
            sharding: self.sharding.unwrap_or(stencil.exec.sharding),
            tier: self.tier.unwrap_or(stencil.exec.tier),
            bind_checks,
            first_reported: false,
        })
    }
}

/// A validated, reusable invocation of one [`Stencil`]. Owns no storages
/// and borrows nothing: it can be kept for the lifetime of a model run
/// and is `Send` (each thread drives its own invocation; the underlying
/// stencil handle and backend are shared).
pub struct BoundInvocation {
    stencil: Stencil,
    domain: [usize; 3],
    /// Field names in declaration order (the order `run` expects).
    field_names: Vec<String>,
    /// Geometry validated at bind time, per field.
    expected: Vec<StorageInfo>,
    /// `(name, value)` in declaration order.
    scalars: Vec<(String, f64)>,
    /// Resolved intra-call sharding plan for every run of this invocation.
    sharding: Sharding,
    /// Resolved fused-path executor tier for every run of this invocation.
    tier: ExecTier,
    /// Wall time of the bind-time full validation; reported as the first
    /// call's `RunStats::checks` so per-call accounting stays complete.
    bind_checks: Duration,
    first_reported: bool,
}

impl BoundInvocation {
    pub fn domain(&self) -> [usize; 3] {
        self.domain
    }

    /// The full execution-options surface of this invocation: the compile
    /// half comes from the handle's artifact, the scheduling half is the
    /// invocation's own resolved plan/tier.
    pub fn exec_options(&self) -> ExecOptions {
        self.stencil
            .exec_options()
            .with_sharding(self.sharding)
            .with_tier(self.tier)
    }

    /// Apply the scheduling half of `exec` (sharding, tier) to this
    /// invocation — no re-validation needed, neither knob affects
    /// results. The compile half is fixed by the bound artifact and
    /// ignored here.
    pub fn set_exec_options(&mut self, exec: ExecOptions) {
        self.sharding = exec.sharding;
        self.tier = exec.tier;
    }

    /// The sharding plan this invocation runs with.
    pub fn sharding(&self) -> Sharding {
        self.sharding
    }

    /// Change the sharding plan between calls (no re-validation needed —
    /// the plan never affects results, only scheduling).
    pub fn set_sharding(&mut self, sharding: Sharding) {
        self.sharding = sharding;
    }

    /// The fused-path executor tier this invocation runs with.
    pub fn exec_tier(&self) -> ExecTier {
        self.tier
    }

    /// Change the executor tier between calls (no re-validation needed —
    /// the tier never affects results, only how the fused path executes).
    pub fn set_exec_tier(&mut self, tier: ExecTier) {
        self.tier = tier;
    }

    /// Field names in the order [`BoundInvocation::run`] expects.
    pub fn field_order(&self) -> &[String] {
        &self.field_names
    }

    /// Wall time the bind-time full validation took (zero when the
    /// handle's checks are disabled).
    pub fn bind_validation_time(&self) -> Duration {
        self.bind_checks
    }

    /// Update a bound scalar without re-validating storages (e.g. a time
    /// step that changes between model steps).
    pub fn set_scalar(&mut self, name: &str, value: f64) -> Result<()> {
        for (n, v) in &mut self.scalars {
            if n == name {
                *v = value;
                return Ok(());
            }
        }
        bail!("no scalar `{name}` bound on stencil `{}`", self.stencil.ir.name)
    }

    /// Execute once. `fields` must hold the storages in declaration order
    /// ([`BoundInvocation::field_order`]); only their geometry is
    /// re-checked against the bind-time snapshot — a reallocated storage
    /// with a different shape/halo/layout is rejected with a re-bind
    /// error, anything else is a cheap pass-through to the backend.
    ///
    /// The pairing is positional, like function arguments: two fields
    /// with *identical* geometry passed in the wrong order cannot be
    /// detected (deliberately — double-buffer patterns swap same-shape
    /// storages between calls). Consult [`field_order`] when in doubt.
    ///
    /// [`field_order`]: BoundInvocation::field_order
    pub fn run(&mut self, fields: &mut [&mut Storage]) -> Result<RunStats> {
        let t0 = Instant::now();
        if fields.len() != self.field_names.len() {
            bail!(
                "stencil `{}` takes {} field(s) ({}), got {}",
                self.stencil.ir.name,
                self.field_names.len(),
                self.field_names.join(", "),
                fields.len()
            );
        }
        let recheck = if self.stencil.checks_enabled {
            for ((storage, expected), name) in
                fields.iter().zip(&self.expected).zip(&self.field_names)
            {
                if storage.info != *expected {
                    bail!(
                        "field `{name}` geometry changed since bind \
                         (bound {expected:?}, got {:?}); re-bind the invocation",
                        storage.info
                    );
                }
            }
            t0.elapsed()
        } else {
            Duration::ZERO
        };

        let mut refs: Vec<(&str, &mut Storage)> = self
            .field_names
            .iter()
            .map(String::as_str)
            .zip(fields.iter_mut().map(|s| &mut **s))
            .collect();
        let srefs: Vec<(&str, f64)> =
            self.scalars.iter().map(|(n, v)| (n.as_str(), *v)).collect();
        let t1 = Instant::now();
        let shard = self.stencil.backend.run_sharded(
            &self.stencil.ir,
            &mut StencilArgs { fields: &mut refs, scalars: &srefs, domain: self.domain },
            &RunConfig { sharding: self.sharding, tier: self.tier },
        )?;
        let execute = t1.elapsed();

        // The first call carries the bind-time validation cost so summed
        // RunStats over a bind+run-many sequence account for every check.
        let checks = if self.first_reported {
            recheck
        } else {
            self.first_reported = true;
            self.bind_checks + recheck
        };
        self.stencil.metrics.record(
            &self.stencil.ir.name,
            self.stencil.backend.name(),
            checks,
            execute,
            shard.threads,
        );
        Ok(RunStats { checks, execute, shard })
    }
}

/// Allocate a zeroed storage with exactly the halo `ir`'s `field` requires
/// for `domain`, at the field's declared (or overridden) element dtype —
/// an f32 stencil gets genuine f32 buffers, never silently-widened f64.
pub(super) fn alloc_field_for(
    ir: &StencilIr,
    field: &str,
    domain: [usize; 3],
) -> Result<Storage> {
    let f = ir
        .field(field)
        .ok_or_else(|| anyhow!("stencil `{}` has no field `{field}`", ir.name))?;
    let e = f.extent;
    Ok(Storage::zeros(
        StorageInfo::new(
            domain,
            [
                ((-e.i.0) as usize, e.i.1 as usize),
                ((-e.j.0) as usize, e.j.1 as usize),
                ((-e.k.0) as usize, e.k.1 as usize),
            ],
        )
        .with_dtype(f.dtype),
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Coordinator;

    fn handle(backend: &str) -> Stencil {
        let mut c = Coordinator::new();
        c.stencil_library("diffuse", backend).unwrap()
    }

    #[test]
    fn handle_is_cheap_to_clone_and_shares_ir() {
        let s = handle("debug");
        let s2 = s.clone();
        assert!(Arc::ptr_eq(&s.ir, &s2.ir), "clones must share the IR");
        assert_eq!(s2.name(), "diffuse");
        assert_eq!(s2.backend_name(), "debug");
    }

    #[test]
    fn handles_are_send_and_sync() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<Stencil>();
        assert_send_sync::<BoundInvocation>();
    }

    #[test]
    fn bind_once_run_many() {
        let s = handle("debug");
        let domain = [6, 5, 2];
        let mut phi = s.alloc_field("phi", domain).unwrap();
        let mut out = s.alloc_field("out", domain).unwrap();
        phi.fill(1.0);
        let mut inv = s
            .bind()
            .field("out", &out) // bind order is free...
            .field("phi", &phi)
            .scalar("alpha", 0.1)
            .domain(domain)
            .finish()
            .unwrap();
        // ...but run order is declaration order.
        assert_eq!(inv.field_order(), &["phi".to_string(), "out".to_string()]);
        for _ in 0..3 {
            inv.run(&mut [&mut phi, &mut out]).unwrap();
        }
        assert_eq!(out.get(2, 2, 0), 1.0); // constant field: identity
    }

    #[test]
    fn bind_rejects_bad_arguments() {
        let s = handle("debug");
        let domain = [4, 4, 2];
        let phi = s.alloc_field("phi", domain).unwrap();
        let out = s.alloc_field("out", domain).unwrap();
        // missing domain
        assert!(s.bind().field("phi", &phi).field("out", &out).finish().is_err());
        // missing field
        assert!(s
            .bind()
            .field("phi", &phi)
            .scalar("alpha", 0.1)
            .domain(domain)
            .finish()
            .is_err());
        // unknown field
        assert!(s
            .bind()
            .field("phi", &phi)
            .field("out", &out)
            .field("ghost", &phi)
            .scalar("alpha", 0.1)
            .domain(domain)
            .finish()
            .is_err());
        // duplicate field
        assert!(s
            .bind()
            .field("phi", &phi)
            .field("phi", &phi)
            .field("out", &out)
            .scalar("alpha", 0.1)
            .domain(domain)
            .finish()
            .is_err());
        // missing / unknown / duplicate scalar
        assert!(s.bind().field("phi", &phi).field("out", &out).domain(domain).finish().is_err());
        assert!(s
            .bind()
            .field("phi", &phi)
            .field("out", &out)
            .scalar("alpha", 0.1)
            .scalar("beta", 1.0)
            .domain(domain)
            .finish()
            .is_err());
        assert!(s
            .bind()
            .field("phi", &phi)
            .field("out", &out)
            .scalar("alpha", 0.1)
            .scalar("alpha", 0.9)
            .domain(domain)
            .finish()
            .is_err());
        // insufficient halo caught at bind time
        let s2 = {
            let mut c = Coordinator::new();
            c.stencil_library("laplacian", "debug").unwrap()
        };
        let bad = Storage::with_halo(domain, 0);
        let o = s2.alloc_field("out", domain).unwrap();
        assert!(s2
            .bind()
            .field("phi", &bad)
            .field("out", &o)
            .domain(domain)
            .finish()
            .is_err());
    }

    #[test]
    fn stale_shape_rejected_until_rebind() {
        let s = handle("debug");
        let domain = [4, 4, 2];
        let mut phi = s.alloc_field("phi", domain).unwrap();
        let mut out = s.alloc_field("out", domain).unwrap();
        let mut inv = s
            .bind()
            .field("phi", &phi)
            .field("out", &out)
            .scalar("alpha", 0.2)
            .domain(domain)
            .finish()
            .unwrap();
        inv.run(&mut [&mut phi, &mut out]).unwrap();

        // Reallocate phi with a different geometry: the next call must be
        // rejected with a re-bind hint, not silently recomputed.
        let bigger = [8, 8, 2];
        let mut phi = s.alloc_field("phi", bigger).unwrap();
        let err = inv.run(&mut [&mut phi, &mut out]).unwrap_err();
        assert!(format!("{err:#}").contains("re-bind"), "{err:#}");

        // Re-binding against the new storages works.
        let mut out = s.alloc_field("out", bigger).unwrap();
        let mut inv = s
            .bind()
            .field("phi", &phi)
            .field("out", &out)
            .scalar("alpha", 0.2)
            .domain(bigger)
            .finish()
            .unwrap();
        inv.run(&mut [&mut phi, &mut out]).unwrap();
    }

    #[test]
    fn disabled_checks_report_zero_durations() {
        let mut s = handle("debug");
        s.set_checks_enabled(false);
        let domain = [4, 4, 2];
        let mut phi = s.alloc_field("phi", domain).unwrap();
        let mut out = s.alloc_field("out", domain).unwrap();
        let mut inv = s
            .bind()
            .field("phi", &phi)
            .field("out", &out)
            .scalar("alpha", 0.1)
            .domain(domain)
            .finish()
            .unwrap();
        assert_eq!(inv.bind_validation_time(), Duration::ZERO);
        let stats = inv.run(&mut [&mut phi, &mut out]).unwrap();
        assert_eq!(stats.checks, Duration::ZERO);
    }

    #[test]
    fn set_scalar_updates_between_calls() {
        let s = handle("vector");
        let domain = [4, 4, 1];
        let mut phi = s.alloc_field("phi", domain).unwrap();
        phi.fill(2.0);
        let mut out = s.alloc_field("out", domain).unwrap();
        let mut inv = s
            .bind()
            .field("phi", &phi)
            .field("out", &out)
            .scalar("alpha", 0.0)
            .domain(domain)
            .finish()
            .unwrap();
        inv.run(&mut [&mut phi, &mut out]).unwrap();
        assert_eq!(out.get(1, 1, 0), 2.0);
        inv.set_scalar("alpha", 0.5).unwrap();
        assert!(inv.set_scalar("nope", 1.0).is_err());
        inv.run(&mut [&mut phi, &mut out]).unwrap();
        // constant field: laplacian term zero, diffuse stays identity
        assert_eq!(out.get(1, 1, 0), 2.0);
    }

    #[test]
    fn exec_tier_overrides_flow_to_invocations() {
        let mut s = handle("vector");
        assert_eq!(s.exec_tier(), ExecTier::Specialized, "specialized is the default");
        s.set_exec_tier(ExecTier::Interpreted);
        let domain = [4, 4, 2];
        let mut phi = s.alloc_field("phi", domain).unwrap();
        phi.fill(1.0);
        let mut out = s.alloc_field("out", domain).unwrap();
        // The builder override beats the handle default...
        let mut inv = s
            .bind()
            .field("phi", &phi)
            .field("out", &out)
            .scalar("alpha", 0.1)
            .domain(domain)
            .exec_tier(ExecTier::Specialized)
            .finish()
            .unwrap();
        assert_eq!(inv.exec_tier(), ExecTier::Specialized);
        // ...and can be flipped between calls without re-binding.
        inv.run(&mut [&mut phi, &mut out]).unwrap();
        inv.set_exec_tier(ExecTier::Interpreted);
        inv.run(&mut [&mut phi, &mut out]).unwrap();
        assert_eq!(out.get(2, 2, 0), 1.0);
        // Without an override the handle's tier applies.
        let inv2 = s
            .bind()
            .field("phi", &phi)
            .field("out", &out)
            .scalar("alpha", 0.1)
            .domain(domain)
            .finish()
            .unwrap();
        assert_eq!(inv2.exec_tier(), ExecTier::Interpreted);
    }

    #[test]
    fn f32_sources_get_f32_storages_not_widened_f64() {
        use crate::dsl::ast::DType;
        // Regression: an f32 declaration used to be silently widened —
        // alloc_field handed back f64 buffers. Now the allocation honors
        // the declared dtype end to end and the arithmetic genuinely
        // rounds at single precision.
        // `(a + h) - a` with `h` below half an f32 ulp of 1: genuine f32
        // arithmetic absorbs `h` (result exactly 0), while f64 arithmetic
        // narrowed at the end keeps it (result ≈ h ≠ 0).
        const SRC: &str = "
            stencil cancel(a: Field<f32>, out: Field<f32>) {
                with computation(PARALLEL), interval(...) {
                    out = (a + 0.00000001) - a;
                }
            }";
        let mut c = Coordinator::new();
        let s = c.stencil(SRC, "cancel", "vector", &std::collections::BTreeMap::new()).unwrap();
        let domain = [4, 3, 2];
        let mut a = s.alloc_field("a", domain).unwrap();
        assert_eq!(a.info.dtype, DType::F32, "allocation must honor the declared dtype");
        let mut out = s.alloc_field("out", domain).unwrap();
        a.fill(1.0);
        let mut inv = s
            .bind()
            .field("a", &a)
            .field("out", &out)
            .domain(domain)
            .finish()
            .unwrap();
        inv.run(&mut [&mut a, &mut out]).unwrap();
        assert_eq!(out.get_t::<f32>(1, 1, 1), 0.0, "f32 must absorb the sub-ulp term");
        let widened = ((1.0f64 + 0.00000001) - 1.0) as f32;
        assert_ne!(widened, 0.0, "test must discriminate the paths");

        // Mixed-dtype binding is a structured bind-time error, not a
        // silent conversion: hand the f32 stencil an f64 storage.
        let bad = Storage::with_halo(domain, 0); // f64 default
        let err = s
            .bind()
            .field("a", &bad)
            .field("out", &out)
            .domain(domain)
            .finish()
            .unwrap_err();
        assert!(format!("{err:#}").contains("dtype"), "{err:#}");
    }

    #[test]
    fn metrics_recorded_through_handles() {
        let mut c = Coordinator::new();
        let s = c.stencil_library("copy", "debug").unwrap();
        let domain = [3, 3, 1];
        let mut src = s.alloc_field("src", domain).unwrap();
        let mut dst = s.alloc_field("dst", domain).unwrap();
        let mut inv = s
            .bind()
            .field("src", &src)
            .field("dst", &dst)
            .domain(domain)
            .finish()
            .unwrap();
        inv.run(&mut [&mut src, &mut dst]).unwrap();
        inv.run(&mut [&mut src, &mut dst]).unwrap();
        let t = c.metrics.get("copy", "debug").unwrap();
        assert_eq!(t.calls, 2);
    }

    #[test]
    fn exec_options_flow_handle_builder_invocation() {
        use crate::opt::OptLevel;
        let mut c = Coordinator::new();
        c.set_exec_options(
            ExecOptions::new()
                .with_opt_level(OptLevel::O3)
                .with_sharding(Sharding::Threads(2)),
        );
        let mut s = c.stencil_library("diffuse", "vector").unwrap();
        // The handle records the full surface it was minted with...
        assert_eq!(s.exec_options().opt_level, OptLevel::O3);
        assert_eq!(s.exec_options().sharding, Sharding::Threads(2));
        // ...and set_exec_options only moves the scheduling half.
        s.set_exec_options(
            ExecOptions::new()
                .with_sharding(Sharding::Off)
                .with_tier(ExecTier::Interpreted),
        );
        assert_eq!(s.exec_options().opt_level, OptLevel::O3, "compile half is baked in");
        assert_eq!(s.sharding(), Sharding::Off);
        assert_eq!(s.exec_tier(), ExecTier::Interpreted);

        let domain = [4, 4, 2];
        let mut phi = s.alloc_field("phi", domain).unwrap();
        phi.fill(1.0);
        let mut out = s.alloc_field("out", domain).unwrap();
        // Builder-level override via the unified surface...
        let mut inv = s
            .bind()
            .field("phi", &phi)
            .field("out", &out)
            .scalar("alpha", 0.1)
            .domain(domain)
            .exec_options(ExecOptions::new().with_sharding(Sharding::Auto))
            .finish()
            .unwrap();
        assert_eq!(inv.sharding(), Sharding::Auto);
        assert_eq!(inv.exec_tier(), ExecTier::default());
        assert_eq!(inv.exec_options().opt_level, OptLevel::O3);
        // ...and the invocation-level scheduling setter between calls.
        inv.run(&mut [&mut phi, &mut out]).unwrap();
        inv.set_exec_options(ExecOptions::new().with_sharding(Sharding::Off));
        inv.run(&mut [&mut phi, &mut out]).unwrap();
        assert_eq!(out.get(2, 2, 0), 1.0);
    }
}
