//! Per-stencil, per-backend execution metrics.
//!
//! The coordinator records wall-clock timings split into *check* time (the
//! run-time storage validation responsible for the paper's constant
//! per-call overhead, Fig. 3 solid-vs-dashed) and *execute* time, so the
//! overhead experiment is a first-class query.
//!
//! [`SharedMetrics`] is the thread-safe handle to one registry: every
//! [`crate::coordinator::Stencil`] cloned off a coordinator records into
//! the same registry, from any thread.

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};
use std::time::Duration;

#[derive(Debug, Default, Clone, Copy)]
pub struct Timing {
    pub calls: u64,
    pub checks: Duration,
    pub execute: Duration,
    /// Largest *effective* intra-call thread count any recorded run used
    /// (1 = every call ran serially; see
    /// [`crate::backend::shard::ShardReport::threads`]).
    pub max_threads: u32,
}

impl Timing {
    pub fn total(&self) -> Duration {
        self.checks + self.execute
    }

    pub fn mean_execute(&self) -> Duration {
        if self.calls == 0 {
            Duration::ZERO
        } else {
            self.execute / self.calls as u32
        }
    }

    pub fn mean_total(&self) -> Duration {
        if self.calls == 0 {
            Duration::ZERO
        } else {
            self.total() / self.calls as u32
        }
    }
}

/// Metrics registry keyed by `(stencil, backend)`.
#[derive(Debug, Default)]
pub struct Metrics {
    entries: BTreeMap<(String, String), Timing>,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(
        &mut self,
        stencil: &str,
        backend: &str,
        checks: Duration,
        execute: Duration,
        threads: u32,
    ) {
        let e = self
            .entries
            .entry((stencil.to_string(), backend.to_string()))
            .or_default();
        e.calls += 1;
        e.checks += checks;
        e.execute += execute;
        e.max_threads = e.max_threads.max(threads.max(1));
    }

    pub fn get(&self, stencil: &str, backend: &str) -> Option<&Timing> {
        self.entries.get(&(stencil.to_string(), backend.to_string()))
    }

    pub fn iter(&self) -> impl Iterator<Item = (&(String, String), &Timing)> {
        self.entries.iter()
    }

    /// Human-readable report table.
    pub fn report(&self) -> String {
        use std::fmt::Write as _;
        let mut s = String::new();
        let _ = writeln!(
            s,
            "{:<24} {:<10} {:>8} {:>14} {:>14} {:>8}",
            "stencil", "backend", "calls", "mean exec", "mean checks", "threads"
        );
        for ((st, be), t) in &self.entries {
            let _ = writeln!(
                s,
                "{:<24} {:<10} {:>8} {:>14?} {:>14?} {:>8}",
                st,
                be,
                t.calls,
                t.mean_execute(),
                if t.calls == 0 { Duration::ZERO } else { t.checks / t.calls as u32 },
                t.max_threads.max(1)
            );
        }
        s
    }
}

/// Thread-safe, clonable handle to one [`Metrics`] registry. The
/// coordinator owns one and stamps a clone into every [`Stencil`] handle
/// it hands out, so timings recorded by concurrent dispatches all land in
/// the same place.
///
/// [`Stencil`]: crate::coordinator::Stencil
#[derive(Debug, Default, Clone)]
pub struct SharedMetrics(Arc<Mutex<Metrics>>);

impl SharedMetrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(
        &self,
        stencil: &str,
        backend: &str,
        checks: Duration,
        execute: Duration,
        threads: u32,
    ) {
        self.0.lock().unwrap().record(stencil, backend, checks, execute, threads);
    }

    /// Timing for a `(stencil, backend)` pair ([`Timing`] is `Copy`).
    pub fn get(&self, stencil: &str, backend: &str) -> Option<Timing> {
        self.0.lock().unwrap().get(stencil, backend).copied()
    }

    /// Human-readable report table.
    pub fn report(&self) -> String {
        self.0.lock().unwrap().report()
    }

    /// Snapshot of every `((stencil, backend), timing)` entry.
    pub fn entries(&self) -> Vec<((String, String), Timing)> {
        self.0
            .lock()
            .unwrap()
            .iter()
            .map(|(k, t)| (k.clone(), *t))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_averages() {
        let mut m = Metrics::new();
        m.record("hdiff", "xla", Duration::from_micros(100), Duration::from_micros(900), 1);
        m.record("hdiff", "xla", Duration::from_micros(100), Duration::from_micros(1100), 4);
        let t = m.get("hdiff", "xla").unwrap();
        assert_eq!(t.calls, 2);
        assert_eq!(t.mean_execute(), Duration::from_micros(1000));
        assert_eq!(t.total(), Duration::from_micros(2200));
        assert_eq!(t.max_threads, 4, "effective thread high-water mark");
        assert!(m.report().contains("hdiff"));
    }

    #[test]
    fn missing_entry_is_none() {
        let m = Metrics::new();
        assert!(m.get("x", "y").is_none());
    }

    #[test]
    fn shared_metrics_aggregate_across_clones_and_threads() {
        let shared = SharedMetrics::new();
        let clones: Vec<SharedMetrics> = (0..4).map(|_| shared.clone()).collect();
        std::thread::scope(|s| {
            for m in &clones {
                s.spawn(move || {
                    m.record(
                        "hdiff",
                        "vector",
                        Duration::from_micros(1),
                        Duration::from_micros(10),
                        1,
                    );
                });
            }
        });
        let t = shared.get("hdiff", "vector").unwrap();
        assert_eq!(t.calls, 4);
        assert_eq!(t.execute, Duration::from_micros(40));
        assert_eq!(shared.entries().len(), 1);
    }
}
