//! The coordinator: the user-facing engine tying frontend, cache, backends
//! and run-time checks together (the role `gtscript.stencil(...)` +
//! generated stencil objects play in GT4Py).
//!
//! Responsibilities:
//! * compile sources (or library stencils) through the pipeline *and the
//!   optimizing pass manager* ([`crate::opt`]), memoized by a formatting-
//!   insensitive definition fingerprint salted with the pass
//!   configuration (different opt levels never share cache entries);
//! * dispatch runs to any registered backend, reusing backend instances so
//!   their executable caches stay warm;
//! * perform the run-time storage checks (layout/halo/dtype) the paper
//!   attributes its small-domain constant overhead to — and allow turning
//!   them off (`checks_enabled`), reproducing the Fig. 3 dashed lines;
//! * collect per-(stencil, backend) metrics.

pub mod metrics;

use crate::analysis;
use crate::backend::{self, Backend, StencilArgs};
use crate::cache::StencilCache;
use crate::dsl::parser::parse_module;
use crate::ir::canon;
use crate::ir::implir::StencilIr;
use crate::opt::{OptConfig, OptLevel};
use crate::stdlib;
use crate::storage::{Storage, StorageInfo};
use anyhow::{anyhow, Result};
use metrics::Metrics;
use std::collections::{BTreeMap, HashMap};
use std::time::{Duration, Instant};

/// Formatting-insensitive fingerprint of a stencil *definition* plus its
/// externals — computable before analysis, used to memoize the pipeline.
pub fn def_fingerprint(
    src: &str,
    stencil: &str,
    externals: &BTreeMap<String, f64>,
) -> Result<u64> {
    let module = parse_module(src).map_err(|e| anyhow!("{e}"))?;
    let def = module
        .stencil(stencil)
        .ok_or_else(|| anyhow!("no stencil `{stencil}` in module"))?;
    let mut s = String::new();
    use std::fmt::Write as _;
    let _ = write!(s, "def {stencil};");
    for f in &def.fields {
        let _ = write!(s, "f {}:{};", f.name, f.dtype);
    }
    for sc in &def.scalars {
        let _ = write!(s, "s {}:{};", sc.name, sc.dtype);
    }
    for (k, v) in externals {
        let _ = write!(s, "x {}={:016x};", k, v.to_bits());
    }
    for (k, v) in &module.extern_defaults {
        let _ = write!(s, "d {}={:016x};", k, v.to_bits());
    }
    for c in &def.computations {
        let _ = write!(s, "c {};", c.policy);
        for b in &c.blocks {
            let _ = write!(s, "i {};", b.interval);
            canon::canon_stmts(&b.body, &mut s);
        }
    }
    // Functions are part of the definition: include them canonically.
    for func in &module.functions {
        let _ = write!(s, "fn {}(", func.name);
        for p in &func.params {
            let _ = write!(s, "{p},");
        }
        let _ = write!(s, ");");
        for (n, e) in &func.bindings {
            let _ = write!(s, "let {n}=");
            canon::canon_expr(e, &mut s);
            s.push(';');
        }
        canon::canon_expr(&func.ret, &mut s);
        s.push(';');
    }
    Ok(canon::fnv1a64(s.as_bytes()))
}

/// Statistics of one `run` call.
#[derive(Debug, Clone, Copy)]
pub struct RunStats {
    pub checks: Duration,
    pub execute: Duration,
}

impl RunStats {
    pub fn total(&self) -> Duration {
        self.checks + self.execute
    }
}

/// The engine. One instance per thread (PJRT clients are not `Sync`).
pub struct Coordinator {
    backends: HashMap<String, Box<dyn Backend>>,
    stencils: StencilCache,
    /// Fingerprints by registered stencil name, for name-based dispatch.
    by_name: HashMap<String, u64>,
    /// Run-time storage validation (the paper's per-call checks).
    pub checks_enabled: bool,
    /// Pass-manager configuration applied after analysis. Defaults to the
    /// full opt-level 2 set; part of every compilation cache key, so one
    /// coordinator can serve multiple opt levels without collisions.
    opt: OptConfig,
    pub metrics: Metrics,
}

impl Default for Coordinator {
    fn default() -> Self {
        Self::new()
    }
}

impl Coordinator {
    pub fn new() -> Coordinator {
        Coordinator {
            backends: HashMap::new(),
            stencils: StencilCache::new(),
            by_name: HashMap::new(),
            checks_enabled: true,
            opt: OptConfig::default(),
            metrics: Metrics::new(),
        }
    }

    /// A coordinator pinned to an optimization level.
    pub fn with_opt_level(level: OptLevel) -> Coordinator {
        let mut c = Coordinator::new();
        c.set_opt_level(level);
        c
    }

    pub fn set_opt_level(&mut self, level: OptLevel) {
        self.opt = OptConfig::level(level);
    }

    pub fn set_opt_config(&mut self, config: OptConfig) {
        self.opt = config;
    }

    pub fn opt_config(&self) -> &OptConfig {
        &self.opt
    }

    /// Compile (or fetch from cache) a stencil from module source, running
    /// the optimizing pass manager over the pipeline output. Returns the
    /// stencil's cache key (definition fingerprint salted with the pass
    /// configuration — recompiling the same source at a different opt
    /// level is a distinct cache entry).
    pub fn compile_source(
        &mut self,
        src: &str,
        stencil: &str,
        externals: &BTreeMap<String, f64>,
    ) -> Result<u64> {
        let def_fp = def_fingerprint(src, stencil, externals)? ^ self.opt.salt();
        let opt = self.opt;
        let ir = self.stencils.get_or_insert(def_fp, || {
            analysis::compile_source_opt(src, stencil, externals, &opt)
                .map_err(|e| anyhow!("{e}"))
        })?;
        let name = ir.name.clone();
        self.by_name.insert(name, def_fp);
        Ok(def_fp)
    }

    /// Compile a stencil from the standard library.
    pub fn compile_library(&mut self, name: &str) -> Result<u64> {
        let src = stdlib::source(name)
            .ok_or_else(|| anyhow!("no library stencil named `{name}`"))?;
        self.compile_source(src, name, &BTreeMap::new())
    }

    /// The analyzed IR for a previously compiled stencil.
    pub fn ir(&mut self, fingerprint: u64) -> Result<StencilIr> {
        Ok(self
            .stencils
            .get_or_insert(fingerprint, || {
                Err(anyhow!("fingerprint {fingerprint:016x} not compiled"))
            })?
            .clone())
    }

    /// Fingerprint registered for a stencil name.
    pub fn fingerprint_of(&self, name: &str) -> Option<u64> {
        self.by_name.get(name).copied()
    }

    /// Cache statistics `(hits, misses)` of the stencil cache.
    pub fn cache_stats(&self) -> (usize, usize) {
        (self.stencils.hits, self.stencils.misses)
    }

    fn backend(&mut self, name: &str) -> Result<&mut Box<dyn Backend>> {
        if !self.backends.contains_key(name) {
            let be = backend::create(name)?;
            self.backends.insert(name.to_string(), be);
        }
        Ok(self.backends.get_mut(name).unwrap())
    }

    /// Register a custom backend instance under its name (e.g. a
    /// pre-warmed `XlaBackend` sharing a runtime).
    pub fn register_backend(&mut self, be: Box<dyn Backend>) {
        self.backends.insert(be.name().to_string(), be);
    }

    /// Allocate a zeroed storage with exactly the halo a stencil's field
    /// requires for `domain` (the `gt4py.storage.zeros(backend=...)`
    /// analog).
    pub fn alloc_field(
        &mut self,
        fingerprint: u64,
        field: &str,
        domain: [usize; 3],
    ) -> Result<Storage> {
        let ir = self.ir(fingerprint)?;
        let f = ir
            .field(field)
            .ok_or_else(|| anyhow!("stencil `{}` has no field `{field}`", ir.name))?;
        let e = f.extent;
        Ok(Storage::zeros(StorageInfo::new(
            domain,
            [
                ((-e.i.0) as usize, e.i.1 as usize),
                ((-e.j.0) as usize, e.j.1 as usize),
                ((-e.k.0) as usize, e.k.1 as usize),
            ],
        )))
    }

    /// Run a compiled stencil on a backend.
    pub fn run<'b>(
        &mut self,
        fingerprint: u64,
        backend_name: &str,
        fields: &mut [(&'b str, &'b mut Storage)],
        scalars: &[(&'b str, f64)],
        domain: [usize; 3],
    ) -> Result<RunStats> {
        let ir = self.ir(fingerprint)?;

        let checks = if self.checks_enabled {
            let t0 = Instant::now();
            crate::backend::program::validate_args(&ir, fields, scalars, domain)?;
            t0.elapsed()
        } else {
            Duration::ZERO
        };

        let be = self.backend(backend_name)?;
        let t1 = Instant::now();
        be.run(&ir, &mut StencilArgs { fields, scalars, domain })?;
        let execute = t1.elapsed();

        self.metrics.record(&ir.name, backend_name, checks, execute);
        Ok(RunStats { checks, execute })
    }

    /// Run a stencil by registered name.
    pub fn run_by_name<'b>(
        &mut self,
        stencil: &str,
        backend_name: &str,
        fields: &mut [(&'b str, &'b mut Storage)],
        scalars: &[(&'b str, f64)],
        domain: [usize; 3],
    ) -> Result<RunStats> {
        let fp = self
            .fingerprint_of(stencil)
            .ok_or_else(|| anyhow!("stencil `{stencil}` not compiled"))?;
        self.run(fp, backend_name, fields, scalars, domain)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn compile_run_roundtrip_with_cache() {
        let mut c = Coordinator::new();
        let fp = c.compile_library("copy").unwrap();
        // Recompiling is a cache hit.
        let fp2 = c.compile_library("copy").unwrap();
        assert_eq!(fp, fp2);
        assert_eq!(c.cache_stats(), (1, 1));

        let domain = [4, 3, 2];
        let mut src = c.alloc_field(fp, "src", domain).unwrap();
        let mut dst = c.alloc_field(fp, "dst", domain).unwrap();
        src.set(1, 2, 1, 7.0);
        let mut refs: Vec<(&str, &mut Storage)> =
            vec![("src", &mut src), ("dst", &mut dst)];
        let stats = c.run(fp, "debug", &mut refs, &[], domain).unwrap();
        assert!(stats.execute > Duration::ZERO);
        assert_eq!(dst.get(1, 2, 1), 7.0);
        assert!(c.metrics.get("copy", "debug").is_some());
    }

    #[test]
    fn reformatted_source_hits_cache() {
        let a = "stencil s(a: Field<f64>, b: Field<f64>) {\n\
                   with computation(PARALLEL), interval(...) { b = a; }\n\
                 }";
        let b = "stencil   s(  a : Field<f64>,   b : Field<f64> ) {
                   # a comment
                   with computation(PARALLEL), interval(...) {
                       b = a;
                   }
                 }";
        let mut c = Coordinator::new();
        let fa = c.compile_source(a, "s", &BTreeMap::new()).unwrap();
        let fb = c.compile_source(b, "s", &BTreeMap::new()).unwrap();
        assert_eq!(fa, fb);
        assert_eq!(c.cache_stats(), (1, 1));
    }

    #[test]
    fn checks_catch_bad_halo_and_can_be_disabled() {
        let mut c = Coordinator::new();
        let fp = c.compile_library("laplacian").unwrap();
        let domain = [4, 4, 2];
        // Deliberately halo-less storages: checks must reject them.
        let mut phi = Storage::with_halo(domain, 0);
        let mut out = Storage::with_halo(domain, 0);
        {
            let mut refs: Vec<(&str, &mut Storage)> =
                vec![("phi", &mut phi), ("out", &mut out)];
            assert!(c.run(fp, "debug", &mut refs, &[], domain).is_err());
        }
        // Disabling the checks reproduces the unvalidated (dashed-line)
        // path; with an OOB halo this would be UB-ish, so use valid
        // storages and just assert the checks time is zero-ish.
        c.checks_enabled = false;
        let mut phi = c.alloc_field(fp, "phi", domain).unwrap();
        let mut out = c.alloc_field(fp, "out", domain).unwrap();
        let mut refs: Vec<(&str, &mut Storage)> =
            vec![("phi", &mut phi), ("out", &mut out)];
        let stats = c.run(fp, "debug", &mut refs, &[], domain).unwrap();
        assert_eq!(stats.checks, Duration::ZERO);
    }

    #[test]
    fn scalar_args_flow_through() {
        let mut c = Coordinator::new();
        let fp = c.compile_library("diffuse").unwrap();
        let domain = [4, 4, 1];
        let mut phi = c.alloc_field(fp, "phi", domain).unwrap();
        phi.fill(1.0);
        let mut out = c.alloc_field(fp, "out", domain).unwrap();
        let mut refs: Vec<(&str, &mut Storage)> =
            vec![("phi", &mut phi), ("out", &mut out)];
        c.run(fp, "debug", &mut refs, &[("alpha", 0.1)], domain).unwrap();
        // constant field: laplacian zero, out == phi
        assert_eq!(out.get(2, 2, 0), 1.0);
    }

    #[test]
    fn opt_levels_get_distinct_cache_entries() {
        use crate::opt::OptLevel;
        let src = "stencil s(a: Field<f64>, b: Field<f64>) {\n\
                     with computation(PARALLEL), interval(...) { t = a * 2.0; b = t; }\n\
                   }";
        let mut c = Coordinator::new();
        c.set_opt_level(OptLevel::O0);
        let k0 = c.compile_source(src, "s", &BTreeMap::new()).unwrap();
        c.set_opt_level(OptLevel::O2);
        let k2 = c.compile_source(src, "s", &BTreeMap::new()).unwrap();
        assert_ne!(k0, k2, "opt levels must not collide in the cache");
        assert_eq!(c.cache_stats(), (0, 2));
        // Same source at the same level is still a pure cache hit.
        let k2b = c.compile_source(src, "s", &BTreeMap::new()).unwrap();
        assert_eq!(k2, k2b);
        assert_eq!(c.cache_stats(), (1, 2));
        // The cached IRs really differ: O2 demotes the temporary.
        // (Each `ir()` lookup below is itself a cache hit.)
        use crate::ir::implir::StorageClass;
        assert_eq!(c.ir(k0).unwrap().temporary("t").unwrap().storage, StorageClass::Field3D);
        assert_eq!(c.ir(k2).unwrap().temporary("t").unwrap().storage, StorageClass::Register);
        assert_ne!(c.ir(k0).unwrap().fingerprint, c.ir(k2).unwrap().fingerprint);
    }

    #[test]
    fn optimized_and_unoptimized_runs_agree() {
        let domain = [8, 7, 4];
        let mut sums = Vec::new();
        for level in [crate::opt::OptLevel::O0, crate::opt::OptLevel::O2] {
            let mut c = Coordinator::with_opt_level(level);
            let fp = c.compile_library("hdiff").unwrap();
            let mut inp = c.alloc_field(fp, "in_phi", domain).unwrap();
            let mut coeff = c.alloc_field(fp, "coeff", domain).unwrap();
            let mut out = c.alloc_field(fp, "out_phi", domain).unwrap();
            let h = inp.info.halo;
            let [ni, nj, nk] = domain;
            for i in -(h[0].0 as i64)..(ni + h[0].1) as i64 {
                for j in -(h[1].0 as i64)..(nj + h[1].1) as i64 {
                    for k in 0..nk as i64 {
                        inp.set(i, j, k, ((i * 3 + j * 5 + k * 7) as f64).sin());
                    }
                }
            }
            coeff.fill(0.05);
            let mut refs: Vec<(&str, &mut Storage)> = vec![
                ("in_phi", &mut inp),
                ("coeff", &mut coeff),
                ("out_phi", &mut out),
            ];
            c.run(fp, "vector", &mut refs, &[], domain).unwrap();
            sums.push(out.domain_sum());
        }
        assert_eq!(sums[0].to_bits(), sums[1].to_bits(), "opt level changed results");
    }

    #[test]
    fn unknown_backend_or_name_errors() {
        let mut c = Coordinator::new();
        let fp = c.compile_library("copy").unwrap();
        let domain = [2, 2, 1];
        let mut a = c.alloc_field(fp, "src", domain).unwrap();
        let mut b = c.alloc_field(fp, "dst", domain).unwrap();
        let mut refs: Vec<(&str, &mut Storage)> = vec![("src", &mut a), ("dst", &mut b)];
        assert!(c.run(fp, "warp-drive", &mut refs, &[], domain).is_err());
        assert!(c
            .run_by_name("never_compiled", "debug", &mut [], &[], domain)
            .is_err());
    }
}
