//! The coordinator: the compilation front door tying frontend, cache,
//! optimizer and backends together — and the factory for [`Stencil`]
//! handles, the user-facing artifact (the object `gtscript.stencil(...)`
//! returns in GT4Py).
//!
//! Responsibilities:
//! * compile sources (or library stencils) through the pipeline *and the
//!   optimizing pass manager* ([`crate::opt`]), memoized by a formatting-
//!   insensitive definition fingerprint salted with the pass
//!   configuration (different opt levels never share cache entries); the
//!   cache hands out `Arc<StencilIr>`, so a hit is a refcount bump, never
//!   a deep copy;
//! * mint [`Stencil`] handles — cheap-to-clone, `Send + Sync` pairings of
//!   one compiled IR with one backend instance. Handles dispatch through
//!   an invocation builder ([`Stencil::bind`]) that validates storages
//!   once and then only re-checks shapes per call; cloned handles
//!   dispatch the same compiled stencil concurrently from many threads;
//! * reuse backend instances across stencils and handles so their
//!   executable caches stay warm;
//! * collect per-(stencil, backend) metrics ([`metrics::SharedMetrics`]).
//!
//! Execution knobs flow through one [`ExecOptions`] surface
//! ([`Coordinator::set_exec_options`]): the fingerprint-salting half (opt
//! level, fast-math) selects what artifact is compiled, the scheduling
//! half (sharding, tier) is stamped into minted handles and overridable
//! per invocation. The per-knob setters survive as thin delegates.
//! (The old slice-based `Coordinator::run` shims are gone: the handle API
//! is the only entry point.)

pub mod metrics;
pub mod stencil;

pub use stencil::{BoundInvocation, InvocationBuilder, Stencil};

use crate::analysis;
use crate::backend::kernels::ExecTier;
use crate::backend::shard::{ShardReport, Sharding};
use crate::backend::{self, Backend};
use crate::cache::StencilCache;
use crate::dsl::parser::parse_module;
use crate::ir::canon;
use crate::ir::implir::StencilIr;
use crate::opt::{ExecOptions, OptConfig, OptLevel};
use crate::persist::{self, PersistStore};
use crate::stdlib;
use crate::storage::Storage;
use anyhow::{anyhow, Result};
use metrics::SharedMetrics;
use std::collections::{BTreeMap, HashMap};
use std::sync::Arc;
use std::time::Duration;

/// Formatting-insensitive fingerprint of a stencil *definition* plus its
/// externals — computable before analysis, used to memoize the pipeline.
pub fn def_fingerprint(
    src: &str,
    stencil: &str,
    externals: &BTreeMap<String, f64>,
) -> Result<u64> {
    let module = parse_module(src).map_err(|e| anyhow!("{e}"))?;
    let def = module
        .stencil(stencil)
        .ok_or_else(|| anyhow!("no stencil `{stencil}` in module"))?;
    let mut s = String::new();
    use std::fmt::Write as _;
    let _ = write!(s, "def {stencil};");
    for f in &def.fields {
        let _ = write!(s, "f {}:{};", f.name, f.dtype);
    }
    for sc in &def.scalars {
        let _ = write!(s, "s {}:{};", sc.name, sc.dtype);
    }
    for (k, v) in externals {
        let _ = write!(s, "x {}={:016x};", k, v.to_bits());
    }
    for (k, v) in &module.extern_defaults {
        let _ = write!(s, "d {}={:016x};", k, v.to_bits());
    }
    for c in &def.computations {
        let _ = write!(s, "c {};", c.policy);
        for b in &c.blocks {
            let _ = write!(s, "i {};", b.interval);
            canon::canon_stmts(&b.body, &mut s);
        }
    }
    // Functions are part of the definition: include them canonically.
    for func in &module.functions {
        let _ = write!(s, "fn {}(", func.name);
        for p in &func.params {
            let _ = write!(s, "{p},");
        }
        let _ = write!(s, ");");
        for (n, e) in &func.bindings {
            let _ = write!(s, "let {n}=");
            canon::canon_expr(e, &mut s);
            s.push(';');
        }
        canon::canon_expr(&func.ret, &mut s);
        s.push(';');
    }
    Ok(canon::fnv1a64(s.as_bytes()))
}

/// Statistics of one run call.
#[derive(Debug, Clone, Copy)]
pub struct RunStats {
    pub checks: Duration,
    pub execute: Duration,
    /// What the intra-call sharding schedule actually did: the
    /// *effective* thread count (1 when the plan degraded to serial),
    /// slab count, and per-slab busy-time spread. Always truthful —
    /// `--json` consumers must never see the requested plan echoed back
    /// as if it had run.
    pub shard: ShardReport,
}

impl RunStats {
    pub fn total(&self) -> Duration {
        self.checks + self.execute
    }

    /// Effective intra-call thread count of this run.
    pub fn threads_used(&self) -> u32 {
        self.shard.threads.max(1)
    }
}

/// The engine. Compilation (`&mut self`) is single-threaded; the
/// [`Stencil`] handles it mints are `Send + Sync` and dispatch from any
/// number of threads.
pub struct Coordinator {
    backends: HashMap<String, Arc<dyn Backend>>,
    stencils: StencilCache,
    /// Fingerprints by registered stencil name, for name-based dispatch.
    by_name: HashMap<String, u64>,
    /// Run-time storage validation (the paper's per-call checks); stamped
    /// into every handle minted afterwards.
    pub checks_enabled: bool,
    /// Pass-manager configuration applied after analysis. Defaults to the
    /// full opt-level 2 set; part of every compilation cache key, so one
    /// coordinator can serve multiple opt levels without collisions.
    opt: OptConfig,
    /// The level that produced `opt` (reported by
    /// [`Coordinator::exec_options`]; a raw [`Coordinator::set_opt_config`]
    /// escape-hatch call leaves it at the last level set).
    level: OptLevel,
    /// Optional on-disk artifact store (see [`crate::persist`]). When
    /// attached, compilation consults it before running the pipeline
    /// (load-or-compile) and every backend the coordinator creates is
    /// handed the same store for its own artifacts.
    persist: Option<Arc<PersistStore>>,
    /// Full dsl→analysis→opt pipeline runs this coordinator performed —
    /// the warm-start honesty counter: a process served entirely from the
    /// persist store reports zero here even though every stencil it minted
    /// was a [`StencilCache`] *miss* (the in-memory cache counts lookups;
    /// this counts actual compilations).
    pipeline_compiles: u64,
    pub metrics: SharedMetrics,
}

impl Default for Coordinator {
    fn default() -> Self {
        Self::new()
    }
}

impl Coordinator {
    pub fn new() -> Coordinator {
        Coordinator {
            backends: HashMap::new(),
            stencils: StencilCache::new(),
            by_name: HashMap::new(),
            checks_enabled: true,
            opt: OptConfig::default(),
            level: OptLevel::O2,
            persist: None,
            pipeline_compiles: 0,
            metrics: SharedMetrics::new(),
        }
    }

    /// Attach a persistent artifact store: subsequent compilations
    /// load-or-compile through it, and every backend instance (existing
    /// and future) is handed the store for its own artifacts (fused
    /// tapes, HLO text).
    pub fn set_persist(&mut self, store: Arc<PersistStore>) {
        for be in self.backends.values() {
            be.set_persist(&store);
        }
        self.persist = Some(store);
    }

    /// The attached persist store, if any.
    pub fn persist(&self) -> Option<&Arc<PersistStore>> {
        self.persist.as_ref()
    }

    /// Persist-store `(hits, misses, rejects)` counters, `None` when no
    /// store is attached.
    pub fn persist_counters(&self) -> Option<(u64, u64, u64)> {
        self.persist.as_ref().map(|s| s.counters())
    }

    /// How many times this coordinator ran the full dsl→analysis→opt
    /// pipeline (persist hits and in-memory cache hits don't count). A
    /// fresh process serving a warmed cache reports zero.
    pub fn pipeline_compiles(&self) -> u64 {
        self.pipeline_compiles
    }

    /// A coordinator pinned to an optimization level.
    pub fn with_opt_level(level: OptLevel) -> Coordinator {
        let mut c = Coordinator::new();
        c.set_opt_level(level);
        c
    }

    /// A coordinator pinned to a full [`ExecOptions`] configuration.
    pub fn with_exec_options(exec: ExecOptions) -> Coordinator {
        let mut c = Coordinator::new();
        c.set_exec_options(exec);
        c
    }

    /// Set every execution knob at once — the unified surface. The
    /// fingerprint-salting half (opt level, fast-math) applies to
    /// subsequent compilations; the scheduling half (sharding, tier) is
    /// stamped into every handle minted afterwards.
    pub fn set_exec_options(&mut self, exec: ExecOptions) {
        self.level = exec.opt_level;
        self.opt = exec.opt_config();
    }

    /// The coordinator's current execution options (reconstructed from
    /// the active pass configuration; a custom [`Coordinator::set_opt_config`]
    /// reports the last level set through this surface).
    pub fn exec_options(&self) -> ExecOptions {
        ExecOptions {
            opt_level: self.level,
            fast_math: self.opt.fast_math,
            dtype: self.opt.dtype,
            sharding: self.opt.sharding,
            tier: self.opt.tier,
        }
    }

    /// Thin delegate: change only the opt level. The scheduling knobs and
    /// the fast-math opt-in are orthogonal and survive level changes (a
    /// level switch must not silently revoke a numeric-policy choice).
    pub fn set_opt_level(&mut self, level: OptLevel) {
        self.set_exec_options(self.exec_options().with_opt_level(level));
    }

    /// Thin delegate: default intra-call sharding plan stamped into every
    /// handle minted afterwards (never part of compilation cache keys —
    /// every plan is bitwise-identical by contract).
    pub fn set_sharding(&mut self, sharding: Sharding) {
        self.opt.sharding = sharding;
    }

    pub fn sharding(&self) -> Sharding {
        self.opt.sharding
    }

    /// Thin delegate: default fused-path executor tier stamped into every
    /// handle minted afterwards. Like sharding, a pure scheduling knob:
    /// both tiers are bitwise-identical by contract and share one
    /// compilation cache entry.
    pub fn set_exec_tier(&mut self, tier: ExecTier) {
        self.opt.tier = tier;
    }

    pub fn exec_tier(&self) -> ExecTier {
        self.opt.tier
    }

    /// Thin delegate: opt into (or out of) fast-math numeric relaxation
    /// for subsequent compilations. Unlike sharding and the executor tier
    /// this *does* salt the compilation cache key — exact and relaxed
    /// artifacts never share a slot — because it changes results within a
    /// tolerance bound.
    pub fn set_fast_math(&mut self, fast_math: bool) {
        self.opt.fast_math = fast_math;
    }

    pub fn fast_math(&self) -> bool {
        self.opt.fast_math
    }

    /// Thin delegate: storage-precision override for subsequent
    /// compilations (`None` honors the source declarations). Salts the
    /// compilation cache key like fast-math — an f32 artifact computes
    /// genuinely different bits than the f64 one, so the two must never
    /// share a slot.
    pub fn set_dtype(&mut self, dtype: Option<crate::dsl::ast::DType>) {
        self.opt.dtype = dtype;
    }

    pub fn dtype(&self) -> Option<crate::dsl::ast::DType> {
        self.opt.dtype
    }

    /// Low-level escape hatch: install an arbitrary pass combination that
    /// no [`OptLevel`] names. Prefer [`Coordinator::set_exec_options`].
    pub fn set_opt_config(&mut self, config: OptConfig) {
        self.opt = config;
    }

    pub fn opt_config(&self) -> &OptConfig {
        &self.opt
    }

    /// Compile (or fetch from cache) a stencil from module source, running
    /// the optimizing pass manager over the pipeline output. Returns the
    /// stencil's cache key (definition fingerprint salted with the pass
    /// configuration — recompiling the same source at a different opt
    /// level is a distinct cache entry).
    pub fn compile_source(
        &mut self,
        src: &str,
        stencil: &str,
        externals: &BTreeMap<String, f64>,
    ) -> Result<u64> {
        let def_fp = def_fingerprint(src, stencil, externals)? ^ self.opt.salt();
        let opt = self.opt;
        let store = self.persist.clone();
        let mut ran_pipeline = false;
        let ir = self.stencils.get_or_insert(def_fp, || {
            // Load-or-compile: a persist hit skips the pipeline entirely.
            // Loaded IR is only trusted after its fingerprint recomputes
            // from the canonical text under the *current* pass tag — a
            // digest-valid entry that fails this is demoted to a reject.
            let key = format!("{def_fp:016x}");
            if let Some(s) = &store {
                if let Some(payload) = s.load("ir", &key) {
                    match persist::irser::ir_from_json(&payload) {
                        Some(ir)
                            if analysis::fingerprint_ir_with(&ir, &opt.canon())
                                == ir.fingerprint =>
                        {
                            return Ok(ir)
                        }
                        _ => s.reject_loaded(),
                    }
                }
            }
            ran_pipeline = true;
            let ir = analysis::compile_source_opt(src, stencil, externals, &opt)
                .map_err(|e| anyhow!("{e}"))?;
            if let Some(s) = &store {
                if let Some(payload) = persist::irser::ir_to_json(&ir) {
                    let _ = s.store("ir", &key, &payload);
                }
            }
            Ok(ir)
        })?;
        if ran_pipeline {
            self.pipeline_compiles += 1;
        }
        self.by_name.insert(ir.name.clone(), def_fp);
        Ok(def_fp)
    }

    /// Compile a stencil from the standard library.
    pub fn compile_library(&mut self, name: &str) -> Result<u64> {
        let src = stdlib::source(name)
            .ok_or_else(|| anyhow!("no library stencil named `{name}`"))?;
        self.compile_source(src, name, &BTreeMap::new())
    }

    /// The analyzed IR for a previously compiled stencil (shared — a
    /// refcount bump, not a copy).
    pub fn ir(&mut self, fingerprint: u64) -> Result<Arc<StencilIr>> {
        self.stencils.get_or_insert(fingerprint, || {
            Err(anyhow!("fingerprint {fingerprint:016x} not compiled"))
        })
    }

    /// Fingerprint registered for a stencil name.
    pub fn fingerprint_of(&self, name: &str) -> Option<u64> {
        self.by_name.get(name).copied()
    }

    /// Cache statistics `(hits, misses)` of the stencil cache.
    pub fn cache_stats(&self) -> (usize, usize) {
        (self.stencils.hits, self.stencils.misses)
    }

    fn backend(&mut self, name: &str) -> Result<Arc<dyn Backend>> {
        if !self.backends.contains_key(name) {
            let be: Arc<dyn Backend> = Arc::from(backend::create(name)?);
            if let Some(store) = &self.persist {
                be.set_persist(store);
            }
            self.backends.insert(name.to_string(), be);
        }
        Ok(self.backends[name].clone())
    }

    /// Register a custom backend instance under its name (e.g. a
    /// pre-warmed `XlaBackend` sharing a runtime).
    pub fn register_backend(&mut self, be: Box<dyn Backend>) {
        let be: Arc<dyn Backend> = Arc::from(be);
        if let Some(store) = &self.persist {
            be.set_persist(store);
        }
        self.backends.insert(be.name().to_string(), be);
    }

    /// Force backend preparation (compilation/codegen) for an
    /// already-compiled fingerprint without running it — `repro warm`
    /// uses this so warmed caches include backend artifacts (e.g. the
    /// vector backend's fused tapes), not just IR.
    pub fn prepare(&mut self, fingerprint: u64, backend: &str) -> Result<()> {
        let ir = self.ir(fingerprint)?;
        self.backend(backend)?.prepare(&ir)
    }

    /// Compile `stencil` from `src` and return a [`Stencil`] handle bound
    /// to `backend` — the `gtscript.stencil(backend=...)` analog. The
    /// handle shares the cached IR and the backend instance; clone it
    /// freely (including across threads).
    pub fn stencil(
        &mut self,
        src: &str,
        stencil: &str,
        backend: &str,
        externals: &BTreeMap<String, f64>,
    ) -> Result<Stencil> {
        let fp = self.compile_source(src, stencil, externals)?;
        self.stencil_for(fp, backend)
    }

    /// [`Coordinator::stencil`] for a standard-library stencil.
    pub fn stencil_library(&mut self, name: &str, backend: &str) -> Result<Stencil> {
        let fp = self.compile_library(name)?;
        self.stencil_for(fp, backend)
    }

    /// A [`Stencil`] handle for an already-compiled fingerprint.
    pub fn stencil_for(&mut self, fingerprint: u64, backend: &str) -> Result<Stencil> {
        let ir = self.ir(fingerprint)?;
        let be = self.backend(backend)?;
        Ok(Stencil::new(ir, be, self.checks_enabled, self.exec_options(), self.metrics.clone()))
    }

    /// Executor/buffer-pool counters of every instantiated backend that
    /// keeps any (currently `vector`), sorted by backend name — the
    /// metrics-snapshot API behind the serve layer's `/metrics` pool
    /// section. A peek: counters keep accumulating.
    pub fn pool_stats(&self) -> Vec<(String, crate::backend::vector::PoolStats)> {
        let mut out: Vec<_> = self
            .backends
            .iter()
            .filter_map(|(name, be)| be.pool_stats().map(|s| (name.clone(), s)))
            .collect();
        out.sort_by(|a, b| a.0.cmp(&b.0));
        out
    }

    /// Allocate a zeroed storage with exactly the halo a stencil's field
    /// requires for `domain` (the `gt4py.storage.zeros(backend=...)`
    /// analog; also available as [`Stencil::alloc_field`]).
    pub fn alloc_field(
        &mut self,
        fingerprint: u64,
        field: &str,
        domain: [usize; 3],
    ) -> Result<Storage> {
        let ir = self.ir(fingerprint)?;
        stencil::alloc_field_for(&ir, field, domain)
    }

}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn compile_stencil_roundtrip_with_cache() {
        let mut c = Coordinator::new();
        let fp = c.compile_library("copy").unwrap();
        // Recompiling is a cache hit, and the handle shares the cached IR.
        let fp2 = c.compile_library("copy").unwrap();
        assert_eq!(fp, fp2);
        assert_eq!(c.cache_stats(), (1, 1));
        let s = c.stencil_for(fp, "debug").unwrap();
        assert!(Arc::ptr_eq(&c.ir(fp).unwrap(), &c.ir(fp).unwrap()));

        let domain = [4, 3, 2];
        let mut src = s.alloc_field("src", domain).unwrap();
        let mut dst = s.alloc_field("dst", domain).unwrap();
        src.set(1, 2, 1, 7.0);
        let mut inv = s
            .bind()
            .field("src", &src)
            .field("dst", &dst)
            .domain(domain)
            .finish()
            .unwrap();
        let stats = inv.run(&mut [&mut src, &mut dst]).unwrap();
        assert!(stats.execute > Duration::ZERO);
        assert_eq!(dst.get(1, 2, 1), 7.0);
        assert!(c.metrics.get("copy", "debug").is_some());
    }

    #[test]
    fn backend_instances_are_shared_across_handles() {
        let mut c = Coordinator::new();
        let a = c.stencil_library("copy", "vector").unwrap();
        let b = c.stencil_library("laplacian", "vector").unwrap();
        // Same backend instance behind both handles: executable caches
        // stay warm across stencils (asserted via Arc identity).
        let be_a = c.backend("vector").unwrap();
        let be_b = c.backend("vector").unwrap();
        assert!(Arc::ptr_eq(&be_a, &be_b));
        assert_eq!(a.backend_name(), b.backend_name());
    }

    #[test]
    fn reformatted_source_hits_cache() {
        let a = "stencil s(a: Field<f64>, b: Field<f64>) {\n\
                   with computation(PARALLEL), interval(...) { b = a; }\n\
                 }";
        let b = "stencil   s(  a : Field<f64>,   b : Field<f64> ) {
                   # a comment
                   with computation(PARALLEL), interval(...) {
                       b = a;
                   }
                 }";
        let mut c = Coordinator::new();
        let fa = c.compile_source(a, "s", &BTreeMap::new()).unwrap();
        let fb = c.compile_source(b, "s", &BTreeMap::new()).unwrap();
        assert_eq!(fa, fb);
        assert_eq!(c.cache_stats(), (1, 1));
    }

    #[test]
    fn checks_catch_bad_halo_and_can_be_disabled() {
        let mut c = Coordinator::new();
        let s = c.stencil_library("laplacian", "debug").unwrap();
        let domain = [4, 4, 2];
        // Deliberately halo-less storages: bind-time checks reject them.
        let phi = Storage::with_halo(domain, 0);
        let out = Storage::with_halo(domain, 0);
        assert!(s
            .bind()
            .field("phi", &phi)
            .field("out", &out)
            .domain(domain)
            .finish()
            .is_err());
        // Disabling the checks reproduces the unvalidated (dashed-line)
        // path; with an OOB halo this would be UB-ish, so use valid
        // storages and just assert the checks time is zero.
        c.checks_enabled = false;
        let s = c.stencil_library("laplacian", "debug").unwrap();
        let mut phi = s.alloc_field("phi", domain).unwrap();
        let mut out = s.alloc_field("out", domain).unwrap();
        let mut inv = s
            .bind()
            .field("phi", &phi)
            .field("out", &out)
            .domain(domain)
            .finish()
            .unwrap();
        let stats = inv.run(&mut [&mut phi, &mut out]).unwrap();
        assert_eq!(stats.checks, Duration::ZERO);
    }

    #[test]
    fn scalar_args_flow_through() {
        let mut c = Coordinator::new();
        let s = c.stencil_library("diffuse", "debug").unwrap();
        let domain = [4, 4, 1];
        let mut phi = s.alloc_field("phi", domain).unwrap();
        phi.fill(1.0);
        let mut out = s.alloc_field("out", domain).unwrap();
        let mut inv = s
            .bind()
            .field("phi", &phi)
            .field("out", &out)
            .scalar("alpha", 0.1)
            .domain(domain)
            .finish()
            .unwrap();
        inv.run(&mut [&mut phi, &mut out]).unwrap();
        // constant field: laplacian zero, out == phi
        assert_eq!(out.get(2, 2, 0), 1.0);
    }

    #[test]
    fn opt_levels_get_distinct_cache_entries() {
        use crate::opt::OptLevel;
        let src = "stencil s(a: Field<f64>, b: Field<f64>) {\n\
                     with computation(PARALLEL), interval(...) { t = a * 2.0; b = t; }\n\
                   }";
        let mut c = Coordinator::new();
        c.set_opt_level(OptLevel::O0);
        let k0 = c.compile_source(src, "s", &BTreeMap::new()).unwrap();
        c.set_opt_level(OptLevel::O2);
        let k2 = c.compile_source(src, "s", &BTreeMap::new()).unwrap();
        assert_ne!(k0, k2, "opt levels must not collide in the cache");
        assert_eq!(c.cache_stats(), (0, 2));
        // Same source at the same level is still a pure cache hit.
        let k2b = c.compile_source(src, "s", &BTreeMap::new()).unwrap();
        assert_eq!(k2, k2b);
        assert_eq!(c.cache_stats(), (1, 2));
        // The cached IRs really differ: O2 demotes the temporary.
        // (Each `ir()` lookup below is itself a cache hit.)
        use crate::ir::implir::StorageClass;
        assert_eq!(c.ir(k0).unwrap().temporary("t").unwrap().storage, StorageClass::Field3D);
        assert_eq!(c.ir(k2).unwrap().temporary("t").unwrap().storage, StorageClass::Register);
        assert_ne!(c.ir(k0).unwrap().fingerprint, c.ir(k2).unwrap().fingerprint);
    }

    #[test]
    fn optimized_and_unoptimized_runs_agree() {
        let domain = [8, 7, 4];
        let mut sums = Vec::new();
        for level in [crate::opt::OptLevel::O0, crate::opt::OptLevel::O2] {
            let mut c = Coordinator::with_opt_level(level);
            let s = c.stencil_library("hdiff", "vector").unwrap();
            let mut inp = s.alloc_field("in_phi", domain).unwrap();
            let mut coeff = s.alloc_field("coeff", domain).unwrap();
            let mut out = s.alloc_field("out_phi", domain).unwrap();
            let h = inp.info.halo;
            let [ni, nj, nk] = domain;
            for i in -(h[0].0 as i64)..(ni + h[0].1) as i64 {
                for j in -(h[1].0 as i64)..(nj + h[1].1) as i64 {
                    for k in 0..nk as i64 {
                        inp.set(i, j, k, ((i * 3 + j * 5 + k * 7) as f64).sin());
                    }
                }
            }
            coeff.fill(0.05);
            let mut inv = s
                .bind()
                .field("in_phi", &inp)
                .field("coeff", &coeff)
                .field("out_phi", &out)
                .domain(domain)
                .finish()
                .unwrap();
            inv.run(&mut [&mut inp, &mut coeff, &mut out]).unwrap();
            sums.push(out.domain_sum());
        }
        assert_eq!(sums[0].to_bits(), sums[1].to_bits(), "opt level changed results");
    }

    #[test]
    fn sharding_plans_share_cache_entries_and_agree_bitwise() {
        use crate::backend::shard::Sharding;
        let domain = [16, 12, 6];
        let mut sums: Vec<u64> = Vec::new();
        for sharding in [Sharding::Off, Sharding::Threads(3), Sharding::Auto] {
            let mut c = Coordinator::with_opt_level(crate::opt::OptLevel::O3);
            c.set_sharding(sharding);
            let fp = c.compile_library("hdiff").unwrap();
            let s = c.stencil_for(fp, "vector").unwrap();
            assert_eq!(s.sharding(), sharding);
            let mut inp = s.alloc_field("in_phi", domain).unwrap();
            let mut coeff = s.alloc_field("coeff", domain).unwrap();
            let mut out = s.alloc_field("out_phi", domain).unwrap();
            let h = inp.info.halo;
            for i in -(h[0].0 as i64)..(domain[0] + h[0].1) as i64 {
                for j in -(h[1].0 as i64)..(domain[1] + h[1].1) as i64 {
                    for k in 0..domain[2] as i64 {
                        inp.set(i, j, k, ((i * 3 + j * 5 + k * 7) as f64).sin());
                    }
                }
            }
            coeff.fill(0.05);
            let mut inv = s
                .bind()
                .field("in_phi", &inp)
                .field("coeff", &coeff)
                .field("out_phi", &out)
                .domain(domain)
                .finish()
                .unwrap();
            let stats = inv.run(&mut [&mut inp, &mut coeff, &mut out]).unwrap();
            if sharding == Sharding::Threads(3) {
                assert_eq!(stats.threads_used(), 3);
            }
            // The plan must not salt the cache: every coordinator sees the
            // same fingerprint for the same source + opt level.
            sums.push(out.domain_sum().to_bits());
        }
        assert!(sums.windows(2).all(|w| w[0] == w[1]), "sharding changed results");
        // Same coordinator, plan changed between compiles: still one entry.
        let mut c = Coordinator::new();
        c.set_sharding(Sharding::Off);
        let a = c.compile_library("copy").unwrap();
        c.set_sharding(Sharding::Threads(8));
        let b = c.compile_library("copy").unwrap();
        assert_eq!(a, b, "sharding must not salt compilation cache keys");
        assert_eq!(c.cache_stats(), (1, 1));
    }

    #[test]
    fn tier_and_fast_math_knobs_survive_level_changes() {
        let mut c = Coordinator::new();
        c.set_exec_tier(ExecTier::Interpreted);
        c.set_fast_math(true);
        c.set_sharding(Sharding::Threads(2));
        c.set_opt_level(OptLevel::O3);
        assert_eq!(c.exec_tier(), ExecTier::Interpreted);
        assert!(c.fast_math());
        assert_eq!(c.sharding(), Sharding::Threads(2));
        // The executor tier never salts the cache; fast-math always does.
        let a = c.compile_library("copy").unwrap();
        c.set_exec_tier(ExecTier::Specialized);
        let b = c.compile_library("copy").unwrap();
        assert_eq!(a, b, "exec tier must not salt compilation cache keys");
        assert_eq!(c.cache_stats(), (1, 1));
        c.set_fast_math(false);
        let d = c.compile_library("copy").unwrap();
        assert_ne!(a, d, "fast-math must salt compilation cache keys");
        // Handles minted now carry the coordinator's current tier default
        // (set to Specialized above).
        let s = c.stencil_for(d, "vector").unwrap();
        assert_eq!(s.exec_tier(), ExecTier::Specialized);
    }

    #[test]
    fn dtype_override_salts_cache_keys_and_runs_f32() {
        use crate::dsl::ast::DType;
        let mut c = Coordinator::new();
        let a = c.compile_library("copy").unwrap();
        c.set_dtype(Some(DType::F32));
        let b = c.compile_library("copy").unwrap();
        assert_ne!(a, b, "dtype override must salt compilation cache keys");
        assert_eq!(c.ir(b).unwrap().dtype(), DType::F32);
        // And the minted handle allocates + runs genuine f32 storages.
        let s = c.stencil_for(b, "vector").unwrap();
        assert_eq!(s.exec_options().dtype, Some(DType::F32));
        let domain = [4, 3, 2];
        let mut src = s.alloc_field("src", domain).unwrap();
        let mut dst = s.alloc_field("dst", domain).unwrap();
        assert_eq!(src.info.dtype, DType::F32);
        src.set(1, 2, 1, 7.5);
        let mut inv = s
            .bind()
            .field("src", &src)
            .field("dst", &dst)
            .domain(domain)
            .finish()
            .unwrap();
        inv.run(&mut [&mut src, &mut dst]).unwrap();
        assert_eq!(dst.get(1, 2, 1), 7.5);
    }

    #[test]
    fn unknown_backend_or_name_errors() {
        let mut c = Coordinator::new();
        let fp = c.compile_library("copy").unwrap();
        assert!(c.stencil_for(fp, "warp-drive").is_err());
        assert!(c.stencil_for(0xdead_beef, "debug").is_err());
        assert!(c.fingerprint_of("never_compiled").is_none());
    }

    #[test]
    fn exec_options_roundtrip_and_delegating_setters_agree() {
        // One source of truth: the unified surface and the thin per-knob
        // delegates must always observe each other's effects.
        let mut c = Coordinator::new();
        assert_eq!(c.exec_options(), ExecOptions::default());
        let exec = ExecOptions::new()
            .with_opt_level(OptLevel::O3)
            .with_fast_math(true)
            .with_sharding(Sharding::Threads(2))
            .with_tier(ExecTier::Interpreted);
        c.set_exec_options(exec);
        assert_eq!(c.exec_options(), exec);
        assert_eq!(c.sharding(), Sharding::Threads(2));
        assert_eq!(c.exec_tier(), ExecTier::Interpreted);
        assert!(c.fast_math());
        // Delegates mutate the same state the unified getter reports.
        c.set_sharding(Sharding::Auto);
        c.set_fast_math(false);
        assert_eq!(c.exec_options(), exec.with_sharding(Sharding::Auto).with_fast_math(false));
        // The compile half drives cache keys exactly as before.
        let a = c.compile_library("copy").unwrap();
        c.set_exec_options(exec.with_fast_math(false).with_opt_level(OptLevel::O0));
        let b = c.compile_library("copy").unwrap();
        assert_ne!(a, b, "opt level through ExecOptions must salt cache keys");
        // Minted handles carry the full options surface.
        let s = c.stencil_for(b, "vector").unwrap();
        assert_eq!(s.exec_options().opt_level, OptLevel::O0);
        assert_eq!(s.exec_options().sharding, Sharding::Threads(2));
    }
}
