//! Hand-written native implementations of the two Fig. 3 stencils.
//!
//! The paper's compiled backends are measured against "near-native C++
//! performance"; these functions are that reference point on this testbed:
//! straightforward, loop-fused, allocation-free Rust over raw storage
//! buffers, the code a careful human would write without any framework.

use crate::storage::Storage;

/// Hand-written horizontal diffusion with flux limiting (matches the
/// `hdiff` library stencil semantics exactly).
pub fn hdiff_native(
    in_phi: &Storage,
    coeff: &Storage,
    out_phi: &mut Storage,
    domain: [usize; 3],
) {
    let [ni, nj, nk] = domain;
    let lap = |i: i64, j: i64, k: i64| -> f64 {
        4.0 * in_phi.get(i, j, k)
            - (in_phi.get(i - 1, j, k)
                + in_phi.get(i + 1, j, k)
                + in_phi.get(i, j - 1, k)
                + in_phi.get(i, j + 1, k))
    };
    let flx = |i: i64, j: i64, k: i64| -> f64 {
        let f = lap(i + 1, j, k) - lap(i, j, k);
        if f * (in_phi.get(i + 1, j, k) - in_phi.get(i, j, k)) > 0.0 {
            0.0
        } else {
            f
        }
    };
    let fly = |i: i64, j: i64, k: i64| -> f64 {
        let f = lap(i, j + 1, k) - lap(i, j, k);
        if f * (in_phi.get(i, j + 1, k) - in_phi.get(i, j, k)) > 0.0 {
            0.0
        } else {
            f
        }
    };
    for k in 0..nk as i64 {
        for i in 0..ni as i64 {
            for j in 0..nj as i64 {
                let v = in_phi.get(i, j, k)
                    - coeff.get(i, j, k)
                        * (flx(i, j, k) - flx(i - 1, j, k) + fly(i, j, k)
                            - fly(i, j - 1, k));
                out_phi.set(i, j, k, v);
            }
        }
    }
}

/// Hand-written implicit vertical advection (Thomas solver), matching the
/// `vadv` library stencil semantics exactly. `phi` is solved in place.
pub fn vadv_native(phi: &mut Storage, w: &Storage, dtdz: f64, domain: [usize; 3]) {
    let [ni, nj, nk] = domain;
    // Column scratch reused across columns: no allocation inside the loop.
    let mut cp = vec![0.0f64; nk];
    let mut dp = vec![0.0f64; nk];
    for i in 0..ni as i64 {
        for j in 0..nj as i64 {
            // forward elimination
            cp[0] = 0.5 * dtdz * w.get(i, j, 0);
            dp[0] = phi.get(i, j, 0);
            for k in 1..nk {
                let av = -0.5 * dtdz * w.get(i, j, k as i64);
                let denom = 1.0 - av * cp[k - 1];
                cp[k] = (0.5 * dtdz * w.get(i, j, k as i64)) / denom;
                dp[k] = (phi.get(i, j, k as i64) - av * dp[k - 1]) / denom;
            }
            // backward substitution
            phi.set(i, j, nk as i64 - 1, dp[nk - 1]);
            for k in (0..nk - 1).rev() {
                let v = dp[k] - cp[k] * phi.get(i, j, k as i64 + 1);
                phi.set(i, j, k as i64, v);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::debug::DebugBackend;
    use crate::backend::{Backend, StencilArgs};
    use crate::stdlib;

    fn rand_storage(domain: [usize; 3], halo: usize, seed: &mut u64) -> Storage {
        Storage::from_fn_extended(domain, halo, |_, _, _| {
            *seed = seed.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            ((*seed >> 33) as f64) / (u32::MAX as f64) - 0.5
        })
    }

    #[test]
    fn native_hdiff_matches_dsl() {
        let domain = [9, 8, 3];
        let mut seed = 11u64;
        let in_phi = rand_storage(domain, 3, &mut seed);
        let coeff = rand_storage(domain, 3, &mut seed);
        let mut out_native = Storage::with_horizontal_halo(domain, 3);
        hdiff_native(&in_phi, &coeff, &mut out_native, domain);

        let ir = stdlib::compile("hdiff").unwrap();
        let mut in2 = in_phi.clone();
        let mut c2 = coeff.clone();
        let mut out_dsl = Storage::with_horizontal_halo(domain, 3);
        let mut refs: Vec<(&str, &mut Storage)> = vec![
            ("in_phi", &mut in2),
            ("coeff", &mut c2),
            ("out_phi", &mut out_dsl),
        ];
        DebugBackend::new()
            .run(&ir, &mut StencilArgs { fields: &mut refs, scalars: &[], domain })
            .unwrap();
        assert!(out_native.max_abs_diff(&out_dsl) < 1e-14);
    }

    #[test]
    fn native_vadv_matches_dsl() {
        let domain = [5, 4, 8];
        let mut seed = 23u64;
        let phi0 = rand_storage(domain, 0, &mut seed);
        let w = rand_storage(domain, 0, &mut seed);
        let dtdz = 0.3;

        let mut phi_native = phi0.clone();
        vadv_native(&mut phi_native, &w, dtdz, domain);

        let ir = stdlib::compile("vadv").unwrap();
        let mut phi_dsl = phi0.clone();
        let mut w2 = w.clone();
        let mut refs: Vec<(&str, &mut Storage)> =
            vec![("phi", &mut phi_dsl), ("w", &mut w2)];
        DebugBackend::new()
            .run(
                &ir,
                &mut StencilArgs { fields: &mut refs, scalars: &[("dtdz", dtdz)], domain },
            )
            .unwrap();
        assert!(phi_native.max_abs_diff(&phi_dsl) < 1e-13);
    }

    #[test]
    fn vadv_solves_tridiagonal_system() {
        // Verify the Thomas solve satisfies the discretized equations:
        // a_k x_{k-1} + x_k + c_k x_{k+1} = phi0_k.
        let domain = [2, 2, 6];
        let mut seed = 5u64;
        let phi0 = rand_storage(domain, 0, &mut seed);
        let w = rand_storage(domain, 0, &mut seed);
        let dtdz = 0.4;
        let mut x = phi0.clone();
        vadv_native(&mut x, &w, dtdz, domain);
        for i in 0..2i64 {
            for j in 0..2i64 {
                for k in 0..6i64 {
                    let a = if k > 0 { -0.5 * dtdz * w.get(i, j, k) } else { 0.0 };
                    let c = if k < 5 { 0.5 * dtdz * w.get(i, j, k) } else { 0.0 };
                    let lhs = a * if k > 0 { x.get(i, j, k - 1) } else { 0.0 }
                        + x.get(i, j, k)
                        + c * if k < 5 { x.get(i, j, k + 1) } else { 0.0 };
                    let rhs = phi0.get(i, j, k);
                    assert!(
                        (lhs - rhs).abs() < 1e-12,
                        "residual {} at ({i},{j},{k})",
                        lhs - rhs
                    );
                }
            }
        }
    }
}
