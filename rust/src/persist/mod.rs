//! Persistent on-disk artifact store — compiled stencils that survive the
//! process (the analog of GT4Py's `.gt_cache` directory).
//!
//! GT4Py pays code generation once because generated extensions live in an
//! on-disk cache keyed by stencil definition + backend options. gt4rs'
//! [`crate::cache::StencilCache`] is per-process: without this layer every
//! cold `repro run`, model-driver launch and `repro serve` restart re-pays
//! the full dsl → analysis → opt → compile pipeline per stencil. The
//! persist store closes that gap with three artifact kinds, all keyed by
//! the existing opt-salted fingerprints:
//!
//! * `ir` — the canonicalized [`StencilIr`](crate::ir::implir::StencilIr),
//!   serialized by [`irser`] and re-validated on load by recomputing the
//!   canonical fingerprint (a warm coordinator skips the whole pipeline);
//! * `tape` — the vector backend's compiled fused program ([`tapeser`]):
//!   the value-numbered `CTape`s and scratch/alloc extents, so an O3 warm
//!   start skips tape lowering (kernel plans and halo plans are
//!   deterministically re-derived from the tapes, see `tapeser` docs);
//! * `hlo` — HLO module text for the `pjrt-aot` backend, so a warmed cache
//!   can stand in for the `make artifacts` directory. (The `xla` JIT
//!   backend builds its computation through the PJRT C API and has no
//!   text-emission path, so it warm-starts at the IR level only — the
//!   boundary of what the binding exposes.)
//!
//! # Integrity and versioning
//!
//! Every entry is one JSON envelope carrying a schema version, the
//! toolchain tag (`CARGO_PKG_VERSION`) and an FNV-1a content digest of the
//! payload. *Any* mismatch — unparseable file, wrong schema, different
//! toolchain, digest mismatch, or a payload that deserializes to something
//! whose recomputed fingerprint disagrees — is a **miss, never an error**:
//! the caller falls back to a fresh compile and (best-effort) overwrites
//! the bad entry. Corruption is counted separately from plain misses so
//! `/metrics` can distinguish "cold" from "rotten".
//!
//! # Concurrency
//!
//! Writes are atomic: the payload goes to a process-unique temp file in the
//! same directory and is `rename`d into place, so a killed process can
//! never publish a torn entry and concurrent processes can share one cache
//! root. Last writer wins, which is sound because entries are keyed by
//! content fingerprint. The root is chosen with `--cache-dir` or the
//! `REPRO_CACHE_DIR` environment variable and is **off by default** so
//! tests and one-shot runs stay hermetic.

pub mod irser;
pub(crate) mod tapeser;

use crate::ir::canon::fnv1a64;
use anyhow::{Context, Result};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

/// Bumped whenever any payload encoding changes shape; older entries
/// become misses, not errors.
const SCHEMA_VERSION: u64 = 1;

/// Toolchain tag stamped into every entry: artifacts never cross crate
/// versions (the compile pipeline may have changed under the same schema).
const TOOL_TAG: &str = env!("CARGO_PKG_VERSION");

/// Environment variable naming the shared cache root (the CLI flag
/// `--cache-dir` takes precedence).
pub const CACHE_DIR_ENV: &str = "REPRO_CACHE_DIR";

/// One artifact listed by [`PersistStore::entries`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct EntryInfo {
    pub kind: String,
    pub key: String,
    /// On-disk envelope size in bytes.
    pub bytes: u64,
}

/// A shared on-disk artifact store — see the module docs. Cheap to clone
/// behind an `Arc`; all methods take `&self` and the hit/miss/reject
/// counters are atomics, so one store instance is safely shared by every
/// coordinator, backend and serve tenant in the process.
pub struct PersistStore {
    root: PathBuf,
    hits: AtomicU64,
    misses: AtomicU64,
    rejects: AtomicU64,
}

impl PersistStore {
    /// Open (creating if needed) a store rooted at `root`.
    pub fn open(root: impl AsRef<Path>) -> Result<PersistStore> {
        let root = root.as_ref().to_path_buf();
        std::fs::create_dir_all(&root)
            .with_context(|| format!("creating cache dir {}", root.display()))?;
        Ok(PersistStore {
            root,
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            rejects: AtomicU64::new(0),
        })
    }

    /// Open the store named by `REPRO_CACHE_DIR`, if set. A set-but-unusable
    /// directory is reported as an error; unset is simply `Ok(None)`.
    pub fn from_env() -> Result<Option<PersistStore>> {
        match std::env::var(CACHE_DIR_ENV) {
            Ok(dir) if !dir.is_empty() => Ok(Some(PersistStore::open(dir)?)),
            _ => Ok(None),
        }
    }

    pub fn root(&self) -> &Path {
        &self.root
    }

    fn path(&self, kind: &str, key: &str) -> PathBuf {
        self.root.join(format!("{kind}_{key}.json"))
    }

    /// Load an artifact payload. Counts exactly one of hit / miss / reject:
    /// a missing, unparseable or wrong-version entry is a miss; an entry
    /// whose content digest disagrees with its payload is a reject. Never
    /// returns an error — corruption means "compile fresh".
    pub fn load(&self, kind: &str, key: &str) -> Option<String> {
        let text = match std::fs::read_to_string(self.path(kind, key)) {
            Ok(t) => t,
            Err(_) => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                return None;
            }
        };
        let parsed = match crate::jsonw::parse(&text) {
            Ok(v) => v,
            Err(_) => {
                // Torn or truncated entry (writes are atomic, so this means
                // external corruption): a miss, the writer will replace it.
                self.misses.fetch_add(1, Ordering::Relaxed);
                return None;
            }
        };
        let schema = parsed.get("schema").and_then(|v| v.as_u64());
        let tool = parsed.get("tool").and_then(|v| v.as_str());
        let entry_kind = parsed.get("kind").and_then(|v| v.as_str());
        let digest = parsed.get("digest").and_then(|v| v.as_str());
        let payload = parsed.get("payload").and_then(|v| v.as_str());
        let (Some(digest), Some(payload)) = (digest, payload) else {
            self.misses.fetch_add(1, Ordering::Relaxed);
            return None;
        };
        if schema != Some(SCHEMA_VERSION) || tool != Some(TOOL_TAG) || entry_kind != Some(kind)
        {
            // A different toolchain's (or future schema's) entry: stale,
            // not corrupt.
            self.misses.fetch_add(1, Ordering::Relaxed);
            return None;
        }
        if u64::from_str_radix(digest, 16).ok() != Some(fnv1a64(payload.as_bytes())) {
            self.rejects.fetch_add(1, Ordering::Relaxed);
            return None;
        }
        self.hits.fetch_add(1, Ordering::Relaxed);
        Some(payload.to_string())
    }

    /// Demote the most recent digest-valid load to a corrupt-reject: used
    /// by callers whose *semantic* validation failed (e.g. a reloaded IR
    /// whose recomputed canonical fingerprint disagrees with the stored
    /// one, or a tape referencing out-of-range slots).
    pub fn reject_loaded(&self) {
        self.hits.fetch_sub(1, Ordering::Relaxed);
        self.rejects.fetch_add(1, Ordering::Relaxed);
    }

    /// Publish an artifact atomically (temp file + rename). Best-effort by
    /// design: persistence failures must never fail a compile, so callers
    /// are expected to ignore the result in hot paths.
    pub fn store(&self, kind: &str, key: &str, payload: &str) -> Result<()> {
        let digest = fnv1a64(payload.as_bytes());
        let envelope = crate::jsonw::Obj::new()
            .int("schema", SCHEMA_VERSION as i64)
            .str("tool", TOOL_TAG)
            .str("kind", kind)
            .str("digest", &format!("{digest:016x}"))
            .str("payload", payload)
            .finish();
        let target = self.path(kind, key);
        let tmp = self.root.join(format!(
            ".{kind}_{key}.{}.tmp",
            std::process::id()
        ));
        std::fs::write(&tmp, envelope)
            .with_context(|| format!("writing cache temp {}", tmp.display()))?;
        std::fs::rename(&tmp, &target)
            .with_context(|| format!("publishing cache entry {}", target.display()))?;
        Ok(())
    }

    /// List every entry (kind, key, envelope bytes), sorted by kind then
    /// key — the `repro cache` inspection surface.
    pub fn entries(&self) -> Vec<EntryInfo> {
        let mut out = Vec::new();
        let Ok(dir) = std::fs::read_dir(&self.root) else {
            return out;
        };
        for e in dir.flatten() {
            let name = e.file_name();
            let Some(name) = name.to_str() else { continue };
            let Some(stem) = name.strip_suffix(".json") else { continue };
            // Kinds never contain '_'; keys may (pjrt-aot stems).
            let Some((kind, key)) = stem.split_once('_') else { continue };
            if kind.is_empty() || name.starts_with('.') {
                continue;
            }
            let bytes = e.metadata().map(|m| m.len()).unwrap_or(0);
            out.push(EntryInfo { kind: kind.to_string(), key: key.to_string(), bytes });
        }
        out.sort_by(|a, b| (&a.kind, &a.key).cmp(&(&b.kind, &b.key)));
        out
    }

    /// Delete every entry (and any stale temp files), returning how many
    /// entries were removed.
    pub fn clear(&self) -> Result<usize> {
        let mut removed = 0;
        for e in std::fs::read_dir(&self.root)
            .with_context(|| format!("reading cache dir {}", self.root.display()))?
            .flatten()
        {
            let name = e.file_name();
            let Some(name) = name.to_str() else { continue };
            if name.ends_with(".json") || name.ends_with(".tmp") {
                std::fs::remove_file(e.path())
                    .with_context(|| format!("removing {}", e.path().display()))?;
                if name.ends_with(".json") {
                    removed += 1;
                }
            }
        }
        Ok(removed)
    }

    /// `(hits, misses, rejects)` since this store handle was opened.
    pub fn counters(&self) -> (u64, u64, u64) {
        (
            self.hits.load(Ordering::Relaxed),
            self.misses.load(Ordering::Relaxed),
            self.rejects.load(Ordering::Relaxed),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn scratch_store(tag: &str) -> (PathBuf, PersistStore) {
        let dir = std::env::temp_dir()
            .join(format!("gt4rs_persist_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let store = PersistStore::open(&dir).unwrap();
        (dir, store)
    }

    #[test]
    fn roundtrip_counts_hit_after_miss() {
        let (dir, store) = scratch_store("rt");
        assert_eq!(store.load("ir", "0000000000000001"), None);
        store.store("ir", "0000000000000001", "payload body").unwrap();
        assert_eq!(store.load("ir", "0000000000000001").as_deref(), Some("payload body"));
        // Different kind or key miss independently.
        assert_eq!(store.load("tape", "0000000000000001"), None);
        assert_eq!(store.load("ir", "0000000000000002"), None);
        assert_eq!(store.counters(), (1, 3, 0));
        // A second handle over the same root sees everything (shared-root
        // contract for concurrent processes).
        let reopened = PersistStore::open(&dir).unwrap();
        assert_eq!(
            reopened.load("ir", "0000000000000001").as_deref(),
            Some("payload body")
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn truncated_entry_is_a_miss() {
        let (dir, store) = scratch_store("trunc");
        store.store("hlo", "k", "HloModule m, lots of text here").unwrap();
        let path = dir.join("hlo_k.json");
        let full = std::fs::read_to_string(&path).unwrap();
        // A torn write: only the first half of the envelope made it.
        std::fs::write(&path, &full[..full.len() / 2]).unwrap();
        assert_eq!(store.load("hlo", "k"), None);
        let (h, m, r) = store.counters();
        assert_eq!((h, m, r), (0, 1, 0), "truncation must be a plain miss");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn bit_flipped_payload_is_a_reject() {
        let (dir, store) = scratch_store("flip");
        store.store("hlo", "k", "HloModule m").unwrap();
        let path = dir.join("hlo_k.json");
        let full = std::fs::read_to_string(&path).unwrap();
        // Flip one payload character without breaking the JSON shape.
        let corrupted = full.replace("HloModule m", "HloModule x");
        assert_ne!(corrupted, full);
        std::fs::write(&path, corrupted).unwrap();
        assert_eq!(store.load("hlo", "k"), None);
        assert_eq!(store.counters(), (0, 0, 1), "digest mismatch must count as reject");
        // Overwriting repairs the entry.
        store.store("hlo", "k", "HloModule m").unwrap();
        assert_eq!(store.load("hlo", "k").as_deref(), Some("HloModule m"));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn schema_or_tool_mismatch_is_a_miss() {
        let (dir, store) = scratch_store("ver");
        store.store("ir", "a", "body").unwrap();
        let path = dir.join("ir_a.json");
        let full = std::fs::read_to_string(&path).unwrap();
        std::fs::write(&path, full.replace("\"schema\":1", "\"schema\":999")).unwrap();
        assert_eq!(store.load("ir", "a"), None);
        store.store("ir", "a", "body").unwrap();
        let full = std::fs::read_to_string(&path).unwrap();
        std::fs::write(
            &path,
            full.replace(TOOL_TAG, "0.0.0-someone-elses-build"),
        )
        .unwrap();
        assert_eq!(store.load("ir", "a"), None);
        assert_eq!(store.counters(), (0, 2, 0));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn entries_and_clear() {
        let (dir, store) = scratch_store("ls");
        store.store("ir", "b", "x").unwrap();
        store.store("ir", "a", "y").unwrap();
        store.store("tape", "a", "z").unwrap();
        let listed = store.entries();
        assert_eq!(
            listed.iter().map(|e| (e.kind.as_str(), e.key.as_str())).collect::<Vec<_>>(),
            vec![("ir", "a"), ("ir", "b"), ("tape", "a")]
        );
        assert!(listed.iter().all(|e| e.bytes > 0));
        assert_eq!(store.clear().unwrap(), 3);
        assert!(store.entries().is_empty());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn reject_loaded_demotes_a_hit() {
        let (dir, store) = scratch_store("demote");
        store.store("ir", "a", "digest-valid but semantically wrong").unwrap();
        assert!(store.load("ir", "a").is_some());
        store.reject_loaded();
        assert_eq!(store.counters(), (0, 0, 1));
        let _ = std::fs::remove_dir_all(&dir);
    }
}
