//! Serialize / deserialize the vector backend's compiled fused programs.
//!
//! The payload of a `kind = "tape"` persist entry: the expensive half of
//! [`FusedProgram::compile`] — per-group value-numbered SSA tapes
//! ([`CTape`]), scratch/alloc extents and intervals — so an O3 warm
//! start skips tape lowering entirely. The halo plan is *not* stored: a
//! load recomputes it from the tapes with the same analysis the fresh
//! compile runs, so a stale payload can never smuggle in a laxer
//! synchronization verdict.
//!
//! Kernel plans ([`TierPlan`]) are deliberately *not* serialized: they
//! contain monomorphized kernel variants (and the fast-math FMA choice)
//! that are a cheap, deterministic function of `(tape, storage classes,
//! fast_math)`, so a load re-derives each tier's plan with
//! [`TierPlan::lower`] — the same call the fresh compile path makes,
//! which is what keeps warm-loaded programs bitwise-identical to fresh
//! ones by construction.
//!
//! Every slot and SSA operand index is bounds-checked on load; a payload
//! that fails any check deserializes to `None` and the caller counts a
//! cache reject and compiles fresh.

use crate::backend::cexpr::{CTape, TapeInst, TapeOp};
use crate::backend::fused::{FusedGroup, FusedMultistage, FusedProgram, Tier};
use crate::backend::kernels::TierPlan;
use crate::dsl::ast::Builtin;
use crate::ir::implir::{Extent, StorageClass};
use crate::jsonw::{self, string, Value};

use super::irser::{
    extent_from, extent_to_json, f64_from, f64_to_json, i32_from, interval_from,
    interval_to_json, policy_from, policy_to_str, usize_from,
};

fn op_to_json(op: &TapeOp) -> String {
    match op {
        TapeOp::Const(c) => format!("[\"c\",{}]", f64_to_json(*c)),
        TapeOp::Scalar(ix) => format!("[\"s\",{ix}]"),
        TapeOp::Load { slot, off } => {
            format!("[\"l\",{slot},{},{},{}]", off[0], off[1], off[2])
        }
        TapeOp::LoadLocal { slot, off } => {
            format!("[\"L\",{slot},{},{},{}]", off[0], off[1], off[2])
        }
        TapeOp::Neg(a) => format!("[\"n\",{a}]"),
        TapeOp::Not(a) => format!("[\"!\",{a}]"),
        TapeOp::Bin(op, a, b) => format!("[\"o\",{},{a},{b}]", string(op.symbol())),
        TapeOp::Select(c, t, f) => format!("[\"sel\",{c},{t},{f}]"),
        TapeOp::Call1(f, a) => format!("[\"1\",{},{a}]", string(f.name())),
        TapeOp::Call2(f, a, b) => format!("[\"2\",{},{a},{b}]", string(f.name())),
        TapeOp::StoreField { slot, v } => format!("[\"S\",{slot},{v}]"),
        TapeOp::StoreLocal { slot, v } => format!("[\"T\",{slot},{v}]"),
    }
}

/// Decode one tape op. `ix` is the op's own SSA index and `num_slots` the
/// program's slot count: every operand must reference an earlier value and
/// every slot must exist, otherwise the payload is rejected.
fn op_from(v: &Value, ix: usize, num_slots: usize) -> Option<TapeOp> {
    let a = v.as_arr()?;
    let val = |v: &Value| -> Option<u32> {
        let n = v.as_u64()?;
        ((n as usize) < ix).then_some(n as u32)
    };
    let slot = |v: &Value| -> Option<usize> {
        let s = usize_from(v)?;
        (s < num_slots).then_some(s)
    };
    Some(match a.first()?.as_str()? {
        "c" if a.len() == 2 => TapeOp::Const(f64_from(&a[1])?),
        "s" if a.len() == 2 => TapeOp::Scalar(usize_from(&a[1])?),
        "l" if a.len() == 5 => TapeOp::Load {
            slot: slot(&a[1])?,
            off: [i32_from(&a[2])?, i32_from(&a[3])?, i32_from(&a[4])?],
        },
        "L" if a.len() == 5 => TapeOp::LoadLocal {
            slot: slot(&a[1])?,
            off: [i32_from(&a[2])?, i32_from(&a[3])?, i32_from(&a[4])?],
        },
        "n" if a.len() == 2 => TapeOp::Neg(val(&a[1])?),
        "!" if a.len() == 2 => TapeOp::Not(val(&a[1])?),
        "o" if a.len() == 4 => TapeOp::Bin(
            super::irser::binop_from_symbol(a[1].as_str()?)?,
            val(&a[2])?,
            val(&a[3])?,
        ),
        "sel" if a.len() == 4 => TapeOp::Select(val(&a[1])?, val(&a[2])?, val(&a[3])?),
        "1" if a.len() == 3 => {
            let f = Builtin::from_name(a[1].as_str()?)?;
            if f.arity() != 1 {
                return None;
            }
            TapeOp::Call1(f, val(&a[2])?)
        }
        "2" if a.len() == 4 => {
            let f = Builtin::from_name(a[1].as_str()?)?;
            if f.arity() != 2 {
                return None;
            }
            TapeOp::Call2(f, val(&a[2])?, val(&a[3])?)
        }
        "S" if a.len() == 3 => TapeOp::StoreField { slot: slot(&a[1])?, v: val(&a[2])? },
        "T" if a.len() == 3 => TapeOp::StoreLocal { slot: slot(&a[1])?, v: val(&a[2])? },
        _ => return None,
    })
}

/// Serialize a compiled fused program to the `"tape"` persist payload.
pub(crate) fn fused_to_json(fp: &FusedProgram) -> String {
    let alloc: Vec<String> = fp.alloc.iter().map(extent_to_json).collect();
    let mut multistages: Vec<String> = Vec::with_capacity(fp.multistages.len());
    for ms in &fp.multistages {
        let mut groups: Vec<String> = Vec::with_capacity(ms.groups.len());
        for g in &ms.groups {
            let scratch: Vec<String> = g
                .scratch
                .iter()
                .map(|(slot, e)| format!("[{slot},{}]", extent_to_json(e)))
                .collect();
            let tiers: Vec<String> = g
                .tiers
                .iter()
                .map(|t| {
                    let ops: Vec<String> = t
                        .tape
                        .ops
                        .iter()
                        .map(|inst| {
                            format!(
                                "[{},{}]",
                                op_to_json(&inst.op),
                                extent_to_json(&inst.region)
                            )
                        })
                        .collect();
                    format!(
                        "{{\"extent\":{},\"ops\":[{}]}}",
                        extent_to_json(&t.extent),
                        ops.join(",")
                    )
                })
                .collect();
            groups.push(format!(
                "{{\"interval\":{},\"scratch\":[{}],\"tiers\":[{}]}}",
                interval_to_json(&g.interval),
                scratch.join(","),
                tiers.join(",")
            ));
        }
        multistages.push(format!(
            "{{\"policy\":\"{}\",\"groups\":[{}]}}",
            policy_to_str(ms.policy),
            groups.join(",")
        ));
    }
    format!(
        "{{\"alloc\":[{}],\"multistages\":[{}]}}",
        alloc.join(","),
        multistages.join(",")
    )
}

/// Deserialize a persisted fused program, re-lowering each tier's kernel
/// plan from its tape. `classes` must be the slot storage classes of the
/// `Program` compiled from the same fingerprint's IR (they size and type
/// the plan), and `fast_math` the IR's fingerprint-salted flag. `None` on
/// any structural mismatch.
pub(crate) fn fused_from_json(
    payload: &str,
    classes: &[StorageClass],
    fast_math: bool,
) -> Option<FusedProgram> {
    let v = jsonw::parse(payload).ok()?;
    let alloc_v = v.get("alloc")?.as_arr()?;
    if alloc_v.len() != classes.len() {
        return None;
    }
    let alloc: Vec<Extent> = alloc_v.iter().map(extent_from).collect::<Option<Vec<_>>>()?;
    let mut multistages = Vec::new();
    for ms in v.get("multistages")?.as_arr()? {
        let policy = policy_from(ms.get("policy")?.as_str()?)?;
        let mut groups = Vec::new();
        for g in ms.get("groups")?.as_arr()? {
            let interval = interval_from(g.get("interval")?)?;
            let mut scratch = Vec::new();
            for s in g.get("scratch")?.as_arr()? {
                let pair = s.as_arr()?;
                if pair.len() != 2 {
                    return None;
                }
                let slot = usize_from(&pair[0])?;
                if slot >= classes.len() {
                    return None;
                }
                scratch.push((slot, extent_from(&pair[1])?));
            }
            let mut tiers = Vec::new();
            for t in g.get("tiers")?.as_arr()? {
                let extent = extent_from(t.get("extent")?)?;
                let mut ops = Vec::new();
                for (ix, inst) in t.get("ops")?.as_arr()?.iter().enumerate() {
                    let pair = inst.as_arr()?;
                    if pair.len() != 2 {
                        return None;
                    }
                    ops.push(TapeInst {
                        op: op_from(&pair[0], ix, classes.len())?,
                        region: extent_from(&pair[1])?,
                    });
                }
                let tape = CTape { ops };
                // Same lowering call as the fresh-compile path: plans are
                // derived, never trusted from disk.
                let plan = TierPlan::lower(&tape, classes, fast_math);
                tiers.push(Tier { extent, tape, plan });
            }
            groups.push(FusedGroup { interval, scratch, tiers });
        }
        // Like kernel plans, the halo plan is derived, never trusted from
        // disk: recompute it from the reloaded tapes.
        let halo = crate::backend::fused::ms_halo_plan_fused(&groups, policy);
        multistages.push(FusedMultistage { policy, groups, halo });
    }
    Some(FusedProgram { multistages, alloc })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis;
    use crate::backend::fused::FusedProgram;
    use crate::backend::program::Program;
    use crate::opt::{OptConfig, OptLevel};
    use crate::stdlib;

    fn compiled(name: &str, fast_math: bool) -> (Program, FusedProgram) {
        let src = stdlib::source(name).unwrap();
        let ir = analysis::compile_source_opt(
            src,
            name,
            &Default::default(),
            &OptConfig::level(OptLevel::O3).with_fast_math(fast_math),
        )
        .unwrap();
        let p = Program::compile(&ir).unwrap();
        let fp = FusedProgram::compile(&p, fast_math);
        (p, fp)
    }

    /// Round-trip every stdlib stencil's O3 fused program (exact and
    /// fast-math): the reloaded program — tapes, extents, intervals,
    /// scratch, the recomputed halo plan *and re-lowered kernel plans* —
    /// must be structurally identical to the fresh compile.
    #[test]
    fn stdlib_fused_programs_roundtrip_identically() {
        for name in stdlib::names() {
            for fast_math in [false, true] {
                let (program, fp) = compiled(name, fast_math);
                let classes: Vec<StorageClass> =
                    program.slots.iter().map(|s| s.storage).collect();
                let payload = fused_to_json(&fp);
                let back = fused_from_json(&payload, &classes, fast_math)
                    .unwrap_or_else(|| panic!("{name}: reload failed"));
                // Debug formatting covers the full structure including the
                // re-lowered plans; f64 Debug is shortest-roundtrip, so
                // bitwise-identical constants format identically.
                assert_eq!(
                    format!("{fp:?}"),
                    format!("{back:?}"),
                    "{name} fast_math={fast_math}: reloaded fused program diverged"
                );
            }
        }
    }

    /// Slot and SSA-operand bounds are enforced on load.
    #[test]
    fn out_of_range_indices_reject() {
        let (program, fp) = compiled("hdiff", false);
        let classes: Vec<StorageClass> = program.slots.iter().map(|s| s.storage).collect();
        let payload = fused_to_json(&fp);
        // Fewer classes than slots: alloc length check must reject.
        assert!(fused_from_json(&payload, &classes[..1], false).is_none());
        // A forward SSA reference must reject (operand index >= own index).
        let zero = "[0,0,0,0,0,0]";
        let bad = format!(
            "{{\"alloc\":[{zero}],\"multistages\":[{{\"policy\":\"PARALLEL\",\
             \"groups\":[{{\"interval\":[[\"s\",0],[\"e\",0]],\
             \"scratch\":[],\"tiers\":[{{\"extent\":{zero},\"ops\":[[[\"n\",0],{zero}]]}}]}}]}}]}}"
        );
        assert!(fused_from_json(&bad, &classes[..1], false).is_none());
        // Garbage payloads never panic.
        for bad in ["", "17", "{\"alloc\":[]}"] {
            assert!(fused_from_json(bad, &classes, false).is_none());
        }
    }
}
