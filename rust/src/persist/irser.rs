//! Serialize / deserialize the canonicalized implementation IR.
//!
//! The payload of a `kind = "ir"` persist entry: a compact JSON encoding
//! of [`StencilIr`] built on [`crate::jsonw`], designed for *bit-exact*
//! round-trips — every float (literals, folded externals) travels as the
//! hex of its IEEE-754 bits, never as a decimal rendering.
//!
//! Two invariants the encoding relies on:
//!
//! * Post-analysis expressions contain only `Float` / `Bool` / `Field` /
//!   `Scalar` / `Unary` / `Binary` / `Ternary` / `Builtin` nodes (`Name`,
//!   `External` and `Call` are resolved away by the pipeline).
//!   [`ir_to_json`] returns `None` if that invariant is violated rather
//!   than persisting a half-representable artifact.
//! * Source spans are *not* canonical (the whole point of the
//!   formatting-insensitive fingerprint), so they are not serialized; a
//!   reloaded IR carries default spans and is validated by recomputing
//!   its canonical fingerprint, not by structural equality.

use crate::dsl::ast::{
    BinOp, Builtin, DType, Expr, Interval, IterationPolicy, LevelBound, Offset, ScalarDecl,
    Span, UnOp,
};
use crate::ir::implir::{
    Assign, Extent, FieldInfo, Intent, Multistage, Stage, StencilIr, StorageClass, TempField,
};
use crate::jsonw::{self, string, Value};
use std::collections::BTreeMap;

// ---------------------------------------------------------------------------
// Shared scalar encoders / decoders (also used by `tapeser`)

pub(crate) fn f64_to_json(v: f64) -> String {
    string(&format!("{:016x}", v.to_bits()))
}

pub(crate) fn f64_from(v: &Value) -> Option<f64> {
    let bits = u64::from_str_radix(v.as_str()?, 16).ok()?;
    Some(f64::from_bits(bits))
}

pub(crate) fn i32_from(v: &Value) -> Option<i32> {
    let f = v.as_f64()?;
    if f.fract() != 0.0 || !(f64::from(i32::MIN)..=f64::from(i32::MAX)).contains(&f) {
        return None;
    }
    Some(f as i32)
}

pub(crate) fn usize_from(v: &Value) -> Option<usize> {
    v.as_u64().map(|n| n as usize)
}

pub(crate) fn extent_to_json(e: &Extent) -> String {
    format!(
        "[{},{},{},{},{},{}]",
        e.i.0, e.i.1, e.j.0, e.j.1, e.k.0, e.k.1
    )
}

pub(crate) fn extent_from(v: &Value) -> Option<Extent> {
    let a = v.as_arr()?;
    if a.len() != 6 {
        return None;
    }
    let mut n = [0i32; 6];
    for (slot, item) in n.iter_mut().zip(a) {
        *slot = i32_from(item)?;
    }
    Some(Extent { i: (n[0], n[1]), j: (n[2], n[3]), k: (n[4], n[5]) })
}

pub(crate) fn interval_to_json(iv: &Interval) -> String {
    let bound = |b: &LevelBound| match b {
        LevelBound::FromStart(n) => format!("[\"s\",{n}]"),
        LevelBound::FromEnd(n) => format!("[\"e\",{n}]"),
    };
    format!("[{},{}]", bound(&iv.lo), bound(&iv.hi))
}

pub(crate) fn interval_from(v: &Value) -> Option<Interval> {
    let a = v.as_arr()?;
    if a.len() != 2 {
        return None;
    }
    let bound = |v: &Value| -> Option<LevelBound> {
        let b = v.as_arr()?;
        if b.len() != 2 {
            return None;
        }
        let n = i32_from(&b[1])?;
        match b[0].as_str()? {
            "s" => Some(LevelBound::FromStart(n)),
            "e" => Some(LevelBound::FromEnd(n)),
            _ => None,
        }
    };
    Some(Interval { lo: bound(&a[0])?, hi: bound(&a[1])? })
}

pub(crate) fn binop_from_symbol(sym: &str) -> Option<BinOp> {
    Some(match sym {
        "+" => BinOp::Add,
        "-" => BinOp::Sub,
        "*" => BinOp::Mul,
        "/" => BinOp::Div,
        "%" => BinOp::Mod,
        "<" => BinOp::Lt,
        "<=" => BinOp::Le,
        ">" => BinOp::Gt,
        ">=" => BinOp::Ge,
        "==" => BinOp::Eq,
        "!=" => BinOp::Ne,
        "and" => BinOp::And,
        "or" => BinOp::Or,
        _ => return None,
    })
}

pub(crate) fn policy_to_str(p: IterationPolicy) -> &'static str {
    match p {
        IterationPolicy::Parallel => "PARALLEL",
        IterationPolicy::Forward => "FORWARD",
        IterationPolicy::Backward => "BACKWARD",
    }
}

pub(crate) fn policy_from(s: &str) -> Option<IterationPolicy> {
    Some(match s {
        "PARALLEL" => IterationPolicy::Parallel,
        "FORWARD" => IterationPolicy::Forward,
        "BACKWARD" => IterationPolicy::Backward,
        _ => return None,
    })
}

fn dtype_from(s: &str) -> Option<DType> {
    Some(match s {
        "f32" => DType::F32,
        "f64" => DType::F64,
        _ => return None,
    })
}

fn intent_from(s: &str) -> Option<Intent> {
    Some(match s {
        "in" => Intent::In,
        "out" => Intent::Out,
        "inout" => Intent::InOut,
        _ => return None,
    })
}

fn storage_from(s: &str) -> Option<StorageClass> {
    Some(match s {
        "field3d" => StorageClass::Field3D,
        "register" => StorageClass::Register,
        "plane" => StorageClass::Plane,
        "ring" => StorageClass::Ring,
        _ => return None,
    })
}

// ---------------------------------------------------------------------------
// Expressions

fn expr_to_json(e: &Expr) -> Option<String> {
    Some(match e {
        Expr::Float(v) => format!("[\"f\",{}]", f64_to_json(*v)),
        Expr::Bool(b) => format!("[\"b\",{b}]"),
        Expr::Field { name, offset, .. } => format!(
            "[\"F\",{},{},{},{}]",
            string(name),
            offset[0],
            offset[1],
            offset[2]
        ),
        Expr::Scalar(name) => format!("[\"s\",{}]", string(name)),
        Expr::Unary { op, operand } => {
            let sym = match op {
                UnOp::Neg => "-",
                UnOp::Not => "!",
            };
            format!("[\"u\",\"{sym}\",{}]", expr_to_json(operand)?)
        }
        Expr::Binary { op, lhs, rhs } => format!(
            "[\"o\",{},{},{}]",
            string(op.symbol()),
            expr_to_json(lhs)?,
            expr_to_json(rhs)?
        ),
        Expr::Ternary { cond, then_e, else_e } => format!(
            "[\"t\",{},{},{}]",
            expr_to_json(cond)?,
            expr_to_json(then_e)?,
            expr_to_json(else_e)?
        ),
        Expr::Builtin { func, args } => {
            let mut parts = vec!["\"B\"".to_string(), string(func.name())];
            for a in args {
                parts.push(expr_to_json(a)?);
            }
            format!("[{}]", parts.join(","))
        }
        // Analysis resolves these away; an IR still carrying them is not a
        // persistable artifact.
        Expr::Name(..) | Expr::External(..) | Expr::Call { .. } => return None,
    })
}

fn expr_from(v: &Value) -> Option<Expr> {
    let a = v.as_arr()?;
    Some(match a.first()?.as_str()? {
        "f" if a.len() == 2 => Expr::Float(f64_from(&a[1])?),
        "b" if a.len() == 2 => Expr::Bool(a[1].as_bool()?),
        "F" if a.len() == 5 => {
            let off: Offset = [i32_from(&a[2])?, i32_from(&a[3])?, i32_from(&a[4])?];
            Expr::field(a[1].as_str()?, off)
        }
        "s" if a.len() == 2 => Expr::Scalar(a[1].as_str()?.to_string()),
        "u" if a.len() == 3 => {
            let op = match a[1].as_str()? {
                "-" => UnOp::Neg,
                "!" => UnOp::Not,
                _ => return None,
            };
            Expr::Unary { op, operand: Box::new(expr_from(&a[2])?) }
        }
        "o" if a.len() == 4 => Expr::binary(
            binop_from_symbol(a[1].as_str()?)?,
            expr_from(&a[2])?,
            expr_from(&a[3])?,
        ),
        "t" if a.len() == 4 => {
            Expr::ternary(expr_from(&a[1])?, expr_from(&a[2])?, expr_from(&a[3])?)
        }
        "B" if a.len() >= 2 => {
            let func = Builtin::from_name(a[1].as_str()?)?;
            let args: Vec<Expr> =
                a[2..].iter().map(expr_from).collect::<Option<Vec<_>>>()?;
            if args.len() != func.arity() {
                return None;
            }
            Expr::Builtin { func, args }
        }
        _ => return None,
    })
}

// ---------------------------------------------------------------------------
// Whole-IR envelope

/// Serialize an analyzed IR to the `"ir"` persist payload. Returns `None`
/// if the IR violates the post-analysis expression invariant (never the
/// case for pipeline output; the guard keeps a broken artifact out of the
/// shared cache rather than panicking a server).
pub fn ir_to_json(ir: &StencilIr) -> Option<String> {
    let fields: Vec<String> = ir
        .fields
        .iter()
        .map(|f| {
            format!(
                "{{\"name\":{},\"dtype\":\"{}\",\"intent\":\"{}\",\"extent\":{}}}",
                string(&f.name),
                f.dtype,
                f.intent,
                extent_to_json(&f.extent)
            )
        })
        .collect();
    let scalars: Vec<String> = ir
        .scalars
        .iter()
        .map(|s| format!("{{\"name\":{},\"dtype\":\"{}\"}}", string(&s.name), s.dtype))
        .collect();
    let temps: Vec<String> = ir
        .temporaries
        .iter()
        .map(|t| {
            format!(
                "{{\"name\":{},\"dtype\":\"{}\",\"extent\":{},\"storage\":\"{}\",\"ring_depth\":{}}}",
                string(&t.name),
                t.dtype,
                extent_to_json(&t.extent),
                t.storage,
                t.ring_depth
            )
        })
        .collect();
    let externals: Vec<String> = ir
        .externals
        .iter()
        .map(|(name, v)| format!("[{},{}]", string(name), f64_to_json(*v)))
        .collect();
    let mut multistages: Vec<String> = Vec::with_capacity(ir.multistages.len());
    for ms in &ir.multistages {
        let mut stages: Vec<String> = Vec::with_capacity(ms.stages.len());
        for st in &ms.stages {
            stages.push(format!(
                "{{\"target\":{},\"value\":{},\"interval\":{},\"extent\":{},\"group\":{}}}",
                string(&st.stmt.target),
                expr_to_json(&st.stmt.value)?,
                interval_to_json(&st.interval),
                extent_to_json(&st.extent),
                st.fusion_group
            ));
        }
        multistages.push(format!(
            "{{\"policy\":\"{}\",\"stages\":[{}]}}",
            policy_to_str(ms.policy),
            stages.join(",")
        ));
    }
    Some(format!(
        "{{\"name\":{},\"fingerprint\":{},\"fused\":{},\"fast_math\":{},\
         \"fields\":[{}],\"scalars\":[{}],\"temporaries\":[{}],\"externals\":[{}],\
         \"multistages\":[{}]}}",
        string(&ir.name),
        string(&format!("{:016x}", ir.fingerprint)),
        ir.fused,
        ir.fast_math,
        fields.join(","),
        scalars.join(","),
        temps.join(","),
        externals.join(","),
        multistages.join(",")
    ))
}

/// Deserialize a persisted IR payload. `None` on any structural mismatch —
/// the caller treats that as a cache reject and compiles fresh. Stage read
/// sets are *recomputed* from the deserialized expressions (they are a
/// pure function of the assignment), and the caller must still validate
/// the artifact by recomputing the canonical fingerprint.
pub fn ir_from_json(payload: &str) -> Option<StencilIr> {
    let v = jsonw::parse(payload).ok()?;
    let name = v.get("name")?.as_str()?.to_string();
    let fingerprint = u64::from_str_radix(v.get("fingerprint")?.as_str()?, 16).ok()?;
    let fused = v.get("fused")?.as_bool()?;
    let fast_math = v.get("fast_math")?.as_bool()?;

    let mut fields = Vec::new();
    for f in v.get("fields")?.as_arr()? {
        fields.push(FieldInfo {
            name: f.get("name")?.as_str()?.to_string(),
            dtype: dtype_from(f.get("dtype")?.as_str()?)?,
            intent: intent_from(f.get("intent")?.as_str()?)?,
            extent: extent_from(f.get("extent")?)?,
        });
    }
    let mut scalars = Vec::new();
    for s in v.get("scalars")?.as_arr()? {
        scalars.push(ScalarDecl {
            name: s.get("name")?.as_str()?.to_string(),
            dtype: dtype_from(s.get("dtype")?.as_str()?)?,
            span: Span::default(),
        });
    }
    let mut temporaries = Vec::new();
    for t in v.get("temporaries")?.as_arr()? {
        temporaries.push(TempField {
            name: t.get("name")?.as_str()?.to_string(),
            dtype: dtype_from(t.get("dtype")?.as_str()?)?,
            extent: extent_from(t.get("extent")?)?,
            storage: storage_from(t.get("storage")?.as_str()?)?,
            ring_depth: i32_from(t.get("ring_depth")?)?,
        });
    }
    let mut externals = BTreeMap::new();
    for e in v.get("externals")?.as_arr()? {
        let pair = e.as_arr()?;
        if pair.len() != 2 {
            return None;
        }
        externals.insert(pair[0].as_str()?.to_string(), f64_from(&pair[1])?);
    }
    let mut multistages = Vec::new();
    for ms in v.get("multistages")?.as_arr()? {
        let policy = policy_from(ms.get("policy")?.as_str()?)?;
        let mut stages = Vec::new();
        for st in ms.get("stages")?.as_arr()? {
            let stmt = Assign {
                target: st.get("target")?.as_str()?.to_string(),
                value: expr_from(st.get("value")?)?,
            };
            let reads = Stage::collect_reads(&stmt);
            stages.push(Stage {
                stmt,
                interval: interval_from(st.get("interval")?)?,
                extent: extent_from(st.get("extent")?)?,
                reads,
                fusion_group: usize_from(st.get("group")?)?,
            });
        }
        multistages.push(Multistage { policy, stages });
    }

    Some(StencilIr {
        name,
        fields,
        scalars,
        temporaries,
        multistages,
        externals,
        fingerprint,
        fused,
        fast_math,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis;
    use crate::ir::canon;
    use crate::opt::{OptConfig, OptLevel};
    use crate::stdlib;

    const LEVELS: [OptLevel; 4] = [OptLevel::O0, OptLevel::O1, OptLevel::O2, OptLevel::O3];

    /// The tentpole round-trip property: for every stdlib stencil at every
    /// opt level (and the fast-math variants), a reloaded IR is canon- and
    /// fingerprint-identical to the original.
    #[test]
    fn stdlib_roundtrip_is_canon_and_fingerprint_identical() {
        for name in stdlib::names() {
            let src = stdlib::source(name).unwrap();
            for level in LEVELS {
                for fast_math in [false, true] {
                    for dtype in [None, Some(DType::F32)] {
                        let config = OptConfig::level(level)
                            .with_fast_math(fast_math)
                            .with_dtype(dtype);
                        let ir = analysis::compile_source_opt(
                            src,
                            name,
                            &Default::default(),
                            &config,
                        )
                        .unwrap();
                        if let Some(dt) = dtype {
                            assert!(ir.fields.iter().all(|f| f.dtype == dt));
                        }
                        let payload = ir_to_json(&ir)
                            .unwrap_or_else(|| panic!("{name} O{level}: unserializable IR"));
                        let back = ir_from_json(&payload)
                            .unwrap_or_else(|| panic!("{name} O{level}: reload failed"));
                        // dtypes ride the canonical text, so a reloaded
                        // f32 artifact keeps its element type.
                        assert_eq!(ir.dtype(), back.dtype());
                        let tag = config.canon();
                        assert_eq!(
                            canon::canon_ir(&ir, &tag),
                            canon::canon_ir(&back, &tag),
                            "{name} O{level} fast_math={fast_math}: canon text diverged"
                        );
                        assert_eq!(
                            analysis::fingerprint_ir_with(&back, &tag),
                            ir.fingerprint,
                            "{name} O{level} fast_math={fast_math}: fingerprint diverged"
                        );
                        assert_eq!(back.fingerprint, ir.fingerprint);
                        // Derived read sets must be rebuilt identically too.
                        for (m0, m1) in ir.multistages.iter().zip(&back.multistages) {
                            for (s0, s1) in m0.stages.iter().zip(&m1.stages) {
                                assert_eq!(s0.reads, s1.reads);
                            }
                        }
                    }
                }
            }
        }
    }

    /// Floats survive bit-exactly, including values a decimal rendering
    /// would mangle.
    #[test]
    fn float_bits_survive_exactly() {
        for v in [0.1f64, 1.0 / 3.0, f64::MIN_POSITIVE, 1e300, -0.0] {
            let e = Expr::binary(crate::dsl::ast::BinOp::Add, Expr::Float(v), Expr::Float(v));
            let json = expr_to_json(&e).unwrap();
            let back = expr_from(&jsonw::parse(&json).unwrap()).unwrap();
            match back {
                Expr::Binary { lhs, .. } => match *lhs {
                    Expr::Float(got) => assert_eq!(got.to_bits(), v.to_bits()),
                    _ => panic!("wrong node"),
                },
                _ => panic!("wrong node"),
            }
        }
    }

    /// Structural garbage is a reject (`None`), never a panic.
    #[test]
    fn malformed_payloads_reject_cleanly() {
        for bad in [
            "",
            "42",
            "{\"name\":\"x\"}",
            "{\"name\":\"x\",\"fingerprint\":\"zz\",\"fused\":false,\"fast_math\":false,\
             \"fields\":[],\"scalars\":[],\"temporaries\":[],\"externals\":[],\
             \"multistages\":[]}",
        ] {
            assert!(ir_from_json(bad).is_none(), "accepted: {bad}");
        }
    }
}
