//! The stencil standard library: the paper's workloads as `.gts` sources,
//! embedded in the binary and compiled through the regular pipeline.
//!
//! * `hdiff` — horizontal diffusion with flux limiting (Fig. 3 left);
//! * `vadv` — implicit vertical advection / Thomas solver (Fig. 3 right);
//! * `vadv_carry` — vertical sweep with a horizontally spread carry
//!   (`x[±1,0,-1]`): the per-level halo-exchange workload;
//! * `diffusion` — the paper's Figure 1 listing, verbatim;
//! * `basic` — copy/laplacian/diffuse/upwind/column-sum/smagorinsky
//!   building blocks used by the examples and the model.

use crate::analysis;
use crate::dsl::span::CResult;
use crate::ir::implir::StencilIr;
use std::collections::BTreeMap;

pub const HDIFF_SRC: &str = include_str!("gts/hdiff.gts");
pub const VADV_SRC: &str = include_str!("gts/vadv.gts");
pub const FIGURE1_SRC: &str = include_str!("gts/figure1.gts");
pub const BASIC_SRC: &str = include_str!("gts/basic.gts");

/// `(stencil name, module source)` for every library stencil.
pub const LIBRARY: [(&str, &str); 10] = [
    ("hdiff", HDIFF_SRC),
    ("vadv", VADV_SRC),
    ("vadv_carry", VADV_SRC),
    ("diffusion", FIGURE1_SRC),
    ("copy", BASIC_SRC),
    ("laplacian", BASIC_SRC),
    ("diffuse", BASIC_SRC),
    ("upwind_advect", BASIC_SRC),
    ("column_sum", BASIC_SRC),
    ("smagorinsky", BASIC_SRC),
];

/// Source module containing `name`, if it is a library stencil.
pub fn source(name: &str) -> Option<&'static str> {
    LIBRARY.iter().find(|(n, _)| *n == name).map(|(_, s)| *s)
}

/// Compile a library stencil to implementation IR.
pub fn compile(name: &str) -> CResult<StencilIr> {
    compile_with_externals(name, &BTreeMap::new())
}

/// Compile a library stencil with external overrides.
pub fn compile_with_externals(
    name: &str,
    externals: &BTreeMap<String, f64>,
) -> CResult<StencilIr> {
    let src = source(name).ok_or_else(|| {
        crate::dsl::span::CompileError::new(
            "stdlib",
            format!("no library stencil named `{name}`"),
        )
    })?;
    analysis::compile_source(src, name, externals)
}

/// All library stencil names.
pub fn names() -> Vec<&'static str> {
    LIBRARY.iter().map(|(n, _)| *n).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::implir::Intent;

    #[test]
    fn all_library_stencils_compile() {
        for (name, _) in LIBRARY {
            let ir = compile(name).unwrap_or_else(|e| panic!("{name}: {e}"));
            assert_eq!(ir.name, name);
            assert!(ir.num_stages() > 0);
        }
    }

    #[test]
    fn hdiff_has_classic_halo_2() {
        let ir = compile("hdiff").unwrap();
        let inp = ir.field("in_phi").unwrap();
        assert_eq!(inp.extent.i, (-2, 2));
        assert_eq!(inp.extent.j, (-2, 2));
        assert_eq!(inp.extent.k, (0, 0));
        assert_eq!(ir.field("out_phi").unwrap().intent, Intent::Out);
        // three temporaries: lapf, flx, fly
        assert_eq!(ir.temporaries.len(), 3);
    }

    #[test]
    fn vadv_structure() {
        let ir = compile("vadv").unwrap();
        assert_eq!(ir.multistages.len(), 2);
        assert_eq!(
            ir.multistages[0].policy,
            crate::dsl::ast::IterationPolicy::Forward
        );
        assert_eq!(
            ir.multistages[1].policy,
            crate::dsl::ast::IterationPolicy::Backward
        );
        let phi = ir.field("phi").unwrap();
        assert_eq!(phi.intent, Intent::InOut);
        // No horizontal halo for a purely vertical solver.
        assert_eq!(phi.extent.i, (0, 0));
        assert_eq!(phi.extent.j, (0, 0));
    }

    #[test]
    fn vadv_carry_structure() {
        let ir = compile("vadv_carry").unwrap();
        assert_eq!(ir.multistages.len(), 1);
        assert_eq!(
            ir.multistages[0].policy,
            crate::dsl::ast::IterationPolicy::Forward
        );
        // The carry is horizontally spread: one-column halo each side.
        let x = ir.field("x").unwrap();
        assert_eq!(x.extent.i, (-1, 1));
        assert_eq!(x.extent.j, (0, 0));
    }

    #[test]
    fn figure1_externals_default() {
        let ir = compile("diffusion").unwrap();
        assert_eq!(ir.externals.get("LIM"), Some(&0.01));
        let mut ov = BTreeMap::new();
        ov.insert("LIM".to_string(), 0.5);
        let ir2 = compile_with_externals("diffusion", &ov).unwrap();
        assert_ne!(ir.fingerprint, ir2.fingerprint);
    }

    #[test]
    fn unknown_stencil_is_error() {
        assert!(compile("nope").is_err());
        assert!(source("nope").is_none());
    }
}
