//! Name resolution and external folding.
//!
//! After inlining, every remaining name in a stencil body refers to one of:
//! a field parameter, a scalar parameter, a *temporary field* (a name whose
//! first appearance is on a lhs — paper §2.2: "Fields appearing for the
//! first time on the lhs of expressions ... are treated as temporary
//! fields"), or an external compile-time constant. This pass classifies
//! every `Name`, rewrites bare names into `Field` accesses at offset 0, and
//! folds externals into literals.

use crate::dsl::ast::{Expr, Module, StencilDef, Stmt};
use crate::dsl::span::{CResult, CompileError, Span};
use std::collections::{BTreeMap, HashSet};

/// Symbol classification computed for one stencil.
pub struct SymbolTable {
    pub fields: HashSet<String>,
    pub scalars: HashSet<String>,
    pub temporaries: Vec<String>,
    pub externals: BTreeMap<String, f64>,
}

/// Collect every assignment target in a statement tree.
pub fn collect_targets(stmts: &[Stmt], out: &mut Vec<String>) {
    for s in stmts {
        match s {
            Stmt::Assign { target, .. } => {
                if !out.contains(target) {
                    out.push(target.clone());
                }
            }
            Stmt::If { then_body, else_body, .. } => {
                collect_targets(then_body, out);
                collect_targets(else_body, out);
            }
        }
    }
}

/// Build the symbol table for a stencil given the module's extern defaults
/// and compile-time external overrides.
pub fn build_symbols(
    def: &StencilDef,
    module: &Module,
    extern_overrides: &BTreeMap<String, f64>,
) -> CResult<SymbolTable> {
    let fields: HashSet<String> = def.fields.iter().map(|f| f.name.clone()).collect();
    let scalars: HashSet<String> = def.scalars.iter().map(|s| s.name.clone()).collect();

    let mut externals: BTreeMap<String, f64> = BTreeMap::new();
    for (name, default) in &module.extern_defaults {
        externals.insert(name.clone(), *default);
    }
    for (name, value) in extern_overrides {
        externals.insert(name.clone(), *value);
    }
    for (name, value) in &externals {
        if value.is_nan() {
            return Err(CompileError::new(
                "resolve",
                format!("external `{name}` has no value (declare a default or pass one at compile time)"),
            ));
        }
        if fields.contains(name) || scalars.contains(name) {
            return Err(CompileError::new(
                "resolve",
                format!("external `{name}` shadows a stencil parameter"),
            ));
        }
    }

    let mut targets = Vec::new();
    for c in &def.computations {
        for b in &c.blocks {
            collect_targets(&b.body, &mut targets);
        }
    }
    let temporaries: Vec<String> = targets
        .into_iter()
        .filter(|t| !fields.contains(t) && !scalars.contains(t))
        .collect();
    for t in &temporaries {
        if externals.contains_key(t) {
            return Err(CompileError::new(
                "resolve",
                format!("cannot assign to external `{t}`"),
            ));
        }
    }
    Ok(SymbolTable { fields, scalars, temporaries, externals })
}

/// Resolve all names in an expression and fold externals to literals.
pub fn resolve_expr(e: &Expr, sym: &SymbolTable) -> CResult<Expr> {
    match e {
        Expr::Name(n, span) => resolve_name(n, [0, 0, 0], *span, sym),
        Expr::Field { name, offset, span } => resolve_name(name, *offset, *span, sym),
        Expr::Scalar(n) => {
            if sym.scalars.contains(n) {
                Ok(e.clone())
            } else {
                Err(CompileError::new("resolve", format!("unknown scalar `{n}`")))
            }
        }
        Expr::External(n, span) => fold_external(n, *span, sym),
        Expr::Unary { op, operand } => Ok(Expr::Unary {
            op: *op,
            operand: Box::new(resolve_expr(operand, sym)?),
        }),
        Expr::Binary { op, lhs, rhs } => Ok(Expr::Binary {
            op: *op,
            lhs: Box::new(resolve_expr(lhs, sym)?),
            rhs: Box::new(resolve_expr(rhs, sym)?),
        }),
        Expr::Ternary { cond, then_e, else_e } => Ok(Expr::Ternary {
            cond: Box::new(resolve_expr(cond, sym)?),
            then_e: Box::new(resolve_expr(then_e, sym)?),
            else_e: Box::new(resolve_expr(else_e, sym)?),
        }),
        Expr::Call { name, span, .. } => Err(CompileError::with_span(
            "resolve",
            format!("unresolved call to `{name}` survived inlining (internal error)"),
            *span,
        )),
        Expr::Builtin { func, args } => Ok(Expr::Builtin {
            func: *func,
            args: args.iter().map(|a| resolve_expr(a, sym)).collect::<CResult<_>>()?,
        }),
        lit => Ok(lit.clone()),
    }
}

fn resolve_name(
    name: &str,
    offset: [i32; 3],
    span: Span,
    sym: &SymbolTable,
) -> CResult<Expr> {
    if sym.fields.contains(name) || sym.temporaries.iter().any(|t| t == name) {
        return Ok(Expr::Field { name: name.to_string(), offset, span });
    }
    if sym.scalars.contains(name) {
        if offset != [0, 0, 0] {
            return Err(CompileError::with_span(
                "resolve",
                format!("scalar parameter `{name}` cannot be indexed with an offset"),
                span,
            ));
        }
        return Ok(Expr::Scalar(name.to_string()));
    }
    if sym.externals.contains_key(name) {
        if offset != [0, 0, 0] {
            return Err(CompileError::with_span(
                "resolve",
                format!("external `{name}` cannot be indexed with an offset"),
                span,
            ));
        }
        return fold_external(name, span, sym);
    }
    Err(CompileError::with_span(
        "resolve",
        format!("undefined symbol `{name}`"),
        span,
    ))
}

fn fold_external(name: &str, span: Span, sym: &SymbolTable) -> CResult<Expr> {
    match sym.externals.get(name) {
        Some(v) => Ok(Expr::Float(*v)),
        None => Err(CompileError::with_span(
            "resolve",
            format!("undefined external `{name}`"),
            span,
        )),
    }
}

/// Resolve a full statement tree.
pub fn resolve_stmts(stmts: &[Stmt], sym: &SymbolTable) -> CResult<Vec<Stmt>> {
    stmts
        .iter()
        .map(|s| {
            Ok(match s {
                Stmt::Assign { target, value, span } => {
                    // Targets must be fields or temporaries.
                    if sym.scalars.contains(target) {
                        return Err(CompileError::with_span(
                            "resolve",
                            format!("cannot assign to scalar parameter `{target}`"),
                            *span,
                        ));
                    }
                    Stmt::Assign {
                        target: target.clone(),
                        value: resolve_expr(value, sym)?,
                        span: *span,
                    }
                }
                Stmt::If { cond, then_body, else_body, span } => Stmt::If {
                    cond: resolve_expr(cond, sym)?,
                    then_body: resolve_stmts(then_body, sym)?,
                    else_body: resolve_stmts(else_body, sym)?,
                    span: *span,
                },
            })
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsl::parser::parse_module;

    fn setup(src: &str) -> (Module, SymbolTable) {
        let m = parse_module(src).unwrap();
        let sym = build_symbols(&m.stencils[0], &m, &BTreeMap::new()).unwrap();
        (m, sym)
    }

    #[test]
    fn classifies_temporaries() {
        let (_, sym) = setup(
            "stencil s(a: Field<f64>, b: Field<f64>; c: f64) {\n\
               with computation(PARALLEL), interval(...) { tmp = a * c; b = tmp; }\n\
             }",
        );
        assert_eq!(sym.temporaries, vec!["tmp".to_string()]);
        assert!(sym.fields.contains("a"));
        assert!(sym.scalars.contains("c"));
    }

    #[test]
    fn bare_name_becomes_zero_offset_field() {
        let (m, sym) = setup(
            "stencil s(a: Field<f64>, b: Field<f64>) {\n\
               with computation(PARALLEL), interval(...) { b = a; }\n\
             }",
        );
        let body = resolve_stmts(&m.stencils[0].computations[0].blocks[0].body, &sym).unwrap();
        let Stmt::Assign { value, .. } = &body[0] else { panic!() };
        assert!(matches!(value, Expr::Field { offset: [0, 0, 0], .. }));
    }

    #[test]
    fn externals_fold_to_literals() {
        let m = parse_module(
            "extern LIM = 0.25;\n\
             stencil s(a: Field<f64>, b: Field<f64>) {\n\
               with computation(PARALLEL), interval(...) { b = a * LIM; }\n\
             }",
        )
        .unwrap();
        let sym = build_symbols(&m.stencils[0], &m, &BTreeMap::new()).unwrap();
        let body = resolve_stmts(&m.stencils[0].computations[0].blocks[0].body, &sym).unwrap();
        let Stmt::Assign { value, .. } = &body[0] else { panic!() };
        let Expr::Binary { rhs, .. } = value else { panic!() };
        assert_eq!(**rhs, Expr::Float(0.25));
    }

    #[test]
    fn extern_override_wins() {
        let m = parse_module(
            "extern LIM = 0.25;\n\
             stencil s(a: Field<f64>) {\n\
               with computation(PARALLEL), interval(...) { a = LIM; }\n\
             }",
        )
        .unwrap();
        let mut ov = BTreeMap::new();
        ov.insert("LIM".to_string(), 9.0);
        let sym = build_symbols(&m.stencils[0], &m, &ov).unwrap();
        assert_eq!(sym.externals["LIM"], 9.0);
    }

    #[test]
    fn extern_without_value_is_error() {
        let m = parse_module(
            "extern LIM;\n\
             stencil s(a: Field<f64>) {\n\
               with computation(PARALLEL), interval(...) { a = LIM; }\n\
             }",
        )
        .unwrap();
        assert!(build_symbols(&m.stencils[0], &m, &BTreeMap::new()).is_err());
    }

    #[test]
    fn undefined_symbol_is_error() {
        let (m, sym) = setup(
            "stencil s(a: Field<f64>) {\n\
               with computation(PARALLEL), interval(...) { a = ghost; }\n\
             }",
        );
        assert!(resolve_stmts(&m.stencils[0].computations[0].blocks[0].body, &sym).is_err());
    }

    #[test]
    fn scalar_with_offset_is_error() {
        let (m, sym) = setup(
            "stencil s(a: Field<f64>; c: f64) {\n\
               with computation(PARALLEL), interval(...) { a = c[1,0,0]; }\n\
             }",
        );
        assert!(resolve_stmts(&m.stencils[0].computations[0].blocks[0].body, &sym).is_err());
    }

    #[test]
    fn assign_to_scalar_is_error() {
        let (m, sym) = setup(
            "stencil s(a: Field<f64>; c: f64) {\n\
               with computation(PARALLEL), interval(...) { c = a; }\n\
             }",
        );
        assert!(resolve_stmts(&m.stencils[0].computations[0].blocks[0].body, &sym).is_err());
    }
}
