//! The analysis pipeline: definition IR → implementation IR (paper Fig. 2).
//!
//! Phases, in order:
//! 1. **inline** — expand GTScript function calls (offsets compose);
//! 2. **resolve** — classify names (field / scalar / temporary / external)
//!    and fold externals into literals;
//! 3. **lower** — rewrite point-wise if/else into guarded selects
//!    (materializing mask temporaries where required);
//! 4. **checks** — vertical-dependency and initialization rules;
//! 5. **schedule** — one multistage per `with computation`, one stage per
//!    lowered assignment;
//! 6. **extents** — backward halo analysis, stamping per-stage compute
//!    extents and per-field storage requirements;
//! 7. **fingerprint** — canonical-IR identity for the compilation cache.
//!
//! The pipeline emits *pre-optimization* IR: every stage in its own fusion
//! group, every temporary a full 3-D field. [`analyze_opt`] additionally
//! runs the [`crate::opt`] pass manager over that IR (stage fusion,
//! temporary demotion, DCE, folding/CSE) before any backend sees it.

use crate::dsl::ast::{DType, Module, StencilDef};
use crate::dsl::span::{CResult, CompileError};
use crate::ir::canon;
use crate::ir::implir::*;
use std::collections::BTreeMap;

use super::checks::{self, LoweredComputation};
use super::extents::{self, ScheduledComputation};
use super::inline;
use super::lowering;
use super::resolve;

/// Compile a stencil definition into implementation IR.
///
/// `extern_overrides` provides/overrides compile-time external constants
/// (the analog of the `externals={...}` argument of `@gtscript.stencil`).
pub fn analyze(
    def: &StencilDef,
    module: &Module,
    extern_overrides: &BTreeMap<String, f64>,
) -> CResult<StencilIr> {
    checks::check_dtypes(def)?;

    // Phase 1+2: inline calls, then resolve names / fold externals.
    let sym = resolve::build_symbols(def, module, extern_overrides)?;
    let mut lowered_comps: Vec<LoweredComputation> = Vec::new();
    let mut mask_temps: Vec<String> = Vec::new();
    for comp in &def.computations {
        let mut assigns = Vec::new();
        for block in &comp.blocks {
            let inlined = inline::inline_stmts(&block.body, module)?;
            let resolved = resolve::resolve_stmts(&inlined, &sym)?;
            let (lowered, masks) = lowering::lower_stmts(&resolved)?;
            mask_temps.extend(masks);
            for a in lowered {
                assigns.push((block.interval, a));
            }
        }
        lowered_comps.push(LoweredComputation { policy: comp.policy, assigns });
    }

    // Temporaries: user temporaries (first-on-lhs) plus generated masks.
    let mut temp_names = sym.temporaries.clone();
    temp_names.extend(mask_temps);

    // Re-resolve any mask fields introduced by lowering: they are already
    // `Expr::Field` nodes, nothing to do — but they must participate in the
    // initialization check.
    checks::check_dependencies(&lowered_comps)?;
    checks::check_temporaries_initialized(&lowered_comps, &temp_names)?;

    // Phase 5: schedule.
    let scheduled: Vec<ScheduledComputation> = lowered_comps
        .into_iter()
        .map(|c| ScheduledComputation { policy: c.policy, assigns: c.assigns })
        .collect();

    // Phase 6: extents.
    let is_temp = |n: &str| temp_names.iter().any(|t| t == n);
    let info = extents::compute_extents(&scheduled, is_temp);

    // Assemble the implementation IR.
    let temp_dtype = def.fields.first().map(|f| f.dtype).unwrap_or(DType::F64);
    let mut multistages = Vec::new();
    let mut flat_idx = 0usize;
    for comp in &scheduled {
        let mut stages = Vec::new();
        for (interval, assign) in &comp.assigns {
            let reads = Stage::collect_reads(assign);
            stages.push(Stage {
                stmt: assign.clone(),
                interval: *interval,
                extent: info.stage_extents[flat_idx],
                reads,
                // Pre-opt: one group per stage (no fusion).
                fusion_group: flat_idx,
            });
            flat_idx += 1;
        }
        multistages.push(Multistage { policy: comp.policy, stages });
    }

    // Field intents.
    let mut fields = Vec::new();
    for f in &def.fields {
        let written = multistages
            .iter()
            .flat_map(|m| &m.stages)
            .any(|s| s.stmt.target == f.name);
        let read = multistages
            .iter()
            .flat_map(|m| &m.stages)
            .any(|s| s.reads.iter().any(|(n, _)| n == &f.name));
        let intent = match (read, written) {
            (true, true) => Intent::InOut,
            (false, true) => Intent::Out,
            (true, false) => Intent::In,
            (false, false) => {
                return Err(CompileError::new(
                    "pipeline",
                    format!("field parameter `{}` is never used in stencil `{}`", f.name, def.name),
                ))
            }
        };
        let extent = info
            .field_requirements
            .get(&f.name)
            .copied()
            .unwrap_or_else(Extent::zero)
            // Normalize: halo requirements always include the center.
            .union(Extent::zero());
        fields.push(FieldInfo { name: f.name.clone(), dtype: f.dtype, intent, extent });
    }

    let temporaries: Vec<TempField> = temp_names
        .iter()
        .map(|t| TempField {
            name: t.clone(),
            dtype: temp_dtype,
            extent: info
                .field_requirements
                .get(t)
                .copied()
                .unwrap_or_else(Extent::zero)
                .union(Extent::zero()),
            storage: StorageClass::Field3D,
            ring_depth: 0,
        })
        .collect();

    let mut ir = StencilIr {
        name: def.name.clone(),
        fields,
        scalars: def.scalars.clone(),
        temporaries,
        multistages,
        externals: sym.externals.clone(),
        fingerprint: 0,
        fused: false,
        fast_math: false,
    };
    ir.fingerprint = fingerprint_ir(&ir);
    Ok(ir)
}

/// Formatting-insensitive fingerprint over the canonical IR (paper §2.3:
/// "code reformatting would not trigger a new compilation").
pub fn fingerprint_ir(ir: &StencilIr) -> u64 {
    fingerprint_ir_with(ir, "")
}

/// Fingerprint including an optimization tag: the pass configuration's
/// canonical string is mixed into the canonical IR so artifacts compiled at
/// different opt levels never share a cache slot, even when the passes
/// happen to leave the IR unchanged.
pub fn fingerprint_ir_with(ir: &StencilIr, opt_tag: &str) -> u64 {
    canon::fnv1a64(canon::canon_ir(ir, opt_tag).as_bytes())
}

/// Analyze and then optimize: run the [`crate::opt`] pass manager over the
/// pipeline's pre-opt IR. The returned IR's fingerprint incorporates the
/// pass configuration.
pub fn analyze_opt(
    def: &StencilDef,
    module: &Module,
    extern_overrides: &BTreeMap<String, f64>,
    config: &crate::opt::OptConfig,
) -> CResult<StencilIr> {
    let mut ir = analyze(def, module, extern_overrides)?;
    crate::opt::PassManager::new(config).run(&mut ir);
    Ok(ir)
}

/// Convenience: parse + analyze a single-stencil module source.
pub fn compile_source(
    src: &str,
    stencil_name: &str,
    extern_overrides: &BTreeMap<String, f64>,
) -> CResult<StencilIr> {
    let module = crate::dsl::parser::parse_module(src)?;
    let def = module
        .stencil(stencil_name)
        .ok_or_else(|| CompileError::new("pipeline", format!("no stencil `{stencil_name}` in module")))?;
    analyze(def, &module, extern_overrides)
}

/// Convenience: parse + analyze + optimize a single-stencil module source.
pub fn compile_source_opt(
    src: &str,
    stencil_name: &str,
    extern_overrides: &BTreeMap<String, f64>,
    config: &crate::opt::OptConfig,
) -> CResult<StencilIr> {
    let mut ir = compile_source(src, stencil_name, extern_overrides)?;
    crate::opt::PassManager::new(config).run(&mut ir);
    Ok(ir)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsl::ast::IterationPolicy;

    const HDIFF_SIMPLE: &str = "
        function lap(phi) {
            return -4.0 * phi[0,0,0] + phi[-1,0,0] + phi[1,0,0] + phi[0,-1,0] + phi[0,1,0];
        }
        stencil hdiff(in_phi: Field<f64>, out_phi: Field<f64>; alpha: f64) {
            with computation(PARALLEL), interval(...) {
                l = lap(in_phi);
                out_phi = in_phi + alpha * lap(l);
            }
        }";

    #[test]
    fn full_pipeline_hdiff() {
        let ir = compile_source(HDIFF_SIMPLE, "hdiff", &BTreeMap::new()).unwrap();
        assert_eq!(ir.temporaries.len(), 1);
        assert_eq!(ir.num_stages(), 2);
        // l computed over ±1, in_phi needs ±2 halo.
        let inp = ir.field("in_phi").unwrap();
        assert_eq!(inp.extent.i, (-2, 2));
        assert_eq!(inp.intent, Intent::In);
        let out = ir.field("out_phi").unwrap();
        assert_eq!(out.intent, Intent::Out);
        assert_eq!(out.extent, Extent::zero());
        let l = ir.temporary("l").unwrap();
        assert_eq!(l.extent.i, (-1, 1));
        assert_eq!(ir.multistages[0].stages[0].extent.i, (-1, 1));
    }

    #[test]
    fn fingerprint_formatting_insensitive() {
        let a = compile_source(HDIFF_SIMPLE, "hdiff", &BTreeMap::new()).unwrap();
        let reformatted = HDIFF_SIMPLE.replace("\n            ", " ").replace("  ", " ");
        let b = compile_source(&reformatted, "hdiff", &BTreeMap::new()).unwrap();
        assert_eq!(a.fingerprint, b.fingerprint);
    }

    #[test]
    fn fingerprint_sensitive_to_externals() {
        const SRC: &str = "
            extern C = 1.0;
            stencil s(a: Field<f64>, b: Field<f64>) {
                with computation(PARALLEL), interval(...) { b = a * C; }
            }";
        let a = compile_source(SRC, "s", &BTreeMap::new()).unwrap();
        let mut ov = BTreeMap::new();
        ov.insert("C".to_string(), 2.0);
        let b = compile_source(SRC, "s", &ov).unwrap();
        assert_ne!(a.fingerprint, b.fingerprint);
    }

    #[test]
    fn sequential_policies_preserved() {
        const SRC: &str = "
            stencil cum(a: Field<f64>, b: Field<f64>) {
                with computation(FORWARD) {
                    interval(0, 1) { b = a; }
                    interval(1, None) { b = b[0,0,-1] + a; }
                }
                with computation(BACKWARD) {
                    interval(-1, None) { a = b; }
                    interval(0, -1) { a = a[0,0,1] + b; }
                }
            }";
        let ir = compile_source(SRC, "cum", &BTreeMap::new()).unwrap();
        assert_eq!(ir.multistages.len(), 2);
        assert_eq!(ir.multistages[0].policy, IterationPolicy::Forward);
        assert_eq!(ir.multistages[1].policy, IterationPolicy::Backward);
        assert_eq!(ir.multistages[0].stages.len(), 2);
        let a = ir.field("a").unwrap();
        assert_eq!(a.intent, Intent::InOut);
    }

    #[test]
    fn unused_field_is_error() {
        const SRC: &str = "
            stencil s(a: Field<f64>, ghost: Field<f64>) {
                with computation(PARALLEL), interval(...) { a = a * 2.0; }
            }";
        assert!(compile_source(SRC, "s", &BTreeMap::new()).is_err());
    }

    #[test]
    fn if_else_produces_select_stages() {
        const SRC: &str = "
            stencil s(a: Field<f64>, b: Field<f64>; lim: f64) {
                with computation(PARALLEL), interval(...) {
                    if a > lim { b = a; } else { b = lim; }
                }
            }";
        let ir = compile_source(SRC, "s", &BTreeMap::new()).unwrap();
        assert_eq!(ir.num_stages(), 2);
        assert!(ir.temporaries.is_empty());
    }

    #[test]
    fn parallel_self_dependency_rejected_by_pipeline() {
        const SRC: &str = "
            stencil s(a: Field<f64>) {
                with computation(PARALLEL), interval(...) { a = a[1,0,0]; }
            }";
        let err = compile_source(SRC, "s", &BTreeMap::new()).unwrap_err();
        assert_eq!(err.phase, "checks");
    }

    #[test]
    fn figure1_hdiff_with_flux_limiter_compiles() {
        // The paper's Figure 1 stencil, transcribed into GTScript-RS.
        const SRC: &str = "
            extern LIM = 0.01;
            function laplacian(phi) {
                return -4.0 * phi[0,0,0]
                    + (phi[-1,0,0] + phi[1,0,0] + phi[0,-1,0] + phi[0,1,0]);
            }
            function gradx(f) { return f[1,0,0] - f[0,0,0]; }
            function grady(f) { return f[0,1,0] - f[0,0,0]; }
            stencil diffusion(in_phi: Field<f64>, out_phi: Field<f64>; alpha: f64) {
                with computation(PARALLEL), interval(...) {
                    lap = laplacian(in_phi);
                    bilap = laplacian(lap);
                    flux_x = gradx(bilap);
                    flux_y = grady(bilap);
                    grad_x = gradx(in_phi);
                    grad_y = grady(in_phi);
                    fx = flux_x * grad_x > LIM ? flux_x : LIM;
                    fy = flux_y * grad_y > LIM ? flux_y : LIM;
                    out_phi = in_phi + alpha * (gradx(fx[-1,0,0]) + grady(fy[0,-1,0]));
                }
            }";
        let ir = compile_source(SRC, "diffusion", &BTreeMap::new()).unwrap();
        assert_eq!(ir.temporaries.len(), 8);
        // in_phi needs a halo of 3: fx at [-1,0] -> flux_x at [-1,0] ->
        // bilap at [-1,1] -> lap at [-2,2] -> in_phi at [-3,3].
        let inp = ir.field("in_phi").unwrap();
        assert_eq!(inp.extent.i, (-3, 3));
        assert_eq!(inp.extent.j, (-3, 3));
        assert_eq!(ir.externals.get("LIM"), Some(&0.01));
    }
}
