//! GTScript function inlining.
//!
//! GT4Py functions (`@gtscript.function`) are *pure, point-wise* mappings:
//! a sequence of local bindings followed by a returned expression. Calls are
//! inlined by substitution; locals never materialize as fields. Offsets
//! compose additively: if a caller passes `fx[-1,0,0]` and the function body
//! reads its parameter at `[1,0,0]`, the inlined access is `fx[0,0,0]`
//! (paper §2.2, Figure 1 line 33).

use crate::dsl::ast::{Expr, Module, Stmt};
use crate::dsl::span::{CResult, CompileError};
use std::collections::HashMap;

/// Inline all `Expr::Call` nodes in an expression.
pub fn inline_expr(e: &Expr, module: &Module) -> CResult<Expr> {
    let mut stack = Vec::new();
    inline_rec(e, module, &mut stack)
}

/// Inline all calls in every statement of a stencil body.
pub fn inline_stmts(stmts: &[Stmt], module: &Module) -> CResult<Vec<Stmt>> {
    stmts
        .iter()
        .map(|s| {
            Ok(match s {
                Stmt::Assign { target, value, span } => Stmt::Assign {
                    target: target.clone(),
                    value: inline_expr(value, module)?,
                    span: *span,
                },
                Stmt::If { cond, then_body, else_body, span } => Stmt::If {
                    cond: inline_expr(cond, module)?,
                    then_body: inline_stmts(then_body, module)?,
                    else_body: inline_stmts(else_body, module)?,
                    span: *span,
                },
            })
        })
        .collect()
}

fn inline_rec(e: &Expr, module: &Module, stack: &mut Vec<String>) -> CResult<Expr> {
    match e {
        Expr::Call { name, args, span } => {
            let func = module.function(name).ok_or_else(|| {
                CompileError::with_span(
                    "inline",
                    format!("call to undefined function `{name}`"),
                    *span,
                )
            })?;
            if stack.contains(name) {
                return Err(CompileError::with_span(
                    "inline",
                    format!("recursive function call cycle through `{name}`"),
                    *span,
                ));
            }
            if args.len() != func.params.len() {
                return Err(CompileError::with_span(
                    "inline",
                    format!(
                        "function `{name}` takes {} argument(s), got {}",
                        func.params.len(),
                        args.len()
                    ),
                    *span,
                ));
            }
            // Inline nested calls inside the arguments first.
            let mut env: HashMap<String, Expr> = HashMap::new();
            for (p, a) in func.params.iter().zip(args) {
                env.insert(p.clone(), inline_rec(a, module, stack)?);
            }
            stack.push(name.clone());
            // Bindings are evaluated in order; each may reference parameters
            // and earlier locals.
            for (local, bexpr) in &func.bindings {
                let inlined = subst(bexpr, &env, module, stack)?;
                env.insert(local.clone(), inlined);
            }
            let result = subst(&func.ret, &env, module, stack)?;
            stack.pop();
            Ok(result)
        }
        Expr::Unary { op, operand } => Ok(Expr::Unary {
            op: *op,
            operand: Box::new(inline_rec(operand, module, stack)?),
        }),
        Expr::Binary { op, lhs, rhs } => Ok(Expr::Binary {
            op: *op,
            lhs: Box::new(inline_rec(lhs, module, stack)?),
            rhs: Box::new(inline_rec(rhs, module, stack)?),
        }),
        Expr::Ternary { cond, then_e, else_e } => Ok(Expr::Ternary {
            cond: Box::new(inline_rec(cond, module, stack)?),
            then_e: Box::new(inline_rec(then_e, module, stack)?),
            else_e: Box::new(inline_rec(else_e, module, stack)?),
        }),
        Expr::Builtin { func, args } => Ok(Expr::Builtin {
            func: *func,
            args: args.iter().map(|a| inline_rec(a, module, stack)).collect::<CResult<_>>()?,
        }),
        other => Ok(other.clone()),
    }
}

/// Substitute environment bindings into a function-body expression while
/// inlining nested calls. `Name(p)` becomes `env[p]`; `Field{p, off}`
/// becomes `env[p]` with all its field accesses shifted by `off`.
fn subst(
    e: &Expr,
    env: &HashMap<String, Expr>,
    module: &Module,
    stack: &mut Vec<String>,
) -> CResult<Expr> {
    match e {
        Expr::Name(n, _) => {
            if let Some(bound) = env.get(n) {
                Ok(bound.clone())
            } else {
                // Not a parameter or local: leave for the resolution pass
                // (it may be an external).
                Ok(e.clone())
            }
        }
        Expr::Field { name, offset, span } => {
            if let Some(bound) = env.get(name) {
                // A parameter/local *accessed as a field* resolves to the
                // bound expression shifted by the access offset; a bound
                // bare `Name` becomes an explicit field access so the
                // offset is preserved even when it is zero.
                match bound {
                    Expr::Name(n, s) => {
                        Ok(Expr::Field { name: n.clone(), offset: *offset, span: *s })
                    }
                    other => Ok(other.shifted(*offset)),
                }
            } else {
                Ok(Expr::Field { name: name.clone(), offset: *offset, span: *span })
            }
        }
        Expr::Call { name, args, span } => {
            let new_args = args
                .iter()
                .map(|a| subst(a, env, module, stack))
                .collect::<CResult<Vec<_>>>()?;
            inline_rec(
                &Expr::Call { name: name.clone(), args: new_args, span: *span },
                module,
                stack,
            )
        }
        Expr::Unary { op, operand } => Ok(Expr::Unary {
            op: *op,
            operand: Box::new(subst(operand, env, module, stack)?),
        }),
        Expr::Binary { op, lhs, rhs } => Ok(Expr::Binary {
            op: *op,
            lhs: Box::new(subst(lhs, env, module, stack)?),
            rhs: Box::new(subst(rhs, env, module, stack)?),
        }),
        Expr::Ternary { cond, then_e, else_e } => Ok(Expr::Ternary {
            cond: Box::new(subst(cond, env, module, stack)?),
            then_e: Box::new(subst(then_e, env, module, stack)?),
            else_e: Box::new(subst(else_e, env, module, stack)?),
        }),
        Expr::Builtin { func, args } => Ok(Expr::Builtin {
            func: *func,
            args: args
                .iter()
                .map(|a| subst(a, env, module, stack))
                .collect::<CResult<Vec<_>>>()?,
        }),
        other => Ok(other.clone()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsl::parser::parse_module;

    fn module(src: &str) -> Module {
        parse_module(src).unwrap()
    }

    #[test]
    fn inlines_laplacian() {
        let m = module(
            "function lap(phi) {\n\
               return -4.0 * phi[0,0,0] + phi[-1,0,0] + phi[1,0,0] + phi[0,-1,0] + phi[0,1,0];\n\
             }\n\
             stencil s(a: Field<f64>, b: Field<f64>) {\n\
               with computation(PARALLEL), interval(...) { b = lap(a); }\n\
             }",
        );
        let body = &m.stencils[0].computations[0].blocks[0].body;
        let inlined = inline_stmts(body, &m).unwrap();
        let Stmt::Assign { value, .. } = &inlined[0] else { panic!() };
        let mut offsets = vec![];
        value.visit_fields(&mut |n, off| {
            assert_eq!(n, "a");
            offsets.push(off);
        });
        assert_eq!(offsets.len(), 5);
        assert!(offsets.contains(&[-1, 0, 0]));
        assert!(offsets.contains(&[0, 1, 0]));
    }

    #[test]
    fn offsets_compose_through_calls() {
        // gradx(f) = f[1,0,0] - f[0,0,0]; calling gradx(fx[-1,0,0]) must
        // access fx at [0,0,0] and [-1,0,0] (paper Figure 1, line 33).
        let m = module(
            "function gradx(f) { return f[1,0,0] - f[0,0,0]; }\n\
             stencil s(fx: Field<f64>, out: Field<f64>) {\n\
               with computation(PARALLEL), interval(...) { out = gradx(fx[-1,0,0]); }\n\
             }",
        );
        let body = &m.stencils[0].computations[0].blocks[0].body;
        let inlined = inline_stmts(body, &m).unwrap();
        let Stmt::Assign { value, .. } = &inlined[0] else { panic!() };
        let mut offsets = vec![];
        value.visit_fields(&mut |_, off| offsets.push(off));
        assert_eq!(offsets, vec![[0, 0, 0], [-1, 0, 0]]);
    }

    #[test]
    fn nested_function_calls_inline() {
        let m = module(
            "function lap(phi) {\n\
               return -4.0 * phi[0,0,0] + phi[-1,0,0] + phi[1,0,0] + phi[0,-1,0] + phi[0,1,0];\n\
             }\n\
             function bilap(phi) { return lap(lap(phi)); }\n\
             stencil s(a: Field<f64>, b: Field<f64>) {\n\
               with computation(PARALLEL), interval(...) { b = bilap(a); }\n\
             }",
        );
        let body = &m.stencils[0].computations[0].blocks[0].body;
        let inlined = inline_stmts(body, &m).unwrap();
        let Stmt::Assign { value, .. } = &inlined[0] else { panic!() };
        // laplacian-of-laplacian touches offsets up to ±2.
        let mut max_off = 0;
        value.visit_fields(&mut |_, off| {
            max_off = max_off.max(off[0].abs()).max(off[1].abs());
        });
        assert_eq!(max_off, 2);
    }

    #[test]
    fn local_bindings_shift_correctly() {
        // d = f[1,0,0]; return d[0,1,0]  ==> f[1,1,0]
        let m = module(
            "function g(f) { d = f[1,0,0]; return d[0,1,0]; }\n\
             stencil s(a: Field<f64>, b: Field<f64>) {\n\
               with computation(PARALLEL), interval(...) { b = g(a); }\n\
             }",
        );
        let body = &m.stencils[0].computations[0].blocks[0].body;
        let inlined = inline_stmts(body, &m).unwrap();
        let Stmt::Assign { value, .. } = &inlined[0] else { panic!() };
        let mut offsets = vec![];
        value.visit_fields(&mut |_, off| offsets.push(off));
        assert_eq!(offsets, vec![[1, 1, 0]]);
    }

    #[test]
    fn undefined_function_is_error() {
        let m = module(
            "stencil s(a: Field<f64>) {\n\
               with computation(PARALLEL), interval(...) { a = nosuch(a); }\n\
             }",
        );
        let body = &m.stencils[0].computations[0].blocks[0].body;
        assert!(inline_stmts(body, &m).is_err());
    }

    #[test]
    fn arity_mismatch_is_error() {
        let m = module(
            "function g(f) { return f; }\n\
             stencil s(a: Field<f64>) {\n\
               with computation(PARALLEL), interval(...) { a = g(a, a); }\n\
             }",
        );
        let body = &m.stencils[0].computations[0].blocks[0].body;
        assert!(inline_stmts(body, &m).is_err());
    }
}
