//! Access-extent (halo) analysis.
//!
//! The backward dataflow pass at the heart of the analysis pipeline: walking
//! the scheduled stages in reverse program order, it computes
//!
//! * the *compute extent* of every stage — how far beyond the compute
//!   domain a temporary must be evaluated so all its consumers see valid
//!   values (paper §2.2: implicit iteration "ultimately also enables
//!   performance" — exact loop bounds are derived here, not by the user);
//! * the *halo requirement* of every API field — how much padding the
//!   caller's storages must provide around the compute domain;
//! * the allocation extent of every temporary.
//!
//! API (parameter) fields are only ever *written* over the unextended
//! compute domain (writes outside it would be observable side effects);
//! temporaries are computed over their full required extent.

use crate::dsl::ast::{Interval, IterationPolicy, LevelBound};
use crate::ir::implir::{Assign, Extent, Stage};
use std::collections::HashMap;

/// A scheduled-but-unextended stage list for one computation.
pub struct ScheduledComputation {
    pub policy: IterationPolicy,
    pub assigns: Vec<(Interval, Assign)>,
}

/// Result of the extent pass.
pub struct ExtentInfo {
    /// Compute extent per stage, in flat program order across computations.
    pub stage_extents: Vec<Extent>,
    /// Storage halo required per field (API fields and temporaries alike).
    pub field_requirements: HashMap<String, Extent>,
}

/// Run the backward extent pass.
///
/// `is_temporary(name)` distinguishes temporaries from API fields.
pub fn compute_extents(
    computations: &[ScheduledComputation],
    is_temporary: impl Fn(&str) -> bool,
) -> ExtentInfo {
    // Flatten to program order.
    let flat: Vec<(&Interval, &Assign)> = computations
        .iter()
        .flat_map(|c| c.assigns.iter().map(|(iv, a)| (iv, a)))
        .collect();

    let mut req: HashMap<String, Extent> = HashMap::new();
    // Every write to an API field is observable over the compute domain.
    for (_, a) in &flat {
        if !is_temporary(&a.target) {
            req.entry(a.target.clone()).or_insert_with(Extent::zero);
        }
    }

    let mut stage_extents = vec![Extent::zero(); flat.len()];
    for (idx, (interval, a)) in flat.iter().enumerate().rev() {
        // Temporaries are computed over everything their consumers need;
        // API fields only over the compute domain.
        let ext = if is_temporary(&a.target) {
            req.get(&a.target).copied().unwrap_or_else(Extent::zero)
        } else {
            Extent::zero()
        };
        stage_extents[idx] = ext;
        for (f, off) in Stage::collect_reads(a) {
            let mut need = ext.translate(off);
            // Refine the vertical requirement against the reading stage's
            // interval: a read at k-1 from `interval(1, None)` never leaves
            // the domain, so it must not demand a k-halo.
            let (klo_rel, khi_rel) = (ext.k.0 + off[2], ext.k.1 + off[2]);
            need.k.0 = match interval.lo {
                LevelBound::FromStart(n) => (n + klo_rel).min(0),
                LevelBound::FromEnd(_) => klo_rel.min(0),
            };
            need.k.1 = match interval.hi {
                LevelBound::FromEnd(m) => (khi_rel - m).max(0),
                LevelBound::FromStart(_) => khi_rel.max(0),
            };
            req.entry(f)
                .and_modify(|e| *e = e.union(need))
                .or_insert(need);
        }
    }

    ExtentInfo { stage_extents, field_requirements: req }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsl::ast::{BinOp, Expr};

    fn asg(t: &str, v: Expr) -> (Interval, Assign) {
        (Interval::full(), Assign { target: t.into(), value: v })
    }

    fn lap(of: &str) -> Expr {
        // simplified: f[-1,0,0] + f[1,0,0] + f[0,-1,0] + f[0,1,0]
        let f = |o| Expr::field(of, o);
        Expr::binary(
            BinOp::Add,
            Expr::binary(BinOp::Add, f([-1, 0, 0]), f([1, 0, 0])),
            Expr::binary(BinOp::Add, f([0, -1, 0]), f([0, 1, 0])),
        )
    }

    #[test]
    fn laplacian_of_laplacian_extents() {
        // lap = Δ(in); out = Δ(lap)  =>  lap computed over ±1, in needed ±2.
        let comps = [ScheduledComputation {
            policy: IterationPolicy::Parallel,
            assigns: vec![asg("lap", lap("inp")), asg("out", lap("lap"))],
        }];
        let info = compute_extents(&comps, |n| n == "lap");
        assert_eq!(info.stage_extents[1], Extent::zero()); // out: API field
        assert_eq!(info.stage_extents[0].i, (-1, 1)); // lap computed ±1
        assert_eq!(info.stage_extents[0].j, (-1, 1));
        let inp = info.field_requirements["inp"];
        assert_eq!(inp.i, (-2, 2));
        assert_eq!(inp.j, (-2, 2));
        let lap_req = info.field_requirements["lap"];
        assert_eq!(lap_req.i, (-1, 1));
    }

    #[test]
    fn api_writes_not_extended() {
        // out1 = in[+1]; out2 = out1[+1]  — out1 is an API field, so it is
        // computed only over the domain and still *requires* halo 1 of the
        // caller for out2's read.
        let comps = [ScheduledComputation {
            policy: IterationPolicy::Parallel,
            assigns: vec![
                asg("out1", Expr::field("inp", [1, 0, 0])),
                asg("out2", Expr::field("out1", [1, 0, 0])),
            ],
        }];
        let info = compute_extents(&comps, |_| false);
        assert_eq!(info.stage_extents[0], Extent::zero());
        assert_eq!(info.field_requirements["out1"].i, (0, 1));
        assert_eq!(info.field_requirements["inp"].i, (0, 1));
    }

    #[test]
    fn dead_temporary_gets_zero_extent() {
        let comps = [ScheduledComputation {
            policy: IterationPolicy::Parallel,
            assigns: vec![
                asg("unused", Expr::field("inp", [1, 0, 0])),
                asg("out", Expr::field("inp", [0, 0, 0])),
            ],
        }];
        let info = compute_extents(&comps, |n| n == "unused");
        assert_eq!(info.stage_extents[0], Extent::zero());
    }

    #[test]
    fn k_offsets_tracked() {
        let comps = [ScheduledComputation {
            policy: IterationPolicy::Forward,
            assigns: vec![asg("out", Expr::field("inp", [0, 0, -1]))],
        }];
        let info = compute_extents(&comps, |_| false);
        assert_eq!(info.field_requirements["inp"].k, (-1, 0));
    }

    #[test]
    fn k_requirement_interval_aware() {
        use crate::dsl::ast::LevelBound;
        // Reading b[0,0,-1] from interval(1, None) stays inside the domain:
        // no k-halo demanded of the caller.
        let iv = Interval::new(LevelBound::FromStart(1), LevelBound::FromEnd(0));
        let comps = [ScheduledComputation {
            policy: IterationPolicy::Forward,
            assigns: vec![(iv, Assign {
                target: "out".into(),
                value: Expr::field("b", [0, 0, -1]),
            })],
        }];
        let info = compute_extents(&comps, |_| false);
        assert_eq!(info.field_requirements["b"].k, (0, 0));
        // Reading b[0,0,1] from interval(0, -1) also stays inside.
        let iv2 = Interval::new(LevelBound::FromStart(0), LevelBound::FromEnd(1));
        let comps2 = [ScheduledComputation {
            policy: IterationPolicy::Backward,
            assigns: vec![(iv2, Assign {
                target: "out".into(),
                value: Expr::field("b", [0, 0, 1]),
            })],
        }];
        let info2 = compute_extents(&comps2, |_| false);
        assert_eq!(info2.field_requirements["b"].k, (0, 0));
    }

    #[test]
    fn chained_temporaries_accumulate() {
        // t1 over ±1 because t2 reads it at ±1; t2 over zero; in needs ±2.
        let comps = [ScheduledComputation {
            policy: IterationPolicy::Parallel,
            assigns: vec![
                asg("t1", lap("inp")),
                asg("t2", lap("t1")),
                asg("out", Expr::field("t2", [0, 0, 0])),
            ],
        }];
        let info = compute_extents(&comps, |n| n.starts_with('t'));
        assert_eq!(info.stage_extents[0].i, (-1, 1));
        assert_eq!(info.stage_extents[1], Extent::zero());
        assert_eq!(info.field_requirements["inp"].i, (-2, 2));
    }
}
