//! Compile-time semantic checks (paper §2.2):
//!
//! * In `PARALLEL` computations, self-assignment with a dependency in any
//!   direction is forbidden ("This is why line 15 ... reads from `in` and
//!   writes into `lap`").
//! * In `FORWARD`/`BACKWARD` computations, vertical offsets are validated
//!   against the iteration direction: a field written in the computation
//!   may only be read at levels already visited, and never with a
//!   horizontal offset at the current level (undefined within the parallel
//!   horizontal plane).
//! * Temporaries must be written before they are read (stage order).
//! * All fields of one stencil share a single element dtype.

use crate::dsl::ast::{IterationPolicy, StencilDef};
use crate::dsl::span::{CResult, CompileError};
use crate::ir::implir::{Assign, Stage};
use std::collections::HashSet;

/// A lowered computation: the policy plus its per-interval assignment list,
/// produced by the pipeline before scheduling.
pub struct LoweredComputation {
    pub policy: IterationPolicy,
    /// `(interval index within computation, assignment)` in program order.
    pub assigns: Vec<(crate::dsl::ast::Interval, Assign)>,
}

/// Check vertical-dependency rules within each computation.
pub fn check_dependencies(computations: &[LoweredComputation]) -> CResult<()> {
    for comp in computations {
        let written: HashSet<&str> =
            comp.assigns.iter().map(|(_, a)| a.target.as_str()).collect();
        for (_, a) in &comp.assigns {
            let reads = Stage::collect_reads(a);
            for (f, off) in &reads {
                let is_self = *f == a.target;
                let nonzero = *off != [0, 0, 0];
                match comp.policy {
                    IterationPolicy::Parallel => {
                        if is_self && nonzero {
                            return Err(CompileError::new(
                                "checks",
                                format!(
                                    "self-assignment of `{f}` with offset [{},{},{}] in a PARALLEL computation (undefined evaluation order; compute into a temporary instead)",
                                    off[0], off[1], off[2]
                                ),
                            ));
                        }
                    }
                    IterationPolicy::Forward | IterationPolicy::Backward => {
                        if !written.contains(f.as_str()) {
                            continue; // pure input: any offset is fine
                        }
                        let k = off[2];
                        let against_direction = match comp.policy {
                            IterationPolicy::Forward => k < 0,
                            IterationPolicy::Backward => k > 0,
                            IterationPolicy::Parallel => unreachable!(),
                        };
                        let ahead = match comp.policy {
                            IterationPolicy::Forward => k > 0,
                            IterationPolicy::Backward => k < 0,
                            IterationPolicy::Parallel => unreachable!(),
                        };
                        if ahead {
                            return Err(CompileError::new(
                                "checks",
                                format!(
                                    "`{f}` is written in this {} computation but read at k-offset {k}, a level not yet computed",
                                    comp.policy
                                ),
                            ));
                        }
                        if !against_direction && (off[0] != 0 || off[1] != 0) {
                            return Err(CompileError::new(
                                "checks",
                                format!(
                                    "`{f}` is written in this {} computation and read with horizontal offset [{},{}] at the current level (undefined within the parallel plane)",
                                    comp.policy, off[0], off[1]
                                ),
                            ));
                        }
                    }
                }
            }
        }
    }
    Ok(())
}

/// Check that temporaries are written before any read, at stage granularity
/// across the whole stencil. A same-stage self-read is permitted only in a
/// sequential computation with the k-offset strictly against the iteration
/// direction (reading the level computed on the previous sweep step).
pub fn check_temporaries_initialized(
    computations: &[LoweredComputation],
    temporaries: &[String],
) -> CResult<()> {
    let temps: HashSet<&str> = temporaries.iter().map(|s| s.as_str()).collect();
    let mut written: HashSet<&str> = HashSet::new();
    for comp in computations {
        for (_, a) in &comp.assigns {
            let reads = Stage::collect_reads(a);
            for (f, off) in &reads {
                if !temps.contains(f.as_str()) || written.contains(f.as_str()) {
                    continue;
                }
                // Not yet written by an earlier stage; a self-read against
                // the sweep direction in the same statement is legal past
                // the first level, which requires an earlier interval to
                // have initialized it — and none did. Always an error,
                // except the benign case of the statement defining it now
                // reading strictly backwards *after* some interval block
                // initialized it (handled by `written` above).
                let self_seq_read = *f == a.target
                    && match comp.policy {
                        IterationPolicy::Forward => off[2] < 0,
                        IterationPolicy::Backward => off[2] > 0,
                        IterationPolicy::Parallel => false,
                    };
                if !self_seq_read {
                    return Err(CompileError::new(
                        "checks",
                        format!("temporary `{f}` is read before it is written"),
                    ));
                }
            }
            if let Some(t) = temps.get(a.target.as_str()) {
                written.insert(t);
            }
        }
    }
    Ok(())
}

/// All fields (and scalars) of one stencil must share a dtype; backends and
/// the AOT artifacts are specialized per element type.
pub fn check_dtypes(def: &StencilDef) -> CResult<()> {
    let mut dtypes = def
        .fields
        .iter()
        .map(|f| f.dtype)
        .chain(def.scalars.iter().map(|s| s.dtype));
    if let Some(first) = dtypes.next() {
        if dtypes.any(|d| d != first) {
            return Err(CompileError::new(
                "checks",
                format!(
                    "stencil `{}` mixes element dtypes; all fields and scalars must share one",
                    def.name
                ),
            ));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsl::ast::{Expr, Interval};

    fn comp(
        policy: IterationPolicy,
        assigns: Vec<Assign>,
    ) -> LoweredComputation {
        LoweredComputation {
            policy,
            assigns: assigns.into_iter().map(|a| (Interval::full(), a)).collect(),
        }
    }

    fn asg(t: &str, v: Expr) -> Assign {
        Assign { target: t.into(), value: v }
    }

    #[test]
    fn parallel_self_offset_forbidden() {
        let c = comp(
            IterationPolicy::Parallel,
            vec![asg("a", Expr::field("a", [1, 0, 0]))],
        );
        assert!(check_dependencies(&[c]).is_err());
    }

    #[test]
    fn parallel_self_zero_offset_allowed() {
        let c = comp(
            IterationPolicy::Parallel,
            vec![asg(
                "a",
                Expr::binary(
                    crate::dsl::ast::BinOp::Mul,
                    Expr::field("a", [0, 0, 0]),
                    Expr::Float(2.0),
                ),
            )],
        );
        assert!(check_dependencies(&[c]).is_ok());
    }

    #[test]
    fn forward_backward_k_direction_enforced() {
        // FORWARD reading k+1 of a written field: error.
        let bad = comp(
            IterationPolicy::Forward,
            vec![asg("a", Expr::field("a", [0, 0, 1]))],
        );
        assert!(check_dependencies(&[bad]).is_err());
        // FORWARD reading k-1: fine.
        let good = comp(
            IterationPolicy::Forward,
            vec![asg("a", Expr::field("a", [0, 0, -1]))],
        );
        assert!(check_dependencies(&[good]).is_ok());
        // BACKWARD mirrored.
        let bad_b = comp(
            IterationPolicy::Backward,
            vec![asg("a", Expr::field("a", [0, 0, -1]))],
        );
        assert!(check_dependencies(&[bad_b]).is_err());
        let good_b = comp(
            IterationPolicy::Backward,
            vec![asg("a", Expr::field("a", [0, 0, 1]))],
        );
        assert!(check_dependencies(&[good_b]).is_ok());
    }

    #[test]
    fn sequential_horizontal_offset_on_written_field_forbidden() {
        let c = comp(
            IterationPolicy::Forward,
            vec![
                asg("t", Expr::field("x", [0, 0, 0])),
                asg("y", Expr::field("t", [1, 0, 0])),
            ],
        );
        assert!(check_dependencies(&[c]).is_err());
        // ... but allowed when combined with a k-offset against direction.
        let ok = comp(
            IterationPolicy::Forward,
            vec![
                asg("t", Expr::field("x", [0, 0, 0])),
                asg("y", Expr::field("t", [1, 0, -1])),
            ],
        );
        assert!(check_dependencies(&[ok]).is_ok());
    }

    #[test]
    fn pure_input_reads_unrestricted_in_sequential() {
        let c = comp(
            IterationPolicy::Forward,
            vec![asg("out", Expr::field("inp", [2, -1, 1]))],
        );
        assert!(check_dependencies(&[c]).is_ok());
    }

    #[test]
    fn temp_read_before_write_rejected() {
        let c = comp(
            IterationPolicy::Parallel,
            vec![
                asg("b", Expr::field("t", [0, 0, 0])),
                asg("t", Expr::field("a", [0, 0, 0])),
            ],
        );
        assert!(check_temporaries_initialized(&[c], &["t".to_string()]).is_err());
    }

    #[test]
    fn temp_write_then_read_ok() {
        let c = comp(
            IterationPolicy::Parallel,
            vec![
                asg("t", Expr::field("a", [0, 0, 0])),
                asg("b", Expr::field("t", [1, 0, 0])),
            ],
        );
        assert!(check_temporaries_initialized(&[c], &["t".to_string()]).is_ok());
    }

    #[test]
    fn dtype_mixing_rejected() {
        use crate::dsl::builder::*;
        use crate::dsl::ast::DType;
        let s = stencil("s")
            .field("a", DType::F64)
            .field("b", DType::F32)
            .computation(parallel().interval_full(|b| {
                b.assign("b", here("a"));
            }))
            .build()
            .unwrap();
        assert!(check_dtypes(&s).is_err());
    }
}
