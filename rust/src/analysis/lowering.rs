//! Control-flow lowering: point-wise `if/else` → guarded assignments.
//!
//! GTScript if/else has *per-point* semantics: at every point of the
//! iteration space the condition selects which branch's assignments apply.
//! We lower each assignment `t = v` under guard `g` to `t = g ? v : t`,
//! preserving program order. When a branch writes a field that the
//! condition reads, the condition is first materialized into a *mask
//! temporary* (`__mask_N`) so later guarded statements keep seeing the
//! entry value of the condition — the same mask-field strategy GT4Py's
//! analysis pipeline uses.

use crate::dsl::ast::{BinOp, Expr, Stmt, UnOp};
use crate::dsl::span::CResult;
use crate::ir::implir::Assign;
use std::collections::HashSet;

/// Lower a resolved statement tree into a flat assignment list.
/// Returns the assignments plus names of any generated mask temporaries.
pub fn lower_stmts(stmts: &[Stmt]) -> CResult<(Vec<Assign>, Vec<String>)> {
    let mut out = Vec::new();
    let mut masks = Vec::new();
    let mut counter = 0usize;
    lower_block(stmts, None, &mut out, &mut masks, &mut counter)?;
    Ok((out, masks))
}

fn lower_block(
    stmts: &[Stmt],
    guard: Option<&Expr>,
    out: &mut Vec<Assign>,
    masks: &mut Vec<String>,
    counter: &mut usize,
) -> CResult<()> {
    for s in stmts {
        match s {
            Stmt::Assign { target, value, .. } => {
                let value = match guard {
                    Some(g) => Expr::ternary(
                        g.clone(),
                        value.clone(),
                        Expr::field(target.clone(), [0, 0, 0]),
                    ),
                    None => value.clone(),
                };
                out.push(Assign { target: target.clone(), value });
            }
            Stmt::If { cond, then_body, else_body, .. } => {
                // Effective condition includes the enclosing guard.
                let full_cond = match guard {
                    Some(g) => Expr::binary(BinOp::And, g.clone(), cond.clone()),
                    None => cond.clone(),
                };
                // Materialize when any branch writes a field the condition
                // reads (entry-value semantics would otherwise break).
                let cond_reads = expr_fields(&full_cond);
                let mut branch_writes = Vec::new();
                super::resolve::collect_targets(then_body, &mut branch_writes);
                super::resolve::collect_targets(else_body, &mut branch_writes);
                let needs_mask =
                    branch_writes.iter().any(|w| cond_reads.contains(w.as_str()));
                let guard_expr = if needs_mask {
                    let mask = format!("__mask_{}", *counter);
                    *counter += 1;
                    out.push(Assign {
                        target: mask.clone(),
                        value: Expr::ternary(full_cond, Expr::Float(1.0), Expr::Float(0.0)),
                    });
                    masks.push(mask.clone());
                    Expr::binary(BinOp::Gt, Expr::field(mask, [0, 0, 0]), Expr::Float(0.5))
                } else {
                    full_cond
                };
                lower_block(then_body, Some(&guard_expr), out, masks, counter)?;
                if !else_body.is_empty() {
                    let neg = Expr::Unary { op: UnOp::Not, operand: Box::new(guard_expr) };
                    lower_block(else_body, Some(&neg), out, masks, counter)?;
                }
            }
        }
    }
    Ok(())
}

fn expr_fields(e: &Expr) -> HashSet<&str> {
    let mut set = HashSet::new();
    collect(e, &mut set);
    fn collect<'a>(e: &'a Expr, set: &mut HashSet<&'a str>) {
        match e {
            Expr::Field { name, .. } => {
                set.insert(name.as_str());
            }
            Expr::Unary { operand, .. } => collect(operand, set),
            Expr::Binary { lhs, rhs, .. } => {
                collect(lhs, set);
                collect(rhs, set);
            }
            Expr::Ternary { cond, then_e, else_e } => {
                collect(cond, set);
                collect(then_e, set);
                collect(else_e, set);
            }
            Expr::Call { args, .. } | Expr::Builtin { args, .. } => {
                for a in args {
                    collect(a, set);
                }
            }
            _ => {}
        }
    }
    set
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsl::ast::Span;

    fn assign(t: &str, v: Expr) -> Stmt {
        Stmt::Assign { target: t.into(), value: v, span: Span::default() }
    }

    fn iff(cond: Expr, then_body: Vec<Stmt>, else_body: Vec<Stmt>) -> Stmt {
        Stmt::If { cond, then_body, else_body, span: Span::default() }
    }

    fn agt(name: &str, v: f64) -> Expr {
        Expr::binary(BinOp::Gt, Expr::field(name, [0, 0, 0]), Expr::Float(v))
    }

    #[test]
    fn plain_assignments_pass_through() {
        let (lowered, masks) =
            lower_stmts(&[assign("b", Expr::field("a", [0, 0, 0]))]).unwrap();
        assert_eq!(lowered.len(), 1);
        assert!(masks.is_empty());
        assert_eq!(lowered[0].target, "b");
        assert!(matches!(lowered[0].value, Expr::Field { .. }));
    }

    #[test]
    fn if_lowered_to_guarded_select() {
        // if a > 0 { b = 1 } else { b = 2 }
        let (lowered, masks) = lower_stmts(&[iff(
            agt("a", 0.0),
            vec![assign("b", Expr::Float(1.0))],
            vec![assign("b", Expr::Float(2.0))],
        )])
        .unwrap();
        assert!(masks.is_empty());
        assert_eq!(lowered.len(), 2);
        // both lowered to ternaries writing b
        for a in &lowered {
            assert_eq!(a.target, "b");
            assert!(matches!(a.value, Expr::Ternary { .. }));
        }
    }

    #[test]
    fn mask_materialized_when_branch_writes_cond_field() {
        // if a > 0 { a = -a; b = a } — cond reads `a`, branch writes it.
        let (lowered, masks) = lower_stmts(&[iff(
            agt("a", 0.0),
            vec![
                assign("a", Expr::Unary {
                    op: UnOp::Neg,
                    operand: Box::new(Expr::field("a", [0, 0, 0])),
                }),
                assign("b", Expr::field("a", [0, 0, 0])),
            ],
            vec![],
        )])
        .unwrap();
        assert_eq!(masks.len(), 1);
        assert_eq!(lowered.len(), 3); // mask + two guarded assigns
        assert_eq!(lowered[0].target, masks[0]);
    }

    #[test]
    fn nested_ifs_conjoin_guards() {
        // if a > 0 { if b > 0 { c = 1 } }
        let (lowered, _) = lower_stmts(&[iff(
            agt("a", 0.0),
            vec![iff(agt("b", 0.0), vec![assign("c", Expr::Float(1.0))], vec![])],
            vec![],
        )])
        .unwrap();
        assert_eq!(lowered.len(), 1);
        let Expr::Ternary { cond, .. } = &lowered[0].value else { panic!() };
        assert!(matches!(**cond, Expr::Binary { op: BinOp::And, .. }));
    }

    #[test]
    fn guarded_assign_preserves_current_value_in_else_arm() {
        let (lowered, _) = lower_stmts(&[iff(
            agt("a", 0.0),
            vec![assign("b", Expr::Float(1.0))],
            vec![],
        )])
        .unwrap();
        let Expr::Ternary { else_e, .. } = &lowered[0].value else { panic!() };
        assert!(
            matches!(&**else_e, Expr::Field { name, offset: [0, 0, 0], .. } if name == "b")
        );
    }
}
