//! The analysis pipeline (paper Fig. 2): definition IR → implementation IR.

pub mod checks;
pub mod extents;
pub mod inline;
pub mod lowering;
pub mod pipeline;
pub mod resolve;

pub use pipeline::{
    analyze, analyze_opt, compile_source, compile_source_opt, fingerprint_ir,
    fingerprint_ir_with,
};
