//! The analysis pipeline (paper Fig. 2): definition IR → implementation IR.

pub mod checks;
pub mod extents;
pub mod inline;
pub mod lowering;
pub mod pipeline;
pub mod resolve;

pub use pipeline::{analyze, compile_source, fingerprint_ir};
