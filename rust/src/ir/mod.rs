//! Intermediate representations.
//!
//! * The *definition IR* is the AST itself ([`crate::dsl::ast`]), produced
//!   by either frontend.
//! * The *implementation IR* ([`implir`]) is the scheduled, lowered form the
//!   backends consume.
//! * [`canon`] provides the canonical serialization both the fingerprint
//!   cache and the IR tests rely on.

pub mod canon;
pub mod implir;

pub use implir::{
    Assign, Extent, FieldInfo, Intent, Multistage, Stage, StencilIr, StorageClass,
    TempField,
};
