//! Canonical serialization of IR trees.
//!
//! The paper's caching mechanism fingerprints stencil definitions "in such a
//! way that code reformatting would not trigger a new compilation". Our
//! canonical form serializes the *resolved* IR (spans dropped, formatting
//! long gone, externals folded), so two sources differing only in layout,
//! comments, or function factoring that inline to the same computation map
//! to the same canonical string.

use crate::dsl::ast::{Expr, Stmt, UnOp};
use crate::ir::implir::StencilIr;

/// Serialize an expression to a canonical, unambiguous prefix form.
pub fn canon_expr(e: &Expr, out: &mut String) {
    use std::fmt::Write as _;
    match e {
        Expr::Float(v) => {
            // Bit-exact float identity (avoids 0.1 display surprises).
            let _ = write!(out, "f{:016x}", v.to_bits());
        }
        Expr::Bool(b) => {
            let _ = write!(out, "b{}", if *b { 1 } else { 0 });
        }
        Expr::Name(n, _) => {
            let _ = write!(out, "n({n})");
        }
        Expr::Field { name, offset, .. } => {
            let _ = write!(out, "F({name},{},{},{})", offset[0], offset[1], offset[2]);
        }
        Expr::Scalar(n) => {
            let _ = write!(out, "s({n})");
        }
        Expr::External(n, _) => {
            let _ = write!(out, "x({n})");
        }
        Expr::Unary { op, operand } => {
            let _ = write!(out, "u{}(", match op {
                UnOp::Neg => "-",
                UnOp::Not => "!",
            });
            canon_expr(operand, out);
            out.push(')');
        }
        Expr::Binary { op, lhs, rhs } => {
            let _ = write!(out, "o{}(", op.symbol());
            canon_expr(lhs, out);
            out.push(',');
            canon_expr(rhs, out);
            out.push(')');
        }
        Expr::Ternary { cond, then_e, else_e } => {
            out.push_str("t(");
            canon_expr(cond, out);
            out.push(',');
            canon_expr(then_e, out);
            out.push(',');
            canon_expr(else_e, out);
            out.push(')');
        }
        Expr::Call { name, args, .. } => {
            let _ = write!(out, "c({name}");
            for a in args {
                out.push(',');
                canon_expr(a, out);
            }
            out.push(')');
        }
        Expr::Builtin { func, args } => {
            let _ = write!(out, "B({}", func.name());
            for a in args {
                out.push(',');
                canon_expr(a, out);
            }
            out.push(')');
        }
    }
}

/// Canonical form of a statement list.
pub fn canon_stmts(stmts: &[Stmt], out: &mut String) {
    for s in stmts {
        match s {
            Stmt::Assign { target, value, .. } => {
                out.push_str("A(");
                out.push_str(target);
                out.push(',');
                canon_expr(value, out);
                out.push_str(");");
            }
            Stmt::If { cond, then_body, else_body, .. } => {
                out.push_str("I(");
                canon_expr(cond, out);
                out.push_str("){");
                canon_stmts(then_body, out);
                out.push_str("}{");
                canon_stmts(else_body, out);
                out.push_str("};");
            }
        }
    }
}

/// Canonical serialization of a whole implementation IR, including the
/// optimizer-facing stage metadata (fusion groups, temporary storage
/// classes): two IRs that differ only in optimization decisions map to
/// *different* canonical strings, so cached artifacts from different opt
/// levels never collide. `opt_tag` is the pass configuration's canonical
/// string (empty for the unoptimized pipeline output).
pub fn canon_ir(ir: &StencilIr, opt_tag: &str) -> String {
    use std::fmt::Write as _;
    let mut s = String::with_capacity(1024);
    let _ = write!(s, "stencil {};", ir.name);
    if !opt_tag.is_empty() {
        let _ = write!(s, "opt[{opt_tag}];");
    }
    for f in &ir.fields {
        let _ = write!(s, "f {}:{};", f.name, f.dtype);
    }
    for sc in &ir.scalars {
        let _ = write!(s, "s {}:{};", sc.name, sc.dtype);
    }
    for (k, v) in &ir.externals {
        let _ = write!(s, "x {}={:016x};", k, v.to_bits());
    }
    for t in &ir.temporaries {
        let _ = write!(s, "t {}:{};", t.name, t.storage);
    }
    for ms in &ir.multistages {
        let _ = write!(s, "ms {};", ms.policy);
        for st in &ms.stages {
            let _ = write!(s, "st g{} {} {}=", st.fusion_group, st.interval, st.stmt.target);
            canon_expr(&st.stmt.value, &mut s);
            s.push(';');
        }
    }
    s
}

/// 64-bit FNV-1a — stable across platforms and runs, unlike `DefaultHasher`.
pub fn fnv1a64(data: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &b in data {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsl::parser::parse_expr;

    #[test]
    fn canon_is_formatting_insensitive() {
        let a = parse_expr("a  +  b * ( c )").unwrap();
        let b = parse_expr("a+b*c").unwrap();
        let (mut ca, mut cb) = (String::new(), String::new());
        canon_expr(&a, &mut ca);
        canon_expr(&b, &mut cb);
        assert_eq!(ca, cb);
    }

    #[test]
    fn canon_distinguishes_structure() {
        let a = parse_expr("(a + b) * c").unwrap();
        let b = parse_expr("a + b * c").unwrap();
        let (mut ca, mut cb) = (String::new(), String::new());
        canon_expr(&a, &mut ca);
        canon_expr(&b, &mut cb);
        assert_ne!(ca, cb);
    }

    #[test]
    fn canon_distinguishes_offsets_and_floats() {
        let a = parse_expr("phi[1,0,0] * 0.5").unwrap();
        let b = parse_expr("phi[0,1,0] * 0.5").unwrap();
        let c = parse_expr("phi[1,0,0] * 0.25").unwrap();
        let mut sa = String::new();
        let mut sb = String::new();
        let mut sc = String::new();
        canon_expr(&a, &mut sa);
        canon_expr(&b, &mut sb);
        canon_expr(&c, &mut sc);
        assert_ne!(sa, sb);
        assert_ne!(sa, sc);
    }

    #[test]
    fn fnv_known_values() {
        // FNV-1a 64 test vectors.
        assert_eq!(fnv1a64(b""), 0xcbf29ce484222325);
        assert_eq!(fnv1a64(b"a"), 0xaf63dc4c8601ec8c);
        assert_eq!(fnv1a64(b"foobar"), 0x85944171f73967e8);
    }
}
