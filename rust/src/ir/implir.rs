//! Implementation IR — the low-level representation the analysis pipeline
//! produces and all backends consume (paper Fig. 2: definition IR →
//! analysis → implementation IR → backend codegen).
//!
//! A stencil is a sequence of *multistages*, each with a vertical iteration
//! policy; a multistage is a sequence of *stages*, each a single point-wise
//! assignment over a vertical interval with a horizontal compute extent.
//! All if/else control flow has been lowered to point-wise selects, function
//! calls inlined, and externals folded to literals.

use crate::dsl::ast::{DType, Expr, Interval, IterationPolicy, Offset, ScalarDecl};
use std::collections::BTreeMap;
use std::fmt;

/// Inclusive per-axis halo extent: `lo <= 0 <= hi`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Extent {
    pub i: (i32, i32),
    pub j: (i32, i32),
    pub k: (i32, i32),
}

impl Extent {
    pub fn zero() -> Extent {
        Extent { i: (0, 0), j: (0, 0), k: (0, 0) }
    }

    /// Extent covering a single access offset.
    pub fn from_offset(off: Offset) -> Extent {
        Extent {
            i: (off[0].min(0), off[0].max(0)),
            j: (off[1].min(0), off[1].max(0)),
            k: (off[2].min(0), off[2].max(0)),
        }
    }

    /// Hull of two extents.
    pub fn union(self, other: Extent) -> Extent {
        Extent {
            i: (self.i.0.min(other.i.0), self.i.1.max(other.i.1)),
            j: (self.j.0.min(other.j.0), self.j.1.max(other.j.1)),
            k: (self.k.0.min(other.k.0), self.k.1.max(other.k.1)),
        }
    }

    /// Minkowski sum: extent required from a field read at `off` by a stage
    /// computing over `self`.
    pub fn translate(self, off: Offset) -> Extent {
        Extent {
            i: (self.i.0 + off[0].min(0).min(off[0]), self.i.1 + off[0].max(0).max(off[0])),
            j: (self.j.0 + off[1].min(0).min(off[1]), self.j.1 + off[1].max(0).max(off[1])),
            k: (self.k.0 + off[2].min(0).min(off[2]), self.k.1 + off[2].max(0).max(off[2])),
        }
    }

    /// Whether this extent is contained in `outer`.
    pub fn within(&self, outer: &Extent) -> bool {
        self.i.0 >= outer.i.0
            && self.i.1 <= outer.i.1
            && self.j.0 >= outer.j.0
            && self.j.1 <= outer.j.1
            && self.k.0 >= outer.k.0
            && self.k.1 <= outer.k.1
    }

    /// Max halo width on any horizontal axis (used for storage allocation).
    pub fn horizontal_halo(&self) -> usize {
        let m = (-self.i.0).max(self.i.1).max(-self.j.0).max(self.j.1);
        m.max(0) as usize
    }
}

impl fmt::Display for Extent {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "[{},{}]x[{},{}]x[{},{}]",
            self.i.0, self.i.1, self.j.0, self.j.1, self.k.0, self.k.1
        )
    }
}

/// Access intent of a field parameter, inferred by the analysis pipeline.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Intent {
    In,
    Out,
    InOut,
}

impl fmt::Display for Intent {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Intent::In => write!(f, "in"),
            Intent::Out => write!(f, "out"),
            Intent::InOut => write!(f, "inout"),
        }
    }
}

/// A field parameter with everything the backends/coordinator need.
#[derive(Debug, Clone, PartialEq)]
pub struct FieldInfo {
    pub name: String,
    pub dtype: DType,
    pub intent: Intent,
    /// Halo this stencil reads around the compute domain for this field.
    pub extent: Extent,
}

/// Where a temporary's values live at run time — decided by the optimizer
/// (`crate::opt::demote`), consumed by the backends.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum StorageClass {
    /// A full 3-D field storage covering the temporary's extent — the
    /// unoptimized default, and the only class the `debug` reference
    /// interpreter ever materializes.
    Field3D,
    /// Demoted, every access inside a single fused stage group and every
    /// read at offset `[0,0,0]`: the value is a pure per-point SSA register
    /// in the fused evaluator (no buffer at all); interpreting backends may
    /// still use a transient group-local buffer.
    Register,
    /// Demoted, every access inside a single fused stage group, reads have
    /// zero vertical offset but nonzero horizontal offsets: backends keep
    /// the values in a group-scoped scratch buffer (one plane per level in
    /// sequential multistages, the group region in PARALLEL ones) instead
    /// of allocating a field.
    Plane,
    /// Demoted sweep state (a k-cache): every access lives in one
    /// FORWARD/BACKWARD multistage, vertical offsets only ever look at
    /// already-computed levels (enforced by `analysis::checks`), so
    /// backends serve the values from a ring of recent level planes.
    /// Levels never written read as zeros, exactly like the
    /// zero-initialized field the temporary replaces.
    Ring,
}

impl fmt::Display for StorageClass {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            StorageClass::Field3D => write!(f, "field3d"),
            StorageClass::Register => write!(f, "register"),
            StorageClass::Plane => write!(f, "plane"),
            StorageClass::Ring => write!(f, "ring"),
        }
    }
}

/// A temporary (local) field, never observable outside the stencil.
#[derive(Debug, Clone, PartialEq)]
pub struct TempField {
    pub name: String,
    pub dtype: DType,
    /// Halo around the compute domain over which the temporary is computed.
    pub extent: Extent,
    /// Run-time storage class (see [`StorageClass`]).
    pub storage: StorageClass,
    /// For [`StorageClass::Ring`]: how many past level planes backends must
    /// retain (max absolute vertical read offset, at least 1). Stamped by
    /// `opt::demote` together with the class; 0 otherwise. Derived metadata
    /// — a pure function of the stage reads, so not part of the canonical
    /// form.
    pub ring_depth: i32,
}

/// A lowered assignment: `target[0,0,0] = value` with `value` free of
/// `Call`/`Name`/`External` nodes.
#[derive(Debug, Clone, PartialEq)]
pub struct Assign {
    pub target: String,
    pub value: Expr,
}

/// One stage: a single assignment applied point-wise over `interval`
/// (vertically) and the compute domain extended by `extent` (horizontally).
#[derive(Debug, Clone, PartialEq)]
pub struct Stage {
    pub stmt: Assign,
    pub interval: Interval,
    pub extent: Extent,
    /// `(field, offset)` pairs read by this stage (deduplicated).
    pub reads: Vec<(String, Offset)>,
    /// Fusion-group id: stages of one multistage sharing a group id execute
    /// as a unit (consecutively, same interval), which scopes the lifetime
    /// of [`StorageClass::Register`] temporaries. The analysis pipeline
    /// assigns every stage its own group; `crate::opt::fusion` merges them.
    pub fusion_group: usize,
}

impl Stage {
    pub fn collect_reads(stmt: &Assign) -> Vec<(String, Offset)> {
        let mut reads = Vec::new();
        stmt.value.visit_fields(&mut |name, off| {
            let key = (name.to_string(), off);
            if !reads.contains(&key) {
                reads.push(key);
            }
        });
        reads
    }
}

/// Stages sharing one vertical iteration policy, executed as a unit.
/// PARALLEL multistages iterate stage-outermost (each stage is applied over
/// its whole 3-D region before the next starts); FORWARD/BACKWARD iterate
/// k-outermost with the stages applied in order on each level.
#[derive(Debug, Clone, PartialEq)]
pub struct Multistage {
    pub policy: IterationPolicy,
    pub stages: Vec<Stage>,
}

/// The complete implementation IR for one stencil.
#[derive(Debug, Clone, PartialEq)]
pub struct StencilIr {
    pub name: String,
    pub fields: Vec<FieldInfo>,
    pub scalars: Vec<ScalarDecl>,
    pub temporaries: Vec<TempField>,
    pub multistages: Vec<Multistage>,
    /// External values this stencil was specialized with (part of identity).
    pub externals: BTreeMap<String, f64>,
    /// Formatting-insensitive identity of this IR (see `cache::fingerprint`).
    pub fingerprint: u64,
    /// Execution-strategy request from the optimizer configuration
    /// (`--opt-level 3`): backends that support it evaluate fusion groups
    /// with the fused loop-nest evaluator instead of materializing
    /// per-expression-node buffers. Semantics-neutral — backends without a
    /// fused path ignore it. Reflected in the fingerprint via the opt tag.
    pub fused: bool,
    /// Opt-in numeric relaxation (`--fast-math`): backends with a
    /// specialized tape path may contract `a * b ± c` into fused
    /// multiply-adds and commute the addition. *Not* semantics-neutral —
    /// results are tolerance-validated instead of bitwise — so, unlike
    /// scheduling knobs, it participates in the opt tag and therefore the
    /// fingerprint: exact and fast-math artifacts never share a cache
    /// slot. Backends without an FMA-specialized path ignore it and stay
    /// exact.
    pub fast_math: bool,
}

impl StencilIr {
    pub fn field(&self, name: &str) -> Option<&FieldInfo> {
        self.fields.iter().find(|f| f.name == name)
    }

    pub fn temporary(&self, name: &str) -> Option<&TempField> {
        self.temporaries.iter().find(|t| t.name == name)
    }

    pub fn is_temporary(&self, name: &str) -> bool {
        self.temporary(name).is_some()
    }

    /// The stencil's uniform element dtype. `analysis::check_dtypes`
    /// guarantees every field, scalar and temporary shares one dtype, so
    /// the first field's dtype is the stencil's (f64 for the degenerate
    /// field-less case).
    pub fn dtype(&self) -> DType {
        self.fields.first().map(|f| f.dtype).unwrap_or(DType::F64)
    }

    pub fn num_stages(&self) -> usize {
        self.multistages.iter().map(|m| m.stages.len()).sum()
    }

    /// Hull of all field halo extents — the minimum storage halo the caller
    /// must provide around the compute domain.
    pub fn max_field_extent(&self) -> Extent {
        self.fields.iter().fold(Extent::zero(), |acc, f| acc.union(f.extent))
    }

    /// Pretty multi-line dump, used by `repro inspect`.
    pub fn dump(&self) -> String {
        use std::fmt::Write as _;
        let mut s = String::new();
        let _ = writeln!(s, "stencil {} (fingerprint {:016x})", self.name, self.fingerprint);
        for f in &self.fields {
            let _ = writeln!(s, "  field {}: {} {} extent {}", f.name, f.dtype, f.intent, f.extent);
        }
        for sc in &self.scalars {
            let _ = writeln!(s, "  scalar {}: {}", sc.name, sc.dtype);
        }
        for t in &self.temporaries {
            let _ = writeln!(
                s,
                "  temp {}: {} extent {} [{}]",
                t.name, t.dtype, t.extent, t.storage
            );
        }
        for (mi, ms) in self.multistages.iter().enumerate() {
            let _ = writeln!(s, "  multistage {} {}", mi, ms.policy);
            for (si, st) in ms.stages.iter().enumerate() {
                let _ = writeln!(
                    s,
                    "    stage {} {} extent {} group {} -> {}",
                    si, st.interval, st.extent, st.fusion_group, st.stmt.target
                );
            }
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn extent_union_and_translate() {
        let a = Extent { i: (-1, 1), j: (0, 0), k: (0, 0) };
        let b = Extent { i: (0, 2), j: (-1, 0), k: (0, 1) };
        let u = a.union(b);
        assert_eq!(u, Extent { i: (-1, 2), j: (-1, 0), k: (0, 1) });

        // A stage computing over extent a that reads f at offset (1, -1, 0)
        // requires f over a wider extent.
        let t = a.translate([1, -1, 0]);
        assert_eq!(t, Extent { i: (-1, 2), j: (-1, 0), k: (0, 0) });
    }

    #[test]
    fn extent_from_offset() {
        assert_eq!(
            Extent::from_offset([-2, 3, 0]),
            Extent { i: (-2, 0), j: (0, 3), k: (0, 0) }
        );
        assert_eq!(Extent::from_offset([0, 0, 0]), Extent::zero());
    }

    #[test]
    fn within_and_halo() {
        let inner = Extent { i: (-1, 1), j: (-1, 1), k: (0, 0) };
        let outer = Extent { i: (-2, 2), j: (-1, 1), k: (0, 0) };
        assert!(inner.within(&outer));
        assert!(!outer.within(&inner));
        assert_eq!(outer.horizontal_halo(), 2);
    }

    #[test]
    fn translate_zero_is_identity() {
        let a = Extent { i: (-3, 2), j: (-1, 4), k: (0, 0) };
        assert_eq!(a.translate([0, 0, 0]), a);
    }
}
