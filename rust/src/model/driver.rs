//! The model driver: time stepping, halo management, diagnostics.
//!
//! The three physics stencils are bound **once** at model construction
//! ([`crate::coordinator::BoundInvocation`]): the full storage validation
//! runs a single time, and every `step()` afterwards is the cheap
//! re-check-shapes path — the driver-composition style compiled stencil
//! objects exist for. The phi/out double buffer swap is safe under
//! bind-once semantics because both storages share one geometry; a
//! reallocation with a different shape would be rejected with a re-bind
//! error.

use super::grid::{gaussian_blob, periodic_halo_update};
use crate::coordinator::{BoundInvocation, Coordinator, Stencil};
use crate::dsl::ast::DType;
use crate::opt::ExecOptions;
use crate::storage::{Storage, StorageInfo};
use anyhow::Result;
use std::time::{Duration, Instant};

/// Model configuration.
#[derive(Debug, Clone)]
pub struct ModelConfig {
    pub domain: [usize; 3],
    /// Constant horizontal winds (grid cells per unit time).
    pub u: f64,
    pub v: f64,
    /// Vertical velocity amplitude.
    pub w_amp: f64,
    /// Horizontal diffusion coefficient (flux-limited hdiff weight).
    pub diffusion_coeff: f64,
    pub dt: f64,
    pub dx: f64,
    pub dy: f64,
    pub dz: f64,
    /// Backend every stencil runs on.
    pub backend: String,
    /// Execution options for every compiled stencil: opt level and
    /// fast-math select the artifacts, sharding and tier schedule the
    /// invocations (the trajectory is bitwise identical at any plan/tier).
    pub exec: ExecOptions,
    /// Run-time storage checks (bind-time validation; per-step shape
    /// re-checks). Disable for the Fig. 3 dashed-line configuration.
    pub checks: bool,
}

impl Default for ModelConfig {
    fn default() -> Self {
        ModelConfig {
            domain: [32, 32, 8],
            u: 1.0,
            v: 0.5,
            w_amp: 0.2,
            diffusion_coeff: 0.05,
            dt: 0.2,
            dx: 1.0,
            dy: 1.0,
            dz: 1.0,
            backend: "vector".to_string(),
            exec: ExecOptions::default(),
            checks: true,
        }
    }
}

/// Per-step diagnostics.
#[derive(Debug, Clone, Copy)]
pub struct StepDiagnostics {
    pub step: usize,
    /// Total tracer mass over the domain (should be ~conserved).
    pub mass: f64,
    pub min: f64,
    pub max: f64,
    pub wall: Duration,
}

/// The composed model.
pub struct IsentropicModel {
    pub config: ModelConfig,
    coord: Coordinator,
    /// Invocations bound once at construction, reused every step.
    advect: BoundInvocation,
    hdiff: BoundInvocation,
    vadv: BoundInvocation,
    /// Tracer field (with hdiff halo).
    pub phi: Storage,
    /// Scratch for stencil outputs.
    out: Storage,
    /// hdiff coefficient field.
    coeff: Storage,
    /// Vertical wind field.
    w: Storage,
    step_count: usize,
}

impl IsentropicModel {
    pub fn new(config: ModelConfig) -> Result<IsentropicModel> {
        let mut coord = Coordinator::with_exec_options(config.exec);
        coord.checks_enabled = config.checks;
        let advect: Stencil = coord.stencil_library("upwind_advect", &config.backend)?;
        let hdiff: Stencil = coord.stencil_library("hdiff", &config.backend)?;
        let vadv: Stencil = coord.stencil_library("vadv", &config.backend)?;
        let domain = config.domain;
        // A single halo-3 allocation satisfies every stencil in the suite
        // (hdiff needs 2, upwind needs 1).
        let halo = 3;
        let ci = domain[0] as f64 / 2.0;
        let cj = domain[1] as f64 / 2.0;
        let sigma = domain[0] as f64 / 8.0;
        // An `exec.dtype` override recompiles every stencil at that
        // element type, and bind-time validation demands matching
        // storages — so the model's allocations follow the knob.
        let retype = |s: Storage| -> Storage {
            match config.exec.dtype {
                Some(dt) if dt != s.dtype() => s.cast(dt),
                _ => s,
            }
        };
        let phi = retype(gaussian_blob(domain, halo, ci, cj, sigma));
        let out = retype(Storage::with_horizontal_halo(domain, halo));
        let mut coeff = retype(Storage::with_horizontal_halo(domain, halo));
        coeff.fill(config.diffusion_coeff);
        // Gentle vertically-sheared updraft.
        let w = retype(Storage::from_fn(domain, 0, |_, _, k| {
            config.w_amp * (k as f64 / domain[2].max(1) as f64 - 0.5)
        }));

        // Bind once: full validation here; step() only re-checks shapes.
        // phi and out share a geometry, so the per-step double-buffer swap
        // is compatible with the bound snapshots.
        let advect = advect
            .bind()
            .field("phi", &phi)
            .field("out", &out)
            .scalar("u", config.u)
            .scalar("v", config.v)
            .scalar("dtdx", config.dt / config.dx)
            .scalar("dtdy", config.dt / config.dy)
            .domain(domain)
            .finish()?;
        let hdiff = hdiff
            .bind()
            .field("in_phi", &phi)
            .field("coeff", &coeff)
            .field("out_phi", &out)
            .domain(domain)
            .finish()?;
        let vadv = vadv
            .bind()
            .field("phi", &phi)
            .field("w", &w)
            .scalar("dtdz", config.dt / config.dz)
            .domain(domain)
            .finish()?;

        Ok(IsentropicModel {
            config,
            coord,
            advect,
            hdiff,
            vadv,
            phi,
            out,
            coeff,
            w,
            step_count: 0,
        })
    }

    /// Advance one time step; returns diagnostics.
    pub fn step(&mut self) -> Result<StepDiagnostics> {
        let t0 = Instant::now();

        // `config` is public and was historically re-read every step
        // (adaptive time-stepping mutates it between steps): refresh the
        // bound scalars — a few name lookups, no storage re-validation.
        let cfg = self.config.clone();
        self.advect.set_scalar("u", cfg.u)?;
        self.advect.set_scalar("v", cfg.v)?;
        self.advect.set_scalar("dtdx", cfg.dt / cfg.dx)?;
        self.advect.set_scalar("dtdy", cfg.dt / cfg.dy)?;
        self.vadv.set_scalar("dtdz", cfg.dt / cfg.dz)?;

        // (1) horizontal upwind advection: phi -> out
        periodic_halo_update(&mut self.phi);
        self.advect.run(&mut [&mut self.phi, &mut self.out])?;
        std::mem::swap(&mut self.phi, &mut self.out);

        // (2) flux-limited horizontal diffusion: phi -> out
        periodic_halo_update(&mut self.phi);
        self.hdiff
            .run(&mut [&mut self.phi, &mut self.coeff, &mut self.out])?;
        std::mem::swap(&mut self.phi, &mut self.out);

        // (3) implicit vertical advection: phi in place
        // (vadv needs no horizontal halo; phi is reused directly.)
        self.vadv.run(&mut [&mut self.phi, &mut self.w])?;

        self.step_count += 1;
        let (mass, min, max) = self.diagnose();
        Ok(StepDiagnostics {
            step: self.step_count,
            mass,
            min,
            max,
            wall: t0.elapsed(),
        })
    }

    /// Run `n` steps, returning the last diagnostics.
    pub fn run(&mut self, n: usize) -> Result<Vec<StepDiagnostics>> {
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(self.step()?);
        }
        Ok(out)
    }

    fn diagnose(&self) -> (f64, f64, f64) {
        let [ni, nj, nk] = self.config.domain;
        let mut mass = 0.0;
        let mut min = f64::INFINITY;
        let mut max = f64::NEG_INFINITY;
        for i in 0..ni as i64 {
            for j in 0..nj as i64 {
                for k in 0..nk as i64 {
                    let v = self.phi.get(i, j, k);
                    mass += v;
                    min = min.min(v);
                    max = max.max(v);
                }
            }
        }
        (mass, min, max)
    }

    pub fn coordinator(&self) -> &Coordinator {
        &self.coord
    }

    /// Clone the tracer field (for cross-backend comparisons).
    pub fn phi_snapshot(&self) -> Storage {
        let mut s = Storage::zeros(StorageInfo::new(self.config.domain, [(0, 0); 3]));
        let [ni, nj, nk] = self.config.domain;
        for i in 0..ni as i64 {
            for j in 0..nj as i64 {
                for k in 0..nk as i64 {
                    s.set(i, j, k, self.phi.get(i, j, k));
                }
            }
        }
        s
    }
}

/// One row of a [`precision_sweep`]: the f32-vs-f64 relative L2 error of
/// a stencil (or of the composed trajectory) against its tolerance.
#[derive(Debug, Clone)]
pub struct PrecisionReport {
    /// Stencil name, or `model(N steps)` for the composed trajectory.
    pub stencil: String,
    /// Relative L2 norm of the f32 result against the f64 reference.
    pub rel_l2: f64,
    /// Acceptance threshold for this stencil.
    pub tolerance: f64,
}

impl PrecisionReport {
    pub fn within(&self) -> bool {
        self.rel_l2 <= self.tolerance
    }
}

/// Per-stencil f32-vs-f64 tolerances on a single application to the
/// Gaussian-blob initial condition. All three operators are pointwise
/// stable (no cancellation-dominated reductions), so one application
/// stays within a few hundred ulps of f32 epsilon; the composed
/// trajectory accumulates roundoff once per operator per step.
const SWEEP_STENCILS: [(&str, f64); 3] =
    [("upwind_advect", 1e-5), ("hdiff", 1e-5), ("vadv", 1e-5)];

/// Per-√step tolerance for the composed model trajectory: roundoff
/// accumulates as a random walk, so the acceptance threshold is
/// `SWEEP_TRAJECTORY_TOL * sqrt(steps)`.
const SWEEP_TRAJECTORY_TOL: f64 = 5e-5;

/// Run the model suite at f32 and at f64 and report relative-error
/// norms: one single-application row per library stencil (each checked
/// against a per-stencil tolerance) plus one row for the composed
/// trajectory after `steps` steps. Any `exec.dtype` already present in
/// `config` is overridden by the sweep's own precision pair; every
/// other knob (opt level, tier, sharding, fast-math) is honored, so the
/// sweep measures precision alone.
pub fn precision_sweep(config: &ModelConfig, steps: usize) -> Result<Vec<PrecisionReport>> {
    let mut reports = Vec::new();
    for (name, tolerance) in SWEEP_STENCILS {
        let lo = apply_once(config, DType::F32, name)?;
        let hi = apply_once(config, DType::F64, name)?;
        reports.push(PrecisionReport {
            stencil: name.to_string(),
            rel_l2: lo.rel_l2_error(&hi),
            tolerance,
        });
    }
    let at = |dt: DType| ModelConfig {
        exec: config.exec.with_dtype(Some(dt)),
        ..config.clone()
    };
    let mut lo = IsentropicModel::new(at(DType::F32))?;
    let mut hi = IsentropicModel::new(at(DType::F64))?;
    lo.run(steps)?;
    hi.run(steps)?;
    reports.push(PrecisionReport {
        stencil: format!("model({steps} steps)"),
        rel_l2: lo.phi_snapshot().rel_l2_error(&hi.phi_snapshot()),
        tolerance: SWEEP_TRAJECTORY_TOL * (steps.max(1) as f64).sqrt(),
    });
    Ok(reports)
}

/// Apply one library stencil once to the model's initial condition at
/// the given precision and return the (dtype-native) result field.
fn apply_once(config: &ModelConfig, dtype: DType, name: &str) -> Result<Storage> {
    let mut coord = Coordinator::with_exec_options(config.exec.with_dtype(Some(dtype)));
    coord.checks_enabled = config.checks;
    let stencil: Stencil = coord.stencil_library(name, &config.backend)?;
    let domain = config.domain;
    let halo = 3;
    let ci = domain[0] as f64 / 2.0;
    let cj = domain[1] as f64 / 2.0;
    let sigma = domain[0] as f64 / 8.0;
    let mut phi = gaussian_blob(domain, halo, ci, cj, sigma).cast(dtype);
    let mut out = Storage::with_horizontal_halo(domain, halo).cast(dtype);
    match name {
        "upwind_advect" => {
            let mut bound = stencil
                .bind()
                .field("phi", &phi)
                .field("out", &out)
                .scalar("u", config.u)
                .scalar("v", config.v)
                .scalar("dtdx", config.dt / config.dx)
                .scalar("dtdy", config.dt / config.dy)
                .domain(domain)
                .finish()?;
            bound.run(&mut [&mut phi, &mut out])?;
            Ok(out)
        }
        "hdiff" => {
            let mut coeff = Storage::with_horizontal_halo(domain, halo).cast(dtype);
            coeff.fill(config.diffusion_coeff);
            let mut bound = stencil
                .bind()
                .field("in_phi", &phi)
                .field("coeff", &coeff)
                .field("out_phi", &out)
                .domain(domain)
                .finish()?;
            bound.run(&mut [&mut phi, &mut coeff, &mut out])?;
            Ok(out)
        }
        "vadv" => {
            let mut w = Storage::from_fn(domain, 0, |_, _, k| {
                config.w_amp * (k as f64 / domain[2].max(1) as f64 - 0.5)
            })
            .cast(dtype);
            let mut bound = stencil
                .bind()
                .field("phi", &phi)
                .field("w", &w)
                .scalar("dtdz", config.dt / config.dz)
                .domain(domain)
                .finish()?;
            bound.run(&mut [&mut phi, &mut w])?;
            Ok(phi)
        }
        other => anyhow::bail!("precision sweep has no harness for stencil `{other}`"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_config(backend: &str) -> ModelConfig {
        ModelConfig {
            domain: [12, 12, 4],
            backend: backend.to_string(),
            ..ModelConfig::default()
        }
    }

    #[test]
    fn model_runs_and_stays_stable() {
        let mut m = IsentropicModel::new(small_config("vector")).unwrap();
        let diags = m.run(10).unwrap();
        let last = diags.last().unwrap();
        assert_eq!(last.step, 10);
        assert!(last.max.is_finite());
        assert!(last.max <= 1.5, "blew up: max {}", last.max);
        assert!(last.min >= -0.5);
    }

    #[test]
    fn mass_approximately_conserved_without_diffusion_loss() {
        // Upwind + periodic BCs conserve mass exactly; limited hdiff and
        // implicit vadv conserve it approximately.
        let mut cfg = small_config("vector");
        cfg.diffusion_coeff = 0.02;
        // Advective-form vertical advection is not exactly conservative
        // under shear; keep w small so the check isolates the horizontal
        // operators (which are conservative in flux form).
        cfg.w_amp = 0.02;
        let mut m = IsentropicModel::new(cfg).unwrap();
        let before = m.phi_snapshot().domain_sum();
        let diags = m.run(20).unwrap();
        let after = diags.last().unwrap().mass;
        let rel = ((after - before) / before).abs();
        assert!(rel < 0.05, "mass drift {rel}");
    }

    #[test]
    fn backends_agree_on_model_trajectory() {
        let mut md = IsentropicModel::new(small_config("debug")).unwrap();
        let mut mv = IsentropicModel::new(small_config("vector")).unwrap();
        md.run(5).unwrap();
        mv.run(5).unwrap();
        let d = md.phi_snapshot();
        let v = mv.phi_snapshot();
        assert!(d.max_abs_diff(&v) < 1e-12);
    }

    #[test]
    fn sharded_model_trajectory_is_bitwise_identical() {
        // The whole model loop (advect + hdiff + vadv, double-buffer
        // swaps included) under intra-call sharding must reproduce the
        // serial trajectory exactly. The domain is big enough that
        // Threads(2) really shards.
        let mut serial = IsentropicModel::new(small_config("vector")).unwrap();
        let mut sharded = IsentropicModel::new(ModelConfig {
            exec: ExecOptions::default().with_sharding(crate::backend::shard::Sharding::Threads(2)),
            ..small_config("vector")
        })
        .unwrap();
        serial.run(6).unwrap();
        sharded.run(6).unwrap();
        assert_eq!(
            serial.phi_snapshot().max_abs_diff(&sharded.phi_snapshot()),
            0.0,
            "sharded model trajectory diverged"
        );
        let t = sharded.coordinator().metrics.get("hdiff", "vector").unwrap();
        assert_eq!(t.max_threads, 2, "effective thread count must be recorded");
    }

    #[test]
    fn config_mutations_apply_between_steps() {
        // `config` is public; scalar changes after construction must keep
        // taking effect (the invocations refresh their scalars per step).
        let mut a = IsentropicModel::new(small_config("vector")).unwrap();
        let mut b = IsentropicModel::new(ModelConfig {
            dt: 0.05,
            ..small_config("vector")
        })
        .unwrap();
        b.config.dt = a.config.dt;
        a.run(4).unwrap();
        b.run(4).unwrap();
        assert_eq!(a.phi_snapshot().max_abs_diff(&b.phi_snapshot()), 0.0);
    }

    #[test]
    fn bind_once_amortizes_validation() {
        // After construction (which pays the one full validation per
        // stencil), per-step check time is the shape re-check only —
        // the metrics' first-call attribution makes this visible.
        let mut m = IsentropicModel::new(small_config("vector")).unwrap();
        m.run(8).unwrap();
        let t = m.coordinator().metrics.get("hdiff", "vector").unwrap();
        assert_eq!(t.calls, 8);
    }

    #[test]
    fn f32_model_allocates_f32_and_stays_stable() {
        let mut cfg = small_config("vector");
        cfg.exec = cfg.exec.with_dtype(Some(DType::F32));
        let mut m = IsentropicModel::new(cfg).unwrap();
        assert_eq!(m.phi.dtype(), DType::F32);
        let diags = m.run(5).unwrap();
        let last = diags.last().unwrap();
        assert!(last.max.is_finite());
        assert!(last.max <= 1.5, "f32 model blew up: max {}", last.max);
    }

    #[test]
    fn precision_sweep_separates_f32_from_f64_within_tolerance() {
        let cfg = small_config("vector");
        let reports = precision_sweep(&cfg, 5).unwrap();
        assert_eq!(reports.len(), SWEEP_STENCILS.len() + 1);
        for r in &reports {
            assert!(
                r.within(),
                "{} rel_l2 {} exceeds tolerance {}",
                r.stencil,
                r.rel_l2,
                r.tolerance
            );
        }
        // The trajectory row must show *genuine* single-precision
        // arithmetic: if f32 silently widened to f64 the error would be
        // exactly zero.
        let traj = reports.last().unwrap();
        assert!(
            traj.rel_l2 > 0.0,
            "f32 trajectory bitwise-matched f64 — storage silently widened"
        );
    }

    #[test]
    fn disabled_checks_model_still_runs() {
        let mut cfg = small_config("vector");
        cfg.checks = false;
        let mut m = IsentropicModel::new(cfg).unwrap();
        let d = m.run(3).unwrap();
        assert_eq!(d.last().unwrap().step, 3);
        let t = m.coordinator().metrics.get("hdiff", "vector").unwrap();
        assert_eq!(t.checks, Duration::ZERO);
    }
}
