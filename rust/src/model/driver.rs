//! The model driver: time stepping, halo management, diagnostics.

use super::grid::{gaussian_blob, periodic_halo_update};
use crate::coordinator::Coordinator;
use crate::storage::{Storage, StorageInfo};
use anyhow::Result;
use std::time::{Duration, Instant};

/// Model configuration.
#[derive(Debug, Clone)]
pub struct ModelConfig {
    pub domain: [usize; 3],
    /// Constant horizontal winds (grid cells per unit time).
    pub u: f64,
    pub v: f64,
    /// Vertical velocity amplitude.
    pub w_amp: f64,
    /// Horizontal diffusion coefficient (flux-limited hdiff weight).
    pub diffusion_coeff: f64,
    pub dt: f64,
    pub dx: f64,
    pub dy: f64,
    pub dz: f64,
    /// Backend every stencil runs on.
    pub backend: String,
    /// Optimization level for every compiled stencil.
    pub opt_level: crate::opt::OptLevel,
}

impl Default for ModelConfig {
    fn default() -> Self {
        ModelConfig {
            domain: [32, 32, 8],
            u: 1.0,
            v: 0.5,
            w_amp: 0.2,
            diffusion_coeff: 0.05,
            dt: 0.2,
            dx: 1.0,
            dy: 1.0,
            dz: 1.0,
            backend: "vector".to_string(),
            opt_level: crate::opt::OptLevel::O2,
        }
    }
}

/// Per-step diagnostics.
#[derive(Debug, Clone, Copy)]
pub struct StepDiagnostics {
    pub step: usize,
    /// Total tracer mass over the domain (should be ~conserved).
    pub mass: f64,
    pub min: f64,
    pub max: f64,
    pub wall: Duration,
}

/// The composed model.
pub struct IsentropicModel {
    pub config: ModelConfig,
    coord: Coordinator,
    fp_advect: u64,
    fp_hdiff: u64,
    fp_vadv: u64,
    /// Tracer field (with hdiff halo).
    pub phi: Storage,
    /// Scratch for stencil outputs.
    out: Storage,
    /// hdiff coefficient field.
    coeff: Storage,
    /// Vertical wind field.
    w: Storage,
    step_count: usize,
}

impl IsentropicModel {
    pub fn new(config: ModelConfig) -> Result<IsentropicModel> {
        let mut coord = Coordinator::with_opt_level(config.opt_level);
        let fp_advect = coord.compile_library("upwind_advect")?;
        let fp_hdiff = coord.compile_library("hdiff")?;
        let fp_vadv = coord.compile_library("vadv")?;
        let domain = config.domain;
        // A single halo-3 allocation satisfies every stencil in the suite
        // (hdiff needs 2, upwind needs 1).
        let halo = 3;
        let ci = domain[0] as f64 / 2.0;
        let cj = domain[1] as f64 / 2.0;
        let sigma = domain[0] as f64 / 8.0;
        let phi = gaussian_blob(domain, halo, ci, cj, sigma);
        let out = Storage::with_horizontal_halo(domain, halo);
        let mut coeff = Storage::with_horizontal_halo(domain, halo);
        coeff.fill(config.diffusion_coeff);
        // Gentle vertically-sheared updraft.
        let w = Storage::from_fn(domain, 0, |_, _, k| {
            config.w_amp * (k as f64 / domain[2].max(1) as f64 - 0.5)
        });
        Ok(IsentropicModel {
            config,
            coord,
            fp_advect,
            fp_hdiff,
            fp_vadv,
            phi,
            out,
            coeff,
            w,
            step_count: 0,
        })
    }

    /// Advance one time step; returns diagnostics.
    pub fn step(&mut self) -> Result<StepDiagnostics> {
        let t0 = Instant::now();
        let cfg = self.config.clone();
        let domain = cfg.domain;
        let backend = cfg.backend.as_str();

        // (1) horizontal upwind advection: phi -> out
        periodic_halo_update(&mut self.phi);
        {
            let mut refs: Vec<(&str, &mut Storage)> =
                vec![("phi", &mut self.phi), ("out", &mut self.out)];
            self.coord.run(
                self.fp_advect,
                backend,
                &mut refs,
                &[
                    ("u", cfg.u),
                    ("v", cfg.v),
                    ("dtdx", cfg.dt / cfg.dx),
                    ("dtdy", cfg.dt / cfg.dy),
                ],
                domain,
            )?;
        }
        std::mem::swap(&mut self.phi, &mut self.out);

        // (2) flux-limited horizontal diffusion: phi -> out
        periodic_halo_update(&mut self.phi);
        {
            let mut refs: Vec<(&str, &mut Storage)> = vec![
                ("in_phi", &mut self.phi),
                ("coeff", &mut self.coeff),
                ("out_phi", &mut self.out),
            ];
            self.coord
                .run(self.fp_hdiff, backend, &mut refs, &[], domain)?;
        }
        std::mem::swap(&mut self.phi, &mut self.out);

        // (3) implicit vertical advection: phi in place
        {
            // vadv needs no horizontal halo; reuse phi directly.
            let mut refs: Vec<(&str, &mut Storage)> =
                vec![("phi", &mut self.phi), ("w", &mut self.w)];
            self.coord.run(
                self.fp_vadv,
                backend,
                &mut refs,
                &[("dtdz", cfg.dt / cfg.dz)],
                domain,
            )?;
        }

        self.step_count += 1;
        let (mass, min, max) = self.diagnose();
        Ok(StepDiagnostics {
            step: self.step_count,
            mass,
            min,
            max,
            wall: t0.elapsed(),
        })
    }

    /// Run `n` steps, returning the last diagnostics.
    pub fn run(&mut self, n: usize) -> Result<Vec<StepDiagnostics>> {
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(self.step()?);
        }
        Ok(out)
    }

    fn diagnose(&self) -> (f64, f64, f64) {
        let [ni, nj, nk] = self.config.domain;
        let mut mass = 0.0;
        let mut min = f64::INFINITY;
        let mut max = f64::NEG_INFINITY;
        for i in 0..ni as i64 {
            for j in 0..nj as i64 {
                for k in 0..nk as i64 {
                    let v = self.phi.get(i, j, k);
                    mass += v;
                    min = min.min(v);
                    max = max.max(v);
                }
            }
        }
        (mass, min, max)
    }

    pub fn coordinator(&self) -> &Coordinator {
        &self.coord
    }

    /// Clone the tracer field (for cross-backend comparisons).
    pub fn phi_snapshot(&self) -> Storage {
        let mut s = Storage::zeros(StorageInfo::new(self.config.domain, [(0, 0); 3]));
        let [ni, nj, nk] = self.config.domain;
        for i in 0..ni as i64 {
            for j in 0..nj as i64 {
                for k in 0..nk as i64 {
                    s.set(i, j, k, self.phi.get(i, j, k));
                }
            }
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_config(backend: &str) -> ModelConfig {
        ModelConfig {
            domain: [12, 12, 4],
            backend: backend.to_string(),
            ..ModelConfig::default()
        }
    }

    #[test]
    fn model_runs_and_stays_stable() {
        let mut m = IsentropicModel::new(small_config("vector")).unwrap();
        let diags = m.run(10).unwrap();
        let last = diags.last().unwrap();
        assert_eq!(last.step, 10);
        assert!(last.max.is_finite());
        assert!(last.max <= 1.5, "blew up: max {}", last.max);
        assert!(last.min >= -0.5);
    }

    #[test]
    fn mass_approximately_conserved_without_diffusion_loss() {
        // Upwind + periodic BCs conserve mass exactly; limited hdiff and
        // implicit vadv conserve it approximately.
        let mut cfg = small_config("vector");
        cfg.diffusion_coeff = 0.02;
        // Advective-form vertical advection is not exactly conservative
        // under shear; keep w small so the check isolates the horizontal
        // operators (which are conservative in flux form).
        cfg.w_amp = 0.02;
        let mut m = IsentropicModel::new(cfg).unwrap();
        let before = m.phi_snapshot().domain_sum();
        let diags = m.run(20).unwrap();
        let after = diags.last().unwrap().mass;
        let rel = ((after - before) / before).abs();
        assert!(rel < 0.05, "mass drift {rel}");
    }

    #[test]
    fn backends_agree_on_model_trajectory() {
        let mut md = IsentropicModel::new(small_config("debug")).unwrap();
        let mut mv = IsentropicModel::new(small_config("vector")).unwrap();
        md.run(5).unwrap();
        mv.run(5).unwrap();
        let d = md.phi_snapshot();
        let v = mv.phi_snapshot();
        assert!(d.max_abs_diff(&v) < 1e-12);
    }
}
