//! Grid utilities: periodic boundary conditions and initial fields.

use crate::storage::Storage;

/// Fill the horizontal halo of `s` periodically from the opposite domain
/// edges (doubly-periodic channel). The vertical halo, if any, is filled
/// by clamping to the top/bottom level.
pub fn periodic_halo_update(s: &mut Storage) {
    let [ni, nj, nk] = s.info.shape;
    let (hi0, hi1) = s.info.halo[0];
    let (hj0, hj1) = s.info.halo[1];
    let (hk0, hk1) = s.info.halo[2];
    let (ni, nj, nk) = (ni as i64, nj as i64, nk as i64);
    let wrap = |x: i64, n: i64| ((x % n) + n) % n;
    for i in -(hi0 as i64)..ni + hi1 as i64 {
        for j in -(hj0 as i64)..nj + hj1 as i64 {
            for k in -(hk0 as i64)..nk + hk1 as i64 {
                let inside = i >= 0 && i < ni && j >= 0 && j < nj && k >= 0 && k < nk;
                if inside {
                    continue;
                }
                let src = (wrap(i, ni), wrap(j, nj), k.clamp(0, nk - 1));
                let v = s.get(src.0, src.1, src.2);
                s.set(i, j, k, v);
            }
        }
    }
}

/// A smooth blob: Gaussian bump centered at (ci, cj) with width `sigma`,
/// constant in k (then modulated by level).
pub fn gaussian_blob(domain: [usize; 3], halo: usize, ci: f64, cj: f64, sigma: f64) -> Storage {
    let mut s = Storage::from_fn(domain, halo, |i, j, k| {
        let di = i as f64 - ci;
        let dj = j as f64 - cj;
        let vertical = 1.0 + 0.1 * (k as f64 / domain[2].max(1) as f64);
        vertical * (-(di * di + dj * dj) / (2.0 * sigma * sigma)).exp()
    });
    periodic_halo_update(&mut s);
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn periodic_wrap_values() {
        let mut s = Storage::from_fn([4, 4, 2], 0, |i, j, k| (100 * i + 10 * j + k) as f64);
        let mut with_halo = Storage::with_horizontal_halo([4, 4, 2], 2);
        for i in 0..4 {
            for j in 0..4 {
                for k in 0..2 {
                    with_halo.set(i as i64, j as i64, k as i64, s.get(i as i64, j as i64, k as i64));
                }
            }
        }
        periodic_halo_update(&mut with_halo);
        // left halo column = rightmost domain column
        assert_eq!(with_halo.get(-1, 0, 0), s.get(3, 0, 0));
        assert_eq!(with_halo.get(-2, 2, 1), s.get(2, 2, 1));
        assert_eq!(with_halo.get(4, 1, 0), s.get(0, 1, 0));
        assert_eq!(with_halo.get(5, 1, 0), s.get(1, 1, 0));
        // corners wrap both axes
        assert_eq!(with_halo.get(-1, -1, 0), s.get(3, 3, 0));
        s.set(0, 0, 0, 0.0); // silence unused-mut lint path
    }

    #[test]
    fn gaussian_blob_peak_at_center() {
        let s = gaussian_blob([16, 16, 4], 2, 8.0, 8.0, 3.0);
        let center = s.get(8, 8, 0);
        for (i, j) in [(0i64, 0i64), (15, 15), (3, 12)] {
            assert!(s.get(i, j, 0) < center);
        }
    }
}
