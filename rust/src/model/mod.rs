//! An "isentropic-like" toy atmospheric model — the analog of the paper's
//! Tasmania model (§4): a real multi-stencil workload driven end-to-end
//! through the framework, proving the layers compose.
//!
//! Physics: passive tracer transport on a doubly-periodic horizontal grid
//! with nk vertical levels,
//!
//! ```text
//! ∂φ/∂t + u ∂φ/∂x + v ∂φ/∂y + w ∂φ/∂z = K ∇²φ
//! ```
//!
//! discretized as an operator split per step: (1) first-order upwind
//! horizontal advection, (2) horizontal diffusion with flux limiting (the
//! `hdiff` benchmark stencil), (3) *implicit* vertical advection (the
//! `vadv` Thomas-solver stencil). Every stencil runs through the
//! coordinator on a selectable backend; the driver maintains periodic
//! halos and conservation/stability diagnostics.

pub mod driver;
pub mod grid;

pub use driver::{precision_sweep, IsentropicModel, ModelConfig, PrecisionReport, StepDiagnostics};
pub use grid::periodic_halo_update;
