//! `repro` — the gt4rs command-line driver.
//!
//! Subcommands:
//!   inspect   dump the IRs the toolchain produces for a stencil
//!   ir        dump the IR before/after each optimizer pass
//!   run       execute a stencil on synthetic data and report timing
//!   validate  run a stencil on every backend and compare the results
//!   bench     Figure-3 style backend sweep over domain sizes
//!   model     run the isentropic-like demonstration model
//!
//! Every compiling subcommand accepts `--opt-level {0,1,2,3}` (default 2),
//! selecting how much of the pass manager (`gt4rs::opt`) runs between
//! analysis and the backends; level 3 additionally selects the fused
//! loop-nest evaluator on the vector backend.
//!
//! (The CLI is hand-rolled: the offline vendored crate set has no clap.)

use anyhow::{anyhow, bail, Result};
use gt4rs::backend::BACKEND_NAMES;
use gt4rs::coordinator::Coordinator;
use gt4rs::model::{IsentropicModel, ModelConfig};
use gt4rs::opt::{OptConfig, OptLevel, PassManager};
use gt4rs::stdlib;
use gt4rs::storage::Storage;
use std::collections::BTreeMap;
use std::time::Instant;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

/// Minimal flag parser: `--key value` pairs after the subcommand.
struct Flags {
    map: BTreeMap<String, String>,
}

impl Flags {
    fn parse(args: &[String]) -> Result<Flags> {
        let mut map = BTreeMap::new();
        let mut i = 0;
        while i < args.len() {
            let k = &args[i];
            if !k.starts_with("--") {
                bail!("unexpected argument `{k}` (flags are --key value)");
            }
            let key = k.trim_start_matches("--").to_string();
            if i + 1 >= args.len() {
                bail!("flag --{key} needs a value");
            }
            map.insert(key, args[i + 1].clone());
            i += 2;
        }
        Ok(Flags { map })
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.map.get(key).map(|s| s.as_str())
    }

    fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }
}

fn parse_domain(s: &str) -> Result<[usize; 3]> {
    let parts: Vec<usize> = s
        .split('x')
        .map(|p| p.parse::<usize>())
        .collect::<Result<_, _>>()
        .map_err(|_| anyhow!("domain must look like 64x64x32, got `{s}`"))?;
    if parts.len() != 3 {
        bail!("domain must have three components, got `{s}`");
    }
    Ok([parts[0], parts[1], parts[2]])
}

fn parse_opt_level(flags: &Flags) -> Result<OptLevel> {
    let s = flags.get_or("opt-level", "2");
    OptLevel::parse(s).ok_or_else(|| anyhow!("--opt-level must be 0, 1, 2 or 3, got `{s}`"))
}

fn parse_externals(s: Option<&str>) -> Result<BTreeMap<String, f64>> {
    let mut out = BTreeMap::new();
    if let Some(s) = s {
        for pair in s.split(',') {
            let (k, v) = pair
                .split_once('=')
                .ok_or_else(|| anyhow!("externals must be k=v pairs, got `{pair}`"))?;
            out.insert(k.to_string(), v.parse::<f64>()?);
        }
    }
    Ok(out)
}

fn dispatch(args: &[String]) -> Result<()> {
    let Some(cmd) = args.first() else {
        print_help();
        return Ok(());
    };
    let flags = Flags::parse(&args[1..])?;
    match cmd.as_str() {
        "inspect" => cmd_inspect(&flags),
        "ir" => cmd_ir(&flags),
        "run" => cmd_run(&flags),
        "validate" => cmd_validate(&flags),
        "bench" => cmd_bench(&flags),
        "model" => cmd_model(&flags),
        "help" | "--help" | "-h" => {
            print_help();
            Ok(())
        }
        other => bail!("unknown subcommand `{other}` (try `repro help`)"),
    }
}

fn print_help() {
    println!(
        "repro — GT4Py-reproduction stencil framework (gt4rs)

USAGE: repro <subcommand> [--flag value]...

SUBCOMMANDS
  inspect  --stencil NAME [--file F.gts] [--externals K=V,..]
           dump the implementation IR (stages, extents, fingerprint)
  ir       --stencil NAME [--file F.gts] [--externals K=V,..]
           dump the IR before and after each optimizer pass
  run      --stencil NAME [--backend B] [--domain IxJxK] [--iters N]
           run on synthetic data, print checksum + timing
  validate --stencil NAME [--domain IxJxK] [--backends a,b,..]
           cross-check every backend against `debug` (unavailable
           backends are skipped)
  bench    [--stencil hdiff|vadv] [--domains 32x32x16,..] [--iters N]
           [--backends a,b,..] Figure-3 style sweep (see also cargo bench)
  model    [--backend B] [--domain IxJxK] [--steps N]
           run the isentropic-like demo model, log diagnostics

All compiling subcommands take --opt-level 0|1|2|3 (default 2): 0 disables
the optimizer, 1 enables fold-cse/dce/fuse, 2 adds temporary demotion, 3
additionally runs the vector backend's fused loop-nest evaluator (stage
tapes, no per-expression-node buffers).

Backends: {}  (library stencils: {})",
        BACKEND_NAMES.join(", "),
        stdlib::names().join(", ")
    );
}

/// Resolve the stencil source from --file or the standard library.
fn load_source(flags: &Flags) -> Result<(String, String)> {
    let name = flags
        .get("stencil")
        .ok_or_else(|| anyhow!("--stencil NAME is required"))?;
    let src = if let Some(path) = flags.get("file") {
        std::fs::read_to_string(path)?
    } else if let Some(src) = stdlib::source(name) {
        src.to_string()
    } else {
        bail!("`{name}` is not a library stencil; pass --file F.gts");
    };
    Ok((name.to_string(), src))
}

/// Load a stencil from --file or the standard library, honoring
/// `--opt-level`.
fn load_ir(coord: &mut Coordinator, flags: &Flags) -> Result<(u64, gt4rs::StencilIr)> {
    coord.set_opt_level(parse_opt_level(flags)?);
    let (name, src) = load_source(flags)?;
    let externals = parse_externals(flags.get("externals"))?;
    let fp = coord.compile_source(&src, &name, &externals)?;
    let ir = coord.ir(fp)?;
    Ok((fp, ir))
}

fn cmd_inspect(flags: &Flags) -> Result<()> {
    let mut coord = Coordinator::new();
    let (_, ir) = load_ir(&mut coord, flags)?;
    print!("{}", ir.dump());
    Ok(())
}

/// Dump the implementation IR before and after each optimizer pass.
fn cmd_ir(flags: &Flags) -> Result<()> {
    let (name, src) = load_source(flags)?;
    let externals = parse_externals(flags.get("externals"))?;
    let level = parse_opt_level(flags)?;
    let mut ir = gt4rs::analysis::compile_source(&src, &name, &externals)
        .map_err(|e| anyhow!("{e}"))?;
    println!("=== pre-opt (pipeline output) ===");
    print!("{}", ir.dump());
    let pm = PassManager::new(&OptConfig::level(level));
    for (pass, enabled, dump) in pm.run_traced(&mut ir) {
        if enabled {
            println!("=== after pass `{pass}` ===");
            print!("{dump}");
        } else {
            println!("=== pass `{pass}` disabled at --opt-level {level} ===");
        }
    }
    Ok(())
}

/// Synthetic storages for a stencil at a domain: smooth deterministic data.
fn synthetic_fields(
    coord: &mut Coordinator,
    fp: u64,
    ir: &gt4rs::StencilIr,
    domain: [usize; 3],
) -> Result<Vec<(String, Storage)>> {
    let mut out = Vec::new();
    for (idx, f) in ir.fields.iter().enumerate() {
        let mut s = coord.alloc_field(fp, &f.name, domain)?;
        let phase = idx as f64;
        let [ni, nj, nk] = domain;
        let h = s.info.halo;
        for i in -(h[0].0 as i64)..(ni + h[0].1) as i64 {
            for j in -(h[1].0 as i64)..(nj + h[1].1) as i64 {
                for k in -(h[2].0 as i64)..(nk + h[2].1) as i64 {
                    let v = (0.1 * (i as f64) + phase).sin()
                        * (0.13 * (j as f64) - phase).cos()
                        + 0.01 * k as f64;
                    s.set(i, j, k, v);
                }
            }
        }
        out.push((f.name.clone(), s));
    }
    Ok(out)
}

fn default_scalars(ir: &gt4rs::StencilIr) -> Vec<(String, f64)> {
    ir.scalars.iter().map(|s| (s.name.clone(), 0.1)).collect()
}

fn cmd_run(flags: &Flags) -> Result<()> {
    let mut coord = Coordinator::new();
    let (fp, ir) = load_ir(&mut coord, flags)?;
    let backend = flags.get_or("backend", "vector");
    let domain = parse_domain(flags.get_or("domain", "64x64x32"))?;
    let iters: usize = flags.get_or("iters", "3").parse()?;

    let mut fields = synthetic_fields(&mut coord, fp, &ir, domain)?;
    let scalars = default_scalars(&ir);
    for it in 0..iters {
        let mut refs: Vec<(&str, &mut Storage)> =
            fields.iter_mut().map(|(n, s)| (n.as_str(), s)).collect();
        let srefs: Vec<(&str, f64)> =
            scalars.iter().map(|(n, v)| (n.as_str(), *v)).collect();
        let stats = coord.run(fp, backend, &mut refs, &srefs, domain)?;
        println!("iter {it}: checks {:?}  execute {:?}", stats.checks, stats.execute);
    }
    for (n, s) in &fields {
        println!("  {:<12} domain sum = {:+.9e}", n, s.domain_sum());
    }
    Ok(())
}

fn cmd_validate(flags: &Flags) -> Result<()> {
    let mut coord = Coordinator::new();
    let (fp, ir) = load_ir(&mut coord, flags)?;
    let domain = parse_domain(flags.get_or("domain", "24x20x12"))?;
    let backends: Vec<&str> =
        flags.get_or("backends", "debug,vector,xla").split(',').collect();

    // Reference: debug backend.
    let mut reference = synthetic_fields(&mut coord, fp, &ir, domain)?;
    let scalars = default_scalars(&ir);
    {
        let mut refs: Vec<(&str, &mut Storage)> =
            reference.iter_mut().map(|(n, s)| (n.as_str(), s)).collect();
        let srefs: Vec<(&str, f64)> =
            scalars.iter().map(|(n, v)| (n.as_str(), *v)).collect();
        coord.run(fp, "debug", &mut refs, &srefs, domain)?;
    }

    let mut ok = true;
    for be in backends {
        if be == "debug" {
            continue;
        }
        let mut fields = synthetic_fields(&mut coord, fp, &ir, domain)?;
        {
            let mut refs: Vec<(&str, &mut Storage)> =
                fields.iter_mut().map(|(n, s)| (n.as_str(), s)).collect();
            let srefs: Vec<(&str, f64)> =
                scalars.iter().map(|(n, v)| (n.as_str(), *v)).collect();
            match coord.run(fp, be, &mut refs, &srefs, domain) {
                Ok(_) => {}
                Err(e) if gt4rs::backend::is_unavailable(&e) => {
                    println!("{be:<10} SKIP (unavailable: {})", first_line(&format!("{e:#}")));
                    continue;
                }
                Err(e) => return Err(e),
            }
        }
        for ((n, r), (_, v)) in reference.iter().zip(&fields) {
            let diff = r.max_abs_diff(v);
            let pass = diff < 1e-11;
            ok &= pass;
            println!(
                "{be:<10} {n:<12} max|Δ| = {diff:.3e}  {}",
                if pass { "OK" } else { "MISMATCH" }
            );
        }
    }
    if !ok {
        bail!("backend mismatch detected");
    }
    Ok(())
}

fn cmd_bench(flags: &Flags) -> Result<()> {
    let stencil = flags.get_or("stencil", "hdiff");
    let domains: Vec<[usize; 3]> = flags
        .get_or("domains", "16x16x8,32x32x16,48x48x24,64x64x32")
        .split(',')
        .map(parse_domain)
        .collect::<Result<_>>()?;
    let backends: Vec<String> = flags
        .get_or("backends", "debug,vector,xla,pjrt-aot")
        .split(',')
        .map(str::to_string)
        .collect();
    let iters: usize = flags.get_or("iters", "5").parse()?;

    let mut coord = Coordinator::new();
    coord.set_opt_level(parse_opt_level(flags)?);
    let fp = coord.compile_library(stencil)?;
    let ir = coord.ir(fp)?;
    println!(
        "# {stencil}: mean wall time per call over {iters} iters (first call = compile, excluded)"
    );
    println!("{:<12} {:>14} {:>14}", "domain", "backend", "mean");
    for domain in &domains {
        for be in &backends {
            let mut fields = synthetic_fields(&mut coord, fp, &ir, *domain)?;
            let scalars = default_scalars(&ir);
            // warm-up (compile) run
            let warm = {
                let mut refs: Vec<(&str, &mut Storage)> =
                    fields.iter_mut().map(|(n, s)| (n.as_str(), s)).collect();
                let srefs: Vec<(&str, f64)> =
                    scalars.iter().map(|(n, v)| (n.as_str(), *v)).collect();
                coord.run(fp, be, &mut refs, &srefs, *domain)
            };
            if let Err(e) = warm {
                println!(
                    "{:<12} {:>14} {:>14}",
                    format!("{}x{}x{}", domain[0], domain[1], domain[2]),
                    be,
                    format!("n/a ({})", first_line(&format!("{e:#}")))
                );
                continue;
            }
            let t0 = Instant::now();
            for _ in 0..iters {
                let mut refs: Vec<(&str, &mut Storage)> =
                    fields.iter_mut().map(|(n, s)| (n.as_str(), s)).collect();
                let srefs: Vec<(&str, f64)> =
                    scalars.iter().map(|(n, v)| (n.as_str(), *v)).collect();
                coord.run(fp, be, &mut refs, &srefs, *domain)?;
            }
            let mean = t0.elapsed() / iters as u32;
            println!(
                "{:<12} {:>14} {:>14?}",
                format!("{}x{}x{}", domain[0], domain[1], domain[2]),
                be,
                mean
            );
        }
    }
    Ok(())
}

fn first_line(s: &str) -> String {
    s.lines().next().unwrap_or("").chars().take(60).collect()
}

fn cmd_model(flags: &Flags) -> Result<()> {
    let domain = parse_domain(flags.get_or("domain", "48x48x16"))?;
    let steps: usize = flags.get_or("steps", "100").parse()?;
    let backend = flags.get_or("backend", "vector").to_string();
    let config = ModelConfig {
        domain,
        backend: backend.clone(),
        opt_level: parse_opt_level(flags)?,
        ..ModelConfig::default()
    };
    let mut model = IsentropicModel::new(config)?;
    println!("# isentropic-like model: domain {domain:?} backend {backend} steps {steps}");
    println!("{:>6} {:>16} {:>12} {:>12} {:>12}", "step", "mass", "min", "max", "wall");
    let t0 = Instant::now();
    for s in 0..steps {
        let d = model.step()?;
        if s % 10.max(steps / 20) == 0 || s + 1 == steps {
            println!(
                "{:>6} {:>16.9e} {:>12.5e} {:>12.5e} {:>12?}",
                d.step, d.mass, d.min, d.max, d.wall
            );
        }
    }
    println!("total wall: {:?}", t0.elapsed());
    Ok(())
}
