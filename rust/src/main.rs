//! `repro` — the gt4rs command-line driver.
//!
//! Subcommands:
//!   inspect   dump the IRs the toolchain produces for a stencil
//!   ir        dump the IR before/after each optimizer pass
//!   run       execute a stencil on synthetic data and report timing
//!   validate  run a stencil on every backend and compare the results
//!   bench     Figure-3 style backend sweep over domain sizes
//!   model     run the isentropic-like demonstration model
//!   serve     long-running stencil service (NDJSON over TCP)
//!   client    send one request line to a running `repro serve`
//!   warm      pre-populate the persistent artifact cache
//!   cache     inspect or clear the persistent artifact cache
//!
//! Every compiling subcommand accepts `--opt-level {0,1,2,3}` (default 2),
//! selecting how much of the pass manager (`gt4rs::opt`) runs between
//! analysis and the backends; level 3 additionally selects the fused
//! loop-nest evaluator on the vector backend. The four execution knobs
//! (`--opt-level`, `--fast-math`, `--threads`, `--tier`, `--dtype`) are
//! parsed into one [`ExecOptions`] and applied together.
//!
//! Executing subcommands go through the `Stencil` handle API: arguments
//! are bound and validated once, and repeat calls only re-check shapes.
//! `--no-checks` disables the run-time storage validation entirely
//! (the paper's dashed-line configuration); `--json` switches `run` and
//! `bench` to machine-readable output for the perf-trajectory tooling.
//!
//! (The CLI is hand-rolled: the offline vendored crate set has no clap.)

use anyhow::{anyhow, bail, Result};
use gt4rs::backend::kernels::ExecTier;
use gt4rs::backend::shard::Sharding;
use gt4rs::backend::BACKEND_NAMES;
use gt4rs::coordinator::{Coordinator, Stencil};
use gt4rs::jsonw::{self, Obj};
use gt4rs::model::{IsentropicModel, ModelConfig};
use gt4rs::opt::{ExecOptions, OptConfig, OptLevel, PassManager};
use gt4rs::serve::{ServeConfig, Server};
use gt4rs::stdlib;
use gt4rs::storage::{synthetic_fill, Storage};
use std::collections::{BTreeMap, BTreeSet};
use std::time::Instant;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

/// Presence-only flags (no value follows them on the command line).
const BOOL_FLAGS: [&str; 6] =
    ["json", "no-checks", "fast-math", "tapes", "clear", "precision-sweep"];

/// Minimal flag parser: `--key value` pairs plus presence-only booleans
/// (`--json`, `--no-checks`, `--fast-math`, `--tapes`) after the
/// subcommand.
struct Flags {
    map: BTreeMap<String, String>,
    bools: BTreeSet<String>,
}

impl Flags {
    fn parse(args: &[String]) -> Result<Flags> {
        let mut map = BTreeMap::new();
        let mut bools = BTreeSet::new();
        let mut i = 0;
        while i < args.len() {
            let k = &args[i];
            if !k.starts_with("--") {
                bail!("unexpected argument `{k}` (flags are --key value or --switch)");
            }
            let key = k.trim_start_matches("--").to_string();
            if BOOL_FLAGS.contains(&key.as_str()) {
                bools.insert(key);
                i += 1;
                continue;
            }
            if i + 1 >= args.len() {
                bail!("flag --{key} needs a value");
            }
            map.insert(key, args[i + 1].clone());
            i += 2;
        }
        Ok(Flags { map, bools })
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.map.get(key).map(|s| s.as_str())
    }

    fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    /// Whether a presence-only flag was given.
    fn flag(&self, key: &str) -> bool {
        self.bools.contains(key)
    }
}

fn parse_domain(s: &str) -> Result<[usize; 3]> {
    let parts: Vec<usize> = s
        .split('x')
        .map(|p| p.parse::<usize>())
        .collect::<Result<_, _>>()
        .map_err(|_| anyhow!("domain must look like 64x64x32, got `{s}`"))?;
    if parts.len() != 3 {
        bail!("domain must have three components, got `{s}`");
    }
    Ok([parts[0], parts[1], parts[2]])
}

fn parse_opt_level(flags: &Flags) -> Result<OptLevel> {
    let s = flags.get_or("opt-level", "2");
    OptLevel::parse(s).ok_or_else(|| anyhow!("--opt-level must be 0, 1, 2 or 3, got `{s}`"))
}

/// Intra-call sharding plan: `--threads N|auto|off` wins, then the
/// `REPRO_THREADS` environment variable, then `off`.
fn parse_sharding(flags: &Flags) -> Result<Sharding> {
    match flags.get("threads") {
        Some(s) => Sharding::parse(s)
            .ok_or_else(|| anyhow!("--threads must be a count, `auto` or `off`, got `{s}`")),
        None => Ok(Sharding::from_env()),
    }
}

/// Fused-path executor tier: `--tier interpreted|specialized` (default
/// specialized — the compiled kernel plans; both tiers are bitwise
/// identical by contract).
fn parse_tier(flags: &Flags) -> Result<ExecTier> {
    let s = flags.get_or("tier", "specialized");
    ExecTier::parse(s)
        .ok_or_else(|| anyhow!("--tier must be `interpreted` or `specialized`, got `{s}`"))
}

/// Storage-precision override: `--dtype f32|f64` recompiles the stencil
/// with every field/scalar/temporary at that element type; absent, the
/// source declarations stand.
fn parse_dtype(flags: &Flags) -> Result<Option<gt4rs::dsl::ast::DType>> {
    match flags.get("dtype") {
        None => Ok(None),
        Some(s) => gt4rs::dsl::ast::DType::parse(s)
            .map(Some)
            .ok_or_else(|| anyhow!("--dtype must be `f32` or `f64`, got `{s}`")),
    }
}

/// The full execution-option surface as one value: `--opt-level`,
/// `--fast-math` and `--dtype` (the compile half, salting cache keys)
/// plus `--threads` and `--tier` (the scheduling half). Same struct the
/// library API and the serve wire protocol use.
fn parse_exec_options(flags: &Flags) -> Result<ExecOptions> {
    Ok(ExecOptions::new()
        .with_opt_level(parse_opt_level(flags)?)
        .with_fast_math(flags.flag("fast-math"))
        .with_dtype(parse_dtype(flags)?)
        .with_sharding(parse_sharding(flags)?)
        .with_tier(parse_tier(flags)?))
}

/// Open the persistent artifact store (see `gt4rs::persist`): `--cache-dir
/// DIR` wins, then the `REPRO_CACHE_DIR` environment variable; absent both,
/// persistence stays off (`None`).
fn open_persist(flags: &Flags) -> Result<Option<std::sync::Arc<gt4rs::persist::PersistStore>>> {
    use std::sync::Arc;
    if let Some(dir) = flags.get("cache-dir") {
        return Ok(Some(Arc::new(gt4rs::persist::PersistStore::open(dir)?)));
    }
    Ok(gt4rs::persist::PersistStore::from_env()?.map(Arc::new))
}

fn parse_externals(s: Option<&str>) -> Result<BTreeMap<String, f64>> {
    let mut out = BTreeMap::new();
    if let Some(s) = s {
        for pair in s.split(',') {
            let (k, v) = pair
                .split_once('=')
                .ok_or_else(|| anyhow!("externals must be k=v pairs, got `{pair}`"))?;
            out.insert(k.to_string(), v.parse::<f64>()?);
        }
    }
    Ok(out)
}

fn dispatch(args: &[String]) -> Result<()> {
    let Some(cmd) = args.first() else {
        print_help();
        return Ok(());
    };
    let flags = Flags::parse(&args[1..])?;
    match cmd.as_str() {
        "inspect" => cmd_inspect(&flags),
        "ir" => cmd_ir(&flags),
        "run" => cmd_run(&flags),
        "validate" => cmd_validate(&flags),
        "bench" => cmd_bench(&flags),
        "model" => cmd_model(&flags),
        "serve" => cmd_serve(&flags),
        "client" => cmd_client(&flags),
        "warm" => cmd_warm(&flags),
        "cache" => cmd_cache(&flags),
        "help" | "--help" | "-h" => {
            print_help();
            Ok(())
        }
        other => bail!("unknown subcommand `{other}` (try `repro help`)"),
    }
}

fn print_help() {
    println!(
        "repro — GT4Py-reproduction stencil framework (gt4rs)

USAGE: repro <subcommand> [--flag value]... [--json] [--no-checks]

SUBCOMMANDS
  inspect  --stencil NAME [--file F.gts] [--externals K=V,..]
           dump the implementation IR (stages, extents, fingerprint)
  ir       --stencil NAME [--file F.gts] [--externals K=V,..] [--tapes]
           dump the IR before and after each optimizer pass; --tapes
           instead dumps the compiled SSA tapes with their kernel plans
           (per-op kernel class, regions, loop bounds, guard-free
           interior rectangle)
  run      --stencil NAME [--backend B] [--domain IxJxK] [--iters N]
           [--threads T] [--tier interpreted|specialized] [--fast-math]
           [--dtype f32|f64]
           compile to a stencil handle, bind the arguments once, run N
           times; prints checksum + per-call timing (--json for
           machine-readable output)
  validate --stencil NAME [--domain IxJxK] [--backends a,b,..]
           cross-check every backend against `debug` (unavailable
           backends are skipped)
  bench    [--stencil hdiff|vadv] [--domains 32x32x16,..] [--iters N]
           [--backends a,b,..] [--threads T] [--dtype f32|f64]
           Figure-3 style sweep (see also cargo bench); --json emits one
           row per (domain, backend)
  model    [--backend B] [--domain IxJxK] [--steps N] [--threads T]
           [--dtype f32|f64] [--precision-sweep]
           run the isentropic-like demo model, log diagnostics;
           --precision-sweep runs the same model at f32 and f64 and
           reports per-field relative-error norms against per-stencil
           tolerances instead of a single-precision run
  serve    [--addr H:P] [--cores N] [--max-waiters N] [--deadline-ms N]
           [--coalesce-elems N] [--max-leases N] [--cache-dir DIR]
           long-running stencil service: newline-delimited JSON over TCP
           (ops: compile, bind, run, metrics, shutdown), per-tenant
           stencil libraries, a global core budget with structured 429
           backpressure + per-request deadlines, and coalescing of
           same-stencil small-domain runs into one sharded dispatch
  client   --addr H:P --request '<json line>'
           send one request to a running serve daemon, print the reply
  warm     --cache-dir DIR [--stencil A,B,..] [--opt-level L] [--fast-math]
           pre-populate the persistent artifact cache: compile library
           stencils (default: all, at every opt level) through a
           persist-attached coordinator and prepare the vector backend,
           so later processes warm-start without running the pipeline
  cache    --cache-dir DIR [--clear]
           list the persistent cache's entries (kind, key, bytes) or
           wipe it with --clear

All compiling subcommands take --opt-level 0|1|2|3 (default 2): 0 disables
the optimizer, 1 enables fold-cse/dce/fuse, 2 adds temporary demotion, 3
additionally runs the vector backend's fused loop-nest evaluator (stage
tapes, no per-expression-node buffers).

Executing subcommands use the first-class stencil handle API
(`Coordinator::stencil` -> `Stencil::bind` -> `BoundInvocation::run`):
storage layout/halo/dtype validation happens once at bind time, repeat
calls only re-check shapes. --no-checks disables validation entirely
(the paper's Fig. 3 dashed lines).

--threads T selects intra-call domain sharding on backends that support
it (vector): one invocation's compute domain is split into halo-correct
i-slabs executed on T std threads. T is a count, `auto` (one slab per
core, off for narrow domains) or `off` (default). The REPRO_THREADS
environment variable supplies the plan when --threads is absent. Every
plan is bitwise identical to `off`; timing output reports the thread
count *actually used*. Sequential sweeps whose carry crosses slab
boundaries (horizontal field reads) run sharded too, exchanging halo
columns at per-level (or per-stage) rendezvous points; only in-level
wavefronts (a stage reading its own same-level output at an i-offset)
fall back to serial. The serve daemon's /metrics surface the counters:
pool_halo_exchanges_total (rendezvous crossings) and
pool_serial_fallbacks_total (multistages that degraded).

--dtype f32|f64 recompiles a stencil with every field, scalar and
temporary at that element type (absent, source declarations stand). Like
--fast-math it salts the compilation cache — an f32 artifact computes
genuinely different bits than the f64 one, so the two never share a
cache entry, in memory or on disk. Storages must be allocated at the
matching dtype; binding a mismatched storage is a structured bind-time
error.

--tier selects the fused-path executor at --opt-level 3: `specialized`
(default) pre-compiles each tape into a kernel plan — dense stride
tables, guard-hoisted interior spans, cache-blocked j-tiles — while
`interpreted` walks the tape per strip. Both tiers are bitwise
identical by contract. --fast-math opts into FMA contraction in the
specialized executor; it changes results within a small tolerance, so
it salts the compilation cache and is never substituted silently.

--cache-dir DIR (or the REPRO_CACHE_DIR environment variable) attaches a
persistent on-disk artifact store to every compiling subcommand:
compiled IR, fused tapes and HLO text survive the process, so a later
run (or `repro serve`) warm-starts without the dsl->analysis->opt
pipeline. Entries are schema-versioned and digest-checked — corruption
or version skew silently recompiles — and writes are atomic, so
concurrent processes can share one cache root. Off by default.

Backends: {}  (library stencils: {})",
        BACKEND_NAMES.join(", "),
        stdlib::names().join(", ")
    );
}

/// Resolve the stencil source from --file or the standard library.
fn load_source(flags: &Flags) -> Result<(String, String)> {
    let name = flags
        .get("stencil")
        .ok_or_else(|| anyhow!("--stencil NAME is required"))?;
    let src = if let Some(path) = flags.get("file") {
        std::fs::read_to_string(path)?
    } else if let Some(src) = stdlib::source(name) {
        src.to_string()
    } else {
        bail!("`{name}` is not a library stencil; pass --file F.gts");
    };
    Ok((name.to_string(), src))
}

/// Compile a stencil from --file or the standard library, honoring
/// `--opt-level`; returns its cache fingerprint.
fn load_fp(coord: &mut Coordinator, flags: &Flags) -> Result<u64> {
    coord.set_exec_options(parse_exec_options(flags)?);
    coord.checks_enabled = !flags.flag("no-checks");
    if let Some(store) = open_persist(flags)? {
        coord.set_persist(store);
    }
    let (name, src) = load_source(flags)?;
    let externals = parse_externals(flags.get("externals"))?;
    coord.compile_source(&src, &name, &externals)
}

fn cmd_inspect(flags: &Flags) -> Result<()> {
    let mut coord = Coordinator::new();
    let fp = load_fp(&mut coord, flags)?;
    print!("{}", coord.ir(fp)?.dump());
    Ok(())
}

/// Dump the implementation IR before and after each optimizer pass.
fn cmd_ir(flags: &Flags) -> Result<()> {
    let (name, src) = load_source(flags)?;
    let externals = parse_externals(flags.get("externals"))?;
    let level = parse_opt_level(flags)?;
    let mut ir = gt4rs::analysis::compile_source(&src, &name, &externals)
        .map_err(|e| anyhow!("{e}"))?;
    if flags.flag("tapes") {
        // Dump the compiled SSA tapes and their kernel plans instead of
        // the pass-by-pass IR: run the full pass list, then lower the way
        // the vector backend's fused path would.
        let config = OptConfig::level(level).with_fast_math(flags.flag("fast-math"));
        PassManager::new(&config).run(&mut ir);
        let domain = parse_domain(flags.get_or("domain", "16x16x8"))?;
        let program =
            gt4rs::backend::program::Program::compile(&ir).map_err(|e| anyhow!("{e}"))?;
        let fused = gt4rs::backend::fused::FusedProgram::compile(&program, ir.fast_math);
        println!(
            "=== compiled tapes (opt-level {level}{}, domain {}x{}x{}) ===",
            if ir.fast_math { ", fast-math" } else { "" },
            domain[0],
            domain[1],
            domain[2]
        );
        print!("{}", fused.dump_tapes(&program, domain));
        return Ok(());
    }
    println!("=== pre-opt (pipeline output) ===");
    print!("{}", ir.dump());
    let pm = PassManager::new(&OptConfig::level(level));
    for (pass, enabled, dump) in pm.run_traced(&mut ir) {
        if enabled {
            println!("=== after pass `{pass}` ===");
            print!("{dump}");
        } else {
            println!("=== pass `{pass}` disabled at --opt-level {level} ===");
        }
    }
    Ok(())
}

/// Synthetic storages for every field of a stencil at a domain: smooth
/// deterministic data, in declaration order.
fn synthetic_fields(stencil: &Stencil, domain: [usize; 3]) -> Result<Vec<(String, Storage)>> {
    let mut out = Vec::new();
    for (idx, f) in stencil.ir().fields.iter().enumerate() {
        let mut s = stencil.alloc_field(&f.name, domain)?;
        synthetic_fill(&mut s, idx as f64);
        out.push((f.name.clone(), s));
    }
    Ok(out)
}

fn default_scalars(stencil: &Stencil) -> Vec<(String, f64)> {
    stencil.ir().scalars.iter().map(|s| (s.name.clone(), 0.1)).collect()
}

/// Bind a full set of named fields/scalars on a handle (declaration-order
/// storages come back out of `synthetic_fields`, so `run` call sites pass
/// them positionally).
fn bind_all(
    stencil: &Stencil,
    fields: &[(String, Storage)],
    scalars: &[(String, f64)],
    domain: [usize; 3],
) -> Result<gt4rs::coordinator::BoundInvocation> {
    stencil.bind().domain(domain).fields(fields).scalars(scalars).finish()
}

fn cmd_run(flags: &Flags) -> Result<()> {
    let mut coord = Coordinator::new();
    let fp = load_fp(&mut coord, flags)?;
    let backend = flags.get_or("backend", "vector");
    let domain = parse_domain(flags.get_or("domain", "64x64x32"))?;
    let iters: usize = flags.get_or("iters", "3").parse()?;
    let json = flags.flag("json");

    let stencil = coord.stencil_for(fp, backend)?;
    let mut fields = synthetic_fields(&stencil, domain)?;
    let scalars = default_scalars(&stencil);
    // Bind once (full validation), run N times (shape re-checks only).
    let mut inv = bind_all(&stencil, &fields, &scalars, domain)?;

    let mut iter_rows: Vec<String> = Vec::new();
    let mut threads_used = 1u32;
    let mut halo_exchanges = 0u64;
    for it in 0..iters {
        let mut refs: Vec<&mut Storage> = fields.iter_mut().map(|(_, s)| s).collect();
        let stats = inv.run(&mut refs)?;
        threads_used = threads_used.max(stats.threads_used());
        halo_exchanges += stats.shard.exchanges;
        if json {
            iter_rows.push(
                Obj::new()
                    .int("iter", it as u64)
                    .int("checks_ns", stats.checks.as_nanos() as i128)
                    .int("execute_ns", stats.execute.as_nanos() as i128)
                    .int("threads", stats.threads_used())
                    .int("halo_exchanges", stats.shard.exchanges)
                    .finish(),
            );
        } else {
            println!(
                "iter {it}: checks {:?}  execute {:?}  threads {}",
                stats.checks,
                stats.execute,
                stats.threads_used()
            );
        }
    }
    if json {
        let field_rows: Vec<String> = fields
            .iter()
            .map(|(n, s)| Obj::new().str("name", n).f64("domain_sum", s.domain_sum()).finish())
            .collect();
        let exec = parse_exec_options(flags)?;
        // `threads_used` is the *effective* count (a degraded Auto plan
        // reports 1), never an echo of the requested plan. The persist
        // counters are the warm-start honesty surface: a fresh process on
        // a warmed cache reports pipeline_compiles 0 and persist_hits > 0.
        let (ph, pm, pr) = coord.persist_counters().unwrap_or((0, 0, 0));
        println!(
            "{}",
            Obj::new()
                .str("stencil", stencil.name())
                .str("backend", backend)
                .raw("domain", &format!("[{},{},{}]", domain[0], domain[1], domain[2]))
                .str("opt_level", &exec.opt_level.to_string())
                .bool("checks_enabled", !flags.flag("no-checks"))
                .str("sharding", &exec.sharding.to_string())
                .str("tier", &exec.tier.to_string())
                .bool("fast_math", exec.fast_math)
                .str(
                    "dtype",
                    &exec.dtype.map(|d| d.to_string()).unwrap_or_else(|| "declared".into()),
                )
                .int("threads_used", threads_used)
                .int("halo_exchanges", halo_exchanges)
                .int("pipeline_compiles", coord.pipeline_compiles())
                .int("persist_hits", ph)
                .int("persist_misses", pm)
                .int("persist_rejects", pr)
                .raw("iters", &jsonw::array(&iter_rows))
                .raw("fields", &jsonw::array(&field_rows))
                .finish()
        );
    } else {
        for (n, s) in &fields {
            println!("  {:<12} domain sum = {:+.9e}", n, s.domain_sum());
        }
        if let Some((ph, pm, pr)) = coord.persist_counters() {
            println!(
                "  persist: {ph} hits, {pm} misses, {pr} rejects (pipeline compiles: {})",
                coord.pipeline_compiles()
            );
        }
    }
    Ok(())
}

fn cmd_validate(flags: &Flags) -> Result<()> {
    let mut coord = Coordinator::new();
    let fp = load_fp(&mut coord, flags)?;
    let domain = parse_domain(flags.get_or("domain", "24x20x12"))?;
    let backends: Vec<String> = flags
        .get_or("backends", "debug,vector,xla")
        .split(',')
        .map(str::to_string)
        .collect();

    // Reference: debug backend.
    let reference_stencil = coord.stencil_for(fp, "debug")?;
    let mut reference = synthetic_fields(&reference_stencil, domain)?;
    let scalars = default_scalars(&reference_stencil);
    {
        let mut inv = bind_all(&reference_stencil, &reference, &scalars, domain)?;
        let mut refs: Vec<&mut Storage> = reference.iter_mut().map(|(_, s)| s).collect();
        inv.run(&mut refs)?;
    }

    let mut ok = true;
    for be in &backends {
        if be == "debug" {
            continue;
        }
        let stencil = match coord.stencil_for(fp, be) {
            Ok(s) => s,
            Err(e) if gt4rs::backend::is_unavailable(&e) => {
                println!("{be:<10} SKIP (unavailable: {})", first_line(&format!("{e:#}")));
                continue;
            }
            Err(e) => return Err(e),
        };
        let mut fields = synthetic_fields(&stencil, domain)?;
        {
            let mut inv = bind_all(&stencil, &fields, &scalars, domain)?;
            let mut refs: Vec<&mut Storage> = fields.iter_mut().map(|(_, s)| s).collect();
            match inv.run(&mut refs) {
                Ok(_) => {}
                Err(e) if gt4rs::backend::is_unavailable(&e) => {
                    println!("{be:<10} SKIP (unavailable: {})", first_line(&format!("{e:#}")));
                    continue;
                }
                Err(e) => return Err(e),
            }
        }
        for ((n, r), (_, v)) in reference.iter().zip(&fields) {
            let diff = r.max_abs_diff(v);
            let pass = diff < 1e-11;
            ok &= pass;
            println!(
                "{be:<10} {n:<12} max|Δ| = {diff:.3e}  {}",
                if pass { "OK" } else { "MISMATCH" }
            );
        }
    }
    if !ok {
        bail!("backend mismatch detected");
    }
    Ok(())
}

fn cmd_bench(flags: &Flags) -> Result<()> {
    let stencil_name = flags.get_or("stencil", "hdiff");
    let domains: Vec<[usize; 3]> = flags
        .get_or("domains", "16x16x8,32x32x16,48x48x24,64x64x32")
        .split(',')
        .map(parse_domain)
        .collect::<Result<_>>()?;
    let backends: Vec<String> = flags
        .get_or("backends", "debug,vector,xla,pjrt-aot")
        .split(',')
        .map(str::to_string)
        .collect();
    let iters: usize = flags.get_or("iters", "5").parse()?;
    let json = flags.flag("json");

    let mut coord = Coordinator::new();
    coord.set_exec_options(parse_exec_options(flags)?);
    coord.checks_enabled = !flags.flag("no-checks");
    if let Some(store) = open_persist(flags)? {
        coord.set_persist(store);
    }
    let fp = coord.compile_library(stencil_name)?;
    let mut rows: Vec<String> = Vec::new();
    if !json {
        println!(
            "# {stencil_name}: mean wall time per call over {iters} iters (first call = compile, excluded)"
        );
        println!("{:<12} {:>14} {:>14}", "domain", "backend", "mean");
    }
    for domain in &domains {
        let dstr = format!("{}x{}x{}", domain[0], domain[1], domain[2]);
        for be in &backends {
            // A backend that cannot be created or run still gets a row in
            // JSON mode — consumers must be able to tell "skipped" from
            // "silently missing".
            let unavailable = |e: &anyhow::Error, rows: &mut Vec<String>| {
                let reason = first_line(&format!("{e:#}"));
                if json {
                    rows.push(
                        Obj::new()
                            .str("stencil", stencil_name)
                            .str("domain", &dstr)
                            .str("backend", be)
                            .str("error", &reason)
                            .finish(),
                    );
                } else {
                    println!("{dstr:<12} {be:>14} {:>14}", format!("n/a ({reason})"));
                }
            };
            let stencil = match coord.stencil_for(fp, be) {
                Ok(s) => s,
                Err(e) => {
                    unavailable(&e, &mut rows);
                    continue;
                }
            };
            let mut fields = synthetic_fields(&stencil, *domain)?;
            let scalars = default_scalars(&stencil);
            let mut inv = bind_all(&stencil, &fields, &scalars, *domain)?;
            // warm-up (compile) run
            let warm = {
                let mut refs: Vec<&mut Storage> =
                    fields.iter_mut().map(|(_, s)| s).collect();
                inv.run(&mut refs)
            };
            if let Err(e) = warm {
                unavailable(&e, &mut rows);
                continue;
            }
            let t0 = Instant::now();
            for _ in 0..iters {
                let mut refs: Vec<&mut Storage> =
                    fields.iter_mut().map(|(_, s)| s).collect();
                inv.run(&mut refs)?;
            }
            let mean = t0.elapsed() / iters as u32;
            if json {
                rows.push(
                    Obj::new()
                        .str("stencil", stencil_name)
                        .str("domain", &dstr)
                        .str("backend", be)
                        .int("mean_ns", mean.as_nanos() as i128)
                        .int("iters", iters as u64)
                        .finish(),
                );
            } else {
                println!("{dstr:<12} {be:>14} {mean:>14?}");
            }
        }
    }
    if json {
        println!("[{}]", rows.join(","));
    }
    Ok(())
}

fn first_line(s: &str) -> String {
    s.lines().next().unwrap_or("").chars().take(60).collect()
}

fn cmd_model(flags: &Flags) -> Result<()> {
    let domain = parse_domain(flags.get_or("domain", "48x48x16"))?;
    let steps: usize = flags.get_or("steps", "100").parse()?;
    let backend = flags.get_or("backend", "vector").to_string();
    let config = ModelConfig {
        domain,
        backend: backend.clone(),
        exec: parse_exec_options(flags)?,
        checks: !flags.flag("no-checks"),
        ..ModelConfig::default()
    };
    if flags.flag("precision-sweep") {
        println!("# precision sweep: domain {domain:?} backend {backend} steps {steps}");
        println!("{:>20} {:>14} {:>12} {:>8}", "stencil", "rel_l2(f32)", "tolerance", "status");
        let reports = gt4rs::model::precision_sweep(&config, steps)?;
        let mut failed = false;
        for r in &reports {
            println!(
                "{:>20} {:>14.6e} {:>12.1e} {:>8}",
                r.stencil,
                r.rel_l2,
                r.tolerance,
                if r.within() { "ok" } else { "FAIL" }
            );
            failed |= !r.within();
        }
        if failed {
            anyhow::bail!("precision sweep exceeded tolerance");
        }
        return Ok(());
    }
    let mut model = IsentropicModel::new(config)?;
    println!("# isentropic-like model: domain {domain:?} backend {backend} steps {steps}");
    println!("{:>6} {:>16} {:>12} {:>12} {:>12}", "step", "mass", "min", "max", "wall");
    let t0 = Instant::now();
    for s in 0..steps {
        let d = model.step()?;
        if s % 10.max(steps / 20) == 0 || s + 1 == steps {
            println!(
                "{:>6} {:>16.9e} {:>12.5e} {:>12.5e} {:>12?}",
                d.step, d.mass, d.min, d.max, d.wall
            );
        }
    }
    println!("total wall: {:?}", t0.elapsed());
    Ok(())
}

/// `repro serve`: bind, announce the resolved address (port 0 picks an
/// ephemeral port — scripts parse this line), then serve until a
/// `shutdown` request arrives.
fn cmd_serve(flags: &Flags) -> Result<()> {
    let mut config = ServeConfig {
        addr: flags.get_or("addr", "127.0.0.1:7070").to_string(),
        ..ServeConfig::default()
    };
    if let Some(s) = flags.get("cores") {
        config.cores = s.parse()?;
    }
    if let Some(s) = flags.get("max-waiters") {
        config.max_waiters = s.parse()?;
    }
    if let Some(s) = flags.get("deadline-ms") {
        config.default_deadline_ms = s.parse()?;
    }
    if let Some(s) = flags.get("coalesce-elems") {
        config.small_domain_elems = s.parse()?;
    }
    if let Some(s) = flags.get("max-leases") {
        config.max_leases_per_tenant = s.parse()?;
    }
    config.cache_dir = flags.get("cache-dir").map(str::to_string);
    let server = Server::bind(config)?;
    if let Some((root, entries)) = server.persist_info() {
        println!("persist cache {root}: {entries} entries (warm start)");
    }
    println!("listening on {}", server.local_addr());
    use std::io::Write as _;
    std::io::stdout().flush()?;
    server.run()
}

/// `repro client`: one request line in, one response line out — the
/// smallest possible protocol probe for scripts and CI smokes.
fn cmd_client(flags: &Flags) -> Result<()> {
    use std::io::{BufRead, BufReader, Write as _};
    let addr = flags
        .get("addr")
        .ok_or_else(|| anyhow!("--addr HOST:PORT is required"))?;
    let request = flags
        .get("request")
        .ok_or_else(|| anyhow!("--request '<json line>' is required"))?;
    let mut stream = std::net::TcpStream::connect(addr)?;
    stream.write_all(request.as_bytes())?;
    stream.write_all(b"\n")?;
    stream.flush()?;
    let mut line = String::new();
    BufReader::new(stream).read_line(&mut line)?;
    print!("{line}");
    Ok(())
}

/// `repro warm`: pre-populate the persistent artifact cache for a stencil
/// library so later processes (runs, serves) warm-start. Each opt level
/// gets its own coordinator — levels salt the cache keys, so one pass per
/// level writes one IR + tape entry per stencil.
fn cmd_warm(flags: &Flags) -> Result<()> {
    let store = open_persist(flags)?.ok_or_else(|| {
        anyhow!("`repro warm` needs a cache root: pass --cache-dir DIR or set REPRO_CACHE_DIR")
    })?;
    let stencils: Vec<String> = match flags.get("stencil") {
        Some(s) => s.split(',').map(str::to_string).collect(),
        None => stdlib::names().iter().map(|s| s.to_string()).collect(),
    };
    let levels: Vec<OptLevel> = match flags.get("opt-level") {
        Some(s) => vec![OptLevel::parse(s)
            .ok_or_else(|| anyhow!("--opt-level must be 0, 1, 2 or 3, got `{s}`"))?],
        None => vec![OptLevel::O0, OptLevel::O1, OptLevel::O2, OptLevel::O3],
    };
    let fast_math = flags.flag("fast-math");
    let t0 = Instant::now();
    let mut compiled = 0u64;
    for level in &levels {
        let mut coord = Coordinator::new();
        coord.set_exec_options(
            ExecOptions::new().with_opt_level(*level).with_fast_math(fast_math),
        );
        coord.set_persist(store.clone());
        for name in &stencils {
            let fp = coord.compile_library(name)?;
            // Prepare the vector backend so the warmed cache includes
            // compiled fused tapes (O3), not just IR.
            coord.prepare(fp, "vector")?;
        }
        compiled += coord.pipeline_compiles();
    }
    let entries = store.entries();
    println!(
        "warmed {} ({} stencils x {} levels{}): {} pipeline compiles, {} entries on disk in {:?}",
        store.root().display(),
        stencils.len(),
        levels.len(),
        if fast_math { ", fast-math" } else { "" },
        compiled,
        entries.len(),
        t0.elapsed()
    );
    Ok(())
}

/// `repro cache`: inspect (default) or `--clear` the persistent store.
fn cmd_cache(flags: &Flags) -> Result<()> {
    let store = open_persist(flags)?.ok_or_else(|| {
        anyhow!("`repro cache` needs a cache root: pass --cache-dir DIR or set REPRO_CACHE_DIR")
    })?;
    if flags.flag("clear") {
        let n = store.clear()?;
        println!("cleared {n} entries from {}", store.root().display());
        return Ok(());
    }
    let entries = store.entries();
    println!("# {} — {} entries", store.root().display(), entries.len());
    let mut by_kind: BTreeMap<&str, (usize, u64)> = BTreeMap::new();
    for e in &entries {
        let slot = by_kind.entry(e.kind.as_str()).or_default();
        slot.0 += 1;
        slot.1 += e.bytes;
        println!("{:<6} {:<40} {:>10} bytes", e.kind, e.key, e.bytes);
    }
    for (kind, (count, bytes)) in &by_kind {
        println!("# {kind}: {count} entries, {bytes} bytes");
    }
    Ok(())
}
