//! Compilation caching (paper §2.3).
//!
//! GT4Py "provides a caching mechanism to create unique hash identifiers
//! for every stencil implementation ... based on fingerprinting in such a
//! way that code reformatting would not trigger a new compilation."
//!
//! gt4rs splits this into:
//! * the fingerprint itself — [`crate::analysis::fingerprint_ir`], a FNV-1a
//!   over the canonical (formatting-free) implementation IR including the
//!   folded external values, the optimizer's stage metadata (fusion
//!   groups, temporary storage classes) and the pass configuration tag —
//!   so artifacts compiled at different opt levels never share a slot;
//! * an in-memory stencil cache ([`StencilCache`]) used by the coordinator
//!   so re-compiling an unchanged source is a hash lookup;
//! * the on-disk half — persisting artifacts across processes, the analog
//!   of GT4Py's `.gt_cache` directory — lives in [`crate::persist`]: a
//!   versioned, integrity-checked store the coordinator consults before
//!   running the pipeline and the backends use for compiled tapes and
//!   HLO text.

use crate::ir::implir::StencilIr;
use anyhow::Result;
use std::collections::HashMap;
use std::sync::Arc;

/// In-memory cache of analyzed stencils keyed by fingerprint.
///
/// Entries are handed out as `Arc<StencilIr>`: a cache hit is a refcount
/// bump, never a deep copy of the IR, and every [`crate::coordinator::Stencil`]
/// handle compiled from the same definition shares one analyzed artifact.
#[derive(Default)]
pub struct StencilCache {
    by_fingerprint: HashMap<u64, Arc<StencilIr>>,
    pub hits: usize,
    pub misses: usize,
}

impl StencilCache {
    pub fn new() -> Self {
        Self::default()
    }

    /// Get an analyzed stencil, or analyze it with `f` and memoize.
    pub fn get_or_insert(
        &mut self,
        fingerprint: u64,
        f: impl FnOnce() -> Result<StencilIr>,
    ) -> Result<Arc<StencilIr>> {
        if self.by_fingerprint.contains_key(&fingerprint) {
            self.hits += 1;
        } else {
            self.misses += 1;
            let ir = f()?;
            self.by_fingerprint.insert(fingerprint, Arc::new(ir));
        }
        Ok(self.by_fingerprint[&fingerprint].clone())
    }

    pub fn len(&self) -> usize {
        self.by_fingerprint.len()
    }

    pub fn is_empty(&self) -> bool {
        self.by_fingerprint.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::compile_source;
    use std::collections::BTreeMap;

    const SRC: &str = "stencil c(a: Field<f64>, b: Field<f64>) {\n\
        with computation(PARALLEL), interval(...) { b = a; }\n\
    }";

    #[test]
    fn stencil_cache_hits_on_same_fingerprint() {
        let ir = compile_source(SRC, "c", &BTreeMap::new()).unwrap();
        let fp = ir.fingerprint;
        let mut cache = StencilCache::new();
        cache.get_or_insert(fp, || Ok(ir.clone())).unwrap();
        cache
            .get_or_insert(fp, || panic!("should not recompile"))
            .unwrap();
        assert_eq!(cache.hits, 1);
        assert_eq!(cache.misses, 1);
        assert_eq!(cache.len(), 1);
    }

    #[test]
    fn hits_share_one_arc_no_deep_clone() {
        let ir = compile_source(SRC, "c", &BTreeMap::new()).unwrap();
        let fp = ir.fingerprint;
        let mut cache = StencilCache::new();
        let a = cache.get_or_insert(fp, || Ok(ir)).unwrap();
        let b = cache.get_or_insert(fp, || panic!("recompile")).unwrap();
        assert!(Arc::ptr_eq(&a, &b), "cache hit must not copy the IR");
    }
}
