//! Compilation caching (paper §2.3).
//!
//! GT4Py "provides a caching mechanism to create unique hash identifiers
//! for every stencil implementation ... based on fingerprinting in such a
//! way that code reformatting would not trigger a new compilation."
//!
//! gt4rs splits this into:
//! * the fingerprint itself — [`crate::analysis::fingerprint_ir`], a FNV-1a
//!   over the canonical (formatting-free) implementation IR including the
//!   folded external values, the optimizer's stage metadata (fusion
//!   groups, temporary storage classes) and the pass configuration tag —
//!   so artifacts compiled at different opt levels never share a slot;
//! * an in-memory stencil cache ([`StencilCache`]) used by the coordinator
//!   so re-compiling an unchanged source is a hash lookup;
//! * an on-disk artifact store ([`DiskCache`]) keyed by fingerprint, used
//!   to persist generated HLO text across processes (the analog of
//!   GT4Py's `.gt_cache` directory).

use crate::ir::implir::StencilIr;
use anyhow::{Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Arc;

/// In-memory cache of analyzed stencils keyed by fingerprint.
///
/// Entries are handed out as `Arc<StencilIr>`: a cache hit is a refcount
/// bump, never a deep copy of the IR, and every [`crate::coordinator::Stencil`]
/// handle compiled from the same definition shares one analyzed artifact.
#[derive(Default)]
pub struct StencilCache {
    by_fingerprint: HashMap<u64, Arc<StencilIr>>,
    pub hits: usize,
    pub misses: usize,
}

impl StencilCache {
    pub fn new() -> Self {
        Self::default()
    }

    /// Get an analyzed stencil, or analyze it with `f` and memoize.
    pub fn get_or_insert(
        &mut self,
        fingerprint: u64,
        f: impl FnOnce() -> Result<StencilIr>,
    ) -> Result<Arc<StencilIr>> {
        if self.by_fingerprint.contains_key(&fingerprint) {
            self.hits += 1;
        } else {
            self.misses += 1;
            let ir = f()?;
            self.by_fingerprint.insert(fingerprint, Arc::new(ir));
        }
        Ok(self.by_fingerprint[&fingerprint].clone())
    }

    pub fn len(&self) -> usize {
        self.by_fingerprint.len()
    }

    pub fn is_empty(&self) -> bool {
        self.by_fingerprint.is_empty()
    }
}

/// On-disk cache directory: text blobs keyed by `(kind, fingerprint)`.
pub struct DiskCache {
    root: PathBuf,
}

impl DiskCache {
    /// Default location, overridable with `GT4RS_CACHE_DIR`.
    pub fn default_dir() -> PathBuf {
        std::env::var("GT4RS_CACHE_DIR")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from(".gt4rs_cache"))
    }

    pub fn new(root: impl AsRef<Path>) -> Result<DiskCache> {
        let root = root.as_ref().to_path_buf();
        std::fs::create_dir_all(&root)
            .with_context(|| format!("creating cache dir {}", root.display()))?;
        Ok(DiskCache { root })
    }

    fn path(&self, kind: &str, fingerprint: u64) -> PathBuf {
        self.root.join(format!("{kind}_{fingerprint:016x}.txt"))
    }

    pub fn get(&self, kind: &str, fingerprint: u64) -> Option<String> {
        std::fs::read_to_string(self.path(kind, fingerprint)).ok()
    }

    pub fn put(&self, kind: &str, fingerprint: u64, data: &str) -> Result<()> {
        let p = self.path(kind, fingerprint);
        // Write-then-rename for atomicity under concurrent builds.
        let tmp = p.with_extension("tmp");
        std::fs::write(&tmp, data)
            .with_context(|| format!("writing cache file {}", tmp.display()))?;
        std::fs::rename(&tmp, &p)
            .with_context(|| format!("publishing cache file {}", p.display()))?;
        Ok(())
    }

    pub fn contains(&self, kind: &str, fingerprint: u64) -> bool {
        self.path(kind, fingerprint).is_file()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::compile_source;
    use std::collections::BTreeMap;

    const SRC: &str = "stencil c(a: Field<f64>, b: Field<f64>) {\n\
        with computation(PARALLEL), interval(...) { b = a; }\n\
    }";

    #[test]
    fn stencil_cache_hits_on_same_fingerprint() {
        let ir = compile_source(SRC, "c", &BTreeMap::new()).unwrap();
        let fp = ir.fingerprint;
        let mut cache = StencilCache::new();
        cache.get_or_insert(fp, || Ok(ir.clone())).unwrap();
        cache
            .get_or_insert(fp, || panic!("should not recompile"))
            .unwrap();
        assert_eq!(cache.hits, 1);
        assert_eq!(cache.misses, 1);
        assert_eq!(cache.len(), 1);
    }

    #[test]
    fn hits_share_one_arc_no_deep_clone() {
        let ir = compile_source(SRC, "c", &BTreeMap::new()).unwrap();
        let fp = ir.fingerprint;
        let mut cache = StencilCache::new();
        let a = cache.get_or_insert(fp, || Ok(ir)).unwrap();
        let b = cache.get_or_insert(fp, || panic!("recompile")).unwrap();
        assert!(Arc::ptr_eq(&a, &b), "cache hit must not copy the IR");
    }

    #[test]
    fn disk_cache_roundtrip() {
        let dir = std::env::temp_dir().join(format!("gt4rs_cache_test_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let cache = DiskCache::new(&dir).unwrap();
        assert!(!cache.contains("hlo", 42));
        assert_eq!(cache.get("hlo", 42), None);
        cache.put("hlo", 42, "HloModule m").unwrap();
        assert!(cache.contains("hlo", 42));
        assert_eq!(cache.get("hlo", 42).unwrap(), "HloModule m");
        // Different kind or fingerprint miss.
        assert!(!cache.contains("hlo", 43));
        assert!(!cache.contains("cpp", 42));
        let _ = std::fs::remove_dir_all(&dir);
    }
}
