//! Minimal hand-rolled JSON: emission and recursive-descent parsing.
//!
//! The offline vendored crate set has no serde, and this repo needs JSON
//! in exactly two shapes: the CLI's `--json` output (consumed by the
//! perf-trajectory tooling and re-parsed by the recursive-descent checker
//! in `tests/integration_cli.rs`) and the `repro serve` newline-delimited
//! wire protocol. Both go through this one module so there is a single
//! escaping/number policy to validate.
//!
//! Emission is string-building ([`Obj`], [`array`], [`num_f64`]); parsing
//! ([`parse`] → [`Value`]) is a strict recursive-descent reader of one
//! complete JSON document. Numbers are read as `f64` — integer consumers
//! use [`Value::as_u64`], which rejects fractional values; `u64` values
//! that must survive bit-exactly (fingerprints, `f64::to_bits`) travel as
//! hex *strings*, never as JSON numbers.

use std::fmt::Write as _;

// ---------------------------------------------------------------------------
// Emission
// ---------------------------------------------------------------------------

/// `s` with JSON string escaping applied (quotes, backslash, control
/// characters — enough that `python3 -m json.tool` round-trips it).
pub fn escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out
}

/// `s` as a JSON string token (escaped, quoted).
pub fn string(s: &str) -> String {
    format!("\"{}\"", escape(s))
}

/// A f64 as a JSON value: exponent form for finite numbers, a quoted
/// string for NaN/inf (which are not valid JSON numbers).
pub fn num_f64(v: f64) -> String {
    if v.is_finite() {
        format!("{v:e}")
    } else {
        format!("\"{v}\"")
    }
}

/// A JSON array from already-rendered element strings.
pub fn array<S: AsRef<str>>(items: &[S]) -> String {
    let mut out = String::from("[");
    for (i, it) in items.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str(it.as_ref());
    }
    out.push(']');
    out
}

/// Builder for one JSON object, keys in insertion order.
///
/// ```
/// # use gt4rs::jsonw::Obj;
/// let line = Obj::new().str("op", "run").int("iters", 3).bool("ok", true).finish();
/// assert_eq!(line, r#"{"op":"run","iters":3,"ok":true}"#);
/// ```
#[derive(Default)]
pub struct Obj {
    body: String,
}

impl Obj {
    pub fn new() -> Obj {
        Obj::default()
    }

    fn key(&mut self, key: &str) {
        if !self.body.is_empty() {
            self.body.push(',');
        }
        self.body.push_str(&string(key));
        self.body.push(':');
    }

    /// A string-valued member (escaped).
    pub fn str(mut self, key: &str, value: &str) -> Obj {
        self.key(key);
        self.body.push_str(&string(value));
        self
    }

    /// A member whose value is already rendered JSON (nested object,
    /// array, ...). The caller vouches for its validity.
    pub fn raw(mut self, key: &str, value: &str) -> Obj {
        self.key(key);
        self.body.push_str(value);
        self
    }

    pub fn bool(mut self, key: &str, value: bool) -> Obj {
        self.key(key);
        self.body.push_str(if value { "true" } else { "false" });
        self
    }

    pub fn int<I: Into<i128>>(mut self, key: &str, value: I) -> Obj {
        self.key(key);
        let _ = write!(self.body, "{}", value.into());
        self
    }

    /// A f64 member via [`num_f64`] (finite → number, else quoted string).
    pub fn f64(mut self, key: &str, value: f64) -> Obj {
        self.key(key);
        self.body.push_str(&num_f64(value));
        self
    }

    pub fn finish(self) -> String {
        format!("{{{}}}", self.body)
    }
}

// ---------------------------------------------------------------------------
// Parsing
// ---------------------------------------------------------------------------

/// A parsed JSON value. Object member order is preserved; duplicate keys
/// keep their first occurrence under [`Value::get`].
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(Vec<(String, Value)>),
}

impl Value {
    /// Object member lookup (None for non-objects and missing keys).
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(members) => members.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(v) => Some(*v),
            _ => None,
        }
    }

    /// The number as an exact unsigned integer (rejects fractions,
    /// negatives, and magnitudes past 2^53 where f64 loses exactness).
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Value::Num(v)
                if *v >= 0.0 && v.fract() == 0.0 && *v <= 9_007_199_254_740_992.0 =>
            {
                Some(*v as u64)
            }
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(items) => Some(items),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&[(String, Value)]> {
        match self {
            Value::Obj(members) => Some(members),
            _ => None,
        }
    }
}

/// Parse one complete JSON document (trailing whitespace allowed, trailing
/// garbage rejected).
pub fn parse(input: &str) -> Result<Value, String> {
    let bytes = input.as_bytes();
    let mut pos = 0usize;
    let value = parse_value(bytes, &mut pos)?;
    skip_ws(bytes, &mut pos);
    if pos != bytes.len() {
        return Err(format!("trailing garbage at byte {pos}"));
    }
    Ok(value)
}

fn skip_ws(bytes: &[u8], pos: &mut usize) {
    while *pos < bytes.len() && matches!(bytes[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn expect(bytes: &[u8], pos: &mut usize, c: u8) -> Result<(), String> {
    skip_ws(bytes, pos);
    if *pos < bytes.len() && bytes[*pos] == c {
        *pos += 1;
        Ok(())
    } else {
        Err(format!("expected `{}` at byte {}", c as char, *pos))
    }
}

fn parse_value(bytes: &[u8], pos: &mut usize) -> Result<Value, String> {
    skip_ws(bytes, pos);
    match bytes.get(*pos) {
        None => Err("unexpected end of input".to_string()),
        Some(b'{') => parse_obj(bytes, pos),
        Some(b'[') => parse_arr(bytes, pos),
        Some(b'"') => Ok(Value::Str(parse_string(bytes, pos)?)),
        Some(b't') => parse_lit(bytes, pos, "true", Value::Bool(true)),
        Some(b'f') => parse_lit(bytes, pos, "false", Value::Bool(false)),
        Some(b'n') => parse_lit(bytes, pos, "null", Value::Null),
        Some(_) => parse_num(bytes, pos),
    }
}

fn parse_lit(bytes: &[u8], pos: &mut usize, lit: &str, value: Value) -> Result<Value, String> {
    if bytes[*pos..].starts_with(lit.as_bytes()) {
        *pos += lit.len();
        Ok(value)
    } else {
        Err(format!("bad literal at byte {}", *pos))
    }
}

fn parse_num(bytes: &[u8], pos: &mut usize) -> Result<Value, String> {
    let start = *pos;
    if matches!(bytes.get(*pos), Some(b'-')) {
        *pos += 1;
    }
    while matches!(
        bytes.get(*pos),
        Some(b'0'..=b'9') | Some(b'.') | Some(b'e') | Some(b'E') | Some(b'+') | Some(b'-')
    ) {
        *pos += 1;
    }
    let text = std::str::from_utf8(&bytes[start..*pos]).map_err(|e| e.to_string())?;
    text.parse::<f64>()
        .map(Value::Num)
        .map_err(|_| format!("bad number `{text}` at byte {start}"))
}

fn parse_string(bytes: &[u8], pos: &mut usize) -> Result<String, String> {
    expect(bytes, pos, b'"')?;
    let mut out = String::new();
    loop {
        match bytes.get(*pos) {
            None => return Err("unterminated string".to_string()),
            Some(b'"') => {
                *pos += 1;
                return Ok(out);
            }
            Some(b'\\') => {
                *pos += 1;
                match bytes.get(*pos) {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'b') => out.push('\u{0008}'),
                    Some(b'f') => out.push('\u{000c}'),
                    Some(b'u') => {
                        let hex = bytes
                            .get(*pos + 1..*pos + 5)
                            .ok_or_else(|| "truncated \\u escape".to_string())?;
                        let hex = std::str::from_utf8(hex).map_err(|e| e.to_string())?;
                        let code =
                            u32::from_str_radix(hex, 16).map_err(|e| e.to_string())?;
                        // Surrogate pairs are not needed by this repo's
                        // emitters; map lone surrogates to U+FFFD.
                        out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        *pos += 4;
                    }
                    _ => return Err(format!("bad escape at byte {}", *pos)),
                }
                *pos += 1;
            }
            Some(_) => {
                // Consume one full UTF-8 scalar (the input is &str, so
                // boundaries are valid).
                let rest = std::str::from_utf8(&bytes[*pos..]).map_err(|e| e.to_string())?;
                let c = rest.chars().next().unwrap();
                out.push(c);
                *pos += c.len_utf8();
            }
        }
    }
}

fn parse_arr(bytes: &[u8], pos: &mut usize) -> Result<Value, String> {
    expect(bytes, pos, b'[')?;
    let mut items = Vec::new();
    skip_ws(bytes, pos);
    if matches!(bytes.get(*pos), Some(b']')) {
        *pos += 1;
        return Ok(Value::Arr(items));
    }
    loop {
        items.push(parse_value(bytes, pos)?);
        skip_ws(bytes, pos);
        match bytes.get(*pos) {
            Some(b',') => *pos += 1,
            Some(b']') => {
                *pos += 1;
                return Ok(Value::Arr(items));
            }
            _ => return Err(format!("expected `,` or `]` at byte {}", *pos)),
        }
    }
}

fn parse_obj(bytes: &[u8], pos: &mut usize) -> Result<Value, String> {
    expect(bytes, pos, b'{')?;
    let mut members = Vec::new();
    skip_ws(bytes, pos);
    if matches!(bytes.get(*pos), Some(b'}')) {
        *pos += 1;
        return Ok(Value::Obj(members));
    }
    loop {
        skip_ws(bytes, pos);
        let key = parse_string(bytes, pos)?;
        expect(bytes, pos, b':')?;
        let value = parse_value(bytes, pos)?;
        members.push((key, value));
        skip_ws(bytes, pos);
        match bytes.get(*pos) {
            Some(b',') => *pos += 1,
            Some(b'}') => {
                *pos += 1;
                return Ok(Value::Obj(members));
            }
            _ => return Err(format!("expected `,` or `}}` at byte {}", *pos)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn obj_builder_emits_valid_json() {
        let line = Obj::new()
            .str("op", "run")
            .int("iters", 3)
            .bool("ok", true)
            .f64("sum", 1.5)
            .raw("domain", &array(&["4", "4", "2"]))
            .str("weird", "a\"b\\c\nd\u{1}")
            .finish();
        let v = parse(&line).unwrap();
        assert_eq!(v.get("op").unwrap().as_str(), Some("run"));
        assert_eq!(v.get("iters").unwrap().as_u64(), Some(3));
        assert_eq!(v.get("ok").unwrap().as_bool(), Some(true));
        assert_eq!(v.get("sum").unwrap().as_f64(), Some(1.5));
        assert_eq!(
            v.get("domain").unwrap().as_arr().unwrap().len(),
            3,
            "{line}"
        );
        assert_eq!(v.get("weird").unwrap().as_str(), Some("a\"b\\c\nd\u{1}"));
        assert_eq!(v.get("missing"), None);
    }

    #[test]
    fn num_f64_policy() {
        assert_eq!(parse(&num_f64(0.25)).unwrap().as_f64(), Some(0.25));
        // Non-finite values become strings, keeping the document valid.
        assert_eq!(parse(&num_f64(f64::NAN)).unwrap().as_str(), Some("NaN"));
        assert_eq!(parse(&num_f64(f64::INFINITY)).unwrap().as_str(), Some("inf"));
        // Exponent-form round-trip is exact for finite doubles.
        let v = -1.2345678901234567e-89;
        assert_eq!(parse(&num_f64(v)).unwrap().as_f64(), Some(v));
    }

    #[test]
    fn parser_accepts_standard_documents() {
        let v = parse(r#" { "a" : [1, -2.5, 1e3], "b": {"c": null}, "d": false } "#).unwrap();
        let a = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(a[0].as_u64(), Some(1));
        assert_eq!(a[1].as_f64(), Some(-2.5));
        assert_eq!(a[2].as_f64(), Some(1000.0));
        assert_eq!(v.get("b").unwrap().get("c"), Some(&Value::Null));
        assert_eq!(v.get("d").unwrap().as_bool(), Some(false));
        assert_eq!(parse("[]").unwrap(), Value::Arr(vec![]));
        assert_eq!(parse("{}").unwrap(), Value::Obj(vec![]));
    }

    #[test]
    fn parser_rejects_malformed_documents() {
        for bad in [
            "", "{", "[1,", "{\"a\"}", "{\"a\":}", "tru", "1 2", "{\"a\":1}x", "\"abc",
            "nul", "{'a':1}",
        ] {
            assert!(parse(bad).is_err(), "`{bad}` should not parse");
        }
    }

    #[test]
    fn as_u64_is_exactness_checked() {
        assert_eq!(parse("42").unwrap().as_u64(), Some(42));
        assert_eq!(parse("42.5").unwrap().as_u64(), None);
        assert_eq!(parse("-1").unwrap().as_u64(), None);
        assert_eq!(parse("1e300").unwrap().as_u64(), None);
    }
}
