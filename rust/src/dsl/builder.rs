//! Programmatic stencil construction — the analog of GTScript being
//! *embedded* in the host language. Where a GT4Py user decorates a Python
//! function, a gt4rs user either writes `.gts` text (see `parser`) or builds
//! the definition IR directly with this fluent API:
//!
//! ```no_run
//! // (no_run: rustdoc test binaries miss the PJRT rpath in this image)
//! use gt4rs::dsl::builder::*;
//! let stencil = stencil("scale")
//!     .field("inp", gt4rs::dsl::ast::DType::F64)
//!     .field("out", gt4rs::dsl::ast::DType::F64)
//!     .scalar("alpha", gt4rs::dsl::ast::DType::F64)
//!     .computation(parallel().interval_full(|b| {
//!         b.assign("out", mul(scalar("alpha"), at("inp", [0, 0, 0])));
//!     }))
//!     .build()
//!     .unwrap();
//! assert_eq!(stencil.name, "scale");
//! ```

use super::ast::*;
use super::span::{CResult, CompileError, Span};

// ---- expression helpers ----

pub fn lit(v: f64) -> Expr {
    Expr::Float(v)
}

/// Field access at an offset.
pub fn at(name: &str, offset: Offset) -> Expr {
    Expr::Field { name: name.to_string(), offset, span: Span::default() }
}

/// Field access at the evaluation point.
pub fn here(name: &str) -> Expr {
    at(name, [0, 0, 0])
}

pub fn scalar(name: &str) -> Expr {
    Expr::Scalar(name.to_string())
}

pub fn external(name: &str) -> Expr {
    Expr::External(name.to_string(), Span::default())
}

/// Call a GTScript function defined in the same module.
pub fn call(name: &str, args: Vec<Expr>) -> Expr {
    Expr::Call { name: name.to_string(), args, span: Span::default() }
}

pub fn add(a: Expr, b: Expr) -> Expr {
    Expr::binary(BinOp::Add, a, b)
}
pub fn sub(a: Expr, b: Expr) -> Expr {
    Expr::binary(BinOp::Sub, a, b)
}
pub fn mul(a: Expr, b: Expr) -> Expr {
    Expr::binary(BinOp::Mul, a, b)
}
pub fn div(a: Expr, b: Expr) -> Expr {
    Expr::binary(BinOp::Div, a, b)
}
pub fn neg(a: Expr) -> Expr {
    Expr::Unary { op: UnOp::Neg, operand: Box::new(a) }
}
pub fn gt(a: Expr, b: Expr) -> Expr {
    Expr::binary(BinOp::Gt, a, b)
}
pub fn lt(a: Expr, b: Expr) -> Expr {
    Expr::binary(BinOp::Lt, a, b)
}
pub fn ge(a: Expr, b: Expr) -> Expr {
    Expr::binary(BinOp::Ge, a, b)
}
pub fn le(a: Expr, b: Expr) -> Expr {
    Expr::binary(BinOp::Le, a, b)
}
pub fn select(cond: Expr, then_e: Expr, else_e: Expr) -> Expr {
    Expr::ternary(cond, then_e, else_e)
}
pub fn bmin(a: Expr, b: Expr) -> Expr {
    Expr::Builtin { func: Builtin::Min, args: vec![a, b] }
}
pub fn bmax(a: Expr, b: Expr) -> Expr {
    Expr::Builtin { func: Builtin::Max, args: vec![a, b] }
}
pub fn babs(a: Expr) -> Expr {
    Expr::Builtin { func: Builtin::Abs, args: vec![a] }
}
pub fn bsqrt(a: Expr) -> Expr {
    Expr::Builtin { func: Builtin::Sqrt, args: vec![a] }
}

// ---- statement/body builders ----

/// Collects statements for an interval body.
#[derive(Default)]
pub struct BodyBuilder {
    stmts: Vec<Stmt>,
}

impl BodyBuilder {
    pub fn assign(&mut self, target: &str, value: Expr) -> &mut Self {
        self.stmts.push(Stmt::Assign {
            target: target.to_string(),
            value,
            span: Span::default(),
        });
        self
    }

    pub fn if_else(
        &mut self,
        cond: Expr,
        then_f: impl FnOnce(&mut BodyBuilder),
        else_f: impl FnOnce(&mut BodyBuilder),
    ) -> &mut Self {
        let mut tb = BodyBuilder::default();
        then_f(&mut tb);
        let mut eb = BodyBuilder::default();
        else_f(&mut eb);
        self.stmts.push(Stmt::If {
            cond,
            then_body: tb.stmts,
            else_body: eb.stmts,
            span: Span::default(),
        });
        self
    }
}

/// Builder for one `with computation(...)` block.
pub struct ComputationBuilder {
    policy: IterationPolicy,
    blocks: Vec<IntervalBlock>,
}

pub fn parallel() -> ComputationBuilder {
    ComputationBuilder { policy: IterationPolicy::Parallel, blocks: vec![] }
}
pub fn forward() -> ComputationBuilder {
    ComputationBuilder { policy: IterationPolicy::Forward, blocks: vec![] }
}
pub fn backward() -> ComputationBuilder {
    ComputationBuilder { policy: IterationPolicy::Backward, blocks: vec![] }
}

impl ComputationBuilder {
    /// Add an interval region covering the full axis.
    pub fn interval_full(self, f: impl FnOnce(&mut BodyBuilder)) -> Self {
        self.interval(Interval::full(), f)
    }

    /// Add an interval region with Python-style indices (`hi=None` via
    /// `i64::MAX` is not supported here — use `interval_to_end`).
    pub fn interval_idx(self, lo: i32, hi: i32, f: impl FnOnce(&mut BodyBuilder)) -> Self {
        self.interval(
            Interval::new(LevelBound::from_index(lo), LevelBound::from_index(hi)),
            f,
        )
    }

    /// `[lo, K)` region.
    pub fn interval_to_end(self, lo: i32, f: impl FnOnce(&mut BodyBuilder)) -> Self {
        self.interval(Interval::new(LevelBound::from_index(lo), LevelBound::FromEnd(0)), f)
    }

    pub fn interval(mut self, interval: Interval, f: impl FnOnce(&mut BodyBuilder)) -> Self {
        let mut b = BodyBuilder::default();
        f(&mut b);
        self.blocks.push(IntervalBlock { interval, body: b.stmts, span: Span::default() });
        self
    }

    fn finish(self) -> Computation {
        Computation { policy: self.policy, blocks: self.blocks, span: Span::default() }
    }
}

/// Builder for a full stencil definition.
pub struct StencilBuilder {
    name: String,
    fields: Vec<FieldDecl>,
    scalars: Vec<ScalarDecl>,
    computations: Vec<Computation>,
}

pub fn stencil(name: &str) -> StencilBuilder {
    StencilBuilder {
        name: name.to_string(),
        fields: vec![],
        scalars: vec![],
        computations: vec![],
    }
}

impl StencilBuilder {
    pub fn field(mut self, name: &str, dtype: DType) -> Self {
        self.fields.push(FieldDecl {
            name: name.to_string(),
            dtype,
            span: Span::default(),
        });
        self
    }

    pub fn scalar(mut self, name: &str, dtype: DType) -> Self {
        self.scalars.push(ScalarDecl {
            name: name.to_string(),
            dtype,
            span: Span::default(),
        });
        self
    }

    pub fn computation(mut self, c: ComputationBuilder) -> Self {
        self.computations.push(c.finish());
        self
    }

    pub fn build(self) -> CResult<StencilDef> {
        if self.computations.is_empty() {
            return Err(CompileError::new("build", "stencil has no computations"));
        }
        let mut seen = std::collections::HashSet::new();
        for n in self.fields.iter().map(|f| &f.name).chain(self.scalars.iter().map(|s| &s.name))
        {
            if !seen.insert(n.clone()) {
                return Err(CompileError::new("build", format!("duplicate parameter `{n}`")));
            }
        }
        Ok(StencilDef {
            name: self.name,
            fields: self.fields,
            scalars: self.scalars,
            externals: vec![],
            computations: self.computations,
            span: Span::default(),
        })
    }
}

/// Builder for a module holding functions + stencils.
#[derive(Default)]
pub struct ModuleBuilder {
    module: Module,
}

pub fn module() -> ModuleBuilder {
    ModuleBuilder::default()
}

impl ModuleBuilder {
    pub fn function(mut self, name: &str, params: &[&str], ret: Expr) -> Self {
        self.module.functions.push(FunctionDef {
            name: name.to_string(),
            params: params.iter().map(|s| s.to_string()).collect(),
            bindings: vec![],
            ret,
            span: Span::default(),
        });
        self
    }

    pub fn stencil(mut self, s: StencilDef) -> Self {
        self.module.stencils.push(s);
        self
    }

    pub fn extern_default(mut self, name: &str, value: f64) -> Self {
        self.module.extern_defaults.push((name.to_string(), value));
        self
    }

    pub fn build(self) -> Module {
        self.module
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builds_copy_stencil() {
        let s = stencil("copy")
            .field("a", DType::F64)
            .field("b", DType::F64)
            .computation(parallel().interval_full(|b| {
                b.assign("b", here("a"));
            }))
            .build()
            .unwrap();
        assert_eq!(s.computations[0].blocks[0].body.len(), 1);
    }

    #[test]
    fn builder_equivalent_to_parser() {
        let parsed = super::super::parser::parse_module(
            "stencil axpy(x: Field<f64>, y: Field<f64>; alpha: f64) {\n\
               with computation(PARALLEL), interval(...) { y = y + alpha * x; }\n\
             }",
        )
        .unwrap();
        let built = stencil("axpy")
            .field("x", DType::F64)
            .field("y", DType::F64)
            .scalar("alpha", DType::F64)
            .computation(parallel().interval_full(|b| {
                b.assign(
                    "y",
                    add(
                        Expr::Name("y".into(), Span::default()),
                        mul(
                            Expr::Name("alpha".into(), Span::default()),
                            Expr::Name("x".into(), Span::default()),
                        ),
                    ),
                );
            }))
            .build()
            .unwrap();
        // Structural equivalence up to spans is established by the canonical
        // fingerprint; here we compare the coarse shape.
        let p = &parsed.stencils[0];
        assert_eq!(p.name, built.name);
        assert_eq!(p.fields.len(), built.fields.len());
        assert_eq!(p.scalars.len(), built.scalars.len());
        assert_eq!(p.computations.len(), built.computations.len());
    }

    #[test]
    fn duplicate_params_rejected() {
        let r = stencil("s")
            .field("a", DType::F64)
            .scalar("a", DType::F64)
            .computation(parallel().interval_full(|b| {
                b.assign("a", lit(0.0));
            }))
            .build();
        assert!(r.is_err());
    }

    #[test]
    fn if_else_builder() {
        let s = stencil("s")
            .field("a", DType::F64)
            .computation(parallel().interval_full(|b| {
                b.if_else(
                    gt(here("a"), lit(0.0)),
                    |t| {
                        t.assign("a", lit(1.0));
                    },
                    |e| {
                        e.assign("a", lit(-1.0));
                    },
                );
            }))
            .build()
            .unwrap();
        assert!(matches!(s.computations[0].blocks[0].body[0], Stmt::If { .. }));
    }
}
