//! Recursive-descent parser for the GTScript-RS surface syntax.
//!
//! Grammar (EBNF-ish):
//! ```text
//! module        := (extern_decl | function_def | stencil_def)*
//! extern_decl   := "extern" IDENT ("=" number)? ";"
//! function_def  := "function" IDENT "(" [IDENT ("," IDENT)*] ")"
//!                  "{" (assign ";")* "return" expr ";" "}"
//! stencil_def   := "stencil" IDENT "(" field_decls [";" scalar_decls] ")"
//!                  "{" computation+ "}"
//! field_decls   := IDENT ":" "Field" "<" ("f32"|"f64") ">" ("," ...)*
//! scalar_decls  := IDENT ":" ("f32"|"f64") ("," ...)*
//! computation   := "with" "computation" "(" POLICY ")"
//!                  ( "," "interval" "(" ispec ")" block
//!                  | "{" ("interval" "(" ispec ")" block)+ "}" )
//! ispec         := "..." | bound "," bound
//! bound         := INT | "-" INT | "None"
//! block         := "{" stmt* "}"
//! stmt          := IDENT "=" expr ";" | "if" expr block ["else" (block|if)]
//! expr          := or_expr ["?" expr ":" expr]
//! or_expr       := and_expr ("or" and_expr)*
//! and_expr      := not_expr ("and" not_expr)*
//! not_expr      := "not" not_expr | cmp_expr
//! cmp_expr      := add_expr [("<"|"<="|">"|">="|"=="|"!=") add_expr]
//! add_expr      := mul_expr (("+"|"-") mul_expr)*
//! mul_expr      := unary (("*"|"/"|"%") unary)*
//! unary         := "-" unary | primary
//! primary       := number | "true" | "false" | "(" expr ")"
//!                | IDENT [ "[" INT "," INT "," INT "]" | "(" args ")" ]
//! ```
//!
//! The GTScript-in-Python example of the paper's Figure 1 maps 1:1 onto this
//! syntax; see `rust/src/stdlib/hdiff.gts`.

use super::ast::*;
use super::lexer::{Lexer, Tok, Token};
use super::span::{CResult, CompileError, Span};

pub struct Parser {
    toks: Vec<Token>,
    pos: usize,
}

/// Parse a `.gts` module source.
pub fn parse_module(src: &str) -> CResult<Module> {
    let toks = Lexer::tokenize(src)?;
    let mut p = Parser { toks, pos: 0 };
    p.module()
}

/// Parse a single expression (used by tests and the REPL-ish CLI).
pub fn parse_expr(src: &str) -> CResult<Expr> {
    let toks = Lexer::tokenize(src)?;
    let mut p = Parser { toks, pos: 0 };
    let e = p.expr()?;
    p.expect(Tok::Eof)?;
    Ok(e)
}

impl Parser {
    fn peek(&self) -> &Tok {
        &self.toks[self.pos].tok
    }

    fn peek_span(&self) -> Span {
        self.toks[self.pos].span
    }

    fn bump(&mut self) -> Token {
        let t = self.toks[self.pos].clone();
        if self.pos + 1 < self.toks.len() {
            self.pos += 1;
        }
        t
    }

    fn eat(&mut self, tok: &Tok) -> bool {
        if self.peek() == tok {
            self.bump();
            true
        } else {
            false
        }
    }

    fn expect(&mut self, tok: Tok) -> CResult<Token> {
        if self.peek() == &tok {
            Ok(self.bump())
        } else {
            Err(CompileError::with_span(
                "parse",
                format!("expected {:?}, found {}", tok, self.peek().describe()),
                self.peek_span(),
            ))
        }
    }

    fn expect_ident(&mut self) -> CResult<(String, Span)> {
        match self.peek().clone() {
            Tok::Ident(s) => {
                let t = self.bump();
                Ok((s, t.span))
            }
            other => Err(CompileError::with_span(
                "parse",
                format!("expected identifier, found {}", other.describe()),
                self.peek_span(),
            )),
        }
    }

    fn module(&mut self) -> CResult<Module> {
        let mut m = Module::default();
        loop {
            match self.peek() {
                Tok::Eof => break,
                Tok::KwExtern => {
                    self.bump();
                    let (name, _) = self.expect_ident()?;
                    let mut value = f64::NAN;
                    if self.eat(&Tok::Assign) {
                        value = self.number_literal()?;
                    }
                    self.expect(Tok::Semi)?;
                    m.extern_defaults.push((name, value));
                }
                Tok::KwFunction => {
                    let f = self.function_def()?;
                    if m.function(&f.name).is_some() {
                        return Err(CompileError::with_span(
                            "parse",
                            format!("duplicate function `{}`", f.name),
                            f.span,
                        ));
                    }
                    m.functions.push(f);
                }
                Tok::KwStencil => {
                    let s = self.stencil_def()?;
                    if m.stencil(&s.name).is_some() {
                        return Err(CompileError::with_span(
                            "parse",
                            format!("duplicate stencil `{}`", s.name),
                            s.span,
                        ));
                    }
                    m.stencils.push(s);
                }
                other => {
                    return Err(CompileError::with_span(
                        "parse",
                        format!(
                            "expected `stencil`, `function` or `extern`, found {}",
                            other.describe()
                        ),
                        self.peek_span(),
                    ))
                }
            }
        }
        Ok(m)
    }

    fn number_literal(&mut self) -> CResult<f64> {
        let neg = self.eat(&Tok::Minus);
        let v = match self.peek().clone() {
            Tok::Float(v) => {
                self.bump();
                v
            }
            Tok::Int(v) => {
                self.bump();
                v as f64
            }
            other => {
                return Err(CompileError::with_span(
                    "parse",
                    format!("expected numeric literal, found {}", other.describe()),
                    self.peek_span(),
                ))
            }
        };
        Ok(if neg { -v } else { v })
    }

    fn function_def(&mut self) -> CResult<FunctionDef> {
        let kw = self.expect(Tok::KwFunction)?;
        let (name, _) = self.expect_ident()?;
        self.expect(Tok::LParen)?;
        let mut params = Vec::new();
        if self.peek() != &Tok::RParen {
            loop {
                let (p, pspan) = self.expect_ident()?;
                if params.contains(&p) {
                    return Err(CompileError::with_span(
                        "parse",
                        format!("duplicate parameter `{p}`"),
                        pspan,
                    ));
                }
                params.push(p);
                if !self.eat(&Tok::Comma) {
                    break;
                }
            }
        }
        self.expect(Tok::RParen)?;
        self.expect(Tok::LBrace)?;
        let mut bindings = Vec::new();
        let ret;
        loop {
            if self.eat(&Tok::KwReturn) {
                ret = self.expr()?;
                self.expect(Tok::Semi)?;
                break;
            }
            let (target, _) = self.expect_ident()?;
            self.expect(Tok::Assign)?;
            let value = self.expr()?;
            self.expect(Tok::Semi)?;
            bindings.push((target, value));
        }
        let close = self.expect(Tok::RBrace)?;
        Ok(FunctionDef { name, params, bindings, ret, span: kw.span.merge(close.span) })
    }

    fn dtype(&mut self) -> CResult<DType> {
        let (name, span) = self.expect_ident()?;
        match name.as_str() {
            "f32" => Ok(DType::F32),
            "f64" => Ok(DType::F64),
            other => Err(CompileError::with_span(
                "parse",
                format!("unknown dtype `{other}` (expected f32 or f64)"),
                span,
            )),
        }
    }

    fn stencil_def(&mut self) -> CResult<StencilDef> {
        let kw = self.expect(Tok::KwStencil)?;
        let (name, _) = self.expect_ident()?;
        self.expect(Tok::LParen)?;

        let mut fields: Vec<FieldDecl> = Vec::new();
        let mut scalars: Vec<ScalarDecl> = Vec::new();
        let mut in_scalars = false;
        if self.peek() != &Tok::RParen {
            loop {
                let (pname, pspan) = self.expect_ident()?;
                if fields.iter().any(|f| f.name == pname)
                    || scalars.iter().any(|s| s.name == pname)
                {
                    return Err(CompileError::with_span(
                        "parse",
                        format!("duplicate parameter `{pname}`"),
                        pspan,
                    ));
                }
                self.expect(Tok::Colon)?;
                if !in_scalars {
                    // field decl: Field<dtype>
                    let (tyname, tyspan) = self.expect_ident()?;
                    if tyname != "Field" {
                        return Err(CompileError::with_span(
                            "parse",
                            format!(
                                "expected `Field<...>` before `;` separator, found `{tyname}`"
                            ),
                            tyspan,
                        ));
                    }
                    self.expect(Tok::Lt)?;
                    let dt = self.dtype()?;
                    self.expect(Tok::Gt)?;
                    fields.push(FieldDecl { name: pname, dtype: dt, span: pspan });
                } else {
                    let dt = self.dtype()?;
                    scalars.push(ScalarDecl { name: pname, dtype: dt, span: pspan });
                }
                if self.eat(&Tok::Comma) {
                    continue;
                }
                if self.eat(&Tok::Semi) {
                    if in_scalars {
                        return Err(CompileError::with_span(
                            "parse",
                            "only one `;` separator allowed in stencil signature",
                            self.peek_span(),
                        ));
                    }
                    in_scalars = true;
                    continue;
                }
                break;
            }
        }
        self.expect(Tok::RParen)?;
        self.expect(Tok::LBrace)?;
        let mut computations = Vec::new();
        while self.peek() == &Tok::KwWith {
            computations.push(self.computation()?);
        }
        let close = self.expect(Tok::RBrace)?;
        if computations.is_empty() {
            return Err(CompileError::with_span(
                "parse",
                format!("stencil `{name}` has no computations"),
                kw.span,
            ));
        }
        Ok(StencilDef {
            name,
            fields,
            scalars,
            externals: Vec::new(), // filled by the resolution pass
            computations,
            span: kw.span.merge(close.span),
        })
    }

    fn computation(&mut self) -> CResult<Computation> {
        let kw = self.expect(Tok::KwWith)?;
        self.expect(Tok::KwComputation)?;
        self.expect(Tok::LParen)?;
        let (pname, pspan) = self.expect_ident()?;
        let policy = match pname.as_str() {
            "PARALLEL" => IterationPolicy::Parallel,
            "FORWARD" => IterationPolicy::Forward,
            "BACKWARD" => IterationPolicy::Backward,
            other => {
                return Err(CompileError::with_span(
                    "parse",
                    format!("unknown iteration policy `{other}`"),
                    pspan,
                ))
            }
        };
        self.expect(Tok::RParen)?;

        let mut blocks = Vec::new();
        if self.eat(&Tok::Comma) {
            // single-interval shorthand: with computation(P), interval(...) { }
            blocks.push(self.interval_block()?);
        } else {
            self.expect(Tok::LBrace)?;
            while self.peek() == &Tok::KwInterval {
                blocks.push(self.interval_block()?);
            }
            self.expect(Tok::RBrace)?;
            if blocks.is_empty() {
                return Err(CompileError::with_span(
                    "parse",
                    "computation block contains no interval regions",
                    kw.span,
                ));
            }
        }
        let span = kw.span.merge(blocks.last().map(|b| b.span).unwrap_or(kw.span));
        Ok(Computation { policy, blocks, span })
    }

    fn interval_bound(&mut self) -> CResult<LevelBound> {
        match self.peek().clone() {
            Tok::KwNone => {
                self.bump();
                Ok(LevelBound::FromEnd(0))
            }
            Tok::Minus => {
                self.bump();
                match self.peek().clone() {
                    Tok::Int(v) => {
                        self.bump();
                        Ok(LevelBound::from_index(-(v as i32)))
                    }
                    other => Err(CompileError::with_span(
                        "parse",
                        format!("expected integer after `-`, found {}", other.describe()),
                        self.peek_span(),
                    )),
                }
            }
            Tok::Int(v) => {
                self.bump();
                Ok(LevelBound::from_index(v as i32))
            }
            other => Err(CompileError::with_span(
                "parse",
                format!("expected interval bound, found {}", other.describe()),
                self.peek_span(),
            )),
        }
    }

    fn interval_block(&mut self) -> CResult<IntervalBlock> {
        let kw = self.expect(Tok::KwInterval)?;
        self.expect(Tok::LParen)?;
        let interval = if self.eat(&Tok::Ellipsis) {
            Interval::full()
        } else {
            let lo = self.interval_bound()?;
            self.expect(Tok::Comma)?;
            let hi = self.interval_bound()?;
            Interval::new(lo, hi)
        };
        self.expect(Tok::RParen)?;
        if interval.statically_empty() {
            return Err(CompileError::with_span(
                "parse",
                format!("{interval} is empty for every axis size"),
                kw.span,
            ));
        }
        let (body, bspan) = self.block()?;
        if body.is_empty() {
            return Err(CompileError::with_span("parse", "empty interval body", kw.span));
        }
        Ok(IntervalBlock { interval, body, span: kw.span.merge(bspan) })
    }

    fn block(&mut self) -> CResult<(Vec<Stmt>, Span)> {
        let open = self.expect(Tok::LBrace)?;
        let mut stmts = Vec::new();
        while self.peek() != &Tok::RBrace {
            stmts.push(self.stmt()?);
        }
        let close = self.expect(Tok::RBrace)?;
        Ok((stmts, open.span.merge(close.span)))
    }

    fn stmt(&mut self) -> CResult<Stmt> {
        if self.peek() == &Tok::KwIf {
            return self.if_stmt();
        }
        let (target, tspan) = self.expect_ident()?;
        self.expect(Tok::Assign)?;
        let value = self.expr()?;
        let semi = self.expect(Tok::Semi)?;
        Ok(Stmt::Assign { target, value, span: tspan.merge(semi.span) })
    }

    fn if_stmt(&mut self) -> CResult<Stmt> {
        let kw = self.expect(Tok::KwIf)?;
        let cond = self.expr()?;
        let (then_body, mut span) = self.block()?;
        let mut else_body = Vec::new();
        if self.eat(&Tok::KwElse) {
            if self.peek() == &Tok::KwIf {
                let nested = self.if_stmt()?;
                else_body.push(nested);
            } else {
                let (eb, espan) = self.block()?;
                else_body = eb;
                span = span.merge(espan);
            }
        }
        Ok(Stmt::If { cond, then_body, else_body, span: kw.span.merge(span) })
    }

    // ---- expressions (precedence climbing) ----

    pub(crate) fn expr(&mut self) -> CResult<Expr> {
        let cond = self.or_expr()?;
        if self.eat(&Tok::Question) {
            let then_e = self.expr()?;
            self.expect(Tok::Colon)?;
            let else_e = self.expr()?;
            return Ok(Expr::ternary(cond, then_e, else_e));
        }
        Ok(cond)
    }

    fn or_expr(&mut self) -> CResult<Expr> {
        let mut lhs = self.and_expr()?;
        while self.eat(&Tok::KwOr) {
            let rhs = self.and_expr()?;
            lhs = Expr::binary(BinOp::Or, lhs, rhs);
        }
        Ok(lhs)
    }

    fn and_expr(&mut self) -> CResult<Expr> {
        let mut lhs = self.not_expr()?;
        while self.eat(&Tok::KwAnd) {
            let rhs = self.not_expr()?;
            lhs = Expr::binary(BinOp::And, lhs, rhs);
        }
        Ok(lhs)
    }

    fn not_expr(&mut self) -> CResult<Expr> {
        if self.eat(&Tok::KwNot) || self.eat(&Tok::Not) {
            let operand = self.not_expr()?;
            return Ok(Expr::Unary { op: UnOp::Not, operand: Box::new(operand) });
        }
        self.cmp_expr()
    }

    fn cmp_expr(&mut self) -> CResult<Expr> {
        let lhs = self.add_expr()?;
        let op = match self.peek() {
            Tok::Lt => BinOp::Lt,
            Tok::Le => BinOp::Le,
            Tok::Gt => BinOp::Gt,
            Tok::Ge => BinOp::Ge,
            Tok::EqEq => BinOp::Eq,
            Tok::Ne => BinOp::Ne,
            _ => return Ok(lhs),
        };
        self.bump();
        let rhs = self.add_expr()?;
        Ok(Expr::binary(op, lhs, rhs))
    }

    fn add_expr(&mut self) -> CResult<Expr> {
        let mut lhs = self.mul_expr()?;
        loop {
            let op = match self.peek() {
                Tok::Plus => BinOp::Add,
                Tok::Minus => BinOp::Sub,
                _ => break,
            };
            self.bump();
            let rhs = self.mul_expr()?;
            lhs = Expr::binary(op, lhs, rhs);
        }
        Ok(lhs)
    }

    fn mul_expr(&mut self) -> CResult<Expr> {
        let mut lhs = self.unary()?;
        loop {
            let op = match self.peek() {
                Tok::Star => BinOp::Mul,
                Tok::Slash => BinOp::Div,
                Tok::Percent => BinOp::Mod,
                _ => break,
            };
            self.bump();
            let rhs = self.unary()?;
            lhs = Expr::binary(op, lhs, rhs);
        }
        Ok(lhs)
    }

    fn unary(&mut self) -> CResult<Expr> {
        if self.eat(&Tok::Minus) {
            let operand = self.unary()?;
            // fold negation of literals immediately for cleaner IRs
            if let Expr::Float(v) = operand {
                return Ok(Expr::Float(-v));
            }
            return Ok(Expr::Unary { op: UnOp::Neg, operand: Box::new(operand) });
        }
        self.primary()
    }

    fn offset_component(&mut self) -> CResult<i32> {
        let neg = self.eat(&Tok::Minus);
        match self.peek().clone() {
            Tok::Int(v) => {
                self.bump();
                Ok(if neg { -(v as i32) } else { v as i32 })
            }
            other => Err(CompileError::with_span(
                "parse",
                format!("field offsets must be integer literals, found {}", other.describe()),
                self.peek_span(),
            )),
        }
    }

    fn primary(&mut self) -> CResult<Expr> {
        match self.peek().clone() {
            Tok::Float(v) => {
                self.bump();
                Ok(Expr::Float(v))
            }
            Tok::Int(v) => {
                self.bump();
                Ok(Expr::Float(v as f64))
            }
            Tok::KwTrue => {
                self.bump();
                Ok(Expr::Bool(true))
            }
            Tok::KwFalse => {
                self.bump();
                Ok(Expr::Bool(false))
            }
            Tok::LParen => {
                self.bump();
                let e = self.expr()?;
                self.expect(Tok::RParen)?;
                Ok(e)
            }
            Tok::Ident(name) => {
                let t = self.bump();
                match self.peek() {
                    Tok::LBracket => {
                        self.bump();
                        let i = self.offset_component()?;
                        self.expect(Tok::Comma)?;
                        let j = self.offset_component()?;
                        self.expect(Tok::Comma)?;
                        let k = self.offset_component()?;
                        let close = self.expect(Tok::RBracket)?;
                        Ok(Expr::Field {
                            name,
                            offset: [i, j, k],
                            span: t.span.merge(close.span),
                        })
                    }
                    Tok::LParen => {
                        self.bump();
                        let mut args = Vec::new();
                        if self.peek() != &Tok::RParen {
                            loop {
                                args.push(self.expr()?);
                                if !self.eat(&Tok::Comma) {
                                    break;
                                }
                            }
                        }
                        let close = self.expect(Tok::RParen)?;
                        let span = t.span.merge(close.span);
                        if let Some(b) = Builtin::from_name(&name) {
                            if args.len() != b.arity() {
                                return Err(CompileError::with_span(
                                    "parse",
                                    format!(
                                        "builtin `{}` takes {} argument(s), got {}",
                                        b.name(),
                                        b.arity(),
                                        args.len()
                                    ),
                                    span,
                                ));
                            }
                            Ok(Expr::Builtin { func: b, args })
                        } else {
                            Ok(Expr::Call { name, args, span })
                        }
                    }
                    _ => Ok(Expr::Name(name, t.span)),
                }
            }
            other => Err(CompileError::with_span(
                "parse",
                format!("expected expression, found {}", other.describe()),
                self.peek_span(),
            )),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_minimal_stencil() {
        let m = parse_module(
            "stencil copy(a: Field<f64>, b: Field<f64>) {\n\
               with computation(PARALLEL), interval(...) { b = a; }\n\
             }",
        )
        .unwrap();
        assert_eq!(m.stencils.len(), 1);
        let s = &m.stencils[0];
        assert_eq!(s.name, "copy");
        assert_eq!(s.fields.len(), 2);
        assert_eq!(s.computations.len(), 1);
        assert_eq!(s.computations[0].policy, IterationPolicy::Parallel);
    }

    #[test]
    fn parses_scalars_after_semicolon() {
        let m = parse_module(
            "stencil axpy(x: Field<f64>, y: Field<f64>; alpha: f64) {\n\
               with computation(PARALLEL), interval(...) { y = y + alpha * x; }\n\
             }",
        )
        .unwrap();
        let s = &m.stencils[0];
        assert_eq!(s.scalars.len(), 1);
        assert_eq!(s.scalars[0].name, "alpha");
    }

    #[test]
    fn parses_function_with_bindings() {
        let m = parse_module(
            "function lap(phi) {\n\
               c = -4.0 * phi[0,0,0];\n\
               return c + phi[-1,0,0] + phi[1,0,0] + phi[0,-1,0] + phi[0,1,0];\n\
             }\n\
             stencil s(a: Field<f64>, b: Field<f64>) {\n\
               with computation(PARALLEL), interval(...) { b = lap(a); }\n\
             }",
        )
        .unwrap();
        assert_eq!(m.functions.len(), 1);
        assert_eq!(m.functions[0].bindings.len(), 1);
    }

    #[test]
    fn parses_multi_interval_computation() {
        let m = parse_module(
            "stencil s(a: Field<f64>, b: Field<f64>) {\n\
               with computation(FORWARD) {\n\
                 interval(0, 1) { b = a; }\n\
                 interval(1, None) { b = b[0,0,-1] + a; }\n\
               }\n\
             }",
        )
        .unwrap();
        let c = &m.stencils[0].computations[0];
        assert_eq!(c.policy, IterationPolicy::Forward);
        assert_eq!(c.blocks.len(), 2);
        assert_eq!(c.blocks[1].interval.resolve(10), (1, 10));
    }

    #[test]
    fn parses_ternary_and_if() {
        let m = parse_module(
            "stencil s(a: Field<f64>, b: Field<f64>; lim: f64) {\n\
               with computation(PARALLEL), interval(...) {\n\
                 b = a * a > lim ? a : lim;\n\
                 if b > 0.0 { b = b * 2.0; } else { b = 0.0; }\n\
               }\n\
             }",
        )
        .unwrap();
        let body = &m.stencils[0].computations[0].blocks[0].body;
        assert_eq!(body.len(), 2);
        assert!(matches!(body[0], Stmt::Assign { .. }));
        assert!(matches!(body[1], Stmt::If { .. }));
    }

    #[test]
    fn parses_externals_and_builtins() {
        let m = parse_module(
            "extern LIM = 0.01;\n\
             stencil s(a: Field<f64>, b: Field<f64>) {\n\
               with computation(PARALLEL), interval(...) { b = max(a, LIM) + sqrt(abs(a)); }\n\
             }",
        )
        .unwrap();
        assert_eq!(m.extern_defaults, vec![("LIM".to_string(), 0.01)]);
    }

    #[test]
    fn precedence_mul_over_add_and_cmp() {
        let e = parse_expr("a + b * c > d ? 1.0 : 0.0").unwrap();
        // (((a + (b*c)) > d) ? 1 : 0)
        match e {
            Expr::Ternary { cond, .. } => match *cond {
                Expr::Binary { op: BinOp::Gt, lhs, .. } => match *lhs {
                    Expr::Binary { op: BinOp::Add, rhs, .. } => {
                        assert!(matches!(*rhs, Expr::Binary { op: BinOp::Mul, .. }));
                    }
                    other => panic!("expected Add, got {other:?}"),
                },
                other => panic!("expected Gt, got {other:?}"),
            },
            other => panic!("expected ternary, got {other:?}"),
        }
    }

    #[test]
    fn rejects_empty_interval() {
        let r = parse_module(
            "stencil s(a: Field<f64>) {\n\
               with computation(PARALLEL), interval(2, 2) { a = 1.0; }\n\
             }",
        );
        assert!(r.is_err());
    }

    #[test]
    fn rejects_duplicate_params() {
        assert!(parse_module(
            "stencil s(a: Field<f64>, a: Field<f64>) {\n\
               with computation(PARALLEL), interval(...) { a = 1.0; }\n\
             }"
        )
        .is_err());
    }

    #[test]
    fn rejects_wrong_builtin_arity() {
        assert!(parse_expr("max(a)").is_err());
        assert!(parse_expr("sqrt(a, b)").is_err());
    }

    #[test]
    fn parses_negative_interval_bounds() {
        let m = parse_module(
            "stencil s(a: Field<f64>) {\n\
               with computation(BACKWARD), interval(-1, None) { a = 0.0; }\n\
             }",
        )
        .unwrap();
        let iv = m.stencils[0].computations[0].blocks[0].interval;
        assert_eq!(iv.resolve(80), (79, 80));
    }

    #[test]
    fn error_reports_span() {
        let err = parse_module("stencil s(a Field<f64>) {}").unwrap_err();
        assert_eq!(err.phase, "parse");
        assert!(err.span.is_some());
    }
}
