//! Hand-written lexer for the GTScript-RS textual frontend.
//!
//! GTScript proper is a strict subset of Python syntax parsed with Python's
//! own `ast` module; since our host language is Rust we define an equivalent
//! free-standing surface syntax (`.gts` files) with a conventional lexer.
//! `#` starts a line comment, like Python.

use super::span::{CResult, CompileError, Span};

#[derive(Debug, Clone, PartialEq)]
pub enum Tok {
    Ident(String),
    Float(f64),
    Int(i64),
    // punctuation
    LParen,
    RParen,
    LBrace,
    RBrace,
    LBracket,
    RBracket,
    Comma,
    Semi,
    Colon,
    Question,
    Ellipsis,
    // operators
    Plus,
    Minus,
    Star,
    Slash,
    Percent,
    Assign,
    Lt,
    Le,
    Gt,
    Ge,
    EqEq,
    Ne,
    Not,
    // keywords
    KwStencil,
    KwFunction,
    KwReturn,
    KwWith,
    KwComputation,
    KwInterval,
    KwIf,
    KwElse,
    KwExtern,
    KwAnd,
    KwOr,
    KwNot,
    KwTrue,
    KwFalse,
    KwNone,
    Eof,
}

impl Tok {
    pub fn describe(&self) -> String {
        match self {
            Tok::Ident(s) => format!("identifier `{s}`"),
            Tok::Float(v) => format!("float literal `{v}`"),
            Tok::Int(v) => format!("int literal `{v}`"),
            other => format!("{other:?}"),
        }
    }
}

#[derive(Debug, Clone)]
pub struct Token {
    pub tok: Tok,
    pub span: Span,
}

pub struct Lexer<'a> {
    src: &'a [u8],
    pos: usize,
    line: u32,
    col: u32,
}

impl<'a> Lexer<'a> {
    pub fn new(src: &'a str) -> Self {
        Lexer { src: src.as_bytes(), pos: 0, line: 1, col: 1 }
    }

    pub fn tokenize(src: &str) -> CResult<Vec<Token>> {
        let mut lx = Lexer::new(src);
        let mut out = Vec::new();
        loop {
            let t = lx.next_token()?;
            let eof = t.tok == Tok::Eof;
            out.push(t);
            if eof {
                break;
            }
        }
        Ok(out)
    }

    fn peek(&self) -> Option<u8> {
        self.src.get(self.pos).copied()
    }

    fn peek2(&self) -> Option<u8> {
        self.src.get(self.pos + 1).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek()?;
        self.pos += 1;
        if c == b'\n' {
            self.line += 1;
            self.col = 1;
        } else {
            self.col += 1;
        }
        Some(c)
    }

    fn skip_ws_and_comments(&mut self) {
        while let Some(c) = self.peek() {
            if c == b'#' {
                while let Some(c2) = self.peek() {
                    if c2 == b'\n' {
                        break;
                    }
                    self.bump();
                }
            } else if c.is_ascii_whitespace() {
                self.bump();
            } else {
                break;
            }
        }
    }

    fn span_from(&self, start: usize, line: u32, col: u32) -> Span {
        Span::new(start, self.pos, line, col)
    }

    fn next_token(&mut self) -> CResult<Token> {
        self.skip_ws_and_comments();
        let (start, line, col) = (self.pos, self.line, self.col);
        let mk = |lx: &Lexer, tok: Tok| Token { tok, span: lx.span_from(start, line, col) };
        let c = match self.peek() {
            None => return Ok(mk(self, Tok::Eof)),
            Some(c) => c,
        };

        // identifiers / keywords
        if c.is_ascii_alphabetic() || c == b'_' {
            let mut s = String::new();
            while let Some(c2) = self.peek() {
                if c2.is_ascii_alphanumeric() || c2 == b'_' {
                    s.push(c2 as char);
                    self.bump();
                } else {
                    break;
                }
            }
            let tok = match s.as_str() {
                "stencil" => Tok::KwStencil,
                "function" => Tok::KwFunction,
                "return" => Tok::KwReturn,
                "with" => Tok::KwWith,
                "computation" => Tok::KwComputation,
                "interval" => Tok::KwInterval,
                "if" => Tok::KwIf,
                "else" => Tok::KwElse,
                "extern" => Tok::KwExtern,
                "and" => Tok::KwAnd,
                "or" => Tok::KwOr,
                "not" => Tok::KwNot,
                "true" | "True" => Tok::KwTrue,
                "false" | "False" => Tok::KwFalse,
                "None" => Tok::KwNone,
                _ => Tok::Ident(s),
            };
            return Ok(mk(self, tok));
        }

        // numbers: int or float (decimal point and/or exponent)
        if c.is_ascii_digit() {
            let mut s = String::new();
            let mut is_float = false;
            while let Some(c2) = self.peek() {
                if c2.is_ascii_digit() {
                    s.push(c2 as char);
                    self.bump();
                } else if c2 == b'.' && self.peek2() != Some(b'.') {
                    // not the start of `..` / `...`
                    if is_float {
                        break;
                    }
                    is_float = true;
                    s.push('.');
                    self.bump();
                } else if c2 == b'e' || c2 == b'E' {
                    is_float = true;
                    s.push('e');
                    self.bump();
                    if let Some(sign) = self.peek() {
                        if sign == b'+' || sign == b'-' {
                            s.push(sign as char);
                            self.bump();
                        }
                    }
                } else {
                    break;
                }
            }
            let span = self.span_from(start, line, col);
            if is_float {
                let v: f64 = s.parse().map_err(|_| {
                    CompileError::with_span("lex", format!("invalid float literal `{s}`"), span)
                })?;
                return Ok(Token { tok: Tok::Float(v), span });
            }
            let v: i64 = s.parse().map_err(|_| {
                CompileError::with_span("lex", format!("invalid int literal `{s}`"), span)
            })?;
            return Ok(Token { tok: Tok::Int(v), span });
        }

        // punctuation and operators
        self.bump();
        let tok = match c {
            b'(' => Tok::LParen,
            b')' => Tok::RParen,
            b'{' => Tok::LBrace,
            b'}' => Tok::RBrace,
            b'[' => Tok::LBracket,
            b']' => Tok::RBracket,
            b',' => Tok::Comma,
            b';' => Tok::Semi,
            b':' => Tok::Colon,
            b'?' => Tok::Question,
            b'+' => Tok::Plus,
            b'-' => Tok::Minus,
            b'*' => Tok::Star,
            b'/' => Tok::Slash,
            b'%' => Tok::Percent,
            b'.' => {
                if self.peek() == Some(b'.') && self.peek2() == Some(b'.') {
                    self.bump();
                    self.bump();
                    Tok::Ellipsis
                } else {
                    return Err(CompileError::with_span(
                        "lex",
                        "unexpected `.` (did you mean `...`?)",
                        self.span_from(start, line, col),
                    ));
                }
            }
            b'=' => {
                if self.peek() == Some(b'=') {
                    self.bump();
                    Tok::EqEq
                } else {
                    Tok::Assign
                }
            }
            b'<' => {
                if self.peek() == Some(b'=') {
                    self.bump();
                    Tok::Le
                } else {
                    Tok::Lt
                }
            }
            b'>' => {
                if self.peek() == Some(b'=') {
                    self.bump();
                    Tok::Ge
                } else {
                    Tok::Gt
                }
            }
            b'!' => {
                if self.peek() == Some(b'=') {
                    self.bump();
                    Tok::Ne
                } else {
                    Tok::Not
                }
            }
            other => {
                return Err(CompileError::with_span(
                    "lex",
                    format!("unexpected character `{}`", other as char),
                    self.span_from(start, line, col),
                ))
            }
        };
        Ok(Token { tok, span: self.span_from(start, line, col) })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toks(src: &str) -> Vec<Tok> {
        Lexer::tokenize(src).unwrap().into_iter().map(|t| t.tok).collect()
    }

    #[test]
    fn lexes_stencil_header() {
        let t = toks("stencil copy(a: Field<f64>) {}");
        assert_eq!(
            t,
            vec![
                Tok::KwStencil,
                Tok::Ident("copy".into()),
                Tok::LParen,
                Tok::Ident("a".into()),
                Tok::Colon,
                Tok::Ident("Field".into()),
                Tok::Lt,
                Tok::Ident("f64".into()),
                Tok::Gt,
                Tok::RParen,
                Tok::LBrace,
                Tok::RBrace,
                Tok::Eof,
            ]
        );
    }

    #[test]
    fn lexes_numbers() {
        assert_eq!(toks("1 2.5 1e3 2.5e-2 4."), vec![
            Tok::Int(1),
            Tok::Float(2.5),
            Tok::Float(1000.0),
            Tok::Float(0.025),
            Tok::Float(4.0),
            Tok::Eof
        ]);
    }

    #[test]
    fn lexes_offsets_and_ellipsis() {
        assert_eq!(toks("phi[-1, 0, 0] interval(...)"), vec![
            Tok::Ident("phi".into()),
            Tok::LBracket,
            Tok::Minus,
            Tok::Int(1),
            Tok::Comma,
            Tok::Int(0),
            Tok::Comma,
            Tok::Int(0),
            Tok::RBracket,
            Tok::KwInterval,
            Tok::LParen,
            Tok::Ellipsis,
            Tok::RParen,
            Tok::Eof
        ]);
    }

    #[test]
    fn comments_ignored_and_positions_tracked() {
        let tokens = Lexer::tokenize("# header\n  x = 1; # trailing\ny").unwrap();
        assert_eq!(tokens[0].span.line, 2);
        assert_eq!(tokens[0].span.col, 3);
        let y = &tokens[4];
        assert_eq!(y.tok, Tok::Ident("y".into()));
        assert_eq!(y.span.line, 3);
    }

    #[test]
    fn comparison_operators() {
        assert_eq!(toks("< <= > >= == != ="), vec![
            Tok::Lt,
            Tok::Le,
            Tok::Gt,
            Tok::Ge,
            Tok::EqEq,
            Tok::Ne,
            Tok::Assign,
            Tok::Eof
        ]);
    }

    #[test]
    fn rejects_stray_chars() {
        assert!(Lexer::tokenize("a $ b").is_err());
        assert!(Lexer::tokenize("a . b").is_err());
    }

    #[test]
    fn float_then_int_not_range() {
        // `4.` is a float; `4...` would be float then `..`, an error — keep
        // the simple rule: digits followed by `..` lex as int + ellipsis-ish.
        assert_eq!(toks("interval(0, 2)"), vec![
            Tok::KwInterval,
            Tok::LParen,
            Tok::Int(0),
            Tok::Comma,
            Tok::Int(2),
            Tok::RParen,
            Tok::Eof
        ]);
    }
}
