//! Abstract syntax tree of GTScript-RS.
//!
//! This doubles as the paper's *definition IR*: the frontend (text parser or
//! builder API) produces these trees, and the analysis pipeline consumes them.
//! Mirrors GT4Py §2.2: stencils, pure functions, externals, scalar
//! parameters, `computation(PARALLEL|FORWARD|BACKWARD)`, `interval(a, b)`
//! with Python-range semantics, relative field offsets, assignments and
//! (point-wise) if/else control flow.

pub use super::span::Span;
use std::fmt;

/// Relative offset of a field access in (I, J, K).
pub type Offset = [i32; 3];

/// Element type of a field or scalar parameter.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DType {
    F32,
    F64,
}

impl DType {
    /// Parse the CLI/wire spelling (`f32` / `f64`).
    pub fn parse(s: &str) -> Option<DType> {
        match s.trim() {
            "f32" => Some(DType::F32),
            "f64" => Some(DType::F64),
            _ => None,
        }
    }

    /// Element width in bytes.
    pub fn size_of(self) -> usize {
        match self {
            DType::F32 => 4,
            DType::F64 => 8,
        }
    }
}

impl fmt::Display for DType {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            DType::F32 => write!(f, "f32"),
            DType::F64 => write!(f, "f64"),
        }
    }
}

/// Built-in math functions usable in any backend.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Builtin {
    Abs,
    Min,
    Max,
    Sqrt,
    Exp,
    Log,
    Pow,
    Floor,
    Ceil,
    Sin,
    Cos,
    Tanh,
}

impl Builtin {
    pub fn from_name(name: &str) -> Option<Builtin> {
        Some(match name {
            "abs" => Builtin::Abs,
            "min" => Builtin::Min,
            "max" => Builtin::Max,
            "sqrt" => Builtin::Sqrt,
            "exp" => Builtin::Exp,
            "log" => Builtin::Log,
            "pow" => Builtin::Pow,
            "floor" => Builtin::Floor,
            "ceil" => Builtin::Ceil,
            "sin" => Builtin::Sin,
            "cos" => Builtin::Cos,
            "tanh" => Builtin::Tanh,
        _ => return None,
        })
    }

    pub fn arity(&self) -> usize {
        match self {
            Builtin::Min | Builtin::Max | Builtin::Pow => 2,
            _ => 1,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Builtin::Abs => "abs",
            Builtin::Min => "min",
            Builtin::Max => "max",
            Builtin::Sqrt => "sqrt",
            Builtin::Exp => "exp",
            Builtin::Log => "log",
            Builtin::Pow => "pow",
            Builtin::Floor => "floor",
            Builtin::Ceil => "ceil",
            Builtin::Sin => "sin",
            Builtin::Cos => "cos",
            Builtin::Tanh => "tanh",
        }
    }
}

/// Binary operators. Comparisons/logical ops produce boolean values that may
/// only be consumed by ternaries, `if` conditions, and other logical ops.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum BinOp {
    Add,
    Sub,
    Mul,
    Div,
    Mod,
    Lt,
    Le,
    Gt,
    Ge,
    Eq,
    Ne,
    And,
    Or,
}

impl BinOp {
    pub fn is_comparison(&self) -> bool {
        matches!(self, BinOp::Lt | BinOp::Le | BinOp::Gt | BinOp::Ge | BinOp::Eq | BinOp::Ne)
    }
    pub fn is_logical(&self) -> bool {
        matches!(self, BinOp::And | BinOp::Or)
    }
    pub fn symbol(&self) -> &'static str {
        match self {
            BinOp::Add => "+",
            BinOp::Sub => "-",
            BinOp::Mul => "*",
            BinOp::Div => "/",
            BinOp::Mod => "%",
            BinOp::Lt => "<",
            BinOp::Le => "<=",
            BinOp::Gt => ">",
            BinOp::Ge => ">=",
            BinOp::Eq => "==",
            BinOp::Ne => "!=",
            BinOp::And => "and",
            BinOp::Or => "or",
        }
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum UnOp {
    Neg,
    Not,
}

/// Expressions. After name resolution (`analysis::resolve`), `Name` no
/// longer appears: bare names have become `Field` (offset 0), `Scalar`, or
/// `External` references.
#[derive(Debug, Clone, PartialEq)]
pub enum Expr {
    /// Floating-point literal (also used for folded externals).
    Float(f64),
    /// Boolean literal.
    Bool(bool),
    /// Unresolved name (only before the resolution pass / inside functions).
    Name(String, Span),
    /// Field access at a relative offset.
    Field { name: String, offset: Offset, span: Span },
    /// Run-time scalar parameter.
    Scalar(String),
    /// Compile-time external constant (folded before analysis).
    External(String, Span),
    Unary { op: UnOp, operand: Box<Expr> },
    Binary { op: BinOp, lhs: Box<Expr>, rhs: Box<Expr> },
    /// `cond ? then_e : else_e` — point-wise select.
    Ternary { cond: Box<Expr>, then_e: Box<Expr>, else_e: Box<Expr> },
    /// Call of a user GTScript function (inlined by the analysis pipeline).
    Call { name: String, args: Vec<Expr>, span: Span },
    Builtin { func: Builtin, args: Vec<Expr> },
}

impl Expr {
    pub fn binary(op: BinOp, lhs: Expr, rhs: Expr) -> Expr {
        Expr::Binary { op, lhs: Box::new(lhs), rhs: Box::new(rhs) }
    }
    pub fn ternary(cond: Expr, then_e: Expr, else_e: Expr) -> Expr {
        Expr::Ternary { cond: Box::new(cond), then_e: Box::new(then_e), else_e: Box::new(else_e) }
    }
    pub fn field(name: impl Into<String>, offset: Offset) -> Expr {
        Expr::Field { name: name.into(), offset, span: Span::default() }
    }

    /// Walk all field accesses in the expression.
    pub fn visit_fields<'a>(&'a self, f: &mut impl FnMut(&'a str, Offset)) {
        match self {
            Expr::Field { name, offset, .. } => f(name, *offset),
            Expr::Unary { operand, .. } => operand.visit_fields(f),
            Expr::Binary { lhs, rhs, .. } => {
                lhs.visit_fields(f);
                rhs.visit_fields(f);
            }
            Expr::Ternary { cond, then_e, else_e } => {
                cond.visit_fields(f);
                then_e.visit_fields(f);
                else_e.visit_fields(f);
            }
            Expr::Call { args, .. } | Expr::Builtin { args, .. } => {
                for a in args {
                    a.visit_fields(f);
                }
            }
            _ => {}
        }
    }

    /// Shift every field access by `off` (used when inlining function calls
    /// whose arguments were accessed at an offset: offsets compose
    /// additively, per GT4Py semantics).
    pub fn shifted(&self, off: Offset) -> Expr {
        if off == [0, 0, 0] {
            return self.clone();
        }
        match self {
            // A bare name accessed at an offset is a field access: scalars
            // and externals reject offsets later, at resolution.
            Expr::Name(name, span) => {
                Expr::Field { name: name.clone(), offset: off, span: *span }
            }
            Expr::Field { name, offset, span } => Expr::Field {
                name: name.clone(),
                offset: [offset[0] + off[0], offset[1] + off[1], offset[2] + off[2]],
                span: *span,
            },
            Expr::Unary { op, operand } => {
                Expr::Unary { op: *op, operand: Box::new(operand.shifted(off)) }
            }
            Expr::Binary { op, lhs, rhs } => Expr::Binary {
                op: *op,
                lhs: Box::new(lhs.shifted(off)),
                rhs: Box::new(rhs.shifted(off)),
            },
            Expr::Ternary { cond, then_e, else_e } => Expr::Ternary {
                cond: Box::new(cond.shifted(off)),
                then_e: Box::new(then_e.shifted(off)),
                else_e: Box::new(else_e.shifted(off)),
            },
            Expr::Call { name, args, span } => Expr::Call {
                name: name.clone(),
                args: args.iter().map(|a| a.shifted(off)).collect(),
                span: *span,
            },
            Expr::Builtin { func, args } => Expr::Builtin {
                func: *func,
                args: args.iter().map(|a| a.shifted(off)).collect(),
            },
            other => other.clone(),
        }
    }

    /// Number of AST nodes (used for canonical fingerprints and tests).
    pub fn size(&self) -> usize {
        match self {
            Expr::Unary { operand, .. } => 1 + operand.size(),
            Expr::Binary { lhs, rhs, .. } => 1 + lhs.size() + rhs.size(),
            Expr::Ternary { cond, then_e, else_e } => {
                1 + cond.size() + then_e.size() + else_e.size()
            }
            Expr::Call { args, .. } | Expr::Builtin { args, .. } => {
                1 + args.iter().map(Expr::size).sum::<usize>()
            }
            _ => 1,
        }
    }
}

/// Statements allowed in `with interval` bodies (paper: assignments and
/// if-else only).
#[derive(Debug, Clone, PartialEq)]
pub enum Stmt {
    /// `target = value` — target is always written at offset (0,0,0).
    Assign { target: String, value: Expr, span: Span },
    /// Point-wise conditional execution.
    If { cond: Expr, then_body: Vec<Stmt>, else_body: Vec<Stmt>, span: Span },
}

/// Vertical iteration order of a `with computation(...)` block.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum IterationPolicy {
    Parallel,
    Forward,
    Backward,
}

impl fmt::Display for IterationPolicy {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            IterationPolicy::Parallel => write!(f, "PARALLEL"),
            IterationPolicy::Forward => write!(f, "FORWARD"),
            IterationPolicy::Backward => write!(f, "BACKWARD"),
        }
    }
}

/// One end of a vertical interval, relative to the start or end of the axis.
/// Follows Python range conventions: `interval(0, None)` is the full axis,
/// `interval(-1, None)` the top level, `interval(1, -1)` the interior.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum LevelBound {
    /// `FromStart(n)`: level n (n >= 0).
    FromStart(i32),
    /// `FromEnd(n)`: level K - n (n >= 0); `FromEnd(0)` is the exclusive end.
    FromEnd(i32),
}

impl LevelBound {
    /// Resolve against a concrete vertical size.
    pub fn resolve(&self, ksize: usize) -> i64 {
        match self {
            LevelBound::FromStart(n) => *n as i64,
            LevelBound::FromEnd(n) => ksize as i64 - *n as i64,
        }
    }

    /// Convert a Python-style index to a bound (negative = from end).
    pub fn from_index(idx: i32) -> LevelBound {
        if idx >= 0 {
            LevelBound::FromStart(idx)
        } else {
            LevelBound::FromEnd(-idx)
        }
    }
}

/// Half-open vertical interval `[lo, hi)`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Interval {
    pub lo: LevelBound,
    pub hi: LevelBound,
}

impl Interval {
    /// The full vertical axis, `interval(...)`.
    pub fn full() -> Interval {
        Interval { lo: LevelBound::FromStart(0), hi: LevelBound::FromEnd(0) }
    }

    /// Build from Python-style indices; `hi = None` is expressed as
    /// `LevelBound::FromEnd(0)` by the caller.
    pub fn new(lo: LevelBound, hi: LevelBound) -> Interval {
        Interval { lo, hi }
    }

    /// Concrete `[lo, hi)` range for a vertical size; empty ranges resolve
    /// with `lo >= hi`.
    pub fn resolve(&self, ksize: usize) -> (i64, i64) {
        (self.lo.resolve(ksize), self.hi.resolve(ksize))
    }

    /// True when the interval is empty for every possible axis size — a
    /// user error detected statically.
    pub fn statically_empty(&self) -> bool {
        match (self.lo, self.hi) {
            (LevelBound::FromStart(a), LevelBound::FromStart(b)) => a >= b,
            (LevelBound::FromEnd(a), LevelBound::FromEnd(b)) => a <= b,
            // Mixed bounds depend on the axis size.
            _ => false,
        }
    }

    /// Whether two intervals can be shown to overlap for some axis size; a
    /// conservative test used by the overlap check.
    pub fn overlaps(&self, other: &Interval, ksize_probe: &[usize]) -> bool {
        for &k in ksize_probe {
            let (a0, a1) = self.resolve(k);
            let (b0, b1) = other.resolve(k);
            if a0 < a1 && b0 < b1 && a0 < b1 && b0 < a1 {
                return true;
            }
        }
        false
    }
}

impl fmt::Display for Interval {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let b = |b: &LevelBound| match b {
            LevelBound::FromStart(n) => format!("{n}"),
            LevelBound::FromEnd(0) => "None".to_string(),
            LevelBound::FromEnd(n) => format!("-{n}"),
        };
        write!(f, "interval({}, {})", b(&self.lo), b(&self.hi))
    }
}

/// Body of a single `with interval(...)` region.
#[derive(Debug, Clone, PartialEq)]
pub struct IntervalBlock {
    pub interval: Interval,
    pub body: Vec<Stmt>,
    pub span: Span,
}

/// A `with computation(policy)` block with one or more interval regions,
/// executed in program order.
#[derive(Debug, Clone, PartialEq)]
pub struct Computation {
    pub policy: IterationPolicy,
    pub blocks: Vec<IntervalBlock>,
    pub span: Span,
}

/// Declaration of a field parameter of a stencil.
#[derive(Debug, Clone, PartialEq)]
pub struct FieldDecl {
    pub name: String,
    pub dtype: DType,
    pub span: Span,
}

/// Declaration of a read-only scalar parameter (after `;` in the signature,
/// the analog of Python's keyword-only `*,` marker in GTScript).
#[derive(Debug, Clone, PartialEq)]
pub struct ScalarDecl {
    pub name: String,
    pub dtype: DType,
    pub span: Span,
}

/// A stencil definition (the `@gtscript.stencil` analog).
#[derive(Debug, Clone, PartialEq)]
pub struct StencilDef {
    pub name: String,
    pub fields: Vec<FieldDecl>,
    pub scalars: Vec<ScalarDecl>,
    /// Names of externals referenced (values provided at compile time).
    pub externals: Vec<String>,
    pub computations: Vec<Computation>,
    pub span: Span,
}

/// A pure GTScript function (the `@gtscript.function` analog): a sequence of
/// local bindings followed by a single returned expression. Functions are
/// inlined by the analysis pipeline; locals never materialize.
#[derive(Debug, Clone, PartialEq)]
pub struct FunctionDef {
    pub name: String,
    pub params: Vec<String>,
    /// Local bindings `(name, expr)` evaluated in order.
    pub bindings: Vec<(String, Expr)>,
    pub ret: Expr,
    pub span: Span,
}

/// A parsed module: functions, stencils and module-level extern defaults.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct Module {
    pub functions: Vec<FunctionDef>,
    pub stencils: Vec<StencilDef>,
    /// `extern NAME = value;` defaults (overridable at compile time).
    pub extern_defaults: Vec<(String, f64)>,
}

impl Module {
    pub fn function(&self, name: &str) -> Option<&FunctionDef> {
        self.functions.iter().find(|f| f.name == name)
    }
    pub fn stencil(&self, name: &str) -> Option<&StencilDef> {
        self.stencils.iter().find(|s| s.name == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtin_roundtrip() {
        for b in [
            Builtin::Abs,
            Builtin::Min,
            Builtin::Max,
            Builtin::Sqrt,
            Builtin::Exp,
            Builtin::Log,
            Builtin::Pow,
            Builtin::Floor,
            Builtin::Ceil,
            Builtin::Sin,
            Builtin::Cos,
            Builtin::Tanh,
        ] {
            assert_eq!(Builtin::from_name(b.name()), Some(b));
        }
        assert_eq!(Builtin::from_name("nope"), None);
    }

    #[test]
    fn shifted_composes_offsets() {
        let e = Expr::binary(
            BinOp::Add,
            Expr::field("phi", [1, 0, 0]),
            Expr::field("phi", [-1, 0, 0]),
        );
        let s = e.shifted([0, 2, -1]);
        let mut offs = vec![];
        s.visit_fields(&mut |name, off| {
            assert_eq!(name, "phi");
            offs.push(off);
        });
        assert_eq!(offs, vec![[1, 2, -1], [-1, 2, -1]]);
    }

    #[test]
    fn interval_resolution_python_semantics() {
        let full = Interval::full();
        assert_eq!(full.resolve(80), (0, 80));
        let first = Interval::new(LevelBound::from_index(0), LevelBound::from_index(1));
        assert_eq!(first.resolve(80), (0, 1));
        let last = Interval::new(LevelBound::from_index(-1), LevelBound::FromEnd(0));
        assert_eq!(last.resolve(80), (79, 80));
        let interior = Interval::new(LevelBound::from_index(1), LevelBound::from_index(-1));
        assert_eq!(interior.resolve(80), (1, 79));
    }

    #[test]
    fn statically_empty_detection() {
        let e = Interval::new(LevelBound::FromStart(3), LevelBound::FromStart(3));
        assert!(e.statically_empty());
        let ok = Interval::new(LevelBound::FromStart(0), LevelBound::FromEnd(0));
        assert!(!ok.statically_empty());
        let mixed = Interval::new(LevelBound::FromStart(5), LevelBound::FromEnd(2));
        assert!(!mixed.statically_empty()); // empty only for K <= 7
    }

    #[test]
    fn interval_overlap_probe() {
        let a = Interval::new(LevelBound::FromStart(0), LevelBound::FromStart(1));
        let b = Interval::new(LevelBound::FromStart(1), LevelBound::FromEnd(0));
        let probes = [1usize, 2, 8, 80];
        assert!(!a.overlaps(&b, &probes));
        let c = Interval::full();
        assert!(a.overlaps(&c, &probes));
    }

    #[test]
    fn expr_size_counts_nodes() {
        let e = Expr::ternary(
            Expr::binary(BinOp::Gt, Expr::field("a", [0, 0, 0]), Expr::Float(0.0)),
            Expr::field("b", [0, 0, 0]),
            Expr::Float(1.0),
        );
        assert_eq!(e.size(), 6);
    }
}
