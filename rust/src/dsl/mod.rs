//! GTScript-RS frontend: surface syntax, AST (definition IR), and builders.
//!
//! The paper's GTScript is a DSL embedded in Python, parsed by the Python
//! interpreter itself (§2.2). Our host is Rust, so the frontend offers two
//! equivalent entry points producing the same definition IR:
//!
//! * [`parser::parse_module`] — a textual `.gts` syntax mirroring GTScript
//!   construct-for-construct (stencils, functions, externals, computations,
//!   intervals, relative offsets, point-wise if/else);
//! * [`builder`] — a fluent Rust API, the "embedded" flavor.

pub mod ast;
pub mod builder;
pub mod lexer;
pub mod parser;
pub mod span;

pub use ast::{
    BinOp, Builtin, Computation, DType, Expr, FieldDecl, FunctionDef, Interval, IntervalBlock,
    IterationPolicy, LevelBound, Module, Offset, ScalarDecl, StencilDef, Stmt, UnOp,
};
pub use parser::{parse_expr, parse_module};
pub use span::{CResult, CompileError, Span};
