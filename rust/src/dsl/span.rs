//! Source spans and compile-error reporting for the GTScript-RS frontend.

use std::fmt;

/// A half-open byte range into the original source, plus line/column of the
/// start for human-readable diagnostics.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct Span {
    pub start: usize,
    pub end: usize,
    pub line: u32,
    pub col: u32,
}

impl Span {
    pub fn new(start: usize, end: usize, line: u32, col: u32) -> Self {
        Span { start, end, line, col }
    }

    /// Span covering both `self` and `other`.
    pub fn merge(self, other: Span) -> Span {
        Span {
            start: self.start.min(other.start),
            end: self.end.max(other.end),
            line: if self.start <= other.start { self.line } else { other.line },
            col: if self.start <= other.start { self.col } else { other.col },
        }
    }
}

impl fmt::Display for Span {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}", self.line, self.col)
    }
}

/// A compile-time error produced anywhere in the toolchain
/// (lexer, parser, semantic checks, analysis pipeline, backend codegen).
#[derive(Debug, Clone, PartialEq)]
pub struct CompileError {
    pub message: String,
    pub span: Option<Span>,
    /// Which toolchain phase raised the error (e.g. "parse", "extents").
    pub phase: &'static str,
}

impl CompileError {
    pub fn new(phase: &'static str, message: impl Into<String>) -> Self {
        CompileError { message: message.into(), span: None, phase }
    }

    pub fn with_span(phase: &'static str, message: impl Into<String>, span: Span) -> Self {
        CompileError { message: message.into(), span: Some(span), phase }
    }
}

impl fmt::Display for CompileError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self.span {
            Some(s) => write!(f, "[{}] {} (at {})", self.phase, self.message, s),
            None => write!(f, "[{}] {}", self.phase, self.message),
        }
    }
}

impl std::error::Error for CompileError {}

pub type CResult<T> = Result<T, CompileError>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn span_merge_orders() {
        let a = Span::new(0, 4, 1, 1);
        let b = Span::new(10, 14, 2, 3);
        let m = a.merge(b);
        assert_eq!(m.start, 0);
        assert_eq!(m.end, 14);
        assert_eq!(m.line, 1);
        let m2 = b.merge(a);
        assert_eq!(m2, m);
    }

    #[test]
    fn error_display() {
        let e = CompileError::with_span("parse", "unexpected token", Span::new(3, 4, 2, 7));
        assert_eq!(format!("{e}"), "[parse] unexpected token (at 2:7)");
        let e2 = CompileError::new("extents", "boom");
        assert_eq!(format!("{e2}"), "[extents] boom");
    }
}
