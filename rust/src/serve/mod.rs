//! `repro serve` — stencils as a long-running service.
//!
//! A daemon built entirely on `std::net`: newline-delimited JSON over
//! TCP (one request per line, one response per line), many concurrent
//! clients, zero heavy dependencies. The split:
//!
//! * [`protocol`] — the wire format: request parsing, [`WireOptions`]
//!   (the over-the-wire spelling of [`crate::opt::ExecOptions`]),
//!   structured errors with HTTP-flavored codes, and bit-exact hex64
//!   digest transport.
//! * [`server`] — session state (per-tenant coordinators + lease
//!   tables), admission under a global [`CoreBudget`] composing request
//!   concurrency with per-run sharding, leader/follower run coalescing,
//!   the `/metrics` text snapshot, and the accept loop.
//!
//! [`CoreBudget`]: crate::backend::shard::CoreBudget
//! [`WireOptions`]: protocol::WireOptions

pub mod protocol;
pub mod server;

pub use protocol::{Op, Request, ServeError, WireOptions};
pub use server::{ServeConfig, Server, ServerHandle};
