//! The `repro serve` wire protocol: newline-delimited JSON over a plain
//! socket, one request object per line, one response object per line.
//!
//! ## Requests
//!
//! ```json
//! {"op":"bind","id":1,"stencil":"hdiff","backend":"vector",
//!  "domain":[32,32,8],"options":{"opt_level":"3","threads":"2"}}
//! {"op":"run","id":2,"lease":1,"iters":4,"deadline_ms":2000}
//! {"op":"metrics","id":3}
//! {"op":"shutdown"}
//! ```
//!
//! Fields: `op` (required: `compile` | `bind` | `run` | `metrics` |
//! `shutdown`), `id` (optional request tag, echoed verbatim), `tenant`
//! (library namespace, default `"default"`), `stencil` + optional `src`
//! (library name, or any name with inline `.gts` source), `backend`
//! (default `"vector"`), `domain` (`[ni,nj,nk]`), `scalars`
//! (`{name: value}`), `lease` (from a prior `bind`), `iters`,
//! `deadline_ms`, and `options` — the wire spelling of
//! [`ExecOptions`]: `opt_level`, `fast_math`, `threads`, `tier`,
//! `dtype`, parsed by the *same* `OptLevel::parse` / `Sharding::parse` /
//! `ExecTier::parse` / `DType::parse` the CLI flags use, so library,
//! CLI and wire agree on one surface.
//!
//! ## Responses
//!
//! Success: `{"ok":true,"id":…,…}`. Failure:
//! `{"ok":false,"id":…,"code":N,"error":"…"[,"retry_after_ms":N]}` with
//! HTTP-flavored codes: 400 malformed request, 404 unknown
//! stencil/lease/backend, 408 deadline exceeded, 410 stale lease
//! (re-bind), 429 overloaded (load shed — carries `retry_after_ms`),
//! 500 internal, 503 backend unavailable.
//!
//! `u64` values that must survive bit-exactly (fingerprints,
//! `f64::to_bits` digests) travel as zero-padded hex strings, never JSON
//! numbers.

use crate::backend::kernels::ExecTier;
use crate::backend::shard::Sharding;
use crate::dsl::ast::DType;
use crate::jsonw::{self, Obj, Value};
use crate::opt::{ExecOptions, OptLevel};

pub const CODE_BAD_REQUEST: u16 = 400;
pub const CODE_NOT_FOUND: u16 = 404;
pub const CODE_DEADLINE: u16 = 408;
pub const CODE_STALE_LEASE: u16 = 410;
pub const CODE_OVERLOADED: u16 = 429;
pub const CODE_INTERNAL: u16 = 500;
pub const CODE_UNAVAILABLE: u16 = 503;

/// A structured protocol-level failure (the `ok:false` body).
#[derive(Debug, Clone)]
pub struct ServeError {
    pub code: u16,
    pub message: String,
    /// Backpressure hint on 429 responses.
    pub retry_after_ms: Option<u64>,
}

impl ServeError {
    fn new(code: u16, message: impl Into<String>) -> ServeError {
        ServeError { code, message: message.into(), retry_after_ms: None }
    }

    pub fn bad_request(msg: impl Into<String>) -> ServeError {
        ServeError::new(CODE_BAD_REQUEST, msg)
    }

    pub fn not_found(msg: impl Into<String>) -> ServeError {
        ServeError::new(CODE_NOT_FOUND, msg)
    }

    pub fn deadline(msg: impl Into<String>) -> ServeError {
        ServeError::new(CODE_DEADLINE, msg)
    }

    pub fn stale_lease(msg: impl Into<String>) -> ServeError {
        ServeError::new(CODE_STALE_LEASE, msg)
    }

    pub fn overloaded(msg: impl Into<String>, retry_after_ms: u64) -> ServeError {
        ServeError {
            code: CODE_OVERLOADED,
            message: msg.into(),
            retry_after_ms: Some(retry_after_ms),
        }
    }

    pub fn internal(msg: impl Into<String>) -> ServeError {
        ServeError::new(CODE_INTERNAL, msg)
    }

    pub fn unavailable(msg: impl Into<String>) -> ServeError {
        ServeError::new(CODE_UNAVAILABLE, msg)
    }
}

/// Request verbs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Op {
    Compile,
    Bind,
    Run,
    Metrics,
    Shutdown,
}

impl Op {
    pub fn as_str(&self) -> &'static str {
        match self {
            Op::Compile => "compile",
            Op::Bind => "bind",
            Op::Run => "run",
            Op::Metrics => "metrics",
            Op::Shutdown => "shutdown",
        }
    }
}

/// The wire spelling of [`ExecOptions`]: every knob optional, resolved
/// against a base. The scheduling half doubles as a per-`run` override.
#[derive(Debug, Clone, Copy, Default)]
pub struct WireOptions {
    pub opt_level: Option<OptLevel>,
    pub fast_math: Option<bool>,
    pub sharding: Option<Sharding>,
    pub tier: Option<ExecTier>,
    /// Element-type override (`"f32"` / `"f64"`). Like `opt_level` and
    /// `fast_math` it salts the artifact fingerprint, so leases at
    /// different precisions never share a compiled stencil.
    pub dtype: Option<DType>,
}

impl WireOptions {
    /// `base` with every present knob overridden.
    pub fn resolve(&self, base: ExecOptions) -> ExecOptions {
        let mut exec = base;
        if let Some(level) = self.opt_level {
            exec = exec.with_opt_level(level);
        }
        if let Some(fm) = self.fast_math {
            exec = exec.with_fast_math(fm);
        }
        if let Some(sh) = self.sharding {
            exec = exec.with_sharding(sh);
        }
        if let Some(t) = self.tier {
            exec = exec.with_tier(t);
        }
        if let Some(dt) = self.dtype {
            exec = exec.with_dtype(Some(dt));
        }
        exec
    }
}

/// One parsed request line.
#[derive(Debug, Clone)]
pub struct Request {
    pub op: Op,
    /// Echoed verbatim in the response (client-side correlation).
    pub id: Option<u64>,
    pub tenant: String,
    pub stencil: Option<String>,
    /// Inline `.gts` module source (library lookup when absent).
    pub src: Option<String>,
    pub backend: String,
    pub domain: Option<[usize; 3]>,
    pub scalars: Vec<(String, f64)>,
    pub lease: Option<u64>,
    pub iters: u64,
    pub deadline_ms: Option<u64>,
    pub options: WireOptions,
}

fn want_str(v: &Value, key: &str) -> Result<Option<String>, String> {
    match v.get(key) {
        None => Ok(None),
        Some(x) => x
            .as_str()
            .map(|s| Some(s.to_string()))
            .ok_or_else(|| format!("`{key}` must be a string")),
    }
}

fn want_u64(v: &Value, key: &str) -> Result<Option<u64>, String> {
    match v.get(key) {
        None => Ok(None),
        Some(x) => x
            .as_u64()
            .map(Some)
            .ok_or_else(|| format!("`{key}` must be a non-negative integer")),
    }
}

fn want_bool(v: &Value, key: &str) -> Result<Option<bool>, String> {
    match v.get(key) {
        None => Ok(None),
        Some(x) => {
            x.as_bool().map(Some).ok_or_else(|| format!("`{key}` must be a boolean"))
        }
    }
}

fn parse_options(v: &Value) -> Result<WireOptions, String> {
    let Some(opts) = v.get("options") else {
        return Ok(WireOptions::default());
    };
    let members = opts.as_obj().ok_or("`options` must be an object")?;
    for (k, _) in members {
        if !matches!(k.as_str(), "opt_level" | "fast_math" | "threads" | "tier" | "dtype") {
            return Err(format!("unknown option `{k}`"));
        }
    }
    // Numbers are tolerated where the CLI takes a numeric spelling
    // (`opt_level`, `threads`); everything funnels through the same
    // parsers the CLI flags use.
    let as_text = |key: &str| -> Result<Option<String>, String> {
        match opts.get(key) {
            None => Ok(None),
            Some(Value::Str(s)) => Ok(Some(s.clone())),
            Some(x) => x
                .as_u64()
                .map(|n| Some(n.to_string()))
                .ok_or_else(|| format!("`{key}` must be a string or integer")),
        }
    };
    let opt_level = match as_text("opt_level")? {
        None => None,
        Some(s) => Some(
            OptLevel::parse(&s).ok_or_else(|| format!("bad opt_level `{s}`"))?,
        ),
    };
    let sharding = match as_text("threads")? {
        None => None,
        Some(s) => {
            Some(Sharding::parse(&s).ok_or_else(|| format!("bad threads `{s}`"))?)
        }
    };
    let tier = match want_str(opts, "tier")? {
        None => None,
        Some(s) => Some(ExecTier::parse(&s).ok_or_else(|| format!("bad tier `{s}`"))?),
    };
    let dtype = match want_str(opts, "dtype")? {
        None => None,
        Some(s) => Some(DType::parse(&s).ok_or_else(|| format!("bad dtype `{s}`"))?),
    };
    let fast_math = want_bool(opts, "fast_math")?;
    Ok(WireOptions { opt_level, fast_math, sharding, tier, dtype })
}

/// Parse one request line. On failure the request `id` is still
/// recovered when the line was at least valid JSON, so the error
/// response can be correlated.
pub fn parse_request(line: &str) -> Result<Request, (Option<u64>, ServeError)> {
    let v = jsonw::parse(line).map_err(|e| {
        (None, ServeError::bad_request(format!("malformed request: {e}")))
    })?;
    let id = v.get("id").and_then(Value::as_u64);
    let bad = |msg: String| (id, ServeError::bad_request(msg));

    let members = match v.as_obj() {
        Some(m) => m,
        None => return Err(bad("request must be a JSON object".to_string())),
    };
    const KNOWN: [&str; 12] = [
        "op", "id", "tenant", "stencil", "src", "backend", "domain", "scalars",
        "lease", "iters", "deadline_ms", "options",
    ];
    for (k, _) in members {
        if !KNOWN.contains(&k.as_str()) {
            return Err(bad(format!("unknown request field `{k}`")));
        }
    }

    let op = match v.get("op").and_then(Value::as_str) {
        Some("compile") => Op::Compile,
        Some("bind") => Op::Bind,
        Some("run") => Op::Run,
        Some("metrics") => Op::Metrics,
        Some("shutdown") => Op::Shutdown,
        Some(other) => return Err(bad(format!("unknown op `{other}`"))),
        None => return Err(bad("missing string field `op`".to_string())),
    };

    let tenant =
        want_str(&v, "tenant").map_err(&bad)?.unwrap_or_else(|| "default".to_string());
    let stencil = want_str(&v, "stencil").map_err(&bad)?;
    let src = want_str(&v, "src").map_err(&bad)?;
    let backend =
        want_str(&v, "backend").map_err(&bad)?.unwrap_or_else(|| "vector".to_string());
    let lease = want_u64(&v, "lease").map_err(&bad)?;
    let iters = want_u64(&v, "iters").map_err(&bad)?.unwrap_or(1);
    if iters == 0 {
        return Err(bad("`iters` must be at least 1".to_string()));
    }
    let deadline_ms = want_u64(&v, "deadline_ms").map_err(&bad)?;

    let domain = match v.get("domain") {
        None => None,
        Some(d) => {
            let items = d.as_arr().ok_or_else(|| {
                bad("`domain` must be an array of three integers".to_string())
            })?;
            let dims: Option<Vec<u64>> = items.iter().map(Value::as_u64).collect();
            match dims.as_deref() {
                Some([ni, nj, nk]) => Some([*ni as usize, *nj as usize, *nk as usize]),
                _ => {
                    return Err(bad(
                        "`domain` must be an array of three integers".to_string(),
                    ))
                }
            }
        }
    };

    let scalars = match v.get("scalars") {
        None => Vec::new(),
        Some(s) => {
            let members = s
                .as_obj()
                .ok_or_else(|| bad("`scalars` must be an object".to_string()))?;
            let mut out = Vec::with_capacity(members.len());
            for (name, value) in members {
                let value = value.as_f64().ok_or_else(|| {
                    bad(format!("scalar `{name}` must be a number"))
                })?;
                out.push((name.clone(), value));
            }
            out
        }
    };

    let options = parse_options(&v).map_err(&bad)?;

    Ok(Request {
        op,
        id,
        tenant,
        stencil,
        src,
        backend,
        domain,
        scalars,
        lease,
        iters,
        deadline_ms,
        options,
    })
}

/// Start a success response: `{"ok":true[,"id":N]…}`.
pub fn ok_response(id: Option<u64>) -> Obj {
    let mut o = Obj::new().bool("ok", true);
    if let Some(id) = id {
        o = o.int("id", id);
    }
    o
}

/// Render a failure response line.
pub fn error_response(id: Option<u64>, err: &ServeError) -> String {
    let mut o = Obj::new().bool("ok", false);
    if let Some(id) = id {
        o = o.int("id", id);
    }
    o = o.int("code", err.code).str("error", &err.message);
    if let Some(ms) = err.retry_after_ms {
        o = o.int("retry_after_ms", ms);
    }
    o.finish()
}

/// A `u64` that must cross the wire bit-exactly, as zero-padded hex.
pub fn hex64(v: u64) -> String {
    format!("{v:016x}")
}

/// Inverse of [`hex64`].
pub fn parse_hex64(s: &str) -> Option<u64> {
    u64::from_str_radix(s, 16).ok()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_a_full_request() {
        let r = parse_request(
            r#"{"op":"bind","id":7,"tenant":"t1","stencil":"hdiff","backend":"vector",
                "domain":[32,32,8],"scalars":{"alpha":0.25},
                "options":{"opt_level":"3","threads":"2","tier":"interpreted","fast_math":true,"dtype":"f32"}}"#
                .replace('\n', " ")
                .as_str(),
        )
        .unwrap();
        assert_eq!(r.op, Op::Bind);
        assert_eq!(r.id, Some(7));
        assert_eq!(r.tenant, "t1");
        assert_eq!(r.stencil.as_deref(), Some("hdiff"));
        assert_eq!(r.domain, Some([32, 32, 8]));
        assert_eq!(r.scalars, vec![("alpha".to_string(), 0.25)]);
        let exec = r.options.resolve(ExecOptions::default());
        assert_eq!(exec.opt_level, OptLevel::O3);
        assert_eq!(exec.sharding, Sharding::Threads(2));
        assert_eq!(exec.tier, ExecTier::Interpreted);
        assert!(exec.fast_math);
        assert_eq!(exec.dtype, Some(DType::F32));
    }

    #[test]
    fn defaults_apply() {
        let r = parse_request(r#"{"op":"run","lease":3}"#).unwrap();
        assert_eq!(r.op, Op::Run);
        assert_eq!(r.tenant, "default");
        assert_eq!(r.backend, "vector");
        assert_eq!(r.iters, 1);
        assert_eq!(r.lease, Some(3));
        // No options present: resolve is the identity.
        let base = ExecOptions::new().with_opt_level(OptLevel::O1);
        assert_eq!(r.options.resolve(base), base);
    }

    #[test]
    fn numeric_option_spellings_match_cli_parsers() {
        let r = parse_request(
            r#"{"op":"compile","stencil":"copy","options":{"opt_level":0,"threads":4}}"#,
        )
        .unwrap();
        assert_eq!(r.options.opt_level, Some(OptLevel::O0));
        assert_eq!(r.options.sharding, Some(Sharding::Threads(4)));
    }

    #[test]
    fn rejects_malformed_requests_with_400() {
        for bad in [
            "not json",
            r#"[1,2,3]"#,
            r#"{"op":"frobnicate"}"#,
            r#"{"stencil":"hdiff"}"#,
            r#"{"op":"run","lease":-1}"#,
            r#"{"op":"run","lease":1,"iters":0}"#,
            r#"{"op":"bind","domain":[1,2]}"#,
            r#"{"op":"bind","domain":[1,2,"x"]}"#,
            r#"{"op":"bind","mystery":1}"#,
            r#"{"op":"bind","options":{"opt_level":"9"}}"#,
            r#"{"op":"bind","options":{"warp":1}}"#,
            r#"{"op":"bind","options":{"dtype":"f16"}}"#,
            r#"{"op":"bind","scalars":{"a":"b"}}"#,
        ] {
            let (_, err) = parse_request(bad).unwrap_err();
            assert_eq!(err.code, CODE_BAD_REQUEST, "`{bad}`");
        }
        // The id survives a field-level failure for correlation.
        let (id, _) = parse_request(r#"{"op":"nope","id":42}"#).unwrap_err();
        assert_eq!(id, Some(42));
    }

    #[test]
    fn response_shapes() {
        let ok = ok_response(Some(1)).str("fingerprint", &hex64(0xabc)).finish();
        let v = crate::jsonw::parse(&ok).unwrap();
        assert_eq!(v.get("ok").unwrap().as_bool(), Some(true));
        assert_eq!(v.get("id").unwrap().as_u64(), Some(1));
        assert_eq!(
            parse_hex64(v.get("fingerprint").unwrap().as_str().unwrap()),
            Some(0xabc)
        );

        let err = error_response(None, &ServeError::overloaded("core budget full", 25));
        let v = crate::jsonw::parse(&err).unwrap();
        assert_eq!(v.get("ok").unwrap().as_bool(), Some(false));
        assert_eq!(v.get("code").unwrap().as_u64(), Some(CODE_OVERLOADED as u64));
        assert_eq!(v.get("retry_after_ms").unwrap().as_u64(), Some(25));
    }

    #[test]
    fn hex64_roundtrip() {
        for v in [0u64, 1, u64::MAX, 0xdead_beef_cafe_f00d] {
            assert_eq!(parse_hex64(&hex64(v)), Some(v));
        }
        assert_eq!(parse_hex64("zz"), None);
    }
}
