//! The `repro serve` daemon: session state, admission scheduling,
//! run coalescing, and the TCP accept loop.
//!
//! ## Architecture
//!
//! * **Per-tenant stencil libraries.** Each tenant owns one
//!   [`Coordinator`] (its `StencilCache` Arcs are the compiled-artifact
//!   store) and one lease table of [`BoundInvocation`]s with server-side
//!   storages. `bind` validates once; every `run` against the lease is
//!   the cheap re-check-shapes path — the bind-once/run-many contract,
//!   stretched across a socket.
//! * **Admission under a global core budget.** A
//!   [`CoreBudget`] semaphore sized to the machine composes *outer*
//!   request concurrency with each request's *inner* [`Sharding`]
//!   fan-out: a run acquires as many slots as its resolved shard plan
//!   occupies. Saturation sheds load with structured 429 responses
//!   (`retry_after_ms` included) or times queued requests out at their
//!   per-request deadline — the queue is bounded, never a blowup.
//! * **Coalescing.** Same-group (tenant, fingerprint, backend)
//!   small-domain runs queue behind one leader that drains the whole
//!   batch under a single budget admission — one sharded dispatch window
//!   instead of N per-request admissions. Honest by construction:
//!   scheduling never changes results, so a coalesced run is
//!   bit-identical to a solo one.
//! * **Determinism.** Storages are allocated server-side and filled with
//!   [`synthetic_fill`], the same deterministic pattern the CLI uses, so
//!   a wire run and an in-process run of the same stencil/domain/options
//!   produce bit-identical `sum_bits`/`hash` digests.

use crate::backend::is_unavailable;
use crate::backend::shard::{Admission, CoreBudget, Sharding};
use crate::coordinator::{BoundInvocation, Coordinator};
use crate::jsonw::{self, Obj};
use crate::opt::ExecOptions;
use crate::serve::protocol::{
    error_response, hex64, ok_response, parse_request, Op, Request, ServeError,
    CODE_DEADLINE, CODE_OVERLOADED,
};
use crate::storage::{synthetic_fill, Storage};
use anyhow::Result;
use std::collections::{BTreeMap, HashMap, VecDeque};
use std::io::{BufRead, BufReader, Write as _};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Daemon configuration (CLI flags map onto this 1:1).
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Bind address; port 0 picks an ephemeral port.
    pub addr: String,
    /// Global core budget shared by every request's shard fan-out.
    pub cores: usize,
    /// Requests allowed to wait for cores at once; excess is shed with
    /// 429 immediately (0 = shed on any contention).
    pub max_waiters: usize,
    /// Deadline applied to requests that carry none.
    pub default_deadline_ms: u64,
    /// Domains up to this many elements are eligible for same-group run
    /// coalescing (0 disables coalescing).
    pub small_domain_elems: usize,
    /// Leases retained per tenant; the oldest is evicted past this (a
    /// later run against it gets a structured 410 re-bind error).
    pub max_leases_per_tenant: usize,
    /// Persistent artifact cache root (see [`crate::persist`]). `None`
    /// falls back to the `REPRO_CACHE_DIR` environment variable; absent
    /// both, persistence is off. When set, the store is opened at bind
    /// time (warm start) and every tenant coordinator compiles through
    /// it.
    pub cache_dir: Option<String>,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            addr: "127.0.0.1:0".to_string(),
            cores: std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
            max_waiters: 64,
            default_deadline_ms: 10_000,
            small_domain_elems: 4096,
            max_leases_per_tenant: 64,
            cache_dir: None,
        }
    }
}

const OPS: [&str; 5] = ["compile", "bind", "run", "metrics", "shutdown"];

fn op_index(op: Op) -> usize {
    match op {
        Op::Compile => 0,
        Op::Bind => 1,
        Op::Run => 2,
        Op::Metrics => 3,
        Op::Shutdown => 4,
    }
}

#[derive(Default)]
struct ServeStats {
    /// Requests received, by [`OPS`] index.
    requests: [AtomicU64; 5],
    errors: AtomicU64,
    backpressure: AtomicU64,
    deadline_exceeded: AtomicU64,
    /// Runs that rode along behind another run's budget admission.
    coalesced_runs: AtomicU64,
    /// Dispatch windows that served more than one run.
    coalesced_batches: AtomicU64,
}

/// One bound invocation plus its server-side storages.
struct Lease {
    inv: BoundInvocation,
    /// `(name, storage)` in declaration order (the order `inv.run` takes).
    fields: Vec<(String, Storage)>,
    stencil: String,
    backend: String,
    fingerprint: u64,
}

#[derive(Default)]
struct LeaseTable {
    map: HashMap<u64, Arc<Mutex<Lease>>>,
    /// Issue order, for eviction.
    order: VecDeque<u64>,
    /// Last issued id (ids start at 1).
    next: u64,
}

impl LeaseTable {
    fn insert(&mut self, lease: Lease, cap: usize) -> u64 {
        self.next += 1;
        let id = self.next;
        self.map.insert(id, Arc::new(Mutex::new(lease)));
        self.order.push_back(id);
        while self.order.len() > cap.max(1) {
            if let Some(old) = self.order.pop_front() {
                self.map.remove(&old);
            }
        }
        id
    }

    /// Distinguishes *stale* (was issued, since evicted → 410 with a
    /// re-bind hint) from *never issued* (→ 404).
    fn get(&self, id: u64) -> Result<Arc<Mutex<Lease>>, ServeError> {
        if let Some(lease) = self.map.get(&id) {
            return Ok(lease.clone());
        }
        if id >= 1 && id <= self.next {
            Err(ServeError::stale_lease(format!(
                "lease {id} expired (evicted); re-bind the invocation"
            )))
        } else {
            Err(ServeError::not_found(format!("no lease {id}")))
        }
    }
}

struct Tenant {
    coord: Mutex<Coordinator>,
    leases: Mutex<LeaseTable>,
}

/// Digest of one executed run (never the field data itself — results
/// cross the wire as bit-exact hex digests).
struct RunOutcome {
    execute_ns: u64,
    threads_used: u32,
    /// `(name, domain_sum().to_bits(), domain_hash())`, declaration order.
    fields: Vec<(String, u64, u64)>,
    /// This run rode along behind another run's admission.
    coalesced: bool,
}

/// One queued run request inside a coalescing group.
struct RunJob {
    lease: Arc<Mutex<Lease>>,
    iters: u64,
    /// Scheduling-half overrides applied under the lease lock.
    sharding: Option<Sharding>,
    tier: Option<crate::backend::kernels::ExecTier>,
    scalars: Vec<(String, f64)>,
    deadline: Instant,
    /// Cores this run's resolved shard plan occupies.
    want: usize,
    slot: Mutex<Option<Result<RunOutcome, ServeError>>>,
    ready: Condvar,
}

#[derive(Default)]
struct GroupState {
    queue: VecDeque<Arc<RunJob>>,
    /// A leader is currently draining this group.
    leading: bool,
}

struct Group {
    state: Mutex<GroupState>,
}

/// Same-(tenant, fingerprint, backend) run batching.
#[derive(Default)]
struct Coalescer {
    groups: Mutex<HashMap<String, Arc<Group>>>,
}

impl Coalescer {
    /// Enqueue `job`; returns the group and whether the caller must lead
    /// (enqueue + leadership-take are atomic under the group lock, so
    /// exactly one un-led queue ever gains exactly one leader).
    fn enqueue(&self, key: &str, job: Arc<RunJob>) -> (Arc<Group>, bool) {
        let group = self
            .groups
            .lock()
            .unwrap()
            .entry(key.to_string())
            .or_insert_with(|| Arc::new(Group { state: Mutex::new(GroupState::default()) }))
            .clone();
        let mut st = group.state.lock().unwrap();
        st.queue.push_back(job);
        let leader = !st.leading;
        if leader {
            st.leading = true;
        }
        drop(st);
        (group, leader)
    }
}

struct ServerState {
    config: ServeConfig,
    local_addr: SocketAddr,
    tenants: Mutex<HashMap<String, Arc<Tenant>>>,
    budget: Arc<CoreBudget>,
    coalescer: Coalescer,
    stats: ServeStats,
    shutdown: AtomicBool,
    /// Persistent artifact store shared by every tenant coordinator
    /// (opened once at bind time — the warm start).
    persist: Option<Arc<crate::persist::PersistStore>>,
}

impl ServerState {
    fn tenant(&self, name: &str) -> Arc<Tenant> {
        self.tenants
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_insert_with(|| {
                let mut coord = Coordinator::new();
                if let Some(store) = &self.persist {
                    coord.set_persist(store.clone());
                }
                Arc::new(Tenant {
                    coord: Mutex::new(coord),
                    leases: Mutex::new(LeaseTable::default()),
                })
            })
            .clone()
    }

    fn existing_tenant(&self, name: &str) -> Option<Arc<Tenant>> {
        self.tenants.lock().unwrap().get(name).cloned()
    }
}

// ---------------------------------------------------------------------------
// Request handlers
// ---------------------------------------------------------------------------

fn handle_line(state: &Arc<ServerState>, line: &str) -> String {
    let req = match parse_request(line) {
        Ok(r) => r,
        Err((id, err)) => {
            state.stats.errors.fetch_add(1, Ordering::Relaxed);
            return error_response(id, &err);
        }
    };
    state.stats.requests[op_index(req.op)].fetch_add(1, Ordering::Relaxed);
    let result = match req.op {
        Op::Compile => op_compile(state, &req),
        Op::Bind => op_bind(state, &req),
        Op::Run => op_run(state, &req),
        Op::Metrics => op_metrics(state, &req),
        Op::Shutdown => op_shutdown(state, &req),
    };
    match result {
        Ok(resp) => resp,
        Err(err) => {
            match err.code {
                CODE_OVERLOADED => {
                    state.stats.backpressure.fetch_add(1, Ordering::Relaxed);
                }
                CODE_DEADLINE => {
                    state.stats.deadline_exceeded.fetch_add(1, Ordering::Relaxed);
                }
                _ => {}
            }
            state.stats.errors.fetch_add(1, Ordering::Relaxed);
            error_response(req.id, &err)
        }
    }
}

/// Compile `req`'s stencil in the tenant's coordinator under the
/// request's resolved [`ExecOptions`]; returns the salted fingerprint.
fn compile_in(tenant: &Tenant, req: &Request) -> Result<(u64, ExecOptions), ServeError> {
    let name = req
        .stencil
        .as_deref()
        .ok_or_else(|| ServeError::bad_request("missing `stencil`"))?;
    let exec = req.options.resolve(ExecOptions::default());
    let mut coord = tenant.coord.lock().unwrap();
    coord.set_exec_options(exec);
    let fp = match &req.src {
        Some(src) => coord
            .compile_source(src, name, &BTreeMap::new())
            .map_err(|e| ServeError::bad_request(format!("compile failed: {e:#}")))?,
        None => coord
            .compile_library(name)
            .map_err(|e| ServeError::not_found(format!("{e:#}")))?,
    };
    Ok((fp, exec))
}

fn op_compile(state: &Arc<ServerState>, req: &Request) -> Result<String, ServeError> {
    let tenant = state.tenant(&req.tenant);
    let (fp, exec) = compile_in(&tenant, req)?;
    Ok(ok_response(req.id)
        .str("fingerprint", &hex64(fp))
        .str("opt_level", &exec.opt_level.to_string())
        .bool("fast_math", exec.fast_math)
        .finish())
}

fn op_bind(state: &Arc<ServerState>, req: &Request) -> Result<String, ServeError> {
    let domain = req
        .domain
        .ok_or_else(|| ServeError::bad_request("bind needs `domain`"))?;
    let tenant = state.tenant(&req.tenant);
    let (fp, _exec) = compile_in(&tenant, req)?;
    let stencil = {
        let mut coord = tenant.coord.lock().unwrap();
        coord.stencil_for(fp, &req.backend).map_err(|e| {
            if is_unavailable(&e) {
                ServeError::unavailable(format!("{e:#}"))
            } else {
                ServeError::not_found(format!("{e:#}"))
            }
        })?
    };

    // Server-side storages with the canonical deterministic fill: a wire
    // run is bit-comparable to an in-process run of the same stencil.
    let mut fields = Vec::with_capacity(stencil.ir().fields.len());
    for (idx, f) in stencil.ir().fields.iter().enumerate() {
        let mut s = stencil
            .alloc_field(&f.name, domain)
            .map_err(|e| ServeError::bad_request(format!("{e:#}")))?;
        synthetic_fill(&mut s, idx as f64);
        fields.push((f.name.clone(), s));
    }
    for (name, _) in &req.scalars {
        if !stencil.ir().scalars.iter().any(|s| &s.name == name) {
            return Err(ServeError::bad_request(format!(
                "stencil `{}` has no scalar `{name}`",
                stencil.name()
            )));
        }
    }
    let scalars: Vec<(String, f64)> = stencil
        .ir()
        .scalars
        .iter()
        .map(|s| {
            let v = req
                .scalars
                .iter()
                .find(|(n, _)| n == &s.name)
                .map(|(_, v)| *v)
                .unwrap_or(0.1);
            (s.name.clone(), v)
        })
        .collect();
    let inv = stencil
        .bind()
        .domain(domain)
        .fields(&fields)
        .scalars(&scalars)
        .finish()
        .map_err(|e| ServeError::bad_request(format!("{e:#}")))?;

    let field_names: Vec<String> =
        fields.iter().map(|(n, _)| jsonw::string(n)).collect();
    let stencil_name = stencil.name().to_string();
    let lease = Lease {
        inv,
        fields,
        stencil: stencil_name.clone(),
        backend: req.backend.clone(),
        fingerprint: fp,
    };
    let lease_id = tenant
        .leases
        .lock()
        .unwrap()
        .insert(lease, state.config.max_leases_per_tenant);
    Ok(ok_response(req.id)
        .int("lease", lease_id)
        .str("stencil", &stencil_name)
        .str("backend", &req.backend)
        .str("fingerprint", &hex64(fp))
        .raw("domain", &format!("[{},{},{}]", domain[0], domain[1], domain[2]))
        .raw("fields", &jsonw::array(&field_names))
        .finish())
}

fn op_run(state: &Arc<ServerState>, req: &Request) -> Result<String, ServeError> {
    let lease_id = req
        .lease
        .ok_or_else(|| ServeError::bad_request("run needs `lease`"))?;
    let tenant = state
        .existing_tenant(&req.tenant)
        .ok_or_else(|| ServeError::not_found(format!("no tenant `{}`", req.tenant)))?;
    let lease = tenant.leases.lock().unwrap().get(lease_id)?;
    let deadline = Instant::now()
        + Duration::from_millis(
            req.deadline_ms.unwrap_or(state.config.default_deadline_ms),
        );
    let (want, elems, group_key) = {
        let g = lease.lock().unwrap();
        let sharding = req.options.sharding.unwrap_or_else(|| g.inv.sharding());
        let d = g.inv.domain();
        (
            sharding.resolve(d[0]),
            d[0] * d[1] * d[2],
            format!("{}/{:016x}/{}", req.tenant, g.fingerprint, g.backend),
        )
    };
    let job = Arc::new(RunJob {
        lease,
        iters: req.iters,
        sharding: req.options.sharding,
        tier: req.options.tier,
        scalars: req.scalars.clone(),
        deadline,
        want,
        slot: Mutex::new(None),
        ready: Condvar::new(),
    });
    let outcome = if elems <= state.config.small_domain_elems {
        let (group, leader) = state.coalescer.enqueue(&group_key, job.clone());
        if leader {
            lead_group(state, &group);
        }
        await_result(&group, &job)?
    } else {
        run_direct(state, &job)?
    };

    let field_rows: Vec<String> = outcome
        .fields
        .iter()
        .map(|(n, sum_bits, hash)| {
            Obj::new()
                .str("name", n)
                .str("sum_bits", &hex64(*sum_bits))
                .str("hash", &hex64(*hash))
                .finish()
        })
        .collect();
    Ok(ok_response(req.id)
        .int("lease", lease_id)
        .int("iters", req.iters)
        .int("threads_used", outcome.threads_used as u64)
        .int("execute_ns", outcome.execute_ns)
        .bool("coalesced", outcome.coalesced)
        .raw("fields", &jsonw::array(&field_rows))
        .finish())
}

fn overloaded_error(state: &ServerState, in_use: usize, waiters: usize) -> ServeError {
    ServeError::overloaded(
        format!(
            "core budget saturated ({in_use}/{} cores in use, {waiters} waiting)",
            state.budget.cores()
        ),
        50,
    )
}

/// Large-domain path: one budget admission per run.
fn run_direct(state: &Arc<ServerState>, job: &RunJob) -> Result<RunOutcome, ServeError> {
    match state.budget.acquire(job.want, Some(job.deadline)) {
        Admission::Granted(_permit) => execute_run(job, false),
        Admission::Overloaded { in_use, waiters } => {
            Err(overloaded_error(state, in_use, waiters))
        }
        Admission::DeadlineExceeded => {
            Err(ServeError::deadline("deadline exceeded waiting for cores"))
        }
    }
}

/// Execute one job against its lease (the lease lock serializes runs on
/// one lease; different leases run concurrently).
fn execute_run(job: &RunJob, coalesced: bool) -> Result<RunOutcome, ServeError> {
    let mut guard = job.lease.lock().unwrap();
    let Lease { inv, fields, .. } = &mut *guard;
    // Scheduling-half overrides stick to the lease (like
    // `BoundInvocation::set_sharding` in-process).
    if let Some(sh) = job.sharding {
        inv.set_sharding(sh);
    }
    if let Some(t) = job.tier {
        inv.set_exec_tier(t);
    }
    for (name, value) in &job.scalars {
        inv.set_scalar(name, *value)
            .map_err(|e| ServeError::bad_request(format!("{e:#}")))?;
    }
    let mut execute_ns: u128 = 0;
    let mut threads_used = 1u32;
    for _ in 0..job.iters {
        let mut refs: Vec<&mut Storage> = fields.iter_mut().map(|(_, s)| s).collect();
        let stats = inv
            .run(&mut refs)
            .map_err(|e| ServeError::internal(format!("{e:#}")))?;
        execute_ns += stats.execute.as_nanos();
        threads_used = threads_used.max(stats.threads_used());
    }
    let digests = fields
        .iter()
        .map(|(n, s)| (n.clone(), s.domain_sum().to_bits(), s.domain_hash()))
        .collect();
    Ok(RunOutcome {
        execute_ns: execute_ns.min(u64::MAX as u128) as u64,
        threads_used,
        fields: digests,
        coalesced,
    })
}

fn deliver(job: &RunJob, res: Result<RunOutcome, ServeError>) {
    *job.slot.lock().unwrap() = Some(res);
    job.ready.notify_all();
}

/// Leader loop: acquire the budget once, then drain the group queue under
/// that single admission (the coalesced dispatch window). Admission
/// failure sheds the *whole* queued batch with structured errors —
/// honest load shedding, never a silently growing queue.
fn lead_group(state: &Arc<ServerState>, group: &Group) {
    loop {
        let front = { group.state.lock().unwrap().queue.front().cloned() };
        let Some(front) = front else {
            let mut st = group.state.lock().unwrap();
            if st.queue.is_empty() {
                st.leading = false;
                return;
            }
            continue;
        };
        match state.budget.acquire(front.want, Some(front.deadline)) {
            Admission::Granted(_permit) => {
                let mut batch = 0u64;
                loop {
                    let job = {
                        let mut st = group.state.lock().unwrap();
                        match st.queue.pop_front() {
                            Some(j) => j,
                            None => {
                                st.leading = false;
                                break;
                            }
                        }
                    };
                    batch += 1;
                    let res = if Instant::now() > job.deadline {
                        Err(ServeError::deadline("deadline exceeded before dispatch"))
                    } else {
                        execute_run(&job, batch > 1)
                    };
                    deliver(&job, res);
                }
                if batch > 1 {
                    state.stats.coalesced_batches.fetch_add(1, Ordering::Relaxed);
                    state.stats.coalesced_runs.fetch_add(batch - 1, Ordering::Relaxed);
                }
                return;
            }
            Admission::Overloaded { in_use, waiters } => {
                let err = overloaded_error(state, in_use, waiters);
                let drained: Vec<Arc<RunJob>> = {
                    let mut st = group.state.lock().unwrap();
                    st.leading = false;
                    st.queue.drain(..).collect()
                };
                for job in drained {
                    deliver(&job, Err(err.clone()));
                }
                return;
            }
            Admission::DeadlineExceeded => {
                // The front job's deadline lapsed while saturated: shed it
                // and retry admission for whatever is still queued.
                let popped = { group.state.lock().unwrap().queue.pop_front() };
                match popped {
                    Some(job) => deliver(
                        &job,
                        Err(ServeError::deadline("deadline exceeded waiting for cores")),
                    ),
                    None => {
                        let mut st = group.state.lock().unwrap();
                        if st.queue.is_empty() {
                            st.leading = false;
                            return;
                        }
                    }
                }
            }
        }
    }
}

/// Block until this job's result is delivered. A job whose deadline
/// passes while still *queued* removes itself (408); once a leader has
/// taken it, the leader's verdict is awaited.
fn await_result(group: &Group, job: &Arc<RunJob>) -> Result<RunOutcome, ServeError> {
    let mut slot = job.slot.lock().unwrap();
    loop {
        if let Some(res) = slot.take() {
            return res;
        }
        let (guard, _) = job
            .ready
            .wait_timeout(slot, Duration::from_millis(25))
            .unwrap();
        slot = guard;
        if slot.is_some() {
            continue;
        }
        if Instant::now() > job.deadline {
            let mut st = group.state.lock().unwrap();
            if let Some(pos) = st.queue.iter().position(|j| Arc::ptr_eq(j, job)) {
                st.queue.remove(pos);
                drop(st);
                return Err(ServeError::deadline("deadline exceeded while queued"));
            }
        }
    }
}

fn op_metrics(state: &Arc<ServerState>, req: &Request) -> Result<String, ServeError> {
    Ok(ok_response(req.id).str("text", &render_metrics(state)).finish())
}

/// The `/metrics` text body: serve counters, the core budget, per-tenant
/// per-(stencil, backend) timings from `SharedMetrics`, and the vector
/// backend's pool/executor counters from `PoolStats`.
fn render_metrics(state: &Arc<ServerState>) -> String {
    use std::fmt::Write as _;
    let mut out = String::new();
    for (i, op) in OPS.iter().enumerate() {
        let _ = writeln!(
            out,
            "serve_requests_total{{op=\"{op}\"}} {}",
            state.stats.requests[i].load(Ordering::Relaxed)
        );
    }
    let simple: [(&str, u64); 5] = [
        ("serve_errors_total", state.stats.errors.load(Ordering::Relaxed)),
        ("serve_backpressure_total", state.stats.backpressure.load(Ordering::Relaxed)),
        (
            "serve_deadline_exceeded_total",
            state.stats.deadline_exceeded.load(Ordering::Relaxed),
        ),
        ("serve_coalesced_runs_total", state.stats.coalesced_runs.load(Ordering::Relaxed)),
        (
            "serve_coalesced_batches_total",
            state.stats.coalesced_batches.load(Ordering::Relaxed),
        ),
    ];
    for (name, v) in simple {
        let _ = writeln!(out, "{name} {v}");
    }
    let _ = writeln!(out, "serve_core_budget_cores {}", state.budget.cores());
    let _ = writeln!(out, "serve_core_budget_in_use {}", state.budget.in_use());
    let _ = writeln!(out, "serve_core_budget_waiters {}", state.budget.waiters());
    // Persist counters are always present (zeros without a store) so
    // scrapers never need existence checks.
    let (ph, pm, pr) = state.persist.as_ref().map(|s| s.counters()).unwrap_or((0, 0, 0));
    let _ = writeln!(out, "persist_hits {ph}");
    let _ = writeln!(out, "persist_misses {pm}");
    let _ = writeln!(out, "persist_rejects {pr}");

    let tenants: Vec<(String, Arc<Tenant>)> = {
        let t = state.tenants.lock().unwrap();
        let mut v: Vec<_> = t.iter().map(|(k, v)| (k.clone(), v.clone())).collect();
        v.sort_by(|a, b| a.0.cmp(&b.0));
        v
    };
    for (name, tenant) in tenants {
        {
            let coord = tenant.coord.lock().unwrap();
            for ((stencil, backend), t) in coord.metrics.entries() {
                let labels =
                    format!("tenant=\"{name}\",stencil=\"{stencil}\",backend=\"{backend}\"");
                let _ = writeln!(out, "stencil_calls_total{{{labels}}} {}", t.calls);
                let _ = writeln!(
                    out,
                    "stencil_checks_seconds_total{{{labels}}} {}",
                    t.checks.as_secs_f64()
                );
                let _ = writeln!(
                    out,
                    "stencil_execute_seconds_total{{{labels}}} {}",
                    t.execute.as_secs_f64()
                );
                let _ = writeln!(out, "stencil_max_threads{{{labels}}} {}", t.max_threads);
            }
            for (backend, p) in coord.pool_stats() {
                let labels = format!("tenant=\"{name}\",backend=\"{backend}\"");
                let counters: [(&str, u64); 9] = [
                    ("pool_buffers_taken_total", p.taken),
                    ("pool_buffers_allocated_total", p.allocated),
                    ("pool_tiers_interpreted_total", p.tiers_interpreted),
                    ("pool_tiers_specialized_total", p.tiers_specialized),
                    ("pool_strips_interpreted_total", p.strips_interpreted),
                    ("pool_strips_guarded_total", p.strips_guarded),
                    ("pool_blocks_interior_total", p.blocks_interior),
                    // Cross-slab halo-rendezvous crossings on sequential
                    // sweeps, and multistages that still fell back to
                    // serial (in-level wavefronts) — together these prove
                    // whether sharded calls actually ran concurrent.
                    ("pool_halo_exchanges_total", p.halo_exchanges),
                    ("pool_serial_fallbacks_total", p.serial_fallbacks),
                ];
                for (metric, v) in counters {
                    let _ = writeln!(out, "{metric}{{{labels}}} {v}");
                }
            }
        }
        let leases = tenant.leases.lock().unwrap().map.len();
        let _ = writeln!(out, "serve_leases{{tenant=\"{name}\"}} {leases}");
    }
    out
}

fn op_shutdown(state: &Arc<ServerState>, req: &Request) -> Result<String, ServeError> {
    state.shutdown.store(true, Ordering::SeqCst);
    // Poke the accept loop so it observes the flag without a new client.
    let _ = TcpStream::connect(state.local_addr);
    Ok(ok_response(req.id).bool("stopping", true).finish())
}

// ---------------------------------------------------------------------------
// Server lifecycle
// ---------------------------------------------------------------------------

/// A bound-but-not-yet-serving daemon.
pub struct Server {
    listener: TcpListener,
    state: Arc<ServerState>,
}

impl Server {
    pub fn bind(config: ServeConfig) -> Result<Server> {
        let listener = TcpListener::bind(&config.addr)?;
        let local_addr = listener.local_addr()?;
        let budget = CoreBudget::new(config.cores, config.max_waiters);
        // Warm start: open the persist store at bind time so the first
        // request of every tenant compiles through it.
        let persist = match &config.cache_dir {
            Some(dir) => Some(Arc::new(crate::persist::PersistStore::open(dir)?)),
            None => crate::persist::PersistStore::from_env()?.map(Arc::new),
        };
        let state = Arc::new(ServerState {
            config,
            local_addr,
            tenants: Mutex::new(HashMap::new()),
            budget,
            coalescer: Coalescer::default(),
            stats: ServeStats::default(),
            shutdown: AtomicBool::new(false),
            persist,
        });
        Ok(Server { listener, state })
    }

    pub fn local_addr(&self) -> SocketAddr {
        self.state.local_addr
    }

    /// `(cache root, entries on disk)` of the persist store opened at
    /// bind time, if any — the CLI announces this as the warm-start line.
    pub fn persist_info(&self) -> Option<(String, usize)> {
        self.state
            .persist
            .as_ref()
            .map(|s| (s.root().display().to_string(), s.entries().len()))
    }

    /// Blocking accept loop; one handler thread per connection. Returns
    /// after a `shutdown` request (in-flight connections finish their
    /// current request and close).
    pub fn run(self) -> Result<()> {
        for stream in self.listener.incoming() {
            if self.state.shutdown.load(Ordering::SeqCst) {
                break;
            }
            let Ok(stream) = stream else { continue };
            let state = self.state.clone();
            std::thread::Builder::new()
                .name("gt4rs-serve-conn".to_string())
                .spawn(move || handle_connection(&state, stream))?;
        }
        Ok(())
    }

    /// Bind and serve on a background thread — the in-process harness the
    /// protocol tests and the serve bench drive.
    pub fn spawn(config: ServeConfig) -> Result<ServerHandle> {
        let server = Server::bind(config)?;
        let addr = server.local_addr();
        let state = server.state.clone();
        let join = std::thread::Builder::new()
            .name("gt4rs-serve-accept".to_string())
            .spawn(move || {
                let _ = server.run();
            })?;
        Ok(ServerHandle { addr, state, join: Some(join) })
    }
}

fn handle_connection(state: &Arc<ServerState>, stream: TcpStream) {
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let Ok(line) = line else { return };
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let resp = handle_line(state, line);
        let sent = writer
            .write_all(resp.as_bytes())
            .and_then(|_| writer.write_all(b"\n"))
            .and_then(|_| writer.flush());
        if sent.is_err() || state.shutdown.load(Ordering::SeqCst) {
            return;
        }
    }
}

/// Handle to a daemon spawned with [`Server::spawn`]; shuts the daemon
/// down on drop.
pub struct ServerHandle {
    addr: SocketAddr,
    state: Arc<ServerState>,
    join: Option<std::thread::JoinHandle<()>>,
}

impl ServerHandle {
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop accepting, wake the accept loop, and join it (idempotent).
    pub fn shutdown(&mut self) {
        self.state.shutdown.store(true, Ordering::SeqCst);
        let _ = TcpStream::connect(self.addr);
        if let Some(join) = self.join.take() {
            let _ = join.join();
        }
    }
}

impl Drop for ServerHandle {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lease_table_distinguishes_stale_from_unknown() {
        let mut c = Coordinator::new();
        let mk = || {
            let s = c.stencil_library("copy", "debug").unwrap();
            let domain = [4, 4, 2];
            let src = s.alloc_field("src", domain).unwrap();
            let dst = s.alloc_field("dst", domain).unwrap();
            let inv = s
                .bind()
                .field("src", &src)
                .field("dst", &dst)
                .domain(domain)
                .finish()
                .unwrap();
            Lease {
                inv,
                fields: vec![("src".into(), src), ("dst".into(), dst)],
                stencil: "copy".into(),
                backend: "debug".into(),
                fingerprint: 1,
            }
        };
        let mut table = LeaseTable::default();
        let a = table.insert(mk(), 2);
        let b = table.insert(mk(), 2);
        assert!(table.get(a).is_ok());
        assert!(table.get(b).is_ok());
        // Never-issued ids are 404s.
        assert_eq!(table.get(99).unwrap_err().code, crate::serve::protocol::CODE_NOT_FOUND);
        assert_eq!(table.get(0).unwrap_err().code, crate::serve::protocol::CODE_NOT_FOUND);
        // Eviction past the cap turns the oldest into a 410 re-bind.
        let _c = table.insert(mk(), 2);
        let err = table.get(a).unwrap_err();
        assert_eq!(err.code, crate::serve::protocol::CODE_STALE_LEASE);
        assert!(err.message.contains("re-bind"), "{}", err.message);
    }

    #[test]
    fn coalescer_grants_exactly_one_leader_per_drain() {
        let state = {
            let mut c = Coordinator::new();
            let s = c.stencil_library("copy", "debug").unwrap();
            let domain = [4, 4, 2];
            let src = s.alloc_field("src", domain).unwrap();
            let dst = s.alloc_field("dst", domain).unwrap();
            let inv = s
                .bind()
                .field("src", &src)
                .field("dst", &dst)
                .domain(domain)
                .finish()
                .unwrap();
            Arc::new(Mutex::new(Lease {
                inv,
                fields: vec![("src".into(), src), ("dst".into(), dst)],
                stencil: "copy".into(),
                backend: "debug".into(),
                fingerprint: 1,
            }))
        };
        let mk_job = || {
            Arc::new(RunJob {
                lease: state.clone(),
                iters: 1,
                sharding: None,
                tier: None,
                scalars: Vec::new(),
                deadline: Instant::now() + Duration::from_secs(5),
                want: 1,
                slot: Mutex::new(None),
                ready: Condvar::new(),
            })
        };
        let co = Coalescer::default();
        let (_g, lead1) = co.enqueue("k", mk_job());
        let (_g, lead2) = co.enqueue("k", mk_job());
        assert!(lead1, "first enqueue takes leadership");
        assert!(!lead2, "second rides along");
        // A different group gets its own leader.
        let (_g, lead3) = co.enqueue("other", mk_job());
        assert!(lead3);
    }
}
