//! Backend-aware 3-D field storages (paper §2.2 "storage" containers).

pub mod layout;
#[allow(clippy::module_inception)]
pub mod storage;

pub use layout::{Alignment, Layout};
pub use storage::{Storage, StorageInfo};
