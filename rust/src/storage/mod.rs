//! Backend-aware 3-D field storages (paper §2.2 "storage" containers).

pub mod element;
pub mod layout;
#[allow(clippy::module_inception)]
pub mod storage;
pub mod view;

pub use element::{Buf, Element};
pub use layout::{Alignment, Layout};
pub use storage::{Storage, StorageInfo};
pub use view::StorageView;

/// Fill `s` (halo included) with the canonical smooth deterministic test
/// pattern, parameterized by `phase` — by convention the field's
/// declaration index. One definition shared by the CLI's synthetic
/// inputs, the serve daemon's server-side allocations, the quickstart
/// and the protocol tests, so "same stencil, same domain" always means
/// bit-identical inputs whether a run happened in-process or over the
/// wire.
pub fn synthetic_fill(s: &mut Storage, phase: f64) {
    let [ni, nj, nk] = s.info.shape;
    let h = s.info.halo;
    for i in -(h[0].0 as i64)..(ni + h[0].1) as i64 {
        for j in -(h[1].0 as i64)..(nj + h[1].1) as i64 {
            for k in -(h[2].0 as i64)..(nk + h[2].1) as i64 {
                let v = (0.1 * (i as f64) + phase).sin() * (0.13 * (j as f64) - phase).cos()
                    + 0.01 * k as f64;
                s.set(i, j, k, v);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synthetic_fill_and_domain_hash_are_deterministic() {
        let mk = |phase: f64| {
            let mut s = Storage::with_halo([6, 5, 3], 2);
            synthetic_fill(&mut s, phase);
            s
        };
        let a = mk(1.0);
        let b = mk(1.0);
        assert_eq!(a.domain_hash(), b.domain_hash());
        assert_eq!(a.max_abs_diff(&b), 0.0);
        // Different phases give different data (different hashes).
        assert_ne!(a.domain_hash(), mk(2.0).domain_hash());
        // The hash is bit-sensitive where a sum would cancel.
        let mut c = mk(1.0);
        let v = c.get(1, 1, 1);
        c.set(1, 1, 1, v + 1.0);
        c.set(2, 1, 1, c.get(2, 1, 1) - 1.0);
        assert_ne!(a.domain_hash(), c.domain_hash());
    }
}
