//! The element-type abstraction behind dtype-generic storages.
//!
//! [`Element`] is a *sealed* trait implemented exactly for `f64` and `f32`
//! — the two dtypes the DSL declares ([`DType`]). Every execution path
//! (the debug interpreter, the materializing and fused vector paths, the
//! specialized kernel plans) is generic over `T: Element` and monomorphized
//! per dtype, so there is no `dyn` dispatch on any hot path and the
//! autovectorizer sees full-width `f32` lanes.
//!
//! [`Buf`] is the matching enum-of-buffers a [`crate::storage::Storage`]
//! owns: one tagged flat allocation, viewed as `&[T]` through the trait's
//! dispatch hooks. The tag always equals the storage's `info.dtype`, so a
//! `Buf::F32` never masquerades as an `f64` field.
//!
//! ## Numeric honesty
//!
//! All arithmetic on the execution paths happens in `T`'s native precision:
//! constants and scalar parameters are converted from their `f64` source
//! representation exactly once (round-to-nearest, deterministic), then every
//! operation — including the builtins below — runs at `T` width. This is
//! what makes the per-dtype bitwise-equivalence contract meaningful: an
//! `f32` run is a genuine single-precision computation, not an `f64`
//! computation rounded at the end.

use crate::dsl::ast::DType;
use std::ops::{Add, AddAssign, Div, DivAssign, Mul, MulAssign, Neg, Rem, Sub, SubAssign};

mod sealed {
    pub trait Sealed {}
    impl Sealed for f64 {}
    impl Sealed for f32 {}
}

/// A storage element type (`f64` or `f32`). Sealed: the two impls in this
/// module are the only ones possible, which lets unsafe storage-view code
/// rely on `T` being a plain IEEE-754 float with no drop glue.
pub trait Element:
    sealed::Sealed
    + Copy
    + Send
    + Sync
    + PartialEq
    + PartialOrd
    + std::fmt::Debug
    + std::fmt::Display
    + Default
    + Add<Output = Self>
    + Sub<Output = Self>
    + Mul<Output = Self>
    + Div<Output = Self>
    + Rem<Output = Self>
    + Neg<Output = Self>
    + AddAssign
    + SubAssign
    + MulAssign
    + DivAssign
    + 'static
{
    /// The DSL dtype this element type implements.
    const DTYPE: DType;
    const ZERO: Self;
    const ONE: Self;

    /// Deterministic round-to-nearest conversion from the `f64` source
    /// representation of constants, scalars and fill patterns.
    fn from_f64(v: f64) -> Self;
    /// Widening (exact for `f32`) conversion for diagnostics and norms.
    fn to_f64(self) -> f64;
    /// Native IEEE-754 bit pattern, zero-extended to 64 bits — cache and
    /// fingerprint material.
    fn to_bits64(self) -> u64;
    /// One FNV-1a step per *native-width* little-endian byte: `f32` and
    /// `f64` storages holding "the same" values hash differently, which is
    /// exactly what the serve digests and honesty gates need.
    fn fnv1a_step(self, h: u64) -> u64;

    /// Boolean encoding shared by every backend: comparisons and logic
    /// produce `ONE`/`ZERO`, truthiness is `!= ZERO`.
    #[inline(always)]
    fn from_bool(b: bool) -> Self {
        if b {
            Self::ONE
        } else {
            Self::ZERO
        }
    }
    #[inline(always)]
    fn truthy(self) -> bool {
        self != Self::ZERO
    }

    fn abs(self) -> Self;
    fn sqrt(self) -> Self;
    fn exp(self) -> Self;
    fn ln(self) -> Self;
    fn floor(self) -> Self;
    fn ceil(self) -> Self;
    fn sin(self) -> Self;
    fn cos(self) -> Self;
    fn tanh(self) -> Self;
    fn min(self, other: Self) -> Self;
    fn max(self, other: Self) -> Self;
    fn powf(self, other: Self) -> Self;
    /// Fused multiply-add `self * b + c` (used only behind the opt-in
    /// fast-math artifact; exact paths never contract).
    fn mul_add(self, b: Self, c: Self) -> Self;

    /// Slice-level FMA `out[x] = a[x] * b[x] + c[x]`, hardware-contracted
    /// when the CPU has FMA units (fast-math specialized kernels only).
    fn mul_add_slices(out: &mut [Self], a: &[Self], b: &[Self], c: &[Self]);
    /// Slice-level FMS `out[x] = a[x] * b[x] - c[x]` (fast-math only).
    fn mul_sub_slices(out: &mut [Self], a: &[Self], b: &[Self], c: &[Self]);

    // Enum-of-buffers dispatch hooks (monomorphized, no `dyn`).

    /// Wrap an owned vector in the matching [`Buf`] variant.
    fn buf(v: Vec<Self>) -> Buf;
    /// View a [`Buf`] as `&[Self]`; panics if the tag does not match —
    /// unreachable after bind-time dtype validation.
    fn slice(buf: &Buf) -> &[Self];
    /// Mutable variant of [`Element::slice`].
    fn slice_mut(buf: &mut Buf) -> &mut [Self];
}

/// Whether the host CPU exposes hardware FMA (x86_64 `fma` feature);
/// checked once per call site that contracts — cheap (cpuid is cached by
/// `is_x86_feature_detected`).
#[inline]
pub(crate) fn hw_fma() -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        std::arch::is_x86_feature_detected!("fma")
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        false
    }
}

macro_rules! impl_element {
    ($ty:ty, $dtype:expr, $variant:ident, $bits_as:ty) => {
        impl Element for $ty {
            const DTYPE: DType = $dtype;
            const ZERO: Self = 0.0;
            const ONE: Self = 1.0;

            #[inline(always)]
            fn from_f64(v: f64) -> Self {
                v as $ty
            }
            #[inline(always)]
            fn to_f64(self) -> f64 {
                self as f64
            }
            #[inline(always)]
            fn to_bits64(self) -> u64 {
                self.to_bits() as u64
            }
            #[inline(always)]
            fn fnv1a_step(self, mut h: u64) -> u64 {
                for b in self.to_bits().to_le_bytes() {
                    h ^= b as u64;
                    h = h.wrapping_mul(0x0000_0100_0000_01b3);
                }
                h
            }

            #[inline(always)]
            fn abs(self) -> Self {
                self.abs()
            }
            #[inline(always)]
            fn sqrt(self) -> Self {
                self.sqrt()
            }
            #[inline(always)]
            fn exp(self) -> Self {
                self.exp()
            }
            #[inline(always)]
            fn ln(self) -> Self {
                self.ln()
            }
            #[inline(always)]
            fn floor(self) -> Self {
                self.floor()
            }
            #[inline(always)]
            fn ceil(self) -> Self {
                self.ceil()
            }
            #[inline(always)]
            fn sin(self) -> Self {
                self.sin()
            }
            #[inline(always)]
            fn cos(self) -> Self {
                self.cos()
            }
            #[inline(always)]
            fn tanh(self) -> Self {
                self.tanh()
            }
            #[inline(always)]
            fn min(self, other: Self) -> Self {
                self.min(other)
            }
            #[inline(always)]
            fn max(self, other: Self) -> Self {
                self.max(other)
            }
            #[inline(always)]
            fn powf(self, other: Self) -> Self {
                self.powf(other)
            }
            #[inline(always)]
            fn mul_add(self, b: Self, c: Self) -> Self {
                self.mul_add(b, c)
            }

            fn mul_add_slices(out: &mut [Self], a: &[Self], b: &[Self], c: &[Self]) {
                if hw_fma() {
                    #[cfg(target_arch = "x86_64")]
                    // SAFETY: `hw_fma` verified the `fma` target feature.
                    unsafe {
                        return fma_slices_hw::$variant(out, a, b, c, false);
                    }
                }
                for x in 0..out.len() {
                    out[x] = a[x].mul_add(b[x], c[x]);
                }
            }

            fn mul_sub_slices(out: &mut [Self], a: &[Self], b: &[Self], c: &[Self]) {
                if hw_fma() {
                    #[cfg(target_arch = "x86_64")]
                    // SAFETY: `hw_fma` verified the `fma` target feature.
                    unsafe {
                        return fma_slices_hw::$variant(out, a, b, c, true);
                    }
                }
                for x in 0..out.len() {
                    out[x] = a[x].mul_add(b[x], -c[x]);
                }
            }

            #[inline]
            fn buf(v: Vec<Self>) -> Buf {
                Buf::$variant(v)
            }
            #[inline(always)]
            fn slice(buf: &Buf) -> &[Self] {
                match buf {
                    Buf::$variant(v) => v,
                    other => panic!(
                        "storage dtype mismatch: expected {}, buffer holds {}",
                        Self::DTYPE,
                        other.dtype()
                    ),
                }
            }
            #[inline(always)]
            fn slice_mut(buf: &mut Buf) -> &mut [Self] {
                match buf {
                    Buf::$variant(v) => v,
                    other => panic!(
                        "storage dtype mismatch: expected {}, buffer holds {}",
                        Self::DTYPE,
                        other.dtype()
                    ),
                }
            }
        }
    };
}

impl_element!(f64, DType::F64, F64, u64);
impl_element!(f32, DType::F32, F32, u32);

/// `#[target_feature(enable = "fma")]` slice kernels, one per dtype. The
/// feature attribute makes the *compiler* emit `vfmadd`, so contraction is
/// guaranteed (not at the autovectorizer's whim) once `hw_fma()` approves.
#[cfg(target_arch = "x86_64")]
mod fma_slices_hw {
    macro_rules! fma_hw {
        ($name:ident, $ty:ty) => {
            #[target_feature(enable = "fma")]
            #[allow(non_snake_case)]
            pub unsafe fn $name(out: &mut [$ty], a: &[$ty], b: &[$ty], c: &[$ty], sub: bool) {
                if sub {
                    for x in 0..out.len() {
                        out[x] = a[x].mul_add(b[x], -c[x]);
                    }
                } else {
                    for x in 0..out.len() {
                        out[x] = a[x].mul_add(b[x], c[x]);
                    }
                }
            }
        };
    }
    fma_hw!(F64, f64);
    fma_hw!(F32, f32);
}

/// The tagged flat buffer behind a [`crate::storage::Storage`]: exactly one
/// allocation, its variant always matching the storage's `info.dtype`.
#[derive(Clone)]
pub enum Buf {
    F64(Vec<f64>),
    F32(Vec<f32>),
}

impl Buf {
    /// A zero-filled buffer of `len` elements of `dtype`.
    pub fn zeros(dtype: DType, len: usize) -> Buf {
        match dtype {
            DType::F64 => Buf::F64(vec![0.0; len]),
            DType::F32 => Buf::F32(vec![0.0; len]),
        }
    }

    pub fn dtype(&self) -> DType {
        match self {
            Buf::F64(_) => DType::F64,
            Buf::F32(_) => DType::F32,
        }
    }

    pub fn len(&self) -> usize {
        match self {
            Buf::F64(v) => v.len(),
            Buf::F32(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Element read converted to `f64` (diagnostics / fills — execution
    /// paths use the typed [`Element::slice`] views instead).
    #[inline(always)]
    pub fn get_f64(&self, idx: usize) -> f64 {
        match self {
            Buf::F64(v) => v[idx],
            Buf::F32(v) => v[idx] as f64,
        }
    }

    /// Element write rounded from `f64` (round-to-nearest for `f32`).
    #[inline(always)]
    pub fn set_f64(&mut self, idx: usize, val: f64) {
        match self {
            Buf::F64(v) => v[idx] = val,
            Buf::F32(v) => v[idx] = val as f32,
        }
    }

    /// Fill every element with `v` (rounded once per dtype).
    pub fn fill_f64(&mut self, v: f64) {
        match self {
            Buf::F64(d) => d.fill(v),
            Buf::F32(d) => d.fill(v as f32),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn eval_generic<T: Element>(a: f64, b: f64) -> u64 {
        // A tiny expression evaluated at T precision end-to-end.
        let (a, b) = (T::from_f64(a), T::from_f64(b));
        (a * b + a.sqrt().max(b)).to_bits64()
    }

    #[test]
    fn f32_and_f64_are_genuinely_different_precisions() {
        // 0.1 is not exactly representable: single- and double-precision
        // evaluation must produce different bit patterns.
        assert_ne!(eval_generic::<f32>(0.1, 0.3), eval_generic::<f64>(0.1, 0.3));
        // The f32 path really is f32: it equals hand-written f32 math.
        let (a, b) = (0.1f32, 0.3f32);
        assert_eq!(
            eval_generic::<f32>(0.1, 0.3),
            (a * b + a.sqrt().max(b)).to_bits() as u64
        );
    }

    #[test]
    fn buf_tags_and_dispatch() {
        let b = Buf::zeros(DType::F32, 4);
        assert_eq!(b.dtype(), DType::F32);
        assert_eq!(b.len(), 4);
        assert_eq!(<f32 as Element>::slice(&b).len(), 4);
        let mut b = Buf::zeros(DType::F64, 2);
        b.set_f64(1, 0.25);
        assert_eq!(b.get_f64(1), 0.25);
        assert_eq!(<f64 as Element>::slice(&b), &[0.0, 0.25]);
    }

    #[test]
    #[should_panic(expected = "dtype mismatch")]
    fn mismatched_slice_panics() {
        let b = Buf::zeros(DType::F64, 4);
        let _ = <f32 as Element>::slice(&b);
    }

    #[test]
    fn fnv_steps_differ_by_width() {
        // Same value, different dtype: different digest material.
        let h64 = 1.0f64.fnv1a_step(0xcbf2_9ce4_8422_2325);
        let h32 = 1.0f32.fnv1a_step(0xcbf2_9ce4_8422_2325);
        assert_ne!(h64, h32);
    }

    #[test]
    fn fma_slices_match_scalar_mul_add() {
        let a = [0.1f64, 0.2, 0.3, 0.7];
        let b = [1.5f64, -2.5, 3.5, 0.25];
        let c = [0.01f64, 0.02, -0.03, 4.0];
        let mut out = [0.0f64; 4];
        f64::mul_add_slices(&mut out, &a, &b, &c);
        for x in 0..4 {
            assert_eq!(out[x].to_bits(), a[x].mul_add(b[x], c[x]).to_bits());
        }
        let mut out = [0.0f64; 4];
        f64::mul_sub_slices(&mut out, &a, &b, &c);
        for x in 0..4 {
            assert_eq!(out[x].to_bits(), a[x].mul_add(b[x], -c[x]).to_bits());
        }
        // And the f32 monomorphization.
        let a32 = a.map(|v| v as f32);
        let b32 = b.map(|v| v as f32);
        let c32 = c.map(|v| v as f32);
        let mut out32 = [0.0f32; 4];
        f32::mul_add_slices(&mut out32, &a32, &b32, &c32);
        for x in 0..4 {
            assert_eq!(out32[x].to_bits(), a32[x].mul_add(b32[x], c32[x]).to_bits());
        }
    }
}
