//! Shared-slab storage views: the sound replacement for the old
//! `&mut`-aliasing `SyncCell<Env>` trick in `backend/shard.rs`.
//!
//! A [`StorageView`] is a typed window over one storage's flat buffer,
//! borrowed for lifetime `'a` and accessed through `UnsafeCell` element
//! pointers. Unlike handing every worker slab its own `&mut Env`, no two
//! `&mut` references to the same memory ever exist: every read and write
//! goes through a raw element pointer derived from the same
//! `&[UnsafeCell<T>]`, which Rust's aliasing model permits to be shared
//! and concurrently mutated — soundness then rests on the documented
//! *disjoint-write contract* below instead of on UB-adjacent aliasing.
//! This is what makes the storage and shard suites Miri-clean.
//!
//! ## The disjoint-write contract
//!
//! Sharded execution splits the compute domain into i-slabs. Callers of
//! the `unsafe` accessors must uphold, for the lifetime of the view:
//!
//! 1. **Disjoint writes** — no element is written by two threads without
//!    synchronization. The slab ownership rule
//!    (`backend/shard.rs::owned_store_range`) partitions every store
//!    range by slab.
//! 2. **No read/write races** — no element is read by one thread while
//!    another writes it. Stage barriers order cross-slab halo reads after
//!    the writes they observe (PARALLEL multistages); sequential sweeps
//!    with cross-slab field carries rendezvous per level (or per stage)
//!    so every halo read observes a published, quiescent level
//!    (`backend/shard.rs::HaloPlan` / `HaloRendezvous`); sweeps the
//!    halo-plan analysis proves column-local run with no synchronization
//!    at all.
//! 3. **In-bounds** — flat indices stay inside the view (checked in debug
//!    builds).
//!
//! The same views are used on the serial paths (created from `&mut Env`,
//! one thread), so there is exactly one evaluator per backend, not a
//! serial/sharded pair.

use super::element::Element;
use std::cell::UnsafeCell;
use std::marker::PhantomData;

/// A typed, shareable window over one storage buffer (see module docs).
/// `Copy`, pointer-sized cheap; `Send + Sync` by the disjoint-write
/// contract.
pub struct StorageView<'a, T: Element> {
    /// Base of the buffer, element-granular interior mutability.
    cells: *const UnsafeCell<T>,
    len: usize,
    origin: usize,
    strides: [usize; 3],
    _borrow: PhantomData<&'a UnsafeCell<T>>,
}

impl<T: Element> Clone for StorageView<'_, T> {
    fn clone(&self) -> Self {
        *self
    }
}
impl<T: Element> Copy for StorageView<'_, T> {}

// SAFETY: all element access goes through `UnsafeCell` raw pointers inside
// `unsafe` methods whose callers uphold the disjoint-write contract; `T` is
// a sealed plain float (no drop glue, no references).
unsafe impl<T: Element> Send for StorageView<'_, T> {}
unsafe impl<T: Element> Sync for StorageView<'_, T> {}

impl<'a, T: Element> StorageView<'a, T> {
    /// Build a view over an exclusively borrowed element slice. The `&mut`
    /// entry point is what makes the construction safe: for `'a` the slice
    /// is unreachable except through views derived from this call.
    pub(crate) fn new(data: &'a mut [T], origin: usize, strides: [usize; 3]) -> Self {
        let len = data.len();
        // `UnsafeCell<T>` has the same layout as `T`; re-typing an
        // exclusive borrow as a shared slice of cells is the standard
        // (sound) way to hand out element-granular shared mutability.
        let cells = data.as_mut_ptr() as *const UnsafeCell<T>;
        StorageView { cells, len, origin, strides, _borrow: PhantomData }
    }

    /// An empty view (demoted-temporary placeholders).
    pub fn empty() -> Self {
        StorageView {
            cells: std::ptr::NonNull::dangling().as_ptr(),
            len: 0,
            origin: 0,
            strides: [0; 3],
            _borrow: PhantomData,
        }
    }

    #[inline(always)]
    pub fn len(&self) -> usize {
        self.len
    }

    #[inline(always)]
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Flat offset of domain origin (0,0,0).
    #[inline(always)]
    pub fn origin(&self) -> usize {
        self.origin
    }

    /// Flat strides per axis.
    #[inline(always)]
    pub fn strides(&self) -> [usize; 3] {
        self.strides
    }

    /// Flat index of signed domain coordinates (negative = halo).
    #[inline(always)]
    pub fn flat(&self, i: i64, j: i64, k: i64) -> usize {
        (self.origin as i64
            + i * self.strides[0] as i64
            + j * self.strides[1] as i64
            + k * self.strides[2] as i64) as usize
    }

    /// Read one element at a flat index.
    ///
    /// # Safety
    /// `idx < len`, and the disjoint-write contract holds (no concurrent
    /// writer of this element).
    #[inline(always)]
    pub unsafe fn read(&self, idx: usize) -> T {
        debug_assert!(idx < self.len, "storage view OOB read {idx} >= {}", self.len);
        *(*self.cells.add(idx)).get()
    }

    /// Write one element at a flat index.
    ///
    /// # Safety
    /// `idx < len`, and the disjoint-write contract holds (this thread is
    /// the element's unique writer, nobody concurrently reads it).
    #[inline(always)]
    pub unsafe fn write(&self, idx: usize, v: T) {
        debug_assert!(idx < self.len, "storage view OOB write {idx} >= {}", self.len);
        *(*self.cells.add(idx)).get() = v;
    }

    /// Read at signed domain coordinates.
    ///
    /// # Safety
    /// Coordinates in the allocated box; disjoint-write contract.
    #[inline(always)]
    pub unsafe fn get(&self, i: i64, j: i64, k: i64) -> T {
        self.read(self.flat(i, j, k))
    }

    /// Write at signed domain coordinates.
    ///
    /// # Safety
    /// Coordinates in the allocated box; disjoint-write contract.
    #[inline(always)]
    pub unsafe fn set(&self, i: i64, j: i64, k: i64, v: T) {
        self.write(self.flat(i, j, k), v);
    }

    /// Gather `dst.len()` elements starting at `base`, stepping `stride`
    /// elements, into a thread-local buffer (the strip-load primitive; a
    /// `memcpy` when `stride == 1`).
    ///
    /// # Safety
    /// The whole strided range in-bounds; disjoint-write contract (no
    /// concurrent writer of any gathered element).
    #[inline]
    pub unsafe fn read_lanes(&self, base: usize, stride: usize, dst: &mut [T]) {
        let w = dst.len();
        if w == 0 {
            return;
        }
        debug_assert!(base + (w - 1) * stride < self.len, "view OOB lane read");
        if stride == 1 {
            // dst is an exclusive local buffer: never overlaps the view.
            std::ptr::copy_nonoverlapping(
                (*self.cells.add(base)).get() as *const T,
                dst.as_mut_ptr(),
                w,
            );
        } else {
            for (x, d) in dst.iter_mut().enumerate() {
                *d = *(*self.cells.add(base + x * stride)).get();
            }
        }
    }

    /// Scatter `src.len()` elements starting at `base`, stepping `stride`
    /// elements (the strip-store primitive; a `memcpy` when `stride == 1`).
    ///
    /// # Safety
    /// The whole strided range in-bounds; this thread owns the written
    /// elements per the disjoint-write contract.
    #[inline]
    pub unsafe fn write_lanes(&self, base: usize, stride: usize, src: &[T]) {
        let w = src.len();
        if w == 0 {
            return;
        }
        debug_assert!(base + (w - 1) * stride < self.len, "view OOB lane write");
        if stride == 1 {
            // src is an exclusive local buffer: never overlaps the view.
            std::ptr::copy_nonoverlapping(src.as_ptr(), (*self.cells.add(base)).get(), w);
        } else {
            for (x, s) in src.iter().enumerate() {
                *(*self.cells.add(base + x * stride)).get() = *s;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::Storage;

    #[test]
    fn view_reads_and_writes_roundtrip() {
        let mut s = Storage::with_halo([4, 3, 2], 1);
        s.set(1, 1, 1, 6.5);
        let v: StorageView<'_, f64> = s.view();
        // SAFETY: single thread, exclusive borrow — contract trivially holds.
        unsafe {
            assert_eq!(v.get(1, 1, 1), 6.5);
            v.set(-1, 0, 0, 2.25);
            assert_eq!(v.get(-1, 0, 0), 2.25);
        }
        assert_eq!(s.get(-1, 0, 0), 2.25);
    }

    #[test]
    fn lanes_roundtrip_strided_and_contiguous() {
        let mut s = Storage::with_halo([4, 4, 4], 0);
        for k in 0..4 {
            s.set(0, 0, k, k as f64 + 0.5);
        }
        let v: StorageView<'_, f64> = s.view();
        let base = v.flat(0, 0, 0);
        let mut buf = [0.0f64; 4];
        // SAFETY: single thread.
        unsafe {
            v.read_lanes(base, 1, &mut buf); // k is stride-1 in IJK layout
            assert_eq!(buf, [0.5, 1.5, 2.5, 3.5]);
            let kstride = v.strides()[1];
            v.read_lanes(v.flat(0, 0, 0), kstride, &mut buf[..2]);
            buf.reverse();
            v.write_lanes(base, 1, &buf);
            assert_eq!(v.get(0, 0, 0), 3.5);
            // Strided scatter mirrors the strided gather.
            v.write_lanes(v.flat(0, 0, 0), kstride, &[9.0, 8.0]);
            assert_eq!(v.get(0, 1, 0), 8.0);
        }
    }

    #[test]
    fn concurrent_disjoint_writes_are_sound() {
        // The exact sharded-execution shape: two threads write disjoint
        // i-slabs of one storage through copies of the same view. Run
        // under Miri, this is the regression test for the SyncCell
        // replacement.
        let mut s = Storage::with_halo([8, 2, 2], 0);
        let v: StorageView<'_, f64> = s.view();
        std::thread::scope(|scope| {
            for slab in 0..2usize {
                scope.spawn(move || {
                    let (i0, i1) = (slab as i64 * 4, slab as i64 * 4 + 4);
                    for i in i0..i1 {
                        for j in 0..2 {
                            for k in 0..2 {
                                // SAFETY: i-ranges are disjoint per slab.
                                unsafe { v.set(i, j, k, (i * 100 + j * 10 + k) as f64) };
                            }
                        }
                    }
                });
            }
        });
        assert_eq!(s.get(0, 0, 0), 0.0);
        assert_eq!(s.get(3, 1, 1), 311.0);
        assert_eq!(s.get(7, 1, 0), 710.0);
    }

    #[test]
    fn halo_reads_after_rendezvous_are_sound() {
        // The per-level halo-exchange shape (contract point 2): two slabs
        // sweep k-levels in lockstep, and at each level every slab reads
        // the *neighbor's* just-written boundary column from the previous
        // level. The rendezvous between levels is the only ordering; run
        // under Miri/TSan this is the regression test for the sequential
        // cross-slab carry path.
        use crate::backend::shard::HaloRendezvous;
        let (ni, nk) = (6i64, 4i64);
        let mut s = Storage::with_halo([ni as usize, 1, nk as usize], 0);
        for i in 0..ni {
            s.set(i, 0, 0, i as f64); // level 0 seeds the carry
        }
        let v: StorageView<'_, f64> = s.view();
        let gate = HaloRendezvous::new(2);
        std::thread::scope(|scope| {
            for slab in 0..2i64 {
                let gate = &gate;
                scope.spawn(move || {
                    let (i0, i1) = (slab * 3, slab * 3 + 3);
                    for k in 1..nk {
                        gate.wait(); // level k-1 fully published
                        for i in i0..i1 {
                            // Reads at i±1 cross the slab boundary at the
                            // owned edges; clamp at the domain edges.
                            let l = (i - 1).max(0);
                            let r = (i + 1).min(ni - 1);
                            // SAFETY: reads touch only level k-1 (quiescent
                            // since the rendezvous); the write is to this
                            // slab's owned column at level k.
                            unsafe {
                                let x = v.get(l, 0, k - 1) + v.get(r, 0, k - 1);
                                v.set(i, 0, k, x);
                            }
                        }
                    }
                });
            }
        });
        assert_eq!(gate.crossings(), (nk - 1) as u64);
        // Serial reference.
        let mut want = vec![0.0f64; (ni * nk) as usize];
        for i in 0..ni {
            want[i as usize] = i as f64;
        }
        for k in 1..nk {
            for i in 0..ni {
                let l = (i - 1).max(0) as usize;
                let r = (i + 1).min(ni - 1) as usize;
                want[(k * ni + i) as usize] =
                    want[(k - 1) as usize * ni as usize + l] + want[(k - 1) as usize * ni as usize + r];
            }
        }
        for k in 0..nk {
            for i in 0..ni {
                assert_eq!(s.get(i, 0, k), want[(k * ni + i) as usize], "i={i} k={k}");
            }
        }
    }

    #[test]
    fn empty_view_is_inert() {
        let v = StorageView::<'_, f32>::empty();
        assert!(v.is_empty());
        assert_eq!(v.len(), 0);
        // Zero-length lane ops are no-ops even on the dangling base.
        unsafe {
            v.read_lanes(0, 1, &mut []);
            v.write_lanes(0, 1, &[]);
        }
    }
}
