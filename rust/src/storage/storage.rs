//! 3-D field storages — the NumPy-like containers of the paper (§2.2).
//!
//! A [`Storage`] owns a flat buffer holding a (ni, nj, nk) *compute domain*
//! surrounded by a halo, with a backend-specific [`Layout`] and innermost
//! padding to an [`Alignment`] boundary. Index (0, 0, 0) addresses the
//! first point of the compute domain; negative indices address the halo
//! (mirroring GT4Py's `origin` convention). Exports/imports to C-order
//! buffers provide the zero-copy-in-spirit Buffer-Protocol interop with the
//! PJRT runtime.

use super::layout::{Alignment, Layout};
use crate::dsl::ast::DType;
use std::fmt;

/// Descriptor of a storage's geometry (everything except the data).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StorageInfo {
    /// Compute-domain shape (ni, nj, nk).
    pub shape: [usize; 3],
    /// Halo width on each side of each axis: `[(ilo, ihi), (jlo, jhi), (klo, khi)]`.
    pub halo: [(usize, usize); 3],
    pub layout: Layout,
    pub alignment: Alignment,
    pub dtype: DType,
}

impl StorageInfo {
    pub fn new(shape: [usize; 3], halo: [(usize, usize); 3]) -> Self {
        StorageInfo {
            shape,
            halo,
            layout: Layout::IJK,
            alignment: Alignment::default(),
            dtype: DType::F64,
        }
    }

    /// Total (unpadded) size along each axis including halos.
    pub fn full_shape(&self) -> [usize; 3] {
        [
            self.shape[0] + self.halo[0].0 + self.halo[0].1,
            self.shape[1] + self.halo[1].0 + self.halo[1].1,
            self.shape[2] + self.halo[2].0 + self.halo[2].1,
        ]
    }

    /// Allocated size per axis: the innermost axis is padded to alignment.
    pub fn padded_shape(&self) -> [usize; 3] {
        let mut p = self.full_shape();
        let inner = self.layout.inner_axis();
        p[inner] = self.alignment.pad(p[inner]);
        p
    }

    pub fn strides(&self) -> [usize; 3] {
        self.layout.strides(self.padded_shape())
    }

    pub fn len(&self) -> usize {
        let p = self.padded_shape();
        p[0] * p[1] * p[2]
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// An owned 3-D field.
#[derive(Clone)]
pub struct Storage {
    pub info: StorageInfo,
    /// Flat buffer in `info.layout` order with padding; f64 host
    /// representation regardless of `dtype` (converted at PJRT boundaries).
    data: Vec<f64>,
    strides: [usize; 3],
    /// Flat offset of compute-domain origin (0,0,0).
    origin: usize,
}

impl Storage {
    /// Allocate a zero-filled storage.
    pub fn zeros(info: StorageInfo) -> Storage {
        let strides = info.strides();
        let origin = info.halo[0].0 * strides[0]
            + info.halo[1].0 * strides[1]
            + info.halo[2].0 * strides[2];
        Storage { data: vec![0.0; info.len()], strides, origin, info }
    }

    /// Shorthand: domain shape with a symmetric halo, default layout.
    pub fn with_halo(shape: [usize; 3], halo: usize) -> Storage {
        Storage::zeros(StorageInfo::new(
            shape,
            [(halo, halo), (halo, halo), (halo, halo)],
        ))
    }

    /// Shorthand: symmetric horizontal halo, no vertical halo.
    pub fn with_horizontal_halo(shape: [usize; 3], halo: usize) -> Storage {
        Storage::zeros(StorageInfo::new(shape, [(halo, halo), (halo, halo), (0, 0)]))
    }

    /// Build from a function of the *domain* index (halo stays zero).
    pub fn from_fn(
        shape: [usize; 3],
        halo: usize,
        mut f: impl FnMut(usize, usize, usize) -> f64,
    ) -> Storage {
        let mut s = Storage::with_halo(shape, halo);
        for i in 0..shape[0] {
            for j in 0..shape[1] {
                for k in 0..shape[2] {
                    s.set(i as i64, j as i64, k as i64, f(i, j, k));
                }
            }
        }
        s
    }

    /// Build from a function over the full extended (halo-inclusive) index
    /// space; `f` receives signed domain coordinates (negative = halo).
    pub fn from_fn_extended(
        shape: [usize; 3],
        halo: usize,
        mut f: impl FnMut(i64, i64, i64) -> f64,
    ) -> Storage {
        let mut s = Storage::with_halo(shape, halo);
        let h = halo as i64;
        for i in -h..shape[0] as i64 + h {
            for j in -h..shape[1] as i64 + h {
                for k in -h..shape[2] as i64 + h {
                    s.set(i, j, k, f(i, j, k));
                }
            }
        }
        s
    }

    #[inline(always)]
    fn flat(&self, i: i64, j: i64, k: i64) -> usize {
        (self.origin as i64
            + i * self.strides[0] as i64
            + j * self.strides[1] as i64
            + k * self.strides[2] as i64) as usize
    }

    /// Read at signed domain coordinates (negative = halo). Panics on
    /// out-of-allocation access in debug builds.
    #[inline(always)]
    pub fn get(&self, i: i64, j: i64, k: i64) -> f64 {
        debug_assert!(self.in_bounds(i, j, k), "storage OOB read ({i},{j},{k})");
        self.data[self.flat(i, j, k)]
    }

    #[inline(always)]
    pub fn set(&mut self, i: i64, j: i64, k: i64, v: f64) {
        debug_assert!(self.in_bounds(i, j, k), "storage OOB write ({i},{j},{k})");
        let idx = self.flat(i, j, k);
        self.data[idx] = v;
    }

    /// Whether signed coordinates fall inside the allocated halo+domain box.
    pub fn in_bounds(&self, i: i64, j: i64, k: i64) -> bool {
        let h = self.info.halo;
        let s = self.info.shape;
        i >= -(h[0].0 as i64)
            && i < s[0] as i64 + h[0].1 as i64
            && j >= -(h[1].0 as i64)
            && j < s[1] as i64 + h[1].1 as i64
            && k >= -(h[2].0 as i64)
            && k < s[2] as i64 + h[2].1 as i64
    }

    pub fn shape(&self) -> [usize; 3] {
        self.info.shape
    }

    pub fn fill(&mut self, v: f64) {
        self.data.fill(v);
    }

    /// Raw flat access for the vector backend's inner loops.
    #[inline(always)]
    pub fn raw(&self) -> &[f64] {
        &self.data
    }

    #[inline(always)]
    pub fn raw_mut(&mut self) -> &mut [f64] {
        &mut self.data
    }

    #[inline(always)]
    pub fn raw_origin(&self) -> usize {
        self.origin
    }

    #[inline(always)]
    pub fn raw_strides(&self) -> [usize; 3] {
        self.strides
    }

    /// Export the full halo-inclusive box to a C-order (I,J,K) f64 buffer —
    /// the PJRT interchange format (the Buffer-Protocol analog).
    pub fn to_c_order(&self) -> Vec<f64> {
        let fs = self.info.full_shape();
        let h = self.info.halo;
        let mut out = Vec::with_capacity(fs[0] * fs[1] * fs[2]);
        for i in 0..fs[0] {
            for j in 0..fs[1] {
                for k in 0..fs[2] {
                    out.push(self.get(
                        i as i64 - h[0].0 as i64,
                        j as i64 - h[1].0 as i64,
                        k as i64 - h[2].0 as i64,
                    ));
                }
            }
        }
        out
    }

    /// Import a C-order (I,J,K) halo-inclusive buffer (inverse of
    /// [`Storage::to_c_order`]).
    pub fn from_c_order(&mut self, buf: &[f64]) {
        let fs = self.info.full_shape();
        assert_eq!(buf.len(), fs[0] * fs[1] * fs[2], "c-order buffer size mismatch");
        let h = self.info.halo;
        let mut idx = 0;
        for i in 0..fs[0] {
            for j in 0..fs[1] {
                for k in 0..fs[2] {
                    self.set(
                        i as i64 - h[0].0 as i64,
                        j as i64 - h[1].0 as i64,
                        k as i64 - h[2].0 as i64,
                        buf[idx],
                    );
                    idx += 1;
                }
            }
        }
    }

    /// Export an arbitrary signed box `[lo, lo+dims)` (domain coordinates,
    /// negative = halo) to a C-order buffer — used by the compiled backends
    /// to stage exactly the sub-box a stencil requires.
    pub fn box_to_c_order(&self, lo: [i64; 3], dims: [usize; 3]) -> Vec<f64> {
        let mut out = Vec::new();
        self.box_write_c_order(lo, dims, &mut out);
        out
    }

    /// Like [`Storage::box_to_c_order`], but reuses `out`'s allocation
    /// (hot-path staging for the compiled backends) and bulk-copies
    /// contiguous K rows when the layout allows.
    pub fn box_write_c_order(&self, lo: [i64; 3], dims: [usize; 3], out: &mut Vec<f64>) {
        out.clear();
        out.resize(dims[0] * dims[1] * dims[2], 0.0);
        let st = self.strides;
        let (s0, s1, s2) = (st[0] as i64, st[1] as i64, st[2] as i64);
        let org = self.origin as i64;
        let wk = dims[2];
        let mut idx = 0;
        if s2 == 1 {
            for i in 0..dims[0] as i64 {
                let ibase = org + (lo[0] + i) * s0;
                for j in 0..dims[1] as i64 {
                    let base = (ibase + (lo[1] + j) * s1 + lo[2]) as usize;
                    out[idx..idx + wk].copy_from_slice(&self.data[base..base + wk]);
                    idx += wk;
                }
            }
        } else {
            for i in 0..dims[0] as i64 {
                for j in 0..dims[1] as i64 {
                    for k in 0..dims[2] as i64 {
                        out[idx] = self.get(lo[0] + i, lo[1] + j, lo[2] + k);
                        idx += 1;
                    }
                }
            }
        }
    }

    /// Export only the compute domain to a C-order buffer.
    pub fn domain_to_c_order(&self) -> Vec<f64> {
        let s = self.info.shape;
        let mut out = Vec::with_capacity(s[0] * s[1] * s[2]);
        for i in 0..s[0] {
            for j in 0..s[1] {
                for k in 0..s[2] {
                    out.push(self.get(i as i64, j as i64, k as i64));
                }
            }
        }
        out
    }

    /// Write back a C-order compute-domain buffer, leaving the halo alone.
    /// Bulk-copies contiguous K rows when the layout allows.
    pub fn domain_from_c_order(&mut self, buf: &[f64]) {
        let s = self.info.shape;
        assert_eq!(buf.len(), s[0] * s[1] * s[2], "domain buffer size mismatch");
        let st = self.strides;
        if st[2] == 1 {
            let (s0, s1) = (st[0], st[1]);
            let org = self.origin;
            let wk = s[2];
            let mut idx = 0;
            for i in 0..s[0] {
                let ibase = org + i * s0;
                for j in 0..s[1] {
                    let base = ibase + j * s1;
                    self.data[base..base + wk].copy_from_slice(&buf[idx..idx + wk]);
                    idx += wk;
                }
            }
            return;
        }
        let mut idx = 0;
        for i in 0..s[0] {
            for j in 0..s[1] {
                for k in 0..s[2] {
                    self.set(i as i64, j as i64, k as i64, buf[idx]);
                    idx += 1;
                }
            }
        }
    }

    /// Max |a - b| over the compute domain.
    pub fn max_abs_diff(&self, other: &Storage) -> f64 {
        assert_eq!(self.info.shape, other.info.shape);
        let s = self.info.shape;
        let mut m: f64 = 0.0;
        for i in 0..s[0] as i64 {
            for j in 0..s[1] as i64 {
                for k in 0..s[2] as i64 {
                    m = m.max((self.get(i, j, k) - other.get(i, j, k)).abs());
                }
            }
        }
        m
    }

    /// Sum over the compute domain (conservation diagnostics).
    pub fn domain_sum(&self) -> f64 {
        let s = self.info.shape;
        let mut acc = 0.0;
        for i in 0..s[0] as i64 {
            for j in 0..s[1] as i64 {
                for k in 0..s[2] as i64 {
                    acc += self.get(i, j, k);
                }
            }
        }
        acc
    }

    /// Order-sensitive FNV-1a hash of the compute-domain values' f64 bit
    /// patterns (i, then j, then k). Two storages hash equal iff every
    /// domain element is bit-identical — the digest the serve protocol and
    /// the bitwise honesty gates compare, stronger than a summed checksum
    /// (which cancels symmetric errors).
    pub fn domain_hash(&self) -> u64 {
        let s = self.info.shape;
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        for i in 0..s[0] as i64 {
            for j in 0..s[1] as i64 {
                for k in 0..s[2] as i64 {
                    for b in self.get(i, j, k).to_bits().to_le_bytes() {
                        h ^= b as u64;
                        h = h.wrapping_mul(0x0000_0100_0000_01b3);
                    }
                }
            }
        }
        h
    }
}

impl fmt::Debug for Storage {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "Storage({:?} halo {:?} layout {} dtype {})",
            self.info.shape, self.info.halo, self.info.layout, self.info.dtype
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_set_get_all_layouts() {
        for layout in [Layout::IJK, Layout::KJI, Layout::JKI] {
            let mut info = StorageInfo::new([3, 4, 5], [(1, 1), (1, 1), (0, 0)]);
            info.layout = layout;
            let mut s = Storage::zeros(info);
            s.set(2, 3, 4, 7.5);
            s.set(-1, 0, 0, 1.25);
            assert_eq!(s.get(2, 3, 4), 7.5, "layout {layout}");
            assert_eq!(s.get(-1, 0, 0), 1.25, "layout {layout}");
            assert_eq!(s.get(0, 0, 0), 0.0);
        }
    }

    #[test]
    fn distinct_cells_distinct_slots() {
        // Exhaustively check the index map is injective for an asymmetric
        // halo and each layout.
        for layout in [Layout::IJK, Layout::KJI, Layout::JKI] {
            let mut info = StorageInfo::new([3, 2, 4], [(2, 1), (0, 1), (1, 0)]);
            info.layout = layout;
            let mut s = Storage::zeros(info);
            let mut count = 0.0;
            for i in -2..4i64 {
                for j in 0..3i64 {
                    for k in -1..4i64 {
                        count += 1.0;
                        s.set(i, j, k, count);
                    }
                }
            }
            let mut expect = 0.0;
            for i in -2..4i64 {
                for j in 0..3i64 {
                    for k in -1..4i64 {
                        expect += 1.0;
                        assert_eq!(s.get(i, j, k), expect, "layout {layout}");
                    }
                }
            }
        }
    }

    #[test]
    fn padding_respects_alignment() {
        let mut info = StorageInfo::new([3, 3, 3], [(0, 0), (0, 0), (0, 0)]);
        info.alignment = Alignment(8);
        assert_eq!(info.padded_shape()[info.layout.inner_axis()], 8);
        assert_eq!(info.len(), 3 * 3 * 8);
    }

    #[test]
    fn c_order_roundtrip() {
        let src = Storage::from_fn_extended([2, 3, 2], 1, |i, j, k| {
            (i * 100 + j * 10 + k) as f64
        });
        let buf = src.to_c_order();
        let mut dst = Storage::with_halo([2, 3, 2], 1);
        dst.from_c_order(&buf);
        assert_eq!(dst.get(-1, -1, -1), src.get(-1, -1, -1));
        assert_eq!(dst.get(1, 2, 1), src.get(1, 2, 1));
        assert_eq!(dst.max_abs_diff(&src), 0.0);
    }

    #[test]
    fn domain_c_order_leaves_halo() {
        let mut s = Storage::with_halo([2, 2, 1], 1);
        s.set(-1, 0, 0, 42.0);
        let buf = vec![1.0, 2.0, 3.0, 4.0];
        s.domain_from_c_order(&buf);
        assert_eq!(s.get(0, 0, 0), 1.0);
        assert_eq!(s.get(1, 1, 0), 4.0);
        assert_eq!(s.get(-1, 0, 0), 42.0); // halo untouched
        assert_eq!(s.domain_to_c_order(), buf);
    }

    #[test]
    fn from_fn_and_sum() {
        let s = Storage::from_fn([2, 2, 2], 0, |i, j, k| (i + j + k) as f64);
        assert_eq!(s.domain_sum(), 12.0);
    }

    #[test]
    fn in_bounds_logic() {
        let s = Storage::with_horizontal_halo([4, 4, 4], 2);
        assert!(s.in_bounds(-2, 0, 0));
        assert!(!s.in_bounds(-3, 0, 0));
        assert!(s.in_bounds(5, 5, 3));
        assert!(!s.in_bounds(0, 0, -1));
        assert!(!s.in_bounds(0, 0, 4));
    }
}
