//! 3-D field storages — the NumPy-like containers of the paper (§2.2).
//!
//! A [`Storage`] owns a flat buffer holding a (ni, nj, nk) *compute domain*
//! surrounded by a halo, with a backend-specific [`Layout`] and innermost
//! padding to an [`Alignment`] boundary. Index (0, 0, 0) addresses the
//! first point of the compute domain; negative indices address the halo
//! (mirroring GT4Py's `origin` convention). Exports/imports to C-order
//! buffers provide the zero-copy-in-spirit Buffer-Protocol interop with the
//! PJRT runtime.
//!
//! Storages are dtype-generic: the buffer is a tagged [`Buf`] whose variant
//! always matches `info.dtype` (`f64` or `f32`). The convenience accessors
//! ([`Storage::get`], [`Storage::set`], [`Storage::fill`]) speak `f64` and
//! convert at the boundary (round-to-nearest on `f32` storages) — they
//! exist for fills and diagnostics. Execution paths use the typed
//! [`Storage::view`] / [`Storage::raw_t`] accessors so all arithmetic
//! happens at native precision.

use super::element::{Buf, Element};
use super::layout::{Alignment, Layout};
use super::view::StorageView;
use crate::dsl::ast::DType;
use std::fmt;

/// Descriptor of a storage's geometry (everything except the data).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StorageInfo {
    /// Compute-domain shape (ni, nj, nk).
    pub shape: [usize; 3],
    /// Halo width on each side of each axis: `[(ilo, ihi), (jlo, jhi), (klo, khi)]`.
    pub halo: [(usize, usize); 3],
    pub layout: Layout,
    pub alignment: Alignment,
    pub dtype: DType,
}

impl StorageInfo {
    pub fn new(shape: [usize; 3], halo: [(usize, usize); 3]) -> Self {
        StorageInfo {
            shape,
            halo,
            layout: Layout::IJK,
            alignment: Alignment::default(),
            dtype: DType::F64,
        }
    }

    /// The same geometry with a different element dtype.
    pub fn with_dtype(mut self, dtype: DType) -> Self {
        self.dtype = dtype;
        self
    }

    /// Total (unpadded) size along each axis including halos.
    pub fn full_shape(&self) -> [usize; 3] {
        [
            self.shape[0] + self.halo[0].0 + self.halo[0].1,
            self.shape[1] + self.halo[1].0 + self.halo[1].1,
            self.shape[2] + self.halo[2].0 + self.halo[2].1,
        ]
    }

    /// Allocated size per axis: the innermost axis is padded to alignment.
    pub fn padded_shape(&self) -> [usize; 3] {
        let mut p = self.full_shape();
        let inner = self.layout.inner_axis();
        p[inner] = self.alignment.pad(p[inner]);
        p
    }

    pub fn strides(&self) -> [usize; 3] {
        self.layout.strides(self.padded_shape())
    }

    pub fn len(&self) -> usize {
        let p = self.padded_shape();
        p[0] * p[1] * p[2]
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// An owned 3-D field.
#[derive(Clone)]
pub struct Storage {
    pub info: StorageInfo,
    /// Flat buffer in `info.layout` order with padding; the [`Buf`] variant
    /// always matches `info.dtype`.
    data: Buf,
    strides: [usize; 3],
    /// Flat offset of compute-domain origin (0,0,0).
    origin: usize,
}

impl Storage {
    /// Allocate a zero-filled storage (dtype from `info.dtype`).
    pub fn zeros(info: StorageInfo) -> Storage {
        let strides = info.strides();
        let origin = info.halo[0].0 * strides[0]
            + info.halo[1].0 * strides[1]
            + info.halo[2].0 * strides[2];
        Storage { data: Buf::zeros(info.dtype, info.len()), strides, origin, info }
    }

    /// Shorthand: domain shape with a symmetric halo, default layout, f64.
    pub fn with_halo(shape: [usize; 3], halo: usize) -> Storage {
        Storage::zeros(StorageInfo::new(
            shape,
            [(halo, halo), (halo, halo), (halo, halo)],
        ))
    }

    /// Shorthand: symmetric horizontal halo, no vertical halo, f64.
    pub fn with_horizontal_halo(shape: [usize; 3], halo: usize) -> Storage {
        Storage::zeros(StorageInfo::new(shape, [(halo, halo), (halo, halo), (0, 0)]))
    }

    /// Build from a function of the *domain* index (halo stays zero).
    pub fn from_fn(
        shape: [usize; 3],
        halo: usize,
        mut f: impl FnMut(usize, usize, usize) -> f64,
    ) -> Storage {
        let mut s = Storage::with_halo(shape, halo);
        for i in 0..shape[0] {
            for j in 0..shape[1] {
                for k in 0..shape[2] {
                    s.set(i as i64, j as i64, k as i64, f(i, j, k));
                }
            }
        }
        s
    }

    /// Build from a function over the full extended (halo-inclusive) index
    /// space; `f` receives signed domain coordinates (negative = halo).
    pub fn from_fn_extended(
        shape: [usize; 3],
        halo: usize,
        mut f: impl FnMut(i64, i64, i64) -> f64,
    ) -> Storage {
        let mut s = Storage::with_halo(shape, halo);
        let h = halo as i64;
        for i in -h..shape[0] as i64 + h {
            for j in -h..shape[1] as i64 + h {
                for k in -h..shape[2] as i64 + h {
                    s.set(i, j, k, f(i, j, k));
                }
            }
        }
        s
    }

    /// Element dtype of this storage.
    #[inline(always)]
    pub fn dtype(&self) -> DType {
        self.info.dtype
    }

    /// Reallocate this storage at `dtype`, converting every element —
    /// halo included — through the f64 facade (round-to-nearest on a
    /// narrowing cast). Always returns a fresh allocation, even for a
    /// same-dtype cast, so the result never aliases `self`.
    pub fn cast(&self, dtype: DType) -> Storage {
        let mut out = Storage::zeros(StorageInfo { dtype, ..self.info });
        let [ni, nj, nk] = self.info.shape;
        let h = self.info.halo;
        for i in -(h[0].0 as i64)..ni as i64 + h[0].1 as i64 {
            for j in -(h[1].0 as i64)..nj as i64 + h[1].1 as i64 {
                for k in -(h[2].0 as i64)..nk as i64 + h[2].1 as i64 {
                    out.set(i, j, k, self.get(i, j, k));
                }
            }
        }
        out
    }

    #[inline(always)]
    fn flat(&self, i: i64, j: i64, k: i64) -> usize {
        (self.origin as i64
            + i * self.strides[0] as i64
            + j * self.strides[1] as i64
            + k * self.strides[2] as i64) as usize
    }

    /// Read at signed domain coordinates (negative = halo), widened to
    /// `f64` (exact). Panics on out-of-allocation access in debug builds.
    #[inline(always)]
    pub fn get(&self, i: i64, j: i64, k: i64) -> f64 {
        debug_assert!(self.in_bounds(i, j, k), "storage OOB read ({i},{j},{k})");
        self.data.get_f64(self.flat(i, j, k))
    }

    /// Write at signed domain coordinates, rounded to the storage dtype
    /// (round-to-nearest on `f32` storages).
    #[inline(always)]
    pub fn set(&mut self, i: i64, j: i64, k: i64, v: f64) {
        debug_assert!(self.in_bounds(i, j, k), "storage OOB write ({i},{j},{k})");
        let idx = self.flat(i, j, k);
        self.data.set_f64(idx, v);
    }

    /// Native-precision read at signed domain coordinates; panics if `T`
    /// does not match the storage dtype.
    #[inline(always)]
    pub fn get_t<T: Element>(&self, i: i64, j: i64, k: i64) -> T {
        debug_assert!(self.in_bounds(i, j, k), "storage OOB read ({i},{j},{k})");
        T::slice(&self.data)[self.flat(i, j, k)]
    }

    /// Native-precision write; panics if `T` does not match the dtype.
    #[inline(always)]
    pub fn set_t<T: Element>(&mut self, i: i64, j: i64, k: i64, v: T) {
        debug_assert!(self.in_bounds(i, j, k), "storage OOB write ({i},{j},{k})");
        let idx = self.flat(i, j, k);
        T::slice_mut(&mut self.data)[idx] = v;
    }

    /// Whether signed coordinates fall inside the allocated halo+domain box.
    pub fn in_bounds(&self, i: i64, j: i64, k: i64) -> bool {
        let h = self.info.halo;
        let s = self.info.shape;
        i >= -(h[0].0 as i64)
            && i < s[0] as i64 + h[0].1 as i64
            && j >= -(h[1].0 as i64)
            && j < s[1] as i64 + h[1].1 as i64
            && k >= -(h[2].0 as i64)
            && k < s[2] as i64 + h[2].1 as i64
    }

    pub fn shape(&self) -> [usize; 3] {
        self.info.shape
    }

    /// Fill the whole allocation (halo included) with `v`, rounded once to
    /// the storage dtype.
    pub fn fill(&mut self, v: f64) {
        self.data.fill_f64(v);
    }

    /// Raw flat access as `&[f64]` — panics on non-f64 storages. Retained
    /// for the f64-only compiled backends and diagnostics; dtype-generic
    /// code uses [`Storage::raw_t`] or [`Storage::view`].
    #[inline(always)]
    pub fn raw(&self) -> &[f64] {
        <f64 as Element>::slice(&self.data)
    }

    #[inline(always)]
    pub fn raw_mut(&mut self) -> &mut [f64] {
        <f64 as Element>::slice_mut(&mut self.data)
    }

    /// Raw flat access at native precision; panics if `T` does not match
    /// the storage dtype.
    #[inline(always)]
    pub fn raw_t<T: Element>(&self) -> &[T] {
        T::slice(&self.data)
    }

    #[inline(always)]
    pub fn raw_mut_t<T: Element>(&mut self) -> &mut [T] {
        T::slice_mut(&mut self.data)
    }

    /// A typed shared-slab view over this storage (see
    /// [`crate::storage::StorageView`]): the access path of every
    /// evaluator, serial and sharded. Empty storages (the demoted-temporary
    /// placeholders) yield an inert empty view whatever their tag; a
    /// non-empty dtype mismatch panics — unreachable after bind-time
    /// validation.
    #[inline]
    pub fn view<T: Element>(&mut self) -> StorageView<'_, T> {
        if self.data.is_empty() {
            return StorageView::empty();
        }
        StorageView::new(T::slice_mut(&mut self.data), self.origin, self.strides)
    }

    #[inline(always)]
    pub fn raw_origin(&self) -> usize {
        self.origin
    }

    #[inline(always)]
    pub fn raw_strides(&self) -> [usize; 3] {
        self.strides
    }

    /// Export the full halo-inclusive box to a C-order (I,J,K) f64 buffer —
    /// the PJRT interchange format (the Buffer-Protocol analog). Widens
    /// `f32` storages exactly.
    pub fn to_c_order(&self) -> Vec<f64> {
        let fs = self.info.full_shape();
        let h = self.info.halo;
        let mut out = Vec::with_capacity(fs[0] * fs[1] * fs[2]);
        for i in 0..fs[0] {
            for j in 0..fs[1] {
                for k in 0..fs[2] {
                    out.push(self.get(
                        i as i64 - h[0].0 as i64,
                        j as i64 - h[1].0 as i64,
                        k as i64 - h[2].0 as i64,
                    ));
                }
            }
        }
        out
    }

    /// Import a C-order (I,J,K) halo-inclusive buffer (inverse of
    /// [`Storage::to_c_order`]).
    pub fn from_c_order(&mut self, buf: &[f64]) {
        let fs = self.info.full_shape();
        assert_eq!(buf.len(), fs[0] * fs[1] * fs[2], "c-order buffer size mismatch");
        let h = self.info.halo;
        let mut idx = 0;
        for i in 0..fs[0] {
            for j in 0..fs[1] {
                for k in 0..fs[2] {
                    self.set(
                        i as i64 - h[0].0 as i64,
                        j as i64 - h[1].0 as i64,
                        k as i64 - h[2].0 as i64,
                        buf[idx],
                    );
                    idx += 1;
                }
            }
        }
    }

    /// Export an arbitrary signed box `[lo, lo+dims)` (domain coordinates,
    /// negative = halo) to a C-order buffer — used by the compiled backends
    /// to stage exactly the sub-box a stencil requires.
    pub fn box_to_c_order(&self, lo: [i64; 3], dims: [usize; 3]) -> Vec<f64> {
        let mut out = Vec::new();
        self.box_write_c_order(lo, dims, &mut out);
        out
    }

    /// Like [`Storage::box_to_c_order`], but reuses `out`'s allocation
    /// (hot-path staging for the compiled backends) and bulk-copies
    /// contiguous K rows when the layout allows.
    pub fn box_write_c_order(&self, lo: [i64; 3], dims: [usize; 3], out: &mut Vec<f64>) {
        out.clear();
        out.resize(dims[0] * dims[1] * dims[2], 0.0);
        let st = self.strides;
        let (s0, s1, s2) = (st[0] as i64, st[1] as i64, st[2] as i64);
        let org = self.origin as i64;
        let wk = dims[2];
        let mut idx = 0;
        if let (Buf::F64(data), 1) = (&self.data, s2) {
            for i in 0..dims[0] as i64 {
                let ibase = org + (lo[0] + i) * s0;
                for j in 0..dims[1] as i64 {
                    let base = (ibase + (lo[1] + j) * s1 + lo[2]) as usize;
                    out[idx..idx + wk].copy_from_slice(&data[base..base + wk]);
                    idx += wk;
                }
            }
        } else {
            for i in 0..dims[0] as i64 {
                for j in 0..dims[1] as i64 {
                    for k in 0..dims[2] as i64 {
                        out[idx] = self.get(lo[0] + i, lo[1] + j, lo[2] + k);
                        idx += 1;
                    }
                }
            }
        }
    }

    /// Export only the compute domain to a C-order buffer.
    pub fn domain_to_c_order(&self) -> Vec<f64> {
        let s = self.info.shape;
        let mut out = Vec::with_capacity(s[0] * s[1] * s[2]);
        for i in 0..s[0] {
            for j in 0..s[1] {
                for k in 0..s[2] {
                    out.push(self.get(i as i64, j as i64, k as i64));
                }
            }
        }
        out
    }

    /// Write back a C-order compute-domain buffer, leaving the halo alone.
    /// Bulk-copies contiguous K rows when the layout allows.
    pub fn domain_from_c_order(&mut self, buf: &[f64]) {
        let s = self.info.shape;
        assert_eq!(buf.len(), s[0] * s[1] * s[2], "domain buffer size mismatch");
        let st = self.strides;
        if let (Buf::F64(data), 1) = (&mut self.data, st[2]) {
            let (s0, s1) = (st[0], st[1]);
            let org = self.origin;
            let wk = s[2];
            let mut idx = 0;
            for i in 0..s[0] {
                let ibase = org + i * s0;
                for j in 0..s[1] {
                    let base = ibase + j * s1;
                    data[base..base + wk].copy_from_slice(&buf[idx..idx + wk]);
                    idx += wk;
                }
            }
            return;
        }
        let mut idx = 0;
        for i in 0..s[0] {
            for j in 0..s[1] {
                for k in 0..s[2] {
                    self.set(i as i64, j as i64, k as i64, buf[idx]);
                    idx += 1;
                }
            }
        }
    }

    /// Max |a - b| over the compute domain (widened to f64).
    pub fn max_abs_diff(&self, other: &Storage) -> f64 {
        assert_eq!(self.info.shape, other.info.shape);
        let s = self.info.shape;
        let mut m: f64 = 0.0;
        for i in 0..s[0] as i64 {
            for j in 0..s[1] as i64 {
                for k in 0..s[2] as i64 {
                    m = m.max((self.get(i, j, k) - other.get(i, j, k)).abs());
                }
            }
        }
        m
    }

    /// Relative L2 error of `self` against reference `other` over the
    /// compute domain: `||self - other||_2 / ||other||_2` (both widened to
    /// f64; 0 when the reference norm is 0 and the fields agree). The
    /// cross-precision validation norm of the model driver's sweep.
    pub fn rel_l2_error(&self, other: &Storage) -> f64 {
        assert_eq!(self.info.shape, other.info.shape);
        let s = self.info.shape;
        let (mut num, mut den) = (0.0f64, 0.0f64);
        for i in 0..s[0] as i64 {
            for j in 0..s[1] as i64 {
                for k in 0..s[2] as i64 {
                    let r = other.get(i, j, k);
                    let d = self.get(i, j, k) - r;
                    num += d * d;
                    den += r * r;
                }
            }
        }
        if den == 0.0 {
            return if num == 0.0 { 0.0 } else { f64::INFINITY };
        }
        (num / den).sqrt()
    }

    /// Sum over the compute domain (conservation diagnostics; f64
    /// accumulator whatever the dtype).
    pub fn domain_sum(&self) -> f64 {
        let s = self.info.shape;
        let mut acc = 0.0;
        for i in 0..s[0] as i64 {
            for j in 0..s[1] as i64 {
                for k in 0..s[2] as i64 {
                    acc += self.get(i, j, k);
                }
            }
        }
        acc
    }

    /// Order-sensitive FNV-1a hash of the compute-domain values'
    /// *native-width* bit patterns (i, then j, then k). Two storages hash
    /// equal iff they share dtype and every domain element is
    /// bit-identical — the digest the serve protocol and the bitwise
    /// honesty gates compare, stronger than a summed checksum (which
    /// cancels symmetric errors). `f32` storages hash 4 bytes per element,
    /// so same-value f32/f64 fields never collide.
    pub fn domain_hash(&self) -> u64 {
        match self.data {
            Buf::F64(_) => self.domain_hash_t::<f64>(),
            Buf::F32(_) => self.domain_hash_t::<f32>(),
        }
    }

    fn domain_hash_t<T: Element>(&self) -> u64 {
        let s = self.info.shape;
        let data = T::slice(&self.data);
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        for i in 0..s[0] as i64 {
            for j in 0..s[1] as i64 {
                for k in 0..s[2] as i64 {
                    h = data[self.flat(i, j, k)].fnv1a_step(h);
                }
            }
        }
        h
    }
}

impl fmt::Debug for Storage {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "Storage({:?} halo {:?} layout {} dtype {})",
            self.info.shape, self.info.halo, self.info.layout, self.info.dtype
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_set_get_all_layouts() {
        for layout in [Layout::IJK, Layout::KJI, Layout::JKI] {
            let mut info = StorageInfo::new([3, 4, 5], [(1, 1), (1, 1), (0, 0)]);
            info.layout = layout;
            let mut s = Storage::zeros(info);
            s.set(2, 3, 4, 7.5);
            s.set(-1, 0, 0, 1.25);
            assert_eq!(s.get(2, 3, 4), 7.5, "layout {layout}");
            assert_eq!(s.get(-1, 0, 0), 1.25, "layout {layout}");
            assert_eq!(s.get(0, 0, 0), 0.0);
        }
    }

    #[test]
    fn distinct_cells_distinct_slots() {
        // Exhaustively check the index map is injective for an asymmetric
        // halo and each layout.
        for layout in [Layout::IJK, Layout::KJI, Layout::JKI] {
            let mut info = StorageInfo::new([3, 2, 4], [(2, 1), (0, 1), (1, 0)]);
            info.layout = layout;
            let mut s = Storage::zeros(info);
            let mut count = 0.0;
            for i in -2..4i64 {
                for j in 0..3i64 {
                    for k in -1..4i64 {
                        count += 1.0;
                        s.set(i, j, k, count);
                    }
                }
            }
            let mut expect = 0.0;
            for i in -2..4i64 {
                for j in 0..3i64 {
                    for k in -1..4i64 {
                        expect += 1.0;
                        assert_eq!(s.get(i, j, k), expect, "layout {layout}");
                    }
                }
            }
        }
    }

    #[test]
    fn padding_respects_alignment() {
        let mut info = StorageInfo::new([3, 3, 3], [(0, 0), (0, 0), (0, 0)]);
        info.alignment = Alignment(8);
        assert_eq!(info.padded_shape()[info.layout.inner_axis()], 8);
        assert_eq!(info.len(), 3 * 3 * 8);
    }

    #[test]
    fn c_order_roundtrip() {
        let src = Storage::from_fn_extended([2, 3, 2], 1, |i, j, k| {
            (i * 100 + j * 10 + k) as f64
        });
        let buf = src.to_c_order();
        let mut dst = Storage::with_halo([2, 3, 2], 1);
        dst.from_c_order(&buf);
        assert_eq!(dst.get(-1, -1, -1), src.get(-1, -1, -1));
        assert_eq!(dst.get(1, 2, 1), src.get(1, 2, 1));
        assert_eq!(dst.max_abs_diff(&src), 0.0);
    }

    #[test]
    fn domain_c_order_leaves_halo() {
        let mut s = Storage::with_halo([2, 2, 1], 1);
        s.set(-1, 0, 0, 42.0);
        let buf = vec![1.0, 2.0, 3.0, 4.0];
        s.domain_from_c_order(&buf);
        assert_eq!(s.get(0, 0, 0), 1.0);
        assert_eq!(s.get(1, 1, 0), 4.0);
        assert_eq!(s.get(-1, 0, 0), 42.0); // halo untouched
        assert_eq!(s.domain_to_c_order(), buf);
    }

    #[test]
    fn from_fn_and_sum() {
        let s = Storage::from_fn([2, 2, 2], 0, |i, j, k| (i + j + k) as f64);
        assert_eq!(s.domain_sum(), 12.0);
    }

    #[test]
    fn in_bounds_logic() {
        let s = Storage::with_horizontal_halo([4, 4, 4], 2);
        assert!(s.in_bounds(-2, 0, 0));
        assert!(!s.in_bounds(-3, 0, 0));
        assert!(s.in_bounds(5, 5, 3));
        assert!(!s.in_bounds(0, 0, -1));
        assert!(!s.in_bounds(0, 0, 4));
    }

    #[test]
    fn f32_storage_stores_single_precision() {
        let info = StorageInfo::new([2, 2, 2], [(0, 0); 3]).with_dtype(DType::F32);
        let mut s = Storage::zeros(info);
        assert_eq!(s.dtype(), DType::F32);
        // 0.1 is inexact: the f32 round-trip must differ from f64 by the
        // rounding error, proving the buffer really is 4 bytes wide.
        s.set(0, 0, 0, 0.1);
        assert_eq!(s.get(0, 0, 0), 0.1f32 as f64);
        assert_ne!(s.get(0, 0, 0), 0.1f64);
        assert_eq!(s.get_t::<f32>(0, 0, 0), 0.1f32);
        s.set_t::<f32>(1, 1, 1, 2.5f32);
        assert_eq!(s.get(1, 1, 1), 2.5);
        assert_eq!(s.raw_t::<f32>().len(), s.info.len());
    }

    #[test]
    fn domain_hash_is_dtype_salted() {
        // Integer values representable exactly in both widths: the values
        // agree, the hashes must not (native-width bit patterns).
        let f64s = Storage::from_fn([3, 3, 2], 0, |i, j, k| (i + j + k) as f64);
        let mut f32s =
            Storage::zeros(StorageInfo::new([3, 3, 2], [(0, 0); 3]).with_dtype(DType::F32));
        for i in 0..3 {
            for j in 0..3 {
                for k in 0..2 {
                    f32s.set(i, j, k, (i + j + k) as f64);
                }
            }
        }
        assert_eq!(f64s.max_abs_diff(&f32s), 0.0);
        assert_ne!(f64s.domain_hash(), f32s.domain_hash());
    }

    #[test]
    fn rel_l2_error_norm() {
        let a = Storage::from_fn([2, 2, 1], 0, |_, _, _| 2.0);
        let b = Storage::from_fn([2, 2, 1], 0, |_, _, _| 1.0);
        assert_eq!(a.rel_l2_error(&a), 0.0);
        assert_eq!(a.rel_l2_error(&b), 1.0); // ||2-1||/||1|| per element
        let z = Storage::with_halo([2, 2, 1], 0);
        assert_eq!(z.rel_l2_error(&z), 0.0);
        assert_eq!(a.rel_l2_error(&z), f64::INFINITY);
    }

    #[test]
    #[should_panic(expected = "dtype mismatch")]
    fn typed_access_rejects_wrong_dtype() {
        let s = Storage::with_halo([2, 2, 1], 0);
        let _ = s.raw_t::<f32>();
    }
}
