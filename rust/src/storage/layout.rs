//! Memory layouts for 3-D storages.
//!
//! The paper's `storage` containers customize "address space, layout,
//! alignment and padding" per backend. We implement the layout/alignment/
//! padding triple for host memory: the dimension order determines which
//! axis is stride-1, and the innermost dimension may be padded so rows
//! start at an alignment boundary (the GridTools trick enabling aligned
//! vector loads).

use std::fmt;

/// Order of dimensions from outermost to innermost (stride-1 last).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Layout {
    /// C-order for (I, J, K): K is stride-1 — the natural layout for
    /// vertical (column) algorithms.
    IJK,
    /// K outermost, I stride-1 — the natural layout for horizontal-plane
    /// vectorization (used by the `vector` backend).
    KJI,
    /// J outermost (I stride-1) — exercised in tests for generality.
    JKI,
}

impl Layout {
    /// Default layout for a backend name (mirrors GT4Py's per-backend
    /// storage defaults).
    pub fn for_backend(backend: &str) -> Layout {
        match backend {
            "debug" => Layout::IJK,
            "vector" => Layout::KJI,
            // XLA literals are row-major C-order over (I, J, K).
            "xla" | "pjrt-aot" => Layout::IJK,
            _ => Layout::IJK,
        }
    }

    /// Permutation mapping (i, j, k) to (outer, mid, inner).
    pub fn axes(&self) -> [usize; 3] {
        match self {
            Layout::IJK => [0, 1, 2],
            Layout::KJI => [2, 1, 0],
            Layout::JKI => [1, 2, 0],
        }
    }

    /// Strides (in elements) for the given *padded* per-axis sizes.
    /// `padded[axis]` is the allocated size along `axis` (i=0, j=1, k=2).
    pub fn strides(&self, padded: [usize; 3]) -> [usize; 3] {
        let order = self.axes();
        let mut strides = [0usize; 3];
        let mut s = 1usize;
        for &ax in order.iter().rev() {
            strides[ax] = s;
            s *= padded[ax];
        }
        strides
    }

    /// The innermost (stride-1) axis index.
    pub fn inner_axis(&self) -> usize {
        self.axes()[2]
    }
}

impl fmt::Display for Layout {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Layout::IJK => write!(f, "IJK"),
            Layout::KJI => write!(f, "KJI"),
            Layout::JKI => write!(f, "JKI"),
        }
    }
}

/// Alignment (in elements) applied to the innermost padded dimension.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Alignment(pub usize);

impl Default for Alignment {
    fn default() -> Self {
        // 64 bytes / 8-byte elements: one cache line of f64.
        Alignment(8)
    }
}

impl Alignment {
    /// Round `n` up to the alignment.
    pub fn pad(&self, n: usize) -> usize {
        if self.0 <= 1 {
            return n;
        }
        n.div_ceil(self.0) * self.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strides_ijk() {
        // (I,J,K) C-order over padded sizes (4, 5, 6): k stride 1,
        // j stride 6, i stride 30.
        let s = Layout::IJK.strides([4, 5, 6]);
        assert_eq!(s, [30, 6, 1]);
    }

    #[test]
    fn strides_kji() {
        // K outermost, I innermost over (4, 5, 6): i stride 1, j stride 4,
        // k stride 20.
        let s = Layout::KJI.strides([4, 5, 6]);
        assert_eq!(s, [1, 4, 20]);
    }

    #[test]
    fn strides_jki() {
        let s = Layout::JKI.strides([4, 5, 6]);
        // order (j, k, i): i stride 1, k stride 4, j stride 24.
        assert_eq!(s, [1, 24, 4]);
    }

    #[test]
    fn alignment_pads_up() {
        let a = Alignment(8);
        assert_eq!(a.pad(1), 8);
        assert_eq!(a.pad(8), 8);
        assert_eq!(a.pad(9), 16);
        assert_eq!(Alignment(1).pad(7), 7);
    }

    #[test]
    fn backend_defaults() {
        assert_eq!(Layout::for_backend("vector"), Layout::KJI);
        assert_eq!(Layout::for_backend("xla"), Layout::IJK);
    }
}
