//! PJRT runtime: load/compile XLA computations and execute them from the
//! Rust hot path (no Python at run time).
//!
//! Two entry points, matching the two compiled backends:
//! * [`Runtime::load_hlo_text`] — load an AOT artifact produced by
//!   `python/compile/aot.py` (HLO *text*: the image's xla_extension 0.5.1
//!   rejects jax≥0.5 serialized protos, see DESIGN.md);
//! * [`Runtime::compile`] — JIT-compile an [`xla::XlaComputation`] built by
//!   the `xla` codegen backend.

use anyhow::{anyhow, Context, Result};
use std::mem::ManuallyDrop;
use std::path::Path;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex, Once, OnceLock};

/// One process-wide lock serializing every PJRT FFI call made through
/// this module (client creation, compilation, execution, *and* the FFI
/// destructors — [`Runtime`] and [`Executable`] drop their handles under
/// it). The backends' `Send`/`Sync` assertions rest on it: even when
/// several backend instances share one [`Runtime`] clone, all use of the
/// underlying client funnels through these entry points and is therefore
/// mutually exclusive — each backend's own mutex alone could not
/// guarantee that.
fn pjrt_lock() -> &'static Mutex<()> {
    static LOCK: OnceLock<Mutex<()>> = OnceLock::new();
    LOCK.get_or_init(|| Mutex::new(()))
}

/// Whether a PJRT CPU client can be created in this process. Probed once
/// and cached; used by the compiled backends to report a structured
/// "backend unavailable" error and by the test suites to skip cleanly
/// instead of erroring when no PJRT runtime exists.
pub fn pjrt_available() -> bool {
    static PROBE: Once = Once::new();
    static AVAILABLE: AtomicBool = AtomicBool::new(false);
    PROBE.call_once(|| {
        let _serial = pjrt_lock().lock().unwrap();
        if xla::PjRtClient::cpu().is_ok() {
            AVAILABLE.store(true, Ordering::SeqCst);
        }
    });
    AVAILABLE.load(Ordering::SeqCst)
}

/// Test-suite helper: returns `true` (after logging a SKIP line) when no
/// PJRT runtime is available, so PJRT-dependent tests degrade to a clean
/// skip instead of erroring.
pub fn skip_test_without_pjrt(test: &str) -> bool {
    if pjrt_available() {
        return false;
    }
    eprintln!("SKIP {test}: PJRT runtime unavailable");
    true
}

/// Shared PJRT CPU client. The handle is reference-counted with an `Arc`
/// (atomic refcounts) so clones may be parked inside backends that assert
/// `Send`/`Sync` and serialize all client *use* behind a lock — see the
/// safety notes on [`crate::backend::xlagen::XlaBackend`]. The client is
/// held in `ManuallyDrop` so the FFI destructor (which runs when the last
/// `Arc` clone goes away, on whatever thread that happens) also executes
/// under [`pjrt_lock`].
#[derive(Clone)]
pub struct Runtime {
    client: ManuallyDrop<Arc<xla::PjRtClient>>,
}

impl Drop for Runtime {
    fn drop(&mut self) {
        // Tolerate poisoning: panicking inside drop would abort.
        let _serial = pjrt_lock().lock().unwrap_or_else(|p| p.into_inner());
        // SAFETY: dropped exactly once, here, under the FFI lock.
        unsafe { ManuallyDrop::drop(&mut self.client) }
    }
}

impl Runtime {
    /// Create a runtime on the PJRT CPU client.
    // The client is deliberately Arc'd despite being `!Send`/`!Sync` at
    // the binding level: all cross-thread use is serialized by the
    // backends (see `backend::xlagen::XlaBackend`'s safety notes).
    #[allow(clippy::arc_with_non_send_sync)]
    pub fn cpu() -> Result<Runtime> {
        let _serial = pjrt_lock().lock().unwrap();
        let client =
            xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT CPU client: {e:?}"))?;
        Ok(Runtime { client: ManuallyDrop::new(Arc::new(client)) })
    }

    pub fn platform(&self) -> String {
        let _serial = pjrt_lock().lock().unwrap();
        self.client.platform_name()
    }

    /// Load + compile an HLO text artifact.
    pub fn load_hlo_text(&self, path: impl AsRef<Path>) -> Result<Executable> {
        let _serial = pjrt_lock().lock().unwrap();
        let path = path.as_ref();
        let proto = xla::HloModuleProto::from_text_file(path)
            .map_err(|e| anyhow!("parsing HLO text {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.compile_locked(&comp)
            .with_context(|| format!("compiling artifact {}", path.display()))
    }

    /// JIT-compile a computation built with `XlaBuilder`.
    pub fn compile(&self, comp: &xla::XlaComputation) -> Result<Executable> {
        let _serial = pjrt_lock().lock().unwrap();
        self.compile_locked(comp)
    }

    /// Compilation body; caller holds [`pjrt_lock`].
    fn compile_locked(&self, comp: &xla::XlaComputation) -> Result<Executable> {
        let exe = self.client.compile(comp).map_err(|e| anyhow!("XLA compile: {e:?}"))?;
        Ok(Executable {
            exe: ManuallyDrop::new(exe),
            client: self.client.clone(),
        })
    }
}

/// An input argument for one execution.
pub enum Arg<'a> {
    /// f64 tensor: flat C-order data + dims.
    F64(&'a [f64], Vec<usize>),
    /// f64 scalar (rank 0).
    Scalar(f64),
}

/// A compiled, loaded executable. Both FFI handles are dropped under
/// [`pjrt_lock`] (see [`Runtime`]).
pub struct Executable {
    exe: ManuallyDrop<xla::PjRtLoadedExecutable>,
    client: ManuallyDrop<Arc<xla::PjRtClient>>,
}

impl Drop for Executable {
    fn drop(&mut self) {
        // Tolerate poisoning: panicking inside drop would abort.
        let _serial = pjrt_lock().lock().unwrap_or_else(|p| p.into_inner());
        // SAFETY: dropped exactly once, here, under the FFI lock.
        unsafe {
            ManuallyDrop::drop(&mut self.exe);
            ManuallyDrop::drop(&mut self.client);
        }
    }
}

impl Executable {
    /// Execute with host arguments, returning each output flattened to f64
    /// (C-order). Tuple outputs (jax `return_tuple=True`) are decomposed.
    pub fn run_f64(&self, args: &[Arg]) -> Result<Vec<Vec<f64>>> {
        let _serial = pjrt_lock().lock().unwrap();
        // Stage inputs as device buffers (avoids a literal copy).
        let mut buffers = Vec::with_capacity(args.len());
        for a in args {
            let buf = match a {
                Arg::F64(data, dims) => self
                    .client
                    .buffer_from_host_buffer::<f64>(data, dims, None)
                    .map_err(|e| anyhow!("host->device transfer: {e:?}"))?,
                Arg::Scalar(v) => self
                    .client
                    .buffer_from_host_buffer::<f64>(&[*v], &[], None)
                    .map_err(|e| anyhow!("host->device transfer: {e:?}"))?,
            };
            buffers.push(buf);
        }
        let outputs = self
            .exe
            .execute_b::<xla::PjRtBuffer>(&buffers)
            .map_err(|e| anyhow!("execute: {e:?}"))?;
        let replica = outputs
            .into_iter()
            .next()
            .ok_or_else(|| anyhow!("no output replica"))?;
        let mut results = Vec::new();
        for buf in replica {
            let mut lit =
                buf.to_literal_sync().map_err(|e| anyhow!("device->host: {e:?}"))?;
            let ty = lit
                .primitive_type()
                .map_err(|e| anyhow!("literal type: {e:?}"))?;
            if ty == xla::PrimitiveType::Tuple {
                let parts =
                    lit.decompose_tuple().map_err(|e| anyhow!("tuple decompose: {e:?}"))?;
                for p in parts {
                    results.push(literal_to_f64(&p)?);
                }
            } else {
                results.push(literal_to_f64(&lit)?);
            }
        }
        Ok(results)
    }
}

fn literal_to_f64(lit: &xla::Literal) -> Result<Vec<f64>> {
    let ty = lit.ty().map_err(|e| anyhow!("literal type: {e:?}"))?;
    match ty {
        xla::ElementType::F64 => {
            lit.to_vec::<f64>().map_err(|e| anyhow!("literal read: {e:?}"))
        }
        xla::ElementType::F32 => {
            let conv = lit
                .convert(xla::PrimitiveType::F64)
                .map_err(|e| anyhow!("literal convert: {e:?}"))?;
            conv.to_vec::<f64>().map_err(|e| anyhow!("literal read: {e:?}"))
        }
        other => Err(anyhow!("unsupported output element type {other:?}")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runtime_builds_and_runs_builder_computation() {
        if skip_test_without_pjrt("runtime_builds_and_runs_builder_computation") {
            return;
        }
        let rt = Runtime::cpu().unwrap();
        assert_eq!(rt.platform(), "cpu");
        // sqrt(x + x) with x = 12.5 -> 5
        let builder = xla::XlaBuilder::new("t");
        let shape = xla::Shape::array::<f64>(vec![]);
        let p = builder.parameter_s(0, &shape, "x").unwrap();
        let comp = p.add_(&p).unwrap().sqrt().unwrap().build().unwrap();
        let exe = rt.compile(&comp).unwrap();
        let out = exe.run_f64(&[Arg::Scalar(12.5)]).unwrap();
        assert_eq!(out.len(), 1);
        assert!((out[0][0] - 5.0).abs() < 1e-12);
    }

    #[test]
    fn runtime_runs_tensor_computation() {
        if skip_test_without_pjrt("runtime_runs_tensor_computation") {
            return;
        }
        let rt = Runtime::cpu().unwrap();
        let builder = xla::XlaBuilder::new("t2");
        let shape = xla::Shape::array::<f64>(vec![2, 3]);
        let p = builder.parameter_s(0, &shape, "x").unwrap();
        let two = builder.c0(2.0f64).unwrap();
        let comp = p.mul_(&two).unwrap().build().unwrap();
        let exe = rt.compile(&comp).unwrap();
        let data: Vec<f64> = (0..6).map(|v| v as f64).collect();
        let out = exe.run_f64(&[Arg::F64(&data, vec![2, 3])]).unwrap();
        assert_eq!(out[0], vec![0.0, 2.0, 4.0, 6.0, 8.0, 10.0]);
    }

    #[test]
    fn missing_artifact_is_error() {
        if skip_test_without_pjrt("missing_artifact_is_error") {
            return;
        }
        let rt = Runtime::cpu().unwrap();
        assert!(rt.load_hlo_text("/nonexistent/file.hlo.txt").is_err());
    }
}
