//! A6: intra-call domain-sharding scaling curve — the multi-core half of
//! the paper's Fig. 3 CPU story (`gt:cpu_kfirst`/`gt:cpu_ifirst` scale
//! with OpenMP threads; here one `vector`-backend call scales with
//! i-slabs on std threads).
//!
//! For the fused O3 evaluator (and the O2 materializing path as a
//! contrast row) this sweeps `Threads(1/2/4/8)` plus `Auto`, measuring
//! median wall time per call, the *effective* thread count the schedule
//! used, buffer-pool traffic, and per-call halo-rendezvous crossings /
//! serial fallbacks (the `vadv_carry` rows prove the old
//! sequential-carry serial fallback is gone). Before any timing, every
//! sharded configuration is checked **bitwise** against `Sharding::Off`
//! on fresh inputs — a scaling curve for a parallel schedule that
//! changed the answer would be worthless.
//!
//!     cargo bench --bench scaling [-- --tiny] [-- --json PATH]
//!
//! `--tiny` shrinks the domain/iterations for CI smoke runs (where
//! `Auto` must degrade to serial — that degradation is itself asserted);
//! `--json PATH` writes every measured row as a JSON array, the
//! `BENCH_scaling.json` CI artifact published next to
//! `BENCH_ablation.json`.

#[path = "harness.rs"]
mod harness;

use gt4rs::backend::shard::Sharding;
use gt4rs::backend::vector::VectorBackend;
use gt4rs::backend::{Backend, RunConfig, StencilArgs};
use gt4rs::opt::{OptConfig, OptLevel, PassManager};
use gt4rs::stdlib;
use gt4rs::storage::Storage;
use gt4rs::StencilIr;
use harness::*;

struct Row {
    stencil: String,
    domain: String,
    opt: &'static str,
    config: String,
    threads_used: u32,
    median_ns: u128,
    speedup_vs_t1: f64,
    pool_taken: u64,
    pool_allocated: u64,
    /// Per-call halo-rendezvous crossings (0 on sync-free plans).
    exchanges: u64,
    /// Per-call serial-fallback multistages (the scaling-regression
    /// gate fails CI when a carry kernel reports these at threads=4).
    serial_fallbacks: u64,
}

impl Row {
    fn json(&self) -> String {
        format!(
            "{{\"bench\":\"A6\",\"stencil\":\"{}\",\"domain\":\"{}\",\"opt\":\"{}\",\
             \"config\":\"{}\",\"threads_used\":{},\"median_ns\":{},\
             \"speedup_vs_t1\":{:.4},\"pool_taken\":{},\"pool_allocated\":{},\
             \"exchanges\":{},\"serial_fallbacks\":{}}}",
            self.stencil,
            self.domain,
            self.opt,
            self.config,
            self.threads_used,
            self.median_ns,
            self.speedup_vs_t1,
            self.pool_taken,
            self.pool_allocated,
            self.exchanges,
            self.serial_fallbacks
        )
    }
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let tiny = args.iter().any(|a| a == "--tiny");
    let json_path = args
        .iter()
        .position(|a| a == "--json")
        .and_then(|p| args.get(p + 1))
        .cloned();

    // The tiny domain is deliberately narrower than one profitable Auto
    // slab (MIN_AUTO_SLAB_WIDTH): the smoke run asserts the degrade.
    let (domain, iters): ([usize; 3], usize) =
        if tiny { ([16, 16, 8], 3) } else { ([128, 128, 64], 9) };

    let mut rows: Vec<Row> = Vec::new();
    a6_scaling(domain, iters, tiny, &mut rows);

    if let Some(path) = json_path {
        let body: Vec<String> = rows.iter().map(Row::json).collect();
        let doc = format!("[\n  {}\n]\n", body.join(",\n  "));
        std::fs::write(&path, doc).expect("write scaling JSON artifact");
        println!("# wrote {} rows to {path}", rows.len());
    }
}

fn compiled(name: &str, level: OptLevel) -> StencilIr {
    let mut ir = stdlib::compile(name).unwrap();
    PassManager::new(&OptConfig::level(level)).run(&mut ir);
    ir
}

/// Fresh deterministically-filled storages for `ir` over `domain`.
fn fresh_fields(ir: &StencilIr, domain: [usize; 3]) -> Vec<(String, Storage)> {
    ir.fields
        .iter()
        .enumerate()
        .map(|(ix, f)| {
            let e = f.extent;
            let mut s = Storage::zeros(gt4rs::storage::StorageInfo::new(
                domain,
                [
                    ((-e.i.0) as usize, e.i.1 as usize),
                    ((-e.j.0) as usize, e.j.1 as usize),
                    ((-e.k.0) as usize, e.k.1 as usize),
                ],
            ));
            fill_storage(&mut s, 1.0 + ix as f64 * 0.5);
            (f.name.clone(), s)
        })
        .collect()
}

/// Run once on fresh inputs, returning every field's domain-sum bits —
/// the honesty fingerprint a sharded configuration must reproduce.
fn run_once_sums(
    be: &VectorBackend,
    ir: &StencilIr,
    domain: [usize; 3],
    scalars: &[(&str, f64)],
    sharding: Sharding,
) -> (Vec<u64>, u32) {
    let mut fields = fresh_fields(ir, domain);
    let report = {
        let mut refs: Vec<(&str, &mut Storage)> =
            fields.iter_mut().map(|(n, s)| (n.as_str(), s)).collect();
        be.run_sharded(
            ir,
            &mut StencilArgs { fields: &mut refs, scalars, domain },
            &RunConfig { sharding, ..RunConfig::default() },
        )
        .unwrap()
    };
    let sums = fields.iter().map(|(_, s)| s.domain_sum().to_bits()).collect();
    (sums, report.threads)
}

fn a6_scaling(domain: [usize; 3], iters: usize, tiny: bool, rows: &mut Vec<Row>) {
    let dstr = format!("{}x{}x{}", domain[0], domain[1], domain[2]);
    println!("# A6: intra-call domain sharding — vector backend, median wall per call");
    println!(
        "{:<12} {:>8} {:>4} {:>12} {:>8} {:>12} {:>10}",
        "domain", "stencil", "opt", "config", "used", "median", "vs t=1"
    );
    // threads=1 is measured first so every later row's speedup_vs_t1 is
    // computed against a real baseline (never fabricated).
    let plans: [(String, Sharding); 6] = [
        ("threads=1".to_string(), Sharding::Threads(1)),
        ("off".to_string(), Sharding::Off),
        ("threads=2".to_string(), Sharding::Threads(2)),
        ("threads=4".to_string(), Sharding::Threads(4)),
        ("threads=8".to_string(), Sharding::Threads(8)),
        ("auto".to_string(), Sharding::Auto),
    ];
    // `vadv_carry` is the kernel that used to hit the serial fallback:
    // its rows prove the per-level halo exchange actually shards it
    // (threads_used > 1 with nonzero exchanges), which CI's
    // scaling-regression gate checks from the JSON artifact.
    for (name, scalars) in [
        ("hdiff", vec![]),
        ("vadv", vec![("dtdz", 0.3)]),
        ("vadv_carry", vec![("dtdz", 0.3)]),
    ] {
        for (opt_name, level) in [("O3", OptLevel::O3), ("O2", OptLevel::O2)] {
            let ir = compiled(name, level);
            let be = VectorBackend::new();
            // Honesty gate: every plan bitwise-equal to Off on fresh
            // inputs before a single timed iteration.
            let (reference, _) = run_once_sums(&be, &ir, domain, &scalars, Sharding::Off);
            for (_, plan) in &plans {
                let (sums, used) = run_once_sums(&be, &ir, domain, &scalars, *plan);
                assert_eq!(
                    sums, reference,
                    "{name} {opt_name} {plan}: sharded result diverged from serial"
                );
                if tiny && *plan == Sharding::Auto {
                    assert_eq!(
                        used, 1,
                        "Auto must degrade to serial on tiny domains (got {used})"
                    );
                }
            }
            let _ = be.take_pool_stats();
            let mut t1_median: Option<f64> = None;
            for (label, plan) in &plans {
                let mut fields = fresh_fields(&ir, domain);
                let mut calls = 0u64;
                let mut used = 1u32;
                let mut exchanges = 0u64;
                let sample = bench(iters, || {
                    calls += 1;
                    let mut refs: Vec<(&str, &mut Storage)> =
                        fields.iter_mut().map(|(n, s)| (n.as_str(), s)).collect();
                    let report = be
                        .run_sharded(
                            &ir,
                            &mut StencilArgs {
                                fields: &mut refs,
                                scalars: &scalars,
                                domain,
                            },
                            &RunConfig { sharding: *plan, ..RunConfig::default() },
                        )
                        .unwrap();
                    used = used.max(report.threads);
                    exchanges += report.exchanges;
                });
                let stats = be.take_pool_stats();
                if *label == "threads=1" {
                    t1_median = Some(sample.median.as_secs_f64());
                }
                let speedup = t1_median.expect("threads=1 measured first")
                    / sample.median.as_secs_f64().max(1e-12);
                println!(
                    "{dstr:<12} {name:>8} {opt_name:>4} {label:>12} {used:>8} {:>12} {speedup:>9.2}x",
                    fmt_duration(sample.median)
                );
                rows.push(Row {
                    stencil: name.to_string(),
                    domain: dstr.clone(),
                    opt: opt_name,
                    config: label.clone(),
                    threads_used: used,
                    median_ns: sample.median.as_nanos(),
                    speedup_vs_t1: speedup,
                    pool_taken: stats.taken / calls.max(1),
                    pool_allocated: stats.allocated / calls.max(1),
                    exchanges: exchanges / calls.max(1),
                    serial_fallbacks: stats.serial_fallbacks / calls.max(1),
                });
            }
        }
    }
    println!();
}
