//! A9: warm start from the persistent artifact store (`crate::persist`)
//! vs a cold dsl→analysis→opt pipeline run. For each library stencil ×
//! opt level this times two "fresh process" configurations:
//!
//! * `cold` — a brand-new coordinator with **no** cache attached:
//!   `compile_library` runs the full pipeline, `prepare("vector")`
//!   lowers the fused tape from scratch (at O3);
//! * `warm` — a brand-new coordinator + a fresh [`PersistStore`] handle
//!   over a pre-warmed cache directory: the IR comes back from disk
//!   (zero pipeline runs, asserted via the `pipeline_compiles` honesty
//!   counter every single iteration) and the O3 tape skips lowering.
//!
//! Honesty gates run before any timing: at **every** opt level O0–O3 the
//! warm-loaded artifact must produce *bitwise*-identical results to its
//! cold twin across executor tiers and sharding plans (the same matrix
//! `tests/persist_warmstart.rs` pins). A latency table for a cache that
//! changed the answer would be worthless.
//!
//!     cargo bench --bench warmstart [-- --tiny] [-- --json PATH]
//!
//! `--tiny` shrinks the stencil set/iterations for CI smoke runs;
//! `--json PATH` writes every measured row as a JSON array, the
//! `BENCH_warmstart.json` CI artifact published next to
//! `BENCH_kernels.json` and `BENCH_serve.json`.

#[path = "harness.rs"]
mod harness;

use gt4rs::coordinator::Coordinator;
use gt4rs::opt::{ExecOptions, OptLevel};
use gt4rs::persist::PersistStore;
use gt4rs::storage::{synthetic_fill, Storage};
use gt4rs::{ExecTier, Sharding};
use harness::*;
use std::sync::Arc;

const LEVELS: [OptLevel; 4] = [OptLevel::O0, OptLevel::O1, OptLevel::O2, OptLevel::O3];
/// The schedule matrix every warm artifact must agree with its cold
/// twin on (tiers only differentiate at O3; elsewhere they are free).
const SCHEDULES: [(ExecTier, Sharding); 3] = [
    (ExecTier::Interpreted, Sharding::Off),
    (ExecTier::Specialized, Sharding::Off),
    (ExecTier::Specialized, Sharding::Threads(2)),
];

struct Row {
    stencil: String,
    opt_level: String,
    phase: &'static str,
    median_ns: u128,
    speedup_warm_vs_cold: f64,
    /// Pipeline runs observed per timed call — 1 for cold, 0 for warm
    /// (asserted, then reported so the JSON artifact carries the proof).
    pipeline_compiles_per_call: u64,
}

impl Row {
    fn json(&self) -> String {
        format!(
            "{{\"bench\":\"A9\",\"stencil\":\"{}\",\"opt_level\":\"{}\",\
             \"phase\":\"{}\",\"median_ns\":{},\"speedup_warm_vs_cold\":{:.4},\
             \"pipeline_compiles_per_call\":{}}}",
            self.stencil,
            self.opt_level,
            self.phase,
            self.median_ns,
            self.speedup_warm_vs_cold,
            self.pipeline_compiles_per_call
        )
    }
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let tiny = args.iter().any(|a| a == "--tiny");
    let json_path = args
        .iter()
        .position(|a| a == "--json")
        .and_then(|p| args.get(p + 1))
        .cloned();

    let (stencils, iters): (&[&str], usize) =
        if tiny { (&["hdiff"], 3) } else { (&["hdiff", "vadv", "diffuse"], 9) };

    honesty_gate(stencils);

    let mut rows: Vec<Row> = Vec::new();
    a9_warmstart(stencils, iters, &mut rows);

    if let Some(path) = json_path {
        let body: Vec<String> = rows.iter().map(Row::json).collect();
        let doc = format!("[\n  {}\n]\n", body.join(",\n  "));
        std::fs::write(&path, doc).expect("write warmstart JSON artifact");
        println!("# wrote {} rows to {path}", rows.len());
    }
}

fn scratch_dir(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("gt4rs_bench_ws_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn coordinator(level: OptLevel, store: Option<&Arc<PersistStore>>) -> Coordinator {
    let mut c = Coordinator::new();
    c.set_exec_options(ExecOptions::new().with_opt_level(level));
    if let Some(store) = store {
        c.set_persist(store.clone());
    }
    c
}

/// Run `fp` on the vector backend under one schedule; returns
/// `(name, sum_bits, hash)` digests in declaration order.
fn run_digests(
    coord: &mut Coordinator,
    fp: u64,
    tier: ExecTier,
    sharding: Sharding,
) -> Vec<(String, u64, u64)> {
    let stencil = coord.stencil_for(fp, "vector").unwrap();
    let domain = [10, 9, 6];
    let mut fields: Vec<(String, Storage)> = Vec::new();
    for (idx, f) in stencil.ir().fields.iter().enumerate() {
        let mut s = stencil.alloc_field(&f.name, domain).unwrap();
        synthetic_fill(&mut s, idx as f64);
        fields.push((f.name.clone(), s));
    }
    let scalars: Vec<(String, f64)> =
        stencil.ir().scalars.iter().map(|s| (s.name.clone(), 0.1)).collect();
    let mut inv = stencil
        .bind()
        .domain(domain)
        .fields(&fields)
        .scalars(&scalars)
        .finish()
        .unwrap();
    inv.set_exec_tier(tier);
    inv.set_sharding(sharding);
    let mut refs: Vec<&mut Storage> = fields.iter_mut().map(|(_, s)| s).collect();
    inv.run(&mut refs).unwrap();
    fields
        .iter()
        .map(|(n, s)| (n.clone(), s.domain_sum().to_bits(), s.domain_hash()))
        .collect()
}

/// Warm artifacts must be bitwise-indistinguishable from cold compiles
/// at every opt level × executor tier × sharding plan before a single
/// timed iteration runs.
fn honesty_gate(stencils: &[&str]) {
    let dir = scratch_dir("gate");
    for level in LEVELS {
        let store = Arc::new(PersistStore::open(&dir).unwrap());
        let mut cold = coordinator(level, Some(&store));
        let mut expected = Vec::new();
        for name in stencils {
            let fp = cold.compile_library(name).unwrap();
            let runs: Vec<_> = SCHEDULES
                .iter()
                .map(|(tier, sharding)| run_digests(&mut cold, fp, *tier, *sharding))
                .collect();
            expected.push((*name, fp, runs));
        }
        drop(cold);
        drop(store);

        let store = Arc::new(PersistStore::open(&dir).unwrap());
        let mut warm = coordinator(level, Some(&store));
        for (name, fp, runs) in &expected {
            let fp2 = warm.compile_library(name).unwrap();
            assert_eq!(fp2, *fp, "O{level} {name}: warm cache key diverged");
            for ((tier, sharding), cold_digests) in SCHEDULES.iter().zip(runs) {
                let warm_digests = run_digests(&mut warm, fp2, *tier, *sharding);
                assert_eq!(
                    &warm_digests, cold_digests,
                    "O{level} {name} {tier:?}/{sharding:?}: warm run not bitwise-identical"
                );
            }
        }
        assert_eq!(warm.pipeline_compiles(), 0, "O{level}: warm gate pass ran the pipeline");
    }
    let _ = std::fs::remove_dir_all(&dir);
    println!("# honesty gate passed: warm == cold bitwise at O0-O3 x tier x sharding");
}

fn a9_warmstart(stencils: &[&str], iters: usize, rows: &mut Vec<Row>) {
    println!("# A9: persistent-store warm start vs cold pipeline compile (compile+prepare latency)");
    println!(
        "{:<10} {:>6} {:>8} {:>12} {:>14}",
        "stencil", "level", "phase", "median", "warm-vs-cold"
    );
    for name in stencils {
        for level in LEVELS {
            // Pre-warm a cache directory once; the warm phase reopens it
            // with a fresh store handle + coordinator every iteration.
            let dir = scratch_dir("time");
            {
                let store = Arc::new(PersistStore::open(&dir).unwrap());
                let mut c = coordinator(level, Some(&store));
                let fp = c.compile_library(name).unwrap();
                c.prepare(fp, "vector").unwrap();
            }

            let cold = bench(iters, || {
                let mut c = coordinator(level, None);
                let fp = c.compile_library(name).unwrap();
                c.prepare(fp, "vector").unwrap();
                assert_eq!(c.pipeline_compiles(), 1, "cold call must run the pipeline");
            });
            let warm = bench(iters, || {
                let store = Arc::new(PersistStore::open(&dir).unwrap());
                let mut c = coordinator(level, Some(&store));
                let fp = c.compile_library(name).unwrap();
                c.prepare(fp, "vector").unwrap();
                assert_eq!(c.pipeline_compiles(), 0, "warm call must skip the pipeline");
            });
            let _ = std::fs::remove_dir_all(&dir);

            let speedup =
                cold.median.as_secs_f64() / warm.median.as_secs_f64().max(1e-12);
            for (phase, sample, pipeline) in
                [("cold", cold, 1u64), ("warm", warm, 0u64)]
            {
                println!(
                    "{name:<10} {:>6} {phase:>8} {:>12} {:>13.2}x",
                    format!("O{level}"),
                    fmt_duration(sample.median),
                    if phase == "warm" { speedup } else { 1.0 },
                );
                rows.push(Row {
                    stencil: name.to_string(),
                    opt_level: format!("O{level}"),
                    phase,
                    median_ns: sample.median.as_nanos(),
                    speedup_warm_vs_cold: if phase == "warm" { speedup } else { 1.0 },
                    pipeline_compiles_per_call: pipeline,
                });
            }
        }
    }
    println!();
}
